//! Integration: the PTQ stack (calibration + weight projection + measured
//! INT8 accuracy) against the real artifacts.

mod common;

use hqp::hqp::{ptq, HqpConfig};
use hqp::quant::{CalibMethod, Calibrator};
use hqp::runtime::{Session, Workspace};

#[test]
fn ptq_produces_valid_scales_and_grid_weights() {
    let ws = Workspace::open(common::require_artifacts()).unwrap();
    let mut sess = Session::new(&ws, "resnet18").unwrap();
    let cfg = HqpConfig::default();
    let params = sess.baseline.clone();
    let res = ptq::quantize(&mut sess, &params, &cfg).unwrap();

    assert_eq!(res.scales.len(), sess.mm.taps.len());
    assert!(res.scales.iter().all(|&s| s > 0.0 && s.is_finite()));
    assert!(res.thresholds.iter().all(|&t| t > 0.0));

    // every quantized weight tensor lies exactly on its int8 grid
    for spec in &sess.mm.param_order.clone() {
        if !spec.name.ends_with(".w") {
            continue;
        }
        let w = res.params.get(&spec.name).unwrap();
        let s = w.absmax() / 127.0;
        if s == 0.0 {
            continue;
        }
        for &v in w.data().iter().take(200) {
            let q = v / s;
            assert!(
                (q - q.round()).abs() < 1e-3,
                "{}: {v} not on grid (s={s})",
                spec.name
            );
        }
    }
    // and accuracy is sane (measured through the Pallas quant_eval path)
    assert!(res.accuracy > 0.5, "int8 accuracy collapsed: {}", res.accuracy);
}

#[test]
fn kl_calibration_never_exceeds_minmax_threshold() {
    let ws = Workspace::open(common::require_artifacts()).unwrap();
    let mut sess = Session::new(&ws, "resnet18").unwrap();
    let params = sess.baseline.clone();
    let ranges = sess.act_absmax(&params).unwrap();
    let hist = sess.act_hist(&params, &ranges).unwrap();
    let bins = hist.shape()[1];
    let kl = Calibrator::new(CalibMethod::Kl);
    let mm = Calibrator::new(CalibMethod::MinMax);
    for (i, &r) in ranges.iter().enumerate() {
        let row = &hist.data()[i * bins..(i + 1) * bins];
        let t_kl = kl.threshold(row, r);
        let t_mm = mm.threshold(row, r);
        assert!(t_kl <= t_mm + 1e-6, "tap {i}: KL {t_kl} > minmax {t_mm}");
        assert!(t_kl > 0.0);
    }
}

#[test]
fn per_channel_weights_do_not_hurt_accuracy() {
    let ws = Workspace::open(common::require_artifacts()).unwrap();
    let mut sess = Session::new(&ws, "resnet18").unwrap();
    let params = sess.baseline.clone();
    let mut cfg = HqpConfig::default();
    let pt = ptq::quantize(&mut sess, &params, &cfg).unwrap();
    cfg.per_channel_weights = true;
    let pc = ptq::quantize(&mut sess, &params, &cfg).unwrap();
    // Per-channel scales isolate per-filter outliers; they can only help
    // (allow a tiny tolerance for rounding luck).
    assert!(
        pc.accuracy >= pt.accuracy - 0.01,
        "per-channel {:.4} much worse than per-tensor {:.4}",
        pc.accuracy,
        pt.accuracy
    );
}

#[test]
fn minmax_calibration_is_not_better_than_kl() {
    // The paper's premise: naive minmax activation ranges are vulnerable to
    // outliers; KL should match or beat them on accuracy.
    let ws = Workspace::open(common::require_artifacts()).unwrap();
    let mut sess = Session::new(&ws, "resnet18").unwrap();
    let params = sess.baseline.clone();
    let mut cfg = HqpConfig::default();
    cfg.calib_method = CalibMethod::Kl;
    let kl = ptq::quantize(&mut sess, &params, &cfg).unwrap();
    cfg.calib_method = CalibMethod::MinMax;
    let mm = ptq::quantize(&mut sess, &params, &cfg).unwrap();
    assert!(
        kl.accuracy >= mm.accuracy - 0.015,
        "KL {:.4} should not lose to minmax {:.4} by a wide margin",
        kl.accuracy,
        mm.accuracy
    );
}
