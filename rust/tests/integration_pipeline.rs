//! Integration: the full HQP pipeline (Algorithm 1 + PTQ + deployment)
//! end-to-end against the real artifacts.
//!
//! Uses a coarsened config (larger δ, fewer calib samples) so the whole
//! file runs in a couple of minutes on the single-core CPU — the
//! paper-parameter runs live in the benches.

mod common;

use hqp::graph::Graph;
use hqp::hqp::{deploy, pipeline, prune, sensitivity, HqpConfig, RankingMethod};
use hqp::hwsim::Device;
use hqp::runtime::{Session, Workspace};

fn fast_cfg() -> HqpConfig {
    HqpConfig {
        // 2% steps: a handful of validation sweeps, while small enough
        // that the first step stays inside Δ_max on these lean models
        // (the substituted models carry far less redundancy than the
        // paper's ImageNet-scale ones — see EXPERIMENTS.md).
        delta_step_frac: 0.02,
        calib_samples: 128,
        ..Default::default()
    }
}

#[test]
fn conditional_prune_respects_delta_max_and_monotonicity() {
    let ws = Workspace::open(common::require_artifacts()).unwrap();
    let mut sess = Session::new(&ws, "resnet18").unwrap();
    let cfg = fast_cfg();
    let baseline = sess.baseline.clone();
    let base_acc = sess.accuracy(&baseline, "val").unwrap();
    let sal =
        sensitivity::compute(&mut sess, &baseline, RankingMethod::Fisher, cfg.calib_samples)
            .unwrap();
    let res = prune::conditional_prune(&mut sess, &baseline, base_acc, &sal, &cfg).unwrap();

    // Algorithm 1 guarantee: the ACCEPTED model satisfies the constraint.
    assert!(
        base_acc - res.accuracy <= cfg.delta_max + 1e-9,
        "constraint violated: {} -> {}",
        base_acc,
        res.accuracy
    );
    // Trace invariants: sparsity strictly increases; only the last step may
    // be rejected.
    let steps = &res.trace.steps;
    assert!(!steps.is_empty());
    for w in steps.windows(2) {
        assert!(w[1].masked > w[0].masked);
    }
    for (i, s) in steps.iter().enumerate() {
        if i + 1 < steps.len() {
            assert!(s.accepted, "only the final step may be rejected");
        }
    }
    // masks agree with the sparsity accounting
    let masked: usize = res
        .masks
        .iter()
        .map(|m| m.iter().filter(|&&k| !k).count())
        .sum();
    assert_eq!(masked as f64 / sess.mm.total_filters() as f64, res.sparsity);
    // masked params are actually zero
    let nz_before = baseline.num_zero();
    assert!(res.params.num_zero() > nz_before);
}

#[test]
fn hqp_beats_q8_and_p50_on_the_deployed_engine() {
    // The core table-shape invariant: HQP (prune+int8) must deploy faster
    // than Q8-only, which must deploy faster than baseline; P50 (fp32)
    // sits between baseline and the int8 engines on NX.
    let ws = Workspace::open(common::require_artifacts()).unwrap();
    let mut sess = Session::new(&ws, "resnet18").unwrap();
    let cfg = fast_cfg();
    let dev = Device::xavier_nx();
    let graph = Graph::from_manifest(&sess.mm).unwrap();

    let base = pipeline::run_baseline(&mut sess).unwrap();
    let q8 = pipeline::run_q8(&mut sess, &cfg).unwrap();
    let hqp = pipeline::run_hqp(&mut sess, &cfg).unwrap();

    let r_base = deploy::report(&graph, &base, &dev, cfg.delta_max).unwrap();
    let r_q8 = deploy::report(&graph, &q8, &dev, cfg.delta_max).unwrap();
    let r_hqp = deploy::report(&graph, &hqp, &dev, cfg.delta_max).unwrap();

    assert!((r_base.speedup - 1.0).abs() < 1e-9);
    assert!(r_q8.speedup > 1.0, "q8 speedup {}", r_q8.speedup);
    assert!(
        r_hqp.speedup >= r_q8.speedup,
        "hqp {} must be at least q8 {}",
        r_hqp.speedup,
        r_q8.speedup
    );
    // energy identity (paper §V-E)
    assert!((r_hqp.energy_ratio - r_hqp.speedup).abs() < 1e-9);
    // HQP pruned something
    assert!(hqp.sparsity > 0.0);
}

#[test]
fn p50_magnitude_pruning_has_no_quality_guarantee() {
    // P50 prunes to 50 % unconditionally; its drop is whatever it is
    // (the paper's point: usually larger than HQP's), while HQP's FP32
    // sparse model must stay within Δ_max by construction.
    let ws = Workspace::open(common::require_artifacts()).unwrap();
    let mut sess = Session::new(&ws, "resnet18").unwrap();
    let cfg = fast_cfg();
    let p50 = pipeline::run_p50(&mut sess, 0.5).unwrap();
    assert!((p50.sparsity - 0.5).abs() < 0.01);
    let prune_only = pipeline::run_hqp_prune_only(&mut sess, &cfg).unwrap();
    assert!(prune_only.compliant(cfg.delta_max));
    assert!(
        p50.acc_drop() >= prune_only.acc_drop() - 0.005,
        "unconditional 50% magnitude pruning (drop {:.4}) should not beat \
         the constraint-bound fisher loop (drop {:.4})",
        p50.acc_drop(),
        prune_only.acc_drop()
    );
}

#[test]
fn rankings_differ_and_random_is_worst_at_matched_sparsity() {
    let ws = Workspace::open(common::require_artifacts()).unwrap();
    let mut sess = Session::new(&ws, "resnet18").unwrap();
    let baseline = sess.baseline.clone();
    let theta = 0.3;
    let acc_of = |sess: &mut Session, method: RankingMethod| {
        let sal = sensitivity::compute(sess, &baseline, method, 128).unwrap();
        prune::prune_to_sparsity(sess, &baseline, &sal, theta)
            .unwrap()
            .accuracy
    };
    let fisher = acc_of(&mut sess, RankingMethod::Fisher);
    let random = acc_of(&mut sess, RankingMethod::Random(7));
    // Fisher must beat random pruning at the same sparsity — the premise of
    // sensitivity-aware pruning. (Magnitude may land anywhere in between.)
    assert!(
        fisher > random - 0.005,
        "fisher {fisher:.4} should not lose to random {random:.4}"
    );
}

#[test]
fn schedule_presets_match_the_pre_schedule_implementations() {
    // the api_redesign acceptance criterion, pinned against the *old
    // code*, not against itself: each preset's outcome (accuracies,
    // masks, scales, trace) and session counters must be byte-identical
    // to an inline replica of the pre-schedule free-function bodies
    // (the literal run_q8/run_p50/run_hqp implementations this PR
    // replaced), built from the still-public primitives.
    use hqp::hqp::{ptq, Schedule};
    let ws = Workspace::open(common::require_artifacts()).unwrap();
    let cfg = fast_cfg();

    let steps = |t: &hqp::hqp::PruneTrace| -> Vec<(usize, f64, f64, bool)> {
        t.steps.iter().map(|s| (s.masked, s.sparsity, s.accuracy, s.accepted)).collect()
    };

    // ---- legacy run_hqp replica ------------------------------------------
    let mut old = Session::new(&ws, "resnet18").unwrap();
    let baseline = old.baseline.clone();
    let baseline_acc = old.accuracy(&baseline, "val").unwrap();
    let sal = sensitivity::compute(&mut old, &baseline, cfg.ranking, cfg.calib_samples).unwrap();
    let pruned = prune::conditional_prune(&mut old, &baseline, baseline_acc, &sal, &cfg).unwrap();
    let quant = ptq::quantize(&mut old, &pruned.params, &cfg).unwrap();

    let mut new = Session::new(&ws, "resnet18").unwrap();
    let o = Schedule::preset("hqp", &cfg).unwrap().run(&mut new, &cfg).unwrap();
    assert_eq!(o.method, "hqp");
    assert_eq!(o.baseline_acc, baseline_acc);
    assert_eq!(o.accuracy, quant.accuracy);
    assert_eq!(o.masks, pruned.masks);
    assert_eq!(o.sparsity, pruned.sparsity);
    assert_eq!(o.scales.as_deref(), Some(quant.scales.as_slice()));
    assert_eq!(o.saliency_scores.as_deref(), Some(sal.scores.as_slice()));
    assert_eq!(steps(&o.trace), steps(&pruned.trace));
    assert_eq!(
        format!("{:?}", new.counters),
        format!("{:?}", old.counters),
        "hqp preset must issue exactly the legacy measurement sequence"
    );

    // ---- legacy run_q8 replica -------------------------------------------
    let mut old = Session::new(&ws, "resnet18").unwrap();
    let baseline = old.baseline.clone();
    let baseline_acc = old.accuracy(&baseline, "val").unwrap();
    let quant = ptq::quantize(&mut old, &baseline, &cfg).unwrap();
    let mut new = Session::new(&ws, "resnet18").unwrap();
    let o = Schedule::preset("q8-only", &cfg).unwrap().run(&mut new, &cfg).unwrap();
    assert_eq!(o.method, "q8-only");
    assert_eq!((o.baseline_acc, o.accuracy), (baseline_acc, quant.accuracy));
    assert_eq!(o.scales.as_deref(), Some(quant.scales.as_slice()));
    assert_eq!(o.sparsity, 0.0);
    assert_eq!(format!("{:?}", new.counters), format!("{:?}", old.counters));

    // ---- legacy run_p50 replica ------------------------------------------
    let mut old = Session::new(&ws, "resnet18").unwrap();
    let baseline = old.baseline.clone();
    let baseline_acc = old.accuracy(&baseline, "val").unwrap();
    let sal =
        sensitivity::compute(&mut old, &baseline, RankingMethod::MagnitudeL1, 0).unwrap();
    let res = prune::prune_to_sparsity(&mut old, &baseline, &sal, 0.5).unwrap();
    let mut new = Session::new(&ws, "resnet18").unwrap();
    let o = Schedule::prune_only_at(0.5).run(&mut new, &cfg).unwrap();
    assert_eq!(o.method, "p50-only");
    assert_eq!((o.baseline_acc, o.accuracy), (baseline_acc, res.accuracy));
    assert_eq!(o.masks, res.masks);
    assert_eq!(o.sparsity, res.sparsity);
    assert_eq!(format!("{:?}", new.counters), format!("{:?}", old.counters));
}

#[test]
fn legacy_methods_and_their_presets_produce_byte_identical_rows() {
    // wiring check on the deprecated alias: run_method (the MethodSpec
    // entry point) and run_schedule on the lowered preset must assemble
    // byte-identical ResultRow files — guards the label/cache/row
    // plumbing and determinism across sessions (the true equivalence
    // against the pre-schedule implementation is pinned above)
    use hqp::coordinator::{run_method, run_schedule, save_results, MethodSpec};
    let ws = Workspace::open(common::require_artifacts()).unwrap();
    let cfg = fast_cfg();
    let dev = [Device::xavier_nx()];
    let tmp = std::env::temp_dir().join("hqp_preset_equiv");
    std::fs::create_dir_all(&tmp).unwrap();
    for spec in [
        MethodSpec::Baseline,
        MethodSpec::Q8Only,
        MethodSpec::PruneOnly(50),
        MethodSpec::Hqp,
        MethodSpec::HqpPruneOnly,
    ] {
        let legacy = run_method(&ws, "resnet18", spec, &cfg, &dev, true).unwrap();
        let sched = spec.to_schedule(&cfg);
        let preset = run_schedule(&ws, "resnet18", &sched, &cfg, &dev, true).unwrap();
        save_results(&tmp, "legacy", &legacy).unwrap();
        save_results(&tmp, "preset", &preset).unwrap();
        assert_eq!(
            std::fs::read(tmp.join("legacy.json")).unwrap(),
            std::fs::read(tmp.join("preset.json")).unwrap(),
            "{spec:?} and its preset `{}` must serialize byte-identically",
            sched.canonical()
        );
    }
}

#[test]
fn quantize_first_ordering_runs_and_loses_to_prune_first() {
    // the §V-B ablation the closed enum could not express: ptq >> prune
    // (quantize-first, calibration locked to the dense model) vs the
    // paper's prune >> ptq
    use hqp::hqp::Schedule;
    let ws = Workspace::open(common::require_artifacts()).unwrap();
    let mut sess = Session::new(&ws, "resnet18").unwrap();
    let cfg = fast_cfg();
    let qf = Schedule::parse("ptq >> prune").unwrap().run(&mut sess, &cfg).unwrap();
    assert_eq!(qf.method, "ptq >> prune");
    assert!(qf.scales.is_some(), "quantize-first still deploys int8");
    assert!(
        !qf.trace.steps.is_empty(),
        "the prune stage must run after ptq"
    );
    let pf = Schedule::parse("prune >> ptq").unwrap().run(&mut sess, &cfg).unwrap();
    // prune-first prunes under Δ_max on the FP32 model, so its sparse
    // model is compliant by construction; quantize-first must not end up
    // *more* accurate at equal-or-higher sparsity (the ordering claim)
    assert!(
        pf.acc_drop() <= qf.acc_drop() + 0.005 || pf.sparsity >= qf.sparsity,
        "prune-first (drop {:.4}, θ {:.3}) should not lose outright to \
         quantize-first (drop {:.4}, θ {:.3})",
        pf.acc_drop(),
        pf.sparsity,
        qf.acc_drop(),
        qf.sparsity
    );
}

#[test]
fn baseline_accuracy_is_memoized_across_schedules() {
    let ws = Workspace::open(common::require_artifacts()).unwrap();
    let mut sess = Session::new(&ws, "resnet18").unwrap();
    let a1 = sess.baseline_accuracy("val").unwrap();
    let after_first = sess.counters.inference_samples;
    let a2 = sess.baseline_accuracy("val").unwrap();
    assert_eq!(a1, a2);
    assert_eq!(
        sess.counters.inference_samples, after_first,
        "the second baseline measurement must be free"
    );
    // a whole method on the warm session re-uses the memo: baseline runs
    // no inference at all
    let o = pipeline::run_baseline(&mut sess).unwrap();
    assert_eq!(o.accuracy, a1);
    assert_eq!(
        sess.counters.inference_samples, after_first,
        "run_baseline on a warm session must not re-sweep the split"
    );
}

#[test]
fn counters_feed_the_cost_model() {
    use hqp::hqp::cost;
    let ws = Workspace::open(common::require_artifacts()).unwrap();
    let mut sess = Session::new(&ws, "resnet18").unwrap();
    let cfg = fast_cfg();
    pipeline::run_hqp(&mut sess, &cfg).unwrap();
    let c = cost::HqpCost::from_counters(&sess.counters);
    assert!(c.grad_samples >= cfg.calib_samples as u64);
    assert!(c.inference_samples > 0);
    let qat = cost::QatCost::paper_default(8192);
    assert!(
        cost::overhead_ratio(&c, &qat) > 1.0,
        "even on this tiny workload QAT must cost more than HQP"
    );
}
