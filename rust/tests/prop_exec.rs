//! Property tests for the `exec` worker pool's determinism contract
//! (testkit harness — the offline proptest substitute, DESIGN.md
//! §Substitutions and §Parallelism).
//!
//! These run WITHOUT artifacts. The contract under test is the one
//! `run_suite_jobs` and `hqp run --jobs` rely on:
//!
//! * **submission order** — results merge by task index, never by
//!   completion order, for every worker count;
//! * **byte-identical persistence** — `ResultRow` JSON written through
//!   [`save_results`] by concurrent pool workers is byte-for-byte the
//!   file a sequential run writes (atomic temp-file + rename, one cache
//!   key per task);
//! * **failure visibility** — a panicking task surfaces as a hard error
//!   naming the task, not a hang or a silently dropped result;
//! * **`--jobs 0`** — rejected loudly at construction.

use hqp::coordinator::{load_results, save_results, ResultRow};
use hqp::exec::{parallel_map, Jobs};
use hqp::hqp::MethodReport;
use hqp::runtime::Counters;
use hqp::testkit::prng::Prng;

const CASES: usize = 40;

/// A cheap but non-trivial pure task: the pool must not care what runs
/// inside, only that slot `i` of the output holds task `i`'s result.
fn churn(seed: u64, rounds: usize) -> u64 {
    let mut x = seed | 1;
    for _ in 0..rounds {
        x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        x ^= x >> 29;
    }
    x
}

#[test]
fn prop_results_merge_in_submission_order_at_any_job_count() {
    let mut rng = Prng::new(0xE8EC);
    for case_no in 0..CASES {
        let n = rng.below(24) + 1;
        let tasks: Vec<(u64, usize)> =
            (0..n).map(|_| (rng.next_u64(), rng.below(4000) + 10)).collect();
        let want: Vec<u64> = tasks.iter().map(|&(s, r)| churn(s, r)).collect();
        for jobs in [1usize, 2, 4, 8] {
            let (got, pool) = parallel_map(
                Jobs::new(jobs).unwrap(),
                tasks.clone(),
                |(s, r), _i| Ok(churn(s, r)),
            )
            .expect("pure tasks never fail");
            assert_eq!(got, want, "case {case_no}: jobs={jobs} broke submission order");
            // the pool's own books must balance: every task ran exactly
            // once somewhere, and claims cost at least one message each
            assert_eq!(pool.tasks, n, "case {case_no}");
            assert_eq!(pool.task_ms.len(), n, "case {case_no}");
            let ran: u64 = pool.workers.iter().map(|w| w.tasks).sum();
            assert_eq!(ran, n as u64, "case {case_no}: jobs={jobs} task census");
            let messages: u64 = pool.workers.iter().map(|w| w.messages).sum();
            assert!(messages >= ran, "case {case_no}: claims cost messages");
        }
    }
}

fn random_row(rng: &mut Prng, model: &str, method: &str) -> ResultRow {
    ResultRow {
        report: MethodReport {
            method: method.to_string(),
            model: model.to_string(),
            device: if rng.next_f64() < 0.5 { "xavier-nx" } else { "jetson-nano" }.into(),
            latency_ms: rng.next_f64() * 10.0,
            speedup: 1.0 + rng.next_f64() * 4.0,
            size_reduction: rng.next_f64(),
            acc_drop: rng.next_f64() * 0.03,
            sparsity: rng.next_f64(),
            compliant: rng.next_f64() < 0.8,
            energy_mj: rng.next_f64() * 20.0,
            energy_ratio: 1.0 + rng.next_f64(),
            flops: rng.next_u64() % 1_000_000_000,
        },
        trace: (0..rng.below(6))
            .map(|_| (rng.next_f64(), rng.next_f64(), rng.next_f64() < 0.5))
            .collect(),
        group_sparsity: (0..rng.below(8)).map(|_| rng.next_f64()).collect(),
        group_saliency: (0..rng.below(8)).map(|_| rng.next_f64() * 2.0).collect(),
        counters: Counters {
            inference_samples: rng.next_u64() % 10_000,
            grad_samples: rng.next_u64() % 1_000,
            executions: rng.next_u64() % 100,
            upload_bytes: rng.next_u64() % 1_000_000,
            upload_tensors: rng.next_u64() % 100,
            batches_skipped: rng.next_u64() % 20,
        },
    }
}

#[test]
fn prop_result_cache_bytes_identical_across_jobs() {
    // the coordinator's cache contract: each suite candidate persists
    // under its own key, so N workers racing through save_results leave
    // exactly the files — byte for byte — that a sequential run leaves
    let mut rng = Prng::new(0xCAC8E);
    let base = std::env::temp_dir().join(format!("hqp_prop_exec_{}", std::process::id()));
    for case_no in 0..CASES / 4 {
        let n_keys = rng.below(6) + 2;
        let candidates: Vec<(String, Vec<ResultRow>)> = (0..n_keys)
            .map(|k| {
                let name = format!("case{case_no}_m{k}");
                let rows =
                    (0..rng.below(3) + 1).map(|r| random_row(&mut rng, "m", &format!("s{r}"))).collect();
                (name, rows)
            })
            .collect();
        let mut bytes_by_jobs: Vec<Vec<Vec<u8>>> = Vec::new();
        for jobs in [1usize, 4] {
            let dir = base.join(format!("jobs{jobs}"));
            let dir_ref = &dir;
            parallel_map(Jobs::new(jobs).unwrap(), candidates.clone(), |(name, rows), _i| {
                save_results(dir_ref, &name, &rows)?;
                Ok(())
            })
            .expect("saving distinct keys never fails");
            bytes_by_jobs.push(
                candidates
                    .iter()
                    .map(|(name, _)| {
                        std::fs::read(dir.join(format!("{name}.json"))).expect("saved file")
                    })
                    .collect(),
            );
            // and no temp litter survives the renames
            for entry in std::fs::read_dir(&dir).unwrap() {
                let p = entry.unwrap().path();
                assert_eq!(
                    p.extension().and_then(|e| e.to_str()),
                    Some("json"),
                    "case {case_no}: stray temp file {p:?}"
                );
            }
            // the files round-trip through the loader workers actually use
            for (name, rows) in &candidates {
                let back = load_results(&dir, name).unwrap().expect("file exists");
                assert_eq!(back.len(), rows.len(), "case {case_no} key {name}");
            }
        }
        assert_eq!(
            bytes_by_jobs[0], bytes_by_jobs[1],
            "case {case_no}: cache bytes diverged between jobs=1 and jobs=4"
        );
    }
    let _ = std::fs::remove_dir_all(&base);
}

#[test]
fn worker_pool_panics_are_hard_errors_not_hangs() {
    // a panicking candidate must fail the whole suite with an error that
    // names the task — and the pool must still join every worker (this
    // test completing at all is the no-hang proof)
    let tasks: Vec<usize> = (0..16).collect();
    let err = parallel_map(Jobs::new(4).unwrap(), tasks, |i, _| {
        if i == 11 {
            panic!("candidate 11 exploded");
        }
        Ok(i)
    })
    .expect_err("a panicking task must fail the pool");
    let msg = err.to_string();
    assert!(msg.contains("panicked"), "error must say a panic happened: {msg}");
    assert!(msg.contains("11"), "error must name the failing task: {msg}");
    assert!(msg.contains("exploded"), "error must carry the panic payload: {msg}");
}

#[test]
fn jobs_zero_is_rejected_loudly() {
    let err = Jobs::new(0).expect_err("--jobs 0 must not construct");
    let msg = err.to_string();
    assert!(msg.contains("--jobs 0"), "the error must name the flag: {msg}");
    assert!(Jobs::new(1).is_ok() && Jobs::new(64).is_ok());
    assert!(Jobs::available().get() >= 1, "auto-detection never yields zero workers");
}
