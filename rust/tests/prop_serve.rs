//! Property tests for the serving simulator's conservation laws (testkit
//! harness — the offline proptest substitute, DESIGN.md §Substitutions).
//!
//! These run WITHOUT artifacts: fleets come from the paper-anchored
//! reference profiles. Over randomized (fleet, trace, config) triples —
//! including capped engine memory, the swap-aware policy, finite
//! uplinks, replicated multi-server fleets and the elastic autoscaling
//! controllers:
//!
//! * **conservation** — every generated request is exactly one of
//!   {completed, rejected, expired}, swaps and scale events included;
//! * **determinism** — the same seed reproduces a byte-identical summary,
//!   swap and scale counters included, and the worker count is invisible:
//!   `--jobs N` produces the same summary bytes as sequential for every
//!   generated case (DESIGN.md §Parallelism);
//! * **admission** — the router never serves a variant whose accuracy
//!   drop exceeds Δ_max, never serves a non-resident variant, and never
//!   routes to an asleep or draining server (`simulate_fleet` errors out
//!   on a residency or lifecycle violation — a stranded queue, an invalid
//!   swap plan or a misdirected scale event — so `Ok` is the proof;
//!   static policies are additionally pinned to the initial resident set);
//! * **monotone virtual time** — the event loop never travels backwards;
//! * **fixed-fleet identity** — with autoscaling off the other autoscale
//!   knobs are inert: the summary is bit-identical whatever they say, and
//!   no scale machinery is ever reported;
//! * **streaming identity** — the lazy `ArrivalGen` iterator reproduces
//!   the eager `trace::generate` vector bit-for-bit (bounded horizon and
//!   unbounded-`take(n)` prefix alike), and a streamed run's `Summary` is
//!   byte-identical to the materialized-trace run at jobs 1 and 4
//!   (DESIGN.md §Serving, "Memory & streaming");
//! * **sanity** — percentiles are ordered, attainment ⊆ completions, the
//!   latency histogram's census matches the completion counter, and swap
//!   and scale counters are internally consistent.

use hqp::exec::Jobs;
use hqp::gopt::{FusedKind, FusedOp, OptimizedGraph};
use hqp::hwsim::{simulate, simulate_batch, Device, Precision};
use hqp::serve::{
    parse_tenants, reference_fleet, simulate_fleet, simulate_fleet_jobs, simulate_fleet_stream,
    trace, AdmitPolicy, ArrivalProcess, AutoscaleConfig, Policy, ScalePolicy, ServeConfig,
};
use hqp::testkit::prng::Prng;

const CASES: usize = 50;
const METHODS: [&str; 5] = ["baseline", "q8", "p50", "hqp", "mixed"];

struct Case {
    model: &'static str,
    methods: Vec<&'static str>,
    two_servers: bool,
    /// Replicate the device servers cyclically up to this fleet size
    /// (equal to the device count = no replication).
    n_servers: usize,
    /// Per-server engine-memory cap as a fraction of that server's total
    /// variant bytes (None = unlimited — the pre-residency behavior).
    mem_frac: Option<f64>,
    cfg: ServeConfig,
    process: ArrivalProcess,
    duration_ms: f64,
    trace_seed: u64,
}

fn gen_case(rng: &mut Prng) -> Case {
    let mut methods: Vec<&'static str> =
        METHODS.iter().copied().filter(|_| rng.next_f64() < 0.6).collect();
    if methods.is_empty() {
        methods.push(if rng.next_f64() < 0.5 { "baseline" } else { "p50" });
    }
    let rps = 20.0 + rng.next_f64() * 1200.0;
    let process = match rng.below(4) {
        0 => ArrivalProcess::Poisson { rps },
        1 => ArrivalProcess::parse("mmpp", rps).unwrap(),
        2 => ArrivalProcess::parse("diurnal", rps).unwrap(),
        _ => ArrivalProcess::parse("flash-crowd", rps).unwrap(),
    };
    let two_servers = rng.next_f64() < 0.4;
    let base_servers = if two_servers { 2 } else { 1 };
    let n_servers = base_servers + rng.below(3);
    // elastic control plane on ~40% of cases, exercising both
    // controllers against every routing policy / memory-cap combination
    let autoscale = if rng.next_f64() < 0.4 {
        let min_active = rng.below(n_servers) + 1;
        AutoscaleConfig {
            policy: [ScalePolicy::QueueDepth, ScalePolicy::Attainment][rng.below(2)],
            interval_ms: 20.0 + rng.next_f64() * 200.0,
            min_active,
            max_active: min_active + rng.below(n_servers - min_active + 1),
            ..AutoscaleConfig::off()
        }
    } else {
        AutoscaleConfig::off()
    };
    Case {
        model: if rng.next_f64() < 0.5 { "resnet18" } else { "mobilenetv3" },
        methods,
        two_servers,
        n_servers,
        mem_frac: if rng.next_f64() < 0.5 {
            Some(0.15 + rng.next_f64() * 0.95)
        } else {
            None
        },
        cfg: ServeConfig {
            slo_ms: 1.0 + rng.next_f64() * 80.0,
            delta_max: [0.004, 0.01, 0.015, 0.03][rng.below(4)],
            policy: Policy::ALL[rng.below(Policy::ALL.len())],
            max_batch: rng.below(8) + 1,
            batch_timeout_ms: rng.next_f64() * 4.0,
            queue_cap: rng.below(124) + 4,
            swap_init_ms: rng.next_f64() * 10.0,
            link_mbps: if rng.next_f64() < 0.25 {
                10.0 + rng.next_f64() * 990.0
            } else {
                f64::INFINITY
            },
            autoscale,
            ..Default::default()
        },
        process,
        duration_ms: 300.0 + rng.next_f64() * 1200.0,
        trace_seed: rng.next_u64(),
    }
}

fn build_fleet(case: &Case) -> hqp::serve::Fleet {
    let devices = if case.two_servers {
        vec![Device::xavier_nx(), Device::jetson_nano()]
    } else {
        vec![Device::xavier_nx()]
    };
    let mut fleet = reference_fleet(case.model, &devices, &case.methods, case.cfg.max_batch)
        .unwrap()
        .replicate_to(case.n_servers)
        .unwrap();
    if let Some(frac) = case.mem_frac {
        for s in &mut fleet.servers {
            s.mem_capacity_bytes = Some((s.total_variant_bytes() as f64 * frac) as u64);
        }
    }
    fleet
}

fn run_case(case: &Case) -> (hqp::serve::Summary, Vec<f64>) {
    let fleet = build_fleet(case);
    let arrivals = trace::generate(&case.process, case.duration_ms, case.trace_seed);
    let summary = simulate_fleet(&fleet, &arrivals, &case.cfg).expect(
        "virtual time must stay monotone, residency must hold and the config is valid",
    );
    (summary, arrivals)
}

#[test]
fn prop_conservation_every_request_accounted_once() {
    let mut rng = Prng::new(0x5E21E);
    for case_no in 0..CASES {
        let case = gen_case(&mut rng);
        let (s, arrivals) = run_case(&case);
        assert_eq!(
            s.generated,
            arrivals.len() as u64,
            "case {case_no}: generated != trace length"
        );
        assert_eq!(
            s.completed + s.rejected + s.expired,
            s.generated,
            "case {case_no}: {} completed + {} rejected + {} expired != {} generated",
            s.completed,
            s.rejected,
            s.expired,
            s.generated
        );
        let per_variant_completed: u64 = s.per_variant.iter().map(|u| u.completed).sum();
        assert_eq!(per_variant_completed, s.completed, "case {case_no}: usage split");
        // open loop: every attempt is final, so the closed-loop counters
        // collapse onto the attempt census and the retry machinery is
        // provably idle
        assert!(!s.closed_loop, "case {case_no}: gen_case is open-loop");
        assert_eq!(s.retries, 0, "case {case_no}: open loop never retries");
        assert_eq!(s.dropped_final, s.rejected, "case {case_no}");
        assert_eq!(s.expired_final, s.expired, "case {case_no}");
        assert!(s.tenants.is_empty(), "case {case_no}: no tenant table, no tenant rows");
        // swap counters are internally consistent
        assert!(s.expired_during_swap <= s.expired, "case {case_no}");
        assert!(
            s.rejected_noncompliant + s.rejected_unavailable <= s.rejected,
            "case {case_no}"
        );
        if case.cfg.policy != Policy::SwapAware {
            assert_eq!(s.swaps, 0, "case {case_no}: static policies never swap");
        }
        if s.swaps > 0 {
            assert!(
                s.swap_ms >= s.swaps as f64 * case.cfg.swap_init_ms - 1e-9,
                "case {case_no}: each swap pays at least the init overhead"
            );
            assert!(
                s.swap_energy_mj > 0.0,
                "case {case_no}: swap windows charge E = P·L"
            );
        } else {
            assert_eq!(s.swap_ms, 0.0, "case {case_no}");
            assert_eq!(s.swap_energy_mj, 0.0, "case {case_no}: no swap, no charge");
            assert_eq!(s.expired_during_swap, 0, "case {case_no}");
        }
        // the energy total is exactly serving + wake + swap windows
        let usage_energy: f64 = s.per_variant.iter().map(|u| u.energy_mj).sum();
        assert!(
            (s.energy_mj - (usage_energy + s.wake_energy_mj + s.swap_energy_mj)).abs()
                < 1e-6,
            "case {case_no}: energy accounting must close"
        );
        if case.mem_frac.is_none() && !case.cfg.autoscale.enabled() {
            assert!(!s.residency_limited, "case {case_no}");
            assert_eq!(s.rejected_unavailable, 0, "case {case_no}");
            assert_eq!(s.swaps, 0, "case {case_no}: unlimited memory never swaps");
        }
        // scale counters are internally consistent
        if case.cfg.autoscale.enabled() {
            assert!(s.autoscaled, "case {case_no}");
            if s.scale_ups > 0 {
                assert!(
                    s.wake_ms >= s.scale_ups as f64 * case.cfg.swap_init_ms - 1e-9,
                    "case {case_no}: each wake pays at least the init overhead"
                );
                assert!(s.wake_energy_mj > 0.0, "case {case_no}: wakes charge E = P·L");
                assert!(
                    s.mean_reaction_ms + 1e-9 >= s.wake_ms / s.scale_ups as f64,
                    "case {case_no}: reaction time includes the wake itself"
                );
            } else {
                assert_eq!(s.wake_ms, 0.0, "case {case_no}");
                assert_eq!(s.wake_energy_mj, 0.0, "case {case_no}");
                assert_eq!(s.mean_reaction_ms, 0.0, "case {case_no}");
            }
        } else {
            assert!(!s.autoscaled, "case {case_no}");
            assert_eq!((s.scale_ups, s.scale_downs), (0, 0), "case {case_no}");
            assert_eq!(s.wake_ms, 0.0, "case {case_no}");
            assert_eq!(s.wake_energy_mj, 0.0, "case {case_no}");
            assert!(
                !s.render().contains("scale    :"),
                "case {case_no}: fixed fleets must not grow a scale line"
            );
        }
    }
}

#[test]
fn prop_autoscale_off_knobs_are_inert() {
    // fixed-fleet identity: with the controller off, the other autoscale
    // knobs must not perturb the simulation in any way — the summary is
    // bit-identical to the default-config run (the PR 3 behavior)
    let mut rng = Prng::new(0x0FF5CA1E);
    for case_no in 0..CASES / 2 {
        let mut case = gen_case(&mut rng);
        case.cfg.autoscale = AutoscaleConfig::off();
        let (base, _) = run_case(&case);
        case.cfg.autoscale = AutoscaleConfig {
            policy: ScalePolicy::Off,
            interval_ms: rng.next_f64() * 500.0,
            min_active: rng.below(9),
            max_active: rng.below(3),
            queue_high: rng.next_f64(),
            queue_low: rng.next_f64() + 2.0,
        };
        let (knobs, _) = run_case(&case);
        assert_eq!(base, knobs, "case {case_no}: Off knobs must be inert");
        assert_eq!(base.render(), knobs.render(), "case {case_no}");
        // swap-energy pricing is gated on a swap actually happening: a
        // no-swap run charges nothing and renders the pre-swap-energy
        // swaps line (fixed-fleet/no-swap output stays byte-identical)
        if base.swaps == 0 {
            assert_eq!(base.swap_energy_mj, 0.0, "case {case_no}");
            assert!(
                !base.render().contains("ms swapping, "),
                "case {case_no}: no-swap render must not grow an energy term"
            );
        }
    }
}

#[test]
fn prop_same_seed_reproduces_identical_summary() {
    let mut rng = Prng::new(0xDE7E12);
    for case_no in 0..CASES / 2 {
        let case = gen_case(&mut rng);
        let (a, _) = run_case(&case);
        let (b, _) = run_case(&case);
        assert_eq!(a, b, "case {case_no}: summaries diverged on identical inputs");
        assert_eq!(
            a.render(),
            b.render(),
            "case {case_no}: rendered summaries not byte-identical"
        );
    }
}

#[test]
fn prop_worker_count_never_changes_the_summary() {
    // the sharded-engine determinism contract (DESIGN.md §Parallelism):
    // --jobs only sets the OS thread count; shards advance between the
    // same virtual-time barriers in the same canonical order at any N,
    // so the summary — counters, percentiles, per-variant usage, event
    // census and rendered bytes — is identical to sequential across
    // every random (fleet, trace, config) triple, autoscaling, capped
    // memory, hot-swaps and finite uplinks included
    let mut rng = Prng::new(0x10B5);
    for case_no in 0..CASES / 2 {
        let case = gen_case(&mut rng);
        let fleet = build_fleet(&case);
        let arrivals = trace::generate(&case.process, case.duration_ms, case.trace_seed);
        let seq = simulate_fleet(&fleet, &arrivals, &case.cfg)
            .expect("sequential simulation of a valid case");
        assert!(seq.events > 0, "case {case_no}: the event census must count");
        for jobs in [2usize, 4] {
            let par =
                simulate_fleet_jobs(&fleet, &arrivals, &case.cfg, Jobs::new(jobs).unwrap())
                    .expect("parallel simulation of the same case");
            assert_eq!(seq, par, "case {case_no}: jobs={jobs} diverged from sequential");
            assert_eq!(
                seq.render(),
                par.render(),
                "case {case_no}: rendered bytes diverged at jobs={jobs}"
            );
        }
    }
}

#[test]
fn prop_streamed_run_matches_materialized_run_at_any_jobs() {
    // the O(1)-memory serving contract (DESIGN.md §Serving, "Memory &
    // streaming"): feeding the coordinator a lazy ArrivalGen through the
    // bounded lookahead buffer must reproduce the materialized &[f64]
    // run byte-for-byte — same Summary, same rendered bytes — and the
    // jobs-invariance contract must hold on the streaming path too
    let mut rng = Prng::new(0x57EA3);
    for case_no in 0..CASES / 2 {
        let case = gen_case(&mut rng);
        let fleet = build_fleet(&case);
        let arrivals = trace::generate(&case.process, case.duration_ms, case.trace_seed);

        // (a) the lazy generator IS the eager trace, bit for bit — both
        // the bounded-horizon form and the unbounded .take(n) prefix
        let lazy: Vec<f64> =
            trace::ArrivalGen::new(&case.process, case.duration_ms, case.trace_seed).collect();
        assert_eq!(
            lazy.len(),
            arrivals.len(),
            "case {case_no}: lazy/eager trace length mismatch"
        );
        for (i, (l, e)) in lazy.iter().zip(arrivals.iter()).enumerate() {
            assert_eq!(
                l.to_bits(),
                e.to_bits(),
                "case {case_no}: arrival {i} diverged ({l} vs {e})"
            );
        }
        let prefix: Vec<f64> =
            trace::ArrivalGen::new(&case.process, f64::INFINITY, case.trace_seed)
                .take(arrivals.len())
                .collect();
        for (i, (l, e)) in prefix.iter().zip(arrivals.iter()).enumerate() {
            assert_eq!(
                l.to_bits(),
                e.to_bits(),
                "case {case_no}: unbounded take(n) arrival {i} diverged"
            );
        }

        // (b) the streamed Summary is byte-identical to the slice run,
        // sequentially and sharded
        let eager = simulate_fleet(&fleet, &arrivals, &case.cfg)
            .expect("materialized simulation of a valid case");
        for jobs in [1usize, 4] {
            let streamed = simulate_fleet_stream(
                &fleet,
                trace::ArrivalGen::new(&case.process, case.duration_ms, case.trace_seed),
                &case.cfg,
                Jobs::new(jobs).unwrap(),
            )
            .expect("streamed simulation of the same case");
            assert_eq!(
                eager, streamed,
                "case {case_no}: streamed summary diverged at jobs={jobs}"
            );
            assert_eq!(
                eager.render(),
                streamed.render(),
                "case {case_no}: streamed render not byte-identical at jobs={jobs}"
            );
        }
    }
}

#[test]
fn prop_router_respects_delta_max() {
    let mut rng = Prng::new(0xACCE55);
    for case_no in 0..CASES {
        let case = gen_case(&mut rng);
        let (s, _) = run_case(&case);
        for u in &s.per_variant {
            if u.completed > 0 || u.batches > 0 {
                assert!(
                    u.acc_drop <= case.cfg.delta_max,
                    "case {case_no}: served {} (drop {:.3}%) above Δmax {:.3}%",
                    u.variant,
                    u.acc_drop * 100.0,
                    case.cfg.delta_max * 100.0
                );
            }
        }
        // with Δmax = 0.03 every variant is admissible; with a fleet of
        // only-violating variants everything must be rejected — swaps
        // can't help because no compliant engine exists to load
        if s.per_variant.iter().all(|u| u.acc_drop > case.cfg.delta_max) {
            assert_eq!(s.completed, 0, "case {case_no}");
            assert_eq!(s.rejected_noncompliant, s.generated, "case {case_no}");
            assert_eq!(s.swaps, 0, "case {case_no}");
        }
    }
}

#[test]
fn prop_static_policies_serve_only_the_initial_resident_set() {
    let mut rng = Prng::new(0x2E51D);
    for case_no in 0..CASES {
        let mut case = gen_case(&mut rng);
        // force a cap and a static policy
        case.mem_frac = Some(0.15 + rng.next_f64() * 0.8);
        case.cfg.policy =
            [Policy::RoundRobin, Policy::LeastLoaded, Policy::AccFastest][rng.below(3)];
        let fleet = build_fleet(&case);
        let residency: Vec<Vec<bool>> =
            fleet.servers.iter().map(|srv| srv.initial_residency()).collect();
        let (s, _) = run_case(&case);
        assert_eq!(s.swaps, 0, "case {case_no}");
        for u in &s.per_variant {
            if u.completed > 0 || u.batches > 0 {
                let v = fleet.servers[u.server]
                    .variants
                    .iter()
                    .position(|p| p.name == u.variant)
                    .expect("usage row names a fleet variant");
                assert!(
                    residency[u.server][v],
                    "case {case_no}: static {:?} served non-resident {} on server {}",
                    case.cfg.policy,
                    u.variant,
                    u.server
                );
            }
        }
    }
}

#[test]
fn prop_summary_stats_are_sane() {
    let mut rng = Prng::new(0x57A75);
    for case_no in 0..CASES {
        let case = gen_case(&mut rng);
        let (s, _) = run_case(&case);
        assert!(
            s.p50_ms <= s.p95_ms && s.p95_ms <= s.p99_ms,
            "case {case_no}: percentiles out of order"
        );
        assert!(s.slo_attained <= s.completed, "case {case_no}");
        assert!(s.throughput_rps >= 0.0 && s.mean_ms >= 0.0, "case {case_no}");
        assert!(s.acc_mix <= 0.03 + 1e-12, "case {case_no}: acc mix above any budget");
        // the constant-memory telemetry is consistent with the counters:
        // every completion is exactly one histogram sample, the reported
        // stats come straight off the histogram, and the occupied-bin
        // footprint is bounded by the fixed-edge bin space, not by the
        // request count
        assert_eq!(
            s.latency_hist.count(),
            s.completed,
            "case {case_no}: histogram census != completions"
        );
        assert_eq!(s.latency_hist.mean_ms(), s.mean_ms, "case {case_no}");
        assert!(
            s.latency_hist.occupied_bins() as u64 <= s.completed.max(1),
            "case {case_no}: more occupied bins than samples"
        );
        // p99 is a bin midpoint, so it may sit up to the documented
        // relative error above the exact streamed max — never more
        assert!(
            s.p99_ms
                <= s.latency_hist.max_ms()
                    * (1.0 + hqp::serve::stats::LatencyStats::QUANTILE_REL_ERROR),
            "case {case_no}: p99 {} beyond the error bound of the exact max {}",
            s.p99_ms,
            s.latency_hist.max_ms()
        );
        assert!(
            s.peak_queue_depth <= case.cfg.queue_cap as u64,
            "case {case_no}: peak queue depth {} above cap {}",
            s.peak_queue_depth,
            case.cfg.queue_cap
        );
        if s.completed > 0 {
            assert!(s.p50_ms > 0.0, "case {case_no}: zero latency is impossible");
            assert!(
                s.per_variant.iter().any(|u| u.completed > 0),
                "case {case_no}: completions must be attributed to a variant"
            );
            assert!(s.mean_batch >= 1.0, "case {case_no}: batches can't be empty");
        }
    }
}

/// The documented batched-roofline identity, property-tested: at batch 1
/// the weight/activation traffic split must cancel (`w + act == bytes`),
/// so `simulate_batch(g, d, 1)` must reproduce the closed-form batch-1
/// roofline `max(flops / (rate·util), bytes / mem_bw) + launch` per op —
/// recomputed here independently of the split — and `simulate(g, d)`
/// must equal it exactly. For every device and random op mixes across
/// kinds and precisions.
#[test]
fn prop_simulate_batch_at_one_equals_simulate() {
    let kinds = [
        FusedKind::ConvBnAct,
        FusedKind::DwConvBnAct,
        FusedKind::Gemm,
        FusedKind::Se,
        FusedKind::Elementwise,
        FusedKind::Pool,
    ];
    let precs = [Precision::Fp32, Precision::Fp16, Precision::Int8, Precision::Int4];
    let mut rng = Prng::new(0xBA7C41);
    for case_no in 0..100 {
        let n_ops = rng.below(8) + 1;
        let ops: Vec<FusedOp> = (0..n_ops)
            .map(|i| {
                let k = [1, 3, 5, 7][rng.below(4)];
                let hw = [1, 7, 14, 56, 112][rng.below(5)];
                FusedOp {
                    name: format!("op{i}"),
                    kind: kinds[rng.below(kinds.len())],
                    flops: rng.next_u64() % 1_000_000_000,
                    bytes: rng.next_u64() % 100_000_000,
                    precision: precs[rng.below(precs.len())],
                    h: hw,
                    w: hw,
                    cin: rng.below(512) + 1,
                    cout: rng.below(512) + 1,
                    k,
                }
            })
            .collect();
        let g = OptimizedGraph {
            model: "prop".into(),
            ops,
            weight_bytes: 0,
            dense_weight_bytes: 0,
        };
        for dev in Device::all() {
            let a = simulate(&g, &dev);
            let b = simulate_batch(&g, &dev, 1);
            // the closed-form batch-1 roofline, independent of how the
            // implementation splits weight vs activation traffic (at b=1
            // they must sum back to op.bytes, so any split regression —
            // e.g. weights charged per-sample — shows up far beyond ulp)
            for (i, op) in g.ops.iter().enumerate() {
                let rate = dev.rate_gflops(op.precision) * dev.utilization(op.kind);
                let t_comp = op.flops as f64 / (rate * 1e9) * 1e3;
                let t_mem = op.bytes as f64 / (dev.mem_bw_gbps * 1e9) * 1e3;
                let want = t_comp.max(t_mem) + dev.launch_overhead_ms;
                let got = b.per_op_ms[i];
                assert!(
                    (got - want).abs() <= want.abs() * 1e-9 + 1e-12,
                    "case {case_no} op {i} on {}: got {got}, closed form {want}",
                    dev.name
                );
            }
            let want_total: f64 = b.per_op_ms.iter().sum();
            assert_eq!(b.latency_ms, want_total, "case {case_no} on {}", dev.name);
            assert_eq!(b.energy_mj, dev.power_w * b.latency_ms, "case {case_no}");
            // and simulate() must be exactly the b=1 pricing
            assert_eq!(a.latency_ms, b.latency_ms, "case {case_no} on {}", dev.name);
            assert_eq!(a.per_op_ms, b.per_op_ms, "case {case_no} on {}", dev.name);
            assert_eq!(a.energy_mj, b.energy_mj, "case {case_no} on {}", dev.name);
            assert_eq!(
                a.memory_bound_frac, b.memory_bound_frac,
                "case {case_no} on {}",
                dev.name
            );
        }
    }
}

/// The acceptance-criterion scenario, pinned: at an offered load chosen
/// between the two capacities, HQP's compressed engine sustains strictly
/// higher SLO attainment than the FP32 baseline — the serving-level
/// analogue of the paper's 3.12× single-inference speedup.
#[test]
fn hqp_beats_baseline_slo_attainment_under_load() {
    let dev = Device::xavier_nx();
    let base_fleet = reference_fleet("resnet18", &[dev.clone()], &["baseline"], 8).unwrap();
    let hqp_fleet = reference_fleet("resnet18", &[dev], &["hqp"], 8).unwrap();
    let cap_base = base_fleet.servers[0].variants[0].capacity_rps();
    let cap_hqp = hqp_fleet.servers[0].variants[0].capacity_rps();
    assert!(cap_hqp > cap_base * 3.0, "hqp capacity {cap_hqp:.0} vs base {cap_base:.0}");

    let offered = cap_base * 2.0; // saturates baseline, well under hqp
    let slo = base_fleet.servers[0].variants[0].batch1_ms() * 4.0;
    let cfg = ServeConfig {
        slo_ms: slo,
        policy: Policy::AccFastest,
        ..Default::default()
    };
    let arrivals = trace::generate(&ArrivalProcess::Poisson { rps: offered }, 4_000.0, 7);
    let s_base = simulate_fleet(&base_fleet, &arrivals, &cfg).unwrap();
    let s_hqp = simulate_fleet(&hqp_fleet, &arrivals, &cfg).unwrap();
    assert!(
        s_hqp.slo_attainment() > s_base.slo_attainment(),
        "hqp {:.3} must strictly beat baseline {:.3} at {offered:.0} rps",
        s_hqp.slo_attainment(),
        s_base.slo_attainment()
    );
    assert!(s_hqp.p99_ms < s_base.p99_ms, "hqp p99 must be lower under equal load");
}

/// The residency acceptance scenario, pinned: a 48 MB Xavier NX holds the
/// fp32 baseline but not baseline + hqp. Static policies are stuck
/// serving the resident fp32 engine through an MMPP burst at 2× its
/// capacity; swap-aware pays the hot-swap cost once, serves the rest on
/// hqp, and must reach at least the best static policy's attainment.
#[test]
fn swap_aware_beats_static_policies_under_capped_memory() {
    let dev = Device::xavier_nx();
    let fleet = reference_fleet("resnet18", &[dev.clone()], &["baseline", "hqp"], 8)
        .unwrap()
        .with_mem_cap_mb(48.0);
    assert_eq!(
        fleet.servers[0].initial_residency(),
        vec![true, false],
        "48 MB must hold baseline (~46.7 MB) but not baseline + hqp (~50.4 MB)"
    );
    let cap_base = fleet.servers[0].variants[0].capacity_rps();
    let offered = cap_base * 2.0;
    let slo = fleet.servers[0].variants[0].batch1_ms() * 4.0;
    let arrivals =
        trace::generate(&ArrivalProcess::parse("mmpp", offered).unwrap(), 4_000.0, 13);
    let run = |policy: Policy| {
        let cfg = ServeConfig { slo_ms: slo, policy, ..Default::default() };
        simulate_fleet(&fleet, &arrivals, &cfg).unwrap()
    };

    let mut best_static = 0.0f64;
    for policy in [Policy::RoundRobin, Policy::LeastLoaded, Policy::AccFastest] {
        let s = run(policy);
        assert_eq!(s.swaps, 0, "{policy:?} must never swap");
        let hqp_row = s.per_variant.iter().find(|u| u.variant == "hqp").unwrap();
        assert_eq!(hqp_row.completed, 0, "{policy:?} served the non-resident engine");
        best_static = best_static.max(s.slo_attainment());
    }

    let s = run(Policy::SwapAware);
    assert!(s.swaps >= 1, "pressure through the burst must trigger a hot-swap");
    assert!(s.swap_ms > 0.0);
    assert!(
        s.slo_attainment() >= best_static,
        "swap-aware {:.3} must reach at least the best static {:.3}",
        s.slo_attainment(),
        best_static
    );
    let hqp_row = s.per_variant.iter().find(|u| u.variant == "hqp").unwrap();
    assert!(hqp_row.completed > 0, "the swapped-in engine must carry load");
}

/// The autoscaling acceptance scenario, pinned (the bench_serve analogue):
/// a 4-server hqp fleet under an MMPP burst whose mean load needs ~2.4
/// servers and whose high state needs ~3.84. The fixed fleet of equal
/// *mean* capacity (2 servers) sheds through every burst; the elastic
/// fleet (2..4 active, queue-depth controller) must wake capacity into
/// the burst — paying the priced wake cost and energy — and reach at
/// least the fixed-mean fleet's attainment.
#[test]
fn autoscaled_fleet_beats_fixed_fleet_of_equal_mean_capacity() {
    let dev = Device::xavier_nx();
    let one = reference_fleet("resnet18", &[dev], &["hqp"], 8).unwrap();
    let cap_one = one.servers[0].variants[0].capacity_rps();
    let slo = one.servers[0].variants[0].batch1_ms() * 8.0;
    let peak = one.clone().replicate_to(4).unwrap();
    let mean = one.replicate_to(2).unwrap();
    let burst =
        trace::generate(&ArrivalProcess::parse("mmpp", cap_one * 2.4).unwrap(), 4_000.0, 17);

    let fixed_cfg = ServeConfig { slo_ms: slo, ..Default::default() };
    let auto_cfg = ServeConfig {
        slo_ms: slo,
        autoscale: AutoscaleConfig {
            policy: ScalePolicy::QueueDepth,
            interval_ms: 50.0,
            min_active: 2,
            max_active: 4,
            ..AutoscaleConfig::off()
        },
        ..Default::default()
    };
    let s_mean = simulate_fleet(&mean, &burst, &fixed_cfg).unwrap();
    let s_auto = simulate_fleet(&peak, &burst, &auto_cfg).unwrap();

    assert!(!s_mean.autoscaled && s_mean.scale_ups == 0);
    assert!(s_auto.autoscaled);
    assert!(s_auto.scale_ups >= 1, "the burst must wake capacity at least once");
    assert!(s_auto.wake_ms > 0.0 && s_auto.wake_energy_mj > 0.0, "wakes are priced");
    assert!(
        s_auto.mean_reaction_ms > 0.0,
        "reaction time must cover detection + wake"
    );
    assert_eq!(
        s_auto.completed + s_auto.rejected + s_auto.expired,
        s_auto.generated,
        "conservation holds across scale events"
    );
    assert!(
        s_auto.slo_attainment() >= s_mean.slo_attainment(),
        "autoscaled {:.3} must reach at least the equal-mean-capacity fixed {:.3}",
        s_auto.slo_attainment(),
        s_mean.slo_attainment()
    );
    // the woken servers (indices >= min_active) must actually carry load
    let woken: u64 = s_auto
        .per_variant
        .iter()
        .filter(|u| u.server >= 2)
        .map(|u| u.completed)
        .sum();
    assert!(woken > 0, "scale-ups must translate into served traffic");
}

/// Randomize the closed-loop / multi-tenant knobs onto a generated case.
fn enable_closed_loop(case: &mut Case, rng: &mut Prng) {
    case.cfg.retries = rng.below(3) + 1;
    case.cfg.retry_base_ms = 1.0 + rng.next_f64() * 20.0;
    case.cfg.retry_seed = rng.next_u64();
    if rng.next_f64() < 0.7 {
        case.cfg.tenants = parse_tenants("gold:0.015:40:8,free:0.03:120:1").unwrap();
        case.cfg.admit = if rng.next_f64() < 0.5 {
            AdmitPolicy::WeightedFair
        } else {
            AdmitPolicy::Fifo
        };
    }
}

#[test]
fn prop_closed_loop_off_knobs_are_inert() {
    // off-knobs-inert: with retries off and no tenant table, the backoff
    // knobs must not perturb the simulation in any way — the Summary and
    // its rendered bytes are identical to the default-knob run at every
    // worker count (the PR 8 behavior, byte for byte)
    let mut rng = Prng::new(0x1E27);
    for case_no in 0..CASES / 2 {
        let case = gen_case(&mut rng);
        assert_eq!(case.cfg.retries, 0, "gen_case must stay open-loop");
        let fleet = build_fleet(&case);
        let arrivals = trace::generate(&case.process, case.duration_ms, case.trace_seed);
        let base = simulate_fleet(&fleet, &arrivals, &case.cfg).unwrap();
        let mut weird = case.cfg.clone();
        weird.retry_base_ms = rng.next_f64() * 500.0;
        weird.retry_seed = rng.next_u64();
        for jobs in [1usize, 4] {
            let knobs =
                simulate_fleet_jobs(&fleet, &arrivals, &weird, Jobs::new(jobs).unwrap()).unwrap();
            assert_eq!(base, knobs, "case {case_no}: open-loop backoff knobs must be inert");
            assert_eq!(base.render(), knobs.render(), "case {case_no}: jobs={jobs}");
        }
        assert!(
            !base.render().contains("retries  :") && !base.render().contains("tenants  :"),
            "case {case_no}: open-loop render must not grow new lines"
        );
    }
}

#[test]
fn prop_closed_loop_conservation_and_determinism() {
    // with retries, tenants and the new arrival processes enabled:
    // conservation holds over *final* outcomes (attempt censuses float
    // above it), and the jobs/streaming byte-identity contract carries
    // over unchanged
    let mut rng = Prng::new(0xC105ED);
    for case_no in 0..CASES / 2 {
        let mut case = gen_case(&mut rng);
        enable_closed_loop(&mut case, &mut rng);
        let fleet = build_fleet(&case);
        let arrivals = trace::generate(&case.process, case.duration_ms, case.trace_seed);
        let s = simulate_fleet(&fleet, &arrivals, &case.cfg).unwrap();
        assert!(s.closed_loop, "case {case_no}");
        assert_eq!(s.generated, arrivals.len() as u64, "case {case_no}: fresh census");
        assert_eq!(
            s.completed + s.dropped_final + s.expired_final,
            s.generated,
            "case {case_no}: {} completed + {} dropped + {} expired != {} generated",
            s.completed,
            s.dropped_final,
            s.expired_final,
            s.generated
        );
        // finals never exceed the attempt census, and every retry
        // re-entry stems from exactly one failed attempt
        assert!(s.dropped_final <= s.rejected, "case {case_no}");
        assert!(s.expired_final <= s.expired, "case {case_no}");
        assert!(s.retries <= s.rejected + s.expired, "case {case_no}");
        // byte-identity: jobs and the streamed path are invisible
        for jobs in [1usize, 4] {
            let par =
                simulate_fleet_jobs(&fleet, &arrivals, &case.cfg, Jobs::new(jobs).unwrap())
                    .unwrap();
            assert_eq!(s, par, "case {case_no}: jobs={jobs} diverged closed-loop");
            assert_eq!(s.render(), par.render(), "case {case_no}: jobs={jobs} render");
            let streamed = simulate_fleet_stream(
                &fleet,
                trace::ArrivalGen::new(&case.process, case.duration_ms, case.trace_seed),
                &case.cfg,
                Jobs::new(jobs).unwrap(),
            )
            .unwrap();
            assert_eq!(s, streamed, "case {case_no}: streamed diverged at jobs={jobs}");
        }
    }
}

#[test]
fn prop_tenant_census_sums_to_the_global_census() {
    // the per-tenant table is a partition of the global counters: every
    // census column sums back exactly, including the latency histograms
    let mut rng = Prng::new(0x7E7A27);
    for case_no in 0..CASES / 2 {
        let mut case = gen_case(&mut rng);
        enable_closed_loop(&mut case, &mut rng);
        case.cfg.tenants = parse_tenants("gold:0.015:40:8,free:0.03:120:1").unwrap();
        let (s, _) = run_case(&case);
        assert_eq!(s.tenants.len(), 2, "case {case_no}");
        let sum = |f: fn(&hqp::serve::TenantSummary) -> u64| -> u64 {
            s.tenants.iter().map(f).sum()
        };
        assert_eq!(sum(|t| t.generated), s.generated, "case {case_no}: generated");
        assert_eq!(sum(|t| t.completed), s.completed, "case {case_no}: completed");
        assert_eq!(sum(|t| t.dropped_final), s.dropped_final, "case {case_no}: dropped");
        assert_eq!(sum(|t| t.expired_final), s.expired_final, "case {case_no}: expired");
        assert_eq!(sum(|t| t.retries), s.retries, "case {case_no}: retries");
        assert_eq!(sum(|t| t.slo_attained), s.slo_attained, "case {case_no}: attained");
        assert_eq!(
            sum(|t| t.latency.count()),
            s.completed,
            "case {case_no}: tenant histograms partition the completions"
        );
        for t in &s.tenants {
            assert!(
                t.completed + t.dropped_final + t.expired_final == t.generated,
                "case {case_no}: per-tenant conservation for {}",
                t.name
            );
            assert!(t.slo_attained <= t.completed, "case {case_no}: {}", t.name);
        }
        // the tenant table is rendered (gated on the table being set)
        assert!(s.render().contains("tenants  : 2 classes"), "case {case_no}");
    }
}

/// Randomize the predictive / energy-accounting knobs onto a generated
/// case: the predictive controller (sometimes with an explicit horizon),
/// sometimes idle-power pricing, sometimes the joules-per-slo router.
fn enable_predictive(case: &mut Case, rng: &mut Prng) {
    let min_active = rng.below(case.n_servers) + 1;
    case.cfg.autoscale = AutoscaleConfig {
        policy: ScalePolicy::Predictive,
        interval_ms: 10.0 + rng.next_f64() * 80.0,
        min_active,
        max_active: min_active + rng.below(case.n_servers - min_active + 1),
        ..AutoscaleConfig::off()
    };
    if rng.next_f64() < 0.5 {
        case.cfg.forecast_horizon_ms = Some(20.0 + rng.next_f64() * 400.0);
    }
    if rng.next_f64() < 0.5 {
        case.cfg.idle_watts = rng.next_f64() * 5.0;
    }
    if rng.next_f64() < 0.3 {
        case.cfg.policy = Policy::JoulesPerSlo;
    }
}

#[test]
fn prop_rate_share_recuts_the_assignment_not_the_trace() {
    // the optional 5th --tenants field re-cuts only the id → class
    // assignment: the offered arrival timeline is bit-identical with and
    // without it, the global census is untouched, and each class's share
    // of *generated* requests follows the pinned rate share within the
    // golden-ratio sequence's discrepancy bound
    let mut rng = Prng::new(0x2A7E5);
    for case_no in 0..CASES / 4 {
        let mut case = gen_case(&mut rng);
        case.cfg.tenants = parse_tenants("gold:0.015:40:8,free:0.03:120:1").unwrap();
        let (weighted, tw) = run_case(&case);
        case.cfg.tenants =
            parse_tenants("gold:0.015:40:8:0.2,free:0.03:120:1:0.8").unwrap();
        let (shared, ts) = run_case(&case);
        assert_eq!(tw.len(), ts.len(), "case {case_no}: trace length moved");
        for (i, (a, b)) in tw.iter().zip(ts.iter()).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "case {case_no}: arrival {i} moved");
        }
        assert_eq!(weighted.generated, shared.generated, "case {case_no}");
        let n = shared.generated as f64;
        if shared.generated >= 200 {
            // Kronecker-sequence discrepancy is O(log n / n); this bound
            // is loose enough for every generated trace length
            let tol = 0.05 + 5.0 / n;
            let w_gold = weighted.tenants[0].generated as f64 / n;
            let s_gold = shared.tenants[0].generated as f64 / n;
            assert!(
                (w_gold - 8.0 / 9.0).abs() <= tol,
                "case {case_no}: weight-cut gold share {w_gold:.3} vs 8/9"
            );
            assert!(
                (s_gold - 0.2).abs() <= tol,
                "case {case_no}: rate-share gold share {s_gold:.3} vs 0.2"
            );
        }
    }
}

#[test]
fn prop_reactive_runs_report_no_predictive_machinery() {
    // frozen-surface property: without --autoscale predictive none of the
    // new machinery may leave a trace — no prewake/prefetch/reselect
    // counters, no forecast error, no predict render line; and with
    // idle_watts at its 0 default, no idle energy and no idle line
    let mut rng = Prng::new(0x0FF9ED);
    for case_no in 0..CASES / 2 {
        let case = gen_case(&mut rng);
        assert_eq!(case.cfg.idle_watts, 0.0, "gen_case must keep the legacy default");
        let (s, _) = run_case(&case);
        assert!(!s.predictive, "case {case_no}");
        assert_eq!(s.prewakes, 0, "case {case_no}");
        assert_eq!(s.prefetch_swaps, 0, "case {case_no}");
        assert_eq!(s.reselect_swaps, 0, "case {case_no}");
        assert_eq!(s.forecast_abs_err_pct, 0.0, "case {case_no}");
        assert_eq!(s.idle_energy_mj, 0.0, "case {case_no}");
        let r = s.render();
        assert!(!r.contains("predict  :"), "case {case_no}: reactive render grew a line");
        assert!(!r.contains("idle     :"), "case {case_no}: zero-idle render grew a line");
    }
}

#[test]
fn prop_predictive_conservation_and_jobs_invariance() {
    // the tentpole's determinism contract: the forecaster consumes the
    // trace in arrival order on the coordinator, so every prewake,
    // prefetch and reselect it drives is a pure function of the inputs —
    // conservation holds, the counters are internally consistent, the
    // energy accounting (idle term included) closes, and the summary is
    // byte-identical at any worker count and on the streamed path
    let mut rng = Prng::new(0x93ED1C7);
    for case_no in 0..CASES / 2 {
        let mut case = gen_case(&mut rng);
        enable_predictive(&mut case, &mut rng);
        let fleet = build_fleet(&case);
        let arrivals = trace::generate(&case.process, case.duration_ms, case.trace_seed);
        let s = simulate_fleet(&fleet, &arrivals, &case.cfg).unwrap();
        assert!(s.autoscaled && s.predictive, "case {case_no}");
        assert_eq!(
            s.completed + s.rejected + s.expired,
            s.generated,
            "case {case_no}: conservation must hold under prewake + prefetch"
        );
        assert!(
            s.prewakes <= s.scale_ups,
            "case {case_no}: every prewake is a scale-up ({} > {})",
            s.prewakes,
            s.scale_ups
        );
        assert!(
            s.prefetch_swaps + s.reselect_swaps <= s.swaps,
            "case {case_no}: forecast-driven swaps are a subset of all swaps"
        );
        if case.mem_frac.is_none() {
            assert_eq!(s.swaps, 0, "case {case_no}: unlimited memory never swaps");
        }
        assert!(
            s.forecast_abs_err_pct.is_finite() && s.forecast_abs_err_pct >= 0.0,
            "case {case_no}: forecast error {}",
            s.forecast_abs_err_pct
        );
        if case.cfg.idle_watts == 0.0 {
            assert_eq!(s.idle_energy_mj, 0.0, "case {case_no}");
        } else {
            assert!(s.idle_energy_mj >= 0.0, "case {case_no}");
        }
        let usage_energy: f64 = s.per_variant.iter().map(|u| u.energy_mj).sum();
        assert!(
            (s.energy_mj
                - (usage_energy + s.wake_energy_mj + s.swap_energy_mj + s.idle_energy_mj))
                .abs()
                < 1e-6,
            "case {case_no}: energy accounting must close with the idle term"
        );
        assert!(s.render().contains("predict  :"), "case {case_no}");
        // byte-identity: reruns, worker counts and the streamed path
        let again = simulate_fleet(&fleet, &arrivals, &case.cfg).unwrap();
        assert_eq!(s, again, "case {case_no}: predictive rerun diverged");
        for jobs in [2usize, 4] {
            let par =
                simulate_fleet_jobs(&fleet, &arrivals, &case.cfg, Jobs::new(jobs).unwrap())
                    .unwrap();
            assert_eq!(s, par, "case {case_no}: jobs={jobs} diverged under predictive");
            assert_eq!(s.render(), par.render(), "case {case_no}: jobs={jobs} render");
        }
        let streamed = simulate_fleet_stream(
            &fleet,
            trace::ArrivalGen::new(&case.process, case.duration_ms, case.trace_seed),
            &case.cfg,
            Jobs::new(4).unwrap(),
        )
        .unwrap();
        assert_eq!(s, streamed, "case {case_no}: streamed diverged under predictive");
    }
}

#[test]
fn prop_new_generators_stream_bit_identically() {
    // PR 8's streaming property, extended to the diurnal and flash-crowd
    // generators: the lazy ArrivalGen is the eager trace bit-for-bit,
    // bounded horizon and unbounded .take(n) prefix alike
    let mut rng = Prng::new(0xD1A2A1);
    for case_no in 0..CASES {
        let rps = 20.0 + rng.next_f64() * 1500.0;
        let name = ["diurnal", "flash-crowd"][rng.below(2)];
        let process = ArrivalProcess::parse(name, rps).unwrap();
        let duration = 200.0 + rng.next_f64() * 2000.0;
        let seed = rng.next_u64();
        let eager = trace::generate(&process, duration, seed);
        let lazy: Vec<f64> = trace::ArrivalGen::new(&process, duration, seed).collect();
        assert_eq!(eager.len(), lazy.len(), "case {case_no} ({name}): length");
        for (i, (l, e)) in lazy.iter().zip(eager.iter()).enumerate() {
            assert_eq!(
                l.to_bits(),
                e.to_bits(),
                "case {case_no} ({name}): arrival {i} diverged ({l} vs {e})"
            );
        }
        let prefix: Vec<f64> = trace::ArrivalGen::new(&process, f64::INFINITY, seed)
            .take(eager.len())
            .collect();
        for (i, (l, e)) in prefix.iter().zip(eager.iter()).enumerate() {
            assert_eq!(
                l.to_bits(),
                e.to_bits(),
                "case {case_no} ({name}): unbounded take(n) arrival {i} diverged"
            );
        }
    }
}
