//! Property tests for the serving simulator's conservation laws (testkit
//! harness — the offline proptest substitute, DESIGN.md §Substitutions).
//!
//! These run WITHOUT artifacts: fleets come from the paper-anchored
//! reference profiles. Over randomized (fleet, trace, config) triples:
//!
//! * **conservation** — every generated request is exactly one of
//!   {completed, rejected, expired};
//! * **determinism** — the same seed reproduces a byte-identical summary;
//! * **admission** — the router never serves a variant whose accuracy
//!   drop exceeds Δ_max;
//! * **monotone virtual time** — the event loop never travels backwards
//!   (`simulate_fleet` errors out on regression, so `Ok` is the proof);
//! * **sanity** — percentiles are ordered, attainment ⊆ completions.

use hqp::hwsim::Device;
use hqp::serve::{reference_fleet, simulate_fleet, trace, ArrivalProcess, Policy, ServeConfig};
use hqp::testkit::prng::Prng;

const CASES: usize = 50;
const METHODS: [&str; 5] = ["baseline", "q8", "p50", "hqp", "mixed"];
const POLICIES: [Policy; 3] = [Policy::RoundRobin, Policy::LeastLoaded, Policy::AccFastest];

struct Case {
    model: &'static str,
    methods: Vec<&'static str>,
    two_servers: bool,
    cfg: ServeConfig,
    process: ArrivalProcess,
    duration_ms: f64,
    trace_seed: u64,
}

fn gen_case(rng: &mut Prng) -> Case {
    let mut methods: Vec<&'static str> =
        METHODS.iter().copied().filter(|_| rng.next_f64() < 0.6).collect();
    if methods.is_empty() {
        methods.push(if rng.next_f64() < 0.5 { "baseline" } else { "p50" });
    }
    let rps = 20.0 + rng.next_f64() * 1200.0;
    let process = if rng.next_f64() < 0.5 {
        ArrivalProcess::Poisson { rps }
    } else {
        ArrivalProcess::parse("mmpp", rps).unwrap()
    };
    Case {
        model: if rng.next_f64() < 0.5 { "resnet18" } else { "mobilenetv3" },
        methods,
        two_servers: rng.next_f64() < 0.4,
        cfg: ServeConfig {
            slo_ms: 1.0 + rng.next_f64() * 80.0,
            delta_max: [0.004, 0.01, 0.015, 0.03][rng.below(4)],
            policy: POLICIES[rng.below(3)],
            max_batch: rng.below(8) + 1,
            batch_timeout_ms: rng.next_f64() * 4.0,
            queue_cap: rng.below(124) + 4,
        },
        duration_ms: 300.0 + rng.next_f64() * 1200.0,
        trace_seed: rng.next_u64(),
    }
}

fn run_case(case: &Case) -> (hqp::serve::Summary, Vec<f64>) {
    let devices = if case.two_servers {
        vec![Device::xavier_nx(), Device::jetson_nano()]
    } else {
        vec![Device::xavier_nx()]
    };
    let fleet =
        reference_fleet(case.model, &devices, &case.methods, case.cfg.max_batch).unwrap();
    let arrivals = trace::generate(&case.process, case.duration_ms, case.trace_seed);
    let summary = simulate_fleet(&fleet, &arrivals, &case.cfg)
        .expect("virtual time must stay monotone and the config is valid");
    (summary, arrivals)
}

#[test]
fn prop_conservation_every_request_accounted_once() {
    let mut rng = Prng::new(0x5E21E);
    for case_no in 0..CASES {
        let case = gen_case(&mut rng);
        let (s, arrivals) = run_case(&case);
        assert_eq!(
            s.generated,
            arrivals.len() as u64,
            "case {case_no}: generated != trace length"
        );
        assert_eq!(
            s.completed + s.rejected + s.expired,
            s.generated,
            "case {case_no}: {} completed + {} rejected + {} expired != {} generated",
            s.completed,
            s.rejected,
            s.expired,
            s.generated
        );
        let per_variant_completed: u64 = s.per_variant.iter().map(|u| u.completed).sum();
        assert_eq!(per_variant_completed, s.completed, "case {case_no}: usage split");
    }
}

#[test]
fn prop_same_seed_reproduces_identical_summary() {
    let mut rng = Prng::new(0xDE7E12);
    for case_no in 0..CASES / 2 {
        let case = gen_case(&mut rng);
        let (a, _) = run_case(&case);
        let (b, _) = run_case(&case);
        assert_eq!(a, b, "case {case_no}: summaries diverged on identical inputs");
        assert_eq!(
            a.render(),
            b.render(),
            "case {case_no}: rendered summaries not byte-identical"
        );
    }
}

#[test]
fn prop_router_respects_delta_max() {
    let mut rng = Prng::new(0xACCE55);
    for case_no in 0..CASES {
        let case = gen_case(&mut rng);
        let (s, _) = run_case(&case);
        for u in &s.per_variant {
            if u.completed > 0 || u.batches > 0 {
                assert!(
                    u.acc_drop <= case.cfg.delta_max,
                    "case {case_no}: served {} (drop {:.3}%) above Δmax {:.3}%",
                    u.variant,
                    u.acc_drop * 100.0,
                    case.cfg.delta_max * 100.0
                );
            }
        }
        // with Δmax = 0.03 every variant is admissible; with a fleet of
        // only-violating variants everything must be rejected
        if s.per_variant.iter().all(|u| u.acc_drop > case.cfg.delta_max) {
            assert_eq!(s.completed, 0, "case {case_no}");
            assert_eq!(s.rejected_noncompliant, s.generated, "case {case_no}");
        }
    }
}

#[test]
fn prop_summary_stats_are_sane() {
    let mut rng = Prng::new(0x57A75);
    for case_no in 0..CASES {
        let case = gen_case(&mut rng);
        let (s, _) = run_case(&case);
        assert!(
            s.p50_ms <= s.p95_ms && s.p95_ms <= s.p99_ms,
            "case {case_no}: percentiles out of order"
        );
        assert!(s.slo_attained <= s.completed, "case {case_no}");
        assert!(s.throughput_rps >= 0.0 && s.mean_ms >= 0.0, "case {case_no}");
        assert!(s.acc_mix <= 0.03 + 1e-12, "case {case_no}: acc mix above any budget");
        if s.completed > 0 {
            assert!(s.p50_ms > 0.0, "case {case_no}: zero latency is impossible");
            assert!(
                s.per_variant.iter().any(|u| u.completed > 0),
                "case {case_no}: completions must be attributed to a variant"
            );
            assert!(s.mean_batch >= 1.0, "case {case_no}: batches can't be empty");
        }
    }
}

/// The acceptance-criterion scenario, pinned: at an offered load chosen
/// between the two capacities, HQP's compressed engine sustains strictly
/// higher SLO attainment than the FP32 baseline — the serving-level
/// analogue of the paper's 3.12× single-inference speedup.
#[test]
fn hqp_beats_baseline_slo_attainment_under_load() {
    let dev = Device::xavier_nx();
    let base_fleet = reference_fleet("resnet18", &[dev.clone()], &["baseline"], 8).unwrap();
    let hqp_fleet = reference_fleet("resnet18", &[dev], &["hqp"], 8).unwrap();
    let cap_base = base_fleet.servers[0].variants[0].capacity_rps();
    let cap_hqp = hqp_fleet.servers[0].variants[0].capacity_rps();
    assert!(cap_hqp > cap_base * 3.0, "hqp capacity {cap_hqp:.0} vs base {cap_base:.0}");

    let offered = cap_base * 2.0; // saturates baseline, well under hqp
    let slo = base_fleet.servers[0].variants[0].batch1_ms() * 4.0;
    let cfg = ServeConfig {
        slo_ms: slo,
        policy: Policy::AccFastest,
        ..Default::default()
    };
    let arrivals = trace::generate(&ArrivalProcess::Poisson { rps: offered }, 4_000.0, 7);
    let s_base = simulate_fleet(&base_fleet, &arrivals, &cfg).unwrap();
    let s_hqp = simulate_fleet(&hqp_fleet, &arrivals, &cfg).unwrap();
    assert!(
        s_hqp.slo_attainment() > s_base.slo_attainment(),
        "hqp {:.3} must strictly beat baseline {:.3} at {offered:.0} rps",
        s_hqp.slo_attainment(),
        s_base.slo_attainment()
    );
    assert!(s_hqp.p99_ms < s_base.p99_ms, "hqp p99 must be lower under equal load");
}
