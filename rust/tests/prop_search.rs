//! Property tests for the schedule-search engine (testkit harness — the
//! offline proptest substitute, DESIGN.md §Substitutions).
//!
//! These run WITHOUT artifacts against the reference surrogate backend
//! and pin the subsystem's four contracts (ISSUE/DESIGN.md §Search):
//!
//! * **jobs invariance** — same seed + budget ⇒ a byte-identical ranked
//!   front (rendered table AND JSON) at any `--jobs`;
//! * **Pareto soundness** — the returned front contains no dominated
//!   point;
//! * **budget** — the evaluation count never exceeds `--budget N`;
//! * **compliance** — no Δ_max-violating schedule ever appears on the
//!   front.

use hqp::exec::Jobs;
use hqp::hqp::HqpConfig;
use hqp::hwsim::Device;
use hqp::search::{
    generate, outcome_json, pareto, render, run_search, Backend, SearchConfig, SearchSpace,
};

fn config(budget: usize, seed: u64, jobs: Jobs) -> SearchConfig {
    SearchConfig {
        model: "resnet18".into(),
        device: Device::xavier_nx(),
        hqp: HqpConfig::default(),
        budget,
        seed,
        space: SearchSpace::all(),
        jobs,
        backend: Backend::Reference,
    }
}

#[test]
fn prop_front_is_byte_identical_at_any_jobs() {
    for (budget, seed) in [(8usize, 42u64), (24, 7), (40, 0xBEEF)] {
        let base = run_search(&config(budget, seed, Jobs::one())).unwrap();
        let sc1 = config(budget, seed, Jobs::one());
        let text1 = render(&sc1, &base);
        let json1 = outcome_json(&sc1, &base).to_string_pretty();
        for jobs in [2, 3, 8] {
            let scn = config(budget, seed, Jobs::new(jobs).unwrap());
            let out = run_search(&scn).unwrap();
            assert_eq!(
                render(&scn, &out),
                text1,
                "budget {budget} seed {seed}: rendered front must be \
                 byte-identical at --jobs {jobs}"
            );
            assert_eq!(
                outcome_json(&scn, &out).to_string_pretty(),
                json1,
                "budget {budget} seed {seed}: JSON must be byte-identical \
                 at --jobs {jobs}"
            );
        }
    }
}

#[test]
fn prop_front_has_no_dominated_point() {
    for seed in [1u64, 2, 3, 4, 5] {
        let out = run_search(&config(32, seed, Jobs::one())).unwrap();
        for (i, a) in out.front.iter().enumerate() {
            for (j, b) in out.front.iter().enumerate() {
                assert!(
                    i == j || !pareto::dominates(a, b),
                    "seed {seed}: `{}` dominates `{}` on the front",
                    a.schedule,
                    b.schedule
                );
            }
        }
        // every front point must also be a full-fidelity eval
        for e in &out.front {
            assert_eq!(e.fidelity, hqp::search::Fidelity::Full);
            assert!(out.full.iter().any(|f| f.schedule == e.schedule));
        }
    }
}

#[test]
fn prop_budget_is_never_exceeded() {
    for budget in 1..=40usize {
        let out = run_search(&config(budget, 42, Jobs::one())).unwrap();
        assert!(
            out.evals() <= budget,
            "budget {budget}: spent {} evaluations",
            out.evals()
        );
        assert!(out.full_evals >= 1, "at least one full eval at any budget");
    }
}

#[test]
fn prop_no_violator_ever_reaches_the_front() {
    // sweep Δ_max from punishing to generous; at every setting the front
    // respects the budget in force
    for delta_max in [0.001f64, 0.005, 0.015, 0.05] {
        for seed in [42u64, 99] {
            let mut sc = config(24, seed, Jobs::one());
            sc.hqp.delta_max = delta_max;
            let out = run_search(&sc).unwrap();
            for e in &out.front {
                assert!(
                    e.compliant && e.acc_drop <= delta_max + 1e-9,
                    "Δ_max={delta_max} seed {seed}: `{}` (drop {:.4}) on front",
                    e.schedule,
                    e.acc_drop
                );
            }
        }
    }
}

#[test]
fn prop_candidate_stream_is_deterministic_and_budget_sized() {
    let cfg = HqpConfig::default();
    for seed in [0u64, 42, 1234] {
        for n in [1usize, 5, 17, 40] {
            let a = generate(&SearchSpace::all(), &cfg, seed, n);
            let b = generate(&SearchSpace::all(), &cfg, seed, n);
            let ca: Vec<String> = a.iter().map(|c| c.sched.canonical()).collect();
            let cb: Vec<String> = b.iter().map(|c| c.sched.canonical()).collect();
            assert_eq!(ca, cb, "seed {seed} n {n}");
            assert!(ca.len() <= n);
            let mut d = ca.clone();
            d.sort();
            d.dedup();
            assert_eq!(d.len(), ca.len(), "seed {seed} n {n}: duplicates");
        }
    }
}

#[test]
fn prop_bad_inputs_are_loud() {
    // --budget 0
    let e = run_search(&config(0, 42, Jobs::one())).unwrap_err().to_string();
    assert!(e.contains("--budget"), "{e}");
    // malformed --space lists the valid axes
    let e = SearchSpace::parse("order,banana").unwrap_err().to_string();
    assert!(e.contains("unknown search axis"), "{e}");
    for axis in hqp::search::AXIS_NAMES {
        assert!(e.contains(axis), "error must list `{axis}`: {e}");
    }
}

#[test]
fn prop_ordering_claim_rediscovered_across_seeds() {
    // §V-B: prune-first is always promoted (it leads the candidate
    // stream and wins every cheap-rung tie), survives full fidelity and
    // lands on the front; quantize-first, *whenever* it reaches full
    // fidelity, measures the stale-scale penalty and is hard-excluded.
    for seed in [42u64, 7, 2026] {
        for budget in [8usize, 16, 32] {
            let out = run_search(&config(budget, seed, Jobs::one())).unwrap();
            let pf = out
                .full
                .iter()
                .find(|e| e.schedule == "prune >> ptq")
                .expect("prune-first must always be promoted");
            assert!(pf.compliant, "seed {seed} budget {budget}");
            assert!(
                out.front.iter().any(|e| e.schedule == "prune >> ptq"),
                "seed {seed} budget {budget}: prune-first missing from front"
            );
            if let Some(qf) = out.full.iter().find(|e| e.schedule == "ptq >> prune") {
                assert!(!qf.compliant, "seed {seed} budget {budget}");
                assert!(pf.acc_drop < qf.acc_drop, "seed {seed} budget {budget}");
                assert!(
                    !out.front.iter().any(|e| e.schedule == "ptq >> prune"),
                    "seed {seed} budget {budget}: violator on front"
                );
            }
        }
    }
    // the hand-checked point: budget 8, seed 42 promotes BOTH orderings
    let out = run_search(&config(8, 42, Jobs::one())).unwrap();
    assert!(out.full.iter().any(|e| e.schedule == "ptq >> prune"));
}
