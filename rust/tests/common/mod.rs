//! Shared helpers for integration tests (need `make artifacts` first).

use std::path::PathBuf;

/// Locate the artifacts directory (env override for CI layouts).
pub fn artifacts_dir() -> PathBuf {
    if let Ok(p) = std::env::var("HQP_ARTIFACTS") {
        return PathBuf::from(p);
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Panic with a helpful message when artifacts are missing.
pub fn require_artifacts() -> PathBuf {
    let dir = artifacts_dir();
    assert!(
        dir.join("manifest.json").exists(),
        "integration tests need AOT artifacts — run `make artifacts` first \
         (looked in {})",
        dir.display()
    );
    dir
}
