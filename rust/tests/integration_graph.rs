//! Integration: graph IR + optimizer + hardware model against the real
//! manifests (no PJRT execution — structural/deployment checks only).

mod common;

use hqp::gopt::{optimize, OptimizeOptions};
use hqp::graph::{full_masks, Graph, Liveness};
use hqp::hwsim::{simulate, Device};
use hqp::runtime::Workspace;

const MODELS: &[&str] = &["mobilenetv3", "resnet18"];

fn graph(ws: &Workspace, model: &str) -> Graph {
    Graph::from_manifest(ws.manifest.model(model).unwrap()).unwrap()
}

#[test]
fn graphs_build_and_validate() {
    let ws = Workspace::open(common::require_artifacts()).unwrap();
    for model in MODELS {
        let g = graph(&ws, model);
        g.validate().unwrap();
        assert!(g.dense_flops() > 1_000_000, "{model} should be MFLOP-scale");
        assert!(g.dense_params() > 10_000);
    }
}

#[test]
fn full_liveness_keeps_every_channel() {
    let ws = Workspace::open(common::require_artifacts()).unwrap();
    for model in MODELS {
        let g = graph(&ws, model);
        let live = Liveness::analyze(&g, &full_masks(&g)).unwrap();
        for n in &g.nodes {
            assert_eq!(
                live.count(n.output),
                g.channels(n.output),
                "{model}/{}: full masks must keep all channels",
                n.name
            );
        }
    }
}

#[test]
fn residual_coupling_limits_elimination_on_resnet() {
    // Masking a residual-block conv2 channel must NOT eliminate the trunk
    // channel (the skip path keeps it alive) — the §V-D coupling story.
    let ws = Workspace::open(common::require_artifacts()).unwrap();
    let g = graph(&ws, "resnet18");
    let gid = g
        .groups
        .iter()
        .find(|gr| gr.name == "stage0.block1.conv2")
        .expect("conv2 group")
        .id;
    let mut masks = full_masks(&g);
    masks[gid][0] = false;
    let live = Liveness::analyze(&g, &masks).unwrap();
    let add_node = g
        .nodes
        .iter()
        .find(|n| n.name == "stage0.block1.add")
        .unwrap();
    assert_eq!(
        live.count(add_node.output),
        g.channels(add_node.output),
        "skip path must keep the trunk channel alive"
    );
}

#[test]
fn mobilenet_expansion_masking_shrinks_depthwise() {
    // Masking expansion channels must propagate through the depthwise conv
    // (same prune group) and shrink the deployed engine.
    let ws = Workspace::open(common::require_artifacts()).unwrap();
    let g = graph(&ws, "mobilenetv3");
    let gid = g
        .groups
        .iter()
        .find(|gr| gr.name == "block1.expand")
        .expect("expand group")
        .id;
    let mut masks = full_masks(&g);
    let half = g.groups[gid].size / 2;
    for j in 0..half {
        masks[gid][j] = false;
    }
    let full_eng = optimize(&g, &full_masks(&g), &OptimizeOptions::fp32()).unwrap();
    let prun_eng = optimize(&g, &masks, &OptimizeOptions::fp32()).unwrap();
    assert!(prun_eng.flops() < full_eng.flops());
    let dw = prun_eng
        .ops
        .iter()
        .find(|o| o.name == "block1.dw")
        .expect("depthwise op survives");
    assert_eq!(dw.cout, g.groups[gid].size - half);
}

#[test]
fn deployment_orderings_hold_on_every_device() {
    // The relations the paper's tables depend on must hold structurally:
    // int8 ≤ fp32 latency; pruned+int8 ≤ int8; sizes likewise.
    let ws = Workspace::open(common::require_artifacts()).unwrap();
    for model in MODELS {
        let g = graph(&ws, model);
        let masks_full = full_masks(&g);
        let mut masks_third = masks_full.clone();
        for (gi, gr) in g.groups.iter().enumerate() {
            for j in 0..gr.size / 3 {
                masks_third[gi][j] = false;
            }
        }
        let fp32 = optimize(&g, &masks_full, &OptimizeOptions::fp32()).unwrap();
        let int8 = optimize(&g, &masks_full, &OptimizeOptions::int8()).unwrap();
        let hqp8 = optimize(&g, &masks_third, &OptimizeOptions::int8()).unwrap();
        assert!(int8.weight_bytes < fp32.weight_bytes);
        assert!(hqp8.weight_bytes < int8.weight_bytes);
        for dev in Device::all() {
            let l32 = simulate(&fp32, &dev).latency_ms;
            let l8 = simulate(&int8, &dev).latency_ms;
            let lh = simulate(&hqp8, &dev).latency_ms;
            assert!(l8 <= l32 * 1.0001, "{model}@{}: int8 {l8} vs fp32 {l32}", dev.name);
            assert!(lh <= l8 * 1.0001, "{model}@{}: hqp {lh} vs int8 {l8}", dev.name);
        }
    }
}

#[test]
fn int8_speedup_larger_on_nx_than_nano() {
    // §IV-A heterogeneity: tensor cores only on NX.
    let ws = Workspace::open(common::require_artifacts()).unwrap();
    for model in MODELS {
        let g = graph(&ws, model);
        let fp32 = optimize(&g, &full_masks(&g), &OptimizeOptions::fp32()).unwrap();
        let int8 = optimize(&g, &full_masks(&g), &OptimizeOptions::int8()).unwrap();
        let sp =
            |dev: &Device| simulate(&fp32, dev).latency_ms / simulate(&int8, dev).latency_ms;
        let nano = sp(&Device::jetson_nano());
        let nx = sp(&Device::xavier_nx());
        assert!(
            nx > nano,
            "{model}: NX int8 speedup {nx:.2} must exceed Nano {nano:.2}"
        );
    }
}

#[test]
fn fusion_reduces_deployed_latency() {
    let ws = Workspace::open(common::require_artifacts()).unwrap();
    for model in MODELS {
        let g = graph(&ws, model);
        let mut no_fuse = OptimizeOptions::fp32();
        no_fuse.fusion = false;
        let fused = optimize(&g, &full_masks(&g), &OptimizeOptions::fp32()).unwrap();
        let unfused = optimize(&g, &full_masks(&g), &no_fuse).unwrap();
        assert!(fused.ops.len() < unfused.ops.len());
        let dev = Device::xavier_nx();
        assert!(
            simulate(&fused, &dev).latency_ms < simulate(&unfused, &dev).latency_ms,
            "{model}: fusion must reduce latency"
        );
    }
}

#[test]
fn masking_everything_but_one_group_still_validates() {
    // Extreme masks must not break the optimizer (degenerate engines are
    // legal as long as at least the classifier path survives).
    let ws = Workspace::open(common::require_artifacts()).unwrap();
    let g = graph(&ws, "resnet18");
    let mut masks = full_masks(&g);
    for m in masks.iter_mut() {
        for j in 1..m.len() {
            m[j] = false; // keep exactly one filter per group
        }
    }
    let eng = optimize(&g, &masks, &OptimizeOptions::int8()).unwrap();
    assert!(eng.flops() > 0);
    assert!(simulate(&eng, &Device::xavier_nx()).latency_ms > 0.0);
}
