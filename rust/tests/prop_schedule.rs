//! Property tests for the schedule grammar (testkit harness — the
//! offline proptest substitute, DESIGN.md §Substitutions).
//!
//! These run WITHOUT artifacts: they exercise parsing, canonicalization,
//! preset lowering and cache-key construction over randomized schedules:
//!
//! * **round-trip** — `parse → canonical → parse` is the identity
//!   (spec-level equality AND canonical-string fixed point);
//! * **loud errors** — unknown stage names list the valid stage set,
//!   unknown arguments list the stage's valid arguments;
//! * **lowering** — every legacy `MethodSpec` lowers to a schedule whose
//!   label matches the legacy method name and whose legacy cache key is
//!   exactly the pre-schedule key (the on-disk fallback contract);
//! * **keys** — distinct schedules get distinct, filesystem-safe slugs.

use hqp::coordinator::MethodSpec;
use hqp::hqp::{HqpConfig, RankingMethod, Schedule, StageSpec};
use hqp::quant::CalibMethod;
use hqp::testkit::prng::Prng;

const CASES: usize = 300;

const RANKINGS: [RankingMethod; 4] = [
    RankingMethod::Fisher,
    RankingMethod::MagnitudeL1,
    RankingMethod::MagnitudeL2,
    RankingMethod::BnGamma,
];
const CALIBS: [CalibMethod; 3] = [CalibMethod::Kl, CalibMethod::MinMax, CalibMethod::Percentile];

/// A random fraction over (0, 1] with a power-of-two denominator, so the
/// percent round-trip (`v*100` → shortest decimal → `/100`) is exact and
/// spec-level equality is testable with `==`. (Grammar users type decimal
/// percents, which are themselves fixed points after one parse — the
/// string-level identity below covers that path.)
fn frac(rng: &mut Prng) -> f64 {
    (rng.below(1024) + 1) as f64 / 1024.0
}

/// A random calibration-sample count ≥ 1.
fn sample_count(rng: &mut Prng) -> usize {
    [1, 64, 256, 512, 1024, 2048, 4096][rng.below(7)]
}

fn gen_stage(rng: &mut Prng) -> StageSpec {
    match rng.below(5) {
        0 => StageSpec::MeasureBaseline,
        1 => StageSpec::Prune {
            ranking: if rng.next_f64() < 0.5 {
                Some(RANKINGS[rng.below(RANKINGS.len())])
            } else {
                None
            },
            step_frac: if rng.next_f64() < 0.5 { Some(frac(rng)) } else { None },
            delta_max: if rng.next_f64() < 0.5 { Some(frac(rng)) } else { None },
            max_sparsity: if rng.next_f64() < 0.5 { Some(frac(rng)) } else { None },
            samples: if rng.next_f64() < 0.5 { Some(sample_count(rng)) } else { None },
        },
        2 => StageSpec::PruneTo {
            ranking: if rng.next_f64() < 0.5 {
                Some(RANKINGS[rng.below(RANKINGS.len())])
            } else {
                None
            },
            theta: frac(rng),
        },
        3 => StageSpec::Ptq {
            calib: if rng.next_f64() < 0.5 {
                Some(CALIBS[rng.below(CALIBS.len())])
            } else {
                None
            },
            recalib: rng.next_f64() < 0.25,
            samples: if rng.next_f64() < 0.5 { Some(sample_count(rng)) } else { None },
        },
        _ => StageSpec::Mixed {
            int4_quantile: if rng.next_f64() < 0.5 { Some(frac(rng)) } else { None },
            fp16_quantile: if rng.next_f64() < 0.5 { Some(frac(rng)) } else { None },
        },
    }
}

fn gen_schedule(rng: &mut Prng) -> Schedule {
    let n = rng.below(5) + 1;
    Schedule::new((0..n).map(|_| gen_stage(rng)).collect())
}

#[test]
fn prop_parse_canonical_parse_is_identity() {
    let mut rng = Prng::new(0x5C4ED);
    for case_no in 0..CASES {
        let sched = gen_schedule(&mut rng);
        let canonical = sched.canonical();
        let parsed = Schedule::parse(&canonical)
            .unwrap_or_else(|e| panic!("case {case_no}: `{canonical}` must parse: {e}"));
        assert_eq!(
            parsed.stages, sched.stages,
            "case {case_no}: parse(canonical) must reproduce the stages of `{canonical}`"
        );
        assert_eq!(
            parsed.canonical(),
            canonical,
            "case {case_no}: canonical must be a fixed point"
        );
        // the cache slug is a function of the canonical string alone
        assert_eq!(parsed.cache_slug(), sched.cache_slug(), "case {case_no}");
    }
}

#[test]
fn prop_spacing_is_normalized_away() {
    // the same schedule spelled with arbitrary whitespace parses to the
    // same canonical form
    let mut rng = Prng::new(0x51ACE);
    for case_no in 0..CASES / 3 {
        let sched = gen_schedule(&mut rng);
        let canonical = sched.canonical();
        let pad = |rng: &mut Prng| " ".repeat(rng.below(3));
        let mut sloppy = String::new();
        for (i, st) in sched.stages.iter().enumerate() {
            if i > 0 {
                sloppy.push_str(&format!("{}>>{}", pad(&mut rng), pad(&mut rng)));
            }
            sloppy.push_str(&st.canonical());
        }
        let parsed = Schedule::parse(&sloppy)
            .unwrap_or_else(|e| panic!("case {case_no}: `{sloppy}` must parse: {e}"));
        assert_eq!(parsed.canonical(), canonical, "case {case_no}");
    }
}

#[test]
fn prop_typed_decimal_percents_are_canonical_fixed_points() {
    // what the user types is what canonical (and the cache slug) says:
    // every quarter-percent from 0.25% to 100% survives verbatim —
    // fmt_pct searches for the shortest decimal that re-parses exactly,
    // instead of printing the v*100 rounding artifact
    for k in 1..=400u32 {
        let pct = k as f64 / 4.0;
        let src = format!("prune-to(theta={pct}%)");
        let sched = Schedule::parse(&src).unwrap();
        assert_eq!(
            sched.canonical(),
            src,
            "typed percent {pct}% must round-trip verbatim"
        );
        assert_eq!(Schedule::parse(&sched.canonical()).unwrap().stages, sched.stages);
    }
}

#[test]
fn prop_unknown_stages_and_args_are_loud() {
    let mut rng = Prng::new(0xBAD5);
    let valid: Vec<&str> = vec!["measure-baseline", "prune", "prune-to", "ptq", "mixed"];
    for _ in 0..CASES / 3 {
        // a name that is not a valid stage
        let junk: String = (0..rng.below(6) + 1)
            .map(|_| (b'a' + rng.below(26) as u8) as char)
            .collect();
        if valid.contains(&junk.as_str())
            || ["step", "dmax", "theta", "int4", "fp16", "samples", "recalib"]
                .contains(&junk.as_str())
        {
            continue;
        }
        let e = Schedule::parse(&junk).unwrap_err().to_string();
        assert!(e.contains("unknown stage"), "`{junk}`: {e}");
        for name in &valid {
            assert!(e.contains(name), "`{junk}` error must list `{name}`: {e}");
        }
        // a valid stage with a junk keyword argument
        let e = Schedule::parse(&format!("prune({junk}=1%)"))
            .unwrap_err()
            .to_string();
        assert!(
            e.contains("unknown argument") || e.contains("valid"),
            "`prune({junk}=1%)`: {e}"
        );
    }
}

#[test]
fn prop_method_specs_lower_to_matching_presets() {
    let cfg = HqpConfig::default();
    let cases: Vec<(MethodSpec, &str)> = vec![
        (MethodSpec::Baseline, "baseline"),
        (MethodSpec::Q8Only, "q8-only"),
        (MethodSpec::PruneOnly(50), "p50-only"),
        (MethodSpec::PruneOnly(30), "p30-only"),
        (MethodSpec::Hqp, "hqp"),
        (
            MethodSpec::HqpWithRanking(RankingMethod::MagnitudeL2),
            "hqp[mag-l2]",
        ),
        (MethodSpec::HqpPruneOnly, "prune-only[fisher]"),
    ];
    for (spec, label) in cases {
        let sched = spec.to_schedule(&cfg);
        assert_eq!(sched.method_label(), label, "{spec:?}");
        // the fallback key is exactly the legacy on-disk key
        let legacy = sched.legacy_key.as_ref().expect("legacy key");
        assert_eq!(format!("m_{legacy}"), spec.cache_key("m"), "{spec:?}");
        // a preset's canonical form re-parses to the same stages (so the
        // deprecated alias and the grammar agree on what runs)
        let reparsed = Schedule::parse(&sched.canonical()).unwrap();
        assert_eq!(reparsed.stages, sched.stages, "{spec:?}");
    }
    // every legacy --method spelling resolves as a preset
    for name in ["baseline", "q8", "p50", "prune", "hqp"] {
        assert!(
            Schedule::preset(name, &cfg).is_some(),
            "legacy --method {name} must resolve"
        );
    }
}

#[test]
fn prop_distinct_schedules_get_distinct_slugs() {
    let mut rng = Prng::new(0x51CC5);
    let mut by_slug: std::collections::HashMap<String, String> = std::collections::HashMap::new();
    for case_no in 0..CASES {
        let sched = gen_schedule(&mut rng);
        let canonical = sched.canonical();
        let slug = sched.cache_slug();
        assert!(
            slug.chars().all(|c| c.is_ascii_alphanumeric() || "+-._".contains(c)),
            "case {case_no}: slug `{slug}` must be filesystem-safe"
        );
        if let Some(prev) = by_slug.insert(slug.clone(), canonical.clone()) {
            assert_eq!(
                prev, canonical,
                "case {case_no}: slug `{slug}` collides across distinct schedules"
            );
        }
    }
}

#[test]
fn ordering_ablation_is_expressible_and_distinct() {
    // the acceptance-criterion ordering: quantize-first, inexpressible
    // under the closed enum, must parse and must key differently from
    // prune-first
    let qf = Schedule::parse("ptq >> prune").unwrap();
    let pf = Schedule::parse("prune >> ptq").unwrap();
    assert_ne!(qf.stages, pf.stages);
    assert_ne!(qf.cache_slug(), pf.cache_slug());
    assert_eq!(qf.method_label(), "ptq >> prune");
    assert!(qf.legacy_key.is_none(), "ad-hoc schedules have no v1 fallback");
}
