//! Integration: the PJRT runtime against the real AOT artifacts.
//!
//! Exercises the full L2→L3 contract: manifest parse, weight/dataset
//! loading, HLO-text compile, buffer execution, masking semantics.
//! One `#[test]` per concern, all sharing a single workspace (PJRT client
//! creation is cheap but executable compiles are not — tests are grouped
//! to compile each artifact once).

mod common;

use hqp::graph::Graph;
use hqp::runtime::{ParamStore, Session, Workspace};
use hqp::tensor::Tensor;

const MODELS: &[&str] = &["mobilenetv3", "resnet18"];

#[test]
fn manifest_contract_holds_for_all_models() {
    let ws = Workspace::open(common::require_artifacts()).unwrap();
    for model in MODELS {
        let mm = ws.manifest.model(model).unwrap();
        // group offsets tile the filter space exactly
        let mut expect = 0usize;
        for g in &mm.groups {
            assert_eq!(g.offset, expect, "{model}: group {} offset", g.name);
            expect += g.size;
            // every member param exists with the named axis in range
            for (p, axis) in &g.members {
                let spec = &mm.param_order[mm.param_index(p).unwrap()];
                assert!(
                    *axis < spec.shape.len(),
                    "{model}: member {p} axis {axis} vs {:?}",
                    spec.shape
                );
                assert_eq!(
                    spec.shape[*axis], g.size,
                    "{model}: member {p} axis len != group size"
                );
            }
        }
        assert_eq!(expect, mm.total_filters());
        // artifacts present for the full exported fn set
        for fn_name in ["eval", "fisher", "absmax", "hist", "quant_eval"] {
            let art = mm.artifacts.get(fn_name).expect(fn_name);
            assert!(
                ws.root.join(&art.file).exists(),
                "{model}: missing artifact file {}",
                art.file
            );
        }
        // the graph IR builds and validates from the same manifest
        let g = Graph::from_manifest(mm).unwrap();
        assert!(g.dense_flops() > 0);
        assert_eq!(g.groups.len(), mm.groups.len());
    }
}

#[test]
fn weights_and_datasets_load_with_expected_shapes() {
    let ws = Workspace::open(common::require_artifacts()).unwrap();
    for model in MODELS {
        let mm = ws.manifest.model(model).unwrap();
        let ps = ParamStore::load(&ws.root, mm).unwrap();
        assert_eq!(ps.len(), mm.param_order.len());
        assert!(ps.num_elements() > 10_000, "{model} suspiciously small");
    }
    for split in ["calib", "val", "test"] {
        let (x, y) = ws.load_split(split).unwrap();
        assert_eq!(x.shape()[1..], [32, 32, 3]);
        assert_eq!(x.shape()[0], y.shape()[0]);
        // labels are valid classes
        assert!(y.data().iter().all(|&c| (0..10).contains(&c)));
        // images normalized to [0, 1]
        assert!(x.data().iter().all(|&v| (0.0..=1.0).contains(&v)));
    }
}

#[test]
fn eval_executes_and_baseline_accuracy_matches_manifest() {
    let ws = Workspace::open(common::require_artifacts()).unwrap();
    for model in MODELS {
        let mut sess = Session::new(&ws, model).unwrap();
        let params = sess.baseline.clone();
        let acc = sess.accuracy(&params, "val").unwrap();
        let expect = sess.mm.baseline_val_acc;
        assert!(
            (acc - expect).abs() < 0.01,
            "{model}: rust-measured {acc} vs python-measured {expect}"
        );
        assert!(sess.counters.executions > 0);
        assert_eq!(sess.counters.inference_samples, 1024);
    }
}

#[test]
fn eval_logits_padding_invariance() {
    let ws = Workspace::open(common::require_artifacts()).unwrap();
    let mut sess = Session::new(&ws, "resnet18").unwrap();
    let params = sess.baseline.clone();
    let (x, _y) = ws.load_split("val").unwrap();
    let full = x.rows(0, 8).unwrap();
    let l8 = sess.eval_logits(&params, &full).unwrap();
    let l3 = sess.eval_logits(&params, &x.rows(0, 3).unwrap()).unwrap();
    assert_eq!(l8.shape(), &[8, 10]);
    assert_eq!(l3.shape(), &[3, 10]);
    // same inputs -> same logits regardless of padding rows
    for i in 0..3 * 10 {
        assert!(
            (l8.data()[i] - l3.data()[i]).abs() < 1e-4,
            "logit {i}: {} vs {}",
            l8.data()[i],
            l3.data()[i]
        );
    }
}

#[test]
fn masking_a_filter_is_structural_removal() {
    // Zeroing a group via its member list must (a) change the logits of the
    // model only as much as removing that channel would, and (b) be exactly
    // reproducible: masking twice == masking once (idempotent).
    let ws = Workspace::open(common::require_artifacts()).unwrap();
    let mut sess = Session::new(&ws, "resnet18").unwrap();
    let mm = sess.mm.clone();
    let (x, _y) = ws.load_split("val").unwrap();
    let xb = x.rows(0, 16).unwrap();

    let mut masked = sess.baseline.clone();
    let g = &mm.groups[2];
    masked.mask_filter(g, 0).unwrap();
    let once = sess.eval_logits(&masked, &xb).unwrap();

    let mut twice = masked.clone();
    twice.mask_filter(g, 0).unwrap();
    let again = sess.eval_logits(&twice, &xb).unwrap();
    assert_eq!(once.data(), again.data(), "masking must be idempotent");

    // and the zero slices really are zero
    let w = masked.get(&g.producer).unwrap();
    assert_eq!(w.slice_norm(g.producer_axis, 0, true).unwrap(), 0.0);
}

#[test]
fn quant_eval_rejects_wrong_scale_count() {
    let ws = Workspace::open(common::require_artifacts()).unwrap();
    let mut sess = Session::new(&ws, "resnet18").unwrap();
    let params = sess.baseline.clone();
    let bad = vec![0.1f32; 3];
    assert!(sess.quant_accuracy(&params, &bad, "val").is_err());
}

#[test]
fn quant_eval_with_absmax_scales_tracks_fp32() {
    // With per-tap scales = absmax/127 (full range, no saturation) the
    // INT8 artifact must compute nearly the same function as the FP32 one
    // — unquantized weights, only activations on the grid.
    let ws = Workspace::open(common::require_artifacts()).unwrap();
    let mut sess = Session::new(&ws, "resnet18").unwrap();
    let params = sess.baseline.clone();
    let fp32 = sess.accuracy(&params, "val").unwrap();
    let ranges = sess.act_absmax(&params).unwrap();
    let scales: Vec<f32> = ranges.iter().map(|&r| r / 127.0).collect();
    let q = sess.quant_accuracy(&params, &scales, "val").unwrap();
    assert!(
        (fp32 - q).abs() < 0.03,
        "absmax-scale quant_eval {q} strays from fp32 {fp32}"
    );

    // and saturating scales must hurt badly (sanity that scales matter)
    let saturating = vec![1e-4f32; sess.mm.taps.len()];
    let qs = sess.quant_accuracy(&params, &saturating, "val").unwrap();
    assert!(qs < fp32 - 0.2, "saturating scales should collapse accuracy, got {qs}");
}

#[test]
fn absmax_and_hist_are_consistent() {
    let ws = Workspace::open(common::require_artifacts()).unwrap();
    let mut sess = Session::new(&ws, "resnet18").unwrap();
    let params = sess.baseline.clone();
    let ranges = sess.act_absmax(&params).unwrap();
    assert_eq!(ranges.len(), sess.mm.taps.len());
    assert!(ranges.iter().all(|&r| r > 0.0), "activations can't be all-zero");

    let hist = sess.act_hist(&params, &ranges).unwrap();
    assert_eq!(hist.shape(), &[sess.mm.taps.len(), 2048]);
    let total: f32 = hist.data().iter().sum();
    assert!(total > 0.0);
    // every tap's histogram mass equals the number of activation elements
    // counted — and no mass can land beyond the measured absmax except the
    // clamped top bin; sanity: all counts non-negative.
    assert!(hist.data().iter().all(|&c| c >= 0.0));
}

#[test]
fn fisher_scores_nonnegative_and_informative() {
    let ws = Workspace::open(common::require_artifacts()).unwrap();
    let mut sess = Session::new(&ws, "resnet18").unwrap();
    let params = sess.baseline.clone();
    let s = sess.fisher_scores(&params, 64).unwrap();
    assert_eq!(s.len(), sess.mm.total_filters());
    assert!(s.iter().all(|&v| v >= 0.0), "squared grads are non-negative");
    let nonzero = s.iter().filter(|&&v| v > 0.0).count();
    assert!(
        nonzero > s.len() / 2,
        "most filters should carry gradient signal ({nonzero}/{})",
        s.len()
    );
    assert!(sess.counters.grad_samples >= 64);
}

#[test]
fn param_buffer_cache_uploads_cold_then_nothing() {
    let ws = Workspace::open(common::require_artifacts()).unwrap();
    let mut sess = Session::new(&ws, "resnet18").unwrap();
    let params = sess.baseline.clone();

    // first call: every tensor moves host→device
    sess.accuracy(&params, "val").unwrap();
    let after_cold = sess.counters;
    assert_eq!(after_cold.upload_tensors as usize, params.len());
    assert_eq!(after_cold.upload_bytes as usize, params.num_bytes());

    // same (unmutated) params again: zero uploads
    sess.accuracy(&params, "val").unwrap();
    assert_eq!(sess.counters.upload_tensors, after_cold.upload_tensors);
    assert_eq!(sess.counters.upload_bytes, after_cold.upload_bytes);

    // a CLONE of the same params shares every version: still zero uploads
    let cloned = params.clone();
    sess.accuracy(&cloned, "val").unwrap();
    assert_eq!(sess.counters.upload_tensors, after_cold.upload_tensors);
}

#[test]
fn param_buffer_cache_invalidates_exactly_the_masked_tensors() {
    let ws = Workspace::open(common::require_artifacts()).unwrap();
    let mut sess = Session::new(&ws, "resnet18").unwrap();
    let params = sess.baseline.clone();
    let mm = sess.mm.clone();
    sess.accuracy(&params, "val").unwrap(); // warm

    // mask one filter of one group: only that group's member tensors (and
    // exactly their bytes) re-upload
    let g = mm.groups[2].clone();
    let mut cand = params.clone();
    cand.mask_filter(&g, 0).unwrap();
    let before = sess.counters;
    let acc_masked = sess.accuracy(&cand, "val").unwrap();
    let uploaded = (sess.counters.upload_tensors - before.upload_tensors) as usize;
    assert_eq!(uploaded, g.members.len(), "one δ-step uploads only dirty tensors");
    let member_bytes: usize = g
        .members
        .iter()
        .map(|(name, _)| cand.get(name).unwrap().len() * std::mem::size_of::<f32>())
        .sum();
    assert_eq!(
        (sess.counters.upload_bytes - before.upload_bytes) as usize,
        member_bytes
    );

    // and the cached-buffer path computes the same answer as a fresh session
    let mut fresh = Session::new(&ws, "resnet18").unwrap();
    let acc_fresh = fresh.accuracy(&cand, "val").unwrap();
    assert_eq!(acc_masked, acc_fresh, "cache must be byte-exact");
}

#[test]
fn accuracy_bounded_matches_full_sweep_decision_and_value() {
    let ws = Workspace::open(common::require_artifacts()).unwrap();
    let mut sess = Session::new(&ws, "resnet18").unwrap();
    let params = sess.baseline.clone();
    let mm = sess.mm.clone();
    let base = sess.accuracy(&params, "val").unwrap();

    // healthy candidate: decision accept, and (if the sweep completed) the
    // exact same accuracy as the full pass
    let b = sess.accuracy_bounded(&params, "val", base, 0.015).unwrap();
    assert!(b.accepted);
    if b.exact {
        assert_eq!(b.accuracy, base);
    }

    // collapsed candidate: early reject, with batches actually skipped
    let mut collapsed = params.clone();
    for f in 0..mm.total_filters() / 2 {
        let (g, j) = mm.locate_filter(f).unwrap();
        collapsed.mask_filter(g, j).unwrap();
    }
    let full = sess.accuracy(&collapsed, "val").unwrap();
    let before = sess.counters;
    let b = sess.accuracy_bounded(&collapsed, "val", base, 0.015).unwrap();
    assert_eq!(b.accepted, base - full <= 0.015);
    assert_eq!(
        sess.counters.batches_skipped - before.batches_skipped,
        b.batches_skipped as u64
    );
    if !b.accepted {
        assert!(
            b.batches_skipped > 0,
            "a collapsed candidate should reject before the last batch"
        );
    }
}

#[test]
fn pad_rows_respects_batch_contract() {
    let ws = Workspace::open(common::require_artifacts()).unwrap();
    let mut sess = Session::new(&ws, "resnet18").unwrap();
    let params = sess.baseline.clone();
    let eb = sess.mm.eval_batch;
    let too_big = Tensor::zeros(vec![eb + 1, 32, 32, 3]);
    assert!(sess.eval_logits(&params, &too_big).is_err());
}
