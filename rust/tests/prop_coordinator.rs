//! Property tests over the coordinator invariants (testkit harness — the
//! offline substitute for proptest; see DESIGN.md §Substitutions).
//!
//! These run WITHOUT artifacts: they drive the pure-Rust substrates
//! (liveness, quantization, calibration, JSON/npy, autotune, ranking) over
//! randomized inputs.

use hqp::formats::json::Json;
use hqp::formats::npy::{read_npy_f32, write_npy_f32};
use hqp::gopt::autotune::{autotune, tile_efficiency, DEFAULT_TILES};
use hqp::quant::{dequantize, quantize_per_channel, quantize_per_tensor, Calibrator, CalibMethod};
use hqp::tensor::Tensor;
use hqp::testkit::prng::Prng;

const CASES: usize = 200;

#[test]
fn prop_quantize_roundtrip_error_bounded_by_half_step() {
    let mut rng = Prng::new(101);
    for _ in 0..CASES {
        let n = rng.below(64) + 1;
        let amp = rng.next_f32() * 100.0 + 1e-3;
        let data: Vec<f32> = (0..n).map(|_| (rng.next_f32() * 2.0 - 1.0) * amp).collect();
        let t = Tensor::from_slice(&data);
        let q = quantize_per_tensor(&t, 8);
        let d = dequantize(&q).unwrap();
        let s = q.scales[0];
        for (a, b) in t.data().iter().zip(d.data()) {
            assert!(
                (a - b).abs() <= 0.5 * s + 1e-6,
                "|{a} - {b}| > s/2 = {}",
                0.5 * s
            );
        }
    }
}

#[test]
fn prop_per_channel_error_never_worse_than_per_tensor() {
    let mut rng = Prng::new(202);
    for _ in 0..CASES {
        let c = rng.below(8) + 2;
        let k = rng.below(16) + 1;
        let mut data = Vec::with_capacity(c * k);
        for ch in 0..c {
            // channels with wildly different magnitudes
            let amp = 10f32.powi(rng.range(-2, 2) as i32) * (ch as f32 + 1.0);
            for _ in 0..k {
                data.push((rng.next_f32() * 2.0 - 1.0) * amp);
            }
        }
        let t = Tensor::new(vec![c, k], data).unwrap();
        let err = |d: &Tensor| -> f64 {
            t.data()
                .iter()
                .zip(d.data())
                .map(|(a, b)| ((a - b) as f64).powi(2))
                .sum()
        };
        let e_pt = err(&dequantize(&quantize_per_tensor(&t, 8)).unwrap());
        let e_pc = err(&dequantize(&quantize_per_channel(&t, 0, 8).unwrap()).unwrap());
        assert!(
            e_pc <= e_pt * 1.0001 + 1e-12,
            "per-channel mse {e_pc} > per-tensor {e_pt}"
        );
    }
}

#[test]
fn prop_calibrator_threshold_in_range() {
    let mut rng = Prng::new(303);
    let cals = [
        Calibrator::new(CalibMethod::MinMax),
        Calibrator::new(CalibMethod::Percentile),
        Calibrator::new(CalibMethod::Kl),
    ];
    for _ in 0..60 {
        let bins = 2048;
        let mut hist = vec![0f32; bins];
        // random mixture of gaussians + outlier spikes
        for _ in 0..rng.below(4) + 1 {
            let center = rng.below(bins);
            let sigma = (rng.below(200) + 5) as f64;
            for (i, h) in hist.iter_mut().enumerate() {
                let d = (i as f64 - center as f64) / sigma;
                *h += (1000.0 * (-0.5 * d * d).exp()) as f32;
            }
        }
        if rng.next_f32() < 0.5 {
            let spike = bins - 1 - rng.below(50);
            hist[spike] += (rng.below(10) + 1) as f32;
        }
        let range = rng.next_f32() * 20.0 + 0.01;
        for cal in &cals {
            let t = cal.threshold(&hist, range);
            assert!(
                t > 0.0 && t <= range * 1.0001,
                "threshold {t} out of (0, {range}]"
            );
            let s = cal.scale(&hist, range);
            assert!(s > 0.0 && s.is_finite());
        }
    }
}

#[test]
fn prop_json_roundtrip() {
    let mut rng = Prng::new(404);
    fn gen(rng: &mut Prng, depth: usize) -> Json {
        match if depth > 3 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.next_f32() < 0.5),
            2 => Json::Num((rng.next_f64() * 2e6).round() / 1e3 - 1000.0),
            3 => {
                let n = rng.below(12);
                Json::Str(
                    (0..n)
                        .map(|_| {
                            let c = rng.below(96) as u8 + 32;
                            c as char
                        })
                        .collect(),
                )
            }
            4 => Json::Arr((0..rng.below(5)).map(|_| gen(rng, depth + 1)).collect()),
            _ => Json::Obj(
                (0..rng.below(5))
                    .map(|i| (format!("k{i}"), gen(rng, depth + 1)))
                    .collect(),
            ),
        }
    }
    for _ in 0..CASES {
        let v = gen(&mut rng, 0);
        let compact = Json::parse(&v.to_string()).unwrap();
        let pretty = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(compact, v);
        assert_eq!(pretty, v);
    }
}

#[test]
fn prop_npy_roundtrip() {
    let mut rng = Prng::new(505);
    let dir = std::env::temp_dir().join("hqp_prop_npy");
    std::fs::create_dir_all(&dir).unwrap();
    for case in 0..60 {
        let rank = rng.below(3) + 1;
        let shape: Vec<usize> = (0..rank).map(|_| rng.below(6) + 1).collect();
        let n: usize = shape.iter().product();
        let data: Vec<f32> = (0..n).map(|_| (rng.next_f32() - 0.5) * 1e4).collect();
        let t = Tensor::new(shape, data).unwrap();
        let p = dir.join(format!("case{case}.npy"));
        write_npy_f32(&p, &t).unwrap();
        assert_eq!(read_npy_f32(&p).unwrap(), t);
    }
}

#[test]
fn prop_autotune_never_worse_than_any_candidate() {
    let mut rng = Prng::new(606);
    for _ in 0..CASES {
        let m = rng.below(2000) + 1;
        let n = rng.below(2000) + 1;
        let k = rng.below(2000) + 1;
        let (_, best) = autotune(m, n, k, DEFAULT_TILES);
        for &t in DEFAULT_TILES {
            assert!(
                best >= tile_efficiency(m, n, k, t) - 1e-12,
                "autotune missed a better tile for {m}x{n}x{k}"
            );
        }
        assert!(best > 0.0 && best <= 1.0);
    }
}

#[test]
fn prop_ranking_sorts_scores_ascending() {
    let mut rng = Prng::new(707);
    for _ in 0..CASES {
        let n = rng.below(500) + 1;
        let scores: Vec<f32> = (0..n).map(|_| rng.next_f32() * 10.0).collect();
        let mut ranking: Vec<usize> = (0..n).collect();
        ranking.sort_by(|&a, &b| scores[a].total_cmp(&scores[b]));
        for w in ranking.windows(2) {
            assert!(scores[w[0]] <= scores[w[1]]);
        }
        // ranking is a permutation
        let mut seen = vec![false; n];
        for &i in &ranking {
            assert!(!seen[i]);
            seen[i] = true;
        }
    }
}

#[test]
fn prop_zero_slice_only_touches_its_slice() {
    let mut rng = Prng::new(808);
    for _ in 0..CASES {
        let rank = rng.below(3) + 1;
        let shape: Vec<usize> = (0..rank).map(|_| rng.below(5) + 1).collect();
        let n: usize = shape.iter().product();
        let data: Vec<f32> = (0..n).map(|i| i as f32 + 1.0).collect();
        let mut t = Tensor::new(shape.clone(), data.clone()).unwrap();
        let axis = rng.below(rank);
        let idx = rng.below(shape[axis]);
        t.zero_slice(axis, idx).unwrap();
        let strides = t.strides();
        for (i, (&v, &orig)) in t.data().iter().zip(&data).enumerate() {
            let coord = (i / strides[axis]) % shape[axis];
            if coord == idx {
                assert_eq!(v, 0.0);
            } else {
                assert_eq!(v, orig);
            }
        }
    }
}
