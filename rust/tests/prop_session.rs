//! Property tests for the early-exit bounded-validation evaluator
//! (testkit harness; runs WITHOUT artifacts — [`BoundedEval`] is pure
//! host-side arithmetic, exactly the code `Session::accuracy_bounded`
//! drives batch by batch).
//!
//! The contract under test (ISSUE 1 acceptance): the bounded sweep returns
//! the *identical* accept/reject decision as the full sweep — rounding
//! included — and, when it runs to completion, the identical accuracy.

use hqp::runtime::{BoundedEval, BoundedVerdict};
use hqp::testkit::prng::Prng;
use hqp::testkit::prop::{forall, Gen};

/// One randomized validation sweep: a split of `total` samples cut into
/// batches, with a per-batch correct-count, against a (baseline, Δ_max)
/// constraint. Constraint values deliberately stray outside [0, 1] to hit
/// the degenerate always-accept / never-accept regimes.
#[derive(Clone, Debug)]
struct Sweep {
    batches: Vec<(usize, usize)>, // (correct, valid), Σ valid = total
    baseline_acc: f64,
    delta_max: f64,
}

impl Sweep {
    fn total(&self) -> usize {
        self.batches.iter().map(|&(_, v)| v).sum()
    }

    /// The historical full-sweep predicate of Algorithm 1, verbatim.
    fn full_decision(&self) -> bool {
        let total = self.total();
        let correct: usize = self.batches.iter().map(|&(c, _)| c).sum();
        let acc = correct as f64 / total as f64;
        self.baseline_acc - acc <= self.delta_max
    }

    fn full_accuracy(&self) -> f64 {
        let correct: usize = self.batches.iter().map(|&(c, _)| c).sum();
        correct as f64 / self.total() as f64
    }
}

struct SweepGen;

impl Gen for SweepGen {
    type Value = Sweep;

    fn generate(&self, rng: &mut Prng) -> Sweep {
        let total = rng.below(600) + 1;
        let batch = rng.below(total) + 1;
        // per-batch accuracy regimes: collapsed, marginal, healthy
        let p = match rng.below(3) {
            0 => rng.next_f64() * 0.2,
            1 => 0.85 + rng.next_f64() * 0.1,
            _ => rng.next_f64(),
        };
        let mut batches = Vec::new();
        let mut lo = 0usize;
        while lo < total {
            let valid = batch.min(total - lo);
            let correct = (0..valid).filter(|_| rng.next_f64() < p).count();
            batches.push((correct, valid));
            lo += valid;
        }
        let baseline_acc = rng.next_f64() * 1.4 - 0.2; // [-0.2, 1.2]
        let delta_max = rng.next_f64() * 0.6 - 0.1; // [-0.1, 0.5]
        Sweep { batches, baseline_acc, delta_max }
    }

    fn shrink(&self, v: &Sweep) -> Vec<Sweep> {
        let mut out = Vec::new();
        if v.batches.len() > 1 {
            let mut fewer = v.clone();
            fewer.batches.pop();
            out.push(fewer);
        }
        if v.batches.iter().any(|&(c, _)| c > 0) {
            let mut zeroed = v.clone();
            for b in &mut zeroed.batches {
                b.0 = 0;
            }
            out.push(zeroed);
        }
        out
    }
}

/// Run the evaluator the way `Session::accuracy_bounded` does: fold batches
/// until the verdict is forced (or the sweep is pre-decided), then stop.
fn run_bounded(s: &Sweep) -> (BoundedEval, usize) {
    let mut ev = BoundedEval::new(s.total(), s.baseline_acc, s.delta_max);
    let mut run = 0usize;
    if ev.verdict() == BoundedVerdict::Undecided {
        for &(correct, valid) in &s.batches {
            run += 1;
            if ev.update(correct, valid) != BoundedVerdict::Undecided {
                break;
            }
        }
    }
    (ev, run)
}

#[test]
fn prop_bounded_decision_equals_full_sweep() {
    forall(3000, &SweepGen, |s| {
        let (ev, _) = run_bounded(s);
        match ev.verdict() {
            BoundedVerdict::Accept => s.full_decision(),
            BoundedVerdict::Reject => !s.full_decision(),
            // Σ valid = total, so a finished fold is always decided
            BoundedVerdict::Undecided => false,
        }
    });
}

#[test]
fn prop_bounded_accuracy_exact_when_complete() {
    forall(3000, &SweepGen, |s| {
        let (ev, _) = run_bounded(s);
        // bitwise equality, not epsilon: a completed bounded sweep computes
        // the same correct/total division as the full sweep
        !ev.is_complete() || ev.accuracy() == s.full_accuracy()
    });
}

#[test]
fn prop_verdict_is_stable_once_decided() {
    // Folding in the batches an early exit would have skipped can never
    // flip the verdict — the definition of "the decision was forced".
    forall(3000, &SweepGen, |s| {
        let (ev, run) = run_bounded(s);
        let early = ev.verdict();
        if early == BoundedVerdict::Undecided {
            return false;
        }
        let mut cont = ev;
        for &(correct, valid) in &s.batches[run..] {
            cont.update(correct, valid);
        }
        cont.verdict() == early
    });
}

#[test]
fn prop_skipped_batches_only_on_forced_decisions() {
    // If the bounded run stopped early, flipping every remaining sample
    // (all-correct vs all-wrong) must still produce the same decision.
    forall(3000, &SweepGen, |s| {
        let (ev, run) = run_bounded(s);
        if run == s.batches.len() {
            return true; // nothing skipped
        }
        let decided = ev.verdict();
        let mut best = ev;
        let mut worst = ev;
        for &(_, valid) in &s.batches[run..] {
            best.update(valid, valid);
            worst.update(0, valid);
        }
        best.verdict() == decided && worst.verdict() == decided
    });
}
