//! The fidelity ladder: cheap and full schedule evaluations, against
//! either the reference surrogate (bare checkout) or a real workspace.
//!
//! Both fidelities price the *deployed engine* for real — layer table at
//! the final θ/precision through [`reference_engine_at`] and the hwsim
//! roofline — because latency and size are cheap and exact. Fidelity
//! only changes where the *accuracy* comes from:
//!
//! * **Cheap** — the surrogate without the staleness term, or (workspace
//!   backend) a free probe of the coordinator's schedule-slug result
//!   cache: previously-run candidates cost one JSON read.
//! * **Full** — the surrogate with the staleness term, or (workspace
//!   backend) a real [`crate::coordinator::run_schedule`] through
//!   `Schedule::run` with full-split Δ_max validation. `run_schedule`
//!   itself hits the slug cache, so re-searching is cheap.
//!
//! Evaluations fan out through [`crate::exec::parallel_map_init`] with
//! one worker state each (PJRT clients are not `Send`, so workspace
//! backends open a `Workspace` per worker), and results merge in
//! submission order — byte-identical at any `--jobs`.

use std::path::PathBuf;

use crate::coordinator::{self, load_schedule_results};
use crate::error::{Error, Result};
use crate::exec::{parallel_map_init, Jobs, PoolReport};
use crate::hwsim::{simulate, Device};
use crate::runtime::Workspace;
use crate::serve::fleet::reference_engine_at;

use super::generator::Candidate;
use super::surrogate;
use super::SearchConfig;

/// Successive-halving rung.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Fidelity {
    /// Rung 0: roofline latency + surrogate/cached accuracy.
    Cheap,
    /// Rung 1: full-split Δ_max validation (or staleness-aware surrogate).
    Full,
}

impl Fidelity {
    pub fn name(&self) -> &'static str {
        match self {
            Fidelity::Cheap => "cheap",
            Fidelity::Full => "full",
        }
    }
}

/// Where accuracy numbers come from.
#[derive(Clone, Debug)]
pub enum Backend {
    /// Paper-anchored surrogate (no artifacts needed — CI, benches).
    Reference,
    /// Real pipeline runs through a PJRT workspace at `root`.
    Workspace { root: PathBuf },
}

/// Per-worker evaluation state (a PJRT workspace is not `Send`, so each
/// worker opens its own).
pub enum WorkerState {
    Stateless,
    Ws(Box<Workspace>),
}

/// One priced schedule.
#[derive(Clone, Debug)]
pub struct Eval {
    /// Canonical schedule string (the candidate's identity).
    pub schedule: String,
    pub fidelity: Fidelity,
    /// Batch-1 latency on the search device, ms.
    pub latency_ms: f64,
    /// vs the dense FP32 engine on the same device.
    pub speedup: f64,
    /// 1 − deployed_bytes / dense_fp32_bytes.
    pub size_reduction: f64,
    /// Measured (full, workspace) or modeled accuracy drop.
    pub acc_drop: f64,
    /// Final filter sparsity θ.
    pub sparsity: f64,
    /// Δ_max compliance at the search's budget.
    pub compliant: bool,
    /// Accuracy came from the coordinator's result cache for free.
    pub cached: bool,
}

impl Backend {
    pub fn name(&self) -> &'static str {
        match self {
            Backend::Reference => "reference",
            Backend::Workspace { .. } => "workspace",
        }
    }

    fn init_worker(&self) -> Result<WorkerState> {
        match self {
            Backend::Reference => Ok(WorkerState::Stateless),
            Backend::Workspace { root } => Ok(WorkerState::Ws(Box::new(Workspace::open(root)?))),
        }
    }

    /// Price one candidate at one fidelity.
    fn evaluate(
        &self,
        st: &mut WorkerState,
        sc: &SearchConfig,
        cand: &Candidate,
        fid: Fidelity,
    ) -> Result<Eval> {
        match st {
            WorkerState::Stateless => surrogate_eval(sc, cand, fid),
            WorkerState::Ws(ws) => match fid {
                Fidelity::Cheap => {
                    let results_dir = ws.root.join("results");
                    match load_schedule_results(&results_dir, &sc.model, &cand.sched)? {
                        Some(rows) => rows_eval(sc, cand, fid, &rows, true),
                        None => surrogate_eval(sc, cand, fid),
                    }
                }
                Fidelity::Full => {
                    let rows = coordinator::run_schedule(
                        ws,
                        &sc.model,
                        &cand.sched,
                        &sc.hqp,
                        &Device::all(),
                        false,
                    )?;
                    rows_eval(sc, cand, fid, &rows, false)
                }
            },
        }
    }
}

/// Surrogate accuracy + real engine pricing.
fn surrogate_eval(sc: &SearchConfig, cand: &Candidate, fid: Fidelity) -> Result<Eval> {
    let p = surrogate::walk(&sc.model, &cand.sched, &sc.hqp, fid == Fidelity::Full)?;
    let engine = reference_engine_at(&sc.model, p.theta, p.int8, p.int4_back_frac)?;
    let baseline = reference_engine_at(&sc.model, 0.0, false, 0.0)?;
    let lat = simulate(&engine, &sc.device).latency_ms;
    let base_lat = simulate(&baseline, &sc.device).latency_ms;
    Ok(Eval {
        schedule: cand.sched.canonical(),
        fidelity: fid,
        latency_ms: lat,
        speedup: base_lat / lat,
        size_reduction: engine.size_reduction(),
        acc_drop: p.acc_drop,
        sparsity: p.theta,
        compliant: p.acc_drop <= sc.hqp.delta_max + 1e-9,
        cached: false,
    })
}

/// Map coordinator result rows (measured pipeline runs) onto an [`Eval`].
fn rows_eval(
    sc: &SearchConfig,
    cand: &Candidate,
    fid: Fidelity,
    rows: &[coordinator::ResultRow],
    cached: bool,
) -> Result<Eval> {
    let reports = coordinator::experiments::reports_for_device(rows, &sc.device.name);
    let r = reports.first().ok_or_else(|| {
        Error::hqp(format!(
            "schedule `{}` produced no rows for device {}",
            cand.sched.canonical(),
            sc.device.name
        ))
    })?;
    Ok(Eval {
        schedule: cand.sched.canonical(),
        fidelity: fid,
        latency_ms: r.latency_ms,
        speedup: r.speedup,
        size_reduction: r.size_reduction,
        acc_drop: r.acc_drop,
        sparsity: r.sparsity,
        compliant: r.acc_drop <= sc.hqp.delta_max + 1e-9,
        cached,
    })
}

/// Fan one rung's candidates across the worker pool. Results come back
/// in submission order (the determinism contract), with the pool report
/// for diagnostics.
pub fn eval_rung(
    sc: &SearchConfig,
    cands: &[Candidate],
    fid: Fidelity,
    jobs: Jobs,
) -> Result<(Vec<Eval>, PoolReport)> {
    let backend = &sc.backend;
    parallel_map_init(
        jobs,
        cands.to_vec(),
        |_wid| backend.init_worker(),
        |st, cand, _i| backend.evaluate(st, sc, &cand, fid),
    )
}
