//! Candidate generation over the schedule grammar.
//!
//! The generator is a deterministic stream: the same `(space, seed)` pair
//! yields the same candidate sequence, byte for byte. It opens with a
//! fixed prefix of load-bearing schedules — the paper's §V-B ordering
//! ablation (`prune >> ptq` vs `ptq >> prune`), the recalibration fix,
//! and the single-objective strawmen — so even tiny budgets evaluate the
//! claims the search exists to test, then mutates knobs over the enabled
//! axes. Candidates are deduplicated by canonical string, so the budget
//! is never spent evaluating the same schedule twice.

use crate::error::{Error, Result};
use crate::hqp::{HqpConfig, RankingMethod, Schedule, StageSpec};
use crate::quant::CalibMethod;
use crate::testkit::prng::Prng;

use std::collections::HashSet;

/// The search-space axes `--space` can enable (comma list or `all`).
pub const AXIS_NAMES: &[&str] = &[
    "order", "dmax-split", "step", "ranking", "calib", "recalib", "max-sparsity", "samples",
];

/// Which schedule-grammar axes the generator may vary.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SearchSpace {
    /// Stage order: quantize-first (`ptq >> prune`) candidates.
    pub order: bool,
    /// Split the Δ_max budget across two prune stages.
    pub dmax_split: bool,
    /// Per-stage pruning step size.
    pub step: bool,
    /// Saliency ranking method.
    pub ranking: bool,
    /// PTQ calibration method.
    pub calib: bool,
    /// Trailing `ptq(recalib)` stages (the §V-B fix).
    pub recalib: bool,
    /// Per-stage `max-sparsity` safety stops.
    pub max_sparsity: bool,
    /// Per-stage calibration sample counts.
    pub samples: bool,
}

impl SearchSpace {
    /// Every axis enabled (the `--space all` default).
    pub fn all() -> SearchSpace {
        SearchSpace {
            order: true,
            dmax_split: true,
            step: true,
            ranking: true,
            calib: true,
            recalib: true,
            max_sparsity: true,
            samples: true,
        }
    }

    /// Parse `--space`: `all` or a comma list of axis names. Unknown
    /// axes are loud and list the valid set.
    pub fn parse(s: &str) -> Result<SearchSpace> {
        if s.trim() == "all" {
            return Ok(SearchSpace::all());
        }
        let mut sp = SearchSpace::default();
        for tok in s.split(',') {
            match tok.trim() {
                "order" => sp.order = true,
                "dmax-split" => sp.dmax_split = true,
                "step" => sp.step = true,
                "ranking" => sp.ranking = true,
                "calib" => sp.calib = true,
                "recalib" => sp.recalib = true,
                "max-sparsity" => sp.max_sparsity = true,
                "samples" => sp.samples = true,
                other => {
                    return Err(Error::Cli(format!(
                        "unknown search axis `{other}` (valid axes: {}, or `all`)",
                        AXIS_NAMES.join(", ")
                    )))
                }
            }
        }
        Ok(sp)
    }
}

/// One schedule the evaluator prices.
#[derive(Clone, Debug)]
pub struct Candidate {
    pub sched: Schedule,
}

impl Candidate {
    fn new(sched: Schedule) -> Candidate {
        Candidate { sched }
    }
}

/// Knob pools the mutator draws from. Values are exact short decimals so
/// canonical percent round-trips stay verbatim.
const STEPS: [f64; 3] = [0.005, 0.01, 0.02];
const CAPS: [f64; 3] = [0.25, 0.35, 0.5];
const SAMPLE_COUNTS: [usize; 3] = [256, 512, 2048];
const SPLIT_FRACS: [f64; 3] = [0.25, 0.5, 0.75];
const RANKINGS: [RankingMethod; 4] = [
    RankingMethod::Fisher,
    RankingMethod::MagnitudeL1,
    RankingMethod::MagnitudeL2,
    RankingMethod::BnGamma,
];
const CALIBS: [CalibMethod; 3] =
    [CalibMethod::Kl, CalibMethod::MinMax, CalibMethod::Percentile];

fn prune_stage(space: &SearchSpace, rng: &mut Prng, delta_max: Option<f64>) -> StageSpec {
    StageSpec::Prune {
        ranking: if space.ranking && rng.next_f64() < 0.5 {
            Some(RANKINGS[rng.below(RANKINGS.len())])
        } else {
            None
        },
        step_frac: if space.step && rng.next_f64() < 0.5 {
            Some(STEPS[rng.below(STEPS.len())])
        } else {
            None
        },
        delta_max,
        max_sparsity: if space.max_sparsity && rng.next_f64() < 0.5 {
            Some(CAPS[rng.below(CAPS.len())])
        } else {
            None
        },
        samples: if space.samples && rng.next_f64() < 0.5 {
            Some(SAMPLE_COUNTS[rng.below(SAMPLE_COUNTS.len())])
        } else {
            None
        },
    }
}

fn ptq_stage(space: &SearchSpace, rng: &mut Prng, recalib: bool) -> StageSpec {
    StageSpec::Ptq {
        calib: if space.calib && rng.next_f64() < 0.5 {
            Some(CALIBS[rng.below(CALIBS.len())])
        } else {
            None
        },
        recalib,
        samples: if space.samples && rng.next_f64() < 0.5 {
            Some(SAMPLE_COUNTS[rng.below(SAMPLE_COUNTS.len())])
        } else {
            None
        },
    }
}

/// One random schedule over the enabled axes.
fn mutate(space: &SearchSpace, cfg: &HqpConfig, rng: &mut Prng) -> Schedule {
    // shape pool: prune>>ptq, prune-only and ptq-only are always
    // expressible; the rest gate on their axis
    let mut shapes = vec![0usize, 1, 2];
    if space.order {
        shapes.push(3);
    }
    if space.recalib {
        shapes.push(4);
    }
    if space.dmax_split {
        shapes.push(5);
    }
    let stages = match shapes[rng.below(shapes.len())] {
        0 => vec![prune_stage(space, rng, None), ptq_stage(space, rng, false)],
        1 => vec![prune_stage(space, rng, None)],
        2 => vec![ptq_stage(space, rng, false)],
        3 => vec![ptq_stage(space, rng, false), prune_stage(space, rng, None)],
        // quantize-first *with* the §V-B fix: re-collect scales after
        // the prune
        4 => vec![
            ptq_stage(space, rng, false),
            prune_stage(space, rng, None),
            ptq_stage(space, rng, true),
        ],
        // two-stage Δ_max split: a conservative first prune, then the
        // full-budget prune, then ptq
        _ => {
            let f = SPLIT_FRACS[rng.below(SPLIT_FRACS.len())];
            vec![
                prune_stage(space, rng, Some(f * cfg.delta_max)),
                prune_stage(space, rng, None),
                ptq_stage(space, rng, false),
            ]
        }
    };
    Schedule::new(stages)
}

/// The fixed seed-independent prefix: the ablation schedules the search
/// must compare even at tiny budgets.
fn prefix(space: &SearchSpace) -> Vec<Schedule> {
    let mut p = vec![Schedule::parse("prune >> ptq").unwrap()];
    if space.order {
        p.push(Schedule::parse("ptq >> prune").unwrap());
    }
    if space.order && space.recalib {
        p.push(Schedule::parse("ptq >> prune >> ptq(recalib)").unwrap());
    }
    p.push(Schedule::parse("prune").unwrap());
    p.push(Schedule::parse("ptq").unwrap());
    p
}

/// Generate up to `n` distinct candidates. Fewer are returned only when
/// the enabled axes cannot produce `n` distinct schedules within the
/// attempt cap (tiny spaces) — callers treat the returned length as the
/// effective candidate count.
pub fn generate(space: &SearchSpace, cfg: &HqpConfig, seed: u64, n: usize) -> Vec<Candidate> {
    let mut rng = Prng::new(seed);
    let mut seen: HashSet<String> = HashSet::new();
    let mut out = Vec::with_capacity(n);
    for sched in prefix(space) {
        if out.len() >= n {
            return out;
        }
        if seen.insert(sched.canonical()) {
            out.push(Candidate::new(sched));
        }
    }
    let mut attempts = 0usize;
    let cap = n * 64 + 64;
    while out.len() < n && attempts < cap {
        attempts += 1;
        let sched = mutate(space, cfg, &mut rng);
        if seen.insert(sched.canonical()) {
            out.push(Candidate::new(sched));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn space_parses_all_and_lists() {
        assert_eq!(SearchSpace::parse("all").unwrap(), SearchSpace::all());
        let sp = SearchSpace::parse("order,recalib").unwrap();
        assert!(sp.order && sp.recalib);
        assert!(!sp.ranking && !sp.calib);
        let e = SearchSpace::parse("order,quantum").unwrap_err().to_string();
        assert!(e.contains("unknown search axis"), "{e}");
        for axis in AXIS_NAMES {
            assert!(e.contains(axis), "error must list `{axis}`: {e}");
        }
    }

    #[test]
    fn generation_is_deterministic_and_distinct() {
        let cfg = HqpConfig::default();
        let a = generate(&SearchSpace::all(), &cfg, 7, 40);
        let b = generate(&SearchSpace::all(), &cfg, 7, 40);
        assert_eq!(a.len(), 40);
        let ca: Vec<String> = a.iter().map(|c| c.sched.canonical()).collect();
        let cb: Vec<String> = b.iter().map(|c| c.sched.canonical()).collect();
        assert_eq!(ca, cb, "same seed must yield the same stream");
        let mut dedup = ca.clone();
        dedup.sort();
        dedup.dedup();
        assert_eq!(dedup.len(), ca.len(), "candidates must be distinct");
        // every candidate round-trips through the grammar
        for c in &ca {
            assert_eq!(&Schedule::parse(c).unwrap().canonical(), c);
        }
    }

    #[test]
    fn prefix_carries_the_ordering_ablation() {
        let cfg = HqpConfig::default();
        let cands = generate(&SearchSpace::all(), &cfg, 0, 3);
        let c: Vec<String> = cands.iter().map(|c| c.sched.canonical()).collect();
        assert_eq!(c[0], "prune >> ptq");
        assert_eq!(c[1], "ptq >> prune");
        assert_eq!(c[2], "ptq >> prune >> ptq(recalib)");
    }
}
