//! Surrogate accuracy model — the no-artifacts evaluation path.
//!
//! The searcher must price schedules on a bare checkout (CI, benches)
//! where no PJRT workspace exists, and must price *cheaply* on the first
//! successive-halving rung even when one does. This module walks a
//! [`Schedule`]'s stages through a closed-form accuracy model anchored to
//! the same paper constants as the serving reference profiles
//! ([`crate::serve::fleet::reference_stats`]), so the surrogate's named
//! points (`hqp`, `q8`, `p50`, `mixed`) reproduce Tables I/II exactly:
//!
//! * **pruning** follows a gentle-slope-then-cliff drop curve per
//!   ranking, `drop(θ) = gentle·θ + cliff·max(0, θ−knee)²`, with the
//!   Fisher slope solved from the paper's HQP row and the magnitude-L1
//!   cliff solved from its p50 row;
//! * **quantization** adds the model's Q8 drop scaled by calibration
//!   method and sample count;
//! * **calibration staleness** — pruning *after* `ptq` leaves the
//!   activation scales collected on the dense model — adds
//!   `0.06·(θ_end − θ_calib)`, the §V-B failure mode. The cheap fidelity
//!   rung deliberately omits this term (scales look fine until the full
//!   re-measure), which is exactly why survivors must be promoted to
//!   full fidelity before they may reach the front;
//! * a **`ptq(recalib)`** stage re-collects scales at the current θ,
//!   zeroing the staleness term — the §V-B fix, discoverable by search.
//!
//! The deployed engine (latency, size) is priced for real through
//! [`crate::serve::fleet::reference_engine_at`] + the hwsim roofline —
//! only the *accuracy* is modeled.

use crate::error::{Error, Result};
use crate::hqp::{HqpConfig, RankingMethod, Schedule, StageSpec};
use crate::quant::CalibMethod;
use crate::serve::fleet::reference_stats;

/// Per-unit-θ staleness penalty for deploying scales calibrated at a
/// sparser-than-current θ (§V-B).
const STALENESS_PER_THETA: f64 = 0.06;

/// One ranking's prune drop curve: gentle slope, then a quadratic cliff
/// past the knee.
struct PruneCurve {
    gentle: f64,
    knee: f64,
    cliff: f64,
}

impl PruneCurve {
    fn drop(&self, theta: f64) -> f64 {
        let over = (theta - self.knee).max(0.0);
        self.gentle * theta + self.cliff * over * over
    }
}

/// Paper-anchored accuracy constants for one model.
struct ModelPrior {
    /// Q8 (KL, full-split) quantization drop.
    q8_drop: f64,
    /// Fisher gentle slope (solved from the HQP row: prune drop at
    /// θ=0.45 is `hqp_drop − q8_drop`).
    fisher_gentle: f64,
    /// Magnitude-L1 drop at θ=0.50 (the p50 row).
    p50_drop: f64,
    /// Mixed-precision extra drop at the default int4 quantile.
    mixed_extra: f64,
}

fn prior(model: &str) -> Result<ModelPrior> {
    let (_, q8_drop) = reference_stats(model, "q8")?;
    let (hqp_theta, hqp_drop) = reference_stats(model, "hqp")?;
    let (_, p50_drop) = reference_stats(model, "p50")?;
    let (_, mixed_drop) = reference_stats(model, "mixed")?;
    Ok(ModelPrior {
        q8_drop,
        fisher_gentle: (hqp_drop - q8_drop) / hqp_theta,
        p50_drop,
        mixed_extra: mixed_drop - hqp_drop,
    })
}

fn curve(p: &ModelPrior, ranking: RankingMethod) -> PruneCurve {
    let g = p.fisher_gentle;
    match ranking {
        // steep cliff right past the paper's operating point: θ=0.45
        // fits the budget, θ=0.46 blows it
        RankingMethod::Fisher => PruneCurve { gentle: g, knee: 0.45, cliff: 200.0 },
        // L1's cliff solved from the p50 anchor so the p50-only preset
        // reproduces its table row exactly
        RankingMethod::MagnitudeL1 => {
            let gentle = 1.45 * g;
            let knee = 0.40;
            let cliff = (p.p50_drop - 0.5 * gentle) / ((0.5 - knee) * (0.5 - knee));
            PruneCurve { gentle, knee, cliff }
        }
        RankingMethod::MagnitudeL2 => PruneCurve { gentle: 1.2 * g, knee: 0.43, cliff: 4.0 },
        RankingMethod::BnGamma => PruneCurve { gentle: 1.7 * g, knee: 0.38, cliff: 1.5 },
        RankingMethod::Random(_) => PruneCurve { gentle: 4.0 * g, knee: 0.25, cliff: 2.0 },
    }
}

fn calib_mult(m: CalibMethod) -> f64 {
    match m {
        CalibMethod::Kl => 1.0,
        CalibMethod::Percentile => 1.22,
        CalibMethod::MinMax => 1.8,
    }
}

/// Fewer calibration samples → noisier thresholds → larger drop (and a
/// small win past the default 1024).
fn sample_mult(samples: Option<usize>) -> f64 {
    match samples {
        None => 1.0,
        Some(s) => (1024.0 / s as f64).powf(0.2).clamp(0.8, 2.0),
    }
}

/// Fewer saliency samples → noisier ranking → a slightly steeper gentle
/// slope.
fn saliency_mult(samples: Option<usize>) -> f64 {
    match samples {
        None => 1.0,
        Some(s) => (1024.0 / s as f64).powf(0.1).clamp(0.85, 1.6),
    }
}

/// What the surrogate concluded about one schedule.
pub struct SurrogatePoint {
    /// Final filter sparsity θ.
    pub theta: f64,
    /// Total modeled accuracy drop (prune + quant + staleness + mixed).
    pub acc_drop: f64,
    /// Deployed numeric regime is INT8.
    pub int8: bool,
    /// Fraction of trailing layers at INT4 (a `mixed` stage ran).
    pub int4_back_frac: f64,
}

/// Walk a schedule through the surrogate. `full` fidelity charges the
/// calibration-staleness term; cheap fidelity omits it (the documented
/// optimism of rung 0).
pub fn walk(model: &str, sched: &Schedule, cfg: &HqpConfig, full: bool) -> Result<SurrogatePoint> {
    let p = prior(model)?;
    let mut theta = 0.0f64;
    let mut prune_drop = 0.0f64;
    let mut quant_drop = 0.0f64;
    let mut mixed_drop = 0.0f64;
    let mut int8 = false;
    let mut theta_calib = 0.0f64;
    let mut int4_back_frac = 0.0f64;
    for st in &sched.stages {
        match st {
            StageSpec::MeasureBaseline => {}
            StageSpec::Prune { ranking, step_frac, delta_max, max_sparsity, samples } => {
                let c = curve(&p, ranking.unwrap_or(cfg.ranking));
                let noisy = saliency_mult(*samples);
                let step = step_frac.unwrap_or(cfg.delta_step_frac);
                let dmax = delta_max.unwrap_or(cfg.delta_max);
                let cap = max_sparsity.unwrap_or(cfg.max_sparsity);
                // Algorithm 1 on the curve: accept step-sized θ increments
                // while the total FP32 drop stays within the stage budget
                loop {
                    let next = theta + step;
                    if next > cap + 1e-12 {
                        break;
                    }
                    let added = noisy * (c.drop(next) - c.drop(theta));
                    if prune_drop + added > dmax + 1e-9 {
                        break;
                    }
                    theta = next;
                    prune_drop += added;
                }
            }
            StageSpec::PruneTo { ranking, theta: target } => {
                let c = curve(&p, ranking.unwrap_or(RankingMethod::MagnitudeL1));
                if *target > theta {
                    prune_drop += c.drop(*target) - c.drop(theta);
                    theta = *target;
                }
            }
            StageSpec::Ptq { calib, recalib, samples } => {
                if *recalib && !int8 {
                    return Err(Error::hqp(
                        "stage `ptq(recalib)`: nothing to recalibrate — no prior \
                         ptq stage quantized the model (add a plain `ptq` stage \
                         first)",
                    ));
                }
                let m = calib.unwrap_or(cfg.calib_method);
                quant_drop = p.q8_drop * calib_mult(m) * sample_mult(*samples);
                int8 = true;
                // plain ptq projects + calibrates at the current θ;
                // recalib re-collects scales only — either way the scales
                // are now fresh
                theta_calib = theta;
            }
            StageSpec::Mixed { int4_quantile, .. } => {
                let q4 = int4_quantile.unwrap_or(0.25);
                mixed_drop = p.mixed_extra * (q4 / 0.25);
                int4_back_frac = (2.0 * q4).min(1.0);
            }
        }
    }
    let mut acc_drop = prune_drop;
    if int8 {
        acc_drop += quant_drop + mixed_drop;
        if full && theta > theta_calib {
            acc_drop += STALENESS_PER_THETA * (theta - theta_calib);
        }
    }
    Ok(SurrogatePoint { theta, acc_drop, int8, int4_back_frac: if int8 { int4_back_frac } else { 0.0 } })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn go(model: &str, s: &str, full: bool) -> SurrogatePoint {
        let cfg = HqpConfig::default();
        walk(model, &Schedule::parse(s).unwrap(), &cfg, full).unwrap()
    }

    #[test]
    fn named_points_match_the_reference_tables() {
        for model in ["resnet18", "mobilenetv3"] {
            let (_, q8) = reference_stats(model, "q8").unwrap();
            let (ht, hd) = reference_stats(model, "hqp").unwrap();
            let (pt, pd) = reference_stats(model, "p50").unwrap();
            let p = go(model, "ptq", true);
            assert!((p.acc_drop - q8).abs() < 1e-9, "{model} q8");
            assert!(p.int8 && p.theta == 0.0);
            let p = go(model, "prune >> ptq", true);
            assert!((p.theta - ht).abs() < 1e-9, "{model} hqp θ: {}", p.theta);
            assert!((p.acc_drop - hd).abs() < 1e-9, "{model} hqp: {}", p.acc_drop);
            let p = go(model, "prune-to(mag-l1,theta=50%)", true);
            assert!((p.theta - pt).abs() < 1e-9);
            assert!((p.acc_drop - pd).abs() < 1e-6, "{model} p50: {}", p.acc_drop);
            assert!(!p.int8);
        }
    }

    #[test]
    fn quantize_first_fails_at_full_fidelity_only() {
        let cfg = HqpConfig::default();
        let cheap = go("resnet18", "ptq >> prune", false);
        let full = go("resnet18", "ptq >> prune", true);
        let fixed = go("resnet18", "ptq >> prune >> ptq(recalib)", true);
        let pf = go("resnet18", "prune >> ptq", true);
        // cheap rung can't see the staleness — it matches prune-first
        assert!((cheap.acc_drop - pf.acc_drop).abs() < 1e-9);
        // full fidelity charges it, past Δ_max
        assert!(full.acc_drop > cfg.delta_max, "{}", full.acc_drop);
        assert!(full.acc_drop > pf.acc_drop + 0.02);
        // ...and the recalib stage repairs it exactly
        assert!((fixed.acc_drop - pf.acc_drop).abs() < 1e-9);
    }

    #[test]
    fn recalib_without_prior_ptq_is_loud() {
        let cfg = HqpConfig::default();
        let e = walk(
            "resnet18",
            &Schedule::parse("prune >> ptq(recalib)").unwrap(),
            &cfg,
            true,
        )
        .unwrap_err()
        .to_string();
        assert!(e.contains("nothing to recalibrate"), "{e}");
    }

    #[test]
    fn knobs_move_the_point_monotonically() {
        let base = go("resnet18", "prune >> ptq", true);
        // a binding max-sparsity cap trades speed for accuracy
        let capped = go("resnet18", "prune(max-sparsity=25%) >> ptq", true);
        assert!(capped.theta < base.theta);
        assert!(capped.acc_drop < base.acc_drop);
        // worse calibration → more drop
        let minmax = go("resnet18", "prune >> ptq(minmax)", true);
        assert!(minmax.acc_drop > base.acc_drop);
        // fewer calib samples → more drop
        let few = go("resnet18", "prune >> ptq(samples=256)", true);
        assert!(few.acc_drop > base.acc_drop);
    }
}
