//! `hqp search` — a budgeted schedule-search engine over the compression
//! grammar (DESIGN.md §Search).
//!
//! The paper's claim is that *coordinated* prune-then-quantize under a
//! strict Δ_max beats single-objective compression; PR 5 made that
//! coordination axis a first-class value (schedule strings). This
//! subsystem closes the loop: it *searches* the grammar for the schedule
//! with the best deployed speedup at equal Δ_max — HALP's latency-driven
//! objective applied to Ps-and-Qs-style interleaved quantization-aware
//! pruning.
//!
//! Three parts, each its own module:
//!
//! * [`generator`] — a deterministic candidate stream over the enabled
//!   `--space` axes, seeded via [`crate::testkit::prng`]; opens with the
//!   §V-B ablation schedules so tiny budgets still test the paper's
//!   ordering claim.
//! * [`eval`] — the two-rung fidelity ladder (cheap roofline+surrogate /
//!   cached rows, then full Δ_max validation), fanned out across
//!   `--jobs` workers with submission-order merge.
//! * [`pareto`] — the front over (deployed speedup, model size, measured
//!   Δ), Δ_max violators hard-excluded.
//!
//! **Budget contract:** `--budget N` is a hard cap on schedule
//! evaluations. Successive halving spends `N − max(1, N/η)` evaluations
//! on the cheap rung, promotes the top `max(1, N/η)` survivors (ranked
//! by compliance, then speedup, then shortest-then-lexicographic
//! canonical string), and spends the
//! rest on full fidelity: exactly `n_cheap + n_full ≤ N` evaluations,
//! never more. η = 4.
//!
//! **Determinism contract:** same `(seed, budget, space)` ⇒ the same
//! candidates, the same promotions, and a byte-identical ranked front at
//! any `--jobs` (property-tested in `tests/prop_search.rs`).

pub mod eval;
pub mod generator;
pub mod pareto;
pub mod surrogate;

pub use eval::{Backend, Eval, Fidelity};
pub use generator::{generate, Candidate, SearchSpace, AXIS_NAMES};

use crate::error::{Error, Result};
use crate::exec::{Jobs, PoolReport};
use crate::formats::json::Json;
use crate::hqp::HqpConfig;
use crate::hwsim::Device;
use crate::report::Table;

/// Successive-halving promotion ratio.
pub const ETA: usize = 4;

/// Everything one search needs.
#[derive(Clone, Debug)]
pub struct SearchConfig {
    pub model: String,
    /// Device the deployed-speedup objective is priced on.
    pub device: Device,
    /// Baseline pipeline config candidates inherit omitted knobs from
    /// (its `delta_max` is the front's compliance gate).
    pub hqp: HqpConfig,
    /// Hard cap on schedule evaluations across both rungs.
    pub budget: usize,
    pub seed: u64,
    pub space: SearchSpace,
    pub jobs: Jobs,
    pub backend: Backend,
}

/// The ranked search result.
#[derive(Debug)]
pub struct SearchOutcome {
    /// Ranked Pareto front (compliant, full-fidelity points only).
    pub front: Vec<Eval>,
    /// Every full-fidelity evaluation, ranked (violators included — the
    /// table shows *why* e.g. quantize-first lost).
    pub full: Vec<Eval>,
    /// Evaluations spent on the cheap rung.
    pub cheap_evals: usize,
    /// Evaluations spent on the full rung.
    pub full_evals: usize,
    /// The configured budget (`cheap_evals + full_evals ≤ budget`).
    pub budget: usize,
    /// Worker-pool reports (one per rung that ran), for stderr.
    pub pools: Vec<PoolReport>,
}

impl SearchOutcome {
    /// Total evaluations spent.
    pub fn evals(&self) -> usize {
        self.cheap_evals + self.full_evals
    }
}

/// Rank order for cheap-rung promotion: compliant first, then speedup,
/// then shortest canonical string, then lexicographic (full determinism
/// under ties). Shorter-first matters: when a knob-decorated mutation
/// ties a bare ablation schedule on the cheap rung, the bare schedule —
/// the one the §V-B comparison needs at full fidelity — is promoted
/// first.
fn promotion_order(a: &Eval, b: &Eval) -> std::cmp::Ordering {
    b.compliant
        .cmp(&a.compliant)
        .then(b.speedup.total_cmp(&a.speedup))
        .then(a.schedule.len().cmp(&b.schedule.len()))
        .then(a.schedule.cmp(&b.schedule))
}

/// Run the search: generate, halve, validate, rank.
pub fn run_search(sc: &SearchConfig) -> Result<SearchOutcome> {
    if sc.budget == 0 {
        return Err(Error::Cli(
            "--budget must be >= 1 (it caps schedule evaluations; \
             try --budget 8 for a smoke run)"
                .into(),
        ));
    }
    let n_full = (sc.budget / ETA).max(1);
    let n_cheap = sc.budget - n_full;
    let cands = generate(&sc.space, &sc.hqp, sc.seed, n_cheap.max(n_full));
    let mut pools = Vec::new();

    // ---- rung 0: cheap fidelity over the wide pool ----------------------
    let (survivors, cheap_evals) = if n_cheap > 0 {
        let pool_cands: Vec<Candidate> = cands.iter().take(n_cheap).cloned().collect();
        let (evals, pool) = eval::eval_rung(sc, &pool_cands, Fidelity::Cheap, sc.jobs)?;
        pools.push(pool);
        let mut order: Vec<usize> = (0..evals.len()).collect();
        order.sort_by(|&i, &j| promotion_order(&evals[i], &evals[j]));
        let survivors: Vec<Candidate> = order
            .into_iter()
            .take(n_full)
            .map(|i| pool_cands[i].clone())
            .collect();
        (survivors, pool_cands.len())
    } else {
        // budget too small for a cheap rung: full-evaluate the head of
        // the candidate stream directly
        (cands.iter().take(n_full).cloned().collect(), 0)
    };

    // ---- rung 1: full fidelity over the survivors -----------------------
    let (mut full, pool) = eval::eval_rung(sc, &survivors, Fidelity::Full, sc.jobs)?;
    pools.push(pool);
    let full_evals = full.len();
    let front = pareto::front(&full);
    pareto::rank(&mut full);
    Ok(SearchOutcome { front, full, cheap_evals, full_evals, budget: sc.budget, pools })
}

fn table_of(evals: &[Eval], delta_max: f64) -> Table {
    let mut t = Table::new(vec![
        "#", "schedule", "speedup", "size red", "acc drop", "theta", "fid", "status",
    ]);
    for (i, e) in evals.iter().enumerate() {
        t.row(vec![
            format!("{}", i + 1),
            e.schedule.clone(),
            format!("{:.2}x", e.speedup),
            format!("{:.1}%", e.size_reduction * 100.0),
            format!("{:.2}%", e.acc_drop * 100.0),
            format!("{:.0}%", e.sparsity * 100.0),
            e.fidelity.name().to_string(),
            if e.compliant {
                if e.cached { "ok (cached)".to_string() } else { "ok".to_string() }
            } else {
                format!("VIOLATES Δmax={:.2}%", delta_max * 100.0)
            },
        ]);
    }
    t
}

/// Human-readable report: the ranked front, then every full evaluation
/// (so excluded violators stay visible).
pub fn render(sc: &SearchConfig, out: &SearchOutcome) -> String {
    let mut s = String::new();
    s.push_str(&format!(
        "search: {} on {} — budget {} ({} cheap + {} full evals), seed {}, backend {}\n",
        sc.model,
        sc.device.name,
        out.budget,
        out.cheap_evals,
        out.full_evals,
        sc.seed,
        sc.backend.name(),
    ));
    s.push_str(&format!(
        "Pareto front (Δ_max = {:.2}%, {} of {} full candidates):\n",
        sc.hqp.delta_max * 100.0,
        out.front.len(),
        out.full.len()
    ));
    s.push_str(&table_of(&out.front, sc.hqp.delta_max).render());
    if out.full.len() > out.front.len() {
        s.push_str("all full-fidelity candidates:\n");
        s.push_str(&table_of(&out.full, sc.hqp.delta_max).render());
    }
    s
}

fn eval_json(e: &Eval) -> Json {
    Json::obj()
        .set("schedule", e.schedule.clone())
        .set("fidelity", e.fidelity.name())
        .set("latency_ms", e.latency_ms)
        .set("speedup", e.speedup)
        .set("size_reduction", e.size_reduction)
        .set("acc_drop", e.acc_drop)
        .set("sparsity", e.sparsity)
        .set("compliant", e.compliant)
        .set("cached", e.cached)
}

/// Machine-readable outcome (the `--out` JSON and BENCH_search payload).
pub fn outcome_json(sc: &SearchConfig, out: &SearchOutcome) -> Json {
    Json::obj()
        .set("model", sc.model.clone())
        .set("device", sc.device.name.clone())
        .set("backend", sc.backend.name())
        .set("budget", out.budget)
        .set("seed", sc.seed as i64)
        .set("delta_max", sc.hqp.delta_max)
        .set("cheap_evals", out.cheap_evals)
        .set("full_evals", out.full_evals)
        .set("front", Json::Arr(out.front.iter().map(eval_json).collect()))
        .set("full", Json::Arr(out.full.iter().map(eval_json).collect()))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn config(budget: usize, seed: u64) -> SearchConfig {
        SearchConfig {
            model: "resnet18".into(),
            device: Device::xavier_nx(),
            hqp: HqpConfig::default(),
            budget,
            seed,
            space: SearchSpace::all(),
            jobs: Jobs::one(),
            backend: Backend::Reference,
        }
    }

    #[test]
    fn budget_zero_is_loud() {
        let e = run_search(&config(0, 42)).unwrap_err().to_string();
        assert!(e.contains("--budget"), "{e}");
    }

    #[test]
    fn budget_one_spends_exactly_one_full_eval() {
        let out = run_search(&config(1, 42)).unwrap();
        assert_eq!(out.cheap_evals, 0);
        assert_eq!(out.full_evals, 1);
        // the single eval is the canonical prune-first schedule
        assert_eq!(out.full[0].schedule, "prune >> ptq");
        assert_eq!(out.front.len(), 1);
    }

    #[test]
    fn front_rediscovers_the_ordering_claim() {
        // §V-B at budget 8: prune-first survives full fidelity,
        // quantize-first is promoted on the (optimistic) cheap rung and
        // then hard-excluded when full fidelity measures the stale scales
        let out = run_search(&config(8, 42)).unwrap();
        assert!(out.evals() <= 8);
        let full_of = |s: &str| out.full.iter().find(|e| e.schedule == s);
        let pf = full_of("prune >> ptq").expect("prune-first must be promoted");
        let qf = full_of("ptq >> prune").expect("quantize-first must be promoted");
        assert!(pf.compliant && !qf.compliant);
        assert!(pf.acc_drop < qf.acc_drop);
        assert!(out.front.iter().any(|e| e.schedule == "prune >> ptq"));
        assert!(!out.front.iter().any(|e| e.schedule == "ptq >> prune"));
    }
}
