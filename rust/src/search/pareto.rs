//! Pareto front over (deployed speedup ↑, size reduction ↑, accuracy
//! drop ↓), Δ_max violators hard-excluded.

use super::eval::Eval;

/// `a` dominates `b`: no worse on every objective, strictly better on
/// at least one.
pub fn dominates(a: &Eval, b: &Eval) -> bool {
    let no_worse = a.speedup >= b.speedup
        && a.size_reduction >= b.size_reduction
        && a.acc_drop <= b.acc_drop;
    let better = a.speedup > b.speedup
        || a.size_reduction > b.size_reduction
        || a.acc_drop < b.acc_drop;
    no_worse && better
}

/// Deterministic ranking: primary objective (deployed speedup) first,
/// then accuracy headroom, then the canonical string so ties never
/// depend on evaluation order.
pub fn rank(evals: &mut [Eval]) {
    evals.sort_by(|a, b| {
        b.speedup
            .total_cmp(&a.speedup)
            .then(a.acc_drop.total_cmp(&b.acc_drop))
            .then(a.schedule.cmp(&b.schedule))
    });
}

/// The ranked Pareto front of the compliant evaluations. Distinct
/// schedules with identical objectives are mutually non-dominating and
/// both stay (e.g. `prune >> ptq` and its recalibrated quantize-first
/// equivalent).
pub fn front(evals: &[Eval]) -> Vec<Eval> {
    let compliant: Vec<&Eval> = evals.iter().filter(|e| e.compliant).collect();
    let mut out: Vec<Eval> = compliant
        .iter()
        .filter(|&&e| !compliant.iter().any(|&o| dominates(o, e)))
        .map(|&e| e.clone())
        .collect();
    rank(&mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::search::Fidelity;

    fn eval(schedule: &str, speedup: f64, size: f64, drop: f64, compliant: bool) -> Eval {
        Eval {
            schedule: schedule.to_string(),
            fidelity: Fidelity::Full,
            latency_ms: 1.0,
            speedup,
            size_reduction: size,
            acc_drop: drop,
            sparsity: 0.0,
            compliant,
            cached: false,
        }
    }

    #[test]
    fn violators_never_surface() {
        let evals = vec![
            eval("a", 9.0, 0.9, 0.05, false), // dominant but non-compliant
            eval("b", 2.0, 0.5, 0.010, true),
        ];
        let f = front(&evals);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].schedule, "b");
    }

    #[test]
    fn dominated_points_are_dropped_and_ranking_is_stable() {
        let evals = vec![
            eval("slow-small", 1.5, 0.80, 0.004, true),
            eval("fast-big", 3.0, 0.60, 0.012, true),
            eval("strictly-worse", 1.4, 0.60, 0.013, true),
            eval("tie", 3.0, 0.60, 0.012, true),
        ];
        let f = front(&evals);
        let names: Vec<&str> = f.iter().map(|e| e.schedule.as_str()).collect();
        // ties are mutually non-dominating and order by canonical string
        assert_eq!(names, vec!["fast-big", "tie", "slow-small"]);
        for (i, a) in f.iter().enumerate() {
            for (j, b) in f.iter().enumerate() {
                assert!(i == j || !dominates(a, b), "front has a dominated point");
            }
        }
    }
}
