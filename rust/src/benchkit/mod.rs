//! Benchmark harness (criterion substitute — unavailable offline).
//!
//! `cargo bench` targets are plain binaries (`harness = false`) built on
//! this module: warmup + timed iterations, robust stats, aligned text
//! output, and a machine-readable [`Report`] that serializes stats plus
//! named scalar metrics (counter deltas, ratios) to `BENCH_*.json` so the
//! perf trajectory is tracked across PRs. Used both by the micro benches
//! (§Perf L3) and as the driver for the table/figure regeneration benches.

use std::path::Path;
use std::time::Instant;

use crate::formats::json::Json;

/// Timing statistics over a batch of iterations.
#[derive(Clone, Debug)]
pub struct Stats {
    pub name: String,
    pub iters: usize,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub min_ms: f64,
    pub max_ms: f64,
    pub std_ms: f64,
}

impl Stats {
    pub fn from_samples(name: &str, mut ms: Vec<f64>) -> Stats {
        assert!(!ms.is_empty());
        ms.sort_by(f64::total_cmp);
        let n = ms.len();
        let mean = ms.iter().sum::<f64>() / n as f64;
        let var = ms.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        let pct = |p: f64| ms[((n - 1) as f64 * p).round() as usize];
        Stats {
            name: name.to_string(),
            iters: n,
            mean_ms: mean,
            p50_ms: pct(0.50),
            p95_ms: pct(0.95),
            min_ms: ms[0],
            max_ms: ms[n - 1],
            std_ms: var.sqrt(),
        }
    }

    /// One aligned report line.
    pub fn line(&self) -> String {
        format!(
            "{:<44} {:>6} it  mean {:>9.3} ms  p50 {:>9.3}  p95 {:>9.3}  min {:>9.3}  sd {:>8.3}",
            self.name, self.iters, self.mean_ms, self.p50_ms, self.p95_ms, self.min_ms, self.std_ms
        )
    }
}

/// Time `f` for `iters` iterations after `warmup` unmeasured runs.
pub fn bench<T>(name: &str, warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> Stats {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    Stats::from_samples(name, samples)
}

/// Time one invocation (long-running pipeline stages).
pub fn time_once<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let v = f();
    (v, t0.elapsed().as_secs_f64() * 1e3)
}

/// Section header for bench output.
pub fn section(title: &str) {
    println!("\n=== {title} {}", "=".repeat(68usize.saturating_sub(title.len())));
}

/// Machine-readable benchmark report: collected [`Stats`] + named scalar
/// metrics, serialized as `BENCH_<name>.json` for cross-PR tracking.
#[derive(Clone, Debug, Default)]
pub struct Report {
    stats: Vec<Stats>,
    metrics: Vec<(String, f64)>,
}

impl Report {
    pub fn new() -> Report {
        Report::default()
    }

    /// Record (and print) one timing result.
    pub fn push(&mut self, s: Stats) {
        println!("{}", s.line());
        self.stats.push(s);
    }

    /// Record a named scalar (counter delta, ratio, byte count, …).
    pub fn metric(&mut self, key: &str, value: f64) {
        println!("{key:<44} {value:>14.3}");
        self.metrics.push((key.to_string(), value));
    }

    fn to_json(&self) -> Json {
        let stats = Json::Arr(
            self.stats
                .iter()
                .map(|s| {
                    Json::obj()
                        .set("name", s.name.clone())
                        .set("iters", s.iters as f64)
                        .set("mean_ms", s.mean_ms)
                        .set("p50_ms", s.p50_ms)
                        .set("p95_ms", s.p95_ms)
                        .set("min_ms", s.min_ms)
                        .set("max_ms", s.max_ms)
                        .set("std_ms", s.std_ms)
                })
                .collect(),
        );
        let metrics = self
            .metrics
            .iter()
            .fold(Json::obj(), |o, (k, v)| o.set(k.clone(), *v));
        Json::obj().set("stats", stats).set("metrics", metrics)
    }

    /// Serialize to `path` (pretty JSON).
    pub fn write_json(&self, path: impl AsRef<Path>) -> crate::error::Result<()> {
        std::fs::write(path.as_ref(), self.to_json().to_string_pretty())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_order() {
        let s = Stats::from_samples("t", vec![3.0, 1.0, 2.0, 10.0]);
        assert_eq!(s.min_ms, 1.0);
        assert_eq!(s.max_ms, 10.0);
        // nearest-rank percentile on even counts takes the upper median
        assert_eq!(s.p50_ms, 3.0);
        assert!((s.mean_ms - 4.0).abs() < 1e-12);
        assert_eq!(s.iters, 4);

        let odd = Stats::from_samples("t", vec![3.0, 1.0, 2.0]);
        assert_eq!(odd.p50_ms, 2.0);
    }

    #[test]
    fn bench_runs_requested_iterations() {
        let mut count = 0;
        let s = bench("inc", 2, 5, || {
            count += 1;
            count
        });
        assert_eq!(count, 7); // 2 warmup + 5 timed
        assert_eq!(s.iters, 5);
        assert!(s.mean_ms >= 0.0);
    }

    #[test]
    fn time_once_returns_value() {
        let (v, ms) = time_once(|| 42);
        assert_eq!(v, 42);
        assert!(ms >= 0.0);
    }

    #[test]
    fn report_serializes_stats_and_metrics() {
        let mut r = Report::new();
        r.push(Stats::from_samples("fast", vec![1.0, 2.0, 3.0]));
        r.metric("upload_bytes_cold", 708608.0);
        r.metric("upload_ratio", 11.4);
        let path = std::env::temp_dir().join("BENCH_benchkit_test.json");
        r.write_json(&path).unwrap();
        let v = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let stats = v.req("stats").unwrap().as_arr().unwrap();
        assert_eq!(stats.len(), 1);
        assert_eq!(stats[0].req("name").unwrap().as_str().unwrap(), "fast");
        let m = v.req("metrics").unwrap();
        assert_eq!(m.req("upload_ratio").unwrap().as_f64().unwrap(), 11.4);
        assert_eq!(
            m.req("upload_bytes_cold").unwrap().as_f64().unwrap(),
            708608.0
        );
    }
}
