//! INT8 post-training quantization machinery (HQP Phase 2).
//!
//! From-scratch implementation of the calibration stack the paper delegates
//! to TensorRT (§IV-B "Robust Post-Training Quantization"): symmetric
//! signed INT8 with per-tensor activation scales chosen by minimizing the
//! KL divergence between the FP32 activation histogram and its quantized
//! re-binning (NVIDIA's 8-bit inference recipe), plus min-max and
//! percentile calibrators as baselines, and per-output-channel symmetric
//! weight quantization.
//!
//! The *numerics* of the quantized model are exercised for real: weights
//! are projected onto their INT8 grid here, activation scales feed the
//! `quant_eval` artifact whose Pallas qmatmul kernel quantizes activations
//! on the fly — so the accuracy drops reported in the tables are measured,
//! not modeled.

mod calibrate;
mod qtensor;

pub use calibrate::{choose_scale, kl_divergence, CalibMethod, Calibrator};
pub use qtensor::{dequantize, quantize_per_channel, quantize_per_tensor, QuantizedTensor};

/// Symmetric signed INT8 grid: [-127, 127] (−128 unused, TensorRT-style).
pub const QMAX: f32 = 127.0;

/// Scale for a symmetric range `[-absmax, absmax]` at bit-width `b`.
///
/// The paper's §II-C step size: `s = R / (2^b − 1)` with `R = 2·absmax`
/// for the symmetric signed case, which reduces to `absmax / (2^(b−1)−1)`.
pub fn scale_for(absmax: f32, bits: u32) -> f32 {
    let qmax = ((1u32 << (bits - 1)) - 1) as f32;
    if absmax <= 0.0 {
        1.0
    } else {
        absmax / qmax
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scale_for_int8() {
        assert!((scale_for(127.0, 8) - 1.0).abs() < 1e-6);
        assert!((scale_for(1.0, 8) - 1.0 / 127.0).abs() < 1e-9);
        // degenerate all-zero tensor
        assert_eq!(scale_for(0.0, 8), 1.0);
    }

    #[test]
    fn scale_for_other_widths() {
        assert!((scale_for(7.0, 4) - 1.0).abs() < 1e-6); // int4: qmax = 7
        assert!((scale_for(32767.0, 16) - 1.0).abs() < 1e-3);
    }
}
