//! Weight quantization: projection of FP32 tensors onto the symmetric INT8
//! grid, per-tensor or per-output-channel (the TensorRT default for conv
//! weights and what HQP deploys).

use crate::error::Result;
use crate::tensor::Tensor;

use super::{scale_for, QMAX};

/// An INT8-quantized tensor: integer codes + scales.
#[derive(Clone, Debug)]
pub struct QuantizedTensor {
    pub shape: Vec<usize>,
    pub codes: Vec<i8>,
    /// One scale (per-tensor) or `shape[axis]` scales (per-channel).
    pub scales: Vec<f32>,
    /// Channel axis for per-channel quantization (None = per-tensor).
    pub axis: Option<usize>,
}

impl QuantizedTensor {
    /// Storage bytes of the deployed quantized tensor (codes + f32 scales).
    pub fn storage_bytes(&self) -> usize {
        self.codes.len() + 4 * self.scales.len()
    }
}

fn quantize_value(v: f32, scale: f32) -> i8 {
    // Round half to even, matching jnp.round in the L1 kernel / ref.py so
    // rust-side weight projection and the pallas fake-quant agree exactly.
    let q = v / scale;
    let r = round_half_even(q).clamp(-QMAX, QMAX);
    r as i8
}

fn round_half_even(x: f32) -> f32 {
    let f = x.floor();
    let d = x - f;
    if d > 0.5 {
        f + 1.0
    } else if d < 0.5 {
        f
    } else if (f as i64) % 2 == 0 {
        f
    } else {
        f + 1.0
    }
}

/// Per-tensor symmetric INT8 quantization.
pub fn quantize_per_tensor(t: &Tensor, bits: u32) -> QuantizedTensor {
    let s = scale_for(t.absmax(), bits);
    QuantizedTensor {
        shape: t.shape().to_vec(),
        codes: t.data().iter().map(|&v| quantize_value(v, s)).collect(),
        scales: vec![s],
        axis: None,
    }
}

/// Per-channel symmetric INT8 quantization along `axis` (conv out-channel
/// axis 3 for HWIO weights, axis 1 for FC (in,out) weights).
pub fn quantize_per_channel(t: &Tensor, axis: usize, bits: u32) -> Result<QuantizedTensor> {
    let maxes = t.absmax_along(axis)?;
    let scales: Vec<f32> = maxes.iter().map(|&m| scale_for(m, bits)).collect();
    let strides = t.strides();
    let axis_stride = strides[axis];
    let axis_len = t.shape()[axis];
    let mut codes = vec![0i8; t.len()];
    for (i, &v) in t.data().iter().enumerate() {
        let ch = (i / axis_stride) % axis_len;
        codes[i] = quantize_value(v, scales[ch]);
    }
    Ok(QuantizedTensor {
        shape: t.shape().to_vec(),
        codes,
        scales,
        axis: Some(axis),
    })
}

/// Dequantize back to an f32 tensor **on the INT8 grid** — this is the
/// weight tensor handed to the `quant_eval` artifact (its values are exact
/// integer multiples of the scales, so the artifact's f32 GEMM is
/// bit-identical to an int8 GEMM with int32 accumulation — see
/// python/compile/kernels/ref.py).
pub fn dequantize(q: &QuantizedTensor) -> Result<Tensor> {
    let mut data = vec![0f32; q.codes.len()];
    match q.axis {
        None => {
            let s = q.scales[0];
            for (d, &c) in data.iter_mut().zip(&q.codes) {
                *d = c as f32 * s;
            }
        }
        Some(axis) => {
            let t = Tensor::zeros(q.shape.clone());
            let strides = t.strides();
            let axis_stride = strides[axis];
            let axis_len = q.shape[axis];
            for (i, &c) in q.codes.iter().enumerate() {
                let ch = (i / axis_stride) % axis_len;
                data[i] = c as f32 * q.scales[ch];
            }
        }
    }
    Tensor::new(q.shape.clone(), data)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn per_tensor_roundtrip_error_bounded() {
        let t = Tensor::new(vec![4], vec![0.5, -1.0, 0.25, 0.99]).unwrap();
        let q = quantize_per_tensor(&t, 8);
        assert_eq!(q.scales.len(), 1);
        let d = dequantize(&q).unwrap();
        let s = q.scales[0];
        for (a, b) in t.data().iter().zip(d.data()) {
            assert!((a - b).abs() <= 0.5 * s + 1e-7, "{a} vs {b}");
        }
    }

    #[test]
    fn per_channel_scales_isolate_outliers() {
        // Channel 1 has a 100x outlier; per-channel keeps channel 0 precise.
        let t = Tensor::new(vec![2, 2], vec![0.5, 100.0, -0.25, 50.0]).unwrap();
        let q = quantize_per_channel(&t, 1, 8).unwrap();
        assert_eq!(q.scales.len(), 2);
        let d = dequantize(&q).unwrap();
        assert!((d.data()[0] - 0.5).abs() < 0.01);
        assert!((d.data()[2] + 0.25).abs() < 0.01);
    }

    #[test]
    fn codes_clamped_to_pm127() {
        let t = Tensor::new(vec![2], vec![1.0, -1.0]).unwrap();
        let q = quantize_per_tensor(&t, 8);
        assert_eq!(q.codes, vec![127, -127]);
    }

    #[test]
    fn round_half_even_matches_numpy() {
        assert_eq!(round_half_even(0.5), 0.0);
        assert_eq!(round_half_even(1.5), 2.0);
        assert_eq!(round_half_even(2.5), 2.0);
        assert_eq!(round_half_even(-0.5), 0.0);
        assert_eq!(round_half_even(-1.5), -2.0);
        assert_eq!(round_half_even(1.4), 1.0);
        assert_eq!(round_half_even(1.6), 2.0);
    }

    #[test]
    fn all_zero_tensor_is_stable() {
        let t = Tensor::zeros(vec![3, 3]);
        let q = quantize_per_tensor(&t, 8);
        let d = dequantize(&q).unwrap();
        assert_eq!(d.data(), t.data());
    }

    #[test]
    fn storage_accounting() {
        let t = Tensor::zeros(vec![3, 4]);
        let q = quantize_per_channel(&t, 1, 8).unwrap();
        assert_eq!(q.storage_bytes(), 12 + 4 * 4);
    }
}
