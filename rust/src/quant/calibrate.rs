//! Activation-scale calibration: min-max, percentile and KL-divergence
//! (the NVIDIA/TensorRT INT8 recipe the paper relies on in §IV-B).
//!
//! Input: a 2048-bin histogram of |activation| over the calibration set
//! (produced on-device by the `hist` artifact — L2 computes the histograms,
//! Rust only searches over thresholds). Output: the per-tensor scale
//! `s = T / 127` for the chosen saturation threshold `T`.

use super::scale_for;

/// Calibration strategy for activation scales.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CalibMethod {
    /// T = max|activation| (no saturation; hurt by outliers — this is the
    /// failure mode the paper's pruning-quantization-conflict story is
    /// about).
    MinMax,
    /// T = smallest threshold covering `percent/100` of the mass.
    Percentile,
    /// NVIDIA KL-divergence sweep: pick T minimizing
    /// KL(P_clipped_ref || Q_quantized).
    Kl,
}

impl CalibMethod {
    /// Canonical name ([`CalibMethod::parse`]'s inverse — also the
    /// schedule-grammar token, e.g. `ptq(kl)`).
    pub fn name(&self) -> &'static str {
        match self {
            CalibMethod::MinMax => "minmax",
            CalibMethod::Percentile => "percentile",
            CalibMethod::Kl => "kl",
        }
    }

    pub fn parse(s: &str) -> Option<CalibMethod> {
        match s {
            "minmax" => Some(CalibMethod::MinMax),
            "percentile" => Some(CalibMethod::Percentile),
            "kl" => Some(CalibMethod::Kl),
            _ => None,
        }
    }
}

/// Scale chooser over an |activation| histogram.
///
/// `hist[i]` counts activations in `[i·range/bins, (i+1)·range/bins)`;
/// `range` is the global absmax observed in calibration pass 1.
pub struct Calibrator {
    pub method: CalibMethod,
    /// For [`CalibMethod::Percentile`]: the covered mass (e.g. 99.9).
    pub percentile: f64,
    /// Quantization levels (128 for signed INT8 magnitudes).
    pub levels: usize,
}

impl Default for Calibrator {
    fn default() -> Self {
        Calibrator { method: CalibMethod::Kl, percentile: 99.9, levels: 128 }
    }
}

impl Calibrator {
    pub fn new(method: CalibMethod) -> Self {
        Calibrator { method, ..Default::default() }
    }

    /// Choose the activation scale for one tap.
    pub fn scale(&self, hist: &[f32], range: f32) -> f32 {
        let t = self.threshold(hist, range);
        scale_for(t, 8)
    }

    /// Choose the saturation threshold T for one tap.
    pub fn threshold(&self, hist: &[f32], range: f32) -> f32 {
        if range <= 0.0 || hist.iter().all(|&h| h == 0.0) {
            return 1.0;
        }
        let bins = hist.len();
        let bin_width = range / bins as f32;
        match self.method {
            CalibMethod::MinMax => range,
            CalibMethod::Percentile => {
                let total: f64 = hist.iter().map(|&h| h as f64).sum();
                let target = total * self.percentile / 100.0;
                let mut acc = 0.0f64;
                for (i, &h) in hist.iter().enumerate() {
                    acc += h as f64;
                    if acc >= target {
                        return (i + 1) as f32 * bin_width;
                    }
                }
                range
            }
            CalibMethod::Kl => {
                let best = kl_sweep(hist, self.levels);
                (best + 1) as f32 * bin_width
            }
        }
    }
}

/// Convenience wrapper: one-shot scale choice.
pub fn choose_scale(method: CalibMethod, hist: &[f32], range: f32) -> f32 {
    Calibrator::new(method).scale(hist, range)
}

/// KL(P||Q) over two unnormalized distributions (normalized internally).
/// Zero-probability Q bins where P is nonzero contribute a large penalty
/// (smoothed, per the TensorRT reference implementation).
pub fn kl_divergence(p: &[f64], q: &[f64]) -> f64 {
    let ps: f64 = p.iter().sum();
    let qs: f64 = q.iter().sum();
    if ps <= 0.0 || qs <= 0.0 {
        return f64::INFINITY;
    }
    let eps = 1e-12;
    let mut kl = 0.0;
    for (&pi, &qi) in p.iter().zip(q) {
        let pn = pi / ps;
        if pn > 0.0 {
            let qn = (qi / qs).max(eps);
            kl += pn * (pn / qn).ln();
        }
    }
    kl
}

/// The NVIDIA calibration sweep: for every candidate threshold bin `t`
/// (from `levels` upward), build the clipped reference P (mass above `t`
/// folded into the last bin) and the quantized-then-expanded Q (the `t`
/// bins re-binned into `levels` buckets and expanded back proportionally),
/// and return the `t-1` (bin index) minimizing KL(P||Q).
fn kl_sweep(hist: &[f32], levels: usize) -> usize {
    let bins = hist.len();
    if bins <= levels {
        return bins - 1;
    }
    let mut h: Vec<f64> = hist.iter().map(|&x| x as f64).collect();
    // Neutralize the zero bin: exact zeros (the post-ReLU spike) quantize
    // losslessly at ANY scale, so they carry no information about the
    // threshold — but left in, their spike dominates the normalized
    // distributions and biases the sweep toward tiny thresholds (the
    // TensorRT reference implementation equally suppresses bin 0).
    h[0] = h[1];
    let mut best_t = bins;
    let mut best_kl = f64::INFINITY;

    for t in (levels..=bins).step_by(8) {
        // Reference P: first t bins with the outlier tail folded into bin
        // t-1 (saturation puts those values at the clip point).
        let mut p: Vec<f64> = h[..t].to_vec();
        let tail: f64 = h[t..].iter().sum();
        p[t - 1] += tail;

        // Candidate Q: quantize the RAW first t bins (without the folded
        // tail!) into `levels` buckets and expand back. Building Q from the
        // folded P would make t == levels lossless (KL = 0) and the sweep
        // would degenerate to always picking the smallest threshold — the
        // saturation error IS the P-vs-Q difference being scored.
        let mut q = vec![0.0f64; t];
        let chunk = t as f64 / levels as f64;
        for l in 0..levels {
            let lo = (l as f64 * chunk).floor() as usize;
            let hi = (((l + 1) as f64 * chunk).floor() as usize).min(t).max(lo + 1);
            let mass: f64 = h[lo..hi].iter().map(|&x| x as f64).sum();
            // Expand back uniformly over the *nonzero* source bins.
            let nz = h[lo..hi].iter().filter(|&&v| v > 0.0).count();
            if nz > 0 {
                let share = mass / nz as f64;
                for (j, src) in h[lo..hi].iter().enumerate() {
                    if *src > 0.0 {
                        q[lo + j] = share;
                    }
                }
            }
        }

        let kl = kl_divergence(&p, &q);
        if kl < best_kl {
            best_kl = kl;
            best_t = t;
        }
    }
    best_t - 1
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gaussian_hist(bins: usize, sigma_bins: f64) -> Vec<f32> {
        (0..bins)
            .map(|i| {
                let x = i as f64 / sigma_bins;
                ((-0.5 * x * x).exp() * 1000.0) as f32
            })
            .collect()
    }

    #[test]
    fn minmax_returns_range() {
        let h = gaussian_hist(2048, 100.0);
        let c = Calibrator::new(CalibMethod::MinMax);
        assert_eq!(c.threshold(&h, 4.0), 4.0);
    }

    #[test]
    fn percentile_clips_tail() {
        let mut h = gaussian_hist(2048, 100.0);
        h[2047] += 5.0; // tiny outlier mass at the top
        let c = Calibrator { method: CalibMethod::Percentile, percentile: 99.9, levels: 128 };
        let t = c.threshold(&h, 4.0);
        assert!(t < 1.5, "99.9th percentile of a sigma=100bin gaussian ~ 0.65, got {t}");
    }

    #[test]
    fn kl_ignores_outlier_spike() {
        // Gaussian bulk in the first ~400 bins + isolated outlier at the top:
        // the KL threshold should saturate well below the outlier.
        let mut h = gaussian_hist(2048, 120.0);
        h[2040] += 3.0;
        let c = Calibrator::default();
        let t = c.threshold(&h, 8.0);
        assert!(t < 6.0, "KL threshold {t} should clip the outlier");
        // and a minmax calibrator would NOT clip:
        assert_eq!(Calibrator::new(CalibMethod::MinMax).threshold(&h, 8.0), 8.0);
    }

    #[test]
    fn kl_divergence_basics() {
        let p = vec![1.0, 2.0, 3.0];
        assert!(kl_divergence(&p, &p) < 1e-12);
        let q = vec![3.0, 2.0, 1.0];
        assert!(kl_divergence(&p, &q) > 0.0);
        assert_eq!(kl_divergence(&[0.0], &[0.0]), f64::INFINITY);
    }

    #[test]
    fn degenerate_histograms() {
        let c = Calibrator::default();
        assert_eq!(c.threshold(&[0.0; 2048], 1.0), 1.0);
        assert_eq!(c.threshold(&[1.0; 64], 1.0), 1.0); // bins <= levels
    }

    #[test]
    fn scale_is_threshold_over_127() {
        let h = gaussian_hist(2048, 100.0);
        let c = Calibrator::new(CalibMethod::MinMax);
        let s = c.scale(&h, 2.54);
        assert!((s - 2.54 / 127.0).abs() < 1e-7);
    }
}
