//! Edge-device latency/energy model — the substitute for the paper's
//! physical Jetson Nano / Xavier NX testbed (DESIGN.md §Substitutions).
//!
//! An analytical roofline: every deployed (fused) op costs
//!
//! ```text
//! t(op) = max( flops / (peak_rate(precision) · util(op)),
//!              bytes / mem_bw )            + launch_overhead
//! ```
//!
//! summed over the optimized graph ([`crate::gopt::OptimizedGraph`]).
//! Device constants come from the public Jetson specifications; per-op-type
//! utilization factors model what the paper's TensorRT auto-tuner achieves
//! (dense conv ≫ depthwise conv on these GPUs). The INT8 path only exists
//! on Xavier NX (48 Volta Tensor Cores); on Nano INT8 falls back to the
//! FP16 rate — exactly the heterogeneity argument of the paper's §IV-A.
//!
//! Energy: `E = P · L` (paper §V-E), with the device's sustained power.

mod device;

pub use device::{Device, DeviceKind, Precision};

use crate::gopt::OptimizedGraph;

/// Latency/energy breakdown for one deployed graph on one device.
#[derive(Clone, Debug)]
pub struct LatencyReport {
    pub device: String,
    /// Batch-1 end-to-end latency, milliseconds.
    pub latency_ms: f64,
    /// Per-fused-op latencies (same order as the optimized graph).
    pub per_op_ms: Vec<f64>,
    /// Fraction of ops that were memory-bound.
    pub memory_bound_frac: f64,
    /// Energy per inference, millijoules.
    pub energy_mj: f64,
}

/// Price one optimized graph on one device.
pub fn simulate(graph: &OptimizedGraph, dev: &Device) -> LatencyReport {
    let mut per_op_ms = Vec::with_capacity(graph.ops.len());
    let mut mem_bound = 0usize;
    for op in &graph.ops {
        let rate = dev.rate_gflops(op.precision) * dev.utilization(op.kind);
        let t_comp_ms = if rate > 0.0 {
            op.flops as f64 / (rate * 1e9) * 1e3
        } else {
            f64::INFINITY
        };
        let t_mem_ms = op.bytes as f64 / (dev.mem_bw_gbps * 1e9) * 1e3;
        if t_mem_ms > t_comp_ms {
            mem_bound += 1;
        }
        per_op_ms.push(t_comp_ms.max(t_mem_ms) + dev.launch_overhead_ms);
    }
    let latency_ms: f64 = per_op_ms.iter().sum();
    LatencyReport {
        device: dev.name.clone(),
        latency_ms,
        memory_bound_frac: if graph.ops.is_empty() {
            0.0
        } else {
            mem_bound as f64 / graph.ops.len() as f64
        },
        energy_mj: dev.power_w * latency_ms, // mW·ms == µJ; see energy()
        per_op_ms,
    }
}

/// Energy per inference in millijoules: `E = P · L` (paper §V-E).
pub fn energy_mj(power_w: f64, latency_ms: f64) -> f64 {
    power_w * latency_ms
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gopt::{FusedKind, FusedOp, OptimizedGraph};

    fn op(flops: u64, bytes: u64, precision: Precision) -> FusedOp {
        FusedOp {
            name: "t".into(),
            kind: FusedKind::ConvBnAct,
            flops,
            bytes,
            precision,
            h: 1,
            w: 1,
            cin: 1,
            cout: 1,
            k: 1,
        }
    }

    fn graph(ops: Vec<FusedOp>) -> OptimizedGraph {
        OptimizedGraph { model: "t".into(), ops, weight_bytes: 0, dense_weight_bytes: 0 }
    }

    #[test]
    fn compute_bound_scales_with_rate() {
        let dev = Device::xavier_nx();
        let g = graph(vec![op(2_000_000_000, 1_000, Precision::Fp32)]);
        let r32 = simulate(&g, &dev);
        let g8 = graph(vec![op(2_000_000_000, 1_000, Precision::Int8)]);
        let r8 = simulate(&g8, &dev);
        assert!(
            r32.latency_ms / r8.latency_ms > 3.0,
            "tensor-core int8 should be much faster: {} vs {}",
            r32.latency_ms,
            r8.latency_ms
        );
    }

    #[test]
    fn memory_bound_insensitive_to_precision_rate() {
        let dev = Device::jetson_nano();
        // tiny flops, huge bytes -> memory bound at any precision
        let a = simulate(&graph(vec![op(10, 500_000_000, Precision::Fp32)]), &dev);
        let b = simulate(&graph(vec![op(10, 500_000_000, Precision::Int8)]), &dev);
        assert!((a.latency_ms - b.latency_ms).abs() / a.latency_ms < 1e-6);
        assert_eq!(a.memory_bound_frac, 1.0);
    }

    #[test]
    fn nano_has_no_int8_advantage_over_fp16() {
        let dev = Device::jetson_nano();
        assert_eq!(
            dev.rate_gflops(Precision::Int8),
            dev.rate_gflops(Precision::Fp16),
            "Nano has no INT8 tensor cores (paper §IV-A)"
        );
        let nx = Device::xavier_nx();
        assert!(nx.rate_gflops(Precision::Int8) > nx.rate_gflops(Precision::Fp16));
    }

    #[test]
    fn energy_is_power_times_latency() {
        let dev = Device::xavier_nx();
        let g = graph(vec![op(1_000_000, 1_000_000, Precision::Fp32)]);
        let r = simulate(&g, &dev);
        assert!((r.energy_mj - dev.power_w * r.latency_ms).abs() < 1e-12);
    }

    #[test]
    fn launch_overhead_rewards_fusion() {
        let dev = Device::xavier_nx();
        let one = graph(vec![op(1000, 1000, Precision::Fp32)]);
        let three = graph(vec![
            op(400, 400, Precision::Fp32),
            op(300, 300, Precision::Fp32),
            op(300, 300, Precision::Fp32),
        ]);
        let r1 = simulate(&one, &dev);
        let r3 = simulate(&three, &dev);
        assert!(r3.latency_ms > r1.latency_ms, "3 launches must beat 1 launch");
    }
}
