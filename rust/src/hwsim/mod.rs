//! Edge-device latency/energy model — the substitute for the paper's
//! physical Jetson Nano / Xavier NX testbed (DESIGN.md §Substitutions).
//!
//! An analytical roofline: every deployed (fused) op costs
//!
//! ```text
//! t(op) = max( flops / (peak_rate(precision) · util(op)),
//!              bytes / mem_bw )            + launch_overhead
//! ```
//!
//! summed over the optimized graph ([`crate::gopt::OptimizedGraph`]).
//! Device constants come from the public Jetson specifications; per-op-type
//! utilization factors model what the paper's TensorRT auto-tuner achieves
//! (dense conv ≫ depthwise conv on these GPUs). The INT8 path only exists
//! on Xavier NX (48 Volta Tensor Cores); on Nano INT8 falls back to the
//! FP16 rate — exactly the heterogeneity argument of the paper's §IV-A.
//!
//! Energy: `E = P · L` (paper §V-E), with the device's sustained power.

mod device;

pub use device::{Device, DeviceKind, Precision};

use crate::gopt::OptimizedGraph;

/// Latency/energy breakdown for one deployed graph on one device.
#[derive(Clone, Debug)]
pub struct LatencyReport {
    pub device: String,
    /// Batch-1 end-to-end latency, milliseconds.
    pub latency_ms: f64,
    /// Per-fused-op latencies (same order as the optimized graph).
    pub per_op_ms: Vec<f64>,
    /// Fraction of ops that were memory-bound.
    pub memory_bound_frac: f64,
    /// Energy per inference, millijoules.
    pub energy_mj: f64,
}

/// Price one optimized graph on one device (batch 1).
pub fn simulate(graph: &OptimizedGraph, dev: &Device) -> LatencyReport {
    simulate_batch(graph, dev, 1)
}

/// Deployed weight bytes of one fused op at its precision, from the
/// shared [`crate::gopt::weight_elems`] formula. Bounded by `op.bytes`
/// (which also carries activation traffic) so the activation share
/// `op.bytes - weight_bytes(op)` is never negative.
fn weight_bytes(op: &crate::gopt::FusedOp) -> f64 {
    (op.weight_elems() as f64 * op.precision.bytes()).min(op.bytes as f64)
}

/// Price one optimized graph on one device at batch size `batch`.
///
/// The batching extension of the roofline (consumed by the serving
/// simulator, [`crate::serve`]): compute and *activation* traffic scale
/// linearly with the batch, while weight traffic and kernel-launch
/// overhead are paid once per batch —
///
/// ```text
/// t(op, b) = max( b·flops / (peak_rate · util),
///                 (w_bytes + b·act_bytes) / mem_bw )  + launch_overhead
/// ```
///
/// At `batch == 1` this reduces exactly to the batch-1 model above
/// (`w + act == bytes`), so [`simulate`] simply delegates here. The
/// returned [`LatencyReport`] prices the *whole batch* (divide by `batch`
/// for per-sample cost); energy likewise is per batch.
pub fn simulate_batch(graph: &OptimizedGraph, dev: &Device, batch: usize) -> LatencyReport {
    let b = batch.max(1) as f64;
    let mut per_op_ms = Vec::with_capacity(graph.ops.len());
    let mut mem_bound = 0usize;
    for op in &graph.ops {
        let rate = dev.rate_gflops(op.precision) * dev.utilization(op.kind);
        let t_comp_ms = if rate > 0.0 {
            b * op.flops as f64 / (rate * 1e9) * 1e3
        } else {
            f64::INFINITY
        };
        let w = weight_bytes(op);
        let act = op.bytes as f64 - w;
        let t_mem_ms = (w + b * act) / (dev.mem_bw_gbps * 1e9) * 1e3;
        if t_mem_ms > t_comp_ms {
            mem_bound += 1;
        }
        per_op_ms.push(t_comp_ms.max(t_mem_ms) + dev.launch_overhead_ms);
    }
    let latency_ms: f64 = per_op_ms.iter().sum();
    LatencyReport {
        device: dev.name.clone(),
        latency_ms,
        memory_bound_frac: if graph.ops.is_empty() {
            0.0
        } else {
            mem_bound as f64 / graph.ops.len() as f64
        },
        energy_mj: dev.power_w * latency_ms, // mW·ms == µJ; see energy()
        per_op_ms,
    }
}

/// Energy per inference in millijoules: `E = P · L` (paper §V-E).
pub fn energy_mj(power_w: f64, latency_ms: f64) -> f64 {
    power_w * latency_ms
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gopt::{FusedKind, FusedOp, OptimizedGraph};

    fn op(flops: u64, bytes: u64, precision: Precision) -> FusedOp {
        FusedOp {
            name: "t".into(),
            kind: FusedKind::ConvBnAct,
            flops,
            bytes,
            precision,
            h: 1,
            w: 1,
            cin: 1,
            cout: 1,
            k: 1,
        }
    }

    fn graph(ops: Vec<FusedOp>) -> OptimizedGraph {
        OptimizedGraph { model: "t".into(), ops, weight_bytes: 0, dense_weight_bytes: 0 }
    }

    #[test]
    fn compute_bound_scales_with_rate() {
        let dev = Device::xavier_nx();
        let g = graph(vec![op(2_000_000_000, 1_000, Precision::Fp32)]);
        let r32 = simulate(&g, &dev);
        let g8 = graph(vec![op(2_000_000_000, 1_000, Precision::Int8)]);
        let r8 = simulate(&g8, &dev);
        assert!(
            r32.latency_ms / r8.latency_ms > 3.0,
            "tensor-core int8 should be much faster: {} vs {}",
            r32.latency_ms,
            r8.latency_ms
        );
    }

    #[test]
    fn memory_bound_insensitive_to_precision_rate() {
        let dev = Device::jetson_nano();
        // tiny flops, huge bytes -> memory bound at any precision
        let a = simulate(&graph(vec![op(10, 500_000_000, Precision::Fp32)]), &dev);
        let b = simulate(&graph(vec![op(10, 500_000_000, Precision::Int8)]), &dev);
        assert!((a.latency_ms - b.latency_ms).abs() / a.latency_ms < 1e-6);
        assert_eq!(a.memory_bound_frac, 1.0);
    }

    #[test]
    fn nano_has_no_int8_advantage_over_fp16() {
        let dev = Device::jetson_nano();
        assert_eq!(
            dev.rate_gflops(Precision::Int8),
            dev.rate_gflops(Precision::Fp16),
            "Nano has no INT8 tensor cores (paper §IV-A)"
        );
        let nx = Device::xavier_nx();
        assert!(nx.rate_gflops(Precision::Int8) > nx.rate_gflops(Precision::Fp16));
    }

    #[test]
    fn energy_is_power_times_latency() {
        let dev = Device::xavier_nx();
        let g = graph(vec![op(1_000_000, 1_000_000, Precision::Fp32)]);
        let r = simulate(&g, &dev);
        assert!((r.energy_mj - dev.power_w * r.latency_ms).abs() < 1e-12);
    }

    #[test]
    fn batch_one_matches_simulate() {
        let dev = Device::xavier_nx();
        let g = graph(vec![
            op(2_000_000, 400_000, Precision::Fp32),
            op(10, 500_000_000, Precision::Int8),
        ]);
        let a = simulate(&g, &dev);
        let b = simulate_batch(&g, &dev, 1);
        assert_eq!(a.latency_ms, b.latency_ms);
        assert_eq!(a.per_op_ms, b.per_op_ms);
        assert_eq!(a.memory_bound_frac, b.memory_bound_frac);
    }

    #[test]
    fn batching_amortizes_but_stays_monotone() {
        let dev = Device::xavier_nx();
        // realistically conv-shaped op: weights + activations in bytes
        let mut o = op(50_000_000, 0, Precision::Fp32);
        o.cin = 64;
        o.cout = 64;
        o.k = 3;
        o.bytes = (3 * 3 * 64 * 64 * 4 + 2 * 56 * 56 * 64 * 4) as u64;
        let g = graph(vec![o]);
        let mut prev = 0.0;
        for b in 1..=16usize {
            let t = simulate_batch(&g, &dev, b).latency_ms;
            assert!(t > prev, "batch latency must grow with batch size");
            // amortization: a batch of b is cheaper than b batches of 1
            let t1 = simulate_batch(&g, &dev, 1).latency_ms;
            assert!(
                t < b as f64 * t1 + 1e-12,
                "batch {b}: {t} ms not cheaper than {b}x{t1} ms"
            );
            prev = t;
        }
    }

    #[test]
    fn weight_split_never_exceeds_total_bytes() {
        let dev = Device::jetson_nano();
        // tiny bytes but huge nominal weight geometry: the weight estimate
        // must clamp to op.bytes so activation traffic never goes negative
        let mut o = op(1_000, 100, Precision::Fp32);
        o.cin = 512;
        o.cout = 512;
        o.k = 3;
        let g = graph(vec![o]);
        for b in [1usize, 2, 8] {
            let t = simulate_batch(&g, &dev, b).latency_ms;
            assert!(t.is_finite() && t > 0.0);
        }
        // with act == 0 the memory term is batch-invariant
        let t1 = simulate_batch(&g, &dev, 1).per_op_ms[0];
        let t8 = simulate_batch(&g, &dev, 8).per_op_ms[0];
        assert!(t8 >= t1);
    }

    #[test]
    fn launch_overhead_rewards_fusion() {
        let dev = Device::xavier_nx();
        let one = graph(vec![op(1000, 1000, Precision::Fp32)]);
        let three = graph(vec![
            op(400, 400, Precision::Fp32),
            op(300, 300, Precision::Fp32),
            op(300, 300, Precision::Fp32),
        ]);
        let r1 = simulate(&one, &dev);
        let r3 = simulate(&three, &dev);
        assert!(r3.latency_ms > r1.latency_ms, "3 launches must beat 1 launch");
    }
}
