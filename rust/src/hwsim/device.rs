//! Device descriptors for the Jetson-class roofline model.
//!
//! Constants from NVIDIA's public module datasheets (peak rates) with
//! per-op-type utilization factors representing what a tuned TensorRT
//! engine typically sustains. Absolute milliseconds are a model, not a
//! measurement — the reproduction targets the *ratios* (speedups,
//! crossovers), as DESIGN.md §7 spells out.

use crate::gopt::FusedKind;

/// Numeric precision of a deployed op.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Precision {
    Fp32,
    Fp16,
    Int8,
    /// Mixed-precision extension (paper §VI-A): INT4 on ultra-low-S filters.
    Int4,
}

impl Precision {
    /// Storage bytes per weight element.
    pub fn bytes(self) -> f64 {
        match self {
            Precision::Fp32 => 4.0,
            Precision::Fp16 => 2.0,
            Precision::Int8 => 1.0,
            Precision::Int4 => 0.5,
        }
    }
}

/// Supported device models.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DeviceKind {
    /// 128-core Maxwell, no tensor cores, 10 W envelope.
    JetsonNano,
    /// 384-core Volta + 48 tensor cores (INT8), 15 W envelope.
    XavierNx,
    /// Idealized device with flat rates (ablations: isolates graph effects
    /// from device effects).
    Ideal,
}

/// An edge device for the roofline simulator.
#[derive(Clone, Debug)]
pub struct Device {
    /// Canonical CLI name ([`Device::by_name`]).
    pub name: String,
    /// Which device model this descriptor instantiates.
    pub kind: DeviceKind,
    /// Peak dense-compute rates in GFLOP/s (GOP/s for int paths).
    pub fp32_gflops: f64,
    pub fp16_gflops: f64,
    pub int8_gops: f64,
    pub int4_gops: f64,
    /// DRAM bandwidth, GB/s.
    pub mem_bw_gbps: f64,
    /// Sustained board power, watts.
    pub power_w: f64,
    /// Per-kernel launch + scheduling overhead, ms (what layer fusion
    /// eliminates).
    pub launch_overhead_ms: f64,
}

impl Device {
    /// NVIDIA Jetson Nano (datasheet: 472 GFLOPS fp16, 25.6 GB/s, 10 W).
    /// No INT8 tensor cores: int8 executes via the fp16 ALU path (dp4a on
    /// Maxwell is marginal; TensorRT falls back) — the paper's low-power
    /// baseline without dedicated INT8 acceleration (§IV-A).
    pub fn jetson_nano() -> Device {
        Device {
            name: "jetson-nano".into(),
            kind: DeviceKind::JetsonNano,
            fp32_gflops: 236.0,
            fp16_gflops: 472.0,
            int8_gops: 472.0, // = fp16: no dedicated units
            int4_gops: 472.0,
            mem_bw_gbps: 25.6,
            power_w: 10.0,
            launch_overhead_ms: 0.010,
        }
    }

    /// NVIDIA Jetson Xavier NX (datasheet: 21 TOPS INT8 via 48 tensor
    /// cores + DLA; ~6 TFLOPS fp16, 59.7 GB/s, 15 W). Peak rates derated
    /// to GPU-only sustained figures.
    pub fn xavier_nx() -> Device {
        Device {
            name: "xavier-nx".into(),
            kind: DeviceKind::XavierNx,
            fp32_gflops: 885.0,
            fp16_gflops: 3540.0,
            int8_gops: 10000.0,
            int4_gops: 10000.0, // tensor cores: int4 ~ int8 rate (storage halves)
            mem_bw_gbps: 59.7,
            power_w: 15.0,
            launch_overhead_ms: 0.008,
        }
    }

    /// Flat-rate idealized accelerator (ablation device).
    pub fn ideal() -> Device {
        Device {
            name: "ideal".into(),
            kind: DeviceKind::Ideal,
            fp32_gflops: 1000.0,
            fp16_gflops: 2000.0,
            int8_gops: 4000.0,
            int4_gops: 8000.0,
            mem_bw_gbps: 100.0,
            power_w: 10.0,
            launch_overhead_ms: 0.0,
        }
    }

    /// Look up by CLI name.
    pub fn by_name(name: &str) -> Option<Device> {
        match name {
            "jetson-nano" | "nano" => Some(Device::jetson_nano()),
            "xavier-nx" | "nx" => Some(Device::xavier_nx()),
            "ideal" => Some(Device::ideal()),
            _ => None,
        }
    }

    /// All devices (sweeps).
    pub fn all() -> Vec<Device> {
        vec![Device::jetson_nano(), Device::xavier_nx(), Device::ideal()]
    }

    /// Peak rate for a precision, GFLOP/s.
    pub fn rate_gflops(&self, p: Precision) -> f64 {
        match p {
            Precision::Fp32 => self.fp32_gflops,
            Precision::Fp16 => self.fp16_gflops,
            Precision::Int8 => self.int8_gops,
            Precision::Int4 => self.int4_gops,
        }
    }

    /// Hardware-aware engine hot-swap cost (the HALP-style pricing the
    /// serving layer charges when a device changes its resident variant
    /// set): streaming `weight_bytes` of engine weights over DRAM
    /// bandwidth, plus a fixed engine-initialization overhead. The
    /// autoscaler prices a server *wake* with the same formula — the
    /// initial resident set's bytes streamed cold — and additionally
    /// charges the wake window E = P·L of energy. Like the rest of the
    /// roofline this is a model, not a measurement — §7's
    /// ratios-not-milliseconds caveat applies.
    pub fn swap_in_ms(&self, weight_bytes: u64, init_ms: f64) -> f64 {
        weight_bytes as f64 / (self.mem_bw_gbps * 1e9) * 1e3 + init_ms
    }

    /// Sustained-utilization factor by op type: what a tuned engine
    /// achieves relative to peak. Depthwise convolutions are notoriously
    /// bandwidth/occupancy limited on these GPUs; dense GEMM-shaped work is
    /// the best case.
    pub fn utilization(&self, kind: FusedKind) -> f64 {
        match kind {
            FusedKind::ConvBnAct => 0.55,
            FusedKind::DwConvBnAct => 0.18,
            FusedKind::Gemm => 0.65,
            FusedKind::Se => 0.25,
            FusedKind::Elementwise => 0.30,
            FusedKind::Pool => 0.30,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn by_name_aliases() {
        assert_eq!(Device::by_name("nano").unwrap().kind, DeviceKind::JetsonNano);
        assert_eq!(Device::by_name("xavier-nx").unwrap().kind, DeviceKind::XavierNx);
        assert!(Device::by_name("h100").is_none());
    }

    #[test]
    fn precision_bytes() {
        assert_eq!(Precision::Fp32.bytes(), 4.0);
        assert_eq!(Precision::Int4.bytes(), 0.5);
    }

    #[test]
    fn nx_int8_is_fastest_path() {
        let d = Device::xavier_nx();
        assert!(d.rate_gflops(Precision::Int8) > d.rate_gflops(Precision::Fp16));
        assert!(d.rate_gflops(Precision::Fp16) > d.rate_gflops(Precision::Fp32));
    }

    #[test]
    fn swap_cost_is_bytes_over_bandwidth_plus_init() {
        let nx = Device::xavier_nx();
        // 59.7 MB at 59.7 GB/s is exactly 1 ms of weight streaming
        assert!((nx.swap_in_ms(59_700_000, 5.0) - 6.0).abs() < 1e-9);
        assert_eq!(nx.swap_in_ms(0, 2.5), 2.5);
        // slower DRAM pays more for the same engine
        let nano = Device::jetson_nano();
        assert!(nano.swap_in_ms(10_000_000, 0.0) > nx.swap_in_ms(10_000_000, 0.0));
    }

    #[test]
    fn utilization_orders_dw_below_dense() {
        let d = Device::jetson_nano();
        assert!(d.utilization(FusedKind::DwConvBnAct) < d.utilization(FusedKind::ConvBnAct));
    }
}
