//! Command-line parsing (clap substitute — unavailable offline).
//!
//! Grammar: `hqp <command> [--flag value]... [--switch]...`
//! Flags are declared per command in main.rs; unknown flags error.

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// Parsed invocation.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub command: String,
    flags: BTreeMap<String, String>,
    switches: Vec<String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse raw argv (without the binary name).
    pub fn parse(argv: &[String]) -> Result<Args> {
        let mut a = Args::default();
        let mut it = argv.iter().peekable();
        if let Some(cmd) = it.next() {
            if cmd.starts_with('-') {
                return Err(Error::Cli(format!("expected command, got flag {cmd}")));
            }
            a.command = cmd.clone();
        }
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if name.is_empty() {
                    return Err(Error::Cli("bare --".into()));
                }
                if let Some((k, v)) = name.split_once('=') {
                    a.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    a.flags.insert(name.to_string(), it.next().unwrap().clone());
                } else {
                    a.switches.push(name.to_string());
                }
            } else {
                a.positional.push(tok.clone());
            }
        }
        Ok(a)
    }

    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(String::as_str)
    }

    pub fn flag_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.flag(name).unwrap_or(default)
    }

    pub fn flag_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| Error::Cli(format!("--{name} wants an integer: {e}"))),
        }
    }

    pub fn flag_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| Error::Cli(format!("--{name} wants a number: {e}"))),
        }
    }

    pub fn switch(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name)
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    /// Error on flags/switches not in the allowed set (catches typos).
    pub fn expect_known(&self, known: &[&str]) -> Result<()> {
        for k in self.flags.keys() {
            if !known.contains(&k.as_str()) {
                return Err(Error::Cli(format!("unknown flag --{k}")));
            }
        }
        for s in &self.switches {
            if !known.contains(&s.as_str()) {
                return Err(Error::Cli(format!("unknown switch --{s}")));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        let v: Vec<String> = s.split_whitespace().map(String::from).collect();
        Args::parse(&v).unwrap()
    }

    #[test]
    fn commands_flags_switches() {
        let a = parse("table --id 1 --device nx --force");
        assert_eq!(a.command, "table");
        assert_eq!(a.flag("id"), Some("1"));
        assert_eq!(a.flag("device"), Some("nx"));
        assert!(a.switch("force"));
        assert!(!a.switch("quiet"));
    }

    #[test]
    fn equals_syntax() {
        let a = parse("run --model=resnet18");
        assert_eq!(a.flag("model"), Some("resnet18"));
    }

    #[test]
    fn typed_accessors() {
        let a = parse("x --n 5 --f 1.5");
        assert_eq!(a.flag_usize("n", 0).unwrap(), 5);
        assert_eq!(a.flag_f64("f", 0.0).unwrap(), 1.5);
        assert_eq!(a.flag_usize("missing", 7).unwrap(), 7);
        assert!(parse("x --n five").flag_usize("n", 0).is_err());
    }

    #[test]
    fn unknown_flags_caught() {
        let a = parse("t --good 1 --bad 2");
        assert!(a.expect_known(&["good"]).is_err());
        assert!(a.expect_known(&["good", "bad"]).is_ok());
    }

    #[test]
    fn flag_before_command_rejected() {
        let v: Vec<String> = vec!["--x".into()];
        assert!(Args::parse(&v).is_err());
    }
}
