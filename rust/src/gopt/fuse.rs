//! Fusion + dead-channel elimination + cost assembly.
//!
//! Walks the IR in topological order, greedily absorbing BN/activation
//! nodes into their producing conv/fc (legal because the tracer guarantees
//! single-consumer chains for those patterns), collapsing SE regions, and
//! pricing every surviving op at its live channel counts.

use std::collections::{BTreeMap, HashMap};

use crate::error::{Error, Result};
use crate::graph::{Graph, Liveness, Node, OpKind};
use crate::hwsim::Precision;

use super::{autotune, FusedKind, FusedOp, OptimizeOptions, OptimizedGraph};
// (FusedKind is used in match arms and the Costing helpers below.)

/// Count consumers of every tensor (fusion legality).
fn consumer_counts(graph: &Graph) -> BTreeMap<usize, usize> {
    let mut c = BTreeMap::new();
    for n in &graph.nodes {
        for i in &n.inputs {
            *c.entry(*i).or_insert(0) += 1;
        }
    }
    c
}

/// Public fusion entry (kept separate for unit tests / ablations).
pub fn fuse(graph: &Graph, live: &Liveness, opts: &OptimizeOptions) -> Result<OptimizedGraph> {
    build(graph, live, opts)
}

struct Costing<'a> {
    graph: &'a Graph,
    live: &'a Liveness,
    opts: &'a OptimizeOptions,
}

impl<'a> Costing<'a> {
    fn live_in(&self, n: &Node) -> usize {
        self.live.count(n.inputs[0])
    }
    fn live_out(&self, n: &Node) -> usize {
        self.live.count(n.output)
    }

    /// Tile efficiency from the auto-tuner (1.0 when disabled).
    fn tile_eff(&self, kind: FusedKind, m: usize, n: usize, k: usize) -> f64 {
        if !self.opts.autotune {
            return 1.0;
        }
        match kind {
            FusedKind::ConvBnAct | FusedKind::DwConvBnAct | FusedKind::Gemm => {
                autotune::autotune(m, n, k, autotune::DEFAULT_TILES).1
            }
            _ => 1.0,
        }
    }

    /// Build the FusedOp for a conv/dwconv/fc `n`, charging `extra_elt`
    /// fused element-wise ops (bn/act) and optional `extra` traffic.
    fn compute_op(&self, n: &Node, kind: FusedKind, fused_elt_ops: u64) -> FusedOp {
        let precision = self.opts.precision.for_group(n.group);
        let (cin_l, cout_l) = (self.live_in(n), self.live_out(n));
        // Spatial ops are priced at the deployment resolution (see
        // OptimizeOptions::spatial_scale); FC layers act on pooled vectors
        // and don't scale.
        let sscale = match n.kind {
            OpKind::Conv | OpKind::DwConv => self.opts.spatial_scale,
            _ => 1.0,
        };
        let hw = ((n.h * n.w) as f64 * sscale) as u64;
        let (flops, welems, m, nn, kk) = match n.kind {
            OpKind::Conv => {
                let f = 2 * (n.k * n.k) as u64 * cin_l as u64 * cout_l as u64 * hw;
                let w = (n.k * n.k) as u64 * cin_l as u64 * cout_l as u64;
                (f, w, hw as usize, cout_l, n.k * n.k * cin_l)
            }
            OpKind::DwConv => {
                let f = 2 * (n.k * n.k) as u64 * cout_l as u64 * hw;
                let w = (n.k * n.k) as u64 * cout_l as u64;
                (f, w, hw as usize, cout_l, n.k * n.k)
            }
            OpKind::Fc => {
                let f = 2 * cin_l as u64 * cout_l as u64;
                let w = cin_l as u64 * cout_l as u64 + cout_l as u64;
                (f, w, 1usize, cout_l, cin_l)
            }
            _ => unreachable!("compute_op on non-compute node"),
        };
        // Tile efficiency derates FLOP throughput: model as extra issued ops.
        let eff = self.tile_eff(kind, m, nn, kk);
        let flops = (flops as f64 / eff).round() as u64 + fused_elt_ops;

        let act_bytes = |c: usize, spatial: u64| -> u64 {
            // activations move at the compute precision (int8 engines carry
            // int8 activations; fp32 engines carry f32)
            (c as u64 * spatial) as u64 * precision.bytes().max(1.0) as u64
        };
        let in_spatial =
            (*self.graph.tensor_spatial.get(&n.inputs[0]).unwrap_or(&1) as f64 * sscale) as u64;
        let weight_bytes = (welems as f64 * precision.bytes()) as u64
            + if precision == Precision::Int8 || precision == Precision::Int4 {
                4 * cout_l as u64 // per-channel scale metadata
            } else {
                0
            };
        let bytes = act_bytes(cin_l, in_spatial) + weight_bytes + act_bytes(cout_l, hw);

        FusedOp {
            name: n.name.clone(),
            kind,
            flops,
            bytes,
            precision,
            h: n.h,
            w: n.w,
            cin: cin_l,
            cout: cout_l,
            k: n.k,
        }
    }

    /// Element-wise op (add / lone act / se_mul).
    fn elt_op(&self, n: &Node) -> FusedOp {
        let c = self.live_out(n);
        // spatial tensors scale to deployment resolution; vectors don't
        let sscale = if n.h * n.w > 1 { self.opts.spatial_scale } else { 1.0 };
        let hw = ((n.h * n.w) as f64 * sscale) as u64;
        let b = self.opts.precision.compute.bytes().max(1.0) as u64;
        FusedOp {
            name: n.name.clone(),
            kind: FusedKind::Elementwise,
            flops: c as u64 * hw,
            bytes: (n.inputs.len() as u64 + 1) * c as u64 * hw * b,
            precision: self.opts.precision.compute,
            h: n.h,
            w: n.w,
            cin: c,
            cout: c,
            k: 1,
        }
    }

    fn pool_op(&self, n: &Node) -> FusedOp {
        let c = self.live_in(n);
        let in_spatial = (*self.graph.tensor_spatial.get(&n.inputs[0]).unwrap_or(&1) as f64
            * self.opts.spatial_scale) as u64;
        let b = self.opts.precision.compute.bytes().max(1.0) as u64;
        FusedOp {
            name: n.name.clone(),
            kind: FusedKind::Pool,
            flops: c as u64 * in_spatial,
            bytes: c as u64 * in_spatial * b + c as u64 * b,
            precision: self.opts.precision.compute,
            h: 1,
            w: 1,
            cin: c,
            cout: c,
            k: 1,
        }
    }
}

/// Weight storage of the deployed engine + the FP32 dense baseline.
fn storage(graph: &Graph, live: &Liveness, opts: &OptimizeOptions) -> (u64, u64) {
    let mut deployed = 0u64;
    let mut dense = 0u64;
    for n in &graph.nodes {
        let (welems_dense, welems_live, cout_l) = match n.kind {
            OpKind::Conv => {
                let cin_l = live.count(n.inputs[0]);
                let cout_l = live.count(n.output);
                (
                    (n.k * n.k * n.cin * n.cout) as u64,
                    (n.k * n.k * cin_l * cout_l) as u64,
                    cout_l as u64,
                )
            }
            OpKind::DwConv => {
                let cout_l = live.count(n.output);
                ((n.k * n.k * n.cout) as u64, (n.k * n.k * cout_l) as u64, cout_l as u64)
            }
            OpKind::Fc => {
                let cin_l = live.count(n.inputs[0]);
                let cout_l = live.count(n.output);
                (
                    (n.cin * n.cout + n.cout) as u64,
                    (cin_l * cout_l + cout_l) as u64,
                    cout_l as u64,
                )
            }
            // BN folds into the conv at deploy; count it only in the dense
            // baseline (the FP32 reference engine also folds, so skip both
            // for a like-for-like comparison).
            _ => (0, 0, 0),
        };
        let p = opts.precision.for_group(n.group);
        dense += welems_dense * 4;
        deployed += (welems_live as f64 * p.bytes()) as u64
            + if matches!(p, Precision::Int8 | Precision::Int4) {
                4 * cout_l
            } else {
                0
            };
    }
    (deployed, dense)
}

pub(super) fn build(
    graph: &Graph,
    live: &Liveness,
    opts: &OptimizeOptions,
) -> Result<OptimizedGraph> {
    let consumers = consumer_counts(graph);
    let costing = Costing { graph, live, opts };

    // Node lookup by id and by output tensor.
    let by_output: HashMap<usize, usize> =
        graph.nodes.iter().enumerate().map(|(i, n)| (n.output, i)).collect();

    let mut absorbed = vec![false; graph.nodes.len()];
    let mut ops = Vec::new();

    // Pre-pass: mark SE regions (squeeze-gap, fc1, fc2, mul share a ".se"
    // name prefix from the tracer).
    let mut se_mul_members: HashMap<usize, Vec<usize>> = HashMap::new();
    if opts.fusion {
        for (i, n) in graph.nodes.iter().enumerate() {
            if n.kind == OpKind::SeMul {
                let prefix = n.name.trim_end_matches(".mul");
                let members: Vec<usize> = graph
                    .nodes
                    .iter()
                    .enumerate()
                    .filter(|(_, m)| m.name.starts_with(prefix) && m.id != n.id)
                    .map(|(j, _)| j)
                    .collect();
                se_mul_members.insert(i, members);
            }
        }
    }

    for (i, n) in graph.nodes.iter().enumerate() {
        if absorbed[i] {
            continue;
        }
        match n.kind {
            OpKind::Conv | OpKind::DwConv | OpKind::Fc => {
                let kind = match n.kind {
                    OpKind::Conv => {
                        // pointwise convs deploy as GEMMs (the L1 kernel path)
                        if n.k == 1 && n.groups == 1 {
                            FusedKind::Gemm
                        } else {
                            FusedKind::ConvBnAct
                        }
                    }
                    OpKind::DwConv => FusedKind::DwConvBnAct,
                    _ => FusedKind::Gemm,
                };
                let mut fused_elt = 0u64;
                if opts.fusion {
                    // Absorb a single-consumer bn -> act chain.
                    let mut tail = n.output;
                    loop {
                        let next = by_output
                            .values()
                            .copied()
                            .find(|&j| !absorbed[j] && graph.nodes[j].inputs.first() == Some(&tail)
                                  && matches!(graph.nodes[j].kind, OpKind::Bn | OpKind::Act)
                                  && graph.nodes[j].inputs.len() == 1);
                        match next {
                            Some(j) if consumers.get(&tail).copied().unwrap_or(0) == 1 => {
                                absorbed[j] = true;
                                let m = &graph.nodes[j];
                                let ssc = if m.h * m.w > 1 { opts.spatial_scale } else { 1.0 };
                                fused_elt +=
                                    ((live.count(m.output) * m.h * m.w) as f64 * ssc) as u64;
                                tail = m.output;
                            }
                            _ => break,
                        }
                    }
                }
                let op = costing.compute_op(n, kind, fused_elt);
                if op.cout > 0 && op.cin > 0 {
                    ops.push(op);
                }
            }
            OpKind::SeMul => {
                if let Some(members) = se_mul_members.get(&i) {
                    // One fused SE region: cost = 2 small GEMMs + scale.
                    let mut flops = 0u64;
                    let mut bytes = 0u64;
                    for &j in members {
                        absorbed[j] = true;
                        let m = &graph.nodes[j];
                        match m.kind {
                            OpKind::Fc => {
                                let f = costing.compute_op(m, FusedKind::Gemm, 0);
                                flops += f.flops;
                                bytes += f.bytes;
                            }
                            OpKind::Gap => {
                                let p = costing.pool_op(m);
                                flops += p.flops;
                                bytes += p.bytes;
                            }
                            _ => {}
                        }
                    }
                    let mul = costing.elt_op(n);
                    ops.push(FusedOp {
                        name: n.name.trim_end_matches(".mul").to_string(),
                        kind: FusedKind::Se,
                        flops: flops + mul.flops,
                        bytes: bytes + mul.bytes,
                        precision: opts.precision.compute,
                        h: n.h,
                        w: n.w,
                        cin: mul.cin,
                        cout: mul.cout,
                        k: 1,
                    });
                } else {
                    ops.push(costing.elt_op(n));
                }
            }
            OpKind::Add | OpKind::Act => ops.push(costing.elt_op(n)),
            OpKind::Bn => {
                // Unfused BN deploys as an elementwise scale-shift.
                ops.push(costing.elt_op(n));
            }
            OpKind::Gap => ops.push(costing.pool_op(n)),
        }
    }

    let (weight_bytes, dense_weight_bytes) = storage(graph, live, opts);
    if ops.is_empty() {
        return Err(Error::graph("optimized graph has no ops"));
    }
    Ok(OptimizedGraph { model: graph.model.clone(), ops, weight_bytes, dense_weight_bytes })
}
