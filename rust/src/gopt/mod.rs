//! Deployment graph optimizer — the from-scratch substitute for TensorRT
//! (DESIGN.md §Substitutions), implementing the three optimizations the
//! paper's §IV-A credits for translating compression into latency:
//!
//! 1. **Layer fusion** ([`fuse`]): conv+BN+activation collapse into single
//!    kernels (BN folds into the conv weights at deploy time), FC+act into
//!    GEMM kernels, the SE block into one fused region, residual adds into
//!    elementwise kernels — eliminating per-op launch overhead and
//!    intermediate tensor traffic.
//! 2. **Dead layer elimination** ([`crate::graph::Liveness`]): channels
//!    masked by HQP pruning are physically removed — effective channel
//!    counts shrink every consumer; a channel survives only if some
//!    producer on a residual path keeps it alive.
//! 3. **Kernel auto-tuning** ([`autotune`]): per-op tile-shape selection
//!    maximizing useful-MAC efficiency, modeling TensorRT's tactic search.
//!
//! Output: an [`OptimizedGraph`] of fused ops with FLOPs/bytes/precision,
//! priced by [`crate::hwsim`].

pub mod autotune;
pub mod fuse;

pub use autotune::{autotune, TileCandidate, DEFAULT_TILES};
pub use fuse::fuse;

use std::collections::HashMap;

use crate::error::Result;
use crate::graph::{Graph, Liveness};
use crate::hwsim::Precision;

/// Kind of a fused deployment op.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum FusedKind {
    /// Dense conv (+BN+act folded).
    ConvBnAct,
    /// Depthwise conv (+BN+act folded).
    DwConvBnAct,
    /// FC / pointwise GEMM (+act folded).
    Gemm,
    /// Squeeze-excitation region (pool + 2 FCs + scale).
    Se,
    /// Residual add / standalone activation.
    Elementwise,
    /// Global average pool.
    Pool,
}

/// One fused op with its deployment cost.
#[derive(Clone, Debug)]
pub struct FusedOp {
    pub name: String,
    pub kind: FusedKind,
    /// FLOPs at batch 1 with eliminated channels.
    pub flops: u64,
    /// DRAM traffic at batch 1: live input + weights + live output bytes.
    pub bytes: u64,
    pub precision: Precision,
    pub h: usize,
    pub w: usize,
    pub cin: usize,
    pub cout: usize,
    pub k: usize,
}

/// Deployed weight-element count of a fused-op shape. The single source
/// of truth shared by the roofline's weight/activation traffic split
/// ([`crate::hwsim::simulate_batch`]) and the serving reference engines
/// ([`crate::serve::fleet`]): changing a factor here changes both sides
/// consistently.
pub fn weight_elems(kind: FusedKind, k: usize, cin: usize, cout: usize) -> u64 {
    match kind {
        FusedKind::ConvBnAct => (k * k * cin * cout) as u64,
        // depthwise: one k×k filter per channel
        FusedKind::DwConvBnAct => (k * k * cout) as u64,
        FusedKind::Gemm => (cin * cout) as u64,
        // squeeze-excitation: two bottleneck FCs (reduction ≈ 8)
        FusedKind::Se => (cin * cout / 4) as u64,
        FusedKind::Elementwise | FusedKind::Pool => 0,
    }
}

impl FusedOp {
    /// [`weight_elems`] of this op's geometry.
    pub fn weight_elems(&self) -> u64 {
        weight_elems(self.kind, self.k, self.cin, self.cout)
    }
}

/// The deployable engine: fused ops + storage accounting.
#[derive(Clone, Debug)]
pub struct OptimizedGraph {
    pub model: String,
    pub ops: Vec<FusedOp>,
    /// Deployed weight storage (live channels only, at per-op precision,
    /// including per-channel scale metadata for int8 ops).
    pub weight_bytes: u64,
    /// FP32 dense baseline storage (the denominator of "size reduction").
    pub dense_weight_bytes: u64,
}

impl OptimizedGraph {
    /// Total FLOPs of the deployed engine (batch 1).
    pub fn flops(&self) -> u64 {
        self.ops.iter().map(|o| o.flops).sum()
    }

    /// Model-size reduction vs the FP32 dense baseline, in [0, 1].
    pub fn size_reduction(&self) -> f64 {
        if self.dense_weight_bytes == 0 {
            0.0
        } else {
            1.0 - self.weight_bytes as f64 / self.dense_weight_bytes as f64
        }
    }
}

/// Precision plan for the deployed engine.
#[derive(Clone, Debug)]
pub struct PrecisionPlan {
    /// Precision of compute ops (conv/dwconv/gemm/se).
    pub compute: Precision,
    /// Optional per-prune-group override (mixed-precision extension,
    /// paper §VI-A: low-S groups can drop to INT4, high-S stay FP16).
    pub per_group: HashMap<usize, Precision>,
}

impl PrecisionPlan {
    pub fn fp32() -> Self {
        PrecisionPlan { compute: Precision::Fp32, per_group: HashMap::new() }
    }
    pub fn int8() -> Self {
        PrecisionPlan { compute: Precision::Int8, per_group: HashMap::new() }
    }

    /// Precision for an op produced by prune group `g`.
    pub fn for_group(&self, g: Option<usize>) -> Precision {
        match g {
            Some(gid) => *self.per_group.get(&gid).unwrap_or(&self.compute),
            None => self.compute,
        }
    }
}

/// Deployment input resolution of the paper's testbed (224×224) relative
/// to the 32×32 resolution the substituted models train at. Engines are
/// priced at the paper's resolution so the compute/memory/launch-overhead
/// mix matches the regime the tables were measured in (DESIGN.md
/// §Substitutions); the channel architecture — the thing HQP transforms —
/// is shared between both resolutions.
pub const PAPER_SPATIAL_SCALE: f64 = 49.0; // (224/32)^2

/// Options for [`optimize`].
#[derive(Clone, Debug)]
pub struct OptimizeOptions {
    pub precision: PrecisionPlan,
    /// Enable layer fusion (ablation switch).
    pub fusion: bool,
    /// Enable kernel auto-tuning (ablation switch).
    pub autotune: bool,
    /// Spatial multiplier applied to activation-sized work when pricing
    /// the deployed engine (1.0 = native 32×32; default = paper's 224×224).
    pub spatial_scale: f64,
}

impl OptimizeOptions {
    pub fn fp32() -> Self {
        OptimizeOptions {
            precision: PrecisionPlan::fp32(),
            fusion: true,
            autotune: true,
            spatial_scale: PAPER_SPATIAL_SCALE,
        }
    }
    pub fn int8() -> Self {
        OptimizeOptions {
            precision: PrecisionPlan::int8(),
            fusion: true,
            autotune: true,
            spatial_scale: PAPER_SPATIAL_SCALE,
        }
    }
}

/// Build the deployable engine from the IR + the HQP filter masks.
///
/// `masks[g][j] == true` keeps filter `j` of group `g` (see
/// [`crate::graph::Liveness`]); pass `graph::liveness::full_masks` for the
/// unpruned engine.
pub fn optimize(graph: &Graph, masks: &[Vec<bool>], opts: &OptimizeOptions) -> Result<OptimizedGraph> {
    let live = Liveness::analyze(graph, masks)?;
    fuse::build(graph, &live, opts)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::liveness::full_masks;
    use crate::runtime::manifest::Manifest;

    fn chain_graph() -> Graph {
        // conv -> bn -> act -> gap -> fc
        let text = r#"{
          "version": 1, "hist_bins": 16,
          "models": {"m": {
            "input_hw": 8, "num_classes": 2, "baseline_val_acc": 1.0,
            "eval_batch": 1, "fisher_batch": 1, "hist_batch": 1,
            "weights_dir": "w", "param_order": [],
            "groups": [{"id": 0, "name": "c", "size": 8, "offset": 0,
                        "members": [["c.w", 3]], "producer": "c.w", "producer_axis": 3}],
            "taps": [],
            "ops": [
              {"id": 0, "kind": "conv", "name": "c", "inputs": [0], "output": 1,
               "attrs": {"cin": 3, "cout": 8, "k": 3, "stride": 1, "groups": 1, "h": 8, "w": 8},
               "params": ["c.w"], "group": 0, "tap": null},
              {"id": 1, "kind": "bn", "name": "cb", "inputs": [1], "output": 2,
               "attrs": {"c": 8}, "params": [], "group": 0, "tap": null},
              {"id": 2, "kind": "act", "name": "ca", "inputs": [2], "output": 3,
               "attrs": {"kind": "relu"}, "params": [], "group": 0, "tap": null},
              {"id": 3, "kind": "gap", "name": "p", "inputs": [3], "output": 4,
               "attrs": {}, "params": [], "group": null, "tap": null},
              {"id": 4, "kind": "fc", "name": "f", "inputs": [4], "output": 5,
               "attrs": {"cin": 8, "cout": 2}, "params": ["f.w", "f.b"], "group": null, "tap": null}
            ],
            "tensor_shapes": {"0": [1, 8, 8, 3], "1": [1, 8, 8, 8], "2": [1, 8, 8, 8],
                              "3": [1, 8, 8, 8], "4": [1, 8], "5": [1, 2]},
            "artifacts": {}
          }},
          "data": {}
        }"#;
        let m = Manifest::parse(text).unwrap();
        Graph::from_manifest(m.model("m").unwrap()).unwrap()
    }

    #[test]
    fn fusion_collapses_conv_bn_act() {
        let g = chain_graph();
        let opt = optimize(&g, &full_masks(&g), &OptimizeOptions::fp32()).unwrap();
        // conv+bn+act fuse; gap; fc => 3 deployed ops
        assert_eq!(opt.ops.len(), 3);
        assert_eq!(opt.ops[0].kind, FusedKind::ConvBnAct);
        assert_eq!(opt.ops[1].kind, FusedKind::Pool);
        assert_eq!(opt.ops[2].kind, FusedKind::Gemm);
    }

    #[test]
    fn no_fusion_keeps_ops_separate() {
        let g = chain_graph();
        let mut o = OptimizeOptions::fp32();
        o.fusion = false;
        let opt = optimize(&g, &full_masks(&g), &o).unwrap();
        assert_eq!(opt.ops.len(), 5);
    }

    #[test]
    fn dead_channels_shrink_flops_and_bytes() {
        let g = chain_graph();
        let full = optimize(&g, &full_masks(&g), &OptimizeOptions::fp32()).unwrap();
        let mut masks = full_masks(&g);
        for j in 0..4 {
            masks[0][j] = false; // kill half of the conv's 8 filters
        }
        let half = optimize(&g, &masks, &OptimizeOptions::fp32()).unwrap();
        assert!(half.flops() < full.flops());
        assert!(half.weight_bytes < full.weight_bytes);
        assert_eq!(half.ops[0].cout, 4);
        assert_eq!(half.ops[2].cin, 4, "fc consumes only live channels");
        assert_eq!(half.dense_weight_bytes, full.dense_weight_bytes);
    }

    #[test]
    fn int8_quarters_weight_storage() {
        let g = chain_graph();
        let f32 = optimize(&g, &full_masks(&g), &OptimizeOptions::fp32()).unwrap();
        let i8 = optimize(&g, &full_masks(&g), &OptimizeOptions::int8()).unwrap();
        let ratio = i8.weight_bytes as f64 / f32.weight_bytes as f64;
        assert!(ratio > 0.24 && ratio < 0.35, "int8 ~ 1/4 + scale overhead, got {ratio}");
        assert!(i8.size_reduction() > 0.6);
    }

    #[test]
    fn mixed_precision_overrides_group() {
        let g = chain_graph();
        let mut opts = OptimizeOptions::int8();
        opts.precision.per_group.insert(0, Precision::Fp16);
        let opt = optimize(&g, &full_masks(&g), &opts).unwrap();
        assert_eq!(opt.ops[0].precision, Precision::Fp16);
        assert_eq!(opt.ops[2].precision, Precision::Int8);
    }
}
