//! Kernel auto-tuning model: per-op tile-shape selection.
//!
//! TensorRT picks, for every layer shape, the fastest kernel tactic from a
//! library of tiled implementations. The analytical analogue: each
//! candidate tile (TM, TN, TK) issues `ceil(M/TM)·TM · ceil(N/TN)·TN ·
//! ceil(K/TK)·TK` MACs for `M·N·K` useful ones; the tuner picks the tile
//! with the highest useful fraction, and that fraction derates the op's
//! effective FLOP rate in the roofline (edge-padding waste — the same
//! quantity the L1 Pallas kernel's `mxu_utilization` reports on the TPU
//! side).

/// One candidate tile shape.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TileCandidate {
    pub tm: usize,
    pub tn: usize,
    pub tk: usize,
}

/// The tactic library: tile shapes spanning skinny and square GEMMs
/// (modeled after typical tensor-core tactic sets).
pub const DEFAULT_TILES: &[TileCandidate] = &[
    TileCandidate { tm: 128, tn: 128, tk: 32 },
    TileCandidate { tm: 256, tn: 64, tk: 32 },
    TileCandidate { tm: 64, tn: 256, tk: 32 },
    TileCandidate { tm: 64, tn: 64, tk: 64 },
    TileCandidate { tm: 32, tn: 32, tk: 32 },
    TileCandidate { tm: 16, tn: 16, tk: 16 },
    TileCandidate { tm: 8, tn: 8, tk: 8 },
];

fn ceil_to(x: usize, t: usize) -> usize {
    x.div_ceil(t) * t
}

/// Efficiency of one tile on an (M, N, K) GEMM.
pub fn tile_efficiency(m: usize, n: usize, k: usize, t: TileCandidate) -> f64 {
    if m == 0 || n == 0 || k == 0 {
        return 1.0;
    }
    let issued = ceil_to(m, t.tm) as f64 * ceil_to(n, t.tn) as f64 * ceil_to(k, t.tk) as f64;
    (m as f64 * n as f64 * k as f64) / issued
}

/// Pick the best tile for an (M, N, K) GEMM; returns (tile, efficiency).
pub fn autotune(m: usize, n: usize, k: usize, tiles: &[TileCandidate]) -> (TileCandidate, f64) {
    let mut best = (tiles[0], 0.0f64);
    for &t in tiles {
        let e = tile_efficiency(m, n, k, t);
        if e > best.1 {
            best = (t, e);
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_fit_is_perfect() {
        let t = TileCandidate { tm: 128, tn: 128, tk: 32 };
        assert_eq!(tile_efficiency(256, 128, 64, t), 1.0);
    }

    #[test]
    fn small_gemm_prefers_small_tile() {
        // A 10x12x9 GEMM wastes most of a 128-wide tile; the tuner must
        // pick one of the small tiles (16- and 8-wide tie at these dims).
        let (t, e) = autotune(10, 12, 9, DEFAULT_TILES);
        assert!(t.tm <= 16 && t.tn <= 16 && t.tk <= 16, "picked {t:?}");
        assert!(e > 0.2 && e <= 1.0);
        // strictly smaller dims break the tie toward the 8-tile
        let (t8, _) = autotune(7, 7, 7, DEFAULT_TILES);
        assert_eq!(t8, TileCandidate { tm: 8, tn: 8, tk: 8 });
    }

    #[test]
    fn big_gemm_prefers_big_tile_or_equal() {
        let (_, e_big) = autotune(1024, 1024, 512, DEFAULT_TILES);
        assert!(e_big >= 0.99);
    }

    #[test]
    fn efficiency_bounded() {
        for &t in DEFAULT_TILES {
            for (m, n, k) in [(1, 1, 1), (17, 33, 65), (1000, 3, 7)] {
                let e = tile_efficiency(m, n, k, t);
                assert!(e > 0.0 && e <= 1.0, "eff {e} for {m}x{n}x{k} on {t:?}");
            }
        }
    }

    #[test]
    fn degenerate_dims() {
        assert_eq!(tile_efficiency(0, 5, 5, DEFAULT_TILES[0]), 1.0);
    }
}
