//! Composable compression schedules — the pipeline as a *value*.
//!
//! The paper's central claim (§III, §V-B) is that *ordering matters*:
//! pruning pre-conditions the model so PTQ survives, while Q8-only on
//! ResNet-18 does not. The original API hard-coded exactly five orderings
//! as free functions behind a closed method enum, so that ablation axis
//! could not be explored. This module makes the schedule itself first
//! class:
//!
//! * [`Stage`] — one pipeline step: `StageState in → StageState out`
//!   against a shared [`Session`]. Open trait: downstream code can add
//!   stages without touching this crate.
//! * [`StageSpec`] — the built-in stages as parseable, canonicalizable
//!   data: `measure-baseline`, `prune` (the Δ_max-gated conditional loop,
//!   Algorithm 1), `prune-to` (unconditional θ target), `ptq` (Phase 2),
//!   and `mixed` (§VI-A S-guided precision planning, folded in from
//!   [`super::mixed`]).
//! * [`Schedule`] — an ordered `Vec<StageSpec>` with a canonical string
//!   form (`prune(fisher,step=1%,dmax=1.5%) >> ptq(kl)`), named presets
//!   for every legacy method, and a filesystem-safe cache slug.
//! * [`StageState`] — the state threaded through the stages: parameters,
//!   keep-masks, activation scales, numeric regime, baseline accuracy and
//!   the accumulated pruning [`PruneTrace`].
//!
//! ## Canonical string grammar
//!
//! ```text
//! schedule := stage (">>" stage)*
//! stage    := name [ "(" arg ("," arg)* ")" ]
//! arg      := key "=" value          (e.g. step=1%, dmax=1.5%, theta=50%,
//!                                     max-sparsity=60%, samples=512)
//!           | value                  (positional: a ranking or calib name,
//!                                     or ptq's `recalib` flag)
//! ```
//!
//! Fractions accept `1.5%` or `0.015`; the canonical form always prints
//! percent. Omitted arguments inherit from [`HqpConfig`] (so canonical
//! strings stay stable cache keys while `--ranking`/`--calib` still
//! work), and `parse(canonical(s)) == s` exactly — property-tested in
//! `tests/prop_schedule.rs`.
//!
//! ## Semantics worth knowing (see DESIGN.md §Schedules)
//!
//! * `prune`/`prune-to` rank and mask only *currently alive* filters, so
//!   schedules may prune repeatedly (interleaved prune/quantize à la
//!   "Ps and Qs"); their per-stage traces concatenate.
//! * `prune` validates through the FP32 eval artifact. When it runs
//!   *after* `ptq` (the quantize-first ablation) the final accuracy is
//!   re-measured through the INT8 artifact with the pre-prune activation
//!   scales — exactly the calibration staleness the paper's ordering
//!   argument is about. A trailing `ptq(recalib)` stage re-collects the
//!   scales on the pruned parameters without re-projecting weights — the
//!   §V-B fix, expressible (and searchable) as a schedule.
//! * `measure-baseline` is memoized per (model, split) in the
//!   [`Session`], so schedules sharing a session pay for one sweep.

use crate::error::{Error, Result};
use crate::gopt::PrecisionPlan;
use crate::quant::CalibMethod;
use crate::runtime::{ParamStore, Session};

use super::mixed::{self, MixedPolicy};
use super::pipeline::{Outcome, Regime};
use super::prune::{conditional_prune, prune_to_sparsity, PruneTrace};
use super::ptq;
use super::sensitivity::{self, RankingMethod, Saliency};
use super::HqpConfig;

/// The state a [`Stage`] transforms. Starts as the pristine M_train
/// ([`StageState::fresh`]) and accumulates masks, scales and measurements
/// as stages run.
pub struct StageState {
    /// Current parameters (masked and/or projected onto the INT8 grid).
    pub params: ParamStore,
    /// Per-group keep-masks (all-true until a prune stage runs).
    pub masks: Vec<Vec<bool>>,
    /// Filter sparsity θ implied by `masks`.
    pub sparsity: f64,
    /// Numeric regime the params currently deploy under.
    pub regime: Regime,
    /// Per-tap activation scales once a `ptq` stage ran.
    pub scales: Option<Vec<f32>>,
    /// A_baseline, once measured (memoized in the session).
    pub baseline_acc: Option<f64>,
    /// Most recent measured validation accuracy (NaN until any stage
    /// measures one — [`finish`] falls back to A_baseline).
    pub accuracy: f64,
    /// Concatenated pruning trajectory across every prune stage.
    pub trace: PruneTrace,
    /// Most recent saliency (scores + ranking) a stage computed.
    pub saliency: Option<Saliency>,
    /// §VI-A per-group precision plan once a `mixed` stage ran.
    pub mixed_plan: Option<PrecisionPlan>,
    /// Set when a stage mutated `params` after `ptq` measured the INT8
    /// accuracy: [`finish`] re-measures through the INT8 artifact (with
    /// the now-stale scales — deliberately: that staleness IS the
    /// quantize-first failure mode).
    pub requant: bool,
}

impl StageState {
    /// Fresh state over the session's pristine M_train (O(slots)
    /// copy-on-write clone — version stamps shared with the baseline, so
    /// the device-buffer cache carries over).
    pub fn fresh(sess: &Session) -> StageState {
        StageState {
            params: sess.baseline.clone(),
            masks: sess.mm.groups.iter().map(|g| vec![true; g.size]).collect(),
            sparsity: 0.0,
            regime: Regime::Fp32,
            scales: None,
            baseline_acc: None,
            accuracy: f64::NAN,
            trace: PruneTrace::default(),
            saliency: None,
            mixed_plan: None,
            requant: false,
        }
    }

    /// A_baseline, measuring (memoized) on first use.
    fn baseline(&mut self, sess: &mut Session, cfg: &HqpConfig) -> Result<f64> {
        match self.baseline_acc {
            Some(a) => Ok(a),
            None => {
                let a = sess.baseline_accuracy(&cfg.val_split)?;
                self.baseline_acc = Some(a);
                Ok(a)
            }
        }
    }

    /// Fold a prune result's fresh-full-relative masks into the threaded
    /// masks and recount θ.
    fn absorb_masks(&mut self, new_masks: &[Vec<bool>]) {
        let mut masked = 0usize;
        let mut total = 0usize;
        for (acc, new) in self.masks.iter_mut().zip(new_masks) {
            for (a, &n) in acc.iter_mut().zip(new) {
                *a &= n;
                total += 1;
                if !*a {
                    masked += 1;
                }
            }
        }
        self.sparsity = if total == 0 { 0.0 } else { masked as f64 / total as f64 };
    }
}

/// One compression-pipeline step. Implementations receive the state by
/// value and return the transformed state; the [`Session`] provides the
/// measurement primitives (and its caches persist across stages).
pub trait Stage {
    fn apply(&self, sess: &mut Session, state: StageState, cfg: &HqpConfig) -> Result<StageState>;
}

/// The built-in stages as data: parseable from (and canonicalizable to)
/// the schedule-string grammar. Every `Option` argument inherits its
/// value from [`HqpConfig`] at run time and is omitted from the
/// canonical string — only explicit overrides are part of the schedule's
/// identity (and therefore its cache key).
#[derive(Clone, Debug, PartialEq)]
pub enum StageSpec {
    /// Measure A_baseline on the validation split (memoized per session).
    MeasureBaseline,
    /// Algorithm 1: the Δ_max-gated conditional pruning loop.
    Prune {
        /// Filter ranking override (default: [`HqpConfig::ranking`]).
        ranking: Option<RankingMethod>,
        /// δ step fraction override (default [`HqpConfig::delta_step_frac`]).
        step_frac: Option<f64>,
        /// Δ_max override (default [`HqpConfig::delta_max`]).
        delta_max: Option<f64>,
        /// Safety-stop override: never mask beyond this filter fraction
        /// (default [`HqpConfig::max_sparsity`]).
        max_sparsity: Option<f64>,
        /// Saliency calibration sample count override (default
        /// [`HqpConfig::calib_samples`]).
        samples: Option<usize>,
    },
    /// Unconditional pruning of a fixed fraction θ of the (still-alive)
    /// filters — no quality guarantee (the paper's P50 strawman).
    PruneTo {
        /// Ranking override (default: magnitude L1, matching P50).
        ranking: Option<RankingMethod>,
        /// Fraction of filters this stage masks.
        theta: f64,
    },
    /// Phase 2: robust INT8 PTQ (calibration + weight projection +
    /// measured INT8 accuracy).
    Ptq {
        /// Calibration override (default: [`HqpConfig::calib_method`]).
        calib: Option<CalibMethod>,
        /// Recalibration-only mode (`ptq(recalib)`): re-collect the
        /// activation scales on the *current* (e.g. freshly pruned)
        /// parameters and re-measure, without re-projecting weights —
        /// the §V-B fix for the quantize-first staleness failure.
        /// Requires a prior `ptq` stage; a loud error otherwise.
        recalib: bool,
        /// Calibration sample cap for the two activation passes
        /// (default: the full calib split, the pre-knob behavior).
        samples: Option<usize>,
    },
    /// §VI-A S-guided mixed precision: plan per-group INT4/INT8/FP16 from
    /// the saliency scores (computing Fisher scores if no prior stage
    /// left any).
    Mixed {
        /// Low-S quantile dropped to INT4 (default 0.25).
        int4_quantile: Option<f64>,
        /// High-S quantile preserved at FP16 (default 0.90).
        fp16_quantile: Option<f64>,
    },
}

/// Valid stage names, in grammar order (error messages list these).
pub const STAGE_NAMES: &[&str] = &["measure-baseline", "prune", "prune-to", "ptq", "mixed"];

/// Format a fraction as the canonical percent token (`0.015` → `1.5%`).
///
/// Naively printing `v * 100.0` corrupts common inputs (`7%` parses to
/// `fl(0.07)`, whose ×100 rounds to `7.000000000000001`), so this
/// searches for the shortest decimal whose `/100` re-parse recovers `v`
/// *exactly* — the canonical token round-trips by construction, and
/// what the user typed is what the cache slug says.
fn fmt_pct(v: f64) -> String {
    let pct = v * 100.0;
    for prec in 0..=12 {
        let s = format!("{pct:.prec$}");
        if s.parse::<f64>().map(|p| p / 100.0) == Ok(v) {
            return format!("{s}%");
        }
    }
    format!("{pct}%")
}

/// Parse a fraction argument: `1.5%` (percent) or `0.015` (plain).
fn parse_frac(stage: &str, key: &str, raw: &str) -> Result<f64> {
    let (num, pct) = match raw.strip_suffix('%') {
        Some(n) => (n, true),
        None => (raw, false),
    };
    let v: f64 = num.trim().parse().map_err(|_| {
        Error::hqp(format!("stage `{stage}`: {key}={raw} is not a number or percent"))
    })?;
    let v = if pct { v / 100.0 } else { v };
    if !(0.0..=1.0).contains(&v) {
        return Err(Error::hqp(format!(
            "stage `{stage}`: {key}={raw} must be in [0%, 100%]"
        )));
    }
    Ok(v)
}

/// Parse a positive-integer argument (`samples=512`).
fn parse_count(stage: &str, key: &str, raw: &str) -> Result<usize> {
    let v: usize = raw.trim().parse().map_err(|_| {
        Error::hqp(format!("stage `{stage}`: {key}={raw} is not a positive integer"))
    })?;
    if v == 0 {
        return Err(Error::hqp(format!("stage `{stage}`: {key} must be >= 1")));
    }
    Ok(v)
}

fn parse_ranking(stage: &str, raw: &str) -> Result<RankingMethod> {
    RankingMethod::parse(raw).ok_or_else(|| {
        Error::hqp(format!(
            "stage `{stage}`: unknown ranking `{raw}` \
             (valid: fisher, mag-l1, mag-l2, bn-gamma, random)"
        ))
    })
}

impl StageSpec {
    /// Parse one stage token (`name` or `name(args)`).
    pub fn parse(tok: &str) -> Result<StageSpec> {
        let tok = tok.trim();
        let (name, args) = match tok.find('(') {
            Some(i) => {
                let inner = tok[i + 1..].strip_suffix(')').ok_or_else(|| {
                    Error::hqp(format!("stage `{tok}`: missing closing `)`"))
                })?;
                (tok[..i].trim(), inner)
            }
            None => (tok, ""),
        };
        let args: Vec<&str> = args
            .split(',')
            .map(str::trim)
            .filter(|a| !a.is_empty())
            .collect();
        match name {
            "measure-baseline" => {
                if !args.is_empty() {
                    return Err(Error::hqp("stage `measure-baseline` takes no arguments"));
                }
                Ok(StageSpec::MeasureBaseline)
            }
            "prune" => {
                let mut ranking = None;
                let mut step_frac = None;
                let mut delta_max = None;
                let mut max_sparsity = None;
                let mut samples = None;
                for a in args {
                    match a.split_once('=') {
                        Some(("step", v)) => step_frac = Some(parse_frac(name, "step", v)?),
                        Some(("dmax", v)) => delta_max = Some(parse_frac(name, "dmax", v)?),
                        Some(("max-sparsity", v)) => {
                            let m = parse_frac(name, "max-sparsity", v)?;
                            if m <= 0.0 {
                                return Err(Error::hqp(
                                    "stage `prune`: max-sparsity must be > 0%",
                                ));
                            }
                            max_sparsity = Some(m);
                        }
                        Some(("samples", v)) => {
                            samples = Some(parse_count(name, "samples", v)?)
                        }
                        Some((k, _)) => {
                            return Err(Error::hqp(format!(
                                "stage `prune`: unknown argument `{k}` (valid: a ranking \
                                 name, step=<pct>, dmax=<pct>, max-sparsity=<pct>, \
                                 samples=<n>)"
                            )))
                        }
                        None => {
                            if ranking.is_some() {
                                return Err(Error::hqp(
                                    "stage `prune`: more than one ranking given",
                                ));
                            }
                            ranking = Some(parse_ranking(name, a)?);
                        }
                    }
                }
                Ok(StageSpec::Prune { ranking, step_frac, delta_max, max_sparsity, samples })
            }
            "prune-to" => {
                let mut ranking = None;
                let mut theta = None;
                for a in args {
                    match a.split_once('=') {
                        Some(("theta", v)) => theta = Some(parse_frac(name, "theta", v)?),
                        Some((k, _)) => {
                            return Err(Error::hqp(format!(
                                "stage `prune-to`: unknown argument `{k}` (valid: a \
                                 ranking name, theta=<pct>)"
                            )))
                        }
                        None => {
                            if ranking.is_some() {
                                return Err(Error::hqp(
                                    "stage `prune-to`: more than one ranking given",
                                ));
                            }
                            ranking = Some(parse_ranking(name, a)?);
                        }
                    }
                }
                let theta = theta.ok_or_else(|| {
                    Error::hqp("stage `prune-to` needs theta=<pct>, e.g. prune-to(theta=50%)")
                })?;
                if theta <= 0.0 {
                    return Err(Error::hqp("stage `prune-to`: theta must be > 0%"));
                }
                Ok(StageSpec::PruneTo { ranking, theta })
            }
            "ptq" => {
                let mut calib = None;
                let mut recalib = false;
                let mut samples = None;
                for a in args {
                    match a.split_once('=') {
                        Some(("samples", v)) => {
                            samples = Some(parse_count(name, "samples", v)?)
                        }
                        Some((k, _)) => {
                            return Err(Error::hqp(format!(
                                "stage `ptq`: unknown argument `{k}` \
                                 (valid: a calibration name — kl, minmax, percentile — \
                                 recalib, samples=<n>)"
                            )))
                        }
                        None if a == "recalib" => {
                            if recalib {
                                return Err(Error::hqp("stage `ptq`: recalib given twice"));
                            }
                            recalib = true;
                        }
                        None => {
                            if calib.is_some() {
                                return Err(Error::hqp(
                                    "stage `ptq`: more than one calibration given",
                                ));
                            }
                            calib = Some(CalibMethod::parse(a).ok_or_else(|| {
                                Error::hqp(format!(
                                    "stage `ptq`: unknown calibration `{a}` \
                                     (valid: kl, minmax, percentile — or recalib, \
                                     samples=<n>)"
                                ))
                            })?);
                        }
                    }
                }
                Ok(StageSpec::Ptq { calib, recalib, samples })
            }
            "mixed" => {
                let mut int4_quantile = None;
                let mut fp16_quantile = None;
                for a in args {
                    match a.split_once('=') {
                        Some(("int4", v)) => {
                            int4_quantile = Some(parse_frac(name, "int4", v)?)
                        }
                        Some(("fp16", v)) => {
                            fp16_quantile = Some(parse_frac(name, "fp16", v)?)
                        }
                        _ => {
                            return Err(Error::hqp(format!(
                                "stage `mixed`: unknown argument `{a}` \
                                 (valid: int4=<pct>, fp16=<pct>)"
                            )))
                        }
                    }
                }
                Ok(StageSpec::Mixed { int4_quantile, fp16_quantile })
            }
            other => Err(Error::hqp(format!(
                "unknown stage `{other}` (valid stages: {})",
                STAGE_NAMES.join(", ")
            ))),
        }
    }

    /// Canonical token — `parse(canonical()) == self`, and only explicit
    /// overrides appear (inherited config values are not part of the
    /// schedule's identity).
    pub fn canonical(&self) -> String {
        let with_args = |name: &str, parts: Vec<String>| -> String {
            if parts.is_empty() {
                name.to_string()
            } else {
                format!("{name}({})", parts.join(","))
            }
        };
        match self {
            StageSpec::MeasureBaseline => "measure-baseline".to_string(),
            StageSpec::Prune { ranking, step_frac, delta_max, max_sparsity, samples } => {
                let mut parts = Vec::new();
                if let Some(r) = ranking {
                    parts.push(r.name().to_string());
                }
                if let Some(s) = step_frac {
                    parts.push(format!("step={}", fmt_pct(*s)));
                }
                if let Some(d) = delta_max {
                    parts.push(format!("dmax={}", fmt_pct(*d)));
                }
                if let Some(m) = max_sparsity {
                    parts.push(format!("max-sparsity={}", fmt_pct(*m)));
                }
                if let Some(n) = samples {
                    parts.push(format!("samples={n}"));
                }
                with_args("prune", parts)
            }
            StageSpec::PruneTo { ranking, theta } => {
                let mut parts = Vec::new();
                if let Some(r) = ranking {
                    parts.push(r.name().to_string());
                }
                parts.push(format!("theta={}", fmt_pct(*theta)));
                with_args("prune-to", parts)
            }
            StageSpec::Ptq { calib, recalib, samples } => {
                let mut parts: Vec<String> =
                    calib.iter().map(|c| c.name().to_string()).collect();
                if *recalib {
                    parts.push("recalib".to_string());
                }
                if let Some(n) = samples {
                    parts.push(format!("samples={n}"));
                }
                with_args("ptq", parts)
            }
            StageSpec::Mixed { int4_quantile, fp16_quantile } => {
                let mut parts = Vec::new();
                if let Some(q) = int4_quantile {
                    parts.push(format!("int4={}", fmt_pct(*q)));
                }
                if let Some(q) = fp16_quantile {
                    parts.push(format!("fp16={}", fmt_pct(*q)));
                }
                with_args("mixed", parts)
            }
        }
    }
}

/// Global-filter-index aliveness under the threaded masks (group offsets
/// from the manifest group specs, exactly the layout `Saliency` ranks in).
fn alive_filters(sess: &Session, masks: &[Vec<bool>]) -> Vec<bool> {
    let total = sess.mm.total_filters();
    let mut alive = vec![true; total];
    for g in &sess.mm.groups {
        for j in 0..g.size {
            alive[g.offset + j] = masks[g.id][j];
        }
    }
    alive
}

/// Drop already-masked filters from a ranking so repeated prune stages
/// spend their δ-budget on live filters (a no-op on an unpruned state —
/// preset schedules are byte-identical to the legacy free functions).
fn retain_alive(mut sal: Saliency, alive: &[bool]) -> Saliency {
    sal.ranking.retain(|&f| alive[f]);
    sal
}

impl Stage for StageSpec {
    fn apply(
        &self,
        sess: &mut Session,
        mut state: StageState,
        cfg: &HqpConfig,
    ) -> Result<StageState> {
        match self {
            StageSpec::MeasureBaseline => {
                let acc = state.baseline(sess, cfg)?;
                if state.accuracy.is_nan() {
                    state.accuracy = acc;
                }
            }
            StageSpec::Prune { ranking, step_frac, delta_max, max_sparsity, samples } => {
                let base_acc = state.baseline(sess, cfg)?;
                let mut c = cfg.clone();
                if let Some(r) = ranking {
                    c.ranking = *r;
                }
                if let Some(s) = step_frac {
                    c.delta_step_frac = *s;
                }
                if let Some(d) = delta_max {
                    c.delta_max = *d;
                }
                if let Some(m) = max_sparsity {
                    c.max_sparsity = *m;
                }
                if let Some(n) = samples {
                    c.calib_samples = *n;
                }
                let sal =
                    sensitivity::compute(sess, &state.params, c.ranking, c.calib_samples)?;
                let sal = retain_alive(sal, &alive_filters(sess, &state.masks));
                let res = conditional_prune(sess, &state.params, base_acc, &sal, &c)?;
                state.params = res.params;
                state.absorb_masks(&res.masks);
                state.trace.steps.extend(res.trace.steps);
                state.accuracy = res.accuracy;
                state.saliency = Some(sal);
                if state.regime == Regime::Int8 {
                    state.requant = true;
                }
            }
            StageSpec::PruneTo { ranking, theta } => {
                let r = ranking.unwrap_or(RankingMethod::MagnitudeL1);
                let sal = sensitivity::compute(sess, &state.params, r, cfg.calib_samples)?;
                let sal = retain_alive(sal, &alive_filters(sess, &state.masks));
                let res = prune_to_sparsity(sess, &state.params, &sal, *theta)?;
                state.params = res.params;
                state.absorb_masks(&res.masks);
                state.trace.steps.extend(res.trace.steps);
                state.accuracy = res.accuracy;
                state.saliency = Some(sal);
                if state.regime == Regime::Int8 {
                    state.requant = true;
                }
            }
            StageSpec::Ptq { calib, recalib, samples } => {
                let mut c = cfg.clone();
                if let Some(m) = calib {
                    c.calib_method = *m;
                }
                let cap = samples.unwrap_or(usize::MAX);
                if *recalib {
                    if state.regime != Regime::Int8 || state.scales.is_none() {
                        return Err(Error::hqp(
                            "stage `ptq(recalib)`: nothing to recalibrate — no prior \
                             ptq stage quantized the model (add a plain `ptq` stage \
                             first)",
                        ));
                    }
                    let r = ptq::recalibrate(sess, &state.params, &c, cap)?;
                    state.scales = Some(r.scales);
                    state.accuracy = r.accuracy;
                    state.requant = false;
                } else {
                    let ptq = ptq::quantize_n(sess, &state.params, &c, cap)?;
                    state.params = ptq.params;
                    state.scales = Some(ptq.scales);
                    state.regime = Regime::Int8;
                    state.accuracy = ptq.accuracy;
                    state.requant = false;
                }
            }
            StageSpec::Mixed { int4_quantile, fp16_quantile } => {
                if state.saliency.is_none() {
                    let sal = sensitivity::compute(
                        sess,
                        &state.params,
                        RankingMethod::Fisher,
                        cfg.calib_samples,
                    )?;
                    state.saliency = Some(sal);
                }
                let default = MixedPolicy::default();
                let policy = MixedPolicy {
                    int4_quantile: int4_quantile.unwrap_or(default.int4_quantile),
                    fp16_quantile: fp16_quantile.unwrap_or(default.fp16_quantile),
                };
                let scores = &state.saliency.as_ref().unwrap().scores;
                state.mixed_plan = Some(mixed::plan(scores, &sess.mm.groups, policy));
            }
        }
        Ok(state)
    }
}

/// An ordered compression pipeline with a canonical string identity.
///
/// Presets carry the legacy method label (so reports and result rows are
/// byte-identical to the pre-schedule API) and the legacy cache-key
/// suffix (so pre-existing `artifacts/results/` files still load — see
/// [`crate::coordinator::run_schedule`]).
#[derive(Clone, Debug, PartialEq)]
pub struct Schedule {
    pub stages: Vec<StageSpec>,
    /// Method label for [`Outcome`]/reports; the canonical string when
    /// `None` (ad-hoc schedules).
    pub label: Option<String>,
    /// Legacy result-cache key *suffix* (`baseline`, `q8`, `p50`,
    /// `hqp`, `hqp_<ranking>`, `hqp_prune`) for pre-schedule caches.
    pub legacy_key: Option<String>,
}

/// Canonical preset names (the legacy method suite).
pub const PRESET_NAMES: &[&str] = &["baseline", "q8-only", "p50-only", "hqp", "hqp-prune", "mixed"];

impl Schedule {
    /// An ad-hoc schedule (canonical-string label, no legacy cache key).
    pub fn new(stages: Vec<StageSpec>) -> Schedule {
        Schedule { stages, label: None, legacy_key: None }
    }

    /// Parse a schedule string (`stage >> stage >> ...`). Errors are loud
    /// and list the valid stage names / arguments.
    pub fn parse(s: &str) -> Result<Schedule> {
        if s.trim().is_empty() {
            return Err(Error::hqp(format!(
                "empty schedule (valid stages: {})",
                STAGE_NAMES.join(", ")
            )));
        }
        let stages = s
            .split(">>")
            .map(StageSpec::parse)
            .collect::<Result<Vec<_>>>()?;
        Ok(Schedule::new(stages))
    }

    /// Resolve a `--schedule` argument: the stage grammar first, then
    /// preset names. Grammar-first keeps stage spellings unambiguous —
    /// `--schedule prune` / `--schedule mixed` mean the *single stage*
    /// (exactly what HELP documents), never the multi-stage preset that
    /// happens to share the name; preset names that are not stages
    /// (`hqp`, `q8-only`, `p50`, …) resolve as presets. On a miss the
    /// grammar's loud error (valid stage list included) is reported.
    pub fn resolve(s: &str, cfg: &HqpConfig) -> Result<Schedule> {
        match Schedule::parse(s) {
            Ok(sched) => Ok(sched),
            Err(parse_err) => Schedule::preset(s.trim(), cfg).ok_or(parse_err),
        }
    }

    /// Named preset lowering of the legacy method suite. Accepts the
    /// legacy `--method` spellings too (`q8`, `p50`, `prune`), plus any
    /// `p<N>`/`p<N>-only` sparsity target.
    pub fn preset(name: &str, cfg: &HqpConfig) -> Option<Schedule> {
        match name {
            "baseline" => Some(Schedule {
                stages: vec![StageSpec::MeasureBaseline],
                label: Some("baseline".into()),
                legacy_key: Some("baseline".into()),
            }),
            "q8" | "q8-only" => Some(Schedule {
                stages: vec![StageSpec::MeasureBaseline, StageSpec::Ptq { calib: None, recalib: false, samples: None }],
                label: Some("q8-only".into()),
                legacy_key: Some("q8".into()),
            }),
            "hqp" => Some(Schedule {
                stages: vec![
                    StageSpec::MeasureBaseline,
                    StageSpec::Prune {
                        ranking: None,
                        step_frac: None,
                        delta_max: None,
                        max_sparsity: None,
                        samples: None,
                    },
                    StageSpec::Ptq { calib: None, recalib: false, samples: None },
                ],
                label: Some("hqp".into()),
                legacy_key: Some("hqp".into()),
            }),
            "prune" | "hqp-prune" => Some(Schedule {
                stages: vec![
                    StageSpec::MeasureBaseline,
                    StageSpec::Prune {
                        ranking: None,
                        step_frac: None,
                        delta_max: None,
                        max_sparsity: None,
                        samples: None,
                    },
                ],
                label: Some(format!("prune-only[{}]", cfg.ranking.name())),
                legacy_key: Some("hqp_prune".into()),
            }),
            "mixed" => Some(Schedule {
                stages: vec![
                    StageSpec::MeasureBaseline,
                    StageSpec::Prune {
                        ranking: None,
                        step_frac: None,
                        delta_max: None,
                        max_sparsity: None,
                        samples: None,
                    },
                    StageSpec::Ptq { calib: None, recalib: false, samples: None },
                    StageSpec::Mixed { int4_quantile: None, fp16_quantile: None },
                ],
                label: Some("mixed".into()),
                legacy_key: None,
            }),
            other => {
                let core = other.strip_suffix("-only").unwrap_or(other);
                let pct: u32 = core.strip_prefix('p')?.parse().ok()?;
                if pct == 0 || pct > 100 {
                    return None;
                }
                Some(Schedule::prune_only_at(pct as f64 / 100.0))
            }
        }
    }

    /// The `p<θ>-only` preset (unconditional magnitude pruning — the
    /// paper's P50 baseline at an arbitrary θ).
    pub fn prune_only_at(theta: f64) -> Schedule {
        Schedule {
            stages: vec![
                StageSpec::MeasureBaseline,
                StageSpec::PruneTo { ranking: Some(RankingMethod::MagnitudeL1), theta },
            ],
            label: Some(format!("p{:02.0}-only", theta * 100.0)),
            legacy_key: Some(format!("p{:.0}", theta * 100.0)),
        }
    }

    /// Canonical string (` >> `-joined canonical stage tokens).
    pub fn canonical(&self) -> String {
        self.stages
            .iter()
            .map(StageSpec::canonical)
            .collect::<Vec<_>>()
            .join(" >> ")
    }

    /// Method label for reports: the preset's legacy name, else the
    /// canonical string.
    pub fn method_label(&self) -> String {
        self.label.clone().unwrap_or_else(|| self.canonical())
    }

    /// Filesystem-safe, injective-over-the-grammar encoding of the
    /// canonical string — the v2 result-cache key suffix
    /// (`prune(fisher,step=1%) >> ptq(kl)` →
    /// `prune.fisher.step-1pct+ptq.kl`). See DESIGN.md §Schedules for
    /// the cache-key versioning story.
    pub fn cache_slug(&self) -> String {
        let mut out = String::new();
        for c in self.canonical().chars() {
            match c {
                ' ' => {}
                '>' => {
                    if !out.ends_with('+') {
                        out.push('+');
                    }
                }
                '(' | ',' => out.push('.'),
                ')' => {}
                '=' => out.push('-'),
                '%' => out.push_str("pct"),
                other => out.push(other),
            }
        }
        out
    }

    /// Run the schedule against a session. Stages execute in order over a
    /// fresh [`StageState`]; see [`finish`] for the final accounting.
    pub fn run(&self, sess: &mut Session, cfg: &HqpConfig) -> Result<Outcome> {
        if self.stages.is_empty() {
            return Err(Error::hqp("empty schedule"));
        }
        let mut state = StageState::fresh(sess);
        for spec in &self.stages {
            state = spec.apply(sess, state, cfg)?;
        }
        finish(sess, state, cfg, self.method_label())
    }
}

/// Finalize a stage pipeline into an [`Outcome`]: re-measure through the
/// INT8 artifact if a post-`ptq` stage left the accuracy stale, ensure
/// A_baseline exists (memoized), and default the accuracy to A_baseline
/// when no stage measured one. Public so custom [`Stage`] pipelines can
/// share the accounting.
pub fn finish(
    sess: &mut Session,
    mut state: StageState,
    cfg: &HqpConfig,
    method: String,
) -> Result<Outcome> {
    if state.requant {
        if let Some(scales) = &state.scales {
            state.accuracy = sess.quant_accuracy(&state.params, scales, &cfg.val_split)?;
        }
        state.requant = false;
    }
    let baseline_acc = match state.baseline_acc {
        Some(a) => a,
        None => sess.baseline_accuracy(&cfg.val_split)?,
    };
    let accuracy = if state.accuracy.is_nan() { baseline_acc } else { state.accuracy };
    Ok(Outcome {
        method,
        model: sess.mm.name.clone(),
        baseline_acc,
        accuracy,
        masks: state.masks,
        sparsity: state.sparsity,
        scales: state.scales,
        params: state.params,
        regime: state.regime,
        trace: state.trace,
        saliency_scores: state.saliency.map(|s| s.scores),
        mixed_plan: state.mixed_plan,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(s: &str) -> Schedule {
        let a = Schedule::parse(s).unwrap();
        let b = Schedule::parse(&a.canonical()).unwrap();
        assert_eq!(a, b, "parse -> canonical -> parse must be identity for {s}");
        assert_eq!(a.canonical(), b.canonical());
        b
    }

    #[test]
    fn parse_canonical_roundtrip() {
        let s = roundtrip("prune(fisher,step=1%,dmax=1.5%) >> ptq(kl)");
        assert_eq!(s.canonical(), "prune(fisher,step=1%,dmax=1.5%) >> ptq(kl)");
        roundtrip("measure-baseline >> prune >> ptq");
        roundtrip("ptq >> prune");
        roundtrip("prune-to(mag-l1,theta=50%)");
        roundtrip("mixed(int4=25%,fp16=90%)");
        roundtrip("prune(max-sparsity=60%,samples=512) >> ptq(samples=256)");
        roundtrip("ptq >> prune >> ptq(recalib)");
        roundtrip("ptq(kl,recalib,samples=1024)");
        // whitespace + plain-fraction spellings normalize
        let a = Schedule::parse("  prune( fisher , dmax=0.015 )>>ptq ").unwrap();
        assert_eq!(a.canonical(), "prune(fisher,dmax=1.5%) >> ptq");
    }

    #[test]
    fn quantize_first_is_expressible() {
        // the ordering the closed enum could not express — the paper's
        // §V-B ablation axis
        let s = Schedule::parse("ptq >> prune").unwrap();
        assert_eq!(
            s.stages,
            vec![
                StageSpec::Ptq { calib: None, recalib: false, samples: None },
                StageSpec::Prune {
                    ranking: None,
                    step_frac: None,
                    delta_max: None,
                    max_sparsity: None,
                    samples: None,
                },
            ]
        );
    }

    #[test]
    fn unknown_stage_is_loud() {
        let e = Schedule::parse("sprune(fisher)").unwrap_err().to_string();
        assert!(e.contains("unknown stage"), "{e}");
        assert!(e.contains("valid stages"), "{e}");
        for name in STAGE_NAMES {
            assert!(e.contains(name), "error must list `{name}`: {e}");
        }
    }

    #[test]
    fn bad_arguments_are_loud() {
        assert!(Schedule::parse("").is_err());
        assert!(Schedule::parse("prune >>").is_err());
        assert!(Schedule::parse("prune(step=banana)").is_err());
        assert!(Schedule::parse("prune(steep=1%)").is_err());
        assert!(Schedule::parse("prune(fisher,mag-l1)").is_err());
        assert!(Schedule::parse("prune(ranking)").is_err());
        assert!(Schedule::parse("prune(step=150%)").is_err());
        assert!(Schedule::parse("prune-to").is_err(), "theta is required");
        assert!(Schedule::parse("prune-to(theta=0%)").is_err());
        assert!(Schedule::parse("ptq(kl,minmax)").is_err());
        assert!(Schedule::parse("ptq(qat)").is_err());
        assert!(Schedule::parse("ptq(recalib,recalib)").is_err());
        assert!(Schedule::parse("ptq(samples=0)").is_err());
        assert!(Schedule::parse("ptq(samples=many)").is_err());
        assert!(Schedule::parse("ptq(split=test)").is_err());
        assert!(Schedule::parse("prune(samples=0)").is_err());
        assert!(Schedule::parse("prune(max-sparsity=0%)").is_err());
        assert!(Schedule::parse("prune(max-sparsity=101%)").is_err());
        assert!(Schedule::parse("mixed(int8=50%)").is_err());
        assert!(Schedule::parse("measure-baseline(x)").is_err());
        assert!(Schedule::parse("prune(fisher").is_err(), "unbalanced paren");
    }

    #[test]
    fn per_stage_knobs_parse_and_canonicalize() {
        // argument order in the source is free; canonical order is fixed
        let s = Schedule::parse("prune(samples=512,max-sparsity=0.6,fisher)").unwrap();
        assert_eq!(s.canonical(), "prune(fisher,max-sparsity=60%,samples=512)");
        assert_eq!(
            s.stages,
            vec![StageSpec::Prune {
                ranking: Some(RankingMethod::Fisher),
                step_frac: None,
                delta_max: None,
                max_sparsity: Some(0.6),
                samples: Some(512),
            }]
        );
        let s = Schedule::parse("ptq(samples=256,recalib,minmax)").unwrap();
        assert_eq!(s.canonical(), "ptq(minmax,recalib,samples=256)");
        assert_eq!(
            s.stages,
            vec![StageSpec::Ptq {
                calib: Some(CalibMethod::MinMax),
                recalib: true,
                samples: Some(256),
            }]
        );
        // unknown ptq arguments must advertise the new valid set
        let e = Schedule::parse("ptq(split=test)").unwrap_err().to_string();
        assert!(e.contains("recalib"), "{e}");
        assert!(e.contains("samples=<n>"), "{e}");
        // the new knobs are part of the schedule's cache identity
        assert_ne!(
            Schedule::parse("ptq").unwrap().cache_slug(),
            Schedule::parse("ptq(samples=256)").unwrap().cache_slug()
        );
        assert_eq!(
            Schedule::parse("prune(max-sparsity=60%,samples=512) >> ptq(recalib)")
                .unwrap()
                .cache_slug(),
            "prune.max-sparsity-60pct.samples-512+ptq.recalib"
        );
    }

    #[test]
    fn presets_lower_to_legacy_labels_and_keys() {
        let cfg = HqpConfig::default();
        let cases: &[(&str, &str, &str, Option<&str>)] = &[
            ("baseline", "baseline", "measure-baseline", Some("baseline")),
            ("q8", "q8-only", "measure-baseline >> ptq", Some("q8")),
            ("q8-only", "q8-only", "measure-baseline >> ptq", Some("q8")),
            (
                "p50",
                "p50-only",
                "measure-baseline >> prune-to(mag-l1,theta=50%)",
                Some("p50"),
            ),
            ("hqp", "hqp", "measure-baseline >> prune >> ptq", Some("hqp")),
            (
                "hqp-prune",
                "prune-only[fisher]",
                "measure-baseline >> prune",
                Some("hqp_prune"),
            ),
            (
                "mixed",
                "mixed",
                "measure-baseline >> prune >> ptq >> mixed",
                None,
            ),
        ];
        for (name, label, canonical, legacy) in cases {
            let s = Schedule::preset(name, &cfg)
                .unwrap_or_else(|| panic!("preset {name} must exist"));
            assert_eq!(s.method_label(), *label, "{name}");
            assert_eq!(s.canonical(), *canonical, "{name}");
            assert_eq!(s.legacy_key.as_deref(), *legacy, "{name}");
            // a preset's canonical string re-parses to the same stages
            assert_eq!(Schedule::parse(&s.canonical()).unwrap().stages, s.stages);
        }
        assert!(Schedule::preset("p0", &cfg).is_none());
        assert!(Schedule::preset("p101", &cfg).is_none());
        assert!(Schedule::preset("qat", &cfg).is_none());
        // the ranking-sensitive label follows the config
        let mut c = cfg.clone();
        c.ranking = RankingMethod::MagnitudeL2;
        assert_eq!(
            Schedule::preset("prune", &c).unwrap().method_label(),
            "prune-only[mag-l2]"
        );
    }

    #[test]
    fn cache_slugs_are_distinct_and_filesystem_safe() {
        let cfg = HqpConfig::default();
        let mut slugs: Vec<String> = PRESET_NAMES
            .iter()
            .map(|n| Schedule::preset(n, &cfg).unwrap().cache_slug())
            .collect();
        slugs.push(Schedule::parse("prune >> ptq").unwrap().cache_slug());
        slugs.push(Schedule::parse("ptq >> prune").unwrap().cache_slug());
        slugs.push(
            Schedule::parse("prune(fisher,step=1%,dmax=1.5%) >> ptq(kl)")
                .unwrap()
                .cache_slug(),
        );
        for s in &slugs {
            assert!(
                s.chars().all(|c| c.is_ascii_alphanumeric() || "+-._".contains(c)),
                "slug `{s}` must be filesystem-safe"
            );
        }
        let mut dedup = slugs.clone();
        dedup.sort();
        dedup.dedup();
        assert_eq!(dedup.len(), slugs.len(), "slugs must not collide: {slugs:?}");
        assert_eq!(
            Schedule::parse("prune(fisher,step=1%,dmax=1.5%) >> ptq(kl)")
                .unwrap()
                .cache_slug(),
            "prune.fisher.step-1pct.dmax-1.5pct+ptq.kl"
        );
    }

    #[test]
    fn resolve_grammar_first_then_presets() {
        let cfg = HqpConfig::default();
        // preset names that are not stages resolve as presets
        assert_eq!(Schedule::resolve("hqp", &cfg).unwrap().method_label(), "hqp");
        assert_eq!(
            Schedule::resolve("p50", &cfg).unwrap().method_label(),
            "p50-only"
        );
        assert_eq!(
            Schedule::resolve("hqp-prune", &cfg).unwrap().method_label(),
            "prune-only[fisher]"
        );
        // stage spellings always mean the single stage, never the
        // same-named preset (HELP documents them as stages)
        assert_eq!(
            Schedule::resolve("prune", &cfg).unwrap().stages,
            vec![StageSpec::Prune {
                ranking: None,
                step_frac: None,
                delta_max: None,
                max_sparsity: None,
                samples: None,
            }]
        );
        assert_eq!(
            Schedule::resolve("mixed", &cfg).unwrap().stages,
            vec![StageSpec::Mixed { int4_quantile: None, fp16_quantile: None }]
        );
        let adhoc = Schedule::resolve("ptq >> prune", &cfg).unwrap();
        assert_eq!(adhoc.method_label(), "ptq >> prune");
        assert!(adhoc.legacy_key.is_none());
        // a miss reports the grammar's loud error
        let e = Schedule::resolve("sprune", &cfg).unwrap_err().to_string();
        assert!(e.contains("valid stages"), "{e}");
    }

    #[test]
    fn percent_tokens_round_trip_verbatim() {
        // fmt_pct must print what the user typed, not the f64 rounding
        // artifact of v*100 (7% used to canonicalize — and cache-key —
        // as 7.000000000000001%)
        for s in ["7%", "29%", "1.5%", "0.5%", "3.25%", "100%"] {
            let src = format!("prune(dmax={s})");
            let sched = Schedule::parse(&src).unwrap();
            assert_eq!(sched.canonical(), src, "typed percent must survive verbatim");
        }
    }
}
