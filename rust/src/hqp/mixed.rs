//! Mixed-precision extension (paper §VI-A, "Future Work" — implemented
//! here): drive per-group precision from the Fisher sensitivity S.
//!
//! Groups in the lowest-S quantile drop to INT4, the highest-S quantile is
//! preserved at FP16, everything else deploys INT8 — "maximizing speedup
//! while preserving fidelity at the most critical points in the network".

use std::collections::HashMap;

use crate::gopt::PrecisionPlan;
use crate::hwsim::Precision;
use crate::runtime::GroupSpec;

use super::sensitivity::per_group_mean;

/// Quantile thresholds for the 3-tier assignment.
#[derive(Clone, Copy, Debug)]
pub struct MixedPolicy {
    /// Groups below this S-quantile go INT4.
    pub int4_quantile: f64,
    /// Groups above this S-quantile stay FP16.
    pub fp16_quantile: f64,
}

impl Default for MixedPolicy {
    fn default() -> Self {
        MixedPolicy { int4_quantile: 0.25, fp16_quantile: 0.90 }
    }
}

/// Build the per-group precision plan from Fisher scores.
pub fn plan(scores: &[f32], groups: &[GroupSpec], policy: MixedPolicy) -> PrecisionPlan {
    let means = per_group_mean(scores, groups);
    let mut sorted = means.clone();
    sorted.sort_by(f32::total_cmp);
    let q = |frac: f64| -> f32 {
        if sorted.is_empty() {
            return 0.0;
        }
        let idx = ((sorted.len() - 1) as f64 * frac).round() as usize;
        sorted[idx]
    };
    let lo = q(policy.int4_quantile);
    let hi = q(policy.fp16_quantile);

    let mut per_group = HashMap::new();
    for (g, &m) in groups.iter().zip(&means) {
        let p = if m <= lo {
            Precision::Int4
        } else if m >= hi {
            Precision::Fp16
        } else {
            Precision::Int8
        };
        per_group.insert(g.id, p);
    }
    PrecisionPlan { compute: Precision::Int8, per_group }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn groups(n: usize) -> Vec<GroupSpec> {
        (0..n)
            .map(|i| GroupSpec {
                id: i,
                name: format!("g{i}"),
                size: 2,
                offset: i * 2,
                members: vec![],
                producer: format!("g{i}.w"),
                producer_axis: 3,
            })
            .collect()
    }

    #[test]
    fn tiers_assigned_by_quantile() {
        let g = groups(10);
        // group i has score i (each filter = i)
        let scores: Vec<f32> = (0..10).flat_map(|i| [i as f32, i as f32]).collect();
        let p = plan(&scores, &g, MixedPolicy::default());
        assert_eq!(p.per_group[&0], Precision::Int4, "lowest-S -> int4");
        assert_eq!(p.per_group[&9], Precision::Fp16, "highest-S -> fp16");
        assert_eq!(p.per_group[&5], Precision::Int8);
    }

    #[test]
    fn degenerate_uniform_scores() {
        let g = groups(4);
        let scores = vec![1.0f32; 8];
        let p = plan(&scores, &g, MixedPolicy::default());
        // all equal: every group matches both thresholds -> int4 wins the
        // <= check; the point is it must not panic and must cover all groups
        assert_eq!(p.per_group.len(), 4);
    }
}
