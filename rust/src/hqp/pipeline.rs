//! The method suite of the paper's evaluation: Baseline (FP32), Q8-only,
//! P50-only, and HQP — each producing an [`Outcome`] with *measured*
//! accuracy (through the PJRT artifacts) and the filter masks + scales
//! that define the deployable engine.
//!
//! Every method here shares one [`Session`], so the incremental parameter
//! buffer cache carries across phases: the baseline-accuracy pass warms the
//! device copy of M_train, the conditional loop re-uploads only each
//! candidate's δ-masked tensors, and its validation sweeps early-exit via
//! `Session::accuracy_bounded` (see `runtime::session` §Perf).

use crate::error::Result;
use crate::runtime::{ParamStore, Session};

use super::prune::{conditional_prune, prune_to_sparsity, PruneTrace};
use super::ptq::quantize;
use super::sensitivity::{self, RankingMethod};
use super::HqpConfig;

/// Numeric regime of the deployed engine an outcome describes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Regime {
    Fp32,
    Int8,
}

/// The outcome of one compression method on one model.
pub struct Outcome {
    pub method: String,
    pub model: String,
    /// Baseline FP32 validation accuracy (A_baseline).
    pub baseline_acc: f64,
    /// Final measured validation accuracy of the produced model.
    pub accuracy: f64,
    /// Per-group keep-masks (all-true when unpruned).
    pub masks: Vec<Vec<bool>>,
    /// Sparsity θ over filters.
    pub sparsity: f64,
    /// Activation scales when the engine is INT8.
    pub scales: Option<Vec<f32>>,
    /// Final parameters (masked and/or on the INT8 grid).
    pub params: ParamStore,
    pub regime: Regime,
    /// Pruning trajectory (empty for quantize-only methods).
    pub trace: PruneTrace,
    /// Fisher scores (kept for the layer-wise analysis / mixed precision).
    pub saliency_scores: Option<Vec<f32>>,
}

impl Outcome {
    /// Absolute Top-1 drop vs baseline.
    pub fn acc_drop(&self) -> f64 {
        self.baseline_acc - self.accuracy
    }

    /// Compliance with the Δ_max constraint.
    pub fn compliant(&self, delta_max: f64) -> bool {
        self.acc_drop() <= delta_max + 1e-9
    }

    fn full_masks(sess: &Session) -> Vec<Vec<bool>> {
        sess.mm.groups.iter().map(|g| vec![true; g.size]).collect()
    }
}

/// Baseline (FP32): measure A_baseline, no compression.
pub fn run_baseline(sess: &mut Session) -> Result<Outcome> {
    let params = sess.baseline.clone();
    let acc = sess.accuracy(&params, "val")?;
    Ok(Outcome {
        method: "baseline".into(),
        model: sess.mm.name.clone(),
        baseline_acc: acc,
        accuracy: acc,
        masks: Outcome::full_masks(sess),
        sparsity: 0.0,
        scales: None,
        params,
        regime: Regime::Fp32,
        trace: PruneTrace::default(),
        saliency_scores: None,
    })
}

/// Q8-only: direct PTQ of M_train — the paper's quantization baseline
/// (the one that fails on ResNet-18 without pruning pre-conditioning).
pub fn run_q8(sess: &mut Session, cfg: &HqpConfig) -> Result<Outcome> {
    let baseline = sess.baseline.clone(); // O(slots) copy-on-write
    let baseline_acc = sess.accuracy(&baseline, "val")?;
    let ptq = quantize(sess, &baseline, cfg)?;
    Ok(Outcome {
        method: "q8-only".into(),
        model: sess.mm.name.clone(),
        baseline_acc,
        accuracy: ptq.accuracy,
        masks: Outcome::full_masks(sess),
        sparsity: 0.0,
        scales: Some(ptq.scales),
        params: ptq.params,
        regime: Regime::Int8,
        trace: PruneTrace::default(),
        saliency_scores: None,
    })
}

/// P50-only: magnitude (L1) pruning straight to 50 % sparsity, FP32, no
/// quality guarantee — the paper's pruning baseline (violates Δ_max).
pub fn run_p50(sess: &mut Session, theta: f64) -> Result<Outcome> {
    let baseline = sess.baseline.clone();
    let baseline_acc = sess.accuracy(&baseline, "val")?;
    let sal = sensitivity::compute(sess, &baseline, RankingMethod::MagnitudeL1, 0)?;
    let res = prune_to_sparsity(sess, &baseline, &sal, theta)?;
    Ok(Outcome {
        method: format!("p{:02.0}-only", theta * 100.0),
        model: sess.mm.name.clone(),
        baseline_acc,
        accuracy: res.accuracy,
        masks: res.masks,
        sparsity: res.sparsity,
        scales: None,
        params: res.params,
        regime: Regime::Fp32,
        trace: res.trace,
        saliency_scores: Some(sal.scores),
    })
}

/// HQP: M_o = Q(P(M_train, τ, Δ_max), b) — the paper's framework.
///
/// Phase 1-A: Fisher saliency (one backward pass over D_calib).
/// Phase 1-B: Algorithm 1 conditional loop under Δ_max.
/// Phase 2:   robust PTQ (KL calibration) of M_sparse.
pub fn run_hqp(sess: &mut Session, cfg: &HqpConfig) -> Result<Outcome> {
    let baseline = sess.baseline.clone();
    let baseline_acc = sess.accuracy(&baseline, "val")?;

    let sal = sensitivity::compute(sess, &baseline, cfg.ranking, cfg.calib_samples)?;
    let pruned = conditional_prune(sess, &baseline, baseline_acc, &sal, cfg)?;
    let ptq = quantize(sess, &pruned.params, cfg)?;

    Ok(Outcome {
        method: "hqp".into(),
        model: sess.mm.name.clone(),
        baseline_acc,
        accuracy: ptq.accuracy,
        masks: pruned.masks,
        sparsity: pruned.sparsity,
        scales: Some(ptq.scales),
        params: ptq.params,
        regime: Regime::Int8,
        trace: pruned.trace,
        saliency_scores: Some(sal.scores),
    })
}

/// Pruning-only variant of HQP (ablation: isolates Phase 1 from Phase 2;
/// also the "M_sparse" row of the sparsity–accuracy analysis).
pub fn run_hqp_prune_only(sess: &mut Session, cfg: &HqpConfig) -> Result<Outcome> {
    let baseline = sess.baseline.clone();
    let baseline_acc = sess.accuracy(&baseline, "val")?;
    let sal = sensitivity::compute(sess, &baseline, cfg.ranking, cfg.calib_samples)?;
    let pruned = conditional_prune(sess, &baseline, baseline_acc, &sal, cfg)?;
    Ok(Outcome {
        method: format!("prune-only[{}]", cfg.ranking.name()),
        model: sess.mm.name.clone(),
        baseline_acc,
        accuracy: pruned.accuracy,
        masks: pruned.masks,
        sparsity: pruned.sparsity,
        scales: None,
        params: pruned.params,
        regime: Regime::Fp32,
        trace: pruned.trace,
        saliency_scores: Some(sal.scores),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn regime_and_compliance_logic() {
        // Outcome invariants that don't need artifacts.
        assert_eq!(Regime::Fp32, Regime::Fp32);
        let o = Outcome {
            method: "x".into(),
            model: "m".into(),
            baseline_acc: 0.9,
            accuracy: 0.889,
            masks: vec![],
            sparsity: 0.3,
            scales: None,
            params: ParamStore::from_tensors(vec![]),
            regime: Regime::Fp32,
            trace: PruneTrace::default(),
            saliency_scores: None,
        };
        assert!((o.acc_drop() - 0.011).abs() < 1e-12);
        assert!(o.compliant(0.015));
        assert!(!o.compliant(0.010));
    }
}
