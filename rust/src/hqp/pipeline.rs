//! The method suite of the paper's evaluation — Baseline (FP32), Q8-only,
//! P50-only, and HQP — expressed as named [`Schedule`] presets
//! (see [`super::schedule`]), each producing an [`Outcome`] with
//! *measured* accuracy (through the PJRT artifacts) and the filter masks
//! + scales that define the deployable engine.
//!
//! The free functions below are thin compatibility wrappers: each lowers
//! to its preset schedule and runs it, so `run_hqp` and
//! `Schedule::preset("hqp", ..)` are the same computation by construction
//! (property-tested in `tests/integration_pipeline.rs`).
//!
//! Every method here shares one [`Session`], so the incremental parameter
//! buffer cache carries across phases: the (memoized) baseline-accuracy
//! pass warms the device copy of M_train, the conditional loop re-uploads
//! only each candidate's δ-masked tensors, and its validation sweeps
//! early-exit via `Session::accuracy_bounded` (see `runtime::session`
//! §Perf).

use crate::error::Result;
use crate::gopt::PrecisionPlan;
use crate::runtime::{ParamStore, Session};

use super::prune::PruneTrace;
use super::schedule::Schedule;
use super::HqpConfig;

/// Numeric regime of the deployed engine an outcome describes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Regime {
    Fp32,
    Int8,
}

/// The outcome of one compression schedule on one model.
pub struct Outcome {
    pub method: String,
    pub model: String,
    /// Baseline FP32 validation accuracy (A_baseline).
    pub baseline_acc: f64,
    /// Final measured validation accuracy of the produced model.
    pub accuracy: f64,
    /// Per-group keep-masks (all-true when unpruned).
    pub masks: Vec<Vec<bool>>,
    /// Sparsity θ over filters.
    pub sparsity: f64,
    /// Activation scales when the engine is INT8.
    pub scales: Option<Vec<f32>>,
    /// Final parameters (masked and/or on the INT8 grid).
    pub params: ParamStore,
    pub regime: Regime,
    /// Pruning trajectory (empty for quantize-only methods).
    pub trace: PruneTrace,
    /// Fisher scores (kept for the layer-wise analysis / mixed precision).
    pub saliency_scores: Option<Vec<f32>>,
    /// §VI-A per-group precision plan, when a `mixed` stage ran
    /// ([`crate::hqp::deploy`] lowers it into the engine).
    pub mixed_plan: Option<PrecisionPlan>,
}

impl Outcome {
    /// Absolute Top-1 drop vs baseline.
    pub fn acc_drop(&self) -> f64 {
        self.baseline_acc - self.accuracy
    }

    /// Compliance with the Δ_max constraint.
    pub fn compliant(&self, delta_max: f64) -> bool {
        self.acc_drop() <= delta_max + 1e-9
    }
}

/// Baseline (FP32): measure A_baseline, no compression.
pub fn run_baseline(sess: &mut Session) -> Result<Outcome> {
    let cfg = HqpConfig::default();
    Schedule::preset("baseline", &cfg).unwrap().run(sess, &cfg)
}

/// Q8-only: direct PTQ of M_train — the paper's quantization baseline
/// (the one that fails on ResNet-18 without pruning pre-conditioning).
pub fn run_q8(sess: &mut Session, cfg: &HqpConfig) -> Result<Outcome> {
    Schedule::preset("q8-only", cfg).unwrap().run(sess, cfg)
}

/// P50-only: magnitude (L1) pruning straight to sparsity θ, FP32, no
/// quality guarantee — the paper's pruning baseline (violates Δ_max).
pub fn run_p50(sess: &mut Session, theta: f64) -> Result<Outcome> {
    let cfg = HqpConfig::default();
    Schedule::prune_only_at(theta).run(sess, &cfg)
}

/// HQP: M_o = Q(P(M_train, τ, Δ_max), b) — the paper's framework, i.e.
/// the `measure-baseline >> prune >> ptq` schedule:
///
/// Phase 1-A: Fisher saliency (one backward pass over D_calib).
/// Phase 1-B: Algorithm 1 conditional loop under Δ_max.
/// Phase 2:   robust PTQ (KL calibration) of M_sparse.
pub fn run_hqp(sess: &mut Session, cfg: &HqpConfig) -> Result<Outcome> {
    Schedule::preset("hqp", cfg).unwrap().run(sess, cfg)
}

/// Pruning-only variant of HQP (ablation: isolates Phase 1 from Phase 2;
/// also the "M_sparse" row of the sparsity–accuracy analysis).
pub fn run_hqp_prune_only(sess: &mut Session, cfg: &HqpConfig) -> Result<Outcome> {
    Schedule::preset("hqp-prune", cfg).unwrap().run(sess, cfg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn regime_and_compliance_logic() {
        // Outcome invariants that don't need artifacts.
        assert_eq!(Regime::Fp32, Regime::Fp32);
        let o = Outcome {
            method: "x".into(),
            model: "m".into(),
            baseline_acc: 0.9,
            accuracy: 0.889,
            masks: vec![],
            sparsity: 0.3,
            scales: None,
            params: ParamStore::from_tensors(vec![]),
            regime: Regime::Fp32,
            trace: PruneTrace::default(),
            saliency_scores: None,
            mixed_plan: None,
        };
        assert!((o.acc_drop() - 0.011).abs() < 1e-12);
        assert!(o.compliant(0.015));
        assert!(!o.compliant(0.010));
    }
}
