//! Filter saliency and the ranked list ℛ (Algorithm 1, lines 6–8).
//!
//! HQP ranks by the diagonal-FIM sensitivity
//! `S_f = (1/|D|) Σ_i ||∂L_i/∂W_f||²` computed by the `fisher` artifact
//! (per-sample grads → Pallas reduction). The second-generation baselines
//! the paper critiques (§II-A) are implemented alongside: L1/L2 filter
//! magnitude and BN-γ scaling, plus a seeded random ranking as the
//! control.

use crate::error::Result;
use crate::runtime::{ParamStore, Session};
use crate::testkit::prng::Prng;

/// Filter-ranking strategy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RankingMethod {
    /// HQP: diagonal Fisher Information (second-order, globally aware).
    Fisher,
    /// Smallest L1 filter norm first (Li & Sifre, ICLR'17).
    MagnitudeL1,
    /// Smallest L2 filter norm first.
    MagnitudeL2,
    /// Smallest |BN γ| first (Network-Slimming-style).
    BnGamma,
    /// Seeded random order (control).
    Random(u64),
}

impl RankingMethod {
    pub fn name(&self) -> &'static str {
        match self {
            RankingMethod::Fisher => "fisher",
            RankingMethod::MagnitudeL1 => "mag-l1",
            RankingMethod::MagnitudeL2 => "mag-l2",
            RankingMethod::BnGamma => "bn-gamma",
            RankingMethod::Random(_) => "random",
        }
    }

    pub fn parse(s: &str) -> Option<RankingMethod> {
        match s {
            "fisher" => Some(RankingMethod::Fisher),
            "mag-l1" | "l1" => Some(RankingMethod::MagnitudeL1),
            "mag-l2" | "l2" => Some(RankingMethod::MagnitudeL2),
            "bn-gamma" | "bn" => Some(RankingMethod::BnGamma),
            "random" => Some(RankingMethod::Random(0)),
            _ => None,
        }
    }
}

/// Per-filter scores in global filter-index space (group offsets from the
/// manifest), plus the ascending ranking ℛ.
#[derive(Clone, Debug)]
pub struct Saliency {
    pub method: &'static str,
    /// score[global_filter_index]
    pub scores: Vec<f32>,
    /// Global filter indices, ascending score — Algorithm 1's ℛ.
    pub ranking: Vec<usize>,
}

/// Compute scores for every filter under `method`.
///
/// Fisher runs the backward-pass artifact over the calibration split (the
/// paper's "single backward pass over D_calib"); when `params` is the same
/// (unmutated) store a previous measurement warmed, the session's
/// version-keyed buffer cache makes this pass upload-free. The
/// magnitude/BN-γ heuristics read the parameter store directly (no data
/// needed — exactly why the paper calls them cheap but myopic).
pub fn compute(
    sess: &mut Session,
    params: &ParamStore,
    method: RankingMethod,
    calib_samples: usize,
) -> Result<Saliency> {
    let mm = sess.mm.clone();
    let scores: Vec<f32> = match method {
        RankingMethod::Fisher => sess.fisher_scores(params, calib_samples)?,
        RankingMethod::MagnitudeL1 | RankingMethod::MagnitudeL2 => {
            let l1 = method == RankingMethod::MagnitudeL1;
            let mut v = vec![0f32; mm.total_filters()];
            for g in &mm.groups {
                let w = params.get(&g.producer)?;
                for j in 0..g.size {
                    v[g.offset + j] = w.slice_norm(g.producer_axis, j, l1)?;
                }
            }
            v
        }
        RankingMethod::BnGamma => {
            let mut v = vec![0f32; mm.total_filters()];
            for g in &mm.groups {
                // find this group's BN gamma among members; groups without
                // a BN (SE fc1) fall back to producer L1 norm.
                let gamma = g
                    .members
                    .iter()
                    .find(|(name, _)| name.ends_with(".gamma"))
                    .map(|(name, _)| name.clone());
                match gamma {
                    Some(name) => {
                        let t = params.get(&name)?;
                        for j in 0..g.size {
                            v[g.offset + j] = t.data()[j].abs();
                        }
                    }
                    None => {
                        let w = params.get(&g.producer)?;
                        for j in 0..g.size {
                            v[g.offset + j] = w.slice_norm(g.producer_axis, j, true)?;
                        }
                    }
                }
            }
            v
        }
        RankingMethod::Random(seed) => {
            let mut rng = Prng::new(seed ^ 0x5EED);
            (0..mm.total_filters()).map(|_| rng.next_f32()).collect()
        }
    };

    let mut ranking: Vec<usize> = (0..scores.len()).collect();
    ranking.sort_by(|&a, &b| scores[a].total_cmp(&scores[b]));
    Ok(Saliency { method: method.name(), scores, ranking })
}

/// Mean score per group (the §V-C layer-wise analysis input).
pub fn per_group_mean(scores: &[f32], groups: &[crate::runtime::GroupSpec]) -> Vec<f32> {
    groups
        .iter()
        .map(|g| {
            let s: f32 = scores[g.offset..g.offset + g.size].iter().sum();
            s / g.size.max(1) as f32
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ranking_is_ascending() {
        let scores = vec![3.0f32, 1.0, 2.0];
        let mut ranking: Vec<usize> = (0..3).collect();
        ranking.sort_by(|&a, &b| scores[a].total_cmp(&scores[b]));
        assert_eq!(ranking, vec![1, 2, 0]);
    }

    #[test]
    fn method_parse_roundtrip() {
        for m in [
            RankingMethod::Fisher,
            RankingMethod::MagnitudeL1,
            RankingMethod::MagnitudeL2,
            RankingMethod::BnGamma,
        ] {
            assert_eq!(RankingMethod::parse(m.name()).unwrap(), m);
        }
        assert!(matches!(
            RankingMethod::parse("random"),
            Some(RankingMethod::Random(_))
        ));
        assert!(RankingMethod::parse("nope").is_none());
    }

    #[test]
    fn per_group_mean_respects_offsets() {
        use crate::runtime::GroupSpec;
        let groups = vec![
            GroupSpec {
                id: 0, name: "a".into(), size: 2, offset: 0,
                members: vec![], producer: "a.w".into(), producer_axis: 3,
            },
            GroupSpec {
                id: 1, name: "b".into(), size: 3, offset: 2,
                members: vec![], producer: "b.w".into(), producer_axis: 3,
            },
        ];
        let scores = vec![1.0, 3.0, 6.0, 6.0, 6.0];
        assert_eq!(per_group_mean(&scores, &groups), vec![2.0, 6.0]);
    }
}
