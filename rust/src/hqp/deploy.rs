//! Deployment reporting: lower an [`Outcome`] through the graph optimizer
//! onto a device model, producing the rows of the paper's Tables I/II.

use crate::error::Result;
use crate::gopt::{optimize, OptimizeOptions, OptimizedGraph, PrecisionPlan};
use crate::graph::Graph;
use crate::hwsim::{simulate, Device};

use super::pipeline::{Outcome, Regime};

/// One table row: method × device.
#[derive(Clone, Debug)]
pub struct MethodReport {
    pub method: String,
    pub model: String,
    pub device: String,
    pub latency_ms: f64,
    /// vs the FP32 baseline engine on the same device.
    pub speedup: f64,
    /// 1 − deployed_bytes / dense_fp32_bytes.
    pub size_reduction: f64,
    /// Absolute Top-1 drop (measured through PJRT).
    pub acc_drop: f64,
    /// Filter sparsity θ.
    pub sparsity: f64,
    /// Compliance with Δ_max.
    pub compliant: bool,
    /// Energy per inference (mJ) and its ratio vs baseline (≡ speedup).
    pub energy_mj: f64,
    pub energy_ratio: f64,
    /// Deployed engine FLOPs (diagnostics).
    pub flops: u64,
}

/// Build the deployed engine for an outcome.
pub fn engine(graph: &Graph, outcome: &Outcome, mixed: Option<PrecisionPlan>) -> Result<OptimizedGraph> {
    let mut opts = match outcome.regime {
        Regime::Fp32 => OptimizeOptions::fp32(),
        Regime::Int8 => OptimizeOptions::int8(),
    };
    if let Some(plan) = mixed {
        opts.precision = plan;
    }
    optimize(graph, &outcome.masks, &opts)
}

/// Produce the table row for `outcome` on `dev`, normalizing against the
/// FP32 dense baseline engine on the same device. An outcome carrying a
/// `mixed` stage's precision plan is lowered with it (None for every
/// legacy method — their rows are byte-identical to the pre-schedule API).
pub fn report(
    graph: &Graph,
    outcome: &Outcome,
    dev: &Device,
    delta_max: f64,
) -> Result<MethodReport> {
    let base_engine = optimize(graph, &crate::graph::full_masks(graph), &OptimizeOptions::fp32())?;
    let base_sim = simulate(&base_engine, dev);

    let eng = engine(graph, outcome, outcome.mixed_plan.clone())?;
    let sim = simulate(&eng, dev);

    Ok(MethodReport {
        method: outcome.method.clone(),
        model: outcome.model.clone(),
        device: dev.name.clone(),
        latency_ms: sim.latency_ms,
        speedup: base_sim.latency_ms / sim.latency_ms,
        size_reduction: eng.size_reduction(),
        acc_drop: outcome.acc_drop(),
        sparsity: outcome.sparsity,
        compliant: outcome.compliant(delta_max),
        energy_mj: sim.energy_mj,
        energy_ratio: base_sim.energy_mj / sim.energy_mj,
        flops: eng.flops(),
    })
}

#[cfg(test)]
mod tests {
    // Exercised end-to-end in rust/tests/integration_pipeline.rs and the
    // table benches; the pieces (optimize, simulate) carry their own units.
}
