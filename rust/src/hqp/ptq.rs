//! HQP Phase 2 — robust post-training quantization (paper §IV-B).
//!
//! Two calibration passes over D_calib through the AOT artifacts:
//!   1. `absmax`  → per-tap dynamic ranges,
//!   2. `hist`    → per-tap 2048-bin |activation| histograms,
//! then the Rust-side [`crate::quant::Calibrator`] picks each tap's
//! saturation threshold (KL-divergence by default — the TensorRT recipe),
//! and every conv/FC weight tensor is projected onto its symmetric INT8
//! grid (per-tensor scales by default, matching the paper's §II-C "global
//! scaling factor" formulation; per-channel available as an ablation).
//!
//! The quantized model's accuracy is then *measured* through the
//! `quant_eval` artifact — the INT8 numerics run for real (Pallas qmatmul),
//! only the INT8 *speed* comes from [`crate::hwsim`].

use crate::error::Result;
use crate::quant::{quantize_per_channel, quantize_per_tensor, Calibrator, dequantize};
use crate::runtime::{ParamStore, Session};

use super::HqpConfig;

/// Result of the PTQ phase.
pub struct PtqResult {
    /// Weights projected onto the INT8 grid (values = code × scale).
    pub params: ParamStore,
    /// Per-tap activation scales (feeds the quant_eval artifact / engine).
    pub scales: Vec<f32>,
    /// Per-tap saturation thresholds chosen by calibration (diagnostics:
    /// the "dynamic range R" the paper's conflict story is about).
    pub thresholds: Vec<f32>,
    /// Accuracy of the quantized model on the validation split.
    pub accuracy: f64,
}

/// Which parameters get quantized: conv/fc weights (".w"). BN parameters
/// and biases stay FP32/FP16 in deployed engines (folded or negligible),
/// exactly as TensorRT does.
fn quantizable(name: &str) -> bool {
    name.ends_with(".w")
}

/// Activation scales + thresholds after a recalibration-only pass
/// (`ptq(recalib)` — no weight projection, see [`recalibrate`]).
pub struct RecalibResult {
    /// Fresh per-tap activation scales for the *current* parameters.
    pub scales: Vec<f32>,
    /// Per-tap saturation thresholds chosen by calibration.
    pub thresholds: Vec<f32>,
    /// Accuracy re-measured with the fresh scales.
    pub accuracy: f64,
}

/// The two calibration passes + threshold sweep, capped at `max_samples`
/// calibration images (`usize::MAX` = the full calib split).
fn calibrate(
    sess: &mut Session,
    params: &ParamStore,
    cfg: &HqpConfig,
    max_samples: usize,
) -> Result<(Vec<f32>, Vec<f32>)> {
    let ranges = sess.act_absmax_n(params, max_samples)?;
    let hist = sess.act_hist_n(params, &ranges, max_samples)?;
    let bins = hist.shape()[1];
    let cal = Calibrator::new(cfg.calib_method);
    let mut scales = Vec::with_capacity(ranges.len());
    let mut thresholds = Vec::with_capacity(ranges.len());
    for (i, &r) in ranges.iter().enumerate() {
        let row = &hist.data()[i * bins..(i + 1) * bins];
        let t = cal.threshold(row, r);
        thresholds.push(t);
        scales.push(crate::quant::scale_for(t, 8));
    }
    Ok((scales, thresholds))
}

/// Run PTQ on `params` (pristine or pruned — HQP runs it on M_sparse).
pub fn quantize(sess: &mut Session, params: &ParamStore, cfg: &HqpConfig) -> Result<PtqResult> {
    quantize_n(sess, params, cfg, usize::MAX)
}

/// [`quantize`] with a calibration sample cap (the schedule grammar's
/// `ptq(samples=<n>)` knob; the weight projection and the accuracy
/// measurement are unaffected — only the two activation passes are capped).
pub fn quantize_n(
    sess: &mut Session,
    params: &ParamStore,
    cfg: &HqpConfig,
    max_samples: usize,
) -> Result<PtqResult> {
    // ---- activation calibration (two artifact passes + KL sweep) --------
    let (scales, thresholds) = calibrate(sess, params, cfg, max_samples)?;

    // ---- weight projection ----------------------------------------------
    // CoW clone: only the ".w" tensors projected below are un-shared and
    // re-uploaded by the final measurement pass; BN params and biases keep
    // their version stamps (and device buffers).
    let mm = sess.mm.clone();
    let mut q = params.clone();
    for spec in &mm.param_order {
        if !quantizable(&spec.name) {
            continue;
        }
        let w = params.get(&spec.name)?;
        let qt = if cfg.per_channel_weights {
            // out-channel axis: last axis for conv HWIO, axis 1 for FC.
            let axis = w.shape().len() - 1;
            quantize_per_channel(w, axis, 8)?
        } else {
            quantize_per_tensor(w, 8)
        };
        q.set(&spec.name, dequantize(&qt)?)?;
    }

    // ---- measured INT8 accuracy ------------------------------------------
    let accuracy = sess.quant_accuracy(&q, &scales, &cfg.val_split)?;
    Ok(PtqResult { params: q, scales, thresholds, accuracy })
}

/// Re-collect activation scales on the *current* (e.g. freshly pruned)
/// parameters and re-measure, without touching the weights — the §V-B fix
/// for the quantize-first staleness failure, exposed to schedules as
/// `ptq(recalib)`. The weights are assumed to already sit on the INT8 grid
/// (a prior [`quantize`] stage); only the activation scales were stale.
pub fn recalibrate(
    sess: &mut Session,
    params: &ParamStore,
    cfg: &HqpConfig,
    max_samples: usize,
) -> Result<RecalibResult> {
    let (scales, thresholds) = calibrate(sess, params, cfg, max_samples)?;
    let accuracy = sess.quant_accuracy(params, &scales, &cfg.val_split)?;
    Ok(RecalibResult { scales, thresholds, accuracy })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantizable_filter() {
        assert!(quantizable("block0.expand.w"));
        assert!(quantizable("head.classifier.w"));
        assert!(!quantizable("stem.bn.gamma"));
        assert!(!quantizable("head.classifier.b"));
        assert!(!quantizable("stem.bn.mean"));
    }
    // Full PTQ round-trips run in rust/tests/integration_pipeline.rs.
}
