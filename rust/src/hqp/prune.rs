//! Algorithm 1 — HQP Conditional Pruning (paper §III-B, verbatim logic).
//!
//! ```text
//! Input:  M_train, A_baseline, Δ_max, δ, D_calib, D_val
//! 1. θ ← 0; M_sparse ← M_train; A ← A_baseline
//! 2. compute S for all filters (one backward pass over D_calib)
//! 3. rank ℛ ascending by S
//! 4. loop:
//!      a. mask the next δ filters from ℛ          (candidate M_candidate)
//!      b. evaluate A_candidate on D_val
//!      c. if A_baseline − A_candidate ≤ Δ_max: accept, continue
//!         else: reject (restore), break
//! Output: M_sparse — maximal structurally pruned model satisfying Δ_max
//! ```
//!
//! The masks double as the dead-channel map handed to [`crate::gopt`] for
//! the deployed engine, so "filters removed" here IS "channels eliminated"
//! there.
//!
//! Perf: the per-candidate `clone()` is O(groups) thanks to the
//! copy-on-write [`ParamStore`], and step (b) runs through
//! [`Session::accuracy_bounded`], which stops the validation sweep as soon
//! as the remaining batches cannot flip the accept/reject decision — the
//! decision is provably identical to a full sweep (see `runtime::session`).

use crate::error::Result;
use crate::runtime::{ParamStore, Session};

use super::sensitivity::Saliency;
use super::HqpConfig;

/// One accepted (or the final rejected) step of the loop.
#[derive(Clone, Copy, Debug)]
pub struct PruneStep {
    /// Filters masked after this step.
    pub masked: usize,
    /// Sparsity θ after this step.
    pub sparsity: f64,
    /// Validation accuracy of the candidate (over the batches the bounded
    /// sweep executed; exact when no early exit fired).
    pub accuracy: f64,
    pub accepted: bool,
}

/// Full loop trajectory (drives the paper's sparsity–accuracy curve).
#[derive(Clone, Debug, Default)]
pub struct PruneTrace {
    pub steps: Vec<PruneStep>,
}

/// Result of the conditional loop.
pub struct PruneResult {
    /// M_sparse parameters (masked).
    pub params: ParamStore,
    /// Per-group keep-masks (true = filter kept).
    pub masks: Vec<Vec<bool>>,
    /// Final sparsity θ (fraction of filters masked).
    pub sparsity: f64,
    /// Validation accuracy of M_sparse.
    pub accuracy: f64,
    pub trace: PruneTrace,
}

/// Run Algorithm 1 given a precomputed saliency ranking.
pub fn conditional_prune(
    sess: &mut Session,
    baseline_params: &ParamStore,
    baseline_acc: f64,
    saliency: &Saliency,
    cfg: &HqpConfig,
) -> Result<PruneResult> {
    let mm = sess.mm.clone();
    let total = mm.total_filters();
    let step = ((total as f64 * cfg.delta_step_frac).round() as usize).max(1);
    let max_masked = (total as f64 * cfg.max_sparsity) as usize;

    // O(groups) copy-on-write clone — candidates only pay for the δ
    // filters' member tensors they actually mask.
    let mut params = baseline_params.clone();
    let mut masks: Vec<Vec<bool>> = mm.groups.iter().map(|g| vec![true; g.size]).collect();
    let mut trace = PruneTrace::default();
    let mut accepted_acc = baseline_acc;
    let mut accepted_exact = true;
    let mut masked = 0usize;
    let mut cursor = 0usize;

    while masked < max_masked && cursor < saliency.ranking.len() {
        // a. Proposed pruning: next δ filters from ℛ.
        let take: Vec<usize> = saliency.ranking[cursor..]
            .iter()
            .copied()
            .take(step)
            .collect();
        if take.is_empty() {
            break;
        }
        let mut candidate = params.clone();
        let mut cand_masks = masks.clone();
        for &f in &take {
            let (g, j) = mm.locate_filter(f)?;
            candidate.mask_filter(g, j)?;
            cand_masks[g.id][j] = false;
        }

        // b + c. Bounded validation: stops once the Δ_max decision is
        // forced; the decision equals the full-sweep one exactly.
        let bounded =
            sess.accuracy_bounded(&candidate, &cfg.val_split, baseline_acc, cfg.delta_max)?;
        let cand_masked = masked + take.len();
        trace.steps.push(PruneStep {
            masked: cand_masked,
            sparsity: cand_masked as f64 / total as f64,
            accuracy: bounded.accuracy,
            accepted: bounded.accepted,
        });
        if bounded.accepted {
            params = candidate;
            masks = cand_masks;
            masked = cand_masked;
            accepted_acc = bounded.accuracy;
            accepted_exact = bounded.exact;
            cursor += take.len();
        } else {
            break; // reject and terminate (Algorithm 1 line 24)
        }
    }

    // The returned accuracy must be the exact full-split value of M_sparse;
    // re-measure only if the last accepted sweep early-exited. (If the loop
    // ended on a rejection, the cache holds the rejected candidate's δ
    // members, so this pass re-uploads just those few tensors.)
    if !accepted_exact {
        accepted_acc = sess.accuracy(&params, &cfg.val_split)?;
    }

    Ok(PruneResult {
        params,
        masks,
        sparsity: masked as f64 / total as f64,
        accuracy: accepted_acc,
        trace,
    })
}

/// Unconditional pruning to a fixed sparsity (the paper's "P50-only"
/// baseline: magnitude pruning straight to θ with NO quality guarantee).
pub fn prune_to_sparsity(
    sess: &mut Session,
    baseline_params: &ParamStore,
    saliency: &Saliency,
    theta: f64,
) -> Result<PruneResult> {
    let mm = sess.mm.clone();
    let total = mm.total_filters();
    let n = ((total as f64 * theta).round() as usize).min(total);
    let mut params = baseline_params.clone();
    let mut masks: Vec<Vec<bool>> = mm.groups.iter().map(|g| vec![true; g.size]).collect();
    for &f in saliency.ranking.iter().take(n) {
        let (g, j) = mm.locate_filter(f)?;
        params.mask_filter(g, j)?;
        masks[g.id][j] = false;
    }
    let accuracy = sess.accuracy(&params, "val")?;
    Ok(PruneResult {
        params,
        masks,
        sparsity: n as f64 / total as f64,
        accuracy,
        trace: PruneTrace::default(),
    })
}

/// Per-group sparsity of a mask set (paper §V-C layer-wise analysis).
pub fn per_group_sparsity(masks: &[Vec<bool>]) -> Vec<f64> {
    masks
        .iter()
        .map(|m| {
            if m.is_empty() {
                0.0
            } else {
                m.iter().filter(|&&keep| !keep).count() as f64 / m.len() as f64
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn per_group_sparsity_counts_masked() {
        let masks = vec![vec![true, false, false, true], vec![true; 3], vec![]];
        let s = per_group_sparsity(&masks);
        assert_eq!(s, vec![0.5, 0.0, 0.0]);
    }
    // The loop itself is exercised end-to-end in
    // rust/tests/integration_pipeline.rs against real artifacts, and its
    // invariants (monotone sparsity, constraint compliance, mask/params
    // consistency) in rust/tests/prop_coordinator.rs.
}
