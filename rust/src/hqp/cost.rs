//! The §III-C computational-cost model: C_HQP vs C_QAT.
//!
//! ```text
//! C_HQP = N_calib·C_grad + T_prune·(N_val·C_inf)
//! C_QAT ≈ N_epochs·N_train·C_grad
//! ```
//!
//! C_HQP's terms are *measured* (the session counts grad/inference samples
//! as the pipeline runs); C_QAT is modeled from the training-set size and
//! epoch count the paper assumes. The bench prints both and the ratio,
//! reproducing the paper's "orders of magnitude" claim (§V-F).

use crate::runtime::Counters;

/// Cost in "forward-pass equivalents": one grad sample ≈ 3 forward passes
/// (fwd + bwd ≈ 2×fwd), the standard accounting.
pub const GRAD_TO_INF: f64 = 3.0;

/// Measured HQP optimization cost, in forward-pass equivalents.
#[derive(Clone, Copy, Debug)]
pub struct HqpCost {
    pub grad_samples: u64,
    pub inference_samples: u64,
}

impl HqpCost {
    pub fn from_counters(c: &Counters) -> HqpCost {
        HqpCost { grad_samples: c.grad_samples, inference_samples: c.inference_samples }
    }

    /// Total in forward-pass equivalents.
    pub fn total_inf_equiv(&self) -> f64 {
        self.grad_samples as f64 * GRAD_TO_INF + self.inference_samples as f64
    }
}

/// Modeled QAT cost for the same model.
#[derive(Clone, Copy, Debug)]
pub struct QatCost {
    pub epochs: u64,
    pub train_samples: u64,
}

impl QatCost {
    /// Paper's assumption: N_epochs ≥ 5 full fine-tuning epochs.
    pub fn paper_default(train_samples: u64) -> QatCost {
        QatCost { epochs: 5, train_samples }
    }

    pub fn total_inf_equiv(&self) -> f64 {
        self.epochs as f64 * self.train_samples as f64 * GRAD_TO_INF
    }
}

/// C_QAT / C_HQP.
pub fn overhead_ratio(hqp: &HqpCost, qat: &QatCost) -> f64 {
    qat.total_inf_equiv() / hqp.total_inf_equiv().max(1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cost_accounting() {
        let h = HqpCost { grad_samples: 1024, inference_samples: 50_000 };
        assert_eq!(h.total_inf_equiv(), 1024.0 * 3.0 + 50_000.0);
        let q = QatCost::paper_default(1_281_167); // ImageNet-sized N_train
        assert_eq!(q.epochs, 5);
        let r = overhead_ratio(&h, &q);
        assert!(r > 100.0, "QAT should dominate by orders of magnitude: {r}");
    }

    #[test]
    fn ratio_guards_zero() {
        let h = HqpCost { grad_samples: 0, inference_samples: 0 };
        let q = QatCost { epochs: 1, train_samples: 10 };
        assert!(overhead_ratio(&h, &q).is_finite());
    }
}
