//! The HQP framework (paper §III): sensitivity-aware conditional structural
//! pruning + robust INT8 PTQ, coordinated so that
//!
//! ```text
//! M_o = Q( P(M_train, τ, Δ_max), b )
//! ```
//!
//! This module is the paper's contribution, running entirely in Rust (L3)
//! against the AOT artifacts:
//!
//! * [`sensitivity`] — the diagonal-FIM saliency S and the ranked list ℛ
//!   (Algorithm 1 lines 6–8), plus the magnitude/BN-γ/random baselines.
//! * [`prune`] — the conditional iterative loop (Algorithm 1 lines 9–25):
//!   mask δ filters, validate on D_val, accept while
//!   `A_baseline − A_candidate ≤ Δ_max`, stop on first violation.
//! * [`ptq`] — Phase 2: KL-divergence activation calibration + symmetric
//!   INT8 weight projection, numerically verified through the
//!   `quant_eval` artifact (Pallas qmatmul hot spots).
//! * [`schedule`] — the compression pipeline as a *value*: the [`Stage`]
//!   trait, the built-in stage specs and the [`Schedule`] type with its
//!   canonical string form (`prune(fisher) >> ptq(kl)`), so orderings the
//!   paper only argues about (§V-B: quantize-first vs prune-first) are
//!   runnable schedules.
//! * [`pipeline`] — the method suite the paper's tables compare: Baseline,
//!   Q8-only, P50-only, HQP (+ ablations) as named schedule presets, each
//!   returning an [`Outcome`].
//! * [`deploy`] — lowers an outcome through [`crate::gopt`] (fusion, dead
//!   channel elimination, autotune) onto a [`crate::hwsim`] device,
//!   producing the paper's table rows ([`MethodReport`]).
//! * [`mixed`] — the §VI-A mixed-precision extension (S-guided INT4/8/16).
//! * [`cost`] — the §III-C C_HQP vs C_QAT cost model, fed by measured
//!   execution counters.

pub mod cost;
pub mod deploy;
pub mod mixed;
pub mod pipeline;
pub mod prune;
pub mod ptq;
pub mod schedule;
pub mod sensitivity;

pub use deploy::MethodReport;
pub use pipeline::{run_baseline, run_hqp, run_p50, run_q8, Outcome};
pub use prune::{PruneStep, PruneTrace};
pub use schedule::{Schedule, Stage, StageSpec, StageState};
pub use sensitivity::RankingMethod;

use crate::quant::CalibMethod;

/// Configuration of the HQP pipeline (paper defaults).
#[derive(Clone, Debug)]
pub struct HqpConfig {
    /// Δ_max: maximum permissible absolute Top-1 accuracy drop (§IV-C:
    /// 1.5 % — "the industrial standard for acceptable model degradation").
    pub delta_max: f64,
    /// δ: pruning step as a fraction of total filters (§IV-B: 1 %).
    pub delta_step_frac: f64,
    /// Calibration samples for the sensitivity pass and PTQ histograms.
    pub calib_samples: usize,
    /// Validation split for the conditional loop.
    pub val_split: String,
    /// Filter ranking (HQP: Fisher; baselines: magnitude/BN-γ/random).
    pub ranking: RankingMethod,
    /// Activation-scale calibration for PTQ.
    pub calib_method: CalibMethod,
    /// Per-channel weight scales (ablation; paper §II-C formulates the
    /// single global scaling factor, i.e. per-tensor — the default here).
    pub per_channel_weights: bool,
    /// Safety stop: never mask beyond this filter fraction.
    pub max_sparsity: f64,
}

impl Default for HqpConfig {
    fn default() -> Self {
        HqpConfig {
            delta_max: 0.015,
            delta_step_frac: 0.01,
            calib_samples: 1024,
            val_split: "val".into(),
            ranking: RankingMethod::Fisher,
            calib_method: CalibMethod::Kl,
            per_channel_weights: false,
            max_sparsity: 0.95,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper() {
        let c = HqpConfig::default();
        assert_eq!(c.delta_max, 0.015);
        assert_eq!(c.delta_step_frac, 0.01);
        assert_eq!(c.ranking, RankingMethod::Fisher);
        assert_eq!(c.calib_method, CalibMethod::Kl);
    }
}
