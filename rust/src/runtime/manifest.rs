//! `artifacts/manifest.json` — the L2→L3 contract emitted by
//! `python/compile/aot.py`. Everything the coordinator knows about a model
//! (parameter layout, prune groups, quantization taps, the op graph, the
//! AOT artifact argument specs) comes from here; nothing is hard-coded.

use std::collections::BTreeMap;
use std::path::Path;

use crate::error::{Error, Result};
use crate::formats::json::Json;

/// Datatype of an artifact argument/output.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    fn parse(s: &str) -> Result<DType> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            other => Err(Error::manifest(format!("unknown dtype {other}"))),
        }
    }
}

/// One named tensor argument or output of an artifact.
#[derive(Clone, Debug)]
pub struct ArgSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

/// One AOT-lowered function (HLO text file + signature).
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    /// File name relative to the artifacts root.
    pub file: String,
    /// Arguments that follow the parameter list.
    pub extra_args: Vec<ArgSpec>,
    pub outputs: Vec<ArgSpec>,
}

/// One model parameter (ordered — index is the artifact argument position).
#[derive(Clone, Debug)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

/// One prune group: the unit ranked and removed by Algorithm 1.
///
/// `members` are `(param_name, axis)` pairs; masking filter `j` zeroes
/// slice `j` along `axis` of every member (producer weights + downstream
/// per-channel params — see DESIGN.md §2 for why that equals structural
/// removal).
#[derive(Clone, Debug)]
pub struct GroupSpec {
    pub id: usize,
    pub name: String,
    /// Number of filters in this group.
    pub size: usize,
    /// Index of this group's filter 0 in the global S vector.
    pub offset: usize,
    pub members: Vec<(String, usize)>,
    /// Weight tensor whose per-sample gradients define S for this group.
    pub producer: String,
    pub producer_axis: usize,
}

/// One quantizable activation (conv/fc input) in traversal order.
#[derive(Clone, Debug)]
pub struct TapSpec {
    pub id: usize,
    pub op: String,
    pub shape: Vec<usize>,
}

/// One node of the inference graph.
#[derive(Clone, Debug)]
pub struct OpSpec {
    pub id: usize,
    pub kind: String,
    pub name: String,
    pub inputs: Vec<usize>,
    pub output: usize,
    pub attrs: BTreeMap<String, Json>,
    pub params: Vec<String>,
    pub group: Option<usize>,
    pub tap: Option<usize>,
}

impl OpSpec {
    /// Numeric attribute accessor.
    pub fn attr(&self, key: &str) -> Result<usize> {
        self.attrs
            .get(key)
            .ok_or_else(|| Error::manifest(format!("op {}: missing attr {key}", self.name)))?
            .as_usize()
    }

    /// String attribute accessor (activation kind).
    pub fn attr_str(&self, key: &str) -> Result<&str> {
        self.attrs
            .get(key)
            .ok_or_else(|| Error::manifest(format!("op {}: missing attr {key}", self.name)))?
            .as_str()
    }
}

/// Everything known about one model.
#[derive(Clone, Debug)]
pub struct ModelManifest {
    pub name: String,
    pub input_hw: usize,
    pub num_classes: usize,
    pub baseline_val_acc: f64,
    pub eval_batch: usize,
    pub fisher_batch: usize,
    pub hist_batch: usize,
    pub weights_dir: String,
    pub param_order: Vec<ParamSpec>,
    pub groups: Vec<GroupSpec>,
    pub taps: Vec<TapSpec>,
    pub ops: Vec<OpSpec>,
    /// tensor id -> shape (batch dim = 1 at trace time).
    pub tensor_shapes: BTreeMap<usize, Vec<usize>>,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

impl ModelManifest {
    /// Total filter count (the length of the S vector / ranked list R).
    pub fn total_filters(&self) -> usize {
        self.groups.iter().map(|g| g.size).sum()
    }

    /// Map a global filter index into (group, channel-within-group).
    pub fn locate_filter(&self, global: usize) -> Result<(&GroupSpec, usize)> {
        for g in &self.groups {
            if global >= g.offset && global < g.offset + g.size {
                return Ok((g, global - g.offset));
            }
        }
        Err(Error::manifest(format!("filter index {global} out of range")))
    }

    /// Index of a parameter by name.
    pub fn param_index(&self, name: &str) -> Result<usize> {
        self.param_order
            .iter()
            .position(|p| p.name == name)
            .ok_or_else(|| Error::manifest(format!("unknown param {name}")))
    }
}

/// One dataset split.
#[derive(Clone, Debug)]
pub struct DataSplit {
    pub x: String,
    pub y: String,
    pub n: usize,
}

/// Parsed manifest.json.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub hist_bins: usize,
    pub models: BTreeMap<String, ModelManifest>,
    pub data: BTreeMap<String, DataSplit>,
}

fn parse_arg_list(v: &Json) -> Result<Vec<ArgSpec>> {
    v.as_arr()?
        .iter()
        .map(|a| {
            let parts = a.as_arr()?;
            if parts.len() != 3 {
                return Err(Error::manifest("arg spec wants [name, shape, dtype]"));
            }
            Ok(ArgSpec {
                name: parts[0].as_str()?.to_string(),
                shape: parts[1].as_usize_vec()?,
                dtype: DType::parse(parts[2].as_str()?)?,
            })
        })
        .collect()
}

fn parse_model(name: &str, v: &Json) -> Result<ModelManifest> {
    let param_order = v
        .req("param_order")?
        .as_arr()?
        .iter()
        .map(|p| {
            Ok(ParamSpec {
                name: p.req("name")?.as_str()?.to_string(),
                shape: p.req("shape")?.as_usize_vec()?,
            })
        })
        .collect::<Result<Vec<_>>>()?;

    let groups = v
        .req("groups")?
        .as_arr()?
        .iter()
        .map(|g| {
            Ok(GroupSpec {
                id: g.req("id")?.as_usize()?,
                name: g.req("name")?.as_str()?.to_string(),
                size: g.req("size")?.as_usize()?,
                offset: g.req("offset")?.as_usize()?,
                members: g
                    .req("members")?
                    .as_arr()?
                    .iter()
                    .map(|m| {
                        let parts = m.as_arr()?;
                        Ok((parts[0].as_str()?.to_string(), parts[1].as_usize()?))
                    })
                    .collect::<Result<Vec<_>>>()?,
                producer: g.req("producer")?.as_str()?.to_string(),
                producer_axis: g.req("producer_axis")?.as_usize()?,
            })
        })
        .collect::<Result<Vec<_>>>()?;

    let taps = v
        .req("taps")?
        .as_arr()?
        .iter()
        .map(|t| {
            Ok(TapSpec {
                id: t.req("id")?.as_usize()?,
                op: t.req("op")?.as_str()?.to_string(),
                shape: t.req("shape")?.as_usize_vec()?,
            })
        })
        .collect::<Result<Vec<_>>>()?;

    let ops = v
        .req("ops")?
        .as_arr()?
        .iter()
        .map(|o| {
            let group = match o.req("group")? {
                Json::Null => None,
                g => Some(g.as_usize()?),
            };
            let tap = match o.req("tap")? {
                Json::Null => None,
                t => Some(t.as_usize()?),
            };
            Ok(OpSpec {
                id: o.req("id")?.as_usize()?,
                kind: o.req("kind")?.as_str()?.to_string(),
                name: o.req("name")?.as_str()?.to_string(),
                inputs: o.req("inputs")?.as_usize_vec()?,
                output: o.req("output")?.as_usize()?,
                attrs: o
                    .req("attrs")?
                    .as_obj()?
                    .iter()
                    .map(|(k, val)| (k.clone(), val.clone()))
                    .collect(),
                params: o
                    .req("params")?
                    .as_arr()?
                    .iter()
                    .map(|p| Ok(p.as_str()?.to_string()))
                    .collect::<Result<Vec<_>>>()?,
                group,
                tap,
            })
        })
        .collect::<Result<Vec<_>>>()?;

    let tensor_shapes = v
        .req("tensor_shapes")?
        .as_obj()?
        .iter()
        .map(|(k, shape)| {
            let tid = k
                .parse::<usize>()
                .map_err(|e| Error::manifest(format!("bad tensor id {k}: {e}")))?;
            Ok((tid, shape.as_usize_vec()?))
        })
        .collect::<Result<BTreeMap<_, _>>>()?;

    let artifacts = v
        .req("artifacts")?
        .as_obj()?
        .iter()
        .map(|(fn_name, a)| {
            Ok((
                fn_name.clone(),
                ArtifactSpec {
                    file: a.req("file")?.as_str()?.to_string(),
                    extra_args: parse_arg_list(a.req("extra_args")?)?,
                    outputs: parse_arg_list(a.req("outputs")?)?,
                },
            ))
        })
        .collect::<Result<BTreeMap<_, _>>>()?;

    Ok(ModelManifest {
        name: name.to_string(),
        input_hw: v.req("input_hw")?.as_usize()?,
        num_classes: v.req("num_classes")?.as_usize()?,
        baseline_val_acc: v.req("baseline_val_acc")?.as_f64()?,
        eval_batch: v.req("eval_batch")?.as_usize()?,
        fisher_batch: v.req("fisher_batch")?.as_usize()?,
        hist_batch: v.req("hist_batch")?.as_usize()?,
        weights_dir: v.req("weights_dir")?.as_str()?.to_string(),
        param_order,
        groups,
        taps,
        ops,
        tensor_shapes,
        artifacts,
    })
}

impl Manifest {
    /// Parse a manifest from JSON text.
    pub fn parse(text: &str) -> Result<Manifest> {
        let v = Json::parse(text)?;
        let models = v
            .req("models")?
            .as_obj()?
            .iter()
            .map(|(name, m)| Ok((name.clone(), parse_model(name, m)?)))
            .collect::<Result<BTreeMap<_, _>>>()?;
        let data = v
            .req("data")?
            .as_obj()?
            .iter()
            .map(|(split, d)| {
                Ok((
                    split.clone(),
                    DataSplit {
                        x: d.req("x")?.as_str()?.to_string(),
                        y: d.req("y")?.as_str()?.to_string(),
                        n: d.req("n")?.as_usize()?,
                    },
                ))
            })
            .collect::<Result<BTreeMap<_, _>>>()?;
        Ok(Manifest {
            hist_bins: v.req("hist_bins")?.as_usize()?,
            models,
            data,
        })
    }

    /// Load from `<root>/manifest.json`.
    pub fn load(root: impl AsRef<Path>) -> Result<Manifest> {
        let path = root.as_ref().join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| Error::manifest(format!("{}: {e}", path.display())))?;
        Manifest::parse(&text)
    }

    pub fn model(&self, name: &str) -> Result<&ModelManifest> {
        self.models
            .get(name)
            .ok_or_else(|| Error::manifest(format!("unknown model {name}")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MINI: &str = r#"{
      "version": 1, "hist_bins": 2048,
      "models": {
        "m": {
          "input_hw": 8, "num_classes": 2, "baseline_val_acc": 0.9,
          "eval_batch": 4, "fisher_batch": 2, "hist_batch": 4,
          "weights_dir": "weights/m",
          "param_order": [{"name": "c.w", "shape": [3, 3, 3, 4]}],
          "groups": [{"id": 0, "name": "c", "size": 4, "offset": 0,
                      "members": [["c.w", 3]], "producer": "c.w", "producer_axis": 3}],
          "taps": [{"id": 0, "op": "c", "shape": [1, 8, 8, 3]}],
          "ops": [{"id": 0, "kind": "conv", "name": "c", "inputs": [0], "output": 1,
                   "attrs": {"cin": 3, "cout": 4, "k": 3, "stride": 1, "groups": 1,
                             "h": 8, "w": 8},
                   "params": ["c.w"], "group": 0, "tap": 0}],
          "tensor_shapes": {"0": [1, 8, 8, 3], "1": [1, 8, 8, 4]},
          "artifacts": {
            "eval": {"file": "m_eval.hlo.txt",
                     "extra_args": [["x", [4, 8, 8, 3], "f32"]],
                     "outputs": [["logits", [4, 2], "f32"]]}
          }
        }
      },
      "data": {"val": {"x": "data/val_x.npy", "y": "data/val_y.npy", "n": 8}}
    }"#;

    #[test]
    fn parses_minimal_manifest() {
        let m = Manifest::parse(MINI).unwrap();
        assert_eq!(m.hist_bins, 2048);
        let mm = m.model("m").unwrap();
        assert_eq!(mm.total_filters(), 4);
        assert_eq!(mm.param_order[0].shape, vec![3, 3, 3, 4]);
        assert_eq!(mm.groups[0].members, vec![("c.w".to_string(), 3)]);
        let art = &mm.artifacts["eval"];
        assert_eq!(art.extra_args[0].dtype, DType::F32);
        assert_eq!(art.outputs[0].shape, vec![4, 2]);
        assert_eq!(mm.ops[0].attr("cout").unwrap(), 4);
        assert_eq!(m.data["val"].n, 8);
    }

    #[test]
    fn locate_filter_maps_offsets() {
        let m = Manifest::parse(MINI).unwrap();
        let mm = m.model("m").unwrap();
        let (g, j) = mm.locate_filter(2).unwrap();
        assert_eq!(g.id, 0);
        assert_eq!(j, 2);
        assert!(mm.locate_filter(4).is_err());
    }

    #[test]
    fn unknown_model_errors() {
        let m = Manifest::parse(MINI).unwrap();
        assert!(m.model("nope").is_err());
    }
}
