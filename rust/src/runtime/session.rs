//! Session: one model bound to the workspace, with device-resident dataset
//! caches and the measurement primitives the HQP pipeline is built from.
//!
//! Perf note (§Perf L3) — the caching contract:
//!
//! * **Dataset batches** are uploaded to PJRT buffers once per
//!   (split, batch-size) and reused for every execution — Algorithm 1
//!   re-validates after every pruning step, so the x-batch upload would
//!   otherwise dominate the loop.
//! * **Parameters** are device-resident too: the session keeps one
//!   [`PjRtBuffer`](xla::PjRtBuffer) per [`ParamStore`] slot, keyed by the
//!   slot's copy-on-write version stamp. A measurement call re-uploads only
//!   the tensors whose stamp changed since the last call — for a δ-step of
//!   Algorithm 1 that is the masked filters' member tensors, not the whole
//!   model. Version stamps are process-globally unique (see
//!   [`crate::runtime::ParamStore`]), so serving a cached buffer for an
//!   equal stamp is always byte-exact, across candidate clones.
//! * **A_baseline is memoized** per split ([`Session::baseline_accuracy`]):
//!   M_train never mutates within a session, so the first schedule's
//!   baseline sweep serves every later schedule (and every stage) sharing
//!   the session for free.
//! * **Validation** can stop early: [`Session::accuracy_bounded`] walks the
//!   batches and exits as soon as the remaining samples cannot change the
//!   accept/reject decision against `(baseline_acc, delta_max)` — an exact
//!   bound (the comparison is monotone in the correct-count), not an
//!   approximation, so the decision is provably identical to a full sweep.
//!
//! Every cache's effect is *measured*, not asserted: [`Counters`] tracks
//! uploaded parameter tensors/bytes and skipped validation batches next to
//! the paper's execution/sample counts, and `benches/bench_session.rs`
//! records the trajectory.

use std::collections::HashMap;
use std::rc::Rc;

use crate::error::{Error, Result};
use crate::runtime::manifest::{ArgSpec, ModelManifest};
use crate::runtime::{run_buffers, to_buffer, to_buffer_i32, ParamStore, Workspace};
use crate::tensor::{count_correct, Tensor, TensorI32};

/// One uploaded batch (x on device, labels on host for the accuracy
/// reduction, y on device for gradient artifacts).
struct Batch {
    x: xla::PjRtBuffer,
    y: xla::PjRtBuffer,
    labels: Vec<i32>,
    valid: usize,
}

/// A dataset split with device-buffer caches keyed by batch size.
pub struct DataSet {
    pub n: usize,
    x: Tensor,
    y: TensorI32,
    batches: HashMap<usize, Vec<Batch>>,
}

/// Execution counters — the measured side of the paper's §III-C cost model
/// (C_HQP = calib·C_grad + T_prune·val·C_inf), plus the caching layer's
/// own effectiveness metrics.
#[derive(Clone, Copy, Debug, Default)]
pub struct Counters {
    /// Forward-pass executions (eval/quant_eval/absmax/hist), in samples.
    pub inference_samples: u64,
    /// Backward-pass executions (fisher), in samples.
    pub grad_samples: u64,
    /// PJRT execute() calls.
    pub executions: u64,
    /// Parameter bytes actually moved host→device (cache misses only).
    pub upload_bytes: u64,
    /// Parameter tensors actually moved host→device (cache misses only).
    pub upload_tensors: u64,
    /// Validation batches skipped by early-exit bounded validation.
    pub batches_skipped: u64,
}

/// One device-resident parameter tensor, valid for a specific version stamp.
struct CachedParam {
    version: u64,
    buf: Rc<xla::PjRtBuffer>,
}

/// Device-buffer cache over [`ParamStore`] slots: slot `i` holds the buffer
/// of the last-uploaded tensor and the version it was uploaded at.
#[derive(Default)]
struct ParamBufferCache {
    slots: Vec<Option<CachedParam>>,
}

/// Verdict of the incremental accept/reject evaluator.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BoundedVerdict {
    /// Even if every remaining sample were wrong, the drop stays ≤ Δ_max.
    Accept,
    /// Even if every remaining sample were right, the drop exceeds Δ_max.
    Reject,
    /// The remaining samples can still swing the decision.
    Undecided,
}

/// Incremental early-exit evaluator for the Δ_max accept/reject decision.
///
/// Pure host-side arithmetic (property-tested without artifacts): feed it
/// per-batch `(correct, valid)` counts and it reports, after each batch,
/// whether the final full-split decision is already forced. The decision
/// predicate is the *same expression* Algorithm 1 evaluates on the full
/// sweep — `baseline_acc − correct/total ≤ delta_max` — and every f64 step
/// of it (division, subtraction, comparison) is monotone in `correct`, so
/// "the lower bound already accepts" / "the upper bound still rejects" are
/// exact, rounding included, never approximations.
#[derive(Clone, Copy, Debug)]
pub struct BoundedEval {
    total: usize,
    seen: usize,
    correct: usize,
    baseline_acc: f64,
    delta_max: f64,
}

impl BoundedEval {
    /// `total` = full split size the final decision would be taken over.
    pub fn new(total: usize, baseline_acc: f64, delta_max: f64) -> BoundedEval {
        BoundedEval { total, seen: 0, correct: 0, baseline_acc, delta_max }
    }

    /// The full-sweep predicate for a hypothetical final correct-count.
    fn accepts(&self, correct: usize) -> bool {
        self.baseline_acc - correct as f64 / self.total as f64 <= self.delta_max
    }

    /// Fold in one batch's result and return the (possibly forced) verdict.
    pub fn update(&mut self, correct: usize, valid: usize) -> BoundedVerdict {
        debug_assert!(correct <= valid);
        debug_assert!(self.seen + valid <= self.total);
        self.correct += correct;
        self.seen += valid;
        self.verdict()
    }

    /// Current verdict given the batches folded in so far.
    pub fn verdict(&self) -> BoundedVerdict {
        let remaining = self.total - self.seen;
        if self.accepts(self.correct) {
            // final correct ≥ current correct, and accepts() is monotone
            BoundedVerdict::Accept
        } else if !self.accepts(self.correct + remaining) {
            // final correct ≤ current + remaining
            BoundedVerdict::Reject
        } else {
            BoundedVerdict::Undecided
        }
    }

    /// Accuracy over the samples folded in so far (the exact full-split
    /// accuracy when [`BoundedEval::is_complete`]).
    pub fn accuracy(&self) -> f64 {
        if self.seen == 0 {
            0.0
        } else {
            self.correct as f64 / self.seen as f64
        }
    }

    pub fn is_complete(&self) -> bool {
        self.seen == self.total
    }
}

/// Result of [`Session::accuracy_bounded`].
#[derive(Clone, Copy, Debug)]
pub struct BoundedAccuracy {
    /// The accept/reject decision — identical to what a full sweep through
    /// [`Session::accuracy`] plus the Δ_max predicate would produce.
    pub accepted: bool,
    /// Accuracy over the batches actually executed; the exact full-split
    /// accuracy iff `exact`.
    pub accuracy: f64,
    /// True when every batch ran (no early exit).
    pub exact: bool,
    /// Batches executed before the decision was forced.
    pub batches_run: usize,
    /// Batches the early exit avoided (also accumulated into
    /// [`Counters::batches_skipped`]).
    pub batches_skipped: usize,
}

/// One model + its datasets, bound to a [`Workspace`].
pub struct Session<'w> {
    pub ws: &'w Workspace,
    pub mm: ModelManifest,
    /// Pristine trained parameters (the paper's M_train).
    pub baseline: ParamStore,
    data: HashMap<String, DataSet>,
    pcache: ParamBufferCache,
    /// Memoized A_baseline per split — M_train never mutates within a
    /// session, so every compression schedule sharing this session pays
    /// for exactly one baseline sweep per split.
    baseline_acc: HashMap<String, f64>,
    pub counters: Counters,
}

impl<'w> Session<'w> {
    pub fn new(ws: &'w Workspace, model: &str) -> Result<Session<'w>> {
        let mm = ws.manifest.model(model)?.clone();
        let baseline = ParamStore::load(&ws.root, &mm)?;
        Ok(Session {
            ws,
            mm,
            baseline,
            data: HashMap::new(),
            pcache: ParamBufferCache::default(),
            baseline_acc: HashMap::new(),
            counters: Counters::default(),
        })
    }

    /// A_baseline on `split`, measured once per session and memoized
    /// (sound because [`Session::baseline`] is pristine for the session's
    /// lifetime — schedules clone it copy-on-write and never mutate it).
    /// The first call costs one full [`Session::accuracy`] sweep; repeats
    /// are free, so a method suite sharing one session no longer pays a
    /// validation sweep per method.
    pub fn baseline_accuracy(&mut self, split: &str) -> Result<f64> {
        if let Some(&a) = self.baseline_acc.get(split) {
            return Ok(a);
        }
        let params = self.baseline.clone(); // O(slots) copy-on-write
        let a = self.accuracy(&params, split)?;
        self.baseline_acc.insert(split.to_string(), a);
        Ok(a)
    }

    /// Ensure `split` is loaded (host-side); returns its dataset entry.
    fn ensure_split(&mut self, split: &str) -> Result<&mut DataSet> {
        if !self.data.contains_key(split) {
            let (x, y) = self.ws.load_split(split)?;
            self.data.insert(
                split.to_string(),
                DataSet { n: x.shape()[0], x, y, batches: HashMap::new() },
            );
        }
        Ok(self.data.get_mut(split).unwrap())
    }

    /// Ensure `split` is loaded and batched at `batch` rows (device upload);
    /// returns the number of batches.
    fn ensure_batches(&mut self, split: &str, batch: usize) -> Result<usize> {
        let ws = self.ws;
        let ds = self.ensure_split(split)?;
        if !ds.batches.contains_key(&batch) {
            let mut list = Vec::new();
            let n = ds.n;
            let mut lo = 0usize;
            while lo < n {
                let hi = (lo + batch).min(n);
                let xb = ds.x.rows(lo, hi)?.pad_rows_to(batch)?;
                let yb = ds.y.rows(lo, hi)?.pad_rows_to(batch)?;
                list.push(Batch {
                    x: to_buffer(ws.client(), &xb)?,
                    y: to_buffer_i32(ws.client(), &yb)?,
                    labels: yb.data()[..hi - lo].to_vec(),
                    valid: hi - lo,
                });
                lo = hi;
            }
            ds.batches.insert(batch, list);
        }
        Ok(ds.batches[&batch].len())
    }

    fn batch(&self, split: &str, batch: usize, i: usize) -> &Batch {
        &self.data[split].batches[&batch][i]
    }

    /// Resolve the device-resident argument list for `params`, uploading
    /// only the tensors whose version stamp misses the cache. Returns
    /// cheap `Rc` handles so callers hold no borrow of the session.
    fn upload_params(&mut self, params: &ParamStore) -> Result<Vec<Rc<xla::PjRtBuffer>>> {
        let n = params.len();
        if self.pcache.slots.len() != n {
            // model changed shape-of-store (only happens across sessions in
            // tests); drop everything rather than alias slots.
            self.pcache.slots = (0..n).map(|_| None).collect();
        }
        let mut out = Vec::with_capacity(n);
        for i in 0..n {
            let version = params.version(i);
            let hit = match &self.pcache.slots[i] {
                Some(c) => c.version == version,
                None => false,
            };
            if !hit {
                let t = params.tensor(i);
                let buf = Rc::new(to_buffer(self.ws.client(), t)?);
                self.counters.upload_tensors += 1;
                self.counters.upload_bytes += (t.len() * std::mem::size_of::<f32>()) as u64;
                self.pcache.slots[i] = Some(CachedParam { version, buf });
            }
            out.push(self.pcache.slots[i].as_ref().unwrap().buf.clone());
        }
        Ok(out)
    }

    /// Upload any dirty tensors of `params` without executing anything
    /// (benchmarks; a warm cache makes the next measurement upload-free).
    pub fn warm_params(&mut self, params: &ParamStore) -> Result<()> {
        self.upload_params(params).map(|_| ())
    }

    /// Drop every cached parameter buffer (benchmarks: forces the next
    /// upload to run cold).
    pub fn reset_param_cache(&mut self) {
        self.pcache.slots.clear();
    }

    fn outputs(&self, fn_name: &str) -> Result<Vec<ArgSpec>> {
        Ok(self
            .mm
            .artifacts
            .get(fn_name)
            .ok_or_else(|| Error::manifest(format!("no artifact '{fn_name}'")))?
            .outputs
            .clone())
    }

    /// Top-1 accuracy of `params` on `split` through the FP32 eval artifact.
    pub fn accuracy(&mut self, params: &ParamStore, split: &str) -> Result<f64> {
        let eb = self.mm.eval_batch;
        let outputs = self.outputs("eval")?;
        let exe = self.ws.executable(&self.mm.name, "eval")?;
        let pbufs = self.upload_params(params)?;
        let nb = self.ensure_batches(split, eb)?;
        let mut correct = 0usize;
        let mut total = 0usize;
        for i in 0..nb {
            let valid = {
                let b = self.batch(split, eb, i);
                let mut args: Vec<&xla::PjRtBuffer> = pbufs.iter().map(|b| &**b).collect();
                args.push(&b.x);
                let out = run_buffers(&exe, &args, &outputs)?;
                correct += count_correct(&out[0], &b.labels, b.valid);
                total += b.valid;
                b.valid
            };
            self.counters.executions += 1;
            self.counters.inference_samples += valid as u64;
        }
        Ok(correct as f64 / total as f64)
    }

    /// Top-1 accuracy on `split` with early exit: stop as soon as the
    /// remaining batches cannot change the accept/reject decision against
    /// `baseline_acc − acc ≤ delta_max`. The decision is exactly the one a
    /// full [`Session::accuracy`] sweep would yield (see [`BoundedEval`]);
    /// the reported accuracy is exact iff the sweep completed.
    pub fn accuracy_bounded(
        &mut self,
        params: &ParamStore,
        split: &str,
        baseline_acc: f64,
        delta_max: f64,
    ) -> Result<BoundedAccuracy> {
        let eb = self.mm.eval_batch;
        let outputs = self.outputs("eval")?;
        let exe = self.ws.executable(&self.mm.name, "eval")?;
        let pbufs = self.upload_params(params)?;
        let nb = self.ensure_batches(split, eb)?;
        let total = self.data[split].n;
        if total == 0 {
            return Err(Error::hqp(format!("accuracy_bounded: empty split {split}")));
        }
        let mut ev = BoundedEval::new(total, baseline_acc, delta_max);
        let mut batches_run = 0usize;
        // a degenerate threshold (baseline_acc ≤ delta_max) is decided
        // before any batch runs
        if ev.verdict() == BoundedVerdict::Undecided {
            for i in 0..nb {
                let (correct, valid) = {
                    let b = self.batch(split, eb, i);
                    let mut args: Vec<&xla::PjRtBuffer> =
                        pbufs.iter().map(|b| &**b).collect();
                    args.push(&b.x);
                    let out = run_buffers(&exe, &args, &outputs)?;
                    (count_correct(&out[0], &b.labels, b.valid), b.valid)
                };
                self.counters.executions += 1;
                self.counters.inference_samples += valid as u64;
                batches_run += 1;
                if ev.update(correct, valid) != BoundedVerdict::Undecided {
                    break;
                }
            }
        }
        let batches_skipped = nb - batches_run;
        self.counters.batches_skipped += batches_skipped as u64;
        Ok(BoundedAccuracy {
            accepted: ev.verdict() == BoundedVerdict::Accept,
            accuracy: ev.accuracy(),
            exact: ev.is_complete(),
            batches_run,
            batches_skipped,
        })
    }

    /// Top-1 accuracy through the fake-quant INT8 artifact (Pallas qmatmul
    /// hot spots), with per-tensor activation `scales` (len = taps).
    pub fn quant_accuracy(
        &mut self,
        params: &ParamStore,
        scales: &[f32],
        split: &str,
    ) -> Result<f64> {
        if scales.len() != self.mm.taps.len() {
            return Err(Error::hqp(format!(
                "scales len {} != taps {}",
                scales.len(),
                self.mm.taps.len()
            )));
        }
        let eb = self.mm.eval_batch;
        let outputs = self.outputs("quant_eval")?;
        let exe = self.ws.executable(&self.mm.name, "quant_eval")?;
        let pbufs = self.upload_params(params)?;
        let sbuf = to_buffer(self.ws.client(), &Tensor::from_slice(scales))?;
        let nb = self.ensure_batches(split, eb)?;
        let mut correct = 0usize;
        let mut total = 0usize;
        for i in 0..nb {
            let valid = {
                let b = self.batch(split, eb, i);
                let mut args: Vec<&xla::PjRtBuffer> = pbufs.iter().map(|b| &**b).collect();
                args.push(&sbuf);
                args.push(&b.x);
                let out = run_buffers(&exe, &args, &outputs)?;
                correct += count_correct(&out[0], &b.labels, b.valid);
                total += b.valid;
                b.valid
            };
            self.counters.executions += 1;
            self.counters.inference_samples += valid as u64;
        }
        Ok(correct as f64 / total as f64)
    }

    /// Fisher sensitivity vector S over (up to) `max_samples` of the calib
    /// split: S_f = (1/N) Σ_i ||∂L_i/∂W_f||² — paper §II-B. One backward
    /// pass over D_calib, exactly as Algorithm 1 line 7 prescribes.
    pub fn fisher_scores(
        &mut self,
        params: &ParamStore,
        max_samples: usize,
    ) -> Result<Vec<f32>> {
        let fb = self.mm.fisher_batch;
        let outputs = self.outputs("fisher")?;
        let exe = self.ws.executable(&self.mm.name, "fisher")?;
        let pbufs = self.upload_params(params)?;
        let nb = self.ensure_batches("calib", fb)?;
        let mut acc = vec![0f32; self.mm.total_filters()];
        let mut seen = 0usize;
        for i in 0..nb {
            if seen >= max_samples {
                break;
            }
            let valid = {
                let b = self.batch("calib", fb, i);
                let mut args: Vec<&xla::PjRtBuffer> = pbufs.iter().map(|b| &**b).collect();
                args.push(&b.x);
                args.push(&b.y);
                let out = run_buffers(&exe, &args, &outputs)?;
                for (a, v) in acc.iter_mut().zip(out[0].data()) {
                    *a += v;
                }
                seen += b.valid;
                b.valid
            };
            self.counters.executions += 1;
            self.counters.grad_samples += valid as u64;
        }
        if seen == 0 {
            return Err(Error::hqp("fisher: no calibration samples"));
        }
        let inv = 1.0 / seen as f32;
        for a in &mut acc {
            *a *= inv;
        }
        Ok(acc)
    }

    /// Per-tap max |activation| over the calib split (calibration pass 1).
    pub fn act_absmax(&mut self, params: &ParamStore) -> Result<Vec<f32>> {
        self.act_absmax_n(params, usize::MAX)
    }

    /// [`Session::act_absmax`] capped at `max_samples` calibration images
    /// (the schedule grammar's `samples=<n>` knob; `usize::MAX` = full
    /// split). Batches are consumed in order, so any cap is a prefix of
    /// the full pass — deterministic for a given split.
    pub fn act_absmax_n(
        &mut self,
        params: &ParamStore,
        max_samples: usize,
    ) -> Result<Vec<f32>> {
        let hb = self.mm.hist_batch;
        let outputs = self.outputs("absmax")?;
        let exe = self.ws.executable(&self.mm.name, "absmax")?;
        let pbufs = self.upload_params(params)?;
        let nb = self.ensure_batches("calib", hb)?;
        let mut maxes = vec![0f32; self.mm.taps.len()];
        let mut seen = 0usize;
        for i in 0..nb {
            if seen >= max_samples {
                break;
            }
            let valid = {
                let b = self.batch("calib", hb, i);
                let mut args: Vec<&xla::PjRtBuffer> = pbufs.iter().map(|b| &**b).collect();
                args.push(&b.x);
                let out = run_buffers(&exe, &args, &outputs)?;
                for (m, v) in maxes.iter_mut().zip(out[0].data()) {
                    if *v > *m {
                        *m = *v;
                    }
                }
                b.valid
            };
            seen += valid;
            self.counters.executions += 1;
            self.counters.inference_samples += valid as u64;
        }
        Ok(maxes)
    }

    /// Per-tap |activation| histograms over the calib split (calibration
    /// pass 2; `ranges` from [`Session::act_absmax`]). Returns a (taps ×
    /// hist_bins) row-major tensor of counts.
    pub fn act_hist(&mut self, params: &ParamStore, ranges: &[f32]) -> Result<Tensor> {
        self.act_hist_n(params, ranges, usize::MAX)
    }

    /// [`Session::act_hist`] capped at `max_samples` calibration images
    /// (same prefix-of-the-split contract as [`Session::act_absmax_n`]).
    pub fn act_hist_n(
        &mut self,
        params: &ParamStore,
        ranges: &[f32],
        max_samples: usize,
    ) -> Result<Tensor> {
        let hb = self.mm.hist_batch;
        let outputs = self.outputs("hist")?;
        let exe = self.ws.executable(&self.mm.name, "hist")?;
        let pbufs = self.upload_params(params)?;
        let rbuf = to_buffer(self.ws.client(), &Tensor::from_slice(ranges))?;
        let nb = self.ensure_batches("calib", hb)?;
        let taps = self.mm.taps.len();
        let bins = outputs[0].shape[1];
        let mut acc = Tensor::zeros(vec![taps, bins]);
        let mut seen = 0usize;
        for i in 0..nb {
            if seen >= max_samples {
                break;
            }
            let valid = {
                let b = self.batch("calib", hb, i);
                let mut args: Vec<&xla::PjRtBuffer> = pbufs.iter().map(|b| &**b).collect();
                args.push(&b.x);
                args.push(&rbuf);
                let out = run_buffers(&exe, &args, &outputs)?;
                for (a, v) in acc.data_mut().iter_mut().zip(out[0].data()) {
                    *a += v;
                }
                b.valid
            };
            seen += valid;
            self.counters.executions += 1;
            self.counters.inference_samples += valid as u64;
        }
        Ok(acc)
    }

    /// Raw logits of the FP32 eval artifact on an arbitrary input batch
    /// (used by integration tests and the quickstart example).
    pub fn eval_logits(&mut self, params: &ParamStore, x: &Tensor) -> Result<Tensor> {
        let eb = self.mm.eval_batch;
        if x.shape()[0] > eb {
            return Err(Error::shape(format!(
                "batch {} exceeds artifact batch {eb}",
                x.shape()[0]
            )));
        }
        let valid = x.shape()[0];
        let xp = x.pad_rows_to(eb)?;
        let outputs = self.outputs("eval")?;
        let exe = self.ws.executable(&self.mm.name, "eval")?;
        let pbufs = self.upload_params(params)?;
        let xbuf = to_buffer(self.ws.client(), &xp)?;
        let mut args: Vec<&xla::PjRtBuffer> = pbufs.iter().map(|b| &**b).collect();
        args.push(&xbuf);
        self.counters.executions += 1;
        self.counters.inference_samples += valid as u64;
        let out = run_buffers(&exe, &args, &outputs)?;
        out[0].rows(0, valid)
    }

    /// Number of samples in a split.
    pub fn split_len(&mut self, split: &str) -> Result<usize> {
        Ok(self.ensure_split(split)?.n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn decide_full(total: usize, correct: usize, baseline: f64, dmax: f64) -> bool {
        baseline - correct as f64 / total as f64 <= dmax
    }

    #[test]
    fn bounded_eval_completes_to_exact_accuracy() {
        let mut ev = BoundedEval::new(10, 2.0, 0.0); // unreachable baseline
        assert_eq!(ev.update(3, 5), BoundedVerdict::Reject); // pre-decided reject
        // fresh evaluator with a reachable threshold, run to completion
        let mut ev = BoundedEval::new(10, 0.9, 0.35);
        assert_eq!(ev.update(3, 5), BoundedVerdict::Undecided);
        let v = ev.update(3, 5);
        assert!(ev.is_complete());
        assert_eq!(ev.accuracy(), 0.6);
        assert_eq!(v == BoundedVerdict::Accept, decide_full(10, 6, 0.9, 0.35));
    }

    #[test]
    fn bounded_eval_early_accept() {
        // threshold = 0.5−0.2 = 0.3 → 3 correct of 10 forces accept
        let mut ev = BoundedEval::new(10, 0.5, 0.2);
        assert_eq!(ev.update(4, 4), BoundedVerdict::Accept);
        assert!(!ev.is_complete());
    }

    #[test]
    fn bounded_eval_early_reject() {
        // threshold 0.9: after 0/8 correct, best case 2/10 = 0.2 < 0.9
        let mut ev = BoundedEval::new(10, 0.95, 0.05);
        assert_eq!(ev.update(0, 8), BoundedVerdict::Reject);
        assert!(!ev.is_complete());
    }

    #[test]
    fn bounded_eval_degenerate_threshold_pre_decided() {
        // baseline ≤ delta_max: accept before any batch
        let ev = BoundedEval::new(10, 0.01, 0.05);
        assert_eq!(ev.verdict(), BoundedVerdict::Accept);
        assert_eq!(ev.accuracy(), 0.0);
    }
}
