//! Session: one model bound to the workspace, with device-resident dataset
//! caches and the measurement primitives the HQP pipeline is built from.
//!
//! Perf note (§Perf L3): dataset batches are uploaded to PJRT buffers once
//! per (split, batch-size) and reused for every execution — Algorithm 1
//! re-validates after every pruning step, so the x-batch upload would
//! otherwise dominate the loop. Parameters are re-uploaded per call (they
//! change between calls: masking / quantization), which is ~1 MB.

use std::collections::HashMap;

use crate::error::{Error, Result};
use crate::runtime::manifest::{ArgSpec, ModelManifest};
use crate::runtime::{run_buffers, to_buffer, to_buffer_i32, ParamStore, Workspace};
use crate::tensor::{count_correct, Tensor, TensorI32};

/// One uploaded batch (x on device, labels on host for the accuracy
/// reduction, y on device for gradient artifacts).
struct Batch {
    x: xla::PjRtBuffer,
    y: xla::PjRtBuffer,
    labels: Vec<i32>,
    valid: usize,
}

/// A dataset split with device-buffer caches keyed by batch size.
pub struct DataSet {
    pub n: usize,
    x: Tensor,
    y: TensorI32,
    batches: HashMap<usize, Vec<Batch>>,
}

/// Execution counters — the measured side of the paper's §III-C cost model
/// (C_HQP = calib·C_grad + T_prune·val·C_inf).
#[derive(Clone, Copy, Debug, Default)]
pub struct Counters {
    /// Forward-pass executions (eval/quant_eval/absmax/hist), in samples.
    pub inference_samples: u64,
    /// Backward-pass executions (fisher), in samples.
    pub grad_samples: u64,
    /// PJRT execute() calls.
    pub executions: u64,
}

/// One model + its datasets, bound to a [`Workspace`].
pub struct Session<'w> {
    pub ws: &'w Workspace,
    pub mm: ModelManifest,
    /// Pristine trained parameters (the paper's M_train).
    pub baseline: ParamStore,
    data: HashMap<String, DataSet>,
    pub counters: Counters,
}

impl<'w> Session<'w> {
    pub fn new(ws: &'w Workspace, model: &str) -> Result<Session<'w>> {
        let mm = ws.manifest.model(model)?.clone();
        let baseline = ParamStore::load(&ws.root, &mm)?;
        Ok(Session {
            ws,
            mm,
            baseline,
            data: HashMap::new(),
            counters: Counters::default(),
        })
    }

    /// Ensure `split` is loaded and batched at `batch` rows (device upload);
    /// returns the number of batches.
    fn ensure_batches(&mut self, split: &str, batch: usize) -> Result<usize> {
        if !self.data.contains_key(split) {
            let (x, y) = self.ws.load_split(split)?;
            self.data.insert(
                split.to_string(),
                DataSet { n: x.shape()[0], x, y, batches: HashMap::new() },
            );
        }
        let client = self.ws.client().clone();
        let ds = self.data.get_mut(split).unwrap();
        if !ds.batches.contains_key(&batch) {
            let mut list = Vec::new();
            let n = ds.n;
            let mut lo = 0usize;
            while lo < n {
                let hi = (lo + batch).min(n);
                let xb = ds.x.rows(lo, hi)?.pad_rows_to(batch)?;
                let yb = ds.y.rows(lo, hi)?.pad_rows_to(batch)?;
                list.push(Batch {
                    x: to_buffer(&client, &xb)?,
                    y: to_buffer_i32(&client, &yb)?,
                    labels: yb.data()[..hi - lo].to_vec(),
                    valid: hi - lo,
                });
                lo = hi;
            }
            ds.batches.insert(batch, list);
        }
        Ok(ds.batches[&batch].len())
    }

    fn batch(&self, split: &str, batch: usize, i: usize) -> &Batch {
        &self.data[split].batches[&batch][i]
    }

    /// Upload the parameter list once for a sequence of executions.
    fn upload_params(&self, params: &ParamStore) -> Result<Vec<xla::PjRtBuffer>> {
        params
            .tensors()
            .iter()
            .map(|t| to_buffer(self.ws.client(), t))
            .collect()
    }

    fn outputs(&self, fn_name: &str) -> Result<Vec<ArgSpec>> {
        Ok(self
            .mm
            .artifacts
            .get(fn_name)
            .ok_or_else(|| Error::manifest(format!("no artifact '{fn_name}'")))?
            .outputs
            .clone())
    }

    /// Top-1 accuracy of `params` on `split` through the FP32 eval artifact.
    pub fn accuracy(&mut self, params: &ParamStore, split: &str) -> Result<f64> {
        let eb = self.mm.eval_batch;
        let outputs = self.outputs("eval")?;
        let exe = self.ws.executable(&self.mm.name, "eval")?;
        let pbufs = self.upload_params(params)?;
        let nb = self.ensure_batches(split, eb)?;
        let mut correct = 0usize;
        let mut total = 0usize;
        for i in 0..nb {
            let valid = {
                let b = self.batch(split, eb, i);
                let mut args: Vec<&xla::PjRtBuffer> = pbufs.iter().collect();
                args.push(&b.x);
                let out = run_buffers(&exe, &args, &outputs)?;
                correct += count_correct(&out[0], &b.labels, b.valid);
                total += b.valid;
                b.valid
            };
            self.counters.executions += 1;
            self.counters.inference_samples += valid as u64;
        }
        Ok(correct as f64 / total as f64)
    }

    /// Top-1 accuracy through the fake-quant INT8 artifact (Pallas qmatmul
    /// hot spots), with per-tensor activation `scales` (len = taps).
    pub fn quant_accuracy(
        &mut self,
        params: &ParamStore,
        scales: &[f32],
        split: &str,
    ) -> Result<f64> {
        if scales.len() != self.mm.taps.len() {
            return Err(Error::hqp(format!(
                "scales len {} != taps {}",
                scales.len(),
                self.mm.taps.len()
            )));
        }
        let eb = self.mm.eval_batch;
        let outputs = self.outputs("quant_eval")?;
        let exe = self.ws.executable(&self.mm.name, "quant_eval")?;
        let pbufs = self.upload_params(params)?;
        let sbuf = to_buffer(self.ws.client(), &Tensor::from_slice(scales))?;
        let nb = self.ensure_batches(split, eb)?;
        let mut correct = 0usize;
        let mut total = 0usize;
        for i in 0..nb {
            let valid = {
                let b = self.batch(split, eb, i);
                let mut args: Vec<&xla::PjRtBuffer> = pbufs.iter().collect();
                args.push(&sbuf);
                args.push(&b.x);
                let out = run_buffers(&exe, &args, &outputs)?;
                correct += count_correct(&out[0], &b.labels, b.valid);
                total += b.valid;
                b.valid
            };
            self.counters.executions += 1;
            self.counters.inference_samples += valid as u64;
        }
        Ok(correct as f64 / total as f64)
    }

    /// Fisher sensitivity vector S over (up to) `max_samples` of the calib
    /// split: S_f = (1/N) Σ_i ||∂L_i/∂W_f||² — paper §II-B. One backward
    /// pass over D_calib, exactly as Algorithm 1 line 7 prescribes.
    pub fn fisher_scores(
        &mut self,
        params: &ParamStore,
        max_samples: usize,
    ) -> Result<Vec<f32>> {
        let fb = self.mm.fisher_batch;
        let outputs = self.outputs("fisher")?;
        let exe = self.ws.executable(&self.mm.name, "fisher")?;
        let pbufs = self.upload_params(params)?;
        let nb = self.ensure_batches("calib", fb)?;
        let mut acc = vec![0f32; self.mm.total_filters()];
        let mut seen = 0usize;
        for i in 0..nb {
            if seen >= max_samples {
                break;
            }
            let valid = {
                let b = self.batch("calib", fb, i);
                let mut args: Vec<&xla::PjRtBuffer> = pbufs.iter().collect();
                args.push(&b.x);
                args.push(&b.y);
                let out = run_buffers(&exe, &args, &outputs)?;
                for (a, v) in acc.iter_mut().zip(out[0].data()) {
                    *a += v;
                }
                seen += b.valid;
                b.valid
            };
            self.counters.executions += 1;
            self.counters.grad_samples += valid as u64;
        }
        if seen == 0 {
            return Err(Error::hqp("fisher: no calibration samples"));
        }
        let inv = 1.0 / seen as f32;
        for a in &mut acc {
            *a *= inv;
        }
        Ok(acc)
    }

    /// Per-tap max |activation| over the calib split (calibration pass 1).
    pub fn act_absmax(&mut self, params: &ParamStore) -> Result<Vec<f32>> {
        let hb = self.mm.hist_batch;
        let outputs = self.outputs("absmax")?;
        let exe = self.ws.executable(&self.mm.name, "absmax")?;
        let pbufs = self.upload_params(params)?;
        let nb = self.ensure_batches("calib", hb)?;
        let mut maxes = vec![0f32; self.mm.taps.len()];
        for i in 0..nb {
            let valid = {
                let b = self.batch("calib", hb, i);
                let mut args: Vec<&xla::PjRtBuffer> = pbufs.iter().collect();
                args.push(&b.x);
                let out = run_buffers(&exe, &args, &outputs)?;
                for (m, v) in maxes.iter_mut().zip(out[0].data()) {
                    if *v > *m {
                        *m = *v;
                    }
                }
                b.valid
            };
            self.counters.executions += 1;
            self.counters.inference_samples += valid as u64;
        }
        Ok(maxes)
    }

    /// Per-tap |activation| histograms over the calib split (calibration
    /// pass 2; `ranges` from [`Session::act_absmax`]). Returns a (taps ×
    /// hist_bins) row-major tensor of counts.
    pub fn act_hist(&mut self, params: &ParamStore, ranges: &[f32]) -> Result<Tensor> {
        let hb = self.mm.hist_batch;
        let outputs = self.outputs("hist")?;
        let exe = self.ws.executable(&self.mm.name, "hist")?;
        let pbufs = self.upload_params(params)?;
        let rbuf = to_buffer(self.ws.client(), &Tensor::from_slice(ranges))?;
        let nb = self.ensure_batches("calib", hb)?;
        let taps = self.mm.taps.len();
        let bins = outputs[0].shape[1];
        let mut acc = Tensor::zeros(vec![taps, bins]);
        for i in 0..nb {
            let valid = {
                let b = self.batch("calib", hb, i);
                let mut args: Vec<&xla::PjRtBuffer> = pbufs.iter().collect();
                args.push(&b.x);
                args.push(&rbuf);
                let out = run_buffers(&exe, &args, &outputs)?;
                for (a, v) in acc.data_mut().iter_mut().zip(out[0].data()) {
                    *a += v;
                }
                b.valid
            };
            self.counters.executions += 1;
            self.counters.inference_samples += valid as u64;
        }
        Ok(acc)
    }

    /// Raw logits of the FP32 eval artifact on an arbitrary input batch
    /// (used by integration tests and the quickstart example).
    pub fn eval_logits(&mut self, params: &ParamStore, x: &Tensor) -> Result<Tensor> {
        let eb = self.mm.eval_batch;
        if x.shape()[0] > eb {
            return Err(Error::shape(format!(
                "batch {} exceeds artifact batch {eb}",
                x.shape()[0]
            )));
        }
        let valid = x.shape()[0];
        let xp = x.pad_rows_to(eb)?;
        let outputs = self.outputs("eval")?;
        let exe = self.ws.executable(&self.mm.name, "eval")?;
        let pbufs = self.upload_params(params)?;
        let xbuf = to_buffer(self.ws.client(), &xp)?;
        let mut args: Vec<&xla::PjRtBuffer> = pbufs.iter().collect();
        args.push(&xbuf);
        self.counters.executions += 1;
        self.counters.inference_samples += valid as u64;
        let out = run_buffers(&exe, &args, &outputs)?;
        out[0].rows(0, valid)
    }

    /// Number of samples in a split.
    pub fn split_len(&mut self, split: &str) -> Result<usize> {
        if !self.data.contains_key(split) {
            let (x, y) = self.ws.load_split(split)?;
            self.data.insert(
                split.to_string(),
                DataSet { n: x.shape()[0], x, y, batches: HashMap::new() },
            );
        }
        Ok(self.data[split].n)
    }
}
