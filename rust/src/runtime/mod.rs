//! L3 runtime: loads the AOT artifacts and executes them on the PJRT CPU
//! client (`xla` crate → xla_extension 0.5.1).
//!
//! Pattern (see /opt/xla-example): HLO **text** → `HloModuleProto::
//! from_text_file` → `XlaComputation::from_proto` → `client.compile` →
//! `execute`. Artifacts are compiled lazily and cached for the process
//! lifetime; dataset batches are uploaded to device buffers once per split
//! and parameter tensors stay device-resident behind a version-stamped
//! buffer cache, so each Algorithm-1 step re-uploads only the δ filters'
//! touched tensors (the validation sweep is the coordinator's hot path —
//! see EXPERIMENTS.md §Perf and the caching contract atop `session.rs`).

pub mod manifest;
mod params;
mod session;

pub use manifest::{ArtifactSpec, DType, GroupSpec, Manifest, ModelManifest, OpSpec, TapSpec};
pub use params::ParamStore;
pub use session::{
    BoundedAccuracy, BoundedEval, BoundedVerdict, Counters, DataSet, Session,
};

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::tensor::{Tensor, TensorI32};

/// An opened artifacts directory: manifest + PJRT client + executable cache.
pub struct Workspace {
    pub root: PathBuf,
    pub manifest: Manifest,
    client: xla::PjRtClient,
    execs: RefCell<HashMap<String, std::rc::Rc<xla::PjRtLoadedExecutable>>>,
}

impl Workspace {
    /// Open `<root>/manifest.json` and create the PJRT CPU client.
    pub fn open(root: impl AsRef<Path>) -> Result<Workspace> {
        let root = root.as_ref().to_path_buf();
        let manifest = Manifest::load(&root)?;
        let client = xla::PjRtClient::cpu().map_err(wrap_xla)?;
        Ok(Workspace {
            root,
            manifest,
            client,
            execs: RefCell::new(HashMap::new()),
        })
    }

    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }

    /// PJRT platform string (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch the cached) executable for `<model>_<fn>`.
    pub fn executable(
        &self,
        model: &str,
        fn_name: &str,
    ) -> Result<std::rc::Rc<xla::PjRtLoadedExecutable>> {
        let key = format!("{model}_{fn_name}");
        if let Some(e) = self.execs.borrow().get(&key) {
            return Ok(e.clone());
        }
        let mm = self.manifest.model(model)?;
        let art = mm
            .artifacts
            .get(fn_name)
            .ok_or_else(|| Error::manifest(format!("{model}: no artifact '{fn_name}'")))?;
        let path = self.root.join(&art.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| Error::manifest("non-utf8 artifact path"))?,
        )
        .map_err(wrap_xla)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).map_err(wrap_xla)?;
        let rc = std::rc::Rc::new(exe);
        self.execs.borrow_mut().insert(key, rc.clone());
        Ok(rc)
    }

    /// Load one dataset split (x f32 + y i32) from the artifacts dir.
    pub fn load_split(&self, split: &str) -> Result<(Tensor, TensorI32)> {
        let d = self
            .manifest
            .data
            .get(split)
            .ok_or_else(|| Error::manifest(format!("unknown split {split}")))?;
        let x = crate::formats::npy::read_npy_f32(self.root.join(&d.x))?;
        let y = crate::formats::npy::read_npy_i32(self.root.join(&d.y))?;
        if x.shape()[0] != d.n || y.shape()[0] != d.n {
            return Err(Error::manifest(format!(
                "split {split}: shape mismatch vs manifest n={}",
                d.n
            )));
        }
        Ok((x, y))
    }
}

pub(crate) fn wrap_xla<E: std::fmt::Display>(e: E) -> Error {
    Error::Xla(e.to_string())
}

/// Upload an f32 tensor to a device buffer.
pub fn to_buffer(client: &xla::PjRtClient, t: &Tensor) -> Result<xla::PjRtBuffer> {
    client
        .buffer_from_host_buffer(t.data(), t.shape(), None)
        .map_err(wrap_xla)
}

/// Upload an i32 tensor to a device buffer.
pub fn to_buffer_i32(client: &xla::PjRtClient, t: &TensorI32) -> Result<xla::PjRtBuffer> {
    client
        .buffer_from_host_buffer(t.data(), t.shape(), None)
        .map_err(wrap_xla)
}

/// Execute with pre-uploaded buffers; decompose the 1-tuple output into
/// host tensors shaped per the artifact output spec.
pub fn run_buffers(
    exe: &xla::PjRtLoadedExecutable,
    args: &[&xla::PjRtBuffer],
    outputs: &[manifest::ArgSpec],
) -> Result<Vec<Tensor>> {
    let results = exe.execute_b(args).map_err(wrap_xla)?;
    let out = results
        .first()
        .and_then(|r| r.first())
        .ok_or_else(|| Error::Xla("empty execution result".into()))?;
    let lit = out.to_literal_sync().map_err(wrap_xla)?;
    let parts = lit.to_tuple().map_err(wrap_xla)?;
    if parts.len() != outputs.len() {
        return Err(Error::Xla(format!(
            "expected {} outputs, got {}",
            outputs.len(),
            parts.len()
        )));
    }
    parts
        .iter()
        .zip(outputs)
        .map(|(p, spec)| {
            let v = p.to_vec::<f32>().map_err(wrap_xla)?;
            Tensor::new(spec.shape.clone(), v)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    // Workspace/Session round-trips against real artifacts live in
    // rust/tests/integration_runtime.rs (they need `make artifacts`).
}
