//! Parameter store: the in-memory copy of a model's weights that the HQP
//! pipeline mutates (filter masking, INT8 grid projection) and feeds to the
//! AOT executables as leading arguments.
//!
//! The store is **copy-on-write**: each slot holds an `Arc<Tensor>` plus a
//! version stamp, so `clone()` is O(slots) — pointer bumps, not byte copies
//! — and Algorithm 1's per-candidate clone in the accept/reject loop costs
//! nothing until a tensor is actually written. Every mutation (masking, PTQ
//! substitution) goes through [`ParamStore::get_mut`], which un-shares just
//! the touched tensor (`Arc::make_mut`) and stamps it with a fresh,
//! process-globally-unique version. The [`crate::runtime::Session`] keys its
//! device-buffer cache on `(slot, version)`, so an unchanged tensor — by far
//! the common case per δ-step — is never re-uploaded.

use std::collections::HashMap;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::error::{Error, Result};
use crate::formats::npy::read_npy_f32;
use crate::runtime::manifest::{GroupSpec, ModelManifest};
use crate::tensor::Tensor;

/// Process-global version source. Versions must be unique across *all*
/// stores (two sibling clones that each mutate the same slot must end up
/// with different stamps, or the session buffer cache would serve one
/// candidate's weights to the other), so a single atomic counter hands out
/// every stamp.
static NEXT_VERSION: AtomicU64 = AtomicU64::new(1);

fn fresh_version() -> u64 {
    NEXT_VERSION.fetch_add(1, Ordering::Relaxed)
}

/// One copy-on-write tensor slot.
#[derive(Clone, Debug)]
struct Slot {
    tensor: Arc<Tensor>,
    version: u64,
}

/// Ordered parameter tensors + name index, with per-slot version stamps.
/// Cloning shares every tensor (and the index) until a writer un-shares it.
#[derive(Clone, Debug)]
pub struct ParamStore {
    slots: Vec<Slot>,
    index: Arc<HashMap<String, usize>>,
}

impl ParamStore {
    fn from_parts(tensors: Vec<Tensor>, index: HashMap<String, usize>) -> ParamStore {
        ParamStore {
            slots: tensors
                .into_iter()
                .map(|t| Slot { tensor: Arc::new(t), version: fresh_version() })
                .collect(),
            index: Arc::new(index),
        }
    }

    /// Load `p0000.npy..` from the model's weights dir, in manifest order.
    pub fn load(root: &Path, mm: &ModelManifest) -> Result<ParamStore> {
        let dir = root.join(&mm.weights_dir);
        let mut tensors = Vec::with_capacity(mm.param_order.len());
        let mut index = HashMap::new();
        for (i, spec) in mm.param_order.iter().enumerate() {
            let t = read_npy_f32(dir.join(format!("p{i:04}.npy")))?;
            if t.shape() != spec.shape.as_slice() {
                return Err(Error::manifest(format!(
                    "param {} ({}): shape {:?} != manifest {:?}",
                    i,
                    spec.name,
                    t.shape(),
                    spec.shape
                )));
            }
            index.insert(spec.name.clone(), i);
            tensors.push(t);
        }
        Ok(ParamStore::from_parts(tensors, index))
    }

    /// Build from raw tensors (tests).
    pub fn from_tensors(named: Vec<(String, Tensor)>) -> ParamStore {
        let mut tensors = Vec::new();
        let mut index = HashMap::new();
        for (i, (n, t)) in named.into_iter().enumerate() {
            index.insert(n, i);
            tensors.push(t);
        }
        ParamStore::from_parts(tensors, index)
    }

    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// Borrow every tensor in slot order (diagnostics; the upload hot path
    /// uses [`ParamStore::tensor`]/[`ParamStore::version`] per slot).
    pub fn tensors(&self) -> Vec<&Tensor> {
        self.slots.iter().map(|s| s.tensor.as_ref()).collect()
    }

    /// Tensor in slot `i` (panics out of range, like slice indexing).
    pub fn tensor(&self, i: usize) -> &Tensor {
        self.slots[i].tensor.as_ref()
    }

    /// Version stamp of slot `i`. Stamps are process-globally unique: equal
    /// stamps imply identical bytes, across clones of the same lineage.
    pub fn version(&self, i: usize) -> u64 {
        self.slots[i].version
    }

    fn slot_index(&self, name: &str) -> Result<usize> {
        self.index
            .get(name)
            .copied()
            .ok_or_else(|| Error::manifest(format!("unknown param {name}")))
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        let i = self.slot_index(name)?;
        Ok(self.slots[i].tensor.as_ref())
    }

    /// Mutable access: un-shares the slot's tensor (copy-on-write) and
    /// stamps a fresh version, invalidating any device buffer cached for it.
    pub fn get_mut(&mut self, name: &str) -> Result<&mut Tensor> {
        let i = self.slot_index(name)?;
        let slot = &mut self.slots[i];
        slot.version = fresh_version();
        Ok(Arc::make_mut(&mut slot.tensor))
    }

    /// Replace a tensor wholesale (PTQ weight substitution).
    pub fn set(&mut self, name: &str, t: Tensor) -> Result<()> {
        let i = self.slot_index(name)?;
        if self.slots[i].tensor.shape() != t.shape() {
            return Err(Error::shape(format!(
                "set {name}: shape {:?} != {:?}",
                t.shape(),
                self.slots[i].tensor.shape()
            )));
        }
        self.slots[i] = Slot { tensor: Arc::new(t), version: fresh_version() };
        Ok(())
    }

    /// Mask (zero) channel `j` of a prune group across all its members.
    /// This IS structural pruning under the fixed-shape artifact contract
    /// (DESIGN.md §2). Only the member tensors' versions are bumped.
    pub fn mask_filter(&mut self, group: &GroupSpec, j: usize) -> Result<()> {
        if j >= group.size {
            return Err(Error::hqp(format!(
                "filter {j} out of range for group {} (size {})",
                group.name, group.size
            )));
        }
        for (pname, axis) in &group.members {
            self.get_mut(pname)?.zero_slice(*axis, j)?;
        }
        Ok(())
    }

    /// Total parameter count.
    pub fn num_elements(&self) -> usize {
        self.slots.iter().map(|s| s.tensor.len()).sum()
    }

    /// Total parameter bytes (f32 payload; what a cold upload moves).
    pub fn num_bytes(&self) -> usize {
        self.num_elements() * std::mem::size_of::<f32>()
    }

    /// Count of exactly-zero elements (masked sparsity diagnostics).
    pub fn num_zero(&self) -> usize {
        self.slots
            .iter()
            .map(|s| s.tensor.data().iter().filter(|v| **v == 0.0).count())
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn store() -> ParamStore {
        ParamStore::from_tensors(vec![
            ("c.w".into(), Tensor::full(vec![3, 3, 2, 4], 1.0)),
            ("c.gamma".into(), Tensor::full(vec![4], 2.0)),
            ("c.beta".into(), Tensor::full(vec![4], 3.0)),
        ])
    }

    fn group() -> GroupSpec {
        GroupSpec {
            id: 0,
            name: "c".into(),
            size: 4,
            offset: 0,
            members: vec![("c.w".into(), 3), ("c.gamma".into(), 0), ("c.beta".into(), 0)],
            producer: "c.w".into(),
            producer_axis: 3,
        }
    }

    #[test]
    fn mask_filter_zeroes_all_members() {
        let mut s = store();
        s.mask_filter(&group(), 1).unwrap();
        assert_eq!(s.get("c.gamma").unwrap().data()[1], 0.0);
        assert_eq!(s.get("c.beta").unwrap().data()[1], 0.0);
        assert_eq!(s.get("c.gamma").unwrap().data()[0], 2.0);
        // conv weight: out-channel 1 of every (k,k,i) position is zero
        let w = s.get("c.w").unwrap();
        for (i, &v) in w.data().iter().enumerate() {
            if i % 4 == 1 {
                assert_eq!(v, 0.0);
            } else {
                assert_eq!(v, 1.0);
            }
        }
        assert_eq!(s.num_zero(), 9 * 2 + 2);
    }

    #[test]
    fn mask_filter_range_checked() {
        let mut s = store();
        assert!(s.mask_filter(&group(), 4).is_err());
    }

    #[test]
    fn set_validates_shape() {
        let mut s = store();
        assert!(s.set("c.gamma", Tensor::zeros(vec![5])).is_err());
        assert!(s.set("c.gamma", Tensor::zeros(vec![4])).is_ok());
        assert_eq!(s.get("c.gamma").unwrap().data()[0], 0.0);
    }

    #[test]
    fn clone_shares_until_write() {
        let s = store();
        let mut c = s.clone();
        // clone keeps every version: nothing to re-upload
        for i in 0..s.len() {
            assert_eq!(s.version(i), c.version(i));
        }
        // writing through the clone un-shares exactly one slot
        c.get_mut("c.gamma").unwrap().data_mut()[0] = 9.0;
        assert_eq!(s.get("c.gamma").unwrap().data()[0], 2.0, "original untouched");
        assert_eq!(c.get("c.gamma").unwrap().data()[0], 9.0);
        assert_ne!(s.version(1), c.version(1), "touched slot re-stamped");
        assert_eq!(s.version(0), c.version(0), "untouched slots still shared");
        assert_eq!(s.version(2), c.version(2));
    }

    #[test]
    fn mask_filter_bumps_only_member_versions() {
        let mut s = store();
        let before: Vec<u64> = (0..s.len()).map(|i| s.version(i)).collect();
        // a group touching only gamma: beta/w keep their stamps
        let g = GroupSpec {
            id: 0,
            name: "c".into(),
            size: 4,
            offset: 0,
            members: vec![("c.gamma".into(), 0)],
            producer: "c.w".into(),
            producer_axis: 3,
        };
        s.mask_filter(&g, 2).unwrap();
        assert_eq!(s.version(0), before[0], "c.w not a member: stamp kept");
        assert_ne!(s.version(1), before[1], "c.gamma masked: stamp bumped");
        assert_eq!(s.version(2), before[2], "c.beta not a member: stamp kept");
    }

    #[test]
    fn sibling_clones_get_distinct_versions() {
        // Two candidates forked from the same store must never collide on a
        // (slot, version) key even when both mutate the same slot.
        let s = store();
        let mut a = s.clone();
        let mut b = s.clone();
        a.get_mut("c.w").unwrap().data_mut()[0] = 1.5;
        b.get_mut("c.w").unwrap().data_mut()[0] = 2.5;
        assert_ne!(a.version(0), b.version(0));
    }

    #[test]
    fn set_restamps_slot() {
        let mut s = store();
        let v0 = s.version(1);
        s.set("c.gamma", Tensor::zeros(vec![4])).unwrap();
        assert_ne!(s.version(1), v0);
    }
}
