//! Parameter store: the in-memory copy of a model's weights that the HQP
//! pipeline mutates (filter masking, INT8 grid projection) and feeds to the
//! AOT executables as leading arguments.

use std::collections::HashMap;
use std::path::Path;

use crate::error::{Error, Result};
use crate::formats::npy::read_npy_f32;
use crate::runtime::manifest::{GroupSpec, ModelManifest};
use crate::tensor::Tensor;

/// Ordered parameter tensors + name index. Cloning is cheap enough at the
/// model sizes involved (<1 MB) and is how candidate models are built in
/// Algorithm 1's accept/reject loop.
#[derive(Clone, Debug)]
pub struct ParamStore {
    tensors: Vec<Tensor>,
    index: HashMap<String, usize>,
}

impl ParamStore {
    /// Load `p0000.npy..` from the model's weights dir, in manifest order.
    pub fn load(root: &Path, mm: &ModelManifest) -> Result<ParamStore> {
        let dir = root.join(&mm.weights_dir);
        let mut tensors = Vec::with_capacity(mm.param_order.len());
        let mut index = HashMap::new();
        for (i, spec) in mm.param_order.iter().enumerate() {
            let t = read_npy_f32(dir.join(format!("p{i:04}.npy")))?;
            if t.shape() != spec.shape.as_slice() {
                return Err(Error::manifest(format!(
                    "param {} ({}): shape {:?} != manifest {:?}",
                    i,
                    spec.name,
                    t.shape(),
                    spec.shape
                )));
            }
            index.insert(spec.name.clone(), i);
            tensors.push(t);
        }
        Ok(ParamStore { tensors, index })
    }

    /// Build from raw tensors (tests).
    pub fn from_tensors(named: Vec<(String, Tensor)>) -> ParamStore {
        let mut tensors = Vec::new();
        let mut index = HashMap::new();
        for (i, (n, t)) in named.into_iter().enumerate() {
            index.insert(n, i);
            tensors.push(t);
        }
        ParamStore { tensors, index }
    }

    pub fn len(&self) -> usize {
        self.tensors.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tensors.is_empty()
    }

    pub fn tensors(&self) -> &[Tensor] {
        &self.tensors
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        let i = *self
            .index
            .get(name)
            .ok_or_else(|| Error::manifest(format!("unknown param {name}")))?;
        Ok(&self.tensors[i])
    }

    pub fn get_mut(&mut self, name: &str) -> Result<&mut Tensor> {
        let i = *self
            .index
            .get(name)
            .ok_or_else(|| Error::manifest(format!("unknown param {name}")))?;
        Ok(&mut self.tensors[i])
    }

    /// Replace a tensor wholesale (PTQ weight substitution).
    pub fn set(&mut self, name: &str, t: Tensor) -> Result<()> {
        let cur = self.get_mut(name)?;
        if cur.shape() != t.shape() {
            return Err(Error::shape(format!(
                "set {name}: shape {:?} != {:?}",
                t.shape(),
                cur.shape()
            )));
        }
        *cur = t;
        Ok(())
    }

    /// Mask (zero) channel `j` of a prune group across all its members.
    /// This IS structural pruning under the fixed-shape artifact contract
    /// (DESIGN.md §2).
    pub fn mask_filter(&mut self, group: &GroupSpec, j: usize) -> Result<()> {
        if j >= group.size {
            return Err(Error::hqp(format!(
                "filter {j} out of range for group {} (size {})",
                group.name, group.size
            )));
        }
        for (pname, axis) in &group.members {
            self.get_mut(pname)?.zero_slice(*axis, j)?;
        }
        Ok(())
    }

    /// Total parameter count.
    pub fn num_elements(&self) -> usize {
        self.tensors.iter().map(|t| t.len()).sum()
    }

    /// Count of exactly-zero elements (masked sparsity diagnostics).
    pub fn num_zero(&self) -> usize {
        self.tensors
            .iter()
            .map(|t| t.data().iter().filter(|v| **v == 0.0).count())
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn store() -> ParamStore {
        ParamStore::from_tensors(vec![
            ("c.w".into(), Tensor::full(vec![3, 3, 2, 4], 1.0)),
            ("c.gamma".into(), Tensor::full(vec![4], 2.0)),
            ("c.beta".into(), Tensor::full(vec![4], 3.0)),
        ])
    }

    fn group() -> GroupSpec {
        GroupSpec {
            id: 0,
            name: "c".into(),
            size: 4,
            offset: 0,
            members: vec![("c.w".into(), 3), ("c.gamma".into(), 0), ("c.beta".into(), 0)],
            producer: "c.w".into(),
            producer_axis: 3,
        }
    }

    #[test]
    fn mask_filter_zeroes_all_members() {
        let mut s = store();
        s.mask_filter(&group(), 1).unwrap();
        assert_eq!(s.get("c.gamma").unwrap().data()[1], 0.0);
        assert_eq!(s.get("c.beta").unwrap().data()[1], 0.0);
        assert_eq!(s.get("c.gamma").unwrap().data()[0], 2.0);
        // conv weight: out-channel 1 of every (k,k,i) position is zero
        let w = s.get("c.w").unwrap();
        for (i, &v) in w.data().iter().enumerate() {
            if i % 4 == 1 {
                assert_eq!(v, 0.0);
            } else {
                assert_eq!(v, 1.0);
            }
        }
        assert_eq!(s.num_zero(), 9 * 2 + 2);
    }

    #[test]
    fn mask_filter_range_checked() {
        let mut s = store();
        assert!(s.mask_filter(&group(), 4).is_err());
    }

    #[test]
    fn set_validates_shape() {
        let mut s = store();
        assert!(s.set("c.gamma", Tensor::zeros(vec![5])).is_err());
        assert!(s.set("c.gamma", Tensor::zeros(vec![4])).is_ok());
        assert_eq!(s.get("c.gamma").unwrap().data()[0], 0.0);
    }
}
