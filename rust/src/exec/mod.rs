//! Deterministic parallel execution core: a message-passing worker pool
//! over `std::thread` (the crate is offline — no rayon/tokio; see
//! DESIGN.md §Substitutions and §Parallelism).
//!
//! The pool fans a `Vec` of tasks out to N workers over a shared atomic
//! claim counter (each `fetch_add` is one "message"; an idle worker steals
//! the next unclaimed index, so the schedule is work-stealing in effect
//! even though no deques change hands). Determinism contract:
//!
//! * **Results merge in submission order.** Slot `i` of the output is
//!   task `i`'s result regardless of which worker ran it or when it
//!   finished, so callers observe byte-identical output for any `--jobs`.
//! * **Errors are deterministic.** Every task runs to completion even if
//!   an earlier one failed; the pool then reports the error of the
//!   *lowest-indexed* failing task, so jobs=1 and jobs=N surface the same
//!   failure.
//! * **Panics are hard errors, not hangs.** A panicking task is caught at
//!   the worker boundary (`catch_unwind`) and converted to
//!   [`Error::Hqp`]; the pool always joins and returns.
//!
//! Workers build their state lazily via the `init` closure on the first
//! task they claim — this is how `coordinator` gives each worker its own
//! `Workspace` (PJRT clients are not `Send`, so they must be *born* on
//! the worker thread) and its own CoW `ParamStore`/`Session` cache.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::error::{Error, Result};

/// Validated parallelism level (`--jobs N`). Zero is rejected loudly at
/// construction, so every downstream consumer can rely on `get() >= 1`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Jobs(usize);

impl Jobs {
    /// `N >= 1` workers. `N == 0` is a configuration error, not "auto".
    pub fn new(n: usize) -> Result<Self> {
        if n == 0 {
            return Err(Error::Cli(
                "--jobs 0 is invalid: pass --jobs N with N >= 1, or omit the flag \
                 to use all available cores"
                    .into(),
            ));
        }
        Ok(Jobs(n))
    }

    /// The sequential fast path.
    pub fn one() -> Self {
        Jobs(1)
    }

    /// Available parallelism of the host (>= 1; falls back to 1 when the
    /// OS refuses to say).
    pub fn available() -> Self {
        Jobs(std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1))
    }

    pub fn get(self) -> usize {
        self.0
    }
}

/// Per-worker counters, reported so speedups are measured, not asserted.
#[derive(Clone, Copy, Debug, Default)]
pub struct WorkerStats {
    /// Worker index (0 = the calling thread).
    pub worker: usize,
    /// Tasks this worker executed.
    pub tasks: u64,
    /// Claim messages sent (successful claims + the final empty probe).
    pub messages: u64,
    /// Wall-clock spent inside task bodies.
    pub busy_ms: f64,
}

/// What one pool run looked like: shape, wall-clock, per-worker load and
/// per-task latency (submission order). Threaded into benchkit reports by
/// the benches and printed by `hqp run --jobs N`.
#[derive(Clone, Debug, Default)]
pub struct PoolReport {
    pub jobs: usize,
    pub tasks: usize,
    pub wall_ms: f64,
    pub workers: Vec<WorkerStats>,
    /// Wall-clock per task, in submission order.
    pub task_ms: Vec<f64>,
}

impl PoolReport {
    /// Sum of per-task wall-clock — the sequential-equivalent cost. The
    /// measured speedup is `busy_ms_total / wall_ms`.
    pub fn busy_ms_total(&self) -> f64 {
        self.workers.iter().map(|w| w.busy_ms).sum()
    }

    /// One human line per worker (for `--jobs` verbose output).
    pub fn render(&self) -> String {
        let mut out = format!(
            "pool: {} task(s) on {} worker(s) in {:.1} ms (busy {:.1} ms, {:.2}x)\n",
            self.tasks,
            self.jobs,
            self.wall_ms,
            self.busy_ms_total(),
            if self.wall_ms > 0.0 { self.busy_ms_total() / self.wall_ms } else { 1.0 },
        );
        for w in &self.workers {
            out.push_str(&format!(
                "  worker {}: {} task(s), {} message(s), busy {:.1} ms\n",
                w.worker, w.tasks, w.messages, w.busy_ms
            ));
        }
        out
    }
}

fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "<non-string panic payload>".to_string()
    }
}

fn lock_ignore_poison<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    // A poisoned mutex means another task panicked; panics are already
    // converted to errors, so the data is still well-defined for us.
    m.lock().unwrap_or_else(|p| p.into_inner())
}

/// Run `tasks` through `work` on up to `jobs` workers; results come back
/// in submission order. `init(worker)` builds per-worker state lazily on
/// the worker's own thread (first claimed task).
///
/// See the module docs for the determinism contract.
pub fn parallel_map_init<T, R, W, I, F>(
    jobs: Jobs,
    tasks: Vec<T>,
    init: I,
    work: F,
) -> Result<(Vec<R>, PoolReport)>
where
    T: Send,
    R: Send,
    I: Fn(usize) -> Result<W> + Sync,
    F: Fn(&mut W, T, usize) -> Result<R> + Sync,
{
    let n = tasks.len();
    let workers = jobs.get().min(n).max(1);
    let started = Instant::now();

    let slots: Vec<Mutex<Option<T>>> = tasks.into_iter().map(|t| Mutex::new(Some(t))).collect();
    let results: Vec<Mutex<Option<Result<R>>>> = (0..n).map(|_| Mutex::new(None)).collect();
    let task_ms: Vec<Mutex<f64>> = (0..n).map(|_| Mutex::new(0.0)).collect();
    let stats: Vec<Mutex<WorkerStats>> = (0..workers)
        .map(|w| Mutex::new(WorkerStats { worker: w, ..WorkerStats::default() }))
        .collect();
    let next = AtomicUsize::new(0);

    let run_worker = |w: usize| {
        let mut state: Option<W> = None;
        loop {
            let i = next.fetch_add(1, Ordering::Relaxed);
            {
                let mut st = lock_ignore_poison(&stats[w]);
                st.messages += 1;
            }
            if i >= n {
                break;
            }
            let task = lock_ignore_poison(&slots[i])
                .take()
                .expect("exec: task slot claimed twice");
            let t0 = Instant::now();
            let out: Result<R> = catch_unwind(AssertUnwindSafe(|| {
                if state.is_none() {
                    state = Some(init(w)?);
                }
                let st = state.as_mut().expect("exec: worker state just initialized");
                work(st, task, i)
            }))
            .unwrap_or_else(|payload| {
                // The worker state may be torn mid-panic; drop it so the
                // next task re-initializes from scratch.
                state = None;
                Err(Error::hqp(format!(
                    "exec: task {i} panicked: {}",
                    panic_message(payload)
                )))
            });
            let elapsed = t0.elapsed().as_secs_f64() * 1e3;
            *lock_ignore_poison(&task_ms[i]) = elapsed;
            {
                let mut st = lock_ignore_poison(&stats[w]);
                st.tasks += 1;
                st.busy_ms += elapsed;
            }
            *lock_ignore_poison(&results[i]) = Some(out);
        }
    };

    if workers == 1 {
        run_worker(0);
    } else {
        std::thread::scope(|scope| {
            for w in 1..workers {
                scope.spawn(|| run_worker(w));
            }
            run_worker(0);
        });
    }

    let report = PoolReport {
        jobs: workers,
        tasks: n,
        wall_ms: started.elapsed().as_secs_f64() * 1e3,
        workers: stats.into_iter().map(|m| m.into_inner().unwrap_or_else(|p| p.into_inner())).collect(),
        task_ms: task_ms
            .into_iter()
            .map(|m| m.into_inner().unwrap_or_else(|p| p.into_inner()))
            .collect(),
    };

    // Deterministic merge: all tasks ran; report the lowest-indexed error.
    let mut out = Vec::with_capacity(n);
    let mut first_err: Option<Error> = None;
    for (i, slot) in results.into_iter().enumerate() {
        let r = slot
            .into_inner()
            .unwrap_or_else(|p| p.into_inner())
            .unwrap_or_else(|| panic!("exec: task {i} never produced a result"));
        match r {
            Ok(v) => out.push(v),
            Err(e) => {
                if first_err.is_none() {
                    first_err = Some(e);
                }
            }
        }
    }
    match first_err {
        Some(e) => Err(e),
        None => Ok((out, report)),
    }
}

/// Stateless convenience wrapper over [`parallel_map_init`].
pub fn parallel_map<T, R, F>(jobs: Jobs, tasks: Vec<T>, work: F) -> Result<(Vec<R>, PoolReport)>
where
    T: Send,
    R: Send,
    F: Fn(T, usize) -> Result<R> + Sync,
{
    parallel_map_init(jobs, tasks, |_| Ok(()), |_, t, i| work(t, i))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn jobs_zero_is_rejected_loudly() {
        let err = Jobs::new(0).unwrap_err().to_string();
        assert!(err.contains("--jobs 0"), "unhelpful error: {err}");
        assert!(Jobs::new(1).is_ok());
        assert!(Jobs::available().get() >= 1);
        assert_eq!(Jobs::one().get(), 1);
    }

    #[test]
    fn results_come_back_in_submission_order() {
        for jobs in [1, 2, 4, 8] {
            let tasks: Vec<u64> = (0..100).collect();
            let (out, report) =
                parallel_map(Jobs::new(jobs).unwrap(), tasks, |t, i| {
                    assert_eq!(t as usize, i);
                    Ok(t * t)
                })
                .unwrap();
            let want: Vec<u64> = (0..100).map(|t| t * t).collect();
            assert_eq!(out, want, "jobs={jobs}");
            assert_eq!(report.tasks, 100);
            assert_eq!(report.task_ms.len(), 100);
            let ran: u64 = report.workers.iter().map(|w| w.tasks).sum();
            assert_eq!(ran, 100, "worker counters must account for every task");
        }
    }

    #[test]
    fn panics_surface_as_hard_errors_not_hangs() {
        let tasks: Vec<usize> = (0..16).collect();
        let err = parallel_map(Jobs::new(4).unwrap(), tasks, |t, _| {
            if t == 7 {
                panic!("boom {t}");
            }
            Ok(t)
        })
        .unwrap_err()
        .to_string();
        assert!(err.contains("task 7 panicked"), "got: {err}");
        assert!(err.contains("boom 7"), "panic payload lost: {err}");
    }

    #[test]
    fn lowest_indexed_error_wins_whatever_the_schedule() {
        for jobs in [1, 3, 8] {
            let tasks: Vec<usize> = (0..32).collect();
            let err = parallel_map(Jobs::new(jobs).unwrap(), tasks, |t, _| {
                if t % 10 == 3 {
                    return Err(Error::hqp(format!("fail {t}")));
                }
                Ok(t)
            })
            .unwrap_err()
            .to_string();
            assert!(err.contains("fail 3"), "jobs={jobs}: got {err}");
        }
    }

    #[test]
    fn init_runs_at_most_once_per_worker_and_on_demand() {
        let inits = AtomicU64::new(0);
        let tasks: Vec<usize> = (0..64).collect();
        let (out, report) = parallel_map_init(
            Jobs::new(4).unwrap(),
            tasks,
            |w| {
                inits.fetch_add(1, Ordering::Relaxed);
                Ok(w)
            },
            |state, t, _| Ok(*state * 1000 + t),
        )
        .unwrap();
        assert_eq!(out.len(), 64);
        let n_inits = inits.load(Ordering::Relaxed);
        assert!(n_inits >= 1 && n_inits <= 4, "lazy init ran {n_inits} times");
        assert!(report.jobs <= 4);
        // every result is consistent with *some* worker's state
        for (i, v) in out.iter().enumerate() {
            assert_eq!(v % 1000, i);
        }
    }

    #[test]
    fn empty_task_list_is_fine() {
        let (out, report) = parallel_map(Jobs::new(4).unwrap(), Vec::<u32>::new(), |t, _| Ok(t))
            .unwrap();
        assert!(out.is_empty());
        assert_eq!(report.tasks, 0);
        assert_eq!(report.jobs, 1, "no tasks -> no extra workers");
    }

    #[test]
    fn pool_report_renders_per_worker_lines() {
        let (_, report) =
            parallel_map(Jobs::new(2).unwrap(), vec![1u32, 2, 3, 4], |t, _| Ok(t)).unwrap();
        let s = report.render();
        assert!(s.contains("worker 0:"), "{s}");
        assert!(s.contains("4 task(s) on 2 worker(s)"), "{s}");
        assert!(report.busy_ms_total() >= 0.0);
    }
}
