//! `hqp` — CLI for the HQP reproduction.
//!
//! Every table/figure of the paper regenerates from here (the `cargo
//! bench` targets drive the same code paths):
//!
//! ```text
//! hqp table --id 1            Table I  (MobileNetV3 on Xavier NX)
//! hqp table --id 2            Table II (ResNet-18 on Xavier NX)
//! hqp figure --id 2           Fig. 2   (latency + accuracy bars)
//! hqp figure --id 3           Fig. 3   (size reduction vs accuracy drop)
//! hqp layerwise               §V-C layer-wise sparsity profile
//! hqp energy                  §V-E energy analysis
//! hqp overhead                §III-C / §V-F C_HQP vs C_QAT
//! hqp devices                 §IV-A heterogeneity sweep (Nano vs NX)
//! hqp run --model M --method hqp|q8|p50|prune|baseline
//! hqp run --model M --schedule "prune(fisher) >> ptq(kl)"
//! hqp mixed --model M         §VI-A mixed-precision extension
//! hqp search --budget N       budgeted schedule search (Pareto front)
//! hqp serve                   trace-driven serving simulator (SLO routing)
//! hqp info                    workspace/platform diagnostics
//! ```

use hqp::cli::Args;
use hqp::coordinator::{self, run_method, MethodSpec};
use hqp::error::Result;
use hqp::exec::Jobs;
use hqp::gopt::{optimize, OptimizeOptions};
use hqp::graph::Graph;
use hqp::hqp::{cost, mixed, pipeline, HqpConfig, RankingMethod, Schedule};
use hqp::hwsim::{simulate, Device, Precision};
use hqp::quant::CalibMethod;
use hqp::report::{self, bar_chart, scatter, BarRow};
use hqp::runtime::{Session, Workspace};
use hqp::serve::{self, ArrivalProcess, AutoscaleConfig, Policy, ScalePolicy, ServeConfig};

const COMMON_FLAGS: &[&str] = &[
    "artifacts", "device", "model", "force", "delta-max", "delta-step", "ranking",
    "calib", "per-channel", "id", "method", "theta",
];

/// Flags only `hqp run` accepts (other commands reject them, the same
/// typo-hardening `--device` gets).
const RUN_FLAGS: &[&str] = &["schedule", "smoke", "jobs"];

/// Flags only `hqp search` accepts (other commands reject them, the same
/// typo-hardening `--device` gets).
const SEARCH_FLAGS: &[&str] = &["budget", "seed", "space", "smoke", "jobs", "out"];

/// Flags only `hqp serve` accepts (other commands reject them, the same
/// typo-hardening `--device` gets).
const SERVE_FLAGS: &[&str] = &[
    "rps", "slo-ms", "policy", "duration-s", "requests", "seed", "max-batch",
    "batch-timeout-ms", "queue-cap", "arrivals", "smoke", "mem-mb",
    "swap-init-ms", "link-mbps", "autoscale", "scale-interval-ms",
    "min-servers", "max-servers", "scale-high-water", "scale-low-water",
    "retries", "retry-base-ms", "tenants", "admit", "jobs",
    "forecast-horizon-ms", "idle-watts", "scale-to-drain",
];

/// Valid `--device` names (aliases included), shown when the flag is bad.
const DEVICE_NAMES: &str = "jetson-nano|nano, xavier-nx|nx, ideal";

const HELP: &str = "hqp — Sensitivity-Aware Hybrid Quantization and Pruning (paper reproduction)

commands:
  table --id 1|2        Table I (MobileNetV3) / Table II (ResNet-18) on Xavier NX
  figure --id 2|3       Fig. 2 latency+accuracy bars / Fig. 3 size-vs-drop scatter
  layerwise             \u{a7}V-C layer-wise sparsity profile
  energy                \u{a7}V-E energy analysis (E = P\u{b7}L)
  overhead              \u{a7}III-C / \u{a7}V-F C_HQP vs C_QAT
  devices               \u{a7}IV-A heterogeneity sweep (Nano vs NX vs ideal)
  run                   one method (--method hqp|q8|p50|prune|baseline), the
                        full candidate suite (--method suite, parallel with
                        --jobs), or any composable pipeline
                        (--schedule \"prune >> ptq\")
  search                budgeted schedule search over the grammar: successive
                        halving from roofline+surrogate up to full \u{394}_max
                        validation, ranked Pareto front over (speedup, size,
                        \u{394}acc) with \u{394}_max violators excluded
  mixed                 \u{a7}VI-A S-guided mixed precision
  serve                 trace-driven serving simulator over deployed variants
  info                  workspace diagnostics
options:
  --artifacts DIR   artifacts root (default: artifacts)
  --device NAME     jetson-nano | xavier-nx | ideal (default: xavier-nx)
  --model NAME      mobilenetv3 | resnet18
  --delta-max X     accuracy-drop budget (default 0.015)
  --delta-step X    pruning step fraction (default 0.01)
  --ranking R       fisher | mag-l1 | mag-l2 | bn-gamma | random
  --calib C         kl | minmax | percentile
  --per-channel     per-channel weight scales (ablation)
  --force           ignore cached results
run options:
  --schedule S      composable compression schedule: stages joined with >>,
                    each `name` or `name(args)` — measure-baseline,
                    prune[(ranking,step=P%,dmax=P%,max-sparsity=P%,samples=N)]
                    (\u{394}_max-gated Algorithm 1),
                    prune-to([ranking,]theta=P%) (unconditional),
                    ptq[(kl|minmax|percentile,recalib,samples=N)] (`recalib`
                    re-collects activation scales on the current params —
                    the \u{a7}V-B fix), mixed[(int4=P%,fp16=P%)] —
                    or a preset name (baseline|q8-only|p50-only|hqp|hqp-prune|
                    mixed; stage spellings win, so `prune`/`mixed` alone mean
                    the single stage). Omitted stage args inherit --ranking/--calib/
                    --delta-max/--delta-step. Ordering is free: --schedule
                    \"ptq >> prune\" runs the \u{a7}V-B quantize-first ablation
                    the closed --method set cannot express.
  --smoke           with --schedule: parse, validate and print the lowered
                    plan (canonical form, label, cache keys), then exit
                    without touching artifacts (CI smoke)
  --jobs N          worker threads for --method suite candidate evaluation
                    (default: all available cores). Results and cache files
                    are byte-identical at any N; --jobs 0 is rejected. The
                    pool report (per-worker tasks/messages/busy time) goes
                    to stderr so stdout diffs clean across worker counts.
search options:
  --budget N        hard cap on schedule evaluations across both fidelity
                    rungs (default 32; 0 is rejected)
  --seed N          candidate-stream seed (default 42; same seed + budget =>
                    byte-identical ranked front at any --jobs)
  --space AXES      `all` (default) or a comma list of mutation axes:
                    order, dmax-split, step, ranking, calib, recalib,
                    max-sparsity, samples
  --jobs N          evaluation worker threads (default: all available cores;
                    results byte-identical at any N; 0 rejected). Pool
                    reports go to stderr
  --out FILE        also write the outcome (front + all full evals) as JSON
  --smoke           force the no-artifacts surrogate backend (CI smoke);
                    without it, artifacts/ is used when present (search then
                    hits the coordinator's schedule result cache)
serve options:
  --rps X               offered load, requests/s (default 100; 50 w/ --smoke)
  --slo-ms X            per-request latency SLO (default 50)
  --policy P            round-robin | least-loaded | acc-fastest (default) |
                        swap-aware | joules-per-slo (routes each request to
                        the variant minimizing expected energy per SLO-met
                        request: batch-1 mJ over the SLO headroom left at
                        its predicted finish)
  --duration-s X        trace length (default 10; 1 w/ --smoke)
  --requests N          stream exactly N requests instead of a timed trace
                        (lazy arrival generation + constant-memory telemetry:
                        resident state is independent of N, so million-request
                        runs are fine; excludes --duration-s; 0 is rejected)
  --arrivals A          poisson | mmpp | diurnal | flash-crowd (default poisson)
  --seed N              trace seed (default 42; identical seed => identical summary;
                        also seeds retry backoff draws)
  --retries N           closed-loop clients: rejected/expired requests re-enter
                        the arrival stream after seeded exponential backoff, up
                        to N re-entries per request (default 0 = open loop;
                        conservation then reads generated = completed +
                        dropped + expired *final*, with retries censused apart)
  --retry-base-ms X     mean backoff before the first re-entry, ms; doubles per
                        attempt (default 5; requires --retries)
  --tenants SPEC        multi-tenant classes \"name:dmax:slo_ms:weight[:rate_share],...\"
                        — each request is assigned a class (weight-proportional,
                        deterministic in the request id) and admitted against
                        that class's \u{394}_max budget and SLO deadline; the
                        summary gains a per-tenant census + attainment table.
                        The optional 5th field pins each class's share of the
                        *offered* trace instead of the admission weight
                        (all-or-none across the table; the arrival timeline
                        itself is untouched)
  --admit P             fifo (default) | weighted-fair — batch admission order
                        across tenant classes (requires --tenants)
  --max-batch N         dynamic batcher max batch size (default 8)
  --batch-timeout-ms X  batching timeout (default 2)
  --queue-cap N         per-server admission queue cap (default 256)
  --mem-mb X            per-server engine memory capacity, MB (default: unlimited;
                        finite caps make variants resident-or-deployable and enable
                        hot-swaps under --policy swap-aware)
  --swap-init-ms X      fixed engine-init overhead charged per hot-swap (default 5)
  --link-mbps X         uplink bandwidth for request payloads, Mbit/s
                        (default: unlimited = no network cost)
  --autoscale P         off (default) | queue-depth | attainment | predictive —
                        elastic fleet controller (wake cost = initial-residency
                        weights over DRAM bandwidth + init; wake energy E = P·L
                        is charged). predictive filters the arrival stream
                        online (MMPP(2) + trace periodicity) and pre-wakes
                        before forecast load crosses committed capacity,
                        falling back to queue-depth below confidence
  --forecast-horizon-ms X  predictive look-ahead, ms (default: the next wake
                        latency + one control interval; requires --autoscale
                        predictive)
  --scale-to-drain      keep control ticks running through the post-trace
                        drain so the fleet can scale down after the last
                        arrival (requires --autoscale; predictive implies it)
  --idle-watts X        idle power drawn by powered-but-idle servers, W;
                        charged as idle energy into the summary total
                        (default 0 = the pre-idle-accounting model)
  --scale-interval-ms X control interval for autoscale decisions (default 100)
  --min-servers N       lower bound on active servers; also how many start
                        awake (default 1; requires --autoscale)
  --max-servers N       fleet size / upper bound on awake servers — replicates
                        the per-device servers cyclically up to N
  --scale-high-water X  queue-depth policy: queued per active server above
                        which the fleet is pressured (default 8)
  --scale-low-water X   queue-depth policy: mark below which the idlest server
                        drains (default 1)
  --jobs N              worker threads advancing server shards between global
                        events (default: all available cores; capped at the
                        fleet size). Summaries are byte-identical at any N;
                        --jobs 0 is rejected
  --smoke               tiny 1 s trace (CI smoke)";

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        println!("{HELP}");
        return;
    }
    match run(&argv) {
        Ok(()) => {}
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(1);
        }
    }
}

fn config_from(args: &Args) -> Result<HqpConfig> {
    let mut cfg = HqpConfig {
        delta_max: args.flag_f64("delta-max", 0.015)?,
        delta_step_frac: args.flag_f64("delta-step", 0.01)?,
        ..Default::default()
    };
    if let Some(r) = args.flag("ranking") {
        cfg.ranking = RankingMethod::parse(r)
            .ok_or_else(|| hqp::Error::Cli(format!("unknown ranking {r}")))?;
    }
    if let Some(c) = args.flag("calib") {
        cfg.calib_method = CalibMethod::parse(c)
            .ok_or_else(|| hqp::Error::Cli(format!("unknown calib method {c}")))?;
    }
    if args.switch("per-channel") {
        cfg.per_channel_weights = true;
    }
    Ok(cfg)
}

fn device_from(args: &Args) -> Result<Device> {
    let name = args.flag_or("device", "xavier-nx");
    Device::by_name(name)
        .ok_or_else(|| hqp::Error::Cli(format!("unknown device {name} (valid: {DEVICE_NAMES})")))
}

/// `--jobs N` (worker threads). Absent → all available cores; `--jobs 0`
/// is rejected loudly rather than silently degraded to one worker.
fn jobs_from(args: &Args) -> Result<Jobs> {
    match args.flag("jobs") {
        Some(_) => Jobs::new(args.flag_usize("jobs", 1)?),
        None => Ok(Jobs::available()),
    }
}

fn run(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv)?;
    if args.command == "serve" {
        let mut known = COMMON_FLAGS.to_vec();
        known.extend_from_slice(SERVE_FLAGS);
        args.expect_known(&known)?;
    } else if args.command == "run" {
        let mut known = COMMON_FLAGS.to_vec();
        known.extend_from_slice(RUN_FLAGS);
        args.expect_known(&known)?;
    } else if args.command == "search" {
        let mut known = COMMON_FLAGS.to_vec();
        known.extend_from_slice(SEARCH_FLAGS);
        args.expect_known(&known)?;
    } else {
        args.expect_known(COMMON_FLAGS)?;
    }
    // validate --device up front so commands that don't consume it still
    // reject typos (e.g. `hqp energy --device h100` used to silently run)
    if let Some(name) = args.flag("device") {
        if Device::by_name(name).is_none() {
            return Err(hqp::Error::Cli(format!(
                "unknown device {name} (valid: {DEVICE_NAMES})"
            )));
        }
    }
    let artifacts = args.flag_or("artifacts", "artifacts").to_string();

    match args.command.as_str() {
        "version" => {
            println!("hqp {}", hqp::version());
            Ok(())
        }
        "info" => cmd_info(&artifacts),
        "table" => cmd_table(&artifacts, &args),
        "figure" => cmd_figure(&artifacts, &args),
        "layerwise" => cmd_layerwise(&artifacts, &args),
        "energy" => cmd_energy(&artifacts, &args),
        "overhead" => cmd_overhead(&artifacts, &args),
        "devices" => cmd_devices(&artifacts, &args),
        "run" => cmd_run(&artifacts, &args),
        "search" => cmd_search(&artifacts, &args),
        "mixed" => cmd_mixed(&artifacts, &args),
        "serve" => cmd_serve(&artifacts, &args),
        "help" | "-h" | "--help" => {
            println!("{HELP}");
            Ok(())
        }
        other => Err(hqp::Error::Cli(format!("unknown command {other} (try `hqp help`)"))),
    }
}

fn cmd_info(artifacts: &str) -> Result<()> {
    let ws = Workspace::open(artifacts)?;
    println!("platform: {}", ws.platform());
    for (name, mm) in &ws.manifest.models {
        let g = Graph::from_manifest(mm)?;
        println!(
            "model {name}: {} params, {} prune groups / {} filters, {} taps, {:.1} MFLOPs dense, baseline acc {:.4}",
            mm.param_order.len(),
            mm.groups.len(),
            mm.total_filters(),
            mm.taps.len(),
            g.dense_flops() as f64 / 1e6,
            mm.baseline_val_acc,
        );
    }
    for (split, d) in &ws.manifest.data {
        println!("split {split}: {} samples", d.n);
    }
    Ok(())
}

fn suite_rows(
    artifacts: &str,
    model: &str,
    args: &Args,
    specs: &[MethodSpec],
) -> Result<Vec<coordinator::ResultRow>> {
    let ws = Workspace::open(artifacts)?;
    let cfg = config_from(args)?;
    let devices = Device::all();
    let mut rows = Vec::new();
    for spec in specs {
        rows.extend(run_method(&ws, model, *spec, &cfg, &devices, args.switch("force"))?);
    }
    Ok(rows)
}

const TABLE_SPECS: &[MethodSpec] = &[
    MethodSpec::Baseline,
    MethodSpec::Q8Only,
    MethodSpec::PruneOnly(50),
    MethodSpec::Hqp,
];

fn cmd_table(artifacts: &str, args: &Args) -> Result<()> {
    let id = args.flag_usize("id", 1)?;
    let (model, title) = match id {
        1 => ("mobilenetv3", "Table I — MobileNetV3, edge-side inference on Jetson Xavier NX"),
        2 => ("resnet18", "Table II — ResNet-18, edge-side inference on Jetson Xavier NX"),
        _ => return Err(hqp::Error::Cli("table --id 1|2".into())),
    };
    let rows = suite_rows(artifacts, model, args, TABLE_SPECS)?;
    let dev = device_from(args)?;
    let reports = coordinator::experiments::reports_for_device(&rows, &dev.name);
    println!("{}", report::method_table(title, &reports));
    Ok(())
}

fn cmd_figure(artifacts: &str, args: &Args) -> Result<()> {
    let id = args.flag_usize("id", 2)?;
    let model = args.flag_or("model", "mobilenetv3");
    let rows = suite_rows(artifacts, model, args, TABLE_SPECS)?;
    let dev = device_from(args)?;
    let reports = coordinator::experiments::reports_for_device(&rows, &dev.name);
    match id {
        2 => {
            let lat: Vec<BarRow> = reports
                .iter()
                .map(|r| {
                    BarRow::new(
                        r.method.clone(),
                        r.latency_ms,
                        format!("{:.3} ms ({:.2}x)", r.latency_ms, r.speedup),
                    )
                })
                .collect();
            println!(
                "{}",
                bar_chart(
                    &format!("Fig. 2a — Latency by method ({model} on {})", dev.name),
                    &lat,
                    48
                )
            );
            let acc: Vec<BarRow> = reports
                .iter()
                .map(|r| {
                    BarRow::new(
                        r.method.clone(),
                        r.acc_drop.max(0.0) * 100.0,
                        format!(
                            "{:.2}% drop{}",
                            r.acc_drop * 100.0,
                            if r.compliant { "" } else { "  << VIOLATES Δmax" }
                        ),
                    )
                })
                .collect();
            println!("{}", bar_chart("Fig. 2b — Accuracy drop by method", &acc, 48));
        }
        3 => {
            let pts: Vec<(f64, f64, String)> = reports
                .iter()
                .map(|r| (r.size_reduction * 100.0, r.acc_drop * 100.0, r.method.clone()))
                .collect();
            println!(
                "{}",
                scatter(
                    &format!("Fig. 3 — Size reduction vs accuracy drop ({model})"),
                    &pts,
                    "size reduction %",
                    "accuracy drop %",
                    56,
                    12
                )
            );
        }
        _ => return Err(hqp::Error::Cli("figure --id 2|3".into())),
    }
    Ok(())
}

fn cmd_layerwise(artifacts: &str, args: &Args) -> Result<()> {
    let model = args.flag_or("model", "mobilenetv3");
    let rows = suite_rows(artifacts, model, args, &[MethodSpec::Hqp])?;
    let ws = Workspace::open(artifacts)?;
    let mm = ws.manifest.model(model)?;
    let row = &rows[0];
    let bars: Vec<BarRow> = mm
        .groups
        .iter()
        .zip(&row.group_sparsity)
        .map(|(g, &s)| {
            BarRow::new(
                g.name.clone(),
                s * 100.0,
                format!("θ={:>4.0}%  ({} filters)", s * 100.0, g.size),
            )
        })
        .collect();
    println!(
        "{}",
        bar_chart(
            &format!("§V-C — Layer-wise sparsity after HQP ({model})"),
            &bars,
            40
        )
    );
    Ok(())
}

fn cmd_energy(artifacts: &str, args: &Args) -> Result<()> {
    for model in ["mobilenetv3", "resnet18"] {
        let rows = suite_rows(artifacts, model, args, TABLE_SPECS)?;
        for dev in [Device::jetson_nano(), Device::xavier_nx()] {
            let reports = coordinator::experiments::reports_for_device(&rows, &dev.name);
            println!("§V-E — Energy per inference, {model} on {}", dev.name);
            for r in &reports {
                println!(
                    "  {:<12} E = {:>8.3} mJ   ratio {:>5.2}x   (speedup {:>5.2}x — identity E=P·L holds: {})",
                    r.method,
                    r.energy_mj,
                    r.energy_ratio,
                    r.speedup,
                    if (r.energy_ratio - r.speedup).abs() < 1e-9 { "yes" } else { "NO" }
                );
            }
        }
    }
    Ok(())
}

fn cmd_overhead(artifacts: &str, args: &Args) -> Result<()> {
    let ws = Workspace::open(artifacts)?;
    let cfg = config_from(args)?;
    let model = args.flag_or("model", "mobilenetv3");
    let mut sess = Session::new(&ws, model)?;
    let (out, ms) = hqp::benchkit::time_once(|| pipeline::run_hqp(&mut sess, &cfg));
    out?;
    let hcost = cost::HqpCost::from_counters(&sess.counters);
    let qat_small = cost::QatCost::paper_default(8192);
    let qat_imagenet = cost::QatCost::paper_default(1_281_167);
    println!("§III-C / §V-F — optimization overhead ({model})");
    println!(
        "  measured C_HQP: {} grad samples + {} inference samples = {:.0} fwd-equiv  ({:.1} s wall)",
        hcost.grad_samples,
        hcost.inference_samples,
        hcost.total_inf_equiv(),
        ms / 1e3
    );
    println!(
        "  modeled  C_QAT (this workload, 5 epochs): {:.0} fwd-equiv  -> C_QAT/C_HQP = {:.1}x",
        qat_small.total_inf_equiv(),
        cost::overhead_ratio(&hcost, &qat_small)
    );
    println!(
        "  modeled  C_QAT (ImageNet-scale, 5 epochs): {:.2e} fwd-equiv -> C_QAT/C_HQP = {:.0}x",
        qat_imagenet.total_inf_equiv(),
        cost::overhead_ratio(&hcost, &qat_imagenet)
    );
    let c = sess.counters;
    println!(
        "  caching: {} param tensors / {} KB uploaded, {} validation batches early-exited",
        c.upload_tensors,
        c.upload_bytes / 1024,
        c.batches_skipped
    );
    Ok(())
}

fn cmd_devices(artifacts: &str, args: &Args) -> Result<()> {
    for model in ["mobilenetv3", "resnet18"] {
        let rows = suite_rows(artifacts, model, args, TABLE_SPECS)?;
        println!("§IV-A heterogeneity — {model}");
        for dev in Device::all() {
            let reports = coordinator::experiments::reports_for_device(&rows, &dev.name);
            println!("{}", report::method_table(&format!("  device: {}", dev.name), &reports));
        }
    }
    Ok(())
}

fn cmd_run(artifacts: &str, args: &Args) -> Result<()> {
    let model = args.flag_or("model", "mobilenetv3");
    // validated up front so `--jobs 0` errors loudly on every run path,
    // including the --smoke dry-run
    let jobs = jobs_from(args)?;
    let rows = if let Some(spec_str) = args.flag("schedule") {
        if args.flag("method").is_some() {
            return Err(hqp::Error::Cli(
                "--schedule and --method are mutually exclusive (a preset name \
                 like --schedule hqp covers every --method)"
                    .into(),
            ));
        }
        let cfg = config_from(args)?;
        let sched = Schedule::resolve(spec_str, &cfg)?;
        if args.switch("smoke") {
            // dry-run: parse + canonicalize + show the lowering without
            // touching artifacts (the CI schedule-grammar smoke)
            println!("schedule : {}", sched.canonical());
            println!("label    : {}", sched.method_label());
            println!("cache key: {model}_{}", sched.cache_slug());
            if let Some(suffix) = &sched.legacy_key {
                println!("legacy   : {model}_{suffix} (v1 read-only fallback)");
            }
            return Ok(());
        }
        let ws = Workspace::open(artifacts)?;
        coordinator::run_schedule(&ws, model, &sched, &cfg, &Device::all(), args.switch("force"))?
    } else {
        if args.switch("smoke") {
            return Err(hqp::Error::Cli(
                "run --smoke is the --schedule dry-run; give it a schedule".into(),
            ));
        }
        match args.flag_or("method", "hqp") {
            "suite" => {
                // the multi-candidate path: all four suite methods, fanned
                // out across --jobs workers (each with its own Workspace).
                // The pool report goes to stderr so stdout stays byte-
                // identical across worker counts.
                let cfg = config_from(args)?;
                let (suite, pool) = coordinator::run_suite_jobs(
                    std::path::Path::new(artifacts),
                    model,
                    &cfg,
                    &Device::all(),
                    args.switch("force"),
                    jobs,
                )?;
                eprint!("{}", pool.render());
                suite.rows
            }
            other => {
                let spec = match other {
                    "baseline" => MethodSpec::Baseline,
                    "q8" => MethodSpec::Q8Only,
                    "p50" => MethodSpec::PruneOnly(args.flag_usize("theta", 50)? as u32),
                    "prune" => MethodSpec::HqpPruneOnly,
                    "hqp" => MethodSpec::Hqp,
                    other => return Err(hqp::Error::Cli(format!("unknown method {other}"))),
                };
                suite_rows(artifacts, model, args, &[spec])?
            }
        }
    };
    let dev = device_from(args)?;
    let reports = coordinator::experiments::reports_for_device(&rows, &dev.name);
    println!("{}", report::method_table(&format!("{model} / {}", dev.name), &reports));
    if let Some(row) = rows.first() {
        if !row.trace.is_empty() {
            println!("conditional-pruning trajectory (sparsity -> val acc):");
            for (s, a, ok) in &row.trace {
                println!(
                    "  θ={:>5.1}%  acc={:.4}  {}",
                    s * 100.0,
                    a,
                    if *ok { "accept" } else { "REJECT (stop)" }
                );
            }
        }
    }
    Ok(())
}

/// `hqp search` — budgeted successive-halving search over the schedule
/// grammar for the best deployed speedup at equal Δ_max (DESIGN.md
/// §Search). Uses real pipeline runs when artifacts exist (hitting the
/// coordinator's schedule-slug result cache, so repeated candidates are
/// free); the paper-anchored surrogate otherwise, so the command — and
/// the CI smoke — runs end-to-end on a bare checkout. `--smoke` forces
/// the surrogate backend.
fn cmd_search(artifacts: &str, args: &Args) -> Result<()> {
    let model = args.flag_or("model", "resnet18").to_string();
    let device = device_from(args)?;
    let jobs = jobs_from(args)?;
    let budget = args.flag_usize("budget", 32)?;
    let seed = args.flag_usize("seed", 42)? as u64;
    let space = hqp::search::SearchSpace::parse(args.flag_or("space", "all"))?;
    let cfg = config_from(args)?;
    let has_artifacts =
        std::path::Path::new(artifacts).join("manifest.json").exists();
    let backend = if !args.switch("smoke") && has_artifacts {
        hqp::search::Backend::Workspace { root: artifacts.into() }
    } else {
        hqp::search::Backend::Reference
    };
    let sc = hqp::search::SearchConfig {
        model,
        device,
        hqp: cfg,
        budget,
        seed,
        space,
        jobs,
        backend,
    };
    let out = hqp::search::run_search(&sc)?;
    // pool reports to stderr so stdout stays byte-identical across --jobs
    for pool in &out.pools {
        eprint!("{}", pool.render());
    }
    print!("{}", hqp::search::render(&sc, &out));
    if let Some(path) = args.flag("out") {
        let json = hqp::search::outcome_json(&sc, &out).to_string_pretty();
        std::fs::write(path, json + "\n")
            .map_err(|e| hqp::Error::Cli(format!("cannot write {path}: {e}")))?;
        println!("wrote {path}");
    }
    Ok(())
}

fn cmd_mixed(artifacts: &str, args: &Args) -> Result<()> {
    let ws = Workspace::open(artifacts)?;
    let cfg = config_from(args)?;
    let model = args.flag_or("model", "mobilenetv3");
    let mut sess = Session::new(&ws, model)?;
    let outcome = pipeline::run_hqp(&mut sess, &cfg)?;
    let scores = outcome
        .saliency_scores
        .clone()
        .ok_or_else(|| hqp::Error::hqp("no saliency scores"))?;
    let plan = mixed::plan(&scores, &sess.mm.groups, mixed::MixedPolicy::default());
    let graph = Graph::from_manifest(&sess.mm)?;

    let dev = device_from(args)?;
    let full_masks: Vec<Vec<bool>> = graph.groups.iter().map(|g| vec![true; g.size]).collect();
    let base = simulate(&optimize(&graph, &full_masks, &OptimizeOptions::fp32())?, &dev);
    let mut opts = OptimizeOptions::int8();
    let int8 = simulate(&optimize(&graph, &outcome.masks, &opts)?, &dev);
    opts.precision = plan.clone();
    let mix = simulate(&optimize(&graph, &outcome.masks, &opts)?, &dev);

    println!("§VI-A — S-guided mixed precision ({model} on {})", dev.name);
    let (mut n4, mut n16) = (0, 0);
    for p in plan.per_group.values() {
        match p {
            Precision::Int4 => n4 += 1,
            Precision::Fp16 => n16 += 1,
            _ => {}
        }
    }
    println!(
        "  plan: {} groups int4, {} fp16, {} int8",
        n4,
        n16,
        plan.per_group.len() - n4 - n16
    );
    println!("  fp32 baseline : {:.3} ms", base.latency_ms);
    println!(
        "  hqp int8      : {:.3} ms ({:.2}x)",
        int8.latency_ms,
        base.latency_ms / int8.latency_ms
    );
    println!(
        "  hqp mixed     : {:.3} ms ({:.2}x)",
        mix.latency_ms,
        base.latency_ms / mix.latency_ms
    );
    Ok(())
}

/// `hqp serve` — replay a synthetic trace against a fleet of deployed
/// variants. Uses workspace engines + cached measured accuracy when
/// artifacts exist, the paper-anchored reference profiles otherwise, so
/// the command runs end-to-end on a bare checkout. With `--mem-mb` each
/// server holds only the variants that fit (resident vs deployable), and
/// `--policy swap-aware` may hot-swap engines under load.
fn cmd_serve(artifacts: &str, args: &Args) -> Result<()> {
    let smoke = args.switch("smoke");
    let model = args.flag_or("model", "resnet18");
    let dev = device_from(args)?;
    // validated up front so `--jobs 0` errors before any header is printed
    let jobs = jobs_from(args)?;
    let policy_name = args.flag_or("policy", "acc-fastest");
    let policy = Policy::parse(policy_name).ok_or_else(|| {
        hqp::Error::Cli(format!(
            "unknown policy {policy_name} (valid: {})",
            Policy::NAMES.join(", ")
        ))
    })?;
    let rps = args.flag_f64("rps", if smoke { 50.0 } else { 100.0 })?;
    let duration_s = args.flag_f64("duration-s", if smoke { 1.0 } else { 10.0 })?;
    // --requests N swaps the timed trace for an exact request budget
    // streamed lazily (ArrivalGen over an unbounded horizon), so trace
    // length no longer bounds memory
    let requests = match args.flag("requests") {
        Some(_) => Some(args.flag_usize("requests", 0)?),
        None => None,
    };
    if let Some(n) = requests {
        if n == 0 {
            return Err(hqp::Error::Cli(
                "--requests must be >= 1 (use --duration-s for a timed trace)".into(),
            ));
        }
        if args.flag("duration-s").is_some() {
            return Err(hqp::Error::Cli(
                "--requests and --duration-s are mutually exclusive (a request \
                 budget streams an unbounded trace)"
                    .into(),
            ));
        }
    }
    let seed = args.flag_usize("seed", 42)? as u64;
    let arrivals_name = args.flag_or("arrivals", "poisson");
    let process = ArrivalProcess::parse(arrivals_name, rps).ok_or_else(|| {
        hqp::Error::Cli(format!(
            "unknown arrival process {arrivals_name} (valid: {})",
            ArrivalProcess::NAMES.join(", ")
        ))
    })?;
    // closed-loop clients: --retries N lets refused requests re-enter the
    // arrival stream after seeded exponential backoff. A bare --retries
    // parses as a switch, so reject it loudly instead of silently running
    // the open loop the user asked to close.
    if args.switch("retries") {
        return Err(hqp::Error::Cli(
            "--retries needs a value (max re-entries per request; 0 = open loop)".into(),
        ));
    }
    let retries = args.flag_usize("retries", 0)?;
    if retries == 0 && args.flag("retry-base-ms").is_some() {
        return Err(hqp::Error::Cli("--retry-base-ms requires --retries".into()));
    }
    let retry_base_ms = args.flag_f64("retry-base-ms", 5.0)?;
    // multi-tenant classes: parse_tenants errors already quote the
    // expected "name:dmax:slo_ms:weight,..." grammar
    if args.switch("tenants") {
        return Err(hqp::Error::Cli(format!(
            "--tenants needs a value: {}",
            serve::TENANT_SPEC_FORMAT
        )));
    }
    let tenants = match args.flag("tenants") {
        Some(spec) => serve::parse_tenants(spec)?,
        None => Vec::new(),
    };
    if args.switch("admit") {
        return Err(hqp::Error::Cli(format!(
            "--admit needs a value (valid: {})",
            serve::AdmitPolicy::NAMES.join(", ")
        )));
    }
    let admit_name = args.flag_or("admit", "fifo");
    let admit = serve::AdmitPolicy::parse(admit_name).ok_or_else(|| {
        hqp::Error::Cli(format!(
            "unknown admission policy {admit_name} (valid: {})",
            serve::AdmitPolicy::NAMES.join(", ")
        ))
    })?;
    if args.flag("admit").is_some() && tenants.is_empty() {
        return Err(hqp::Error::Cli(
            "--admit requires --tenants (admission order is across tenant classes)".into(),
        ));
    }
    // elastic autoscaling: --autoscale names the controller; the knobs
    // below are rejected without one (the same typo-hardening --device
    // gets), and the watermark overrides only exist for queue-depth
    let scale_name = args.flag_or("autoscale", "off");
    let scale_policy = ScalePolicy::parse(scale_name).ok_or_else(|| {
        hqp::Error::Cli(format!(
            "unknown autoscale policy {scale_name} (valid: {})",
            ScalePolicy::NAMES.join(", ")
        ))
    })?;
    if scale_policy == ScalePolicy::Off {
        for f in ["scale-interval-ms", "min-servers", "scale-high-water", "scale-low-water"] {
            if args.flag(f).is_some() {
                return Err(hqp::Error::Cli(format!(
                    "--{f} requires --autoscale queue-depth|attainment|predictive"
                )));
            }
        }
    } else if scale_policy != ScalePolicy::QueueDepth && scale_policy != ScalePolicy::Predictive {
        // the predictive controller keeps queue-depth as its low-confidence
        // fallback, so the watermarks stay meaningful there too
        for f in ["scale-high-water", "scale-low-water"] {
            if args.flag(f).is_some() {
                return Err(hqp::Error::Cli(format!(
                    "--{f} only applies to --autoscale queue-depth|predictive"
                )));
            }
        }
    }
    // predictive/energy knobs: bare switches where a value is required are
    // rejected loudly; the policy gating itself (a horizon without
    // --autoscale predictive, --scale-to-drain without a controller) is
    // enforced by ServeConfig::validate so the library path errors too
    if args.switch("forecast-horizon-ms") {
        return Err(hqp::Error::Cli(
            "--forecast-horizon-ms needs a value (look-ahead in ms)".into(),
        ));
    }
    let forecast_horizon_ms = match args.flag("forecast-horizon-ms") {
        Some(_) => Some(args.flag_f64("forecast-horizon-ms", 0.0)?),
        None => None,
    };
    if args.switch("idle-watts") {
        return Err(hqp::Error::Cli(
            "--idle-watts needs a value (idle power in W; 0 disables)".into(),
        ));
    }
    let idle_watts = args.flag_f64("idle-watts", 0.0)?;
    let scale_to_drain = args.switch("scale-to-drain");
    let mut autoscale = AutoscaleConfig::off();
    autoscale.policy = scale_policy;
    autoscale.interval_ms = args.flag_f64("scale-interval-ms", autoscale.interval_ms)?;
    autoscale.min_active = args.flag_usize("min-servers", autoscale.min_active)?;
    autoscale.queue_high = args.flag_f64("scale-high-water", autoscale.queue_high)?;
    autoscale.queue_low = args.flag_f64("scale-low-water", autoscale.queue_low)?;
    let max_servers = match args.flag("max-servers") {
        Some(_) => Some(args.flag_usize("max-servers", 0)?),
        None => None,
    };
    if let Some(n) = max_servers {
        autoscale.max_active = n;
    }

    let cfg = ServeConfig {
        slo_ms: args.flag_f64("slo-ms", 50.0)?,
        delta_max: args.flag_f64("delta-max", 0.015)?,
        policy,
        max_batch: args.flag_usize("max-batch", 8)?,
        batch_timeout_ms: args.flag_f64("batch-timeout-ms", 2.0)?,
        queue_cap: args.flag_usize("queue-cap", 256)?,
        swap_init_ms: args.flag_f64("swap-init-ms", 5.0)?,
        link_mbps: args.flag_f64("link-mbps", f64::INFINITY)?,
        autoscale,
        retries,
        retry_base_ms,
        retry_seed: seed,
        tenants,
        admit,
        forecast_horizon_ms,
        idle_watts,
        scale_to_drain,
    };

    let methods = ["baseline", "q8", "p50", "hqp", "mixed"];
    let (mut fleet, source) =
        serve::fleet_for(artifacts, model, &[dev.clone()], &methods, cfg.max_batch)?;
    if let Some(n) = max_servers {
        // --max-servers sizes the fleet (the peak an elastic run may wake
        // up to; with --autoscale off, a fixed fleet of n)
        fleet = fleet.replicate_to(n)?;
    }
    if args.flag("mem-mb").is_some() {
        let mem_mb = args.flag_f64("mem-mb", 0.0)?;
        if mem_mb <= 0.0 {
            return Err(hqp::Error::Cli("--mem-mb must be positive".into()));
        }
        fleet = fleet.with_mem_cap_mb(mem_mb);
    }
    // the timed path materializes as before (byte-identical output); the
    // --requests path never holds the trace
    let arrivals = if requests.is_none() {
        serve::trace::generate(&process, duration_s * 1e3, seed)
    } else {
        Vec::new()
    };

    println!(
        "serving {model} on {}: {} variants ({source})",
        dev.name,
        fleet.num_variants()
    );
    if let Some(n) = requests {
        println!(
            "trace: {} streamed at {rps:.0} rps (seed {seed}) -> {n} requests",
            process.name()
        );
    } else {
        println!(
            "trace: {} over {duration_s:.1} s at {rps:.0} rps (seed {seed}) -> {} requests",
            process.name(),
            arrivals.len()
        );
    }
    // elastic-fleet header, gated so fixed-fleet output stays
    // byte-identical to the pre-autoscaling CLI
    if cfg.autoscale.enabled() {
        println!(
            "autoscale: {} every {:.0} ms, {}..{} active of {} servers \
             (servers 0..{} start awake)",
            cfg.autoscale.policy.name(),
            cfg.autoscale.interval_ms,
            cfg.autoscale.min_active,
            cfg.autoscale.max_active.min(fleet.servers.len()),
            fleet.servers.len(),
            cfg.autoscale.min_active,
        );
    }
    // per-server rows: heterogeneous fleets report every device's variant
    // set (and its residency), not just servers[0]'s
    for (si, srv) in fleet.servers.iter().enumerate() {
        if cfg.autoscale.enabled() {
            println!(
                "  server {si} ({}): starts {}",
                srv.device.name,
                if si < cfg.autoscale.min_active { "active" } else { "asleep" }
            );
        }
        if let Some(cap) = srv.mem_capacity_bytes {
            println!(
                "  server {si} ({}): {:.1} MB engine memory ({:.1} MB to hold all variants)",
                srv.device.name,
                cap as f64 / 1e6,
                srv.total_variant_bytes() as f64 / 1e6,
            );
        }
        let res = srv.initial_residency();
        for (vi, v) in srv.variants.iter().enumerate() {
            println!(
                "  s{si} {:<10} {:<9} acc_drop {:>5.2}%  batch-1 {:>8.3} ms  \
                 capacity {:>7.0} rps  weights {:>6.1} MB  {}{}  [{}]",
                srv.device.name,
                v.name,
                v.acc_drop * 100.0,
                v.batch1_ms(),
                v.capacity_rps(),
                v.weight_bytes as f64 / 1e6,
                if res[vi] { "resident" } else { "deployable" },
                if v.compliant(cfg.delta_max) { "" } else { "   << excluded (Δmax)" },
                v.schedule
            );
        }
    }
    // worker count changes wall-clock only: summaries are byte-identical
    // at any --jobs (see DESIGN.md §Parallelism), and the streamed path
    // is byte-identical to the materialized one on the same arrivals
    let summary = match requests {
        Some(n) => serve::simulate_fleet_stream(
            &fleet,
            serve::trace::ArrivalGen::new(&process, f64::INFINITY, seed).take(n),
            &cfg,
            jobs,
        )?,
        None => serve::simulate_fleet_jobs(&fleet, &arrivals, &cfg, jobs)?,
    };
    println!("{}", summary.render());
    Ok(())
}
