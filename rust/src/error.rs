//! Unified error type for the HQP crate.

use thiserror::Error;

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// All failure modes across the stack.
#[derive(Error, Debug)]
pub enum Error {
    /// Underlying XLA / PJRT failure (compile, execute, literal transfer).
    #[error("xla: {0}")]
    Xla(String),

    /// I/O failure (artifacts, weights, datasets).
    #[error("io: {0}")]
    Io(#[from] std::io::Error),

    /// Malformed `.npy` file.
    #[error("npy: {0}")]
    Npy(String),

    /// Malformed JSON (manifest, configs, result files).
    #[error("json: {0}")]
    Json(String),

    /// Manifest/artifact contract violation (missing keys, shape mismatch).
    #[error("manifest: {0}")]
    Manifest(String),

    /// Tensor shape/dtype misuse.
    #[error("shape: {0}")]
    Shape(String),

    /// Graph IR inconsistency (dangling tensor ids, bad channel counts).
    #[error("graph: {0}")]
    Graph(String),

    /// HQP pipeline misconfiguration or invariant violation.
    #[error("hqp: {0}")]
    Hqp(String),

    /// CLI usage error.
    #[error("cli: {0}")]
    Cli(String),
}

impl From<anyhow::Error> for Error {
    fn from(e: anyhow::Error) -> Self {
        Error::Xla(format!("{e:#}"))
    }
}

impl Error {
    /// Shorthand constructors used across the crate.
    pub fn manifest(msg: impl Into<String>) -> Self {
        Error::Manifest(msg.into())
    }
    pub fn shape(msg: impl Into<String>) -> Self {
        Error::Shape(msg.into())
    }
    pub fn graph(msg: impl Into<String>) -> Self {
        Error::Graph(msg.into())
    }
    pub fn hqp(msg: impl Into<String>) -> Self {
        Error::Hqp(msg.into())
    }
}
