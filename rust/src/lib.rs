//! # HQP — Sensitivity-Aware Hybrid Quantization and Pruning
//!
//! Rust implementation of the HQP framework (Gopalan & Ali, CS.DC 2026):
//! a coordinated model-compression pipeline that runs Fisher-information
//! sensitivity ranking, the conditional iterative structural-pruning loop
//! (Algorithm 1) and robust INT8 post-training quantization — entirely in
//! Rust, against JAX/Pallas models AOT-compiled to XLA HLO and executed
//! through the PJRT C API.
//!
//! ## Layering (see DESIGN.md)
//!
//! * **L3 (this crate)** — the paper's contribution: the HQP coordinator
//!   ([`hqp`]), the INT8 calibration machinery ([`quant`]), the
//!   TensorRT-like deployment optimizer ([`gopt`]), the Jetson-class
//!   hardware model ([`hwsim`]), the experiment coordinator
//!   ([`coordinator`]), the trace-driven edge serving simulator
//!   ([`serve`]) and the budgeted schedule-search engine ([`search`]).
//! * **L2/L1 (build time)** — `python/compile/`: JAX models with Pallas
//!   kernels, lowered once to `artifacts/*.hlo.txt` by `make artifacts`.
//!   Python is never on the request path.

pub mod benchkit;
pub mod cli;
pub mod coordinator;
pub mod error;
pub mod exec;
pub mod formats;
pub mod gopt;
pub mod graph;
pub mod hqp;
pub mod hwsim;
pub mod quant;
pub mod report;
pub mod runtime;
pub mod search;
pub mod serve;
pub mod tensor;
pub mod testkit;

pub use error::{Error, Result};

/// Crate version (mirrors Cargo.toml).
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}

/// One-stop imports for examples and downstream users.
pub mod prelude {
    pub use crate::error::{Error, Result};
    pub use crate::exec::Jobs;
    pub use crate::gopt::{optimize, OptimizedGraph};
    pub use crate::graph::Graph;
    pub use crate::hqp::{
        run_baseline, run_hqp, run_p50, run_q8, HqpConfig, MethodReport, Outcome, Schedule,
        Stage, StageSpec, StageState,
    };
    pub use crate::hwsim::{Device, DeviceKind};
    pub use crate::quant::CalibMethod;
    pub use crate::runtime::{Session, Workspace};
    pub use crate::search::{run_search, SearchConfig, SearchOutcome, SearchSpace};
    pub use crate::serve::{
        simulate_fleet, ArrivalProcess, AutoscaleConfig, Fleet, Policy, ScalePolicy, ServeConfig,
    };
    pub use crate::tensor::Tensor;
}
