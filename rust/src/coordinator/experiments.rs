//! The experiment runner: method suite × devices, with on-disk caching.
//!
//! Running one method on one model costs seconds (Q8) to minutes (HQP's
//! conditional loop), so results are cached under `artifacts/results/` and
//! keyed by `(model, method, config-signature)`; the table/figure benches
//! re-render from cache unless `force` is set.

use crate::error::Result;
use crate::gopt::{optimize, OptimizeOptions};
use crate::graph::Graph;
use crate::hqp::sensitivity::per_group_mean;
use crate::hqp::{
    deploy, pipeline, prune::per_group_sparsity, HqpConfig, MethodReport, RankingMethod,
};
use crate::hwsim::{simulate, Device};
use crate::runtime::{Session, Workspace};

use super::results::{load_results, save_results, ResultRow};

/// A method to run (the rows of Tables I/II + ablations).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MethodSpec {
    Baseline,
    Q8Only,
    /// Magnitude pruning to a fixed θ (percent), FP32.
    PruneOnly(u32),
    Hqp,
    /// HQP with a non-default ranking (ablations).
    HqpWithRanking(RankingMethod),
    /// HQP Phase 1 only (no PTQ).
    HqpPruneOnly,
}

impl MethodSpec {
    pub fn cache_key(&self, model: &str) -> String {
        match self {
            MethodSpec::Baseline => format!("{model}_baseline"),
            MethodSpec::Q8Only => format!("{model}_q8"),
            MethodSpec::PruneOnly(p) => format!("{model}_p{p}"),
            MethodSpec::Hqp => format!("{model}_hqp"),
            MethodSpec::HqpWithRanking(r) => format!("{model}_hqp_{}", r.name()),
            MethodSpec::HqpPruneOnly => format!("{model}_hqp_prune"),
        }
    }
}

/// Everything one suite run produces for one model.
pub struct SuiteResult {
    pub model: String,
    pub rows: Vec<ResultRow>,
}

/// Run one method on one model; produce per-device rows + analyses.
pub fn run_method(
    ws: &Workspace,
    model: &str,
    spec: MethodSpec,
    cfg: &HqpConfig,
    devices: &[Device],
    force: bool,
) -> Result<Vec<ResultRow>> {
    let results_dir = ws.root.join("results");
    let key = spec.cache_key(model);
    if !force {
        if let Some(rows) = load_results(&results_dir, &key)? {
            return Ok(rows);
        }
    }

    let mut sess = Session::new(ws, model)?;
    let outcome = match spec {
        MethodSpec::Baseline => pipeline::run_baseline(&mut sess)?,
        MethodSpec::Q8Only => pipeline::run_q8(&mut sess, cfg)?,
        MethodSpec::PruneOnly(pct) => pipeline::run_p50(&mut sess, pct as f64 / 100.0)?,
        MethodSpec::Hqp => pipeline::run_hqp(&mut sess, cfg)?,
        MethodSpec::HqpWithRanking(r) => {
            let mut c = cfg.clone();
            c.ranking = r;
            let mut o = pipeline::run_hqp(&mut sess, &c)?;
            o.method = format!("hqp[{}]", r.name());
            o
        }
        MethodSpec::HqpPruneOnly => pipeline::run_hqp_prune_only(&mut sess, cfg)?,
    };

    let graph = Graph::from_manifest(&sess.mm)?;
    let group_sparsity = per_group_sparsity(&outcome.masks);
    let group_saliency: Vec<f64> = outcome
        .saliency_scores
        .as_ref()
        .map(|s| per_group_mean(s, &sess.mm.groups).iter().map(|&x| x as f64).collect())
        .unwrap_or_default();
    let trace: Vec<(f64, f64, bool)> = outcome
        .trace
        .steps
        .iter()
        .map(|s| (s.sparsity, s.accuracy, s.accepted))
        .collect();

    // Counters describe the (device-independent) method run; every device
    // row carries the same snapshot so consumers of a single row see the
    // measured C_HQP terms and cache effectiveness alongside the report.
    let counters = sess.counters;
    let rows: Vec<ResultRow> = devices
        .iter()
        .map(|dev| {
            Ok(ResultRow {
                report: deploy::report(&graph, &outcome, dev, cfg.delta_max)?,
                trace: trace.clone(),
                group_sparsity: group_sparsity.clone(),
                group_saliency: group_saliency.clone(),
                counters,
            })
        })
        .collect::<Result<Vec<_>>>()?;

    save_results(&results_dir, &key, &rows)?;
    Ok(rows)
}

/// The paper's full method suite for one model.
pub fn run_suite(
    ws: &Workspace,
    model: &str,
    cfg: &HqpConfig,
    devices: &[Device],
    force: bool,
) -> Result<SuiteResult> {
    let mut rows = Vec::new();
    for spec in [
        MethodSpec::Baseline,
        MethodSpec::Q8Only,
        MethodSpec::PruneOnly(50),
        MethodSpec::Hqp,
    ] {
        rows.extend(run_method(ws, model, spec, cfg, devices, force)?);
    }
    Ok(SuiteResult { model: model.to_string(), rows })
}

/// Filter suite rows by device (table rendering helper).
pub fn rows_for_device<'a>(rows: &'a [ResultRow], device: &str) -> Vec<&'a ResultRow> {
    rows.iter().filter(|r| r.report.device == device).collect()
}

/// Convenience: reports only.
pub fn reports_for_device(rows: &[ResultRow], device: &str) -> Vec<MethodReport> {
    rows_for_device(rows, device)
        .into_iter()
        .map(|r| r.report.clone())
        .collect()
}

/// Latency of the dense FP32 engine on a device (speedup denominators in
/// cross-checks and the energy analysis).
pub fn baseline_latency(ws: &Workspace, model: &str, dev: &Device) -> Result<f64> {
    let mm = ws.manifest.model(model)?;
    let graph = Graph::from_manifest(mm)?;
    let masks: Vec<Vec<bool>> = graph.groups.iter().map(|g| vec![true; g.size]).collect();
    let eng = optimize(&graph, &masks, &OptimizeOptions::fp32())?;
    Ok(simulate(&eng, dev).latency_ms)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cache_keys_distinct() {
        let keys: Vec<String> = [
            MethodSpec::Baseline,
            MethodSpec::Q8Only,
            MethodSpec::PruneOnly(50),
            MethodSpec::PruneOnly(30),
            MethodSpec::Hqp,
            MethodSpec::HqpWithRanking(RankingMethod::MagnitudeL2),
            MethodSpec::HqpPruneOnly,
        ]
        .iter()
        .map(|s| s.cache_key("m"))
        .collect();
        let mut dedup = keys.clone();
        dedup.sort();
        dedup.dedup();
        assert_eq!(dedup.len(), keys.len());
    }
}
