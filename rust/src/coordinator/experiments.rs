//! The experiment runner: compression schedules × devices, with on-disk
//! caching.
//!
//! Running one schedule on one model costs seconds (Q8) to minutes (HQP's
//! conditional loop), so results are cached under `artifacts/results/`.
//! Cache keys are *schedule-canonical-string* keyed (v2:
//! `<model>_<schedule cache slug>`, e.g.
//! `resnet18_measure-baseline+prune+ptq`); rows written by the
//! pre-schedule coordinator under the legacy v1 method keys
//! (`<model>_hqp`, …) still load through a read-only fallback — see
//! DESIGN.md §Schedules. The table/figure benches re-render from cache
//! unless `force` is set.

use crate::error::Result;
use crate::exec::{parallel_map_init, Jobs, PoolReport};
use crate::gopt::{optimize, OptimizeOptions};
use crate::graph::Graph;
use crate::hqp::sensitivity::per_group_mean;
use crate::hqp::{
    deploy, prune::per_group_sparsity, HqpConfig, MethodReport, RankingMethod, Schedule,
    StageSpec,
};
use crate::hwsim::{simulate, Device};
use crate::runtime::{Session, Workspace};

use super::results::{load_results, save_results, ResultRow};

/// A legacy method to run (the rows of Tables I/II + ablations).
///
/// **Deprecated alias**: the closed enum survives only as a spelling of
/// the schedule presets — [`MethodSpec::to_schedule`] lowers each variant
/// to its [`Schedule`], and [`run_method`] is now a thin wrapper over
/// [`run_schedule`]. New orderings (e.g. the §V-B quantize-first
/// ablation, `ptq >> prune`) are only expressible as schedules; prefer
/// [`Schedule::parse`] / [`Schedule::preset`] in new code.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MethodSpec {
    Baseline,
    Q8Only,
    /// Magnitude pruning to a fixed θ (percent), FP32.
    PruneOnly(u32),
    Hqp,
    /// HQP with a non-default ranking (ablations).
    HqpWithRanking(RankingMethod),
    /// HQP Phase 1 only (no PTQ).
    HqpPruneOnly,
}

impl MethodSpec {
    /// The legacy (v1) result-cache key — kept so existing caches load.
    pub fn cache_key(&self, model: &str) -> String {
        match self {
            MethodSpec::Baseline => format!("{model}_baseline"),
            MethodSpec::Q8Only => format!("{model}_q8"),
            MethodSpec::PruneOnly(p) => format!("{model}_p{p}"),
            MethodSpec::Hqp => format!("{model}_hqp"),
            MethodSpec::HqpWithRanking(r) => format!("{model}_hqp_{}", r.name()),
            MethodSpec::HqpPruneOnly => format!("{model}_hqp_prune"),
        }
    }

    /// Lower to the equivalent schedule preset (same label, same
    /// computation, same `ResultRow`s — property-tested in
    /// `tests/integration_pipeline.rs`).
    pub fn to_schedule(&self, cfg: &HqpConfig) -> Schedule {
        match self {
            MethodSpec::Baseline => Schedule::preset("baseline", cfg).unwrap(),
            MethodSpec::Q8Only => Schedule::preset("q8-only", cfg).unwrap(),
            MethodSpec::PruneOnly(pct) => Schedule::prune_only_at(*pct as f64 / 100.0),
            MethodSpec::Hqp => Schedule::preset("hqp", cfg).unwrap(),
            MethodSpec::HqpWithRanking(r) => Schedule {
                stages: vec![
                    StageSpec::MeasureBaseline,
                    StageSpec::Prune {
                        ranking: Some(*r),
                        step_frac: None,
                        delta_max: None,
                        max_sparsity: None,
                        samples: None,
                    },
                    StageSpec::Ptq { calib: None, recalib: false, samples: None },
                ],
                label: Some(format!("hqp[{}]", r.name())),
                legacy_key: Some(format!("hqp_{}", r.name())),
            },
            MethodSpec::HqpPruneOnly => Schedule::preset("hqp-prune", cfg).unwrap(),
        }
    }
}

/// Everything one suite run produces for one model.
pub struct SuiteResult {
    pub model: String,
    pub rows: Vec<ResultRow>,
}

/// Load cached rows for a schedule: the v2 schedule-slug key first, then
/// the legacy v1 method key (pre-schedule caches). Shared with
/// [`crate::serve::fleet::workspace_fleet`], so serving picks up measured
/// accuracy from either cache generation.
pub fn load_schedule_results(
    results_dir: &std::path::Path,
    model: &str,
    sched: &Schedule,
) -> Result<Option<Vec<ResultRow>>> {
    let key = format!("{model}_{}", sched.cache_slug());
    if let Some(rows) = load_results(results_dir, &key)? {
        return Ok(Some(rows));
    }
    if let Some(suffix) = &sched.legacy_key {
        if let Some(rows) = load_results(results_dir, &format!("{model}_{suffix}"))? {
            return Ok(Some(rows));
        }
    }
    Ok(None)
}

/// Run one schedule on one model; produce per-device rows + analyses.
pub fn run_schedule(
    ws: &Workspace,
    model: &str,
    sched: &Schedule,
    cfg: &HqpConfig,
    devices: &[Device],
    force: bool,
) -> Result<Vec<ResultRow>> {
    let results_dir = ws.root.join("results");
    if !force {
        if let Some(rows) = load_schedule_results(&results_dir, model, sched)? {
            return Ok(rows);
        }
    }

    let mut sess = Session::new(ws, model)?;
    let outcome = sched.run(&mut sess, cfg)?;

    let graph = Graph::from_manifest(&sess.mm)?;
    let group_sparsity = per_group_sparsity(&outcome.masks);
    let group_saliency: Vec<f64> = outcome
        .saliency_scores
        .as_ref()
        .map(|s| per_group_mean(s, &sess.mm.groups).iter().map(|&x| x as f64).collect())
        .unwrap_or_default();
    let trace: Vec<(f64, f64, bool)> = outcome
        .trace
        .steps
        .iter()
        .map(|s| (s.sparsity, s.accuracy, s.accepted))
        .collect();

    // Counters describe the (device-independent) schedule run; every
    // device row carries the same snapshot so consumers of a single row
    // see the measured C_HQP terms and cache effectiveness alongside the
    // report.
    let counters = sess.counters;
    let rows: Vec<ResultRow> = devices
        .iter()
        .map(|dev| {
            Ok(ResultRow {
                report: deploy::report(&graph, &outcome, dev, cfg.delta_max)?,
                trace: trace.clone(),
                group_sparsity: group_sparsity.clone(),
                group_saliency: group_saliency.clone(),
                counters,
            })
        })
        .collect::<Result<Vec<_>>>()?;

    save_results(&results_dir, &format!("{model}_{}", sched.cache_slug()), &rows)?;
    Ok(rows)
}

/// Run one legacy method on one model (deprecated alias — lowers to the
/// method's schedule preset and delegates to [`run_schedule`]).
pub fn run_method(
    ws: &Workspace,
    model: &str,
    spec: MethodSpec,
    cfg: &HqpConfig,
    devices: &[Device],
    force: bool,
) -> Result<Vec<ResultRow>> {
    run_schedule(ws, model, &spec.to_schedule(cfg), cfg, devices, force)
}

/// The candidates one suite run evaluates, in row order (Tables I/II).
pub const SUITE_SPECS: [MethodSpec; 4] = [
    MethodSpec::Baseline,
    MethodSpec::Q8Only,
    MethodSpec::PruneOnly(50),
    MethodSpec::Hqp,
];

/// The paper's full method suite for one model, evaluated sequentially
/// on the caller's `Workspace`. Byte-identical to [`run_suite_jobs`] at
/// any worker count (rows merge in [`SUITE_SPECS`] order either way).
pub fn run_suite(
    ws: &Workspace,
    model: &str,
    cfg: &HqpConfig,
    devices: &[Device],
    force: bool,
) -> Result<SuiteResult> {
    let mut rows = Vec::new();
    for spec in SUITE_SPECS {
        rows.extend(run_method(ws, model, spec, cfg, devices, force)?);
    }
    Ok(SuiteResult { model: model.to_string(), rows })
}

/// The paper's full method suite for one model, with schedule candidates
/// fanned out to up to `jobs` workers ([`crate::exec::parallel_map_init`]).
///
/// Each worker opens its own [`Workspace`] on its own thread (PJRT
/// clients are not `Send`) and keeps its own `Session` device-buffer
/// cache; CoW `ParamStore` clones make the per-candidate state cheap.
/// Rows merge in submission ([`SUITE_SPECS`]) order and
/// [`save_results`] writes atomically, so both the returned
/// `ResultRow`s and the cache files are byte-identical to [`run_suite`].
/// The returned [`PoolReport`] carries the per-worker counters
/// (`hqp run --jobs N` prints it; `bench_exec` asserts the speedup).
pub fn run_suite_jobs(
    root: &std::path::Path,
    model: &str,
    cfg: &HqpConfig,
    devices: &[Device],
    force: bool,
    jobs: Jobs,
) -> Result<(SuiteResult, PoolReport)> {
    let (per_spec, report) = parallel_map_init(
        jobs,
        SUITE_SPECS.to_vec(),
        |_worker| Workspace::open(root),
        |ws, spec, _i| run_method(ws, model, spec, cfg, devices, force),
    )?;
    let rows = per_spec.into_iter().flatten().collect();
    Ok((SuiteResult { model: model.to_string(), rows }, report))
}

/// Filter suite rows by device (table rendering helper).
pub fn rows_for_device<'a>(rows: &'a [ResultRow], device: &str) -> Vec<&'a ResultRow> {
    rows.iter().filter(|r| r.report.device == device).collect()
}

/// Convenience: reports only.
pub fn reports_for_device(rows: &[ResultRow], device: &str) -> Vec<MethodReport> {
    rows_for_device(rows, device)
        .into_iter()
        .map(|r| r.report.clone())
        .collect()
}

/// Latency of the dense FP32 engine on a device (speedup denominators in
/// cross-checks and the energy analysis).
pub fn baseline_latency(ws: &Workspace, model: &str, dev: &Device) -> Result<f64> {
    let mm = ws.manifest.model(model)?;
    let graph = Graph::from_manifest(mm)?;
    let masks: Vec<Vec<bool>> = graph.groups.iter().map(|g| vec![true; g.size]).collect();
    let eng = optimize(&graph, &masks, &OptimizeOptions::fp32())?;
    Ok(simulate(&eng, dev).latency_ms)
}

#[cfg(test)]
mod tests {
    use super::*;

    const SPECS: [MethodSpec; 7] = [
        MethodSpec::Baseline,
        MethodSpec::Q8Only,
        MethodSpec::PruneOnly(50),
        MethodSpec::PruneOnly(30),
        MethodSpec::Hqp,
        MethodSpec::HqpWithRanking(RankingMethod::MagnitudeL2),
        MethodSpec::HqpPruneOnly,
    ];

    #[test]
    fn cache_keys_distinct() {
        let keys: Vec<String> = SPECS.iter().map(|s| s.cache_key("m")).collect();
        let mut dedup = keys.clone();
        dedup.sort();
        dedup.dedup();
        assert_eq!(dedup.len(), keys.len());
    }

    #[test]
    fn schedule_keys_distinct_and_carry_legacy_fallback() {
        let cfg = HqpConfig::default();
        let keys: Vec<String> = SPECS
            .iter()
            .map(|s| format!("m_{}", s.to_schedule(&cfg).cache_slug()))
            .collect();
        let mut dedup = keys.clone();
        dedup.sort();
        dedup.dedup();
        assert_eq!(dedup.len(), keys.len(), "v2 keys must not collide: {keys:?}");
        // every legacy spec's schedule falls back to exactly its v1 key
        for spec in SPECS {
            let sched = spec.to_schedule(&cfg);
            let legacy = sched
                .legacy_key
                .as_ref()
                .map(|suffix| format!("m_{suffix}"))
                .expect("every MethodSpec preset carries a legacy key");
            assert_eq!(legacy, spec.cache_key("m"), "{spec:?}");
        }
    }

    #[test]
    fn legacy_cache_fallback_loads_v1_files() {
        use crate::runtime::Counters;
        let dir = std::env::temp_dir().join("hqp_sched_cache_fallback");
        let _ = std::fs::remove_dir_all(&dir);
        let cfg = HqpConfig::default();
        let sched = MethodSpec::Hqp.to_schedule(&cfg);
        // nothing cached yet
        assert!(load_schedule_results(&dir, "m", &sched).unwrap().is_none());
        let row = ResultRow {
            report: MethodReport {
                method: "hqp".into(),
                model: "m".into(),
                device: "nx".into(),
                latency_ms: 0.5,
                speedup: 2.5,
                size_reduction: 0.8,
                acc_drop: 0.013,
                sparsity: 0.45,
                compliant: true,
                energy_mj: 7.5,
                energy_ratio: 2.5,
                flops: 1,
            },
            trace: vec![],
            group_sparsity: vec![],
            group_saliency: vec![],
            counters: Counters::default(),
        };
        // a pre-schedule cache file under the legacy v1 key still loads
        save_results(&dir, "m_hqp", &[row]).unwrap();
        let got = load_schedule_results(&dir, "m", &sched).unwrap().unwrap();
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].report.method, "hqp");
        // ad-hoc schedules have no legacy fallback
        let adhoc = Schedule::parse("ptq >> prune").unwrap();
        assert!(load_schedule_results(&dir, "m", &adhoc).unwrap().is_none());
    }
}
