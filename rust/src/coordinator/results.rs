//! Result persistence: method reports + prune traces as JSON under
//! `artifacts/results/`, so figures re-render without re-running pipelines
//! and EXPERIMENTS.md can be regenerated deterministically.

use std::path::Path;

use crate::error::{Error, Result};
use crate::formats::json::Json;
use crate::hqp::MethodReport;
use crate::runtime::Counters;

/// One persisted row = [`MethodReport`] + optional prune trace.
#[derive(Clone, Debug)]
pub struct ResultRow {
    pub report: MethodReport,
    /// (sparsity, accuracy, accepted) triples of the conditional loop.
    pub trace: Vec<(f64, f64, bool)>,
    /// Per-group sparsity (layer-wise analysis).
    pub group_sparsity: Vec<f64>,
    /// Per-group mean Fisher S (layer-wise analysis).
    pub group_saliency: Vec<f64>,
    /// Session execution counters of the method run that produced this row
    /// (the measured §III-C cost terms + caching effectiveness: uploaded
    /// parameter tensors/bytes, early-exit batches skipped).
    pub counters: Counters,
}

fn report_to_json(r: &MethodReport) -> Json {
    Json::obj()
        .set("method", r.method.clone())
        .set("model", r.model.clone())
        .set("device", r.device.clone())
        .set("latency_ms", r.latency_ms)
        .set("speedup", r.speedup)
        .set("size_reduction", r.size_reduction)
        .set("acc_drop", r.acc_drop)
        .set("sparsity", r.sparsity)
        .set("compliant", r.compliant)
        .set("energy_mj", r.energy_mj)
        .set("energy_ratio", r.energy_ratio)
        .set("flops", r.flops as f64)
}

fn counters_to_json(c: &Counters) -> Json {
    Json::obj()
        .set("inference_samples", c.inference_samples as f64)
        .set("grad_samples", c.grad_samples as f64)
        .set("executions", c.executions as f64)
        .set("upload_bytes", c.upload_bytes as f64)
        .set("upload_tensors", c.upload_tensors as f64)
        .set("batches_skipped", c.batches_skipped as f64)
}

/// Missing key → zero counters: rows cached before the counters field
/// existed stay loadable.
fn counters_from_json(v: &Json) -> Result<Counters> {
    let c = match v.get("counters") {
        Some(c) => c,
        None => return Ok(Counters::default()),
    };
    let u = |key: &str| -> Result<u64> { Ok(c.req(key)?.as_f64()? as u64) };
    Ok(Counters {
        inference_samples: u("inference_samples")?,
        grad_samples: u("grad_samples")?,
        executions: u("executions")?,
        upload_bytes: u("upload_bytes")?,
        upload_tensors: u("upload_tensors")?,
        batches_skipped: u("batches_skipped")?,
    })
}

fn report_from_json(v: &Json) -> Result<MethodReport> {
    Ok(MethodReport {
        method: v.req("method")?.as_str()?.to_string(),
        model: v.req("model")?.as_str()?.to_string(),
        device: v.req("device")?.as_str()?.to_string(),
        latency_ms: v.req("latency_ms")?.as_f64()?,
        speedup: v.req("speedup")?.as_f64()?,
        size_reduction: v.req("size_reduction")?.as_f64()?,
        acc_drop: v.req("acc_drop")?.as_f64()?,
        sparsity: v.req("sparsity")?.as_f64()?,
        compliant: v.req("compliant")?.as_bool()?,
        energy_mj: v.req("energy_mj")?.as_f64()?,
        energy_ratio: v.req("energy_ratio")?.as_f64()?,
        flops: v.req("flops")?.as_f64()? as u64,
    })
}

/// Serialize rows to `<dir>/<name>.json`. The write is atomic (unique
/// temp file + rename), so concurrent suite workers saving different
/// keys — or even the same key with the same bytes — never leave a
/// torn file for a reader to trip over.
pub fn save_results(dir: impl AsRef<Path>, name: &str, rows: &[ResultRow]) -> Result<()> {
    let dir = dir.as_ref();
    std::fs::create_dir_all(dir)?;
    let arr = Json::Arr(
        rows.iter()
            .map(|r| {
                report_to_json(&r.report)
                    .set(
                        "trace",
                        Json::Arr(
                            r.trace
                                .iter()
                                .map(|(s, a, ok)| {
                                    Json::Arr(vec![Json::Num(*s), Json::Num(*a), Json::Bool(*ok)])
                                })
                                .collect(),
                        ),
                    )
                    .set("group_sparsity", r.group_sparsity.clone())
                    .set("group_saliency", r.group_saliency.clone())
                    .set("counters", counters_to_json(&r.counters))
            })
            .collect(),
    );
    let tmp = dir.join(format!(
        ".{name}.{}.{}.tmp",
        std::process::id(),
        SAVE_SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed)
    ));
    std::fs::write(&tmp, arr.to_string_pretty())?;
    std::fs::rename(&tmp, dir.join(format!("{name}.json")))?;
    Ok(())
}

/// Per-process temp-file disambiguator for [`save_results`] (two workers
/// saving the same key must not share a temp path).
static SAVE_SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);

/// Load rows back (None if the file doesn't exist).
pub fn load_results(dir: impl AsRef<Path>, name: &str) -> Result<Option<Vec<ResultRow>>> {
    let path = dir.as_ref().join(format!("{name}.json"));
    if !path.exists() {
        return Ok(None);
    }
    let text = std::fs::read_to_string(&path)?;
    let v = Json::parse(&text)?;
    let rows = v
        .as_arr()?
        .iter()
        .map(|r| {
            let trace = r
                .req("trace")?
                .as_arr()?
                .iter()
                .map(|t| {
                    let p = t.as_arr()?;
                    if p.len() != 3 {
                        return Err(Error::Json("trace triple".into()));
                    }
                    Ok((p[0].as_f64()?, p[1].as_f64()?, p[2].as_bool()?))
                })
                .collect::<Result<Vec<_>>>()?;
            let group_sparsity = r
                .req("group_sparsity")?
                .as_arr()?
                .iter()
                .map(|x| x.as_f64())
                .collect::<Result<Vec<_>>>()?;
            let group_saliency = r
                .req("group_saliency")?
                .as_arr()?
                .iter()
                .map(|x| x.as_f64())
                .collect::<Result<Vec<_>>>()?;
            Ok(ResultRow {
                report: report_from_json(r)?,
                trace,
                group_sparsity,
                group_saliency,
                counters: counters_from_json(r)?,
            })
        })
        .collect::<Result<Vec<_>>>()?;
    Ok(Some(rows))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row() -> ResultRow {
        ResultRow {
            report: MethodReport {
                method: "hqp".into(),
                model: "m".into(),
                device: "nx".into(),
                latency_ms: 0.5,
                speedup: 2.5,
                size_reduction: 0.8,
                acc_drop: 0.013,
                sparsity: 0.45,
                compliant: true,
                energy_mj: 7.5,
                energy_ratio: 2.5,
                flops: 123456,
            },
            trace: vec![(0.01, 0.93, true), (0.02, 0.92, false)],
            group_sparsity: vec![0.0, 0.5],
            group_saliency: vec![1.5, 0.1],
            counters: Counters {
                inference_samples: 9216,
                grad_samples: 128,
                executions: 40,
                upload_bytes: 708_608,
                upload_tensors: 62,
                batches_skipped: 5,
            },
        }
    }

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("hqp_results_test");
        save_results(&dir, "t1", &[row()]).unwrap();
        let back = load_results(&dir, "t1").unwrap().unwrap();
        assert_eq!(back.len(), 1);
        let r = &back[0].report;
        assert_eq!(r.method, "hqp");
        assert_eq!(r.flops, 123456);
        assert_eq!(back[0].trace.len(), 2);
        assert_eq!(back[0].trace[1].2, false);
        assert_eq!(back[0].group_sparsity, vec![0.0, 0.5]);
        let c = back[0].counters;
        assert_eq!(c.inference_samples, 9216);
        assert_eq!(c.upload_bytes, 708_608);
        assert_eq!(c.upload_tensors, 62);
        assert_eq!(c.batches_skipped, 5);
    }

    #[test]
    fn rows_without_counters_load_as_zero() {
        // pre-counters cache files stay readable
        let dir = std::env::temp_dir().join("hqp_results_test_compat");
        save_results(&dir, "t2", &[row()]).unwrap();
        let path = dir.join("t2.json");
        let text = std::fs::read_to_string(&path).unwrap();
        let mut arr = crate::formats::json::Json::parse(&text).unwrap();
        if let crate::formats::json::Json::Arr(rows) = &mut arr {
            if let crate::formats::json::Json::Obj(entries) = &mut rows[0] {
                entries.retain(|(k, _)| k != "counters");
            }
        }
        std::fs::write(&path, arr.to_string_pretty()).unwrap();
        let back = load_results(&dir, "t2").unwrap().unwrap();
        assert_eq!(back[0].counters.executions, 0);
        assert_eq!(back[0].counters.upload_bytes, 0);
    }

    #[test]
    fn missing_file_is_none() {
        let dir = std::env::temp_dir().join("hqp_results_test");
        assert!(load_results(&dir, "nope").unwrap().is_none());
    }
}
