//! Experiment coordinator: orchestrates compression schedules across
//! models and devices, caches outcomes (the pruning loop is minutes of
//! PJRT work — the table/figure benches must not re-run it per
//! rendering), and serializes results for EXPERIMENTS.md.
//!
//! [`run_schedule`] is the core entry point; [`run_method`] /
//! [`MethodSpec`] survive as deprecated aliases that lower each legacy
//! method to its schedule preset.
//!
//! Suite candidates are embarrassingly parallel, and [`run_suite_jobs`]
//! fans them out to a [`crate::exec`] worker pool (`hqp run --jobs N`).
//! Each worker opens its own [`crate::runtime::Workspace`] on its own
//! thread — PJRT clients are not `Send`, so per-worker state is *born*
//! where it runs — and keeps its own `Session` cache over CoW
//! `ParamStore` clones. Determinism contract (see DESIGN.md
//! §Parallelism): rows merge in submission order and result-cache files
//! are written atomically, so `ResultRow` JSON and the cache directory
//! are byte-identical to the sequential [`run_suite`] at any `--jobs`
//! (property-tested in `tests/prop_exec.rs`). Result caching stays the
//! first-line optimization either way: a cached candidate costs one
//! JSON read no matter how many workers are idle.

pub mod experiments;
pub mod results;

pub use experiments::{
    load_schedule_results, run_method, run_schedule, run_suite, run_suite_jobs, MethodSpec,
    SuiteResult, SUITE_SPECS,
};
pub use results::{load_results, save_results, ResultRow};
