//! Experiment coordinator: orchestrates compression schedules across
//! models and devices, caches outcomes (the pruning loop is minutes of
//! PJRT work — the table/figure benches must not re-run it per
//! rendering), and serializes results for EXPERIMENTS.md.
//!
//! [`run_schedule`] is the core entry point; [`run_method`] /
//! [`MethodSpec`] survive as deprecated aliases that lower each legacy
//! method to its schedule preset.
//!
//! The coordinator is deliberately synchronous: the execution budget of
//! this environment is one CPU core and PJRT executions fully occupy it, so
//! a thread pool would only add scheduling noise (tokio is additionally
//! unavailable offline — see Cargo.toml). The design keeps the runner
//! single-threaded with explicit result caching instead.

pub mod experiments;
pub mod results;

pub use experiments::{
    load_schedule_results, run_method, run_schedule, run_suite, MethodSpec, SuiteResult,
};
pub use results::{load_results, save_results, ResultRow};
