//! Minimal dense tensor type used across the coordinator.
//!
//! Deliberately small: the heavy math lives in the AOT-compiled XLA
//! executables; the Rust side only needs parameter surgery (filter masking,
//! INT8 grid projection), batching, accuracy reduction and accounting.
//! Row-major (C-order) f32 / i32 tensors, matching `.npy` and XLA literal
//! layouts.

mod ops;

pub use ops::{argmax_rows, count_correct};

use crate::error::{Error, Result};

/// Dense row-major f32 tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

/// Dense row-major i32 tensor (labels, indices).
#[derive(Clone, Debug, PartialEq)]
pub struct TensorI32 {
    shape: Vec<usize>,
    data: Vec<i32>,
}

impl Tensor {
    /// Build from raw parts; validates element count.
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            return Err(Error::shape(format!(
                "shape {shape:?} wants {n} elems, got {}",
                data.len()
            )));
        }
        Ok(Tensor { shape, data })
    }

    /// All-zeros tensor.
    pub fn zeros(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        Tensor { shape, data: vec![0.0; n] }
    }

    /// Filled tensor.
    pub fn full(shape: Vec<usize>, v: f32) -> Self {
        let n = shape.iter().product();
        Tensor { shape, data: vec![v; n] }
    }

    /// 1-D tensor from a slice.
    pub fn from_slice(v: &[f32]) -> Self {
        Tensor { shape: vec![v.len()], data: v.to_vec() }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    /// Reinterpret with a new shape of identical element count.
    pub fn reshape(mut self, shape: Vec<usize>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != self.data.len() {
            return Err(Error::shape(format!(
                "reshape {:?} -> {shape:?}: element count mismatch",
                self.shape
            )));
        }
        self.shape = shape;
        Ok(self)
    }

    /// Row-major strides.
    pub fn strides(&self) -> Vec<usize> {
        let mut s = vec![1usize; self.shape.len()];
        for i in (0..self.shape.len().saturating_sub(1)).rev() {
            s[i] = s[i + 1] * self.shape[i + 1];
        }
        s
    }

    /// Zero every element whose index along `axis` equals `idx`.
    ///
    /// This is the filter-masking primitive of Algorithm 1: pruning filter
    /// `j` of a group zeroes slice `j` of every member tensor (producer
    /// conv weights along the out-channel axis, BN gamma/beta along axis 0,
    /// depthwise filters along their channel axis). See DESIGN.md §2.
    pub fn zero_slice(&mut self, axis: usize, idx: usize) -> Result<()> {
        if axis >= self.shape.len() {
            return Err(Error::shape(format!(
                "zero_slice axis {axis} out of range for {:?}",
                self.shape
            )));
        }
        if idx >= self.shape[axis] {
            return Err(Error::shape(format!(
                "zero_slice idx {idx} out of range for axis {axis} of {:?}",
                self.shape
            )));
        }
        let strides = self.strides();
        let axis_stride = strides[axis];
        let axis_len = self.shape[axis];
        // Iterate blocks of the outer dimensions; within each, the slice at
        // `idx` occupies a contiguous run of `axis_stride` elements.
        let outer: usize = self.shape[..axis].iter().product();
        let block = axis_len * axis_stride;
        for o in 0..outer {
            let base = o * block + idx * axis_stride;
            self.data[base..base + axis_stride].fill(0.0);
        }
        Ok(())
    }

    /// Sum of squares of the slice at `idx` along `axis` (used by the
    /// magnitude-pruning baselines: L1/L2 filter norms).
    pub fn slice_norm(&self, axis: usize, idx: usize, l1: bool) -> Result<f32> {
        if axis >= self.shape.len() || idx >= self.shape[axis] {
            return Err(Error::shape(format!(
                "slice_norm axis {axis}/{idx} out of range for {:?}",
                self.shape
            )));
        }
        let strides = self.strides();
        let axis_stride = strides[axis];
        let axis_len = self.shape[axis];
        let outer: usize = self.shape[..axis].iter().product();
        let block = axis_len * axis_stride;
        let mut acc = 0.0f32;
        for o in 0..outer {
            let base = o * block + idx * axis_stride;
            for &v in &self.data[base..base + axis_stride] {
                acc += if l1 { v.abs() } else { v * v };
            }
        }
        Ok(acc)
    }

    /// Max |x| over the whole tensor.
    pub fn absmax(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &v| m.max(v.abs()))
    }

    /// Max |x| per slice along `axis` (per-channel dynamic ranges).
    pub fn absmax_along(&self, axis: usize) -> Result<Vec<f32>> {
        if axis >= self.shape.len() {
            return Err(Error::shape(format!(
                "absmax_along axis {axis} out of range for {:?}",
                self.shape
            )));
        }
        let strides = self.strides();
        let axis_stride = strides[axis];
        let axis_len = self.shape[axis];
        let outer: usize = self.shape[..axis].iter().product();
        let block = axis_len * axis_stride;
        let mut out = vec![0.0f32; axis_len];
        for o in 0..outer {
            for j in 0..axis_len {
                let base = o * block + j * axis_stride;
                for &v in &self.data[base..base + axis_stride] {
                    if v.abs() > out[j] {
                        out[j] = v.abs();
                    }
                }
            }
        }
        Ok(out)
    }

    /// Rows `lo..hi` of a rank-2+ tensor along axis 0 (batch slicing).
    pub fn rows(&self, lo: usize, hi: usize) -> Result<Tensor> {
        if self.shape.is_empty() || hi > self.shape[0] || lo > hi {
            return Err(Error::shape(format!(
                "rows {lo}..{hi} out of range for {:?}",
                self.shape
            )));
        }
        let row: usize = self.shape[1..].iter().product();
        let mut shape = self.shape.clone();
        shape[0] = hi - lo;
        Ok(Tensor { shape, data: self.data[lo * row..hi * row].to_vec() })
    }

    /// Concatenate along axis 0.
    pub fn concat_rows(parts: &[Tensor]) -> Result<Tensor> {
        let first = parts.first().ok_or_else(|| Error::shape("concat of nothing"))?;
        let mut shape = first.shape.clone();
        let mut data = Vec::new();
        let mut rows = 0usize;
        for p in parts {
            if p.shape[1..] != first.shape[1..] {
                return Err(Error::shape("concat_rows: trailing dims differ"));
            }
            rows += p.shape[0];
            data.extend_from_slice(&p.data);
        }
        shape[0] = rows;
        Tensor::new(shape, data)
    }

    /// Pad with zero rows along axis 0 up to `n` rows.
    pub fn pad_rows_to(&self, n: usize) -> Result<Tensor> {
        if self.shape.is_empty() || self.shape[0] > n {
            return Err(Error::shape(format!(
                "pad_rows_to {n} from {:?}",
                self.shape
            )));
        }
        let row: usize = self.shape[1..].iter().product();
        let mut shape = self.shape.clone();
        shape[0] = n;
        let mut data = self.data.clone();
        data.resize(n * row, 0.0);
        Tensor::new(shape, data)
    }
}

impl TensorI32 {
    pub fn new(shape: Vec<usize>, data: Vec<i32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            return Err(Error::shape(format!(
                "shape {shape:?} wants {n} elems, got {}",
                data.len()
            )));
        }
        Ok(TensorI32 { shape, data })
    }

    pub fn from_slice(v: &[i32]) -> Self {
        TensorI32 { shape: vec![v.len()], data: v.to_vec() }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[i32] {
        &self.data
    }

    pub fn rows(&self, lo: usize, hi: usize) -> Result<TensorI32> {
        if self.shape.is_empty() || hi > self.shape[0] || lo > hi {
            return Err(Error::shape(format!(
                "rows {lo}..{hi} out of range for {:?}",
                self.shape
            )));
        }
        let row: usize = self.shape[1..].iter().product();
        let mut shape = self.shape.clone();
        shape[0] = hi - lo;
        Ok(TensorI32 { shape, data: self.data[lo * row..hi * row].to_vec() })
    }

    pub fn pad_rows_to(&self, n: usize) -> Result<TensorI32> {
        if self.shape.is_empty() || self.shape[0] > n {
            return Err(Error::shape(format!("pad_rows_to {n} from {:?}", self.shape)));
        }
        let row: usize = self.shape[1..].iter().product();
        let mut shape = self.shape.clone();
        shape[0] = n;
        let mut data = self.data.clone();
        data.resize(n * row, 0);
        TensorI32::new(shape, data)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_validates_count() {
        assert!(Tensor::new(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::new(vec![2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn strides_row_major() {
        let t = Tensor::zeros(vec![2, 3, 4]);
        assert_eq!(t.strides(), vec![12, 4, 1]);
    }

    #[test]
    fn zero_slice_axis0() {
        let mut t = Tensor::new(vec![3, 2], (0..6).map(|v| v as f32 + 1.0).collect()).unwrap();
        t.zero_slice(0, 1).unwrap();
        assert_eq!(t.data(), &[1.0, 2.0, 0.0, 0.0, 5.0, 6.0]);
    }

    #[test]
    fn zero_slice_axis1() {
        let mut t = Tensor::new(vec![2, 3], (0..6).map(|v| v as f32 + 1.0).collect()).unwrap();
        t.zero_slice(1, 0).unwrap();
        assert_eq!(t.data(), &[0.0, 2.0, 3.0, 0.0, 5.0, 6.0]);
    }

    #[test]
    fn zero_slice_last_axis_of_conv_weight() {
        // (k,k,I,O) conv weight: zero out-channel 1 of 2
        let mut t = Tensor::full(vec![3, 3, 4, 2], 1.0);
        t.zero_slice(3, 1).unwrap();
        let sum: f32 = t.data().iter().sum();
        assert_eq!(sum, (3 * 3 * 4) as f32);
    }

    #[test]
    fn slice_norm_l1_l2() {
        let t = Tensor::new(vec![2, 2], vec![1.0, -2.0, 3.0, -4.0]).unwrap();
        assert_eq!(t.slice_norm(0, 1, true).unwrap(), 7.0);
        assert_eq!(t.slice_norm(0, 1, false).unwrap(), 25.0);
        assert_eq!(t.slice_norm(1, 0, true).unwrap(), 4.0);
    }

    #[test]
    fn absmax_along_channels() {
        let t = Tensor::new(vec![2, 3], vec![1.0, -5.0, 2.0, -3.0, 4.0, 0.5]).unwrap();
        assert_eq!(t.absmax_along(1).unwrap(), vec![3.0, 5.0, 2.0]);
        assert_eq!(t.absmax(), 5.0);
    }

    #[test]
    fn rows_and_pad() {
        let t = Tensor::new(vec![4, 2], (0..8).map(|v| v as f32).collect()).unwrap();
        let r = t.rows(1, 3).unwrap();
        assert_eq!(r.shape(), &[2, 2]);
        assert_eq!(r.data(), &[2.0, 3.0, 4.0, 5.0]);
        let p = r.pad_rows_to(4).unwrap();
        assert_eq!(p.shape(), &[4, 2]);
        assert_eq!(&p.data()[4..], &[0.0; 4]);
    }

    #[test]
    fn concat_roundtrip() {
        let t = Tensor::new(vec![4, 3], (0..12).map(|v| v as f32).collect()).unwrap();
        let a = t.rows(0, 2).unwrap();
        let b = t.rows(2, 4).unwrap();
        assert_eq!(Tensor::concat_rows(&[a, b]).unwrap(), t);
    }

    #[test]
    fn reshape_checks() {
        let t = Tensor::zeros(vec![2, 6]);
        assert!(t.clone().reshape(vec![3, 4]).is_ok());
        assert!(t.reshape(vec![5]).is_err());
    }
}
