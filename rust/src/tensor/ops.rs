//! Free-standing tensor reductions used on the coordinator hot path.

use super::Tensor;

/// Argmax of each row of a (N, C) tensor.
pub fn argmax_rows(logits: &Tensor) -> Vec<usize> {
    assert_eq!(logits.rank(), 2, "argmax_rows wants rank-2");
    let (n, c) = (logits.shape()[0], logits.shape()[1]);
    let d = logits.data();
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let row = &d[i * c..(i + 1) * c];
        let mut best = 0usize;
        let mut bv = f32::NEG_INFINITY;
        for (j, &v) in row.iter().enumerate() {
            if v > bv {
                bv = v;
                best = j;
            }
        }
        out.push(best);
    }
    out
}

/// Count of rows whose argmax equals the label, in a single pass over the
/// logits (no intermediate argmax Vec). `labels` may be longer than
/// `valid_rows` (padding tail ignored) but never shorter — a short label
/// slice would silently undercount, so it is rejected loudly.
pub fn count_correct(logits: &Tensor, labels: &[i32], valid_rows: usize) -> usize {
    assert_eq!(logits.rank(), 2, "count_correct wants rank-2 logits");
    let (n, c) = (logits.shape()[0], logits.shape()[1]);
    assert!(
        valid_rows <= n,
        "valid_rows {valid_rows} exceeds logits rows {n}"
    );
    assert!(
        labels.len() >= valid_rows,
        "labels ({}) shorter than valid_rows ({valid_rows}) would undercount",
        labels.len()
    );
    let d = logits.data();
    let mut correct = 0usize;
    for i in 0..valid_rows {
        let row = &d[i * c..(i + 1) * c];
        let mut best = 0usize;
        let mut bv = f32::NEG_INFINITY;
        for (j, &v) in row.iter().enumerate() {
            if v > bv {
                bv = v;
                best = j;
            }
        }
        if best == labels[i] as usize {
            correct += 1;
        }
    }
    correct
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_basic() {
        let t = Tensor::new(vec![2, 3], vec![0.1, 0.9, 0.0, 3.0, -1.0, 2.0]).unwrap();
        assert_eq!(argmax_rows(&t), vec![1, 0]);
    }

    #[test]
    fn argmax_ties_take_first() {
        let t = Tensor::new(vec![1, 3], vec![1.0, 1.0, 1.0]).unwrap();
        assert_eq!(argmax_rows(&t), vec![0]);
    }

    #[test]
    fn correct_counts_with_padding() {
        let t = Tensor::new(vec![3, 2], vec![1.0, 0.0, 0.0, 1.0, 1.0, 0.0]).unwrap();
        // only first 2 rows are valid
        assert_eq!(count_correct(&t, &[0, 1], 2), 2);
        assert_eq!(count_correct(&t, &[1, 1], 2), 1);
        // labels longer than valid rows: padding tail ignored
        assert_eq!(count_correct(&t, &[0, 1, 0, 1], 2), 2);
    }

    #[test]
    fn correct_matches_argmax_composition() {
        let t = Tensor::new(
            vec![4, 3],
            vec![0.1, 0.9, 0.0, 3.0, -1.0, 2.0, 0.0, 0.0, 1.0, 0.5, 0.2, 0.1],
        )
        .unwrap();
        let labels = [1, 0, 2, 1];
        for valid in 0..=4usize {
            let slow = argmax_rows(&t)
                .iter()
                .take(valid)
                .zip(labels.iter())
                .filter(|(p, &y)| **p == y as usize)
                .count();
            assert_eq!(count_correct(&t, &labels, valid), slow);
        }
    }

    #[test]
    #[should_panic(expected = "shorter than valid_rows")]
    fn correct_rejects_short_labels() {
        let t = Tensor::new(vec![3, 2], vec![1.0, 0.0, 0.0, 1.0, 1.0, 0.0]).unwrap();
        count_correct(&t, &[0, 1], 3);
    }
}
