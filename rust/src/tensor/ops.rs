//! Free-standing tensor reductions used on the coordinator hot path.

use super::Tensor;

/// Argmax of each row of a (N, C) tensor.
pub fn argmax_rows(logits: &Tensor) -> Vec<usize> {
    assert_eq!(logits.rank(), 2, "argmax_rows wants rank-2");
    let (n, c) = (logits.shape()[0], logits.shape()[1]);
    let d = logits.data();
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let row = &d[i * c..(i + 1) * c];
        let mut best = 0usize;
        let mut bv = f32::NEG_INFINITY;
        for (j, &v) in row.iter().enumerate() {
            if v > bv {
                bv = v;
                best = j;
            }
        }
        out.push(best);
    }
    out
}

/// Count of rows whose argmax equals the label. `labels` may be longer than
/// the logits row count (padding tail ignored).
pub fn count_correct(logits: &Tensor, labels: &[i32], valid_rows: usize) -> usize {
    let preds = argmax_rows(logits);
    preds
        .iter()
        .take(valid_rows)
        .zip(labels.iter())
        .filter(|(p, &y)| **p == y as usize)
        .count()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_basic() {
        let t = Tensor::new(vec![2, 3], vec![0.1, 0.9, 0.0, 3.0, -1.0, 2.0]).unwrap();
        assert_eq!(argmax_rows(&t), vec![1, 0]);
    }

    #[test]
    fn argmax_ties_take_first() {
        let t = Tensor::new(vec![1, 3], vec![1.0, 1.0, 1.0]).unwrap();
        assert_eq!(argmax_rows(&t), vec![0]);
    }

    #[test]
    fn correct_counts_with_padding() {
        let t = Tensor::new(vec![3, 2], vec![1.0, 0.0, 0.0, 1.0, 1.0, 0.0]).unwrap();
        // only first 2 rows are valid
        assert_eq!(count_correct(&t, &[0, 1], 2), 2);
        assert_eq!(count_correct(&t, &[1, 1], 2), 1);
    }
}
