//! SplitMix64 PRNG — deterministic, seedable, dependency-free.
//!
//! Used by the property-test harness, the random-ranking baseline and the
//! benchmark workload generators. SplitMix64 passes BigCrush and is the
//! canonical seeding PRNG (Steele et al., OOPSLA'14).

/// SplitMix64 generator.
#[derive(Clone, Debug)]
pub struct Prng {
    state: u64,
}

impl Prng {
    pub fn new(seed: u64) -> Prng {
        Prng { state: seed.wrapping_add(0x9E3779B97F4A7C15) }
    }

    /// Next u64.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform f32 in [0, 1).
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Uniform f64 in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform usize in [0, n) (n > 0).
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        lo + (self.next_u64() % ((hi - lo + 1) as u64)) as i64
    }

    /// Standard normal (Box–Muller).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-300);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below(i + 1);
            v.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Prng::new(42);
        let mut b = Prng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Prng::new(43);
        assert_ne!(Prng::new(42).next_u64(), c.next_u64());
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Prng::new(7);
        for _ in 0..10_000 {
            let v = r.next_f32();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn mean_roughly_half() {
        let mut r = Prng::new(1);
        let n = 50_000;
        let sum: f64 = (0..n).map(|_| r.next_f64()).sum();
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Prng::new(3);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Prng::new(9);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn below_and_range_bounds() {
        let mut r = Prng::new(11);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
            let x = r.range(-3, 3);
            assert!((-3..=3).contains(&x));
        }
    }
}
