//! Minimal property-testing harness (proptest substitute).
//!
//! `forall(cases, gen, prop)` runs `prop` on `cases` generated inputs; on
//! failure it greedily shrinks via the generator's `shrink` and panics with
//! the minimal counterexample. Generators are plain structs over the
//! [`Prng`]; compose them with closures.

use super::prng::Prng;

/// A generator of values + an optional shrinker.
pub trait Gen {
    type Value: Clone + std::fmt::Debug;

    fn generate(&self, rng: &mut Prng) -> Self::Value;

    /// Candidate smaller values (default: none).
    fn shrink(&self, _v: &Self::Value) -> Vec<Self::Value> {
        Vec::new()
    }
}

/// Run a property over `cases` random inputs (seeded deterministically so
/// CI failures reproduce); panics with the (shrunk) counterexample.
pub fn forall<G: Gen>(cases: usize, gen: &G, prop: impl Fn(&G::Value) -> bool) {
    let mut rng = Prng::new(P_SEED ^ cases as u64);
    for case in 0..cases {
        let v = gen.generate(&mut rng);
        if !prop(&v) {
            // shrink loop
            let mut cur = v.clone();
            'outer: loop {
                for cand in gen.shrink(&cur) {
                    if !prop(&cand) {
                        cur = cand;
                        continue 'outer;
                    }
                }
                break;
            }
            panic!("property failed on case {case}: {cur:?} (shrunk from {v:?})");
        }
    }
}

const P_SEED: u64 = 0x1CEB00DA;

/// Usize generator in [lo, hi].
pub struct UsizeGen {
    pub lo: usize,
    pub hi: usize,
}

impl Gen for UsizeGen {
    type Value = usize;

    fn generate(&self, rng: &mut Prng) -> usize {
        rng.range(self.lo as i64, self.hi as i64) as usize
    }

    fn shrink(&self, v: &usize) -> Vec<usize> {
        let mut out = Vec::new();
        if *v > self.lo {
            out.push(self.lo);
            out.push(self.lo + (*v - self.lo) / 2);
            out.push(*v - 1);
        }
        out.dedup();
        out
    }
}

/// f32 vector generator with bounded length and magnitude.
pub struct VecF32Gen {
    pub min_len: usize,
    pub max_len: usize,
    pub max_abs: f32,
}

impl Gen for VecF32Gen {
    type Value = Vec<f32>;

    fn generate(&self, rng: &mut Prng) -> Vec<f32> {
        let n = rng.range(self.min_len as i64, self.max_len as i64) as usize;
        (0..n)
            .map(|_| (rng.next_f32() * 2.0 - 1.0) * self.max_abs)
            .collect()
    }

    fn shrink(&self, v: &Vec<f32>) -> Vec<Vec<f32>> {
        let mut out = Vec::new();
        if v.len() > self.min_len {
            out.push(v[..v.len() / 2].to_vec());
            out.push(v[..v.len() - 1].to_vec());
        }
        // zero out elements
        if v.iter().any(|&x| x != 0.0) {
            out.push(v.iter().map(|_| 0.0).collect());
        }
        out.retain(|c| c.len() >= self.min_len);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall(200, &UsizeGen { lo: 0, hi: 100 }, |&v| v <= 100);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_shrunk_case() {
        forall(200, &UsizeGen { lo: 0, hi: 100 }, |&v| v < 50);
    }

    #[test]
    fn vec_gen_respects_bounds() {
        let g = VecF32Gen { min_len: 1, max_len: 16, max_abs: 2.0 };
        forall(100, &g, |v| {
            v.len() >= 1 && v.len() <= 16 && v.iter().all(|x| x.abs() <= 2.0)
        });
    }
}
