//! Test substrates: deterministic PRNG + a small property-testing harness
//! (proptest is unavailable offline — see Cargo.toml note).

pub mod prng;
pub mod prop;

pub use prng::Prng;
pub use prop::{forall, Gen};
