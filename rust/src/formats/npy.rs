//! NumPy `.npy` reader/writer (format spec v1.0/v2.0, C-order only).
//!
//! The L2 build step saves model weights and datasets with `np.save`; this
//! module is the Rust side of that contract. Supports `<f4`, `<f8`, `<i4`,
//! `<i8`, `|i1`, `|u1` payloads (f8/i8 down-converted on read — the
//! artifacts are all f4/i4, wider types appear only in hand-written tests).

use std::fs;
use std::io::{Read, Write};
use std::path::Path;

use crate::error::{Error, Result};
use crate::tensor::{Tensor, TensorI32};

const MAGIC: &[u8; 6] = b"\x93NUMPY";

struct Header {
    descr: String,
    fortran: bool,
    shape: Vec<usize>,
}

fn parse_header(text: &str) -> Result<Header> {
    // Header is a python dict literal, e.g.
    // {'descr': '<f4', 'fortran_order': False, 'shape': (2, 3), }
    let get = |key: &str| -> Result<&str> {
        let pat = format!("'{key}':");
        let at = text
            .find(&pat)
            .ok_or_else(|| Error::Npy(format!("missing key {key}")))?;
        Ok(text[at + pat.len()..].trim_start())
    };

    let descr_rest = get("descr")?;
    let descr = descr_rest
        .strip_prefix('\'')
        .and_then(|r| r.split('\'').next())
        .ok_or_else(|| Error::Npy("bad descr".into()))?
        .to_string();

    let fortran = get("fortran_order")?.starts_with("True");

    let shape_rest = get("shape")?;
    let open = shape_rest
        .strip_prefix('(')
        .ok_or_else(|| Error::Npy("bad shape".into()))?;
    let close = open
        .find(')')
        .ok_or_else(|| Error::Npy("unterminated shape".into()))?;
    let mut shape = Vec::new();
    for part in open[..close].split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        shape.push(
            part.parse::<usize>()
                .map_err(|e| Error::Npy(format!("bad dim {part}: {e}")))?,
        );
    }
    Ok(Header { descr, fortran, shape })
}

fn read_raw(path: &Path) -> Result<(Header, Vec<u8>)> {
    let mut f = fs::File::open(path)?;
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    if &magic[..6] != MAGIC {
        return Err(Error::Npy(format!("{}: bad magic", path.display())));
    }
    let major = magic[6];
    let header_len = match major {
        1 => {
            let mut b = [0u8; 2];
            f.read_exact(&mut b)?;
            u16::from_le_bytes(b) as usize
        }
        2 => {
            let mut b = [0u8; 4];
            f.read_exact(&mut b)?;
            u32::from_le_bytes(b) as usize
        }
        v => return Err(Error::Npy(format!("unsupported npy version {v}"))),
    };
    let mut htext = vec![0u8; header_len];
    f.read_exact(&mut htext)?;
    let header = parse_header(
        std::str::from_utf8(&htext).map_err(|e| Error::Npy(format!("header utf8: {e}")))?,
    )?;
    if header.fortran {
        return Err(Error::Npy("fortran_order not supported".into()));
    }
    let mut payload = Vec::new();
    f.read_to_end(&mut payload)?;
    Ok((header, payload))
}

fn expect_len(header: &Header, payload: &[u8], itemsize: usize, path: &Path) -> Result<usize> {
    let n: usize = header.shape.iter().product();
    if payload.len() < n * itemsize {
        return Err(Error::Npy(format!(
            "{}: payload {} bytes < {} wanted",
            path.display(),
            payload.len(),
            n * itemsize
        )));
    }
    Ok(n)
}

/// Read an `.npy` file as an f32 [`Tensor`] (accepts `<f4` and `<f8`).
pub fn read_npy_f32(path: impl AsRef<Path>) -> Result<Tensor> {
    let path = path.as_ref();
    let (header, payload) = read_raw(path)?;
    let data: Vec<f32> = match header.descr.as_str() {
        "<f4" => {
            let n = expect_len(&header, &payload, 4, path)?;
            (0..n)
                .map(|i| f32::from_le_bytes(payload[i * 4..i * 4 + 4].try_into().unwrap()))
                .collect()
        }
        "<f8" => {
            let n = expect_len(&header, &payload, 8, path)?;
            (0..n)
                .map(|i| f64::from_le_bytes(payload[i * 8..i * 8 + 8].try_into().unwrap()) as f32)
                .collect()
        }
        d => return Err(Error::Npy(format!("{}: dtype {d} not f32-compatible", path.display()))),
    };
    Tensor::new(header.shape, data)
}

/// Read an `.npy` file as an i32 [`TensorI32`] (accepts `<i4`, `<i8`, `|i1`, `|u1`).
pub fn read_npy_i32(path: impl AsRef<Path>) -> Result<TensorI32> {
    let path = path.as_ref();
    let (header, payload) = read_raw(path)?;
    let data: Vec<i32> = match header.descr.as_str() {
        "<i4" => {
            let n = expect_len(&header, &payload, 4, path)?;
            (0..n)
                .map(|i| i32::from_le_bytes(payload[i * 4..i * 4 + 4].try_into().unwrap()))
                .collect()
        }
        "<i8" => {
            let n = expect_len(&header, &payload, 8, path)?;
            (0..n)
                .map(|i| i64::from_le_bytes(payload[i * 8..i * 8 + 8].try_into().unwrap()) as i32)
                .collect()
        }
        "|i1" => {
            let n = expect_len(&header, &payload, 1, path)?;
            payload[..n].iter().map(|&b| b as i8 as i32).collect()
        }
        "|u1" => {
            let n = expect_len(&header, &payload, 1, path)?;
            payload[..n].iter().map(|&b| b as i32).collect()
        }
        d => return Err(Error::Npy(format!("{}: dtype {d} not i32-compatible", path.display()))),
    };
    TensorI32::new(header.shape, data)
}

/// Write an f32 tensor as `.npy` v1.0 (`<f4`, C-order).
pub fn write_npy_f32(path: impl AsRef<Path>, t: &Tensor) -> Result<()> {
    let path = path.as_ref();
    if let Some(dir) = path.parent() {
        fs::create_dir_all(dir)?;
    }
    let shape_str = match t.shape().len() {
        0 => "()".to_string(),
        1 => format!("({},)", t.shape()[0]),
        _ => format!(
            "({})",
            t.shape().iter().map(|d| d.to_string()).collect::<Vec<_>>().join(", ")
        ),
    };
    let mut header = format!(
        "{{'descr': '<f4', 'fortran_order': False, 'shape': {shape_str}, }}"
    );
    // Pad so that magic(6)+ver(2)+len(2)+header is a multiple of 64, ending in \n.
    let base = 10 + header.len() + 1;
    let pad = (64 - base % 64) % 64;
    header.push_str(&" ".repeat(pad));
    header.push('\n');

    let mut f = fs::File::create(path)?;
    f.write_all(MAGIC)?;
    f.write_all(&[1u8, 0u8])?;
    f.write_all(&(header.len() as u16).to_le_bytes())?;
    f.write_all(header.as_bytes())?;
    let mut buf = Vec::with_capacity(t.len() * 4);
    for &v in t.data() {
        buf.extend_from_slice(&v.to_le_bytes());
    }
    f.write_all(&buf)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("hqp_npy_tests");
        fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn roundtrip_f32() {
        let t = Tensor::new(vec![2, 3], vec![1.0, -2.5, 3.0, 0.0, 5.5, -6.25]).unwrap();
        let p = tmp("rt.npy");
        write_npy_f32(&p, &t).unwrap();
        let back = read_npy_f32(&p).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn roundtrip_1d_and_scalar_shapes() {
        let t = Tensor::from_slice(&[9.0, 8.0, 7.0]);
        let p = tmp("rt1d.npy");
        write_npy_f32(&p, &t).unwrap();
        assert_eq!(read_npy_f32(&p).unwrap(), t);
    }

    #[test]
    fn bad_magic_rejected() {
        let p = tmp("bad.npy");
        fs::write(&p, b"NOTNUMPYDATA").unwrap();
        assert!(read_npy_f32(&p).is_err());
    }

    #[test]
    fn header_parser_variants() {
        let h = parse_header(
            "{'descr': '<f4', 'fortran_order': False, 'shape': (128, 3, 3, 16), }",
        )
        .unwrap();
        assert_eq!(h.descr, "<f4");
        assert!(!h.fortran);
        assert_eq!(h.shape, vec![128, 3, 3, 16]);

        let h1 = parse_header("{'descr': '<i4', 'fortran_order': False, 'shape': (7,), }").unwrap();
        assert_eq!(h1.shape, vec![7]);

        let h0 = parse_header("{'descr': '<f4', 'fortran_order': False, 'shape': (), }").unwrap();
        assert!(h0.shape.is_empty());
    }

    #[test]
    fn fortran_rejected() {
        let p = tmp("fortran.npy");
        let header = "{'descr': '<f4', 'fortran_order': True, 'shape': (1,), }          \n";
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&[1, 0]);
        bytes.extend_from_slice(&(header.len() as u16).to_le_bytes());
        bytes.extend_from_slice(header.as_bytes());
        bytes.extend_from_slice(&1.0f32.to_le_bytes());
        fs::write(&p, bytes).unwrap();
        assert!(read_npy_f32(&p).is_err());
    }
}
