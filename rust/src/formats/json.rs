//! JSON parser + serializer built from scratch (serde is unavailable in
//! this offline environment). Covers the full JSON grammar; used for
//! `artifacts/manifest.json`, experiment configs and result files.
//!
//! Objects preserve insertion order (`Vec<(String, Json)>`), which keeps
//! serialized reports diff-friendly.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::error::{Error, Result};

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    // ----- constructors -----------------------------------------------------

    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    /// Builder-style insert for objects.
    pub fn set(mut self, key: impl Into<String>, v: impl Into<Json>) -> Json {
        if let Json::Obj(entries) = &mut self {
            entries.push((key.into(), v.into()));
        }
        self
    }

    // ----- accessors --------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(entries) => entries.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// `get` that errors with the key name — manifest parsing helper.
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key)
            .ok_or_else(|| Error::Json(format!("missing key '{key}'")))
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => Err(Error::Json(format!("not a number: {self:?}"))),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let f = self.as_f64()?;
        if f < 0.0 || f.fract() != 0.0 {
            return Err(Error::Json(format!("not a usize: {f}")));
        }
        Ok(f as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => Err(Error::Json(format!("not a string: {self:?}"))),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => Err(Error::Json(format!("not a bool: {self:?}"))),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => Err(Error::Json(format!("not an array: {self:?}"))),
        }
    }

    pub fn as_obj(&self) -> Result<&[(String, Json)]> {
        match self {
            Json::Obj(v) => Ok(v),
            _ => Err(Error::Json(format!("not an object: {self:?}"))),
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    /// Array of usizes (shape vectors etc.).
    pub fn as_usize_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    /// Object as a string-keyed map (tensor_shapes etc.).
    pub fn as_map(&self) -> Result<BTreeMap<String, &Json>> {
        Ok(self
            .as_obj()?
            .iter()
            .map(|(k, v)| (k.clone(), v))
            .collect())
    }

    // ----- parsing ----------------------------------------------------------

    /// Parse a JSON document (must consume all non-whitespace input).
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(Error::Json(format!("trailing garbage at byte {}", p.i)));
        }
        Ok(v)
    }

    // ----- serialization ----------------------------------------------------

    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty serialization with 2-space indent.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(w) = indent {
                        out.push('\n');
                        out.push_str(&" ".repeat(w * (depth + 1)));
                    }
                    v.write(out, indent, depth + 1);
                }
                if indent.is_some() && !items.is_empty() {
                    out.push('\n');
                    out.push_str(&" ".repeat(indent.unwrap() * depth));
                }
                out.push(']');
            }
            Json::Obj(entries) => {
                out.push('{');
                for (i, (k, v)) in entries.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(w) = indent {
                        out.push('\n');
                        out.push_str(&" ".repeat(w * (depth + 1)));
                    }
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if indent.is_some() && !entries.is_empty() {
                    out.push('\n');
                    out.push_str(&" ".repeat(indent.unwrap() * depth));
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<f64> for Json {
    fn from(v: f64) -> Json {
        Json::Num(v)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Json {
        Json::Num(v as f64)
    }
}
impl From<i64> for Json {
    fn from(v: i64) -> Json {
        Json::Num(v as f64)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Json {
        Json::Bool(v)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Json {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Json {
        Json::Str(v)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(Error::Json(format!(
                "expected '{}' at byte {} (found {:?})",
                c as char,
                self.i,
                self.peek().map(|b| b as char)
            )))
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(Error::Json(format!(
                "unexpected {:?} at byte {}",
                other.map(|b| b as char),
                self.i
            ))),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(Error::Json(format!("bad literal at byte {}", self.i)))
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut entries = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(entries));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            entries.push((key, v));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(entries));
                }
                other => {
                    return Err(Error::Json(format!(
                        "expected ',' or '}}' at byte {} (found {:?})",
                        self.i,
                        other.map(|b| b as char)
                    )))
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                other => {
                    return Err(Error::Json(format!(
                        "expected ',' or ']' at byte {} (found {:?})",
                        self.i,
                        other.map(|b| b as char)
                    )))
                }
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(Error::Json("unterminated string".into())),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(Error::Json("truncated \\u escape".into()));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| Error::Json("bad \\u escape".into()))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| Error::Json("bad \\u escape".into()))?;
                            // Surrogate pairs: only BMP escapes appear in our
                            // files; reject surrogates rather than mis-decode.
                            let c = char::from_u32(cp)
                                .ok_or_else(|| Error::Json("surrogate \\u escape".into()))?;
                            s.push(c);
                            self.i += 4;
                        }
                        other => {
                            return Err(Error::Json(format!("bad escape {other:?}")));
                        }
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Decode one UTF-8 char.
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|e| Error::Json(format!("utf8: {e}")))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| Error::Json(format!("bad number '{text}': {e}")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-12.5e2").unwrap(), Json::Num(-1250.0));
        assert_eq!(Json::parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": false}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x");
        assert!(!v.get("a").unwrap().as_arr().unwrap()[2]
            .get("b")
            .unwrap()
            .as_bool()
            .unwrap());
    }

    #[test]
    fn roundtrip_pretty_and_compact() {
        let v = Json::obj()
            .set("name", "hqp")
            .set("n", 3usize)
            .set("xs", vec![1.5f64, 2.0, -0.25])
            .set("flag", true)
            .set("nul", Json::Null);
        for text in [v.to_string(), v.to_string_pretty()] {
            assert_eq!(Json::parse(&text).unwrap(), v);
        }
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("'single'").is_err());
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(
            Json::parse("\"\\u0041\\u00e9\"").unwrap(),
            Json::Str("Aé".into())
        );
    }

    #[test]
    fn int_formatting_is_integral() {
        assert_eq!(Json::Num(5.0).to_string(), "5");
        assert_eq!(Json::Num(5.25).to_string(), "5.25");
    }

    #[test]
    fn helpers() {
        let v = Json::parse(r#"{"shape": [2, 3, 4]}"#).unwrap();
        assert_eq!(v.req("shape").unwrap().as_usize_vec().unwrap(), vec![2, 3, 4]);
        assert!(v.req("missing").is_err());
    }
}
