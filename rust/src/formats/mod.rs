//! Serialization substrates built from scratch (no serde available in this
//! offline environment — see Cargo.toml note): NumPy `.npy` and JSON.

pub mod json;
pub mod npy;

pub use json::Json;
pub use npy::{read_npy_f32, read_npy_i32, write_npy_f32};
