//! Fixed-width text tables.

/// A simple auto-sizing text table.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: Vec<&str>) -> Table {
        Table { headers: headers.into_iter().map(String::from).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    pub fn render(&self) -> String {
        let cols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.chars().count()).collect();
        for r in &self.rows {
            for c in 0..cols {
                widths[c] = widths[c].max(r[c].chars().count());
            }
        }
        let sep: String = widths
            .iter()
            .map(|w| "-".repeat(w + 2))
            .collect::<Vec<_>>()
            .join("+");
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!(" {:<width$} ", c, width = widths[i]))
                .collect::<Vec<_>>()
                .join("|")
        };
        let mut out = String::new();
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aligns_columns() {
        let mut t = Table::new(vec!["a", "long-header"]);
        t.row(vec!["xxxxxx".into(), "1".into()]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 3);
        assert_eq!(lines[0].len(), lines[2].len());
        assert!(lines[1].contains('+'));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        let mut t = Table::new(vec!["a", "b"]);
        t.row(vec!["only-one".into()]);
    }
}
