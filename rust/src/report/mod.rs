//! Report rendering: fixed-width tables (the paper's Tables I/II), ASCII
//! bar charts (Figures 2/3 and the layer-wise profile), and markdown
//! export for EXPERIMENTS.md.

mod chart;
mod table;

pub use chart::{bar_chart, scatter, BarRow};
pub use table::Table;

use crate::hqp::MethodReport;

/// Render a list of method reports as the paper's table layout.
pub fn method_table(title: &str, rows: &[MethodReport]) -> String {
    let mut t = Table::new(vec![
        "Method",
        "Latency (ms)",
        "Speedup (x)",
        "Size Red.",
        "Acc Drop",
        "Sparsity θ",
        "Δ≤1.5%",
    ]);
    for r in rows {
        t.row(vec![
            r.method.clone(),
            format!("{:.3}", r.latency_ms),
            format!("{:.2}", r.speedup),
            format!("{:.1}%", r.size_reduction * 100.0),
            format!("{:.2}%", r.acc_drop * 100.0),
            format!("{:.0}%", r.sparsity * 100.0),
            if r.compliant { "yes".into() } else { "VIOLATED".into() },
        ]);
    }
    format!("{title}\n{}", t.render())
}

/// Markdown variant of [`method_table`] (EXPERIMENTS.md).
pub fn method_table_md(rows: &[MethodReport]) -> String {
    let mut s = String::from(
        "| Method | Latency (ms) | Speedup (×) | Size reduction | Acc drop | θ | compliant |\n|---|---|---|---|---|---|---|\n",
    );
    for r in rows {
        s.push_str(&format!(
            "| {} | {:.3} | {:.2} | {:.1}% | {:.2}% | {:.0}% | {} |\n",
            r.method,
            r.latency_ms,
            r.speedup,
            r.size_reduction * 100.0,
            r.acc_drop * 100.0,
            r.sparsity * 100.0,
            if r.compliant { "yes" } else { "**no**" },
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rep(method: &str, lat: f64) -> MethodReport {
        MethodReport {
            method: method.into(),
            model: "m".into(),
            device: "nx".into(),
            latency_ms: lat,
            speedup: 1.0,
            size_reduction: 0.5,
            acc_drop: 0.012,
            sparsity: 0.4,
            compliant: true,
            energy_mj: 1.0,
            energy_ratio: 1.0,
            flops: 100,
        }
    }

    #[test]
    fn table_contains_rows() {
        let s = method_table("T1", &[rep("baseline", 1.0), rep("hqp", 0.4)]);
        assert!(s.contains("baseline"));
        assert!(s.contains("hqp"));
        assert!(s.contains("T1"));
    }

    #[test]
    fn markdown_shape() {
        let s = method_table_md(&[rep("hqp", 0.4)]);
        assert!(s.starts_with("| Method"));
        assert_eq!(s.lines().count(), 3);
    }
}
