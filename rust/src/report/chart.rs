//! ASCII charts: horizontal bars (Fig. 2, layer-wise sparsity) and a
//! labeled scatter (Fig. 3: size reduction vs accuracy drop).

/// One bar: label + value (+ annotation).
#[derive(Clone, Debug)]
pub struct BarRow {
    pub label: String,
    pub value: f64,
    pub annot: String,
}

impl BarRow {
    pub fn new(label: impl Into<String>, value: f64, annot: impl Into<String>) -> BarRow {
        BarRow { label: label.into(), value, annot: annot.into() }
    }
}

/// Horizontal bar chart scaled to `width` characters.
pub fn bar_chart(title: &str, rows: &[BarRow], width: usize) -> String {
    let max = rows.iter().map(|r| r.value).fold(f64::MIN, f64::max).max(1e-12);
    let lw = rows.iter().map(|r| r.label.chars().count()).max().unwrap_or(0);
    let mut out = format!("{title}\n");
    for r in rows {
        let n = ((r.value / max) * width as f64).round().max(0.0) as usize;
        out.push_str(&format!(
            "  {:<lw$} |{:<width$}| {}\n",
            r.label,
            "█".repeat(n.min(width)),
            r.annot,
            lw = lw,
            width = width
        ));
    }
    out
}

/// Labeled scatter on an x/y grid (rows = points).
pub fn scatter(
    title: &str,
    points: &[(f64, f64, String)],
    xlabel: &str,
    ylabel: &str,
    w: usize,
    h: usize,
) -> String {
    let (mut xmin, mut xmax) = (f64::MAX, f64::MIN);
    let (mut ymin, mut ymax) = (f64::MAX, f64::MIN);
    for (x, y, _) in points {
        xmin = xmin.min(*x);
        xmax = xmax.max(*x);
        ymin = ymin.min(*y);
        ymax = ymax.max(*y);
    }
    if !xmin.is_finite() || points.is_empty() {
        return format!("{title}\n  (no points)\n");
    }
    let xspan = (xmax - xmin).max(1e-12);
    let yspan = (ymax - ymin).max(1e-12);
    let mut grid = vec![vec![' '; w]; h];
    let mut labels = Vec::new();
    for (i, (x, y, name)) in points.iter().enumerate() {
        let cx = (((x - xmin) / xspan) * (w - 1) as f64).round() as usize;
        let cy = (h - 1) - (((y - ymin) / yspan) * (h - 1) as f64).round() as usize;
        let marker = char::from_digit((i + 1) as u32 % 36, 36).unwrap_or('*');
        grid[cy][cx] = marker;
        labels.push(format!("  [{marker}] {name} ({x:.2}, {y:.3})"));
    }
    let mut out = format!("{title}   (y: {ylabel}, x: {xlabel})\n");
    for row in grid {
        out.push_str("  |");
        out.extend(row);
        out.push('\n');
    }
    out.push_str(&format!("  +{}\n", "-".repeat(w)));
    for l in labels {
        out.push_str(&l);
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bars_scale_to_max() {
        let s = bar_chart(
            "t",
            &[BarRow::new("a", 10.0, "10"), BarRow::new("b", 5.0, "5")],
            20,
        );
        let lines: Vec<&str> = s.lines().collect();
        let count = |l: &str| l.matches('█').count();
        assert_eq!(count(lines[1]), 20);
        assert_eq!(count(lines[2]), 10);
    }

    #[test]
    fn scatter_places_all_points() {
        let s = scatter(
            "fig",
            &[(0.0, 0.0, "p0".into()), (1.0, 1.0, "p1".into())],
            "x",
            "y",
            10,
            5,
        );
        assert!(s.contains("[1] p0"));
        assert!(s.contains("[2] p1"));
    }

    #[test]
    fn empty_scatter_is_safe() {
        assert!(scatter("t", &[], "x", "y", 10, 5).contains("no points"));
    }
}
