//! Channel-liveness analysis: which channels of each tensor can carry a
//! nonzero value, given the per-group filter masks.
//!
//! This is the analysis behind "dead layer elimination" in the paper's
//! TensorRT deployment story: a masked (zeroed) filter is only physically
//! removable from the deployed engine if *every* producer of the tensor
//! agrees the channel is dead. Residual adds are the interesting case —
//! ResNet trunk channels stay live unless both the block path and the skip
//! path killed them, which is precisely why HQP reaches lower structural
//! sparsity on ResNet-18 than on MobileNetV3 (paper §V-D).

use std::collections::BTreeMap;

use super::{Graph, OpKind};
use crate::error::{Error, Result};

/// Per-tensor channel liveness bitmaps.
#[derive(Clone, Debug)]
pub struct Liveness {
    /// tensor id -> alive flags (len = channel count).
    pub alive: BTreeMap<usize, Vec<bool>>,
}

impl Liveness {
    /// Propagate group masks through the graph.
    ///
    /// `masks[g][j] == true` means filter `j` of group `g` is KEPT.
    pub fn analyze(graph: &Graph, masks: &[Vec<bool>]) -> Result<Liveness> {
        if masks.len() != graph.groups.len() {
            return Err(Error::graph(format!(
                "masks {} != groups {}",
                masks.len(),
                graph.groups.len()
            )));
        }
        for (g, m) in graph.groups.iter().zip(masks) {
            if m.len() != g.size {
                return Err(Error::graph(format!(
                    "group {}: mask len {} != size {}",
                    g.name,
                    m.len(),
                    g.size
                )));
            }
        }

        let mut alive: BTreeMap<usize, Vec<bool>> = BTreeMap::new();
        // Graph inputs: fully live.
        for (&tid, &c) in &graph.tensor_channels {
            if !graph.nodes.iter().any(|n| n.output == tid) {
                alive.insert(tid, vec![true; c]);
            }
        }

        for n in &graph.nodes {
            let get = |tid: usize| -> Result<&Vec<bool>> {
                alive
                    .get(&tid)
                    .ok_or_else(|| Error::graph(format!("op {}: liveness of {tid} unknown", n.name)))
            };
            let out = match n.kind {
                OpKind::Conv | OpKind::Fc => {
                    // Fresh channel set: the group mask decides (a conv with
                    // no group — e.g. SE expand or the classifier — is fully
                    // live).
                    match n.group {
                        Some(g) => masks[g].clone(),
                        None => vec![true; graph.channels(n.output)],
                    }
                }
                OpKind::DwConv | OpKind::Bn | OpKind::Act | OpKind::Gap => {
                    // Per-channel ops preserve liveness; when the op belongs
                    // to a group (dwconv/bn inside a masked group) intersect
                    // with the mask — a masked BN can no longer re-introduce
                    // a nonzero via beta.
                    let mut v = get(n.inputs[0])?.clone();
                    if let Some(g) = n.group {
                        if masks[g].len() == v.len() {
                            for (a, m) in v.iter_mut().zip(&masks[g]) {
                                *a = *a && *m;
                            }
                        }
                    }
                    v
                }
                OpKind::Add => {
                    // Union: alive if either side can be nonzero.
                    let a = get(n.inputs[0])?.clone();
                    let b = get(n.inputs[1])?;
                    if a.len() != b.len() {
                        return Err(Error::graph(format!(
                            "op {}: add channel mismatch {} vs {}",
                            n.name,
                            a.len(),
                            b.len()
                        )));
                    }
                    a.iter().zip(b).map(|(x, y)| *x || *y).collect()
                }
                OpKind::SeMul => {
                    // Gated scaling: zero channels stay zero.
                    get(n.inputs[0])?.clone()
                }
            };
            alive.insert(n.output, out);
        }
        Ok(Liveness { alive })
    }

    /// Alive channel count of a tensor.
    pub fn count(&self, tid: usize) -> usize {
        self.alive.get(&tid).map(|v| v.iter().filter(|b| **b).count()).unwrap_or(0)
    }

    /// Alive flags of a tensor.
    pub fn of(&self, tid: usize) -> Option<&[bool]> {
        self.alive.get(&tid).map(|v| v.as_slice())
    }
}

/// Full (no pruning) masks for a graph.
pub fn full_masks(graph: &Graph) -> Vec<Vec<bool>> {
    graph.groups.iter().map(|g| vec![true; g.size]).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::Manifest;

    /// conv(4ch, group0) -> bn -> act -+-> add -> out
    ///            input ----conv(4ch, group1)----^   (residual-style union)
    fn resid_graph() -> Graph {
        let text = r#"{
          "version": 1, "hist_bins": 16,
          "models": {"m": {
            "input_hw": 4, "num_classes": 2, "baseline_val_acc": 1.0,
            "eval_batch": 1, "fisher_batch": 1, "hist_batch": 1,
            "weights_dir": "w",
            "param_order": [],
            "groups": [
              {"id": 0, "name": "c1", "size": 4, "offset": 0, "members": [["c1.w", 3]],
               "producer": "c1.w", "producer_axis": 3},
              {"id": 1, "name": "c2", "size": 4, "offset": 4, "members": [["c2.w", 3]],
               "producer": "c2.w", "producer_axis": 3}
            ],
            "taps": [],
            "ops": [
              {"id": 0, "kind": "conv", "name": "c1", "inputs": [0], "output": 1,
               "attrs": {"cin": 3, "cout": 4, "k": 3, "stride": 1, "groups": 1, "h": 4, "w": 4},
               "params": [], "group": 0, "tap": null},
              {"id": 1, "kind": "bn", "name": "b1", "inputs": [1], "output": 2,
               "attrs": {"c": 4}, "params": [], "group": 0, "tap": null},
              {"id": 2, "kind": "conv", "name": "c2", "inputs": [0], "output": 3,
               "attrs": {"cin": 3, "cout": 4, "k": 1, "stride": 1, "groups": 1, "h": 4, "w": 4},
               "params": [], "group": 1, "tap": null},
              {"id": 3, "kind": "add", "name": "add", "inputs": [2, 3], "output": 4,
               "attrs": {}, "params": [], "group": null, "tap": null}
            ],
            "tensor_shapes": {"0": [1, 4, 4, 3], "1": [1, 4, 4, 4], "2": [1, 4, 4, 4],
                              "3": [1, 4, 4, 4], "4": [1, 4, 4, 4]},
            "artifacts": {}
          }},
          "data": {}
        }"#;
        let m = Manifest::parse(text).unwrap();
        Graph::from_manifest(m.model("m").unwrap()).unwrap()
    }

    #[test]
    fn full_masks_all_alive() {
        let g = resid_graph();
        let l = Liveness::analyze(&g, &full_masks(&g)).unwrap();
        assert_eq!(l.count(4), 4);
    }

    #[test]
    fn add_keeps_channel_alive_unless_both_sides_dead() {
        let g = resid_graph();
        // Kill channel 1 on the block path only.
        let mut masks = full_masks(&g);
        masks[0][1] = false;
        let l = Liveness::analyze(&g, &masks).unwrap();
        assert_eq!(l.count(2), 3); // post-bn: dead
        assert_eq!(l.count(4), 4); // post-add: resurrected by skip conv

        // Kill channel 1 on both paths -> structurally removable.
        masks[1][1] = false;
        let l = Liveness::analyze(&g, &masks).unwrap();
        assert_eq!(l.count(4), 3);
        assert_eq!(l.of(4).unwrap(), &[true, false, true, true]);
    }

    #[test]
    fn mask_shape_validated() {
        let g = resid_graph();
        let mut masks = full_masks(&g);
        masks[0].pop();
        assert!(Liveness::analyze(&g, &masks).is_err());
    }
}
