//! Inference-graph IR mirroring the L2 models.
//!
//! Built from the manifest's op list (recorded by the python tracer — the
//! same traversal that produced the HLO, so graph and artifact can't
//! diverge). This IR is what the TensorRT-substitute ([`crate::gopt`])
//! optimizes and what the Jetson hardware model ([`crate::hwsim`]) prices:
//! the *numerics* of a pruned/quantized model run through PJRT, while its
//! *deployed latency* is derived here, exactly as the paper derives device
//! latency from the TensorRT-compiled engine rather than from the python
//! process that produced the ONNX.

pub mod liveness;

pub use liveness::{full_masks, Liveness};

use std::collections::BTreeMap;

use crate::error::{Error, Result};
use crate::runtime::manifest::{GroupSpec, ModelManifest, OpSpec};

/// Node kind (subset of ops the tracer records).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OpKind {
    Conv,
    DwConv,
    Bn,
    Act,
    Add,
    Gap,
    Fc,
    SeMul,
}

impl OpKind {
    pub fn parse(s: &str) -> Result<OpKind> {
        Ok(match s {
            "conv" => OpKind::Conv,
            "dwconv" => OpKind::DwConv,
            "bn" => OpKind::Bn,
            "act" => OpKind::Act,
            "add" => OpKind::Add,
            "gap" => OpKind::Gap,
            "fc" => OpKind::Fc,
            "se_mul" => OpKind::SeMul,
            other => return Err(Error::graph(format!("unknown op kind {other}"))),
        })
    }
}

/// One node.
#[derive(Clone, Debug)]
pub struct Node {
    pub id: usize,
    pub kind: OpKind,
    pub name: String,
    pub inputs: Vec<usize>,
    pub output: usize,
    /// Conv/fc geometry (defaults 0/1 for non-conv ops).
    pub cin: usize,
    pub cout: usize,
    pub k: usize,
    pub stride: usize,
    pub groups: usize,
    /// Output spatial size (1×1 for vector tensors).
    pub h: usize,
    pub w: usize,
    /// Activation kind for Act nodes.
    pub act_kind: Option<String>,
    pub params: Vec<String>,
    pub group: Option<usize>,
    pub tap: Option<usize>,
}

/// The model graph: topologically ordered nodes + tensor channel counts.
#[derive(Clone, Debug)]
pub struct Graph {
    pub model: String,
    pub nodes: Vec<Node>,
    /// tensor id -> channel count (last dim of the traced shape).
    pub tensor_channels: BTreeMap<usize, usize>,
    /// tensor id -> spatial element count (H*W, 1 for vectors).
    pub tensor_spatial: BTreeMap<usize, usize>,
    pub groups: Vec<GroupSpec>,
    /// Number of graph input tensors (tensor ids below this are inputs).
    pub num_inputs: usize,
}

fn node_from_spec(op: &OpSpec, shapes: &BTreeMap<usize, Vec<usize>>) -> Result<Node> {
    let kind = OpKind::parse(&op.kind)?;
    let out_shape = shapes
        .get(&op.output)
        .ok_or_else(|| Error::graph(format!("op {}: no shape for tensor {}", op.name, op.output)))?;
    let (h, w, cout_from_shape) = match out_shape.len() {
        4 => (out_shape[1], out_shape[2], out_shape[3]),
        2 => (1, 1, out_shape[1]),
        _ => (1, 1, *out_shape.last().unwrap_or(&1)),
    };
    let (cin, cout, k, stride, groups) = match kind {
        OpKind::Conv | OpKind::DwConv => (
            op.attr("cin")?,
            op.attr("cout")?,
            op.attr("k")?,
            op.attr("stride")?,
            op.attr("groups")?,
        ),
        OpKind::Fc => (op.attr("cin")?, op.attr("cout")?, 1, 1, 1),
        _ => (cout_from_shape, cout_from_shape, 1, 1, 1),
    };
    Ok(Node {
        id: op.id,
        kind,
        name: op.name.clone(),
        inputs: op.inputs.clone(),
        output: op.output,
        cin,
        cout,
        k,
        stride,
        groups,
        h,
        w,
        act_kind: if kind == OpKind::Act {
            Some(op.attr_str("kind")?.to_string())
        } else {
            None
        },
        params: op.params.clone(),
        group: op.group,
        tap: op.tap,
    })
}

impl Graph {
    /// Build the IR from a model manifest.
    pub fn from_manifest(mm: &ModelManifest) -> Result<Graph> {
        let nodes = mm
            .ops
            .iter()
            .map(|op| node_from_spec(op, &mm.tensor_shapes))
            .collect::<Result<Vec<_>>>()?;

        let mut tensor_channels = BTreeMap::new();
        let mut tensor_spatial = BTreeMap::new();
        for (tid, shape) in &mm.tensor_shapes {
            let (c, sp) = match shape.len() {
                4 => (shape[3], shape[1] * shape[2]),
                2 => (shape[1], 1),
                _ => (*shape.last().unwrap_or(&1), 1),
            };
            tensor_channels.insert(*tid, c);
            tensor_spatial.insert(*tid, sp);
        }

        // Graph inputs = tensor ids that are no node's output.
        let produced: std::collections::BTreeSet<usize> =
            nodes.iter().map(|n| n.output).collect();
        let num_inputs = mm
            .tensor_shapes
            .keys()
            .filter(|t| !produced.contains(t))
            .count();

        let g = Graph {
            model: mm.name.clone(),
            nodes,
            tensor_channels,
            tensor_spatial,
            groups: mm.groups.clone(),
            num_inputs,
        };
        g.validate()?;
        Ok(g)
    }

    /// Structural sanity: inputs precede use, shapes known, groups in range.
    pub fn validate(&self) -> Result<()> {
        let mut seen: std::collections::BTreeSet<usize> = self
            .tensor_channels
            .keys()
            .copied()
            .filter(|t| !self.nodes.iter().any(|n| n.output == *t))
            .collect();
        for n in &self.nodes {
            for i in &n.inputs {
                if !seen.contains(i) {
                    return Err(Error::graph(format!(
                        "op {}: input tensor {i} not yet produced",
                        n.name
                    )));
                }
            }
            if !self.tensor_channels.contains_key(&n.output) {
                return Err(Error::graph(format!("op {}: unknown output shape", n.name)));
            }
            if let Some(g) = n.group {
                if g >= self.groups.len() {
                    return Err(Error::graph(format!("op {}: group {g} out of range", n.name)));
                }
            }
            seen.insert(n.output);
        }
        Ok(())
    }

    /// Channel count of a tensor.
    pub fn channels(&self, tid: usize) -> usize {
        self.tensor_channels.get(&tid).copied().unwrap_or(0)
    }

    /// Dense (unpruned) parameter count of the compute ops.
    pub fn dense_params(&self) -> usize {
        self.nodes
            .iter()
            .map(|n| match n.kind {
                OpKind::Conv | OpKind::DwConv => n.k * n.k * (n.cin / n.groups) * n.cout,
                OpKind::Fc => n.cin * n.cout + n.cout,
                OpKind::Bn => 4 * n.cout,
                _ => 0,
            })
            .sum()
    }

    /// Dense FLOPs for one sample (multiply-accumulate = 2 FLOPs).
    pub fn dense_flops(&self) -> u64 {
        self.nodes
            .iter()
            .map(|n| match n.kind {
                // Pool reduces over its INPUT spatial extent.
                OpKind::Gap => {
                    let in_sp = *self.tensor_spatial.get(&n.inputs[0]).unwrap_or(&1) as u64;
                    n.cout as u64 * in_sp
                }
                _ => n.dense_flops(),
            })
            .sum()
    }
}

impl Node {
    /// FLOPs of this node at dense channel counts, one sample.
    pub fn dense_flops(&self) -> u64 {
        let hw = (self.h * self.w) as u64;
        match self.kind {
            OpKind::Conv | OpKind::DwConv => {
                2 * (self.k * self.k) as u64 * (self.cin / self.groups) as u64
                    * self.cout as u64
                    * hw
            }
            OpKind::Fc => 2 * self.cin as u64 * self.cout as u64,
            OpKind::Bn => 2 * self.cout as u64 * hw,
            OpKind::Act | OpKind::Add | OpKind::SeMul => self.cout as u64 * hw,
            // NOTE: Gap's own h/w are the OUTPUT (1x1); Graph::dense_flops
            // overrides with the input spatial extent.
            OpKind::Gap => self.cout as u64 * hw,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::Manifest;

    fn mini() -> Graph {
        let text = r#"{
          "version": 1, "hist_bins": 16,
          "models": {"m": {
            "input_hw": 8, "num_classes": 2, "baseline_val_acc": 1.0,
            "eval_batch": 4, "fisher_batch": 2, "hist_batch": 4,
            "weights_dir": "w",
            "param_order": [{"name": "c.w", "shape": [3, 3, 3, 4]}],
            "groups": [{"id": 0, "name": "c", "size": 4, "offset": 0,
                        "members": [["c.w", 3]], "producer": "c.w", "producer_axis": 3}],
            "taps": [],
            "ops": [
              {"id": 0, "kind": "conv", "name": "c", "inputs": [0], "output": 1,
               "attrs": {"cin": 3, "cout": 4, "k": 3, "stride": 1, "groups": 1, "h": 8, "w": 8},
               "params": ["c.w"], "group": 0, "tap": null},
              {"id": 1, "kind": "act", "name": "a", "inputs": [1], "output": 2,
               "attrs": {"kind": "relu"}, "params": [], "group": 0, "tap": null},
              {"id": 2, "kind": "gap", "name": "p", "inputs": [2], "output": 3,
               "attrs": {}, "params": [], "group": null, "tap": null},
              {"id": 3, "kind": "fc", "name": "f", "inputs": [3], "output": 4,
               "attrs": {"cin": 4, "cout": 2}, "params": ["f.w", "f.b"], "group": null, "tap": null}
            ],
            "tensor_shapes": {"0": [1, 8, 8, 3], "1": [1, 8, 8, 4], "2": [1, 8, 8, 4],
                              "3": [1, 4], "4": [1, 2]},
            "artifacts": {}
          }},
          "data": {}
        }"#;
        let m = Manifest::parse(text).unwrap();
        Graph::from_manifest(m.model("m").unwrap()).unwrap()
    }

    #[test]
    fn builds_and_validates() {
        let g = mini();
        assert_eq!(g.nodes.len(), 4);
        assert_eq!(g.num_inputs, 1);
        assert_eq!(g.channels(1), 4);
    }

    #[test]
    fn flops_accounting() {
        let g = mini();
        // conv: 2*9*3*4*64 = 13824; act: 4*64; gap: 4*64; fc: 2*4*2 = 16
        assert_eq!(g.nodes[0].dense_flops(), 13824);
        assert_eq!(g.dense_flops(), 13824 + 256 + 256 + 16);
    }

    #[test]
    fn dense_params() {
        let g = mini();
        // conv 3*3*3*4 = 108, fc 4*2+2 = 10
        assert_eq!(g.dense_params(), 118);
    }
}
