//! Trace-driven edge serving simulator with SLO-aware routing over HQP
//! variants — the deployment layer the paper's tables stop short of.
//!
//! The paper's single-inference roofline numbers (Tables I/II) say how
//! fast one request runs; this module says what that buys under *load*: a
//! fleet of [`crate::hwsim`] devices, each loaded with deployed HQP
//! variants ([`fleet::VariantProfile`] — the serving view of the
//! [`crate::hqp::deploy::MethodReport`] engines), replays a synthetic
//! request trace ([`trace`]) through an admission queue, a dynamic
//! batcher ([`batcher`]) and an SLO-aware router ([`router`]) that picks
//! device × variant per request subject to the paper's Δ_max accuracy
//! constraint.
//!
//! ## Design: a virtual-time event heap, not threads
//!
//! The simulator is deliberately single-threaded (the same documented
//! one-core constraint as [`crate::coordinator`]): a discrete-event loop
//! over a virtual-time min-heap. Service times come from the batched
//! roofline ([`crate::hwsim::simulate_batch`]), so no wall-clock time is
//! spent "serving" — a 10-minute trace simulates in milliseconds — and
//! every run is exactly reproducible: the same `(fleet, trace, config)`
//! triple produces a byte-identical [`Summary`]. That determinism is what
//! makes the event-loop conservation laws property-testable
//! (`tests/prop_serve.rs`).
//!
//! ## Request lifecycle
//!
//! Every generated request ends in exactly one of three states:
//!
//! * **rejected** — at admission: no Δ_max-compliant variant exists, the
//!   routed server's queue is at capacity, or (under capped memory) no
//!   compliant variant is resident on an available server;
//! * **expired** — its SLO deadline passed while it waited in a queue
//!   (dropped at batch-formation time or at a swap boundary, never
//!   served);
//! * **completed** — served in a batch; it *attains* the SLO iff it
//!   finishes by `arrival + slo_ms`.
//!
//! ## Stateful variant residency
//!
//! With per-server engine-memory capacities ([`Server::mem_capacity_bytes`],
//! CLI `--mem-mb`) a device holds only a *resident* subset of its
//! deployable variants. The router ([`router`]) then routes only over
//! resident variants, and a [`RoutePolicy`] may propose a hot-swap; the
//! event loop executes it as a `SwapStart`/`SwapDone` event pair: the
//! evicted variant's queue is drained and requeued ([`batcher`]'s
//! eviction semantics), the device serves nothing mid-swap (queued
//! requests wait or expire), and the swap is charged the hardware-aware
//! cost [`crate::hwsim::Device::swap_in_ms`] (weight streaming over DRAM
//! bandwidth + a fixed init overhead, [`ServeConfig::swap_init_ms`]).
//! With capacities unset, every variant is resident, no swap event is
//! ever scheduled, and the simulation is byte-identical to the
//! pre-residency simulator.
//!
//! See `rust/DESIGN.md` §Serving for the model's limits (open-loop
//! arrivals, serial devices, linear activation scaling; the optional
//! [`ServeConfig::link_mbps`] uplink model charges a per-request
//! transfer delay).

pub mod batcher;
pub mod fleet;
pub mod router;
pub mod trace;

pub use fleet::{fleet_for, reference_fleet, workspace_fleet, Fleet, Server, VariantProfile};
pub use router::{Candidate, FleetView, Policy, RouteCtx, RoutePolicy, Router, SwapPlan};
pub use trace::ArrivalProcess;

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::error::{Error, Result};
use crate::report::Table;

use batcher::{Batcher, EnqueueAction, QueuedReq};

/// Serving-simulation parameters.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Per-request latency SLO, ms (deadline = arrival + slo).
    pub slo_ms: f64,
    /// Δ_max: the accuracy-drop budget the router must respect.
    pub delta_max: f64,
    pub policy: Policy,
    /// Dynamic batcher: max batch size…
    pub max_batch: usize,
    /// …and how long an idle device waits for a batch to fill, ms.
    pub batch_timeout_ms: f64,
    /// Admission cap on queued requests per server.
    pub queue_cap: usize,
    /// Fixed engine-initialization overhead added to every hot-swap, ms
    /// (on top of streaming the engine weights over DRAM bandwidth).
    pub swap_init_ms: f64,
    /// Uplink bandwidth for request payloads, Mbit/s. Each request pays
    /// `input_bytes / link_mbps` of transfer delay before admission (the
    /// delay eats into its SLO budget). `f64::INFINITY` (the default)
    /// disables the network model and preserves byte-identical summaries.
    pub link_mbps: f64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            slo_ms: 50.0,
            delta_max: 0.015,
            policy: Policy::AccFastest,
            max_batch: 8,
            batch_timeout_ms: 2.0,
            queue_cap: 256,
            swap_init_ms: 5.0,
            link_mbps: f64::INFINITY,
        }
    }
}

/// Per-(server, variant) serving statistics.
#[derive(Clone, Debug, PartialEq)]
pub struct VariantUsage {
    pub server: usize,
    pub device: String,
    pub variant: String,
    pub acc_drop: f64,
    pub completed: u64,
    pub batches: u64,
    pub mean_batch: f64,
    pub busy_ms: f64,
    /// busy_ms / makespan.
    pub utilization: f64,
    pub energy_mj: f64,
}

/// One simulation's results.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub model: String,
    pub policy: &'static str,
    pub slo_ms: f64,
    pub delta_max: f64,
    pub generated: u64,
    pub completed: u64,
    pub rejected: u64,
    /// Of the rejections: requests with no Δ_max-compliant variant.
    pub rejected_noncompliant: u64,
    /// Of the rejections: compliant variants exist, but none was resident
    /// on an available (not mid-swap) server. Always 0 with unlimited
    /// memory.
    pub rejected_unavailable: u64,
    pub expired: u64,
    /// Of the expired: the deadline lapsed while the routed server was
    /// mid-swap (deadlines in `[swap start, swap done]`). Deadlines that
    /// had already passed before the swap began count only as `expired`.
    pub expired_during_swap: u64,
    /// Completed within their SLO deadline.
    pub slo_attained: u64,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    /// Virtual time of the last event.
    pub makespan_ms: f64,
    pub throughput_rps: f64,
    pub mean_batch: f64,
    /// Completion-weighted mean accuracy drop across served variants.
    pub acc_mix: f64,
    pub energy_mj: f64,
    /// Engine hot-swaps performed.
    pub swaps: u64,
    /// Total virtual time spent swapping (weight streaming + init), ms.
    pub swap_ms: f64,
    /// Whether any server ran with a finite engine-memory capacity (gates
    /// the swap line in [`Summary::render`], keeping unlimited-memory
    /// output byte-identical to the pre-residency simulator).
    pub residency_limited: bool,
    pub per_variant: Vec<VariantUsage>,
}

impl Summary {
    /// SLO attainment over *offered* load (rejected and expired requests
    /// count against it — dropping traffic is not meeting its SLO).
    pub fn slo_attainment(&self) -> f64 {
        if self.generated == 0 {
            0.0
        } else {
            self.slo_attained as f64 / self.generated as f64
        }
    }

    /// Render the summary (the `hqp serve` output). Deterministic: equal
    /// summaries render byte-identically.
    pub fn render(&self) -> String {
        let mut s = format!(
            "serve summary — {} (policy {}, slo {:.1} ms, Δmax {:.2}%)\n",
            self.model,
            self.policy,
            self.slo_ms,
            self.delta_max * 100.0
        );
        s.push_str(&format!(
            "  requests : {} generated = {} completed + {} rejected + {} expired\n",
            self.generated, self.completed, self.rejected, self.expired
        ));
        s.push_str(&format!(
            "  slo      : {:.2}% attainment   throughput {:.1} rps   mean batch {:.2}\n",
            self.slo_attainment() * 100.0,
            self.throughput_rps,
            self.mean_batch
        ));
        s.push_str(&format!(
            "  latency  : p50 {:.3} ms   p95 {:.3} ms   p99 {:.3} ms   mean {:.3} ms\n",
            self.p50_ms, self.p95_ms, self.p99_ms, self.mean_ms
        ));
        s.push_str(&format!(
            "  quality  : completion-weighted acc drop {:.3}%   energy {:.1} mJ\n",
            self.acc_mix * 100.0,
            self.energy_mj
        ));
        if self.residency_limited || self.policy == Policy::SwapAware.name() {
            s.push_str(&format!(
                "  swaps    : {} ({:.1} ms swapping)   {} expired mid-swap   \
                 {} rejected unavailable\n",
                self.swaps, self.swap_ms, self.expired_during_swap, self.rejected_unavailable
            ));
        }
        let mut t = Table::new(vec![
            "Device",
            "Variant",
            "Acc Drop",
            "Completed",
            "Batches",
            "Mean Batch",
            "Util",
            "Energy (mJ)",
        ]);
        for u in &self.per_variant {
            t.row(vec![
                u.device.clone(),
                u.variant.clone(),
                format!("{:.2}%", u.acc_drop * 100.0),
                format!("{}", u.completed),
                format!("{}", u.batches),
                format!("{:.2}", u.mean_batch),
                format!("{:.1}%", u.utilization * 100.0),
                format!("{:.1}", u.energy_mj),
            ]);
        }
        s.push_str(&t.render());
        s
    }
}

// ---------------------------------------------------------------------------
// Event machinery
// ---------------------------------------------------------------------------

#[derive(Clone, Debug)]
enum EventKind {
    Arrival { req: usize },
    Flush { server: usize, variant: usize, token: u64 },
    BatchDone { server: usize, variant: usize, reqs: Vec<QueuedReq> },
    /// Begin the server's pending hot-swap (re-arms itself while a batch
    /// is still running).
    SwapStart { server: usize },
    /// The swapped-in engine is ready: mark it resident and resume
    /// dispatch. `started_ms` is when the swap began, so expiry during
    /// the swap window can be attributed precisely.
    SwapDone { server: usize, load: usize, started_ms: f64 },
}

/// Heap key: virtual time, ties broken by insertion sequence — a total
/// order, so the pop order (and therefore the whole simulation) is
/// deterministic.
#[derive(Clone, Debug)]
struct Event {
    time_ms: f64,
    seq: u64,
    kind: EventKind,
}

impl PartialEq for Event {
    fn eq(&self, other: &Event) -> bool {
        self.seq == other.seq
    }
}
impl Eq for Event {}
impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Event) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Event {
    fn cmp(&self, other: &Event) -> std::cmp::Ordering {
        self.time_ms
            .total_cmp(&other.time_ms)
            .then(self.seq.cmp(&other.seq))
    }
}

struct ServerState {
    batcher: Batcher,
    busy: bool,
    busy_until: f64,
    /// A hot-swap is in flight: the device serves nothing until
    /// `swap_until`.
    swapping: bool,
    swap_until: f64,
    /// A policy-approved swap waiting for the running batch to finish.
    pending_swap: Option<SwapPlan>,
}

impl ServerState {
    /// Can this server start a batch right now?
    fn can_dispatch(&self) -> bool {
        !self.busy && !self.swapping && self.pending_swap.is_none()
    }
}

#[derive(Clone, Copy, Debug, Default)]
struct UsageAcc {
    completed: u64,
    batches: u64,
    occupancy: u64,
    busy_ms: f64,
    energy_mj: f64,
}

#[derive(Default)]
struct Acc {
    completed: u64,
    rejected_full: u64,
    rejected_noncompliant: u64,
    rejected_unavailable: u64,
    expired: u64,
    expired_during_swap: u64,
    swaps: u64,
    swap_ms: f64,
    slo_attained: u64,
    latencies: Vec<f64>,
    usage: Vec<Vec<UsageAcc>>,
}

/// Form and launch a batch on server `s` starting from variant `v`,
/// falling through to the resident variant whose head has waited longest
/// when `v` turns out empty (or fully expired, or non-resident). Leaves
/// the server idle when no servable request remains. Only resident
/// variants can form batches — the structural half of the "never serve a
/// non-resident engine" invariant (the router enforces the other half at
/// admission).
#[allow(clippy::too_many_arguments)]
fn try_dispatch(
    s: usize,
    mut v: usize,
    now: f64,
    st: &mut ServerState,
    server: &Server,
    resident: &[bool],
    heap: &mut BinaryHeap<Reverse<Event>>,
    seq: &mut u64,
    acc: &mut Acc,
) {
    loop {
        if !resident[v] {
            match st.batcher.oldest_allowed(resident) {
                Some(next) => {
                    v = next;
                    continue;
                }
                None => {
                    st.busy = false;
                    return;
                }
            }
        }
        let taken = st.batcher.take_batch(v, now);
        acc.expired += taken.expired.len() as u64;
        if taken.reqs.is_empty() {
            match st.batcher.oldest_allowed(resident) {
                Some(next) => {
                    v = next;
                    continue;
                }
                None => {
                    st.busy = false;
                    return;
                }
            }
        }
        let b = taken.reqs.len();
        let prof = &server.variants[v];
        let service_ms = prof.batch_ms[b - 1];
        st.busy = true;
        st.busy_until = now + service_ms;
        let u = &mut acc.usage[s][v];
        u.batches += 1;
        u.occupancy += b as u64;
        u.busy_ms += service_ms;
        u.energy_mj += prof.energy_mj[b - 1];
        *seq += 1;
        heap.push(Reverse(Event {
            time_ms: st.busy_until,
            seq: *seq,
            kind: EventKind::BatchDone { server: s, variant: v, reqs: taken.reqs },
        }));
        return;
    }
}

/// Replay `arrivals` (sorted ms timestamps from [`trace::generate`])
/// against `fleet` under `cfg`. Virtual-time monotonicity is checked on
/// every event, swap plans are validated against live residency and
/// capacity, and a stranded queue at the end of the trace is reported —
/// each is an internal invariant violation that errors out rather than
/// silently producing garbage (so an `Ok` return is itself the proof the
/// residency and conservation invariants held).
pub fn simulate_fleet(fleet: &Fleet, arrivals: &[f64], cfg: &ServeConfig) -> Result<Summary> {
    if fleet.servers.is_empty() {
        return Err(Error::hqp("serve: empty fleet"));
    }
    if cfg.max_batch == 0 {
        return Err(Error::hqp("serve: max_batch must be >= 1"));
    }
    if cfg.slo_ms <= 0.0 {
        return Err(Error::hqp("serve: slo_ms must be positive"));
    }
    if cfg.swap_init_ms < 0.0 || cfg.swap_init_ms.is_nan() {
        return Err(Error::hqp("serve: swap_init_ms must be >= 0"));
    }
    if cfg.link_mbps <= 0.0 || cfg.link_mbps.is_nan() {
        return Err(Error::hqp("serve: link_mbps must be positive (or infinite)"));
    }
    if fleet.max_batch() < cfg.max_batch {
        return Err(Error::hqp(format!(
            "serve: fleet profiles support batches up to {}, config wants {}",
            fleet.max_batch(),
            cfg.max_batch
        )));
    }

    let residency_limited = fleet.residency_limited();
    // per-request uplink transfer delay (0 with an infinite link, keeping
    // the arrival schedule bit-exact)
    let transfer_ms = if cfg.link_mbps.is_finite() {
        fleet.input_bytes() as f64 * 8.0 / (cfg.link_mbps * 1e6) * 1e3
    } else {
        0.0
    };

    let mut router = Router::new(fleet, cfg.delta_max, cfg.policy, cfg.swap_init_ms);
    let mut state: Vec<ServerState> = fleet
        .servers
        .iter()
        .map(|srv| ServerState {
            batcher: Batcher::new(srv.variants.len(), cfg.max_batch, cfg.batch_timeout_ms),
            busy: false,
            busy_until: 0.0,
            swapping: false,
            swap_until: 0.0,
            pending_swap: None,
        })
        .collect();
    let mut resident: Vec<Vec<bool>> =
        fleet.servers.iter().map(|srv| srv.initial_residency()).collect();
    let mut acc = Acc {
        usage: fleet
            .servers
            .iter()
            .map(|srv| vec![UsageAcc::default(); srv.variants.len()])
            .collect(),
        ..Default::default()
    };

    let mut heap: BinaryHeap<Reverse<Event>> = BinaryHeap::with_capacity(arrivals.len() + 16);
    let mut seq: u64 = 0;
    for (i, &t) in arrivals.iter().enumerate() {
        seq += 1;
        heap.push(Reverse(Event {
            time_ms: t + transfer_ms,
            seq,
            kind: EventKind::Arrival { req: i },
        }));
    }

    let mut backlog = vec![0.0f64; fleet.servers.len()];
    let mut queued = vec![0usize; fleet.servers.len()];
    let mut unavail = vec![false; fleet.servers.len()];
    let mut last_time = f64::NEG_INFINITY;
    let mut makespan = 0.0f64;

    while let Some(Reverse(ev)) = heap.pop() {
        let now = ev.time_ms;
        if now < last_time {
            return Err(Error::hqp(format!(
                "serve: virtual time regressed from {last_time} to {now}"
            )));
        }
        last_time = now;
        makespan = now;

        match ev.kind {
            EventKind::Arrival { req } => {
                // router input: remaining busy/swap time + queued work
                // estimate, plus the residency/availability snapshot
                for (s, st) in state.iter().enumerate() {
                    let mut est = if st.busy {
                        (st.busy_until - now).max(0.0)
                    } else if st.swapping {
                        (st.swap_until - now).max(0.0)
                    } else {
                        0.0
                    };
                    for (v, prof) in fleet.servers[s].variants.iter().enumerate() {
                        est += st.batcher.backlog(v) as f64 * prof.batch1_ms();
                    }
                    backlog[s] = est;
                    queued[s] = st.batcher.total();
                    unavail[s] = st.swapping || st.pending_swap.is_some();
                }
                let view = FleetView {
                    now_ms: now,
                    backlog_ms: &backlog,
                    queued: &queued,
                    resident: &resident,
                    unavailable: &unavail,
                };
                match router.route(&view) {
                    None => {
                        if router.num_candidates() == 0 {
                            acc.rejected_noncompliant += 1;
                        } else {
                            acc.rejected_unavailable += 1;
                        }
                    }
                    Some(c) => {
                        let st = &mut state[c.server];
                        if st.batcher.total() >= cfg.queue_cap {
                            acc.rejected_full += 1;
                        } else {
                            // SLO clock starts at generation: transfer
                            // delay eats into the budget
                            let origin = arrivals[req];
                            let qreq = QueuedReq {
                                id: req,
                                arrival_ms: origin,
                                deadline_ms: origin + cfg.slo_ms,
                            };
                            match st.batcher.enqueue(c.variant, qreq) {
                                EnqueueAction::BatchReady => {
                                    if st.can_dispatch() {
                                        try_dispatch(
                                            c.server,
                                            c.variant,
                                            now,
                                            st,
                                            &fleet.servers[c.server],
                                            &resident[c.server],
                                            &mut heap,
                                            &mut seq,
                                            &mut acc,
                                        );
                                    }
                                }
                                EnqueueAction::ArmFlush(token) => {
                                    if st.can_dispatch() {
                                        seq += 1;
                                        heap.push(Reverse(Event {
                                            time_ms: now + cfg.batch_timeout_ms,
                                            seq,
                                            kind: EventKind::Flush {
                                                server: c.server,
                                                variant: c.variant,
                                                token,
                                            },
                                        }));
                                    }
                                }
                                EnqueueAction::Queued => {}
                            }
                        }
                    }
                }
                // hot-swap planning over the same snapshot: only
                // meaningful under capped memory (static policies never
                // plan; the guard also keeps the unlimited path's event
                // stream bit-exact)
                if residency_limited {
                    if let Some(plan) = router.plan_swap(&view) {
                        let sv = plan.server;
                        let st = &mut state[sv];
                        // one swap per server at a time is part of the
                        // RoutePolicy contract — a plan for a server that
                        // is already swapping is a policy bug
                        if st.swapping || st.pending_swap.is_some() {
                            return Err(Error::hqp(
                                "serve: swap plan targets a server with a swap in flight",
                            ));
                        }
                        let at = if st.busy { st.busy_until } else { now };
                        st.pending_swap = Some(plan);
                        seq += 1;
                        heap.push(Reverse(Event {
                            time_ms: at,
                            seq,
                            kind: EventKind::SwapStart { server: sv },
                        }));
                    }
                }
            }
            EventKind::Flush { server, variant, token } => {
                let st = &mut state[server];
                if st.can_dispatch() && st.batcher.flush_live(variant, token) {
                    try_dispatch(
                        server,
                        variant,
                        now,
                        st,
                        &fleet.servers[server],
                        &resident[server],
                        &mut heap,
                        &mut seq,
                        &mut acc,
                    );
                }
            }
            EventKind::BatchDone { server, variant, reqs } => {
                for r in &reqs {
                    acc.completed += 1;
                    acc.latencies.push(now - r.arrival_ms);
                    if now <= r.deadline_ms {
                        acc.slo_attained += 1;
                    }
                    acc.usage[server][variant].completed += 1;
                }
                let st = &mut state[server];
                st.busy = false;
                // a pending swap takes the idle slot: SwapStart is queued
                // at this very timestamp
                if st.pending_swap.is_none() {
                    if let Some(next) = st.batcher.oldest_allowed(&resident[server]) {
                        try_dispatch(
                            server,
                            next,
                            now,
                            st,
                            &fleet.servers[server],
                            &resident[server],
                            &mut heap,
                            &mut seq,
                            &mut acc,
                        );
                    }
                }
            }
            EventKind::SwapStart { server } => {
                let st = &mut state[server];
                if st.busy {
                    // a batch is still running (time tie): retry the
                    // moment it completes
                    seq += 1;
                    heap.push(Reverse(Event {
                        time_ms: st.busy_until,
                        seq,
                        kind: EventKind::SwapStart { server },
                    }));
                } else if let Some(plan) = st.pending_swap.take() {
                    let srv = &fleet.servers[server];
                    if resident[server][plan.load] {
                        return Err(Error::hqp(
                            "serve: swap plan loads an already-resident variant",
                        ));
                    }
                    // evict: mark non-resident and drain the queues
                    let mut displaced: Vec<QueuedReq> = Vec::new();
                    for &e in &plan.evict {
                        if !resident[server][e] {
                            return Err(Error::hqp(
                                "serve: swap plan evicts a non-resident variant",
                            ));
                        }
                        resident[server][e] = false;
                        displaced.extend(st.batcher.drain(e));
                    }
                    let res_bytes: u64 = srv
                        .variants
                        .iter()
                        .enumerate()
                        .filter(|(v, _)| resident[server][*v])
                        .map(|(_, p)| p.weight_bytes)
                        .sum();
                    if let Some(cap) = srv.mem_capacity_bytes {
                        if res_bytes + srv.variants[plan.load].weight_bytes > cap {
                            return Err(Error::hqp(
                                "serve: swap plan exceeds device memory capacity",
                            ));
                        }
                    }
                    // displaced survivors follow the best remaining
                    // compliant engine, else the incoming one
                    if !displaced.is_empty() {
                        let mut target = plan.load;
                        let mut best = f64::INFINITY;
                        for (v, p) in srv.variants.iter().enumerate() {
                            if resident[server][v]
                                && p.compliant(cfg.delta_max)
                                && p.batch1_ms() < best
                            {
                                best = p.batch1_ms();
                                target = v;
                            }
                        }
                        let mut alive = Vec::with_capacity(displaced.len());
                        for r in displaced {
                            if r.deadline_ms < now {
                                // lapsed before the swap even began: plain
                                // expiry, the eviction only surfaced it
                                acc.expired += 1;
                            } else {
                                alive.push(r);
                            }
                        }
                        st.batcher.requeue(target, alive);
                    }
                    let swap_ms = srv.swap_in_ms(plan.load, cfg.swap_init_ms);
                    st.swapping = true;
                    st.swap_until = now + swap_ms;
                    acc.swaps += 1;
                    acc.swap_ms += swap_ms;
                    seq += 1;
                    heap.push(Reverse(Event {
                        time_ms: st.swap_until,
                        seq,
                        kind: EventKind::SwapDone { server, load: plan.load, started_ms: now },
                    }));
                }
            }
            EventKind::SwapDone { server, load, started_ms } => {
                let st = &mut state[server];
                st.swapping = false;
                resident[server][load] = true;
                // drop lapsed deadlines; only those that lapsed during the
                // swap window are attributed to the swap (earlier ones
                // would have expired at the next batch formation anyway)
                for r in st.batcher.purge_expired(now) {
                    acc.expired += 1;
                    if r.deadline_ms >= started_ms {
                        acc.expired_during_swap += 1;
                    }
                }
                // the survivors have outwaited any batching timeout:
                // dispatch immediately
                if st.can_dispatch() {
                    if let Some(next) = st.batcher.oldest_allowed(&resident[server]) {
                        try_dispatch(
                            server,
                            next,
                            now,
                            st,
                            &fleet.servers[server],
                            &resident[server],
                            &mut heap,
                            &mut seq,
                            &mut acc,
                        );
                    }
                }
            }
        }
    }

    // every queue must have drained: the heap only empties once no flush,
    // batch-done or swap event is pending anywhere, so a leftover request
    // means something routed to a queue residency could never serve
    if state.iter().any(|st| !st.batcher.is_empty()) {
        return Err(Error::hqp(
            "serve: requests stranded in a queue at end of trace (residency routing bug)",
        ));
    }

    Ok(build_summary(fleet, cfg, acc, makespan, residency_limited))
}

fn build_summary(
    fleet: &Fleet,
    cfg: &ServeConfig,
    mut acc: Acc,
    makespan_ms: f64,
    residency_limited: bool,
) -> Summary {
    acc.latencies.sort_by(f64::total_cmp);
    let n = acc.latencies.len();
    let pct = |p: f64| -> f64 {
        if n == 0 {
            0.0
        } else {
            acc.latencies[((n - 1) as f64 * p).round() as usize]
        }
    };
    let mean_ms = if n == 0 {
        0.0
    } else {
        acc.latencies.iter().sum::<f64>() / n as f64
    };

    let mut per_variant = Vec::new();
    let mut total_batches = 0u64;
    let mut total_occupancy = 0u64;
    let mut acc_weighted = 0.0f64;
    let mut energy = 0.0f64;
    for (s, server) in fleet.servers.iter().enumerate() {
        for (v, prof) in server.variants.iter().enumerate() {
            let u = acc.usage[s][v];
            total_batches += u.batches;
            total_occupancy += u.occupancy;
            acc_weighted += u.completed as f64 * prof.acc_drop;
            energy += u.energy_mj;
            per_variant.push(VariantUsage {
                server: s,
                device: server.device.name.clone(),
                variant: prof.name.clone(),
                acc_drop: prof.acc_drop,
                completed: u.completed,
                batches: u.batches,
                mean_batch: if u.batches == 0 {
                    0.0
                } else {
                    u.occupancy as f64 / u.batches as f64
                },
                busy_ms: u.busy_ms,
                utilization: if makespan_ms > 0.0 { u.busy_ms / makespan_ms } else { 0.0 },
                energy_mj: u.energy_mj,
            });
        }
    }

    let rejected = acc.rejected_full + acc.rejected_noncompliant + acc.rejected_unavailable;
    let generated = acc.completed + rejected + acc.expired;
    Summary {
        model: fleet.model.clone(),
        policy: cfg.policy.name(),
        slo_ms: cfg.slo_ms,
        delta_max: cfg.delta_max,
        generated,
        completed: acc.completed,
        rejected,
        rejected_noncompliant: acc.rejected_noncompliant,
        rejected_unavailable: acc.rejected_unavailable,
        expired: acc.expired,
        expired_during_swap: acc.expired_during_swap,
        swaps: acc.swaps,
        swap_ms: acc.swap_ms,
        residency_limited,
        slo_attained: acc.slo_attained,
        mean_ms,
        p50_ms: pct(0.50),
        p95_ms: pct(0.95),
        p99_ms: pct(0.99),
        makespan_ms,
        throughput_rps: if makespan_ms > 0.0 {
            acc.completed as f64 / (makespan_ms / 1e3)
        } else {
            0.0
        },
        mean_batch: if total_batches == 0 {
            0.0
        } else {
            total_occupancy as f64 / total_batches as f64
        },
        acc_mix: if acc.completed == 0 {
            0.0
        } else {
            acc_weighted / acc.completed as f64
        },
        energy_mj: energy,
        per_variant,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hwsim::Device;

    fn var(name: &str, acc_drop: f64, b1: f64, b2: f64) -> VariantProfile {
        VariantProfile {
            name: name.into(),
            acc_drop,
            weight_bytes: 10_000_000,
            batch_ms: vec![b1, b2],
            energy_mj: vec![b1 * 15.0, b2 * 15.0],
        }
    }

    fn one_server(v: Vec<VariantProfile>) -> Fleet {
        Fleet::single("toy", Device::xavier_nx(), v)
    }

    fn cfg() -> ServeConfig {
        ServeConfig {
            slo_ms: 100.0,
            delta_max: 0.015,
            policy: Policy::AccFastest,
            max_batch: 2,
            batch_timeout_ms: 5.0,
            queue_cap: 64,
            swap_init_ms: 5.0,
            link_mbps: f64::INFINITY,
        }
    }

    #[test]
    fn full_batches_dispatch_immediately() {
        let fleet = one_server(vec![var("hqp", 0.012, 10.0, 16.0)]);
        let s = simulate_fleet(&fleet, &[0.0, 1.0, 2.0, 3.0], &cfg()).unwrap();
        // batch [0,1] launches at t=1 (full), completes 17; [2,3] at 17→33
        assert_eq!(s.generated, 4);
        assert_eq!(s.completed, 4);
        assert_eq!(s.rejected, 0);
        assert_eq!(s.expired, 0);
        assert_eq!(s.slo_attained, 4);
        assert_eq!(s.makespan_ms, 33.0);
        assert_eq!(s.mean_batch, 2.0);
        assert_eq!(s.per_variant[0].batches, 2);
        // latencies: 17, 16, 31, 30
        assert_eq!(s.p50_ms, 30.0);
        assert!((s.mean_ms - 23.5).abs() < 1e-12);
    }

    #[test]
    fn partial_batch_waits_for_the_timeout() {
        let fleet = one_server(vec![var("hqp", 0.012, 10.0, 16.0)]);
        let s = simulate_fleet(&fleet, &[0.0], &cfg()).unwrap();
        // flush at 5, service 10 → completes 15
        assert_eq!(s.completed, 1);
        assert_eq!(s.makespan_ms, 15.0);
        assert!((s.mean_ms - 15.0).abs() < 1e-12);
        assert_eq!(s.per_variant[0].mean_batch, 1.0);
    }

    #[test]
    fn expiry_and_slo_misses_are_distinct() {
        let mut c = cfg();
        c.slo_ms = 3.0;
        c.batch_timeout_ms = 2.0;
        c.max_batch = 1;
        let fleet = one_server(vec![var("hqp", 0.012, 10.0, 16.0)]);
        // req0: dispatched at 0 (max_batch 1), completes at 10 > deadline 3
        //   → completed but SLO missed
        // req1 (t=1): queued while busy; at t=10 its deadline 4 < 10
        //   → expired, never served
        let s = simulate_fleet(&fleet, &[0.0, 1.0], &c).unwrap();
        assert_eq!(s.completed, 1);
        assert_eq!(s.expired, 1);
        assert_eq!(s.slo_attained, 0);
        assert_eq!(s.generated, 2);
    }

    #[test]
    fn queue_cap_rejects_at_admission() {
        let mut c = cfg();
        c.queue_cap = 2;
        c.max_batch = 2;
        let fleet = one_server(vec![var("hqp", 0.012, 50.0, 80.0)]);
        // t=0,0,0,0: first two fill the queue (and dispatch), during the
        // long service the cap keeps further arrivals out
        let s = simulate_fleet(&fleet, &[0.0, 0.0, 0.0, 0.0, 0.0], &c).unwrap();
        assert!(s.rejected > 0);
        assert_eq!(s.generated, 5);
        assert_eq!(s.completed + s.rejected + s.expired, 5);
    }

    #[test]
    fn noncompliant_only_fleet_rejects_everything() {
        let fleet = one_server(vec![var("p50", 0.021, 1.0, 1.6)]);
        let s = simulate_fleet(&fleet, &[0.0, 1.0, 2.0], &cfg()).unwrap();
        assert_eq!(s.completed, 0);
        assert_eq!(s.rejected, 3);
        assert_eq!(s.rejected_noncompliant, 3);
        assert_eq!(s.slo_attainment(), 0.0);
    }

    #[test]
    fn same_inputs_reproduce_identical_summaries() {
        let fleet = reference_fleet(
            "resnet18",
            &[Device::xavier_nx()],
            &["baseline", "q8", "p50", "hqp"],
            8,
        )
        .unwrap();
        let arrivals = trace::generate(&ArrivalProcess::Poisson { rps: 300.0 }, 2_000.0, 42);
        let mut c = cfg();
        c.max_batch = 8;
        let a = simulate_fleet(&fleet, &arrivals, &c).unwrap();
        let b = simulate_fleet(&fleet, &arrivals, &c).unwrap();
        assert_eq!(a, b);
        assert_eq!(a.render(), b.render(), "rendered summary must be byte-identical");
        assert_eq!(a.generated, arrivals.len() as u64);
    }

    #[test]
    fn router_never_serves_noncompliant_variants() {
        let fleet = one_server(vec![
            var("baseline", 0.0, 8.0, 13.0),
            var("p50", 0.021, 0.5, 0.8),
            var("hqp", 0.012, 1.0, 1.6),
        ]);
        for policy in [Policy::RoundRobin, Policy::LeastLoaded, Policy::AccFastest] {
            let mut c = cfg();
            c.policy = policy;
            let arrivals: Vec<f64> = (0..200).map(|i| i as f64 * 0.9).collect();
            let s = simulate_fleet(&fleet, &arrivals, &c).unwrap();
            for u in &s.per_variant {
                if u.completed > 0 || u.batches > 0 {
                    assert!(
                        u.acc_drop <= c.delta_max,
                        "{policy:?} served non-compliant {}",
                        u.variant
                    );
                }
            }
            assert!(s.completed > 0);
        }
    }

    #[test]
    fn config_validation() {
        let fleet = one_server(vec![var("hqp", 0.012, 1.0, 1.6)]);
        let mut c = cfg();
        c.max_batch = 4; // profiles only go to 2
        assert!(simulate_fleet(&fleet, &[0.0], &c).is_err());
        let mut c = cfg();
        c.slo_ms = 0.0;
        assert!(simulate_fleet(&fleet, &[0.0], &c).is_err());
        let mut c = cfg();
        c.swap_init_ms = -1.0;
        assert!(simulate_fleet(&fleet, &[0.0], &c).is_err());
        let mut c = cfg();
        c.link_mbps = 0.0;
        assert!(simulate_fleet(&fleet, &[0.0], &c).is_err());
        let empty = Fleet { model: "m".into(), servers: vec![] };
        assert!(simulate_fleet(&empty, &[0.0], &cfg()).is_err());
    }

    #[test]
    fn unlimited_memory_reports_no_swap_machinery() {
        let fleet = one_server(vec![var("hqp", 0.012, 10.0, 16.0)]);
        for policy in Policy::ALL {
            let mut c = cfg();
            c.policy = policy;
            let s = simulate_fleet(&fleet, &[0.0, 1.0, 2.0], &c).unwrap();
            assert_eq!(s.swaps, 0);
            assert_eq!(s.swap_ms, 0.0);
            assert_eq!(s.expired_during_swap, 0);
            assert_eq!(s.rejected_unavailable, 0);
            assert!(!s.residency_limited);
            // static-policy renders must stay byte-compatible with the
            // pre-residency simulator: no swap line at all
            if policy != Policy::SwapAware {
                assert!(!s.render().contains("swaps    :"), "{policy:?}");
            } else {
                assert!(s.render().contains("swaps    :"));
            }
        }
    }

    #[test]
    fn swap_aware_matches_acc_fastest_when_everything_is_resident() {
        let fleet = one_server(vec![
            var("baseline", 0.0, 8.0, 13.0),
            var("hqp", 0.012, 1.0, 1.6),
        ]);
        let arrivals: Vec<f64> = (0..100).map(|i| i as f64 * 0.7).collect();
        let mut ca = cfg();
        ca.policy = Policy::AccFastest;
        let mut cs = cfg();
        cs.policy = Policy::SwapAware;
        let a = simulate_fleet(&fleet, &arrivals, &ca).unwrap();
        let s = simulate_fleet(&fleet, &arrivals, &cs).unwrap();
        assert_eq!(s.swaps, 0, "nothing to swap in: all variants resident");
        assert_eq!((a.completed, a.expired, a.rejected), (s.completed, s.expired, s.rejected));
        assert_eq!(a.slo_attained, s.slo_attained);
        assert_eq!(a.p99_ms, s.p99_ms);
        assert_eq!(a.per_variant.len(), s.per_variant.len());
    }

    #[test]
    fn capped_memory_keeps_static_policies_on_the_resident_set() {
        // slow fp32 resident, fast hqp merely deployable
        let mut fleet = one_server(vec![
            var("fp32", 0.0, 10.0, 16.0),
            var("hqp", 0.012, 1.0, 1.6),
        ]);
        fleet.servers[0].variants[0].weight_bytes = 40_000_000;
        fleet.servers[0].variants[1].weight_bytes = 4_000_000;
        fleet.servers[0].mem_capacity_bytes = Some(41_000_000);
        assert_eq!(fleet.servers[0].initial_residency(), vec![true, false]);
        let arrivals: Vec<f64> = (0..40).map(|i| i as f64 * 2.0).collect();
        for policy in [Policy::RoundRobin, Policy::LeastLoaded, Policy::AccFastest] {
            let mut c = cfg();
            c.policy = policy;
            let s = simulate_fleet(&fleet, &arrivals, &c).unwrap();
            assert_eq!(s.swaps, 0, "{policy:?} must never swap");
            assert!(s.residency_limited);
            let hqp = s.per_variant.iter().find(|u| u.variant == "hqp").unwrap();
            assert_eq!(hqp.completed, 0, "{policy:?} served a non-resident variant");
            assert_eq!(hqp.batches, 0);
            assert!(s.completed > 0, "{policy:?} must still serve the resident one");
        }
    }

    #[test]
    fn swap_aware_hot_swaps_under_pressure_and_counts_it() {
        let mut fleet = one_server(vec![
            var("fp32", 0.0, 10.0, 16.0),
            var("hqp", 0.012, 1.0, 1.6),
        ]);
        fleet.servers[0].variants[0].weight_bytes = 40_000_000;
        fleet.servers[0].variants[1].weight_bytes = 4_000_000;
        fleet.servers[0].mem_capacity_bytes = Some(41_000_000);
        // overload the resident fp32 engine: 1 req/ms against ~0.1 req/ms
        let arrivals: Vec<f64> = (0..200).map(|i| i as f64).collect();
        let mut c = cfg();
        c.policy = Policy::SwapAware;
        let s = simulate_fleet(&fleet, &arrivals, &c).unwrap();
        assert_eq!(s.swaps, 1, "one swap to hqp, then stable");
        let expected_swap = Device::xavier_nx().swap_in_ms(4_000_000, c.swap_init_ms);
        assert!((s.swap_ms - expected_swap).abs() < 1e-9);
        let fp32 = s.per_variant.iter().find(|u| u.variant == "fp32").unwrap();
        let hqp = s.per_variant.iter().find(|u| u.variant == "hqp").unwrap();
        assert!(fp32.completed > 0, "the resident engine serves before the swap");
        assert!(hqp.completed > fp32.completed, "post-swap hqp carries the load");
        assert_eq!(
            s.completed + s.rejected + s.expired,
            s.generated,
            "conservation holds across the swap"
        );
        assert!(s.render().contains("swaps    : 1"));
        // the swap-aware run must beat every static policy stuck on fp32
        for policy in [Policy::RoundRobin, Policy::LeastLoaded, Policy::AccFastest] {
            let mut cs = cfg();
            cs.policy = policy;
            let stat = simulate_fleet(&fleet, &arrivals, &cs).unwrap();
            assert!(
                s.slo_attainment() >= stat.slo_attainment(),
                "swap-aware {:.3} < {policy:?} {:.3}",
                s.slo_attainment(),
                stat.slo_attainment()
            );
        }
    }

    #[test]
    fn finite_link_delays_admission_and_eats_slo_budget() {
        let fleet = one_server(vec![var("hqp", 0.012, 10.0, 16.0)]);
        let mut c = cfg();
        // 150528 input bytes at 1 Mbit/s ≈ 1204 ms per request
        c.link_mbps = 1.0;
        c.slo_ms = 100.0;
        let s = simulate_fleet(&fleet, &[0.0], &c).unwrap();
        assert_eq!(s.generated, 1);
        // the deadline (t=100) passes during the ~1204 ms transfer: the
        // request is admitted but expires before service
        assert_eq!(s.completed + s.expired, 1);
        assert_eq!(s.completed, 0, "transfer delay must count against the SLO");
        // a fat link is exactly the no-network model
        let mut fat = cfg();
        fat.link_mbps = f64::INFINITY;
        let a = simulate_fleet(&fleet, &[0.0, 1.0, 2.0], &fat).unwrap();
        let b = simulate_fleet(&fleet, &[0.0, 1.0, 2.0], &cfg()).unwrap();
        assert_eq!(a, b, "infinite link must be byte-identical to the default");
    }
}
