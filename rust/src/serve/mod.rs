//! Trace-driven edge serving simulator with SLO-aware routing over HQP
//! variants — the deployment layer the paper's tables stop short of.
//!
//! The paper's single-inference roofline numbers (Tables I/II) say how
//! fast one request runs; this module says what that buys under *load*: a
//! fleet of [`crate::hwsim`] devices, each loaded with deployed HQP
//! variants ([`fleet::VariantProfile`] — the serving view of the
//! [`crate::hqp::deploy::MethodReport`] engines), replays a synthetic
//! request trace ([`trace`]) through an admission queue, a dynamic
//! batcher ([`batcher`]) and an SLO-aware router ([`router`]) that picks
//! device × variant per request subject to the paper's Δ_max accuracy
//! constraint.
//!
//! ## Design: a sharded virtual-time event engine
//!
//! The simulator is a discrete-event walk over virtual time with one
//! event heap *per server* (`engine`, this module's private core):
//! arrivals and autoscale control ticks form a global timeline, and
//! between consecutive global events every server advances its own
//! shard-local events (batch flushes and completions, swaps, wakes)
//! independently — in parallel when [`simulate_fleet_jobs`] is given
//! more than one worker (`hqp serve --jobs N`). The event order is fixed
//! by construction: the *same* canonical order runs at every `jobs`
//! value, and `jobs` only chooses how many OS threads advance shards
//! between barriers, so the same `(fleet, trace, config)` triple
//! produces a byte-identical [`Summary`] at any parallelism. That
//! determinism is what makes the conservation laws property-testable
//! (`tests/prop_serve.rs`, including the jobs=1 ≡ jobs=N contract).
//! Service times come from the batched roofline
//! ([`crate::hwsim::simulate_batch`]), so no wall-clock time is spent
//! "serving" — a 10-minute trace simulates in milliseconds. See
//! `rust/DESIGN.md` §Parallelism for the full determinism contract.
//!
//! ## Request lifecycle
//!
//! Every generated request ends in exactly one of three states:
//!
//! * **rejected** — at admission: no Δ_max-compliant variant exists, the
//!   routed server's queue is at capacity, or (under capped memory) no
//!   compliant variant is resident on an available server;
//! * **expired** — its SLO deadline passed while it waited in a queue
//!   (dropped at batch-formation time or at a swap boundary, never
//!   served);
//! * **completed** — served in a batch; it *attains* the SLO iff it
//!   finishes by `arrival + slo_ms`.
//!
//! ## Stateful variant residency
//!
//! With per-server engine-memory capacities ([`Server::mem_capacity_bytes`],
//! CLI `--mem-mb`) a device holds only a *resident* subset of its
//! deployable variants. The router ([`router`]) then routes only over
//! resident variants, and a [`RoutePolicy`] may propose a hot-swap; the
//! event loop executes it as a `SwapStart`/`SwapDone` event pair: the
//! evicted variant's queue is drained and requeued ([`batcher`]'s
//! eviction semantics), the device serves nothing mid-swap (queued
//! requests wait or expire), and the swap is charged the hardware-aware
//! cost [`crate::hwsim::Device::swap_in_ms`] (weight streaming over DRAM
//! bandwidth + a fixed init overhead, [`ServeConfig::swap_init_ms`])
//! plus energy E = P·L for the swap window — the same pricing wake
//! windows get ([`Summary::swap_energy_mj`], folded into the energy
//! total). With capacities unset, every variant is resident, no swap
//! event is ever scheduled, and the simulation is byte-identical to the
//! pre-residency simulator.
//!
//! ## Elastic fleet autoscaling
//!
//! With an [`AutoscaleConfig`] policy enabled ([`ServeConfig::autoscale`],
//! CLI `--autoscale`), servers gain a lifecycle
//! ([`autoscale::Lifecycle`]: `Active` / `Draining` / `Asleep`) and a
//! deterministic controller runs at a fixed control interval: every tick
//! folds the window's outcomes into EWMA queue-depth / SLO-attainment
//! signals ([`autoscale::SignalTracker`]) and asks the configured
//! [`autoscale::AutoscalePolicy`] for a scale decision, executed as
//! `ScaleUp`/`WakeDone`/`DrainStart`/`ScaleDown` events. Waking a server
//! is priced like a cold swap (initial-residency weight bytes over DRAM
//! bandwidth + init overhead) and charged energy E = P·L; a draining
//! server finishes its queue, then sleeps. Routing to an asleep or
//! draining server is structurally impossible (they are `unavailable` in
//! the router's [`FleetView`], and the event loop hard-errors on any
//! scale event that finds its server in the wrong state). With the
//! policy `off` (the default) no control event is ever scheduled and the
//! simulation is byte-identical to the fixed-fleet simulator.
//!
//! The `predictive` policy ([`predict`]) layers an online arrival
//! forecaster (MMPP(2) filter + trace-periodicity estimator) over the
//! queue-depth controller: it pre-wakes servers a wake-latency before a
//! forecast ramp, sleeps early into troughs, prefetches hot-swaps ahead
//! of crests and — under [`Policy::JoulesPerSlo`] — reselects idle
//! capped servers onto cheaper compliant variants; it degrades to plain
//! queue-depth whenever forecast confidence is low.
//! [`ServeConfig::idle_watts`] prices the powered-but-not-busy window
//! and [`ServeConfig::scale_to_drain`] keeps control ticks running past
//! the last arrival; all default off and inert. See `rust/DESIGN.md`
//! §Prediction.
//!
//! ## Streaming at constant memory
//!
//! The hot path never holds the trace or the latencies:
//! [`simulate_fleet_stream`] consumes any iterator of arrival times
//! (e.g. [`trace::ArrivalGen`], the lazy form of [`trace::generate`])
//! through a bounded lookahead, and per-request latencies fold into a
//! fixed-edge log-binned histogram ([`stats::LatencyStats`]) instead of
//! a `Vec<f64>` — so a 10⁶-request run and a 10³-request run hold the
//! same telemetry state. p50/p95/p99 keep their nearest-rank definition
//! with a documented ≤ 1 % relative error
//! ([`stats::LatencyStats::QUANTILE_REL_ERROR`]); mean/max/count stay
//! exact. The slice entry points ([`simulate_fleet`],
//! [`simulate_fleet_jobs`]) are the materialized special case and
//! produce byte-identical summaries. See `rust/DESIGN.md` §Serving,
//! "Memory & streaming".
//!
//! See `rust/DESIGN.md` §Serving, §Autoscaling and §Prediction for the
//! model's limits
//! (open-loop arrivals, serial devices, linear activation scaling; the
//! optional [`ServeConfig::link_mbps`] uplink model charges a per-request
//! transfer delay).

pub mod autoscale;
pub mod batcher;
mod engine;
pub mod fleet;
pub mod predict;
pub mod router;
pub mod stats;
pub mod tenant;
pub mod trace;

pub use autoscale::{
    AutoscaleConfig, AutoscalePolicy, Lifecycle, ScaleDecision, ScalePolicy, ScaleSignals,
    SignalTracker,
};
pub use fleet::{fleet_for, reference_fleet, workspace_fleet, Fleet, Server, VariantProfile};
pub use predict::{ForecastObs, Forecaster, PredictivePolicy, RateForecast};
pub use router::{Candidate, FleetView, Policy, RouteCtx, RoutePolicy, Router, SwapPlan};
pub use tenant::{parse_tenants, AdmitPolicy, TenantClass, TENANT_SPEC_FORMAT};
pub use trace::ArrivalProcess;

use crate::error::{Error, Result};
use crate::exec::Jobs;
use crate::report::Table;

/// Serving-simulation parameters.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Per-request latency SLO, ms (deadline = arrival + slo).
    pub slo_ms: f64,
    /// Δ_max: the accuracy-drop budget the router must respect.
    pub delta_max: f64,
    pub policy: Policy,
    /// Dynamic batcher: max batch size…
    pub max_batch: usize,
    /// …and how long an idle device waits for a batch to fill, ms.
    pub batch_timeout_ms: f64,
    /// Admission cap on queued requests per server.
    pub queue_cap: usize,
    /// Fixed engine-initialization overhead added to every hot-swap, ms
    /// (on top of streaming the engine weights over DRAM bandwidth).
    pub swap_init_ms: f64,
    /// Uplink bandwidth for request payloads, Mbit/s. Each request pays
    /// `input_bytes / link_mbps` of transfer delay before admission (the
    /// delay eats into its SLO budget). `f64::INFINITY` (the default)
    /// disables the network model and preserves byte-identical summaries.
    pub link_mbps: f64,
    /// Elastic autoscaling controller ([`AutoscaleConfig::off`] by
    /// default — the fixed-fleet behavior, byte-identical to the
    /// pre-autoscaling simulator).
    pub autoscale: AutoscaleConfig,
    /// Closed-loop clients: how many times a rejected or expired request
    /// re-enters the arrival stream after seeded exponential backoff.
    /// 0 (the default) is the open-loop behavior — no retry machinery
    /// runs and summaries are byte-identical to the pre-closed-loop
    /// simulator.
    pub retries: usize,
    /// Mean of the first backoff draw, ms; the mean doubles with every
    /// further attempt (classic exponential backoff, with the draw
    /// itself exponentially distributed so retries never synchronize).
    pub retry_base_ms: f64,
    /// Seed of the backoff draws. Each (request id, attempt) pair gets
    /// its own derived stream, so the draw is a pure function of
    /// (seed, id, attempt) — independent of `--jobs` and of the order
    /// failures are discovered in.
    pub retry_seed: u64,
    /// Tenant classes sharing the fleet (empty — the default — means the
    /// single implicit tenant carrying the global `delta_max`/`slo_ms`,
    /// byte-identical to the pre-tenant simulator).
    pub tenants: Vec<TenantClass>,
    /// Batch admission order across tenants ([`AdmitPolicy::Fifo`] is
    /// the pre-tenant behavior and the default).
    pub admit: AdmitPolicy,
    /// Forecast-horizon override for the predictive controller, ms.
    /// `None` (the default) derives the horizon at each control tick as
    /// the next wake's latency plus one control interval — the lead time
    /// a prewake decision taken now can actually buy. Only valid with
    /// the `predictive` autoscale policy.
    pub forecast_horizon_ms: Option<f64>,
    /// Idle power draw per powered server, W: a powered (not asleep)
    /// server accrues `idle_watts × (powered − busy − swapping)` of
    /// energy over the run, surfaced as [`Summary::idle_energy_mj`] and
    /// folded into [`Summary::energy_mj`]. 0 (the default) is inert —
    /// no idle term, summaries byte-identical to the pre-idle-power
    /// simulator.
    pub idle_watts: f64,
    /// Keep issuing control ticks through the drain phase — after the
    /// last arrival, while shard events remain — so draining/asleep
    /// decisions stay live until the final event. Off by default (the
    /// PR 4 behavior: the control plane froze at the last arrival);
    /// implied by the `predictive` autoscale policy.
    pub scale_to_drain: bool,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            slo_ms: 50.0,
            delta_max: 0.015,
            policy: Policy::AccFastest,
            max_batch: 8,
            batch_timeout_ms: 2.0,
            queue_cap: 256,
            swap_init_ms: 5.0,
            link_mbps: f64::INFINITY,
            autoscale: AutoscaleConfig::off(),
            retries: 0,
            retry_base_ms: 5.0,
            retry_seed: 42,
            tenants: Vec::new(),
            admit: AdmitPolicy::Fifo,
            forecast_horizon_ms: None,
            idle_watts: 0.0,
            scale_to_drain: false,
        }
    }
}

impl ServeConfig {
    /// Whether closed-loop clients (retry/backoff) are enabled.
    pub fn closed_loop(&self) -> bool {
        self.retries > 0
    }

    /// Whether an explicit tenant table is configured.
    pub fn multi_tenant(&self) -> bool {
        !self.tenants.is_empty()
    }

    /// The effective tenant table: the configured classes, or the single
    /// implicit tenant carrying the global Δ_max / SLO at weight 1.
    pub fn effective_tenants(&self) -> Vec<TenantClass> {
        if self.tenants.is_empty() {
            vec![TenantClass {
                name: "default".into(),
                dmax: self.delta_max,
                slo_ms: self.slo_ms,
                weight: 1.0,
                rate_share: None,
            }]
        } else {
            self.tenants.clone()
        }
    }
}

/// Per-(server, variant) serving statistics.
#[derive(Clone, Debug, PartialEq)]
pub struct VariantUsage {
    /// Index into [`Fleet::servers`].
    pub server: usize,
    /// The server's device name (display).
    pub device: String,
    /// The variant's method name (display).
    pub variant: String,
    /// The variant's measured accuracy drop.
    pub acc_drop: f64,
    /// Requests this (server, variant) pair completed.
    pub completed: u64,
    /// Batches it dispatched.
    pub batches: u64,
    /// Mean dispatched batch size (0 when it never served).
    pub mean_batch: f64,
    /// Virtual time it spent executing batches, ms.
    pub busy_ms: f64,
    /// busy_ms / makespan.
    pub utilization: f64,
    /// Whole-batch energy it consumed, mJ.
    pub energy_mj: f64,
}

/// Per-tenant serving census (one row of the gated tenant table in
/// [`Summary::render`]). Only populated when [`ServeConfig::tenants`] is
/// non-empty.
#[derive(Clone, Debug, PartialEq)]
pub struct TenantSummary {
    /// Tenant class name.
    pub name: String,
    /// The tenant's accuracy-drop budget.
    pub dmax: f64,
    /// The tenant's latency SLO, ms.
    pub slo_ms: f64,
    /// The tenant's weighted-fair admission share.
    pub weight: f64,
    /// Fresh requests this tenant offered (retries excluded).
    pub generated: u64,
    /// Requests served to completion.
    pub completed: u64,
    /// Admission rejections with no retry budget left.
    pub dropped_final: u64,
    /// Deadline expiries with no retry budget left.
    pub expired_final: u64,
    /// Retry re-entries this tenant's clients made.
    pub retries: u64,
    /// Completions within the tenant's own SLO deadline.
    pub slo_attained: u64,
    /// The tenant's streamed completion-latency histogram (exact
    /// count/mean/max, percentile error as the global histogram).
    pub latency: stats::LatencyStats,
}

impl TenantSummary {
    /// Per-tenant SLO attainment over the tenant's offered load.
    pub fn attainment(&self) -> f64 {
        if self.generated == 0 {
            0.0
        } else {
            self.slo_attained as f64 / self.generated as f64
        }
    }
}

/// One simulation's results.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    /// Model the fleet serves.
    pub model: String,
    /// Routing policy name ([`Policy::name`]).
    pub policy: &'static str,
    /// The per-request latency SLO the run was scored against, ms.
    pub slo_ms: f64,
    /// The accuracy-drop budget the router enforced.
    pub delta_max: f64,
    /// Requests in the offered trace (= completed + rejected + expired).
    pub generated: u64,
    /// Requests served to completion (SLO-attaining or not).
    pub completed: u64,
    /// Requests refused at admission (all causes).
    pub rejected: u64,
    /// Of the rejections: requests with no Δ_max-compliant variant.
    pub rejected_noncompliant: u64,
    /// Of the rejections: compliant variants exist, but none was resident
    /// on an available (not mid-swap) server. Always 0 with unlimited
    /// memory.
    pub rejected_unavailable: u64,
    pub expired: u64,
    /// Of the expired: the deadline lapsed while the routed server was
    /// mid-swap (deadlines in `[swap start, swap done]`). Deadlines that
    /// had already passed before the swap began count only as `expired`.
    pub expired_during_swap: u64,
    /// Completed within their SLO deadline.
    pub slo_attained: u64,
    /// Mean completion latency (arrival → batch completion), ms. Exact
    /// (streamed sum / count, folded in shard-index order).
    pub mean_ms: f64,
    /// Median completion latency, ms. Nearest-rank from
    /// [`Summary::latency_hist`], within
    /// [`stats::LatencyStats::QUANTILE_REL_ERROR`] of the exact sample.
    pub p50_ms: f64,
    /// 95th-percentile completion latency, ms (same definition as p50).
    pub p95_ms: f64,
    /// 99th-percentile completion latency, ms (same definition as p50).
    pub p99_ms: f64,
    /// The streamed latency histogram the percentiles come from — it
    /// records the bin configuration
    /// ([`stats::LatencyStats::BINS_PER_OCTAVE`] fixed log-binned edges)
    /// along with exact count/mean/max. Not rendered (so
    /// [`Summary::render`] stays byte-compatible with earlier releases).
    pub latency_hist: stats::LatencyStats,
    /// Max over servers of the queued-request high-water mark — the
    /// backpressure a run actually hit (bounded by
    /// [`ServeConfig::queue_cap`]). Not rendered, same gating as
    /// [`Summary::events`].
    pub peak_queue_depth: u64,
    /// Virtual time of the last event.
    pub makespan_ms: f64,
    /// Simulation events processed (arrivals, control ticks, scale
    /// decisions and every shard-local event) — the numerator of the
    /// events/sec figure `bench_serve` reports. Not rendered (so
    /// [`Summary::render`] stays byte-compatible with earlier releases).
    pub events: u64,
    /// Goodput: completions per second of makespan.
    pub throughput_rps: f64,
    /// Mean dispatched batch size across the fleet.
    pub mean_batch: f64,
    /// Completion-weighted mean accuracy drop across served variants.
    pub acc_mix: f64,
    /// Total energy: whole-batch serving energy plus any wake and
    /// hot-swap windows' E = P·L, mJ.
    pub energy_mj: f64,
    /// Engine hot-swaps performed.
    pub swaps: u64,
    /// Total virtual time spent swapping (weight streaming + init), ms.
    pub swap_ms: f64,
    /// Energy charged for the hot-swap windows, E = P·L (mJ; included in
    /// [`Summary::energy_mj`]). Zero whenever no swap happened, so
    /// fixed-fleet / no-swap summaries are byte-identical to the
    /// pre-swap-energy simulator.
    pub swap_energy_mj: f64,
    /// Whether any server ran with a finite engine-memory capacity (gates
    /// the swap line in [`Summary::render`], keeping unlimited-memory
    /// output byte-identical to the pre-residency simulator).
    pub residency_limited: bool,
    /// Whether the autoscaling control plane was enabled (gates the scale
    /// line in [`Summary::render`], keeping fixed-fleet output
    /// byte-identical to the pre-autoscaling simulator).
    pub autoscaled: bool,
    /// Scale-up decisions executed (each one wakes a server).
    pub scale_ups: u64,
    /// Scale-down decisions executed (each one drains a server, which
    /// then sleeps).
    pub scale_downs: u64,
    /// Total virtual time servers spent waking (initial-residency weight
    /// streaming + init), ms.
    pub wake_ms: f64,
    /// Energy charged for the wake windows, E = P·L (mJ; included in
    /// [`Summary::energy_mj`]).
    pub wake_energy_mj: f64,
    /// Mean time from the first control tick of a pressure episode to the
    /// woken server coming online — detection hysteresis plus the wake
    /// itself. 0 when no scale-up happened.
    pub mean_reaction_ms: f64,
    /// Whether the `predictive` autoscale policy drove the run (gates the
    /// predict line in [`Summary::render`], keeping reactive output
    /// byte-identical to the pre-prediction simulator).
    pub predictive: bool,
    /// Forecast-driven pre-wakes — scale-ups fired on `rate_ahead` rather
    /// than observed pressure (a subset of [`Summary::scale_ups`]).
    pub prewakes: u64,
    /// Forecast-driven prefetch hot-swaps started ahead of predicted
    /// pressure (a subset of [`Summary::swaps`]).
    pub prefetch_swaps: u64,
    /// Forecast-driven downshift re-selections toward cheaper compliant
    /// variants on predicted sustained low load (a subset of
    /// [`Summary::swaps`]).
    pub reselect_swaps: u64,
    /// Mean absolute forecast error over matured predictions, as a
    /// percent of the realized rate. 0 when no prediction matured.
    pub forecast_abs_err_pct: f64,
    /// Idle-power energy ([`ServeConfig::idle_watts`] × powered-but-idle
    /// time), mJ; included in [`Summary::energy_mj`]. Exactly 0 at the
    /// knob's 0 default, keeping summaries byte-identical.
    pub idle_energy_mj: f64,
    /// Whether closed-loop clients were enabled (gates the retry line in
    /// [`Summary::render`], keeping open-loop output byte-identical to
    /// the pre-closed-loop simulator).
    pub closed_loop: bool,
    /// Client retry re-entries into the arrival stream. Always 0
    /// open-loop.
    pub retries: u64,
    /// Requests refused at admission with no retry budget left. Equals
    /// [`Summary::rejected`] when retries are off, so conservation reads
    /// `generated = completed + dropped_final + expired_final` in both
    /// regimes.
    pub dropped_final: u64,
    /// Requests whose deadline lapsed with no retry budget left. Equals
    /// [`Summary::expired`] when retries are off.
    pub expired_final: u64,
    /// The batch admission order the run used ([`AdmitPolicy::name`]).
    pub admit: &'static str,
    /// Per-tenant census — empty (and unrendered) unless
    /// [`ServeConfig::tenants`] was set.
    pub tenants: Vec<TenantSummary>,
    pub per_variant: Vec<VariantUsage>,
}

impl Summary {
    /// SLO attainment over *offered* load (rejected and expired requests
    /// count against it — dropping traffic is not meeting its SLO).
    pub fn slo_attainment(&self) -> f64 {
        if self.generated == 0 {
            0.0
        } else {
            self.slo_attained as f64 / self.generated as f64
        }
    }

    /// Render the summary (the `hqp serve` output). Deterministic: equal
    /// summaries render byte-identically.
    pub fn render(&self) -> String {
        let mut s = format!(
            "serve summary — {} (policy {}, slo {:.1} ms, Δmax {:.2}%)\n",
            self.model,
            self.policy,
            self.slo_ms,
            self.delta_max * 100.0
        );
        if self.closed_loop {
            // closed loop: rejected/expired count *attempts* (retried
            // ones included), so the conservation identity is stated
            // over final outcomes, with the retry census on its own line
            s.push_str(&format!(
                "  requests : {} generated = {} completed + {} dropped + {} expired (final)\n",
                self.generated, self.completed, self.dropped_final, self.expired_final
            ));
            s.push_str(&format!(
                "  retries  : {} re-entries   ({} rejections, {} expiries before backoff)\n",
                self.retries, self.rejected, self.expired
            ));
        } else {
            s.push_str(&format!(
                "  requests : {} generated = {} completed + {} rejected + {} expired\n",
                self.generated, self.completed, self.rejected, self.expired
            ));
        }
        s.push_str(&format!(
            "  slo      : {:.2}% attainment   throughput {:.1} rps   mean batch {:.2}\n",
            self.slo_attainment() * 100.0,
            self.throughput_rps,
            self.mean_batch
        ));
        s.push_str(&format!(
            "  latency  : p50 {:.3} ms   p95 {:.3} ms   p99 {:.3} ms   mean {:.3} ms\n",
            self.p50_ms, self.p95_ms, self.p99_ms, self.mean_ms
        ));
        s.push_str(&format!(
            "  quality  : completion-weighted acc drop {:.3}%   energy {:.1} mJ\n",
            self.acc_mix * 100.0,
            self.energy_mj
        ));
        if self.residency_limited || self.policy == Policy::SwapAware.name() {
            // the E = P·L term appears only once a swap was charged, so
            // no-swap output stays byte-identical to the pre-swap-energy
            // renderer
            let swapping = if self.swap_energy_mj > 0.0 {
                format!("{:.1} ms swapping, {:.1} mJ", self.swap_ms, self.swap_energy_mj)
            } else {
                format!("{:.1} ms swapping", self.swap_ms)
            };
            s.push_str(&format!(
                "  swaps    : {} ({swapping})   {} expired mid-swap   \
                 {} rejected unavailable\n",
                self.swaps, self.expired_during_swap, self.rejected_unavailable
            ));
        }
        if self.autoscaled {
            s.push_str(&format!(
                "  scale    : {} up / {} down   wake {:.1} ms ({:.1} mJ)   \
                 mean reaction {:.1} ms\n",
                self.scale_ups,
                self.scale_downs,
                self.wake_ms,
                self.wake_energy_mj,
                self.mean_reaction_ms
            ));
        }
        if self.predictive {
            s.push_str(&format!(
                "  predict  : {} prewakes   {} prefetch / {} reselect swaps   \
                 forecast err {:.1}%\n",
                self.prewakes, self.prefetch_swaps, self.reselect_swaps, self.forecast_abs_err_pct
            ));
        }
        if self.idle_energy_mj > 0.0 {
            // the idle term appears only when --idle-watts was set, so
            // default output stays byte-identical to the pre-idle-power
            // renderer
            s.push_str(&format!(
                "  idle     : {:.1} mJ idle-power energy (in the energy total)\n",
                self.idle_energy_mj
            ));
        }
        if !self.tenants.is_empty() {
            s.push_str(&format!(
                "  tenants  : {} classes (admission {})\n",
                self.tenants.len(),
                self.admit
            ));
            let mut tt = Table::new(vec![
                "Tenant",
                "Δmax",
                "SLO (ms)",
                "Weight",
                "Generated",
                "Completed",
                "Attain",
                "p99 (ms)",
            ]);
            for t in &self.tenants {
                tt.row(vec![
                    t.name.clone(),
                    format!("{:.2}%", t.dmax * 100.0),
                    format!("{:.1}", t.slo_ms),
                    format!("{:.1}", t.weight),
                    format!("{}", t.generated),
                    format!("{}", t.completed),
                    format!("{:.2}%", t.attainment() * 100.0),
                    format!("{:.3}", t.latency.quantile(0.99)),
                ]);
            }
            s.push_str(&tt.render());
        }
        let mut t = Table::new(vec![
            "Device",
            "Variant",
            "Acc Drop",
            "Completed",
            "Batches",
            "Mean Batch",
            "Util",
            "Energy (mJ)",
        ]);
        for u in &self.per_variant {
            t.row(vec![
                u.device.clone(),
                u.variant.clone(),
                format!("{:.2}%", u.acc_drop * 100.0),
                format!("{}", u.completed),
                format!("{}", u.batches),
                format!("{:.2}", u.mean_batch),
                format!("{:.1}%", u.utilization * 100.0),
                format!("{:.1}", u.energy_mj),
            ]);
        }
        s.push_str(&t.render());
        s
    }
}

/// Replay `arrivals` (sorted ms timestamps from [`trace::generate`])
/// against `fleet` under `cfg`, single-threaded. Equivalent to
/// [`simulate_fleet_jobs`] with one worker — and, by the determinism
/// contract, byte-identical to it at any worker count.
pub fn simulate_fleet(fleet: &Fleet, arrivals: &[f64], cfg: &ServeConfig) -> Result<Summary> {
    simulate_fleet_jobs(fleet, arrivals, cfg, Jobs::one())
}

/// Replay `arrivals` against `fleet` under `cfg` with up to `jobs`
/// worker threads advancing server shards between global events (see the
/// module docs; `jobs` caps at the server count, so a single-server
/// fleet always runs inline). Virtual-time monotonicity is checked on
/// every event, swap plans are validated against live residency and
/// capacity, and a stranded queue at the end of the trace is reported —
/// each is an internal invariant violation that errors out rather than
/// silently producing garbage (so an `Ok` return is itself the proof the
/// residency and conservation invariants held).
pub fn simulate_fleet_jobs(
    fleet: &Fleet,
    arrivals: &[f64],
    cfg: &ServeConfig,
    jobs: Jobs,
) -> Result<Summary> {
    let auto = validate(fleet, cfg)?;
    let residency_limited = fleet.residency_limited();
    let totals = engine::run(fleet, arrivals, cfg, jobs.get())?;
    Ok(build_summary(fleet, cfg, totals, residency_limited, auto))
}

/// Replay a *streaming* arrival source against `fleet` — the
/// constant-memory form of [`simulate_fleet_jobs`]. The iterator's times
/// must be finite, non-negative and non-decreasing (validated on the
/// fly; the materialized entry points go through this same engine).
/// Pair it with [`trace::ArrivalGen`] to simulate arbitrarily long
/// traces — e.g. `ArrivalGen::new(&p, f64::INFINITY, seed).take(n)` for
/// an exact request budget (`hqp serve --requests N`) — with resident
/// memory independent of the request count. Byte-identical to the slice
/// path on the same arrivals, at any `jobs`.
pub fn simulate_fleet_stream<I: Iterator<Item = f64>>(
    fleet: &Fleet,
    arrivals: I,
    cfg: &ServeConfig,
    jobs: Jobs,
) -> Result<Summary> {
    let auto = validate(fleet, cfg)?;
    let residency_limited = fleet.residency_limited();
    let totals = engine::run_stream(fleet, arrivals, cfg, jobs.get())?;
    Ok(build_summary(fleet, cfg, totals, residency_limited, auto))
}

/// Shared config/fleet validation for the slice and streaming entry
/// points. Returns whether the autoscaling control plane is enabled.
fn validate(fleet: &Fleet, cfg: &ServeConfig) -> Result<bool> {
    if fleet.servers.is_empty() {
        return Err(Error::hqp("serve: empty fleet"));
    }
    if cfg.max_batch == 0 {
        return Err(Error::hqp("serve: max_batch must be >= 1"));
    }
    if cfg.slo_ms <= 0.0 {
        return Err(Error::hqp("serve: slo_ms must be positive"));
    }
    if cfg.swap_init_ms < 0.0 || cfg.swap_init_ms.is_nan() {
        return Err(Error::hqp("serve: swap_init_ms must be >= 0"));
    }
    if cfg.link_mbps <= 0.0 || cfg.link_mbps.is_nan() {
        return Err(Error::hqp("serve: link_mbps must be positive (or infinite)"));
    }
    if fleet.max_batch() < cfg.max_batch {
        return Err(Error::hqp(format!(
            "serve: fleet profiles support batches up to {}, config wants {}",
            fleet.max_batch(),
            cfg.max_batch
        )));
    }
    // closed-loop knobs: validated only when retries are on (an
    // open-loop config's backoff knobs are documented as inert)
    if cfg.closed_loop() && (!(cfg.retry_base_ms > 0.0) || !cfg.retry_base_ms.is_finite()) {
        return Err(Error::hqp("serve: retry_base_ms must be positive and finite"));
    }
    // tenant classes: parse_tenants enforces these for the CLI, but a
    // programmatically built table goes through the same gate
    for (i, t) in cfg.tenants.iter().enumerate() {
        if t.name.is_empty() {
            return Err(Error::hqp(format!("serve: tenant {i} has an empty name")));
        }
        if cfg.tenants[..i].iter().any(|o| o.name == t.name) {
            return Err(Error::hqp(format!("serve: duplicate tenant name {}", t.name)));
        }
        if !(t.dmax >= 0.0) || !t.dmax.is_finite() {
            return Err(Error::hqp(format!("serve: tenant {} needs dmax >= 0", t.name)));
        }
        if !(t.slo_ms > 0.0) || !t.slo_ms.is_finite() {
            return Err(Error::hqp(format!("serve: tenant {} needs slo_ms > 0", t.name)));
        }
        if !(t.weight > 0.0) || !t.weight.is_finite() {
            return Err(Error::hqp(format!("serve: tenant {} needs weight > 0", t.name)));
        }
        if let Some(r) = t.rate_share {
            if !(r > 0.0) || !r.is_finite() {
                return Err(Error::hqp(format!(
                    "serve: tenant {} needs rate_share > 0",
                    t.name
                )));
            }
        }
    }
    // rate shares are all-or-none: a half-pinned table has no defined
    // split for the unpinned classes (parse_tenants enforces this for
    // the CLI; a programmatic table goes through the same gate)
    if cfg.tenants.iter().any(|t| t.rate_share.is_some())
        && cfg.tenants.iter().any(|t| t.rate_share.is_none())
    {
        return Err(Error::hqp(
            "serve: tenant rate_share is all-or-none across the table",
        ));
    }
    // autoscaling bounds: validated only when the control plane is on
    // (an off config's knobs are documented as inert)
    let auto = cfg.autoscale.enabled();
    let max_active = cfg.autoscale.max_active.min(fleet.servers.len());
    if auto {
        let a = &cfg.autoscale;
        if a.interval_ms <= 0.0 || !a.interval_ms.is_finite() {
            return Err(Error::hqp("serve: scale-interval-ms must be positive and finite"));
        }
        if a.min_active == 0 {
            return Err(Error::hqp("serve: min-servers must be >= 1"));
        }
        if a.min_active > max_active {
            return Err(Error::hqp(format!(
                "serve: min-servers {} exceeds max active {} (fleet has {} servers)",
                a.min_active,
                max_active,
                fleet.servers.len()
            )));
        }
        if !(a.queue_high > a.queue_low && a.queue_low >= 0.0) || a.queue_high.is_nan() {
            return Err(Error::hqp(
                "serve: scale watermarks need high-water > low-water >= 0",
            ));
        }
    }
    // predictive-plane knobs: the horizon override is meaningless
    // without the forecaster it parameterizes, so it is rejected loudly
    // rather than silently ignored
    if cfg.forecast_horizon_ms.is_some() && cfg.autoscale.policy != ScalePolicy::Predictive {
        return Err(Error::hqp(
            "serve: forecast-horizon-ms requires --autoscale predictive",
        ));
    }
    if let Some(h) = cfg.forecast_horizon_ms {
        if !(h > 0.0) || !h.is_finite() {
            return Err(Error::hqp(
                "serve: forecast-horizon-ms must be positive and finite",
            ));
        }
    }
    if cfg.idle_watts < 0.0 || cfg.idle_watts.is_nan() {
        return Err(Error::hqp("serve: idle-watts must be >= 0 and finite"));
    }
    if cfg.idle_watts.is_infinite() {
        return Err(Error::hqp("serve: idle-watts must be >= 0 and finite"));
    }
    if cfg.scale_to_drain && !auto {
        return Err(Error::hqp("serve: scale-to-drain requires --autoscale"));
    }
    Ok(auto)
}

fn build_summary(
    fleet: &Fleet,
    cfg: &ServeConfig,
    acc: engine::Totals,
    residency_limited: bool,
    autoscaled: bool,
) -> Summary {
    let makespan_ms = acc.makespan_ms;
    // percentiles come from the streamed histogram — same nearest-rank
    // definition as the old sort-the-Vec path, within the histogram's
    // documented relative error; the mean is exact (streamed sum/count,
    // folded in shard-index order, so it depends only on the shard merge
    // order — fixed — never on `jobs`)
    let mean_ms = acc.latency_stats.mean_ms();
    let p50_ms = acc.latency_stats.quantile(0.50);
    let p95_ms = acc.latency_stats.quantile(0.95);
    let p99_ms = acc.latency_stats.quantile(0.99);

    let mut per_variant = Vec::new();
    let mut total_batches = 0u64;
    let mut total_occupancy = 0u64;
    let mut acc_weighted = 0.0f64;
    let mut energy = 0.0f64;
    for (s, server) in fleet.servers.iter().enumerate() {
        for (v, prof) in server.variants.iter().enumerate() {
            let u = acc.usage[s][v];
            total_batches += u.batches;
            total_occupancy += u.occupancy;
            acc_weighted += u.completed as f64 * prof.acc_drop;
            energy += u.energy_mj;
            per_variant.push(VariantUsage {
                server: s,
                device: server.device.name.clone(),
                variant: prof.name.clone(),
                acc_drop: prof.acc_drop,
                completed: u.completed,
                batches: u.batches,
                mean_batch: if u.batches == 0 {
                    0.0
                } else {
                    u.occupancy as f64 / u.batches as f64
                },
                busy_ms: u.busy_ms,
                utilization: if makespan_ms > 0.0 { u.busy_ms / makespan_ms } else { 0.0 },
                energy_mj: u.energy_mj,
            });
        }
    }

    let rejected = acc.rejected_full + acc.rejected_noncompliant + acc.rejected_unavailable;
    // open loop: every attempt is final, so the old identity
    // `generated = completed + rejected + expired` still derives the
    // census; closed loop counts attempts separately from fresh arrivals
    let generated = acc.completed + acc.dropped_final + acc.expired_final;
    let tenants: Vec<TenantSummary> = if cfg.multi_tenant() {
        cfg.tenants
            .iter()
            .zip(&acc.tenants)
            .map(|(t, a)| TenantSummary {
                name: t.name.clone(),
                dmax: t.dmax,
                slo_ms: t.slo_ms,
                weight: t.weight,
                generated: a.generated,
                completed: a.completed,
                dropped_final: a.dropped_final,
                expired_final: a.expired_final,
                retries: a.retries,
                slo_attained: a.slo_attained,
                latency: a.latency.clone(),
            })
            .collect()
    } else {
        Vec::new()
    };
    Summary {
        model: fleet.model.clone(),
        policy: cfg.policy.name(),
        slo_ms: cfg.slo_ms,
        delta_max: cfg.delta_max,
        generated,
        completed: acc.completed,
        rejected,
        rejected_noncompliant: acc.rejected_noncompliant,
        rejected_unavailable: acc.rejected_unavailable,
        expired: acc.expired,
        expired_during_swap: acc.expired_during_swap,
        swaps: acc.swaps,
        swap_ms: acc.swap_ms,
        swap_energy_mj: acc.swap_energy_mj,
        residency_limited,
        autoscaled,
        scale_ups: acc.scale_ups,
        scale_downs: acc.scale_downs,
        wake_ms: acc.wake_ms,
        wake_energy_mj: acc.wake_energy_mj,
        mean_reaction_ms: if acc.scale_ups == 0 {
            0.0
        } else {
            acc.reaction_sum_ms / acc.scale_ups as f64
        },
        predictive: autoscaled && cfg.autoscale.policy == ScalePolicy::Predictive,
        prewakes: acc.prewakes,
        prefetch_swaps: acc.prefetch_swaps,
        reselect_swaps: acc.reselect_swaps,
        forecast_abs_err_pct: if acc.forecast_err_samples == 0 {
            0.0
        } else {
            acc.forecast_err_sum_pct / acc.forecast_err_samples as f64
        },
        idle_energy_mj: acc.idle_energy_mj,
        closed_loop: cfg.closed_loop(),
        retries: acc.retries,
        dropped_final: acc.dropped_final,
        expired_final: acc.expired_final,
        admit: cfg.admit.name(),
        tenants,
        slo_attained: acc.slo_attained,
        mean_ms,
        p50_ms,
        p95_ms,
        p99_ms,
        latency_hist: acc.latency_stats,
        peak_queue_depth: acc.peak_queue_depth,
        makespan_ms,
        events: acc.events,
        throughput_rps: if makespan_ms > 0.0 {
            acc.completed as f64 / (makespan_ms / 1e3)
        } else {
            0.0
        },
        mean_batch: if total_batches == 0 {
            0.0
        } else {
            total_occupancy as f64 / total_batches as f64
        },
        acc_mix: if acc.completed == 0 {
            0.0
        } else {
            acc_weighted / acc.completed as f64
        },
        // serving energy plus the wake and hot-swap windows' E = P·L and
        // the idle-power term (each zero when its machinery is off,
        // keeping fixed-fleet / no-swap / zero-idle totals bit-exact)
        energy_mj: energy + acc.wake_energy_mj + acc.swap_energy_mj + acc.idle_energy_mj,
        per_variant,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hwsim::Device;

    fn var(name: &str, acc_drop: f64, b1: f64, b2: f64) -> VariantProfile {
        VariantProfile {
            name: name.into(),
            schedule: String::new(),
            acc_drop,
            weight_bytes: 10_000_000,
            batch_ms: vec![b1, b2],
            energy_mj: vec![b1 * 15.0, b2 * 15.0],
        }
    }

    fn one_server(v: Vec<VariantProfile>) -> Fleet {
        Fleet::single("toy", Device::xavier_nx(), v)
    }

    fn cfg() -> ServeConfig {
        ServeConfig {
            slo_ms: 100.0,
            delta_max: 0.015,
            policy: Policy::AccFastest,
            max_batch: 2,
            batch_timeout_ms: 5.0,
            queue_cap: 64,
            swap_init_ms: 5.0,
            link_mbps: f64::INFINITY,
            autoscale: AutoscaleConfig::off(),
            ..ServeConfig::default()
        }
    }

    #[test]
    fn full_batches_dispatch_immediately() {
        let fleet = one_server(vec![var("hqp", 0.012, 10.0, 16.0)]);
        let s = simulate_fleet(&fleet, &[0.0, 1.0, 2.0, 3.0], &cfg()).unwrap();
        // batch [0,1] launches at t=1 (full), completes 17; [2,3] at 17→33
        assert_eq!(s.generated, 4);
        assert_eq!(s.completed, 4);
        assert_eq!(s.rejected, 0);
        assert_eq!(s.expired, 0);
        assert_eq!(s.slo_attained, 4);
        assert_eq!(s.makespan_ms, 33.0);
        assert_eq!(s.mean_batch, 2.0);
        assert_eq!(s.per_variant[0].batches, 2);
        // latencies: 17, 16, 31, 30 — the exact nearest-rank p50 is 30.0
        // (pinned in stats::tests); the reported value is the histogram
        // bin midpoint, within the documented relative error of it
        assert!(
            (s.p50_ms - 30.0).abs() <= 30.0 * stats::LatencyStats::QUANTILE_REL_ERROR,
            "p50 {} strayed beyond the histogram error bound",
            s.p50_ms
        );
        // mean/max/count stay exact on the streamed path
        assert!((s.mean_ms - 23.5).abs() < 1e-12);
        assert_eq!(s.latency_hist.count(), 4);
        assert_eq!(s.latency_hist.max_ms(), 31.0);
    }

    #[test]
    fn partial_batch_waits_for_the_timeout() {
        let fleet = one_server(vec![var("hqp", 0.012, 10.0, 16.0)]);
        let s = simulate_fleet(&fleet, &[0.0], &cfg()).unwrap();
        // flush at 5, service 10 → completes 15
        assert_eq!(s.completed, 1);
        assert_eq!(s.makespan_ms, 15.0);
        assert!((s.mean_ms - 15.0).abs() < 1e-12);
        assert_eq!(s.per_variant[0].mean_batch, 1.0);
    }

    #[test]
    fn expiry_and_slo_misses_are_distinct() {
        let mut c = cfg();
        c.slo_ms = 3.0;
        c.batch_timeout_ms = 2.0;
        c.max_batch = 1;
        let fleet = one_server(vec![var("hqp", 0.012, 10.0, 16.0)]);
        // req0: dispatched at 0 (max_batch 1), completes at 10 > deadline 3
        //   → completed but SLO missed
        // req1 (t=1): queued while busy; at t=10 its deadline 4 < 10
        //   → expired, never served
        let s = simulate_fleet(&fleet, &[0.0, 1.0], &c).unwrap();
        assert_eq!(s.completed, 1);
        assert_eq!(s.expired, 1);
        assert_eq!(s.slo_attained, 0);
        assert_eq!(s.generated, 2);
    }

    #[test]
    fn queue_cap_rejects_at_admission() {
        let mut c = cfg();
        c.queue_cap = 2;
        c.max_batch = 2;
        let fleet = one_server(vec![var("hqp", 0.012, 50.0, 80.0)]);
        // t=0,0,0,0: first two fill the queue (and dispatch), during the
        // long service the cap keeps further arrivals out
        let s = simulate_fleet(&fleet, &[0.0, 0.0, 0.0, 0.0, 0.0], &c).unwrap();
        assert!(s.rejected > 0);
        assert_eq!(s.generated, 5);
        assert_eq!(s.completed + s.rejected + s.expired, 5);
        // admission control bounds the backpressure telemetry
        assert_eq!(s.peak_queue_depth, 2, "peak queue depth must sit at the cap");
    }

    #[test]
    fn noncompliant_only_fleet_rejects_everything() {
        let fleet = one_server(vec![var("p50", 0.021, 1.0, 1.6)]);
        let s = simulate_fleet(&fleet, &[0.0, 1.0, 2.0], &cfg()).unwrap();
        assert_eq!(s.completed, 0);
        assert_eq!(s.rejected, 3);
        assert_eq!(s.rejected_noncompliant, 3);
        assert_eq!(s.slo_attainment(), 0.0);
    }

    #[test]
    fn same_inputs_reproduce_identical_summaries() {
        let fleet = reference_fleet(
            "resnet18",
            &[Device::xavier_nx()],
            &["baseline", "q8", "p50", "hqp"],
            8,
        )
        .unwrap();
        let arrivals = trace::generate(&ArrivalProcess::Poisson { rps: 300.0 }, 2_000.0, 42);
        let mut c = cfg();
        c.max_batch = 8;
        let a = simulate_fleet(&fleet, &arrivals, &c).unwrap();
        let b = simulate_fleet(&fleet, &arrivals, &c).unwrap();
        assert_eq!(a, b);
        assert_eq!(a.render(), b.render(), "rendered summary must be byte-identical");
        assert_eq!(a.generated, arrivals.len() as u64);
    }

    #[test]
    fn streamed_run_is_byte_identical_to_the_slice_run() {
        let fleet = reference_fleet(
            "resnet18",
            &[Device::xavier_nx()],
            &["baseline", "q8", "p50", "hqp"],
            8,
        )
        .unwrap();
        let p = ArrivalProcess::Poisson { rps: 300.0 };
        let arrivals = trace::generate(&p, 2_000.0, 42);
        let mut c = cfg();
        c.max_batch = 8;
        let sliced = simulate_fleet(&fleet, &arrivals, &c).unwrap();
        let streamed = simulate_fleet_stream(
            &fleet,
            trace::ArrivalGen::new(&p, 2_000.0, 42),
            &c,
            Jobs::one(),
        )
        .unwrap();
        assert_eq!(sliced, streamed, "streaming must not change a single byte");
        assert_eq!(sliced.render(), streamed.render());
        // the --requests form: an unbounded generator taken to the same
        // count reproduces the same run
        let n = arrivals.len();
        let taken = simulate_fleet_stream(
            &fleet,
            trace::ArrivalGen::new(&p, f64::INFINITY, 42).take(n),
            &c,
            Jobs::one(),
        )
        .unwrap();
        assert_eq!(sliced, taken);
        assert_eq!(taken.generated, n as u64);
    }

    #[test]
    fn streamed_arrivals_are_validated_on_the_fly() {
        let fleet = one_server(vec![var("hqp", 0.012, 10.0, 16.0)]);
        // a regressing trace must hard-error, not silently misorder
        let bad = [0.0, 5.0, 3.0];
        assert!(
            simulate_fleet_stream(&fleet, bad.iter().copied(), &cfg(), Jobs::one()).is_err(),
            "non-monotone stream must be rejected"
        );
        assert!(
            simulate_fleet_stream(&fleet, [-1.0].iter().copied(), &cfg(), Jobs::one()).is_err(),
            "negative arrival time must be rejected"
        );
        assert!(
            simulate_fleet_stream(&fleet, [f64::NAN].iter().copied(), &cfg(), Jobs::one())
                .is_err(),
            "NaN arrival time must be rejected"
        );
    }

    #[test]
    fn router_never_serves_noncompliant_variants() {
        let fleet = one_server(vec![
            var("baseline", 0.0, 8.0, 13.0),
            var("p50", 0.021, 0.5, 0.8),
            var("hqp", 0.012, 1.0, 1.6),
        ]);
        for policy in [Policy::RoundRobin, Policy::LeastLoaded, Policy::AccFastest] {
            let mut c = cfg();
            c.policy = policy;
            let arrivals: Vec<f64> = (0..200).map(|i| i as f64 * 0.9).collect();
            let s = simulate_fleet(&fleet, &arrivals, &c).unwrap();
            for u in &s.per_variant {
                if u.completed > 0 || u.batches > 0 {
                    assert!(
                        u.acc_drop <= c.delta_max,
                        "{policy:?} served non-compliant {}",
                        u.variant
                    );
                }
            }
            assert!(s.completed > 0);
        }
    }

    #[test]
    fn config_validation() {
        let fleet = one_server(vec![var("hqp", 0.012, 1.0, 1.6)]);
        let mut c = cfg();
        c.max_batch = 4; // profiles only go to 2
        assert!(simulate_fleet(&fleet, &[0.0], &c).is_err());
        let mut c = cfg();
        c.slo_ms = 0.0;
        assert!(simulate_fleet(&fleet, &[0.0], &c).is_err());
        let mut c = cfg();
        c.swap_init_ms = -1.0;
        assert!(simulate_fleet(&fleet, &[0.0], &c).is_err());
        let mut c = cfg();
        c.link_mbps = 0.0;
        assert!(simulate_fleet(&fleet, &[0.0], &c).is_err());
        let empty = Fleet { model: "m".into(), servers: vec![] };
        assert!(simulate_fleet(&empty, &[0.0], &cfg()).is_err());
    }

    #[test]
    fn unlimited_memory_reports_no_swap_machinery() {
        let fleet = one_server(vec![var("hqp", 0.012, 10.0, 16.0)]);
        for policy in Policy::ALL {
            let mut c = cfg();
            c.policy = policy;
            let s = simulate_fleet(&fleet, &[0.0, 1.0, 2.0], &c).unwrap();
            assert_eq!(s.swaps, 0);
            assert_eq!(s.swap_ms, 0.0);
            assert_eq!(s.swap_energy_mj, 0.0, "no swap, no E = P·L charge");
            assert!(!s.render().contains("ms swapping, "), "no-swap render unchanged");
            assert_eq!(s.expired_during_swap, 0);
            assert_eq!(s.rejected_unavailable, 0);
            assert!(!s.residency_limited);
            // static-policy renders must stay byte-compatible with the
            // pre-residency simulator: no swap line at all
            if policy != Policy::SwapAware {
                assert!(!s.render().contains("swaps    :"), "{policy:?}");
            } else {
                assert!(s.render().contains("swaps    :"));
            }
        }
    }

    #[test]
    fn swap_aware_matches_acc_fastest_when_everything_is_resident() {
        let fleet = one_server(vec![
            var("baseline", 0.0, 8.0, 13.0),
            var("hqp", 0.012, 1.0, 1.6),
        ]);
        let arrivals: Vec<f64> = (0..100).map(|i| i as f64 * 0.7).collect();
        let mut ca = cfg();
        ca.policy = Policy::AccFastest;
        let mut cs = cfg();
        cs.policy = Policy::SwapAware;
        let a = simulate_fleet(&fleet, &arrivals, &ca).unwrap();
        let s = simulate_fleet(&fleet, &arrivals, &cs).unwrap();
        assert_eq!(s.swaps, 0, "nothing to swap in: all variants resident");
        assert_eq!((a.completed, a.expired, a.rejected), (s.completed, s.expired, s.rejected));
        assert_eq!(a.slo_attained, s.slo_attained);
        assert_eq!(a.p99_ms, s.p99_ms);
        assert_eq!(a.per_variant.len(), s.per_variant.len());
    }

    #[test]
    fn capped_memory_keeps_static_policies_on_the_resident_set() {
        // slow fp32 resident, fast hqp merely deployable
        let mut fleet = one_server(vec![
            var("fp32", 0.0, 10.0, 16.0),
            var("hqp", 0.012, 1.0, 1.6),
        ]);
        fleet.servers[0].variants[0].weight_bytes = 40_000_000;
        fleet.servers[0].variants[1].weight_bytes = 4_000_000;
        fleet.servers[0].mem_capacity_bytes = Some(41_000_000);
        assert_eq!(fleet.servers[0].initial_residency(), vec![true, false]);
        let arrivals: Vec<f64> = (0..40).map(|i| i as f64 * 2.0).collect();
        for policy in [Policy::RoundRobin, Policy::LeastLoaded, Policy::AccFastest] {
            let mut c = cfg();
            c.policy = policy;
            let s = simulate_fleet(&fleet, &arrivals, &c).unwrap();
            assert_eq!(s.swaps, 0, "{policy:?} must never swap");
            assert!(s.residency_limited);
            let hqp = s.per_variant.iter().find(|u| u.variant == "hqp").unwrap();
            assert_eq!(hqp.completed, 0, "{policy:?} served a non-resident variant");
            assert_eq!(hqp.batches, 0);
            assert!(s.completed > 0, "{policy:?} must still serve the resident one");
        }
    }

    #[test]
    fn swap_aware_hot_swaps_under_pressure_and_counts_it() {
        let mut fleet = one_server(vec![
            var("fp32", 0.0, 10.0, 16.0),
            var("hqp", 0.012, 1.0, 1.6),
        ]);
        fleet.servers[0].variants[0].weight_bytes = 40_000_000;
        fleet.servers[0].variants[1].weight_bytes = 4_000_000;
        fleet.servers[0].mem_capacity_bytes = Some(41_000_000);
        // overload the resident fp32 engine: 1 req/ms against ~0.1 req/ms
        let arrivals: Vec<f64> = (0..200).map(|i| i as f64).collect();
        let mut c = cfg();
        c.policy = Policy::SwapAware;
        let s = simulate_fleet(&fleet, &arrivals, &c).unwrap();
        assert_eq!(s.swaps, 1, "one swap to hqp, then stable");
        let expected_swap = Device::xavier_nx().swap_in_ms(4_000_000, c.swap_init_ms);
        assert!((s.swap_ms - expected_swap).abs() < 1e-9);
        // the swap window is charged E = P·L, folded into the total
        let expected_energy = Device::xavier_nx().power_w * expected_swap;
        assert!((s.swap_energy_mj - expected_energy).abs() < 1e-9);
        let usage: f64 = s.per_variant.iter().map(|u| u.energy_mj).sum();
        assert!((s.energy_mj - (usage + s.swap_energy_mj)).abs() < 1e-9);
        assert!(
            s.render().contains("ms swapping, "),
            "a charged swap must surface its energy in the render"
        );
        let fp32 = s.per_variant.iter().find(|u| u.variant == "fp32").unwrap();
        let hqp = s.per_variant.iter().find(|u| u.variant == "hqp").unwrap();
        assert!(fp32.completed > 0, "the resident engine serves before the swap");
        assert!(hqp.completed > fp32.completed, "post-swap hqp carries the load");
        assert_eq!(
            s.completed + s.rejected + s.expired,
            s.generated,
            "conservation holds across the swap"
        );
        assert!(s.render().contains("swaps    : 1"));
        // the swap-aware run must beat every static policy stuck on fp32
        for policy in [Policy::RoundRobin, Policy::LeastLoaded, Policy::AccFastest] {
            let mut cs = cfg();
            cs.policy = policy;
            let stat = simulate_fleet(&fleet, &arrivals, &cs).unwrap();
            assert!(
                s.slo_attainment() >= stat.slo_attainment(),
                "swap-aware {:.3} < {policy:?} {:.3}",
                s.slo_attainment(),
                stat.slo_attainment()
            );
        }
    }

    /// A two-NX fleet of one fast variant each, for autoscaling tests.
    fn two_server_fleet(b1: f64) -> Fleet {
        Fleet {
            model: "toy".into(),
            servers: vec![
                Server::new(Device::xavier_nx(), vec![var("hqp", 0.012, b1, b1 * 1.6)]),
                Server::new(Device::xavier_nx(), vec![var("hqp", 0.012, b1, b1 * 1.6)]),
            ],
        }
    }

    fn auto_cfg(policy: ScalePolicy, interval_ms: f64, min: usize, max: usize) -> ServeConfig {
        let mut c = cfg();
        c.autoscale = AutoscaleConfig {
            policy,
            interval_ms,
            min_active: min,
            max_active: max,
            ..AutoscaleConfig::off()
        };
        c
    }

    #[test]
    fn autoscale_off_is_byte_identical_whatever_the_knobs_say() {
        let fleet = two_server_fleet(10.0);
        let arrivals: Vec<f64> = (0..60).map(|i| i as f64 * 3.0).collect();
        let base = simulate_fleet(&fleet, &arrivals, &cfg()).unwrap();
        // off-but-weird knobs must be inert
        let mut weird = cfg();
        weird.autoscale =
            AutoscaleConfig { interval_ms: 7.0, min_active: 9, max_active: 1, queue_high: 0.0, ..AutoscaleConfig::off() };
        let same = simulate_fleet(&fleet, &arrivals, &weird).unwrap();
        assert_eq!(base, same, "an Off autoscale config must not perturb the simulation");
        assert_eq!(base.render(), same.render());
        assert!(!base.autoscaled);
        assert_eq!((base.scale_ups, base.scale_downs), (0, 0));
        assert_eq!(base.wake_ms, 0.0);
        assert_eq!(base.wake_energy_mj, 0.0);
        assert!(!base.render().contains("scale    :"), "no scale line on fixed fleets");
    }

    #[test]
    fn overload_wakes_the_second_server_and_charges_the_wake() {
        // one active server at 10 ms/req against 1 req/ms: queue-depth
        // pressure must wake server 1, which then carries load
        let fleet = two_server_fleet(10.0);
        let arrivals: Vec<f64> = (0..600).map(|i| i as f64).collect();
        let c = auto_cfg(ScalePolicy::QueueDepth, 20.0, 1, 2);
        let s = simulate_fleet(&fleet, &arrivals, &c).unwrap();
        assert!(s.autoscaled);
        assert!(s.scale_ups >= 1, "sustained overload must scale up");
        assert!(s.wake_ms > 0.0);
        assert!(s.wake_energy_mj > 0.0, "wake windows are charged E = P·L");
        // reaction covers at least the wake itself plus one interval of
        // detection hysteresis
        assert!(s.mean_reaction_ms >= s.wake_ms / s.scale_ups as f64);
        let s1: u64 = s.per_variant.iter().filter(|u| u.server == 1).map(|u| u.completed).sum();
        assert!(s1 > 0, "the woken server must serve traffic");
        assert_eq!(s.completed + s.rejected + s.expired, s.generated, "conservation");
        assert!(s.render().contains("scale    :"));
        // wake (and any swap) energy is part of the summary total
        let usage: f64 = s.per_variant.iter().map(|u| u.energy_mj).sum();
        assert!(
            (s.energy_mj - (usage + s.wake_energy_mj + s.swap_energy_mj)).abs() < 1e-9
        );
    }

    #[test]
    fn idle_fleet_drains_down_to_min_and_sleeping_servers_take_no_work() {
        // two active servers, trickle load one could serve alone: the
        // queue-depth controller must drain one (and only one: min = 1)
        let fleet = two_server_fleet(1.0);
        let arrivals: Vec<f64> = (0..100).map(|i| i as f64 * 20.0).collect();
        let c = auto_cfg(ScalePolicy::QueueDepth, 25.0, 1, 2);
        let s = simulate_fleet(&fleet, &arrivals, &c).unwrap();
        assert!(s.scale_downs >= 1, "idleness must drain a server");
        assert_eq!(s.completed, s.generated, "the drain must not lose requests");
        assert_eq!(s.expired, 0);
        assert_eq!(s.rejected, 0);
        // min bound: with only two servers and min 1, at most one drain
        // can be outstanding at a time; traffic keeps flowing throughout
        assert!(s.slo_attainment() > 0.9);
    }

    #[test]
    fn attainment_policy_scales_too() {
        let fleet = two_server_fleet(10.0);
        let arrivals: Vec<f64> = (0..600).map(|i| i as f64).collect();
        let mut c = auto_cfg(ScalePolicy::Attainment, 20.0, 1, 2);
        c.slo_ms = 25.0; // tight enough that a single saturated server misses
        let s = simulate_fleet(&fleet, &arrivals, &c).unwrap();
        assert!(s.scale_ups >= 1, "attainment collapse must wake capacity");
        assert_eq!(s.completed + s.rejected + s.expired, s.generated);
    }

    #[test]
    fn autoscaled_runs_are_deterministic() {
        let fleet = two_server_fleet(5.0);
        let arrivals = trace::generate(
            &ArrivalProcess::parse("mmpp", 400.0).unwrap(),
            2_000.0,
            9,
        );
        for policy in
            [ScalePolicy::QueueDepth, ScalePolicy::Attainment, ScalePolicy::Predictive]
        {
            let c = auto_cfg(policy, 50.0, 1, 2);
            let a = simulate_fleet(&fleet, &arrivals, &c).unwrap();
            let b = simulate_fleet(&fleet, &arrivals, &c).unwrap();
            assert_eq!(a, b, "{policy:?}");
            assert_eq!(a.render(), b.render(), "{policy:?}");
        }
    }

    #[test]
    fn predictive_scaling_is_jobs_invariant() {
        // the forecaster lives on the coordinator and consumes the trace
        // in arrival order, so its every prediction — and every prewake,
        // prefetch and reselect it drives — must be jobs-free
        let fleet = two_server_fleet(5.0);
        let arrivals = trace::generate(
            &ArrivalProcess::parse("mmpp", 300.0).unwrap(),
            4_000.0,
            11,
        );
        let c = auto_cfg(ScalePolicy::Predictive, 25.0, 1, 2);
        let seq = simulate_fleet(&fleet, &arrivals, &c).unwrap();
        assert!(seq.predictive);
        for jobs in [2usize, 4] {
            let par =
                simulate_fleet_jobs(&fleet, &arrivals, &c, Jobs::new(jobs).unwrap()).unwrap();
            assert_eq!(seq, par, "jobs={jobs} diverged under the predictive policy");
            assert_eq!(seq.render(), par.render());
        }
    }

    #[test]
    fn worker_count_is_invisible_in_the_summary() {
        // the determinism contract: jobs only picks the OS thread count,
        // never the event order — autoscaled multi-server runs included
        let fleet = two_server_fleet(5.0);
        let arrivals = trace::generate(
            &ArrivalProcess::parse("mmpp", 400.0).unwrap(),
            2_000.0,
            9,
        );
        let c = auto_cfg(ScalePolicy::QueueDepth, 50.0, 1, 2);
        let seq = simulate_fleet(&fleet, &arrivals, &c).unwrap();
        assert!(seq.events > 0, "the event counter must actually count");
        for jobs in [2usize, 4, 8] {
            let par =
                simulate_fleet_jobs(&fleet, &arrivals, &c, Jobs::new(jobs).unwrap()).unwrap();
            assert_eq!(seq, par, "jobs={jobs} diverged from sequential");
            assert_eq!(seq.render(), par.render(), "jobs={jobs} render diverged");
        }
    }

    #[test]
    fn autoscale_config_validation() {
        let fleet = two_server_fleet(5.0);
        let bad = |f: &dyn Fn(&mut ServeConfig)| {
            let mut c = auto_cfg(ScalePolicy::QueueDepth, 50.0, 1, 2);
            f(&mut c);
            simulate_fleet(&fleet, &[0.0], &c)
        };
        assert!(bad(&|c| c.autoscale.interval_ms = 0.0).is_err());
        assert!(bad(&|c| c.autoscale.interval_ms = f64::NAN).is_err());
        assert!(
            bad(&|c| c.autoscale.interval_ms = f64::INFINITY).is_err(),
            "an infinite interval would mean an 'enabled' controller that never ticks"
        );
        assert!(bad(&|c| c.autoscale.min_active = 0).is_err());
        assert!(bad(&|c| c.autoscale.min_active = 3).is_err(), "min above the fleet size");
        assert!(bad(&|c| {
            c.autoscale.min_active = 2;
            c.autoscale.max_active = 1;
        })
        .is_err());
        assert!(bad(&|c| {
            c.autoscale.queue_high = 1.0;
            c.autoscale.queue_low = 2.0;
        })
        .is_err());
        assert!(bad(&|_| {}).is_ok(), "the base autoscale config is valid");
    }

    #[test]
    fn predictive_knob_gating_is_validated() {
        let fleet = two_server_fleet(5.0);
        let mut c = cfg();
        c.forecast_horizon_ms = Some(100.0);
        assert!(
            simulate_fleet(&fleet, &[0.0], &c).is_err(),
            "a forecast horizon without --autoscale predictive must be loud"
        );
        let mut c = auto_cfg(ScalePolicy::QueueDepth, 50.0, 1, 2);
        c.forecast_horizon_ms = Some(100.0);
        assert!(
            simulate_fleet(&fleet, &[0.0], &c).is_err(),
            "reactive policies take no horizon either"
        );
        let mut c = cfg();
        c.scale_to_drain = true;
        assert!(
            simulate_fleet(&fleet, &[0.0], &c).is_err(),
            "drain-phase ticks without a controller to tick"
        );
        let mut c = cfg();
        c.idle_watts = -1.0;
        assert!(simulate_fleet(&fleet, &[0.0], &c).is_err());
        let mut c = cfg();
        c.idle_watts = f64::INFINITY;
        assert!(simulate_fleet(&fleet, &[0.0], &c).is_err());
        let mut c = auto_cfg(ScalePolicy::Predictive, 50.0, 1, 2);
        c.forecast_horizon_ms = Some(0.0);
        assert!(simulate_fleet(&fleet, &[0.0], &c).is_err(), "horizon must be positive");
        let mut c = auto_cfg(ScalePolicy::Predictive, 50.0, 1, 2);
        c.forecast_horizon_ms = Some(120.0);
        assert!(simulate_fleet(&fleet, &[0.0], &c).is_ok());
    }

    #[test]
    fn drain_phase_ticks_keep_scaling_after_the_last_arrival() {
        // regression for the PR-4 limit "the control plane stops at the
        // last arrival": a burst leaves a deep backlog behind, so the
        // queue never looks idle while arrivals flow — without drain-phase
        // ticks the controller can never scale down. With --scale-to-drain
        // the ticks continue while local events remain pending and the
        // post-trace idleness is finally observed.
        let fleet = two_server_fleet(20.0);
        let arrivals: Vec<f64> = (0..200).map(|i| i as f64).collect();
        let mut base = auto_cfg(ScalePolicy::QueueDepth, 4.0, 1, 2);
        base.slo_ms = 10_000.0; // keep the backlog alive instead of expiring it
        let mut drain = base.clone();
        drain.scale_to_drain = true;
        let b = simulate_fleet(&fleet, &arrivals, &base).unwrap();
        let d = simulate_fleet(&fleet, &arrivals, &drain).unwrap();
        assert_eq!(b.scale_downs, 0, "pre-drain ticks never see a quiet queue");
        assert!(
            d.scale_downs >= 1,
            "drain-phase ticks must observe the emptied queue and scale down"
        );
        assert!(d.scale_ups >= 1);
        assert_eq!(d.completed + d.rejected + d.expired, d.generated, "conservation");
        // the flag changes nothing upstream of the drain: the served
        // traffic itself is identical
        assert_eq!(b.completed, d.completed);
        assert_eq!(b.slo_attained, d.slo_attained);
        // and off stays byte-identical to the pre-flag behavior
        let again = simulate_fleet(&fleet, &arrivals, &base).unwrap();
        assert_eq!(b, again);
    }

    #[test]
    fn prewakes_react_faster_than_queue_depth_detection() {
        // the tentpole claim in miniature: on a bursty MMPP trace the
        // predictive policy starts wakes when the forecast crosses
        // committed capacity — its reaction time is the wake latency
        // alone, while queue-depth pays detection hysteresis (two
        // consecutive high ticks) on top of the same wake
        let fleet = two_server_fleet(5.0);
        let arrivals = trace::generate(
            &ArrivalProcess::parse("mmpp", 300.0).unwrap(),
            8_000.0,
            7,
        );
        let reactive = auto_cfg(ScalePolicy::QueueDepth, 25.0, 1, 2);
        let predictive = auto_cfg(ScalePolicy::Predictive, 25.0, 1, 2);
        let r = simulate_fleet(&fleet, &arrivals, &reactive).unwrap();
        let p = simulate_fleet(&fleet, &arrivals, &predictive).unwrap();
        assert!(r.scale_ups >= 1 && p.scale_ups >= 1, "both must wake capacity");
        assert!(!r.predictive && p.predictive);
        assert!(p.prewakes >= 1, "the forecaster must drive at least one prewake");
        assert!(p.render().contains("predict  :"));
        assert!(!r.render().contains("predict  :"), "reactive renders stay unchanged");
        assert!(
            p.mean_reaction_ms < r.mean_reaction_ms,
            "predictive reaction {:.1} ms must beat queue-depth {:.1} ms",
            p.mean_reaction_ms,
            r.mean_reaction_ms
        );
        assert_eq!(p.completed + p.rejected + p.expired, p.generated, "conservation");
    }

    #[test]
    fn idle_power_charges_the_powered_but_not_busy_window() {
        let fleet = one_server(vec![var("hqp", 0.012, 10.0, 16.0)]);
        let base = simulate_fleet(&fleet, &[0.0], &cfg()).unwrap();
        let mut c = cfg();
        c.idle_watts = 2.0;
        let s = simulate_fleet(&fleet, &[0.0], &c).unwrap();
        // flush at 5, service 10..15: powered 15 ms, busy 10 ms → 5 ms
        // idle at 2 W = 10 mJ, folded into the energy total
        assert!((s.idle_energy_mj - 10.0).abs() < 1e-9, "idle {} mJ", s.idle_energy_mj);
        assert!((s.energy_mj - (base.energy_mj + 10.0)).abs() < 1e-9);
        assert!(s.render().contains("idle     :"));
        // the zero default is inert to the byte — no phantom line, no
        // epsilon drift in the total
        let mut z = cfg();
        z.idle_watts = 0.0;
        let same = simulate_fleet(&fleet, &[0.0], &z).unwrap();
        assert_eq!(base, same);
        assert_eq!(base.render(), same.render());
        assert!(!base.render().contains("idle     :"));
    }

    #[test]
    fn retry_cap_exhaustion_is_a_final_drop() {
        // a fleet with no Δ_max-compliant variant can never admit: every
        // request burns its full retry budget at admission and is finally
        // dropped. No backoff draw can change these counts, so they pin
        // the cap semantics exactly: 3 attempts per request (1 fresh + 2
        // retries), every one rejected, the last one final.
        let fleet = one_server(vec![var("p50", 0.021, 1.0, 1.6)]);
        let mut c = cfg();
        c.retries = 2;
        c.retry_base_ms = 1.0;
        let s = simulate_fleet(&fleet, &[0.0, 1.0, 2.0], &c).unwrap();
        assert!(s.closed_loop);
        assert_eq!(s.generated, 3);
        assert_eq!(s.completed, 0);
        assert_eq!(s.rejected, 9, "3 requests x 3 attempts, all refused");
        assert_eq!(s.rejected_noncompliant, 9);
        assert_eq!(s.retries, 6, "every non-final refusal re-enters");
        assert_eq!(s.dropped_final, 3, "out of budget => finally dropped");
        assert_eq!(s.expired_final, 0);
        let r = s.render();
        assert!(r.contains("requests : 3 generated = 0 completed + 3 dropped + 0 expired"));
        assert!(r.contains("retries  : 6 re-entries   (9 rejections, 0 expiries before backoff)"));
    }

    #[test]
    fn rejected_request_reenters_after_backoff_and_completes() {
        // queue_cap 1 + three simultaneous arrivals: the third is refused
        // at t=0 while the queue is full, re-enters after backoff and
        // completes once capacity frees up. The latency clock restarts at
        // the re-entry (mean strictly below the measured-from-t0 value),
        // and the whole retry timeline is byte-identical at any --jobs.
        let fleet = one_server(vec![var("hqp", 0.012, 10.0, 16.0)]);
        let mut c = cfg();
        c.max_batch = 1;
        c.queue_cap = 1;
        c.slo_ms = 10_000.0;
        c.retries = 6;
        c.retry_base_ms = 30.0;
        let arrivals = [0.0, 0.0, 0.0];
        // open loop drops the third request outright
        let mut open = c.clone();
        open.retries = 0;
        let o = simulate_fleet(&fleet, &arrivals, &open).unwrap();
        assert_eq!((o.completed, o.rejected, o.dropped_final), (2, 1, 1));
        // closed loop recovers it
        let s = simulate_fleet(&fleet, &arrivals, &c).unwrap();
        assert!(s.closed_loop);
        assert_eq!(s.completed, 3, "the refused request must eventually serve");
        assert_eq!(s.dropped_final, 0);
        assert_eq!(s.expired, 0);
        assert_eq!(
            s.retries, s.rejected,
            "every refusal schedules exactly one re-entry here"
        );
        // latencies: 10 (head), 20 (queued) and <20 for the retried one —
        // measured from its *re-entry*. Measured from the original t=0 it
        // would be >= 30 and the mean >= 20, so this bound is the proof
        // the attempt's clock starts after the backoff expires.
        assert!(
            s.mean_ms < 20.0,
            "mean {} implies the retry latency clock did not restart",
            s.mean_ms
        );
        // backoff draws are a pure function of (seed, id, attempt): the
        // rerun and every worker count reproduce the same bytes
        let again = simulate_fleet(&fleet, &arrivals, &c).unwrap();
        assert_eq!(s, again);
        for jobs in [2usize, 4] {
            let par =
                simulate_fleet_jobs(&fleet, &arrivals, &c, Jobs::new(jobs).unwrap()).unwrap();
            assert_eq!(s, par, "jobs={jobs} diverged on the closed-loop path");
            assert_eq!(s.render(), par.render());
        }
    }

    #[test]
    fn final_drain_expiries_are_terminal() {
        // the last barrier is the last chance to re-enter: an expiry
        // surfaced by the end-of-trace drain has no barrier left, so it
        // is final even with retry budget remaining
        let fleet = one_server(vec![var("hqp", 0.012, 15.0, 24.0)]);
        let mut c = cfg();
        c.max_batch = 1;
        c.slo_ms = 12.0;
        c.retries = 3;
        let s = simulate_fleet(&fleet, &[0.0, 1.0], &c).unwrap();
        // req0 serves 0..15 (SLO missed); req1's deadline 13 lapses while
        // queued and is only discovered at the t=15 dispatch — after the
        // final barrier
        assert_eq!(s.completed, 1);
        assert_eq!(s.expired, 1);
        assert_eq!(s.expired_final, 1, "no barrier left => terminal");
        assert_eq!(s.retries, 0, "a terminal expiry must not census a retry");
        assert_eq!(s.slo_attained, 0);
        assert_eq!(s.makespan_ms, 15.0);
        assert!(s.render().contains("retries  : 0 re-entries"));
    }

    #[test]
    fn expiry_feedback_reenters_at_a_later_barrier() {
        // same expiry shape, but a later arrival provides a barrier to
        // harvest the feedback at: the expired request re-enters exactly
        // once (whatever the backoff draw, the counters below hold on
        // both the served-late and expired-again branches)
        let fleet = one_server(vec![var("hqp", 0.012, 15.0, 24.0)]);
        let mut c = cfg();
        c.max_batch = 1;
        c.slo_ms = 12.0;
        c.retries = 3;
        let s = simulate_fleet(&fleet, &[0.0, 1.0, 30.0], &c).unwrap();
        assert!(s.closed_loop);
        assert_eq!(s.generated, 3);
        assert_eq!(s.retries, 1, "the queued expiry must re-enter via its barrier");
        assert!(s.expired >= 1);
        assert_eq!(s.dropped_final, 0);
        assert_eq!(
            s.completed + s.expired_final,
            3,
            "every request ends exactly once ({} completed, {} expired final)",
            s.completed,
            s.expired_final
        );
    }

    #[test]
    fn tenant_budgets_gate_admission_per_class() {
        // one variant at 1.2% drop; the strict tenant's Δ_max of 0 makes
        // it inadmissible for that class only — the lax class is served in
        // full. Per-tenant routing, per-tenant census, gated render.
        let fleet = one_server(vec![var("hqp", 0.012, 1.0, 1.6)]);
        let mut c = cfg();
        c.tenants = parse_tenants("strict:0.0:100:1,lax:0.015:100:1").unwrap();
        let arrivals: Vec<f64> = (0..40).map(|i| i as f64 * 5.0).collect();
        let s = simulate_fleet(&fleet, &arrivals, &c).unwrap();
        assert!(!s.closed_loop, "tenants do not imply retries");
        assert_eq!(s.tenants.len(), 2);
        let strict = &s.tenants[0];
        let lax = &s.tenants[1];
        assert_eq!(strict.name, "strict");
        assert!(strict.generated > 0 && lax.generated > 0);
        assert_eq!(strict.generated + lax.generated, 40);
        assert_eq!(strict.completed, 0, "no variant fits a 0% budget");
        assert_eq!(strict.dropped_final, strict.generated);
        assert_eq!(lax.completed, lax.generated, "the lax class must be unaffected");
        assert_eq!(lax.slo_attained, lax.completed);
        assert!((lax.attainment() - 1.0).abs() < 1e-12);
        assert_eq!(s.rejected_noncompliant, strict.generated);
        assert_eq!(s.slo_attained, lax.slo_attained);
        let r = s.render();
        assert!(r.contains("tenants  : 2 classes (admission fifo)"));
        assert!(r.contains("strict") && r.contains("lax"));
        assert!(!r.contains("retries  :"), "open loop must not grow a retry line");
    }

    #[test]
    fn finite_link_delays_admission_and_eats_slo_budget() {
        let fleet = one_server(vec![var("hqp", 0.012, 10.0, 16.0)]);
        let mut c = cfg();
        // 150528 input bytes at 1 Mbit/s ≈ 1204 ms per request
        c.link_mbps = 1.0;
        c.slo_ms = 100.0;
        let s = simulate_fleet(&fleet, &[0.0], &c).unwrap();
        assert_eq!(s.generated, 1);
        // the deadline (t=100) passes during the ~1204 ms transfer: the
        // request is admitted but expires before service
        assert_eq!(s.completed + s.expired, 1);
        assert_eq!(s.completed, 0, "transfer delay must count against the SLO");
        // a fat link is exactly the no-network model
        let mut fat = cfg();
        fat.link_mbps = f64::INFINITY;
        let a = simulate_fleet(&fleet, &[0.0, 1.0, 2.0], &fat).unwrap();
        let b = simulate_fleet(&fleet, &[0.0, 1.0, 2.0], &cfg()).unwrap();
        assert_eq!(a, b, "infinite link must be byte-identical to the default");
    }
}
