//! Trace-driven edge serving simulator with SLO-aware routing over HQP
//! variants — the deployment layer the paper's tables stop short of.
//!
//! The paper's single-inference roofline numbers (Tables I/II) say how
//! fast one request runs; this module says what that buys under *load*: a
//! fleet of [`crate::hwsim`] devices, each loaded with deployed HQP
//! variants ([`fleet::VariantProfile`] — the serving view of the
//! [`crate::hqp::deploy::MethodReport`] engines), replays a synthetic
//! request trace ([`trace`]) through an admission queue, a dynamic
//! batcher ([`batcher`]) and an SLO-aware router ([`router`]) that picks
//! device × variant per request subject to the paper's Δ_max accuracy
//! constraint.
//!
//! ## Design: a virtual-time event heap, not threads
//!
//! The simulator is deliberately single-threaded (the same documented
//! one-core constraint as [`crate::coordinator`]): a discrete-event loop
//! over a virtual-time min-heap. Service times come from the batched
//! roofline ([`crate::hwsim::simulate_batch`]), so no wall-clock time is
//! spent "serving" — a 10-minute trace simulates in milliseconds — and
//! every run is exactly reproducible: the same `(fleet, trace, config)`
//! triple produces a byte-identical [`Summary`]. That determinism is what
//! makes the event-loop conservation laws property-testable
//! (`tests/prop_serve.rs`).
//!
//! ## Request lifecycle
//!
//! Every generated request ends in exactly one of three states:
//!
//! * **rejected** — at admission: no Δ_max-compliant variant exists, or
//!   the routed server's queue is at capacity;
//! * **expired** — its SLO deadline passed while it waited in a queue
//!   (dropped at batch-formation time, never served);
//! * **completed** — served in a batch; it *attains* the SLO iff it
//!   finishes by `arrival + slo_ms`.
//!
//! See `rust/DESIGN.md` §Serving for the model's limits (no network cost,
//! open-loop arrivals, serial devices, linear activation scaling).

pub mod batcher;
pub mod fleet;
pub mod router;
pub mod trace;

pub use fleet::{fleet_for, reference_fleet, workspace_fleet, Fleet, Server, VariantProfile};
pub use router::{Candidate, Policy, Router};
pub use trace::ArrivalProcess;

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::error::{Error, Result};
use crate::report::Table;

use batcher::{Batcher, EnqueueAction, QueuedReq};

/// Serving-simulation parameters.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Per-request latency SLO, ms (deadline = arrival + slo).
    pub slo_ms: f64,
    /// Δ_max: the accuracy-drop budget the router must respect.
    pub delta_max: f64,
    pub policy: Policy,
    /// Dynamic batcher: max batch size…
    pub max_batch: usize,
    /// …and how long an idle device waits for a batch to fill, ms.
    pub batch_timeout_ms: f64,
    /// Admission cap on queued requests per server.
    pub queue_cap: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            slo_ms: 50.0,
            delta_max: 0.015,
            policy: Policy::AccFastest,
            max_batch: 8,
            batch_timeout_ms: 2.0,
            queue_cap: 256,
        }
    }
}

/// Per-(server, variant) serving statistics.
#[derive(Clone, Debug, PartialEq)]
pub struct VariantUsage {
    pub server: usize,
    pub device: String,
    pub variant: String,
    pub acc_drop: f64,
    pub completed: u64,
    pub batches: u64,
    pub mean_batch: f64,
    pub busy_ms: f64,
    /// busy_ms / makespan.
    pub utilization: f64,
    pub energy_mj: f64,
}

/// One simulation's results.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub model: String,
    pub policy: &'static str,
    pub slo_ms: f64,
    pub delta_max: f64,
    pub generated: u64,
    pub completed: u64,
    pub rejected: u64,
    /// Of the rejections: requests with no Δ_max-compliant variant.
    pub rejected_noncompliant: u64,
    pub expired: u64,
    /// Completed within their SLO deadline.
    pub slo_attained: u64,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    /// Virtual time of the last event.
    pub makespan_ms: f64,
    pub throughput_rps: f64,
    pub mean_batch: f64,
    /// Completion-weighted mean accuracy drop across served variants.
    pub acc_mix: f64,
    pub energy_mj: f64,
    pub per_variant: Vec<VariantUsage>,
}

impl Summary {
    /// SLO attainment over *offered* load (rejected and expired requests
    /// count against it — dropping traffic is not meeting its SLO).
    pub fn slo_attainment(&self) -> f64 {
        if self.generated == 0 {
            0.0
        } else {
            self.slo_attained as f64 / self.generated as f64
        }
    }

    /// Render the summary (the `hqp serve` output). Deterministic: equal
    /// summaries render byte-identically.
    pub fn render(&self) -> String {
        let mut s = format!(
            "serve summary — {} (policy {}, slo {:.1} ms, Δmax {:.2}%)\n",
            self.model,
            self.policy,
            self.slo_ms,
            self.delta_max * 100.0
        );
        s.push_str(&format!(
            "  requests : {} generated = {} completed + {} rejected + {} expired\n",
            self.generated, self.completed, self.rejected, self.expired
        ));
        s.push_str(&format!(
            "  slo      : {:.2}% attainment   throughput {:.1} rps   mean batch {:.2}\n",
            self.slo_attainment() * 100.0,
            self.throughput_rps,
            self.mean_batch
        ));
        s.push_str(&format!(
            "  latency  : p50 {:.3} ms   p95 {:.3} ms   p99 {:.3} ms   mean {:.3} ms\n",
            self.p50_ms, self.p95_ms, self.p99_ms, self.mean_ms
        ));
        s.push_str(&format!(
            "  quality  : completion-weighted acc drop {:.3}%   energy {:.1} mJ\n",
            self.acc_mix * 100.0,
            self.energy_mj
        ));
        let mut t = Table::new(vec![
            "Device",
            "Variant",
            "Acc Drop",
            "Completed",
            "Batches",
            "Mean Batch",
            "Util",
            "Energy (mJ)",
        ]);
        for u in &self.per_variant {
            t.row(vec![
                u.device.clone(),
                u.variant.clone(),
                format!("{:.2}%", u.acc_drop * 100.0),
                format!("{}", u.completed),
                format!("{}", u.batches),
                format!("{:.2}", u.mean_batch),
                format!("{:.1}%", u.utilization * 100.0),
                format!("{:.1}", u.energy_mj),
            ]);
        }
        s.push_str(&t.render());
        s
    }
}

// ---------------------------------------------------------------------------
// Event machinery
// ---------------------------------------------------------------------------

#[derive(Clone, Debug)]
enum EventKind {
    Arrival { req: usize },
    Flush { server: usize, variant: usize, token: u64 },
    BatchDone { server: usize, variant: usize, reqs: Vec<QueuedReq> },
}

/// Heap key: virtual time, ties broken by insertion sequence — a total
/// order, so the pop order (and therefore the whole simulation) is
/// deterministic.
#[derive(Clone, Debug)]
struct Event {
    time_ms: f64,
    seq: u64,
    kind: EventKind,
}

impl PartialEq for Event {
    fn eq(&self, other: &Event) -> bool {
        self.seq == other.seq
    }
}
impl Eq for Event {}
impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Event) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Event {
    fn cmp(&self, other: &Event) -> std::cmp::Ordering {
        self.time_ms
            .total_cmp(&other.time_ms)
            .then(self.seq.cmp(&other.seq))
    }
}

struct ServerState {
    batcher: Batcher,
    busy: bool,
    busy_until: f64,
}

#[derive(Clone, Copy, Debug, Default)]
struct UsageAcc {
    completed: u64,
    batches: u64,
    occupancy: u64,
    busy_ms: f64,
    energy_mj: f64,
}

#[derive(Default)]
struct Acc {
    completed: u64,
    rejected_full: u64,
    rejected_noncompliant: u64,
    expired: u64,
    slo_attained: u64,
    latencies: Vec<f64>,
    usage: Vec<Vec<UsageAcc>>,
}

/// Form and launch a batch on server `s` starting from variant `v`,
/// falling through to the variant whose head has waited longest when `v`
/// turns out empty (or fully expired). Leaves the server idle when no
/// servable request remains.
#[allow(clippy::too_many_arguments)]
fn try_dispatch(
    s: usize,
    mut v: usize,
    now: f64,
    st: &mut ServerState,
    server: &Server,
    heap: &mut BinaryHeap<Reverse<Event>>,
    seq: &mut u64,
    acc: &mut Acc,
) {
    loop {
        let taken = st.batcher.take_batch(v, now);
        acc.expired += taken.expired.len() as u64;
        if taken.reqs.is_empty() {
            match st.batcher.oldest_nonempty() {
                Some(next) => {
                    v = next;
                    continue;
                }
                None => {
                    st.busy = false;
                    return;
                }
            }
        }
        let b = taken.reqs.len();
        let prof = &server.variants[v];
        let service_ms = prof.batch_ms[b - 1];
        st.busy = true;
        st.busy_until = now + service_ms;
        let u = &mut acc.usage[s][v];
        u.batches += 1;
        u.occupancy += b as u64;
        u.busy_ms += service_ms;
        u.energy_mj += prof.energy_mj[b - 1];
        *seq += 1;
        heap.push(Reverse(Event {
            time_ms: st.busy_until,
            seq: *seq,
            kind: EventKind::BatchDone { server: s, variant: v, reqs: taken.reqs },
        }));
        return;
    }
}

/// Replay `arrivals` (sorted ms timestamps from [`trace::generate`])
/// against `fleet` under `cfg`. Virtual-time monotonicity is checked on
/// every event; a regression is an internal invariant violation and
/// errors out rather than silently producing garbage.
pub fn simulate_fleet(fleet: &Fleet, arrivals: &[f64], cfg: &ServeConfig) -> Result<Summary> {
    if fleet.servers.is_empty() {
        return Err(Error::hqp("serve: empty fleet"));
    }
    if cfg.max_batch == 0 {
        return Err(Error::hqp("serve: max_batch must be >= 1"));
    }
    if cfg.slo_ms <= 0.0 {
        return Err(Error::hqp("serve: slo_ms must be positive"));
    }
    if fleet.max_batch() < cfg.max_batch {
        return Err(Error::hqp(format!(
            "serve: fleet profiles support batches up to {}, config wants {}",
            fleet.max_batch(),
            cfg.max_batch
        )));
    }

    let mut router = Router::new(fleet, cfg.delta_max, cfg.policy);
    let mut state: Vec<ServerState> = fleet
        .servers
        .iter()
        .map(|srv| ServerState {
            batcher: Batcher::new(srv.variants.len(), cfg.max_batch, cfg.batch_timeout_ms),
            busy: false,
            busy_until: 0.0,
        })
        .collect();
    let mut acc = Acc {
        usage: fleet
            .servers
            .iter()
            .map(|srv| vec![UsageAcc::default(); srv.variants.len()])
            .collect(),
        ..Default::default()
    };

    let mut heap: BinaryHeap<Reverse<Event>> = BinaryHeap::with_capacity(arrivals.len() + 16);
    let mut seq: u64 = 0;
    for (i, &t) in arrivals.iter().enumerate() {
        seq += 1;
        heap.push(Reverse(Event { time_ms: t, seq, kind: EventKind::Arrival { req: i } }));
    }

    let mut backlog = vec![0.0f64; fleet.servers.len()];
    let mut last_time = f64::NEG_INFINITY;
    let mut makespan = 0.0f64;

    while let Some(Reverse(ev)) = heap.pop() {
        let now = ev.time_ms;
        if now < last_time {
            return Err(Error::hqp(format!(
                "serve: virtual time regressed from {last_time} to {now}"
            )));
        }
        last_time = now;
        makespan = now;

        match ev.kind {
            EventKind::Arrival { req } => {
                // router input: remaining busy time + queued work estimate
                for (s, st) in state.iter().enumerate() {
                    let mut est = if st.busy { (st.busy_until - now).max(0.0) } else { 0.0 };
                    for (v, prof) in fleet.servers[s].variants.iter().enumerate() {
                        est += st.batcher.backlog(v) as f64 * prof.batch1_ms();
                    }
                    backlog[s] = est;
                }
                let Some(c) = router.route(&backlog) else {
                    acc.rejected_noncompliant += 1;
                    continue;
                };
                let st = &mut state[c.server];
                if st.batcher.total() >= cfg.queue_cap {
                    acc.rejected_full += 1;
                    continue;
                }
                let qreq = QueuedReq {
                    id: req,
                    arrival_ms: now,
                    deadline_ms: now + cfg.slo_ms,
                };
                match st.batcher.enqueue(c.variant, qreq) {
                    EnqueueAction::BatchReady => {
                        if !st.busy {
                            try_dispatch(
                                c.server,
                                c.variant,
                                now,
                                st,
                                &fleet.servers[c.server],
                                &mut heap,
                                &mut seq,
                                &mut acc,
                            );
                        }
                    }
                    EnqueueAction::ArmFlush(token) => {
                        if !st.busy {
                            seq += 1;
                            heap.push(Reverse(Event {
                                time_ms: now + cfg.batch_timeout_ms,
                                seq,
                                kind: EventKind::Flush {
                                    server: c.server,
                                    variant: c.variant,
                                    token,
                                },
                            }));
                        }
                    }
                    EnqueueAction::Queued => {}
                }
            }
            EventKind::Flush { server, variant, token } => {
                let st = &mut state[server];
                if !st.busy && st.batcher.flush_live(variant, token) {
                    try_dispatch(
                        server,
                        variant,
                        now,
                        st,
                        &fleet.servers[server],
                        &mut heap,
                        &mut seq,
                        &mut acc,
                    );
                }
            }
            EventKind::BatchDone { server, variant, reqs } => {
                for r in &reqs {
                    acc.completed += 1;
                    acc.latencies.push(now - r.arrival_ms);
                    if now <= r.deadline_ms {
                        acc.slo_attained += 1;
                    }
                    acc.usage[server][variant].completed += 1;
                }
                let st = &mut state[server];
                st.busy = false;
                if let Some(next) = st.batcher.oldest_nonempty() {
                    try_dispatch(
                        server,
                        next,
                        now,
                        st,
                        &fleet.servers[server],
                        &mut heap,
                        &mut seq,
                        &mut acc,
                    );
                }
            }
        }
    }

    // every queue must have drained: the heap only empties once no flush
    // or batch-done event is pending anywhere
    debug_assert!(state.iter().all(|st| st.batcher.is_empty()));

    Ok(build_summary(fleet, cfg, acc, makespan))
}

fn build_summary(fleet: &Fleet, cfg: &ServeConfig, mut acc: Acc, makespan_ms: f64) -> Summary {
    acc.latencies.sort_by(f64::total_cmp);
    let n = acc.latencies.len();
    let pct = |p: f64| -> f64 {
        if n == 0 {
            0.0
        } else {
            acc.latencies[((n - 1) as f64 * p).round() as usize]
        }
    };
    let mean_ms = if n == 0 {
        0.0
    } else {
        acc.latencies.iter().sum::<f64>() / n as f64
    };

    let mut per_variant = Vec::new();
    let mut total_batches = 0u64;
    let mut total_occupancy = 0u64;
    let mut acc_weighted = 0.0f64;
    let mut energy = 0.0f64;
    for (s, server) in fleet.servers.iter().enumerate() {
        for (v, prof) in server.variants.iter().enumerate() {
            let u = acc.usage[s][v];
            total_batches += u.batches;
            total_occupancy += u.occupancy;
            acc_weighted += u.completed as f64 * prof.acc_drop;
            energy += u.energy_mj;
            per_variant.push(VariantUsage {
                server: s,
                device: server.device.name.clone(),
                variant: prof.name.clone(),
                acc_drop: prof.acc_drop,
                completed: u.completed,
                batches: u.batches,
                mean_batch: if u.batches == 0 {
                    0.0
                } else {
                    u.occupancy as f64 / u.batches as f64
                },
                busy_ms: u.busy_ms,
                utilization: if makespan_ms > 0.0 { u.busy_ms / makespan_ms } else { 0.0 },
                energy_mj: u.energy_mj,
            });
        }
    }

    let generated =
        acc.completed + acc.rejected_full + acc.rejected_noncompliant + acc.expired;
    Summary {
        model: fleet.model.clone(),
        policy: cfg.policy.name(),
        slo_ms: cfg.slo_ms,
        delta_max: cfg.delta_max,
        generated,
        completed: acc.completed,
        rejected: acc.rejected_full + acc.rejected_noncompliant,
        rejected_noncompliant: acc.rejected_noncompliant,
        expired: acc.expired,
        slo_attained: acc.slo_attained,
        mean_ms,
        p50_ms: pct(0.50),
        p95_ms: pct(0.95),
        p99_ms: pct(0.99),
        makespan_ms,
        throughput_rps: if makespan_ms > 0.0 {
            acc.completed as f64 / (makespan_ms / 1e3)
        } else {
            0.0
        },
        mean_batch: if total_batches == 0 {
            0.0
        } else {
            total_occupancy as f64 / total_batches as f64
        },
        acc_mix: if acc.completed == 0 {
            0.0
        } else {
            acc_weighted / acc.completed as f64
        },
        energy_mj: energy,
        per_variant,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hwsim::Device;

    fn var(name: &str, acc_drop: f64, b1: f64, b2: f64) -> VariantProfile {
        VariantProfile {
            name: name.into(),
            acc_drop,
            batch_ms: vec![b1, b2],
            energy_mj: vec![b1 * 15.0, b2 * 15.0],
        }
    }

    fn one_server(v: Vec<VariantProfile>) -> Fleet {
        Fleet::single("toy", Device::xavier_nx(), v)
    }

    fn cfg() -> ServeConfig {
        ServeConfig {
            slo_ms: 100.0,
            delta_max: 0.015,
            policy: Policy::AccFastest,
            max_batch: 2,
            batch_timeout_ms: 5.0,
            queue_cap: 64,
        }
    }

    #[test]
    fn full_batches_dispatch_immediately() {
        let fleet = one_server(vec![var("hqp", 0.012, 10.0, 16.0)]);
        let s = simulate_fleet(&fleet, &[0.0, 1.0, 2.0, 3.0], &cfg()).unwrap();
        // batch [0,1] launches at t=1 (full), completes 17; [2,3] at 17→33
        assert_eq!(s.generated, 4);
        assert_eq!(s.completed, 4);
        assert_eq!(s.rejected, 0);
        assert_eq!(s.expired, 0);
        assert_eq!(s.slo_attained, 4);
        assert_eq!(s.makespan_ms, 33.0);
        assert_eq!(s.mean_batch, 2.0);
        assert_eq!(s.per_variant[0].batches, 2);
        // latencies: 17, 16, 31, 30
        assert_eq!(s.p50_ms, 30.0);
        assert!((s.mean_ms - 23.5).abs() < 1e-12);
    }

    #[test]
    fn partial_batch_waits_for_the_timeout() {
        let fleet = one_server(vec![var("hqp", 0.012, 10.0, 16.0)]);
        let s = simulate_fleet(&fleet, &[0.0], &cfg()).unwrap();
        // flush at 5, service 10 → completes 15
        assert_eq!(s.completed, 1);
        assert_eq!(s.makespan_ms, 15.0);
        assert!((s.mean_ms - 15.0).abs() < 1e-12);
        assert_eq!(s.per_variant[0].mean_batch, 1.0);
    }

    #[test]
    fn expiry_and_slo_misses_are_distinct() {
        let mut c = cfg();
        c.slo_ms = 3.0;
        c.batch_timeout_ms = 2.0;
        c.max_batch = 1;
        let fleet = one_server(vec![var("hqp", 0.012, 10.0, 16.0)]);
        // req0: dispatched at 0 (max_batch 1), completes at 10 > deadline 3
        //   → completed but SLO missed
        // req1 (t=1): queued while busy; at t=10 its deadline 4 < 10
        //   → expired, never served
        let s = simulate_fleet(&fleet, &[0.0, 1.0], &c).unwrap();
        assert_eq!(s.completed, 1);
        assert_eq!(s.expired, 1);
        assert_eq!(s.slo_attained, 0);
        assert_eq!(s.generated, 2);
    }

    #[test]
    fn queue_cap_rejects_at_admission() {
        let mut c = cfg();
        c.queue_cap = 2;
        c.max_batch = 2;
        let fleet = one_server(vec![var("hqp", 0.012, 50.0, 80.0)]);
        // t=0,0,0,0: first two fill the queue (and dispatch), during the
        // long service the cap keeps further arrivals out
        let s = simulate_fleet(&fleet, &[0.0, 0.0, 0.0, 0.0, 0.0], &c).unwrap();
        assert!(s.rejected > 0);
        assert_eq!(s.generated, 5);
        assert_eq!(s.completed + s.rejected + s.expired, 5);
    }

    #[test]
    fn noncompliant_only_fleet_rejects_everything() {
        let fleet = one_server(vec![var("p50", 0.021, 1.0, 1.6)]);
        let s = simulate_fleet(&fleet, &[0.0, 1.0, 2.0], &cfg()).unwrap();
        assert_eq!(s.completed, 0);
        assert_eq!(s.rejected, 3);
        assert_eq!(s.rejected_noncompliant, 3);
        assert_eq!(s.slo_attainment(), 0.0);
    }

    #[test]
    fn same_inputs_reproduce_identical_summaries() {
        let fleet = reference_fleet(
            "resnet18",
            &[Device::xavier_nx()],
            &["baseline", "q8", "p50", "hqp"],
            8,
        )
        .unwrap();
        let arrivals = trace::generate(&ArrivalProcess::Poisson { rps: 300.0 }, 2_000.0, 42);
        let mut c = cfg();
        c.max_batch = 8;
        let a = simulate_fleet(&fleet, &arrivals, &c).unwrap();
        let b = simulate_fleet(&fleet, &arrivals, &c).unwrap();
        assert_eq!(a, b);
        assert_eq!(a.render(), b.render(), "rendered summary must be byte-identical");
        assert_eq!(a.generated, arrivals.len() as u64);
    }

    #[test]
    fn router_never_serves_noncompliant_variants() {
        let fleet = one_server(vec![
            var("baseline", 0.0, 8.0, 13.0),
            var("p50", 0.021, 0.5, 0.8),
            var("hqp", 0.012, 1.0, 1.6),
        ]);
        for policy in [Policy::RoundRobin, Policy::LeastLoaded, Policy::AccFastest] {
            let mut c = cfg();
            c.policy = policy;
            let arrivals: Vec<f64> = (0..200).map(|i| i as f64 * 0.9).collect();
            let s = simulate_fleet(&fleet, &arrivals, &c).unwrap();
            for u in &s.per_variant {
                if u.completed > 0 || u.batches > 0 {
                    assert!(
                        u.acc_drop <= c.delta_max,
                        "{policy:?} served non-compliant {}",
                        u.variant
                    );
                }
            }
            assert!(s.completed > 0);
        }
    }

    #[test]
    fn config_validation() {
        let fleet = one_server(vec![var("hqp", 0.012, 1.0, 1.6)]);
        let mut c = cfg();
        c.max_batch = 4; // profiles only go to 2
        assert!(simulate_fleet(&fleet, &[0.0], &c).is_err());
        let mut c = cfg();
        c.slo_ms = 0.0;
        assert!(simulate_fleet(&fleet, &[0.0], &c).is_err());
        let empty = Fleet { model: "m".into(), servers: vec![] };
        assert!(simulate_fleet(&empty, &[0.0], &cfg()).is_err());
    }
}
