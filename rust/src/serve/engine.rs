//! The sharded discrete-event engine behind [`super::simulate_fleet`].
//!
//! ## One event heap per server, barriers at the coupling points
//!
//! Every event in the simulator except arrivals and control ticks touches
//! exactly one server's state (its batcher, residency, lifecycle and
//! usage accumulators), so the global event heap of the original
//! single-threaded engine is sharded: each server owns a [`Shard`] with
//! its own min-heap of [`LocalEvent`]s and its own accumulator. The only
//! cross-shard coupling is at *global* events — an `Arrival` routes over
//! a whole-fleet snapshot, a `Control` tick reads whole-fleet signals —
//! so the coordinator walks the globally-ordered timeline of arrivals and
//! control ticks and, between consecutive global events, lets every shard
//! advance independently (in parallel when `jobs > 1`).
//!
//! ## The canonical order at a virtual time `T`
//!
//! 1. all shard-local events with `time < T` (the inter-barrier window —
//!    this is the parallel part);
//! 2. arrivals at `T`, in trace order (routing/admission/inline dispatch);
//! 3. shard-local events with `time == T`, in (shard index, local
//!    sequence) order;
//! 4. the control tick at `T`, with any `ScaleUp`/`DrainStart` decision
//!    executed inline;
//! 5. re-drain shard-local events at `T` (zero-duration wake chains,
//!    `DrainStart → ScaleDown`, swap starts planned at `T`).
//!
//! This order is *fixed*: the same algorithm runs for every `jobs` value,
//! and `jobs` only chooses how many OS threads advance shards in step 1.
//! Per-shard accumulators merge in shard-index order — u64 counts and
//! [`super::stats::LatencyStats`] histogram bins by integer addition, f64
//! sums in that same fold order — so the [`super::Summary`] is
//! byte-identical for jobs=1 and jobs=N (property-tested in
//! `tests/prop_serve.rs`).
//!
//! ## Streaming arrivals
//!
//! The coordinator never holds the trace: [`run_stream`] consumes any
//! `Iterator<Item = f64>` of non-decreasing arrival times through a
//! bounded [`Lookahead`] buffer ([`LOOKAHEAD_CAP`] slots), so resident
//! memory is O(fleet) + O(occupied histogram bins) — independent of the
//! request count. The timeline walk only ever needs the *next* arrival
//! (to pick the next barrier) and, once the source is exhausted, the
//! *last* arrival time (to bound the control-tick schedule), both of
//! which the buffer tracks; a materialized slice is just the
//! `iter().copied()` special case and produces byte-identical output.
//!
//! ## Closed-loop clients (`--retries`)
//!
//! With retries on, a rejected or expired request re-enters the arrival
//! stream after a seeded exponential backoff. Rejections are observed by
//! the coordinator directly; expiries surface inside shard-local windows
//! and flow back through a per-shard *retry outbox*, harvested at the
//! end of every barrier iteration in shard-index order. The backoff draw
//! is a pure function of `(retry_seed, id, attempt)` and every re-entry
//! is floored at its harvest barrier, so the retry timeline — a third
//! barrier source `tr` alongside arrivals `ta` and control ticks `tc` —
//! is identical at any `--jobs`. Memory stays O(fleet): the retry heap
//! holds only in-flight backoffs, never the trace.
//!
//! Relative to the old single-heap engine, only two tie-break orders
//! changed, both without observable effect on fixed-fleet runs: (a)
//! same-time local events on *different* servers now process in shard
//! order instead of creation order (their state is disjoint and their
//! accumulator updates commute), and (b) *all* same-time local events now
//! precede the control tick instead of splitting around it by creation
//! sequence (the controller is deliberately insensitive to sub-tick
//! ordering; autoscaling tests assert robust inequalities, not
//! tick-exact traces).

use std::cmp::Reverse;
use std::collections::{BinaryHeap, VecDeque};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex, MutexGuard};

use crate::error::{Error, Result};
use crate::testkit::prng::Prng;

use super::autoscale::{AutoscalePolicy, Lifecycle, ScaleDecision, ScalePolicy, SignalTracker};
use super::batcher::{Batcher, EnqueueAction, QueuedReq};
use super::fleet::{Fleet, Server};
use super::predict::{ForecastObs, Forecaster, PREDICT_CONFIDENCE_GATE, PREDICT_DOWN_FACTOR};
use super::router::{FleetView, Policy, Router, SwapPlan};
use super::stats::LatencyStats;
use super::tenant::{tenant_of, AdmitPolicy, TenantClass};
use super::ServeConfig;

/// Per-(server, variant) usage accumulator (merged into
/// [`super::VariantUsage`] by `build_summary`).
#[derive(Clone, Copy, Debug, Default)]
pub(crate) struct UsageAcc {
    pub(crate) completed: u64,
    pub(crate) batches: u64,
    pub(crate) occupancy: u64,
    pub(crate) busy_ms: f64,
    pub(crate) energy_mj: f64,
}

/// Per-tenant census: coordinator-side counts (generated, retries,
/// final drops) and shard-side counts (completions, attainment,
/// final expiries, latency) merged in shard-index order.
#[derive(Clone, Debug, Default)]
pub(crate) struct TenantTotals {
    pub(crate) generated: u64,
    pub(crate) completed: u64,
    pub(crate) dropped_final: u64,
    pub(crate) expired_final: u64,
    pub(crate) retries: u64,
    pub(crate) slo_attained: u64,
    pub(crate) latency: LatencyStats,
}

/// The merged run result `build_summary` consumes: per-shard accumulators
/// folded in shard-index order plus the coordinator's global counters.
#[derive(Default)]
pub(crate) struct Totals {
    pub(crate) completed: u64,
    pub(crate) rejected_full: u64,
    pub(crate) rejected_noncompliant: u64,
    pub(crate) rejected_unavailable: u64,
    pub(crate) expired: u64,
    pub(crate) expired_during_swap: u64,
    pub(crate) swaps: u64,
    pub(crate) swap_ms: f64,
    pub(crate) swap_energy_mj: f64,
    pub(crate) scale_ups: u64,
    pub(crate) scale_downs: u64,
    pub(crate) wake_ms: f64,
    pub(crate) wake_energy_mj: f64,
    /// Sum over scale-ups of (wake-done time − pressure-episode start).
    pub(crate) reaction_sum_ms: f64,
    pub(crate) slo_attained: u64,
    /// Streamed latency telemetry: shard histograms merged in shard-index
    /// order (constant-memory replacement for the old `Vec<f64>` + sort).
    pub(crate) latency_stats: LatencyStats,
    pub(crate) usage: Vec<Vec<UsageAcc>>,
    pub(crate) makespan_ms: f64,
    /// Events processed (arrivals + control ticks + scale decisions +
    /// every shard-local event) — the numerator of events/sec.
    pub(crate) events: u64,
    /// Max over servers of each batcher's queued-request high-water mark.
    pub(crate) peak_queue_depth: u64,
    /// Closed-loop retry re-entries (0 open-loop).
    pub(crate) retries: u64,
    /// Rejections with no retry budget left (== rejected sum open-loop).
    pub(crate) dropped_final: u64,
    /// Expiries with no retry budget left (== expired open-loop).
    pub(crate) expired_final: u64,
    /// Per-tenant census, indexed like `ServeConfig::effective_tenants`.
    pub(crate) tenants: Vec<TenantTotals>,
    /// Forecast-driven pre-wakes (a subset of `scale_ups`; 0 unless the
    /// `predictive` autoscale policy ran).
    pub(crate) prewakes: u64,
    /// Forecast-driven prefetch hot-swaps (a subset of `swaps`).
    pub(crate) prefetch_swaps: u64,
    /// Forecast-driven downshift re-selections (a subset of `swaps`).
    pub(crate) reselect_swaps: u64,
    /// Sum of matured |forecast − realized| rate errors, percent, and the
    /// sample count (`build_summary` takes the mean).
    pub(crate) forecast_err_sum_pct: f64,
    pub(crate) forecast_err_samples: u64,
    /// Idle-power energy: `ServeConfig::idle_watts` × powered-but-idle
    /// virtual ms, mJ. Exactly 0 at the knob's 0 default.
    pub(crate) idle_energy_mj: f64,
}

// ---------------------------------------------------------------------------
// Shard-local events
// ---------------------------------------------------------------------------

#[derive(Clone, Debug)]
enum LocalKind {
    Flush { variant: usize, token: u64 },
    BatchDone { variant: usize, reqs: Vec<QueuedReq> },
    /// Begin the server's pending hot-swap (re-arms itself while a batch
    /// is still running).
    SwapStart,
    /// The swapped-in engine is ready: mark it resident and resume
    /// dispatch. `started_ms` is when the swap began, so expiry during
    /// the swap window can be attributed precisely.
    SwapDone { load: usize, started_ms: f64 },
    /// The woken server's initial-residency engines are streamed in:
    /// mark it active and routable.
    WakeDone,
    /// A draining server's queue has fully drained: it goes to sleep.
    ScaleDown,
}

/// Heap key: virtual time, ties broken by per-shard insertion sequence —
/// a total order per shard, so each shard's pop order is deterministic
/// regardless of which worker thread advances it.
#[derive(Clone, Debug)]
struct LocalEvent {
    time_ms: f64,
    seq: u64,
    kind: LocalKind,
}

impl PartialEq for LocalEvent {
    fn eq(&self, other: &LocalEvent) -> bool {
        self.seq == other.seq
    }
}
impl Eq for LocalEvent {}
impl PartialOrd for LocalEvent {
    fn partial_cmp(&self, other: &LocalEvent) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for LocalEvent {
    fn cmp(&self, other: &LocalEvent) -> std::cmp::Ordering {
        self.time_ms
            .total_cmp(&other.time_ms)
            .then(self.seq.cmp(&other.seq))
    }
}

/// Per-shard accumulator: every count a shard-local handler can touch.
/// Merged into [`Totals`] in shard-index order.
#[derive(Default)]
struct ShardAcc {
    completed: u64,
    expired: u64,
    expired_during_swap: u64,
    /// Expiries whose request had no retry budget left (== `expired`
    /// open-loop; terminal leftovers of the final drain are counted by
    /// the coordinator instead).
    expired_final: u64,
    swaps: u64,
    swap_ms: f64,
    swap_energy_mj: f64,
    slo_attained: u64,
    latency_stats: LatencyStats,
    usage: Vec<UsageAcc>,
    /// Per-tenant shard-side census (completions, attainment, final
    /// expiries, latency), always sized to the effective tenant count.
    tenants: Vec<TenantTotals>,
}

/// One server's complete simulation state: batcher, swap/lifecycle flags,
/// residency, its own event heap and its own accumulator. Everything a
/// shard-local event touches lives here — the structural guarantee that
/// inter-barrier windows are data-race-free and order-independent across
/// shards.
struct Shard {
    batcher: Batcher,
    busy: bool,
    busy_until: f64,
    /// A hot-swap is in flight: the device serves nothing until
    /// `swap_until`.
    swapping: bool,
    swap_until: f64,
    /// A policy-approved swap waiting for the running batch to finish.
    pending_swap: Option<SwapPlan>,
    resident: Vec<bool>,
    lifecycle: Lifecycle,
    waking: bool,
    heap: BinaryHeap<Reverse<LocalEvent>>,
    seq: u64,
    /// Monotonicity floor: max of processed-event times and barrier times.
    last_time: f64,
    /// Max processed-event time (the shard's makespan contribution).
    max_time: f64,
    events: u64,
    acc: ShardAcc,
    /// Closed-loop feedback channel: expiries with retry budget left,
    /// as `(expiry time, request)`. Appended in this shard's (total)
    /// event order; the coordinator harvests it at every barrier in
    /// shard-index order, so the retry schedule is independent of how
    /// many worker threads advanced the window. Always empty open-loop.
    retry_outbox: Vec<(f64, QueuedReq)>,
    /// When the current powered (non-asleep) window opened, virtual ms —
    /// `None` while asleep. Idle-power accounting reads `powered_ms`
    /// minus busy/swap time; with `--idle-watts` at its 0 default the
    /// bookkeeping is inert.
    powered_since: Option<f64>,
    /// Closed powered windows, ms (the still-open one is closed at the
    /// global makespan by `run_stream`).
    powered_ms: f64,
}

impl Shard {
    fn new(srv: &Server, cfg: &ServeConfig, asleep: bool) -> Shard {
        let tenants = cfg.effective_tenants();
        let mut batcher = Batcher::new(srv.variants.len(), cfg.max_batch, cfg.batch_timeout_ms);
        if cfg.admit == AdmitPolicy::WeightedFair {
            batcher.set_weighted_fair(tenants.iter().map(|t| t.weight).collect());
        }
        Shard {
            batcher,
            busy: false,
            busy_until: 0.0,
            swapping: false,
            swap_until: 0.0,
            pending_swap: None,
            resident: srv.initial_residency(),
            lifecycle: if asleep { Lifecycle::Asleep } else { Lifecycle::Active },
            waking: false,
            heap: BinaryHeap::new(),
            seq: 0,
            last_time: f64::NEG_INFINITY,
            max_time: 0.0,
            events: 0,
            acc: ShardAcc {
                usage: vec![UsageAcc::default(); srv.variants.len()],
                tenants: vec![TenantTotals::default(); tenants.len()],
                ..ShardAcc::default()
            },
            retry_outbox: Vec::new(),
            powered_since: if asleep { None } else { Some(0.0) },
            powered_ms: 0.0,
        }
    }

    /// Census one queued-past-deadline request: the attempt always counts
    /// as `expired`; with retry budget left it enters the retry outbox
    /// (the coordinator schedules the backoff re-entry), otherwise it is
    /// final for this tenant.
    fn expire(&mut self, req: QueuedReq, now: f64, cfg: &ServeConfig) {
        self.acc.expired += 1;
        if (req.attempt as usize) < cfg.retries {
            self.retry_outbox.push((now, req));
        } else {
            self.acc.expired_final += 1;
            self.acc.tenants[req.tenant as usize].expired_final += 1;
        }
    }

    fn push(&mut self, time_ms: f64, kind: LocalKind) {
        self.seq += 1;
        self.heap.push(Reverse(LocalEvent { time_ms, seq: self.seq, kind }));
    }

    /// Can this server start a batch right now?
    fn can_dispatch(&self) -> bool {
        !self.busy && !self.swapping && self.pending_swap.is_none()
    }

    /// Is this server fully quiescent (no batch, no swap, nothing
    /// queued)? The condition a draining server must reach before it may
    /// sleep.
    fn quiesced(&self) -> bool {
        !self.busy && !self.swapping && self.pending_swap.is_none() && self.batcher.is_empty()
    }

    /// Single place drain completion is decided: if this server is
    /// draining and fully quiescent, schedule its `ScaleDown` now.
    fn sleep_if_drained(&mut self, now: f64) {
        if self.lifecycle == Lifecycle::Draining && self.quiesced() {
            self.push(now, LocalKind::ScaleDown);
        }
    }

    /// Form and launch a batch starting from variant `v`, falling through
    /// to the resident variant whose head has waited longest when `v`
    /// turns out empty (or fully expired, or non-resident). Leaves the
    /// server idle when no servable request remains. Only resident
    /// variants can form batches — the structural half of the "never
    /// serve a non-resident engine" invariant (the router enforces the
    /// other half at admission).
    fn try_dispatch(&mut self, mut v: usize, now: f64, server: &Server, cfg: &ServeConfig) {
        loop {
            if !self.resident[v] {
                match self.batcher.oldest_allowed(&self.resident) {
                    Some(next) => {
                        v = next;
                        continue;
                    }
                    None => {
                        self.busy = false;
                        return;
                    }
                }
            }
            let taken = self.batcher.take_batch(v, now);
            for r in taken.expired {
                self.expire(r, now, cfg);
            }
            if taken.reqs.is_empty() {
                match self.batcher.oldest_allowed(&self.resident) {
                    Some(next) => {
                        v = next;
                        continue;
                    }
                    None => {
                        self.busy = false;
                        return;
                    }
                }
            }
            let b = taken.reqs.len();
            let prof = &server.variants[v];
            let service_ms = prof.batch_ms[b - 1];
            self.busy = true;
            self.busy_until = now + service_ms;
            let u = &mut self.acc.usage[v];
            u.batches += 1;
            u.occupancy += b as u64;
            u.busy_ms += service_ms;
            u.energy_mj += prof.energy_mj[b - 1];
            self.push(self.busy_until, LocalKind::BatchDone { variant: v, reqs: taken.reqs });
            return;
        }
    }

    /// Pop and handle every local event with `time < until` (or `<=` when
    /// `inclusive`), including events scheduled inside the window.
    /// Virtual-time monotonicity is checked on every pop.
    fn advance(
        &mut self,
        server: &Server,
        cfg: &ServeConfig,
        until: f64,
        inclusive: bool,
    ) -> Result<()> {
        loop {
            let ready = match self.heap.peek() {
                Some(Reverse(ev)) => {
                    if inclusive {
                        ev.time_ms <= until
                    } else {
                        ev.time_ms < until
                    }
                }
                None => false,
            };
            if !ready {
                return Ok(());
            }
            let Reverse(ev) = self.heap.pop().expect("serve: peeked event vanished");
            let now = ev.time_ms;
            if now < self.last_time {
                return Err(Error::hqp(format!(
                    "serve: virtual time regressed from {} to {now}",
                    self.last_time
                )));
            }
            self.last_time = now;
            self.max_time = self.max_time.max(now);
            self.events += 1;
            self.handle(ev.kind, now, server, cfg)?;
        }
    }

    fn handle(
        &mut self,
        kind: LocalKind,
        now: f64,
        server: &Server,
        cfg: &ServeConfig,
    ) -> Result<()> {
        match kind {
            LocalKind::Flush { variant, token } => {
                if self.can_dispatch() && self.batcher.flush_live(variant, token) {
                    self.try_dispatch(variant, now, server, cfg);
                }
            }
            LocalKind::BatchDone { variant, reqs } => {
                for r in &reqs {
                    self.acc.completed += 1;
                    self.acc.latency_stats.record(now - r.arrival_ms);
                    let ten = &mut self.acc.tenants[r.tenant as usize];
                    ten.completed += 1;
                    ten.latency.record(now - r.arrival_ms);
                    if now <= r.deadline_ms {
                        self.acc.slo_attained += 1;
                        ten.slo_attained += 1;
                    }
                    self.acc.usage[variant].completed += 1;
                }
                self.busy = false;
                // a pending swap takes the idle slot: SwapStart is queued
                // at this very timestamp
                if self.pending_swap.is_none() {
                    if let Some(next) = self.batcher.oldest_allowed(&self.resident) {
                        self.try_dispatch(next, now, server, cfg);
                    }
                }
                // a draining server whose queue just emptied goes to sleep
                self.sleep_if_drained(now);
            }
            LocalKind::SwapStart => {
                if self.busy {
                    // a batch is still running (time tie): retry the
                    // moment it completes
                    self.push(self.busy_until, LocalKind::SwapStart);
                } else if let Some(plan) = self.pending_swap.take() {
                    if self.resident[plan.load] {
                        return Err(Error::hqp(
                            "serve: swap plan loads an already-resident variant",
                        ));
                    }
                    // evict: mark non-resident and drain the queues
                    let mut displaced: Vec<QueuedReq> = Vec::new();
                    for &e in &plan.evict {
                        if !self.resident[e] {
                            return Err(Error::hqp(
                                "serve: swap plan evicts a non-resident variant",
                            ));
                        }
                        self.resident[e] = false;
                        displaced.extend(self.batcher.drain(e));
                    }
                    let res_bytes: u64 = server
                        .variants
                        .iter()
                        .enumerate()
                        .filter(|(v, _)| self.resident[*v])
                        .map(|(_, p)| p.weight_bytes)
                        .sum();
                    if let Some(cap) = server.mem_capacity_bytes {
                        if res_bytes + server.variants[plan.load].weight_bytes > cap {
                            return Err(Error::hqp(
                                "serve: swap plan exceeds device memory capacity",
                            ));
                        }
                    }
                    // displaced survivors follow the best remaining
                    // compliant engine, else the incoming one
                    if !displaced.is_empty() {
                        let mut target = plan.load;
                        let mut best = f64::INFINITY;
                        for (v, p) in server.variants.iter().enumerate() {
                            if self.resident[v]
                                && p.compliant(cfg.delta_max)
                                && p.batch1_ms() < best
                            {
                                best = p.batch1_ms();
                                target = v;
                            }
                        }
                        let mut alive = Vec::with_capacity(displaced.len());
                        for r in displaced {
                            if r.deadline_ms < now {
                                // lapsed before the swap even began: plain
                                // expiry, the eviction only surfaced it
                                self.expire(r, now, cfg);
                            } else {
                                alive.push(r);
                            }
                        }
                        self.batcher.requeue(target, alive);
                    }
                    let swap_ms = server.swap_in_ms(plan.load, cfg.swap_init_ms);
                    self.swapping = true;
                    self.swap_until = now + swap_ms;
                    self.acc.swaps += 1;
                    self.acc.swap_ms += swap_ms;
                    // the swap window is charged energy E = P·L exactly
                    // like a wake window (W × ms = mJ); zero when no swap
                    // happens, so no-swap summaries stay byte-identical
                    self.acc.swap_energy_mj += server.device.power_w * swap_ms;
                    self.push(
                        self.swap_until,
                        LocalKind::SwapDone { load: plan.load, started_ms: now },
                    );
                }
            }
            LocalKind::SwapDone { load, started_ms } => {
                self.swapping = false;
                self.resident[load] = true;
                // drop lapsed deadlines; only those that lapsed during the
                // swap window are attributed to the swap (earlier ones
                // would have expired at the next batch formation anyway)
                for r in self.batcher.purge_expired(now) {
                    if r.deadline_ms >= started_ms {
                        self.acc.expired_during_swap += 1;
                    }
                    self.expire(r, now, cfg);
                }
                // the survivors have outwaited any batching timeout:
                // dispatch immediately
                if self.can_dispatch() {
                    if let Some(next) = self.batcher.oldest_allowed(&self.resident) {
                        self.try_dispatch(next, now, server, cfg);
                    }
                }
                // a drain that was waiting on this swap can now complete
                self.sleep_if_drained(now);
            }
            LocalKind::WakeDone => {
                if self.lifecycle != Lifecycle::Asleep || !self.waking {
                    return Err(Error::hqp(
                        "serve: wake completion for a server that was not waking",
                    ));
                }
                self.waking = false;
                self.lifecycle = Lifecycle::Active;
                // powered from here on (the wake window itself is already
                // charged at full power as wake energy, never as idle)
                self.powered_since = Some(now);
                // the wake streamed exactly the initial resident set — any
                // residency the server had accumulated before sleeping is
                // gone (its queue was empty, so nothing can strand)
                self.resident = server.initial_residency();
            }
            LocalKind::ScaleDown => {
                if self.lifecycle != Lifecycle::Draining {
                    return Err(Error::hqp(
                        "serve: scale-down for a server that is not draining",
                    ));
                }
                if !self.quiesced() {
                    return Err(Error::hqp("serve: scale-down on a non-quiescent server"));
                }
                self.lifecycle = Lifecycle::Asleep;
                if let Some(t0) = self.powered_since.take() {
                    self.powered_ms += now - t0;
                }
            }
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// The worker gang (jobs > 1)
// ---------------------------------------------------------------------------

fn lock_shard(m: &Mutex<Shard>) -> MutexGuard<'_, Shard> {
    // A poisoned mutex means a worker panicked mid-window; the panic is
    // already recorded as a hard error and the coordinator aborts right
    // after the window, so the torn state never reaches output.
    m.lock().unwrap_or_else(|p| p.into_inner())
}

fn record_error(errors: &Mutex<Vec<(usize, Error)>>, shard: usize, e: Error) {
    errors.lock().unwrap_or_else(|p| p.into_inner()).push((shard, e));
}

fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "<non-string panic payload>".to_string()
    }
}

#[derive(Clone, Copy)]
struct GangState {
    epoch: u64,
    until: f64,
    inclusive: bool,
    /// Spawned workers still running the current epoch.
    remaining: usize,
    shutdown: bool,
}

/// A persistent gang of workers that advances shards through one
/// inter-barrier window per epoch. The gang lives for the whole
/// simulation (one `Condvar` round-trip per window instead of a thread
/// spawn), and the coordinator thread participates in every window.
struct Gang {
    state: Mutex<GangState>,
    go: Condvar,
    done: Condvar,
    /// Shard-claim cursor, reset each epoch.
    next: AtomicUsize,
}

impl Gang {
    fn new() -> Gang {
        Gang {
            state: Mutex::new(GangState {
                epoch: 0,
                until: 0.0,
                inclusive: false,
                remaining: 0,
                shutdown: false,
            }),
            go: Condvar::new(),
            done: Condvar::new(),
            next: AtomicUsize::new(0),
        }
    }

    fn lock_state(&self) -> MutexGuard<'_, GangState> {
        self.state.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Claim shards off the shared cursor and advance each through the
    /// window. Panics are caught and recorded as hard errors; every
    /// claimed shard is still visited, so the error set (and therefore
    /// the lowest-indexed error the coordinator reports) is
    /// deterministic.
    fn claim_and_advance(
        &self,
        shards: &[Mutex<Shard>],
        fleet: &Fleet,
        cfg: &ServeConfig,
        errors: &Mutex<Vec<(usize, Error)>>,
        until: f64,
        inclusive: bool,
    ) {
        loop {
            let i = self.next.fetch_add(1, Ordering::Relaxed);
            if i >= shards.len() {
                return;
            }
            let outcome = catch_unwind(AssertUnwindSafe(|| {
                lock_shard(&shards[i]).advance(&fleet.servers[i], cfg, until, inclusive)
            }));
            match outcome {
                Ok(Ok(())) => {}
                Ok(Err(e)) => record_error(errors, i, e),
                Err(payload) => record_error(
                    errors,
                    i,
                    Error::hqp(format!(
                        "serve: shard {i} worker panicked: {}",
                        panic_message(payload)
                    )),
                ),
            }
        }
    }

    /// Worker thread body: wait for an epoch, run the window, report done.
    fn worker(
        &self,
        shards: &[Mutex<Shard>],
        fleet: &Fleet,
        cfg: &ServeConfig,
        errors: &Mutex<Vec<(usize, Error)>>,
    ) {
        let mut seen = 0u64;
        loop {
            let (until, inclusive) = {
                let mut st = self.lock_state();
                loop {
                    if st.shutdown {
                        return;
                    }
                    if st.epoch != seen {
                        break;
                    }
                    st = self.go.wait(st).unwrap_or_else(|p| p.into_inner());
                }
                seen = st.epoch;
                (st.until, st.inclusive)
            };
            self.claim_and_advance(shards, fleet, cfg, errors, until, inclusive);
            let mut st = self.lock_state();
            st.remaining -= 1;
            if st.remaining == 0 {
                self.done.notify_all();
            }
        }
    }

    /// Run one window across the gang: wake the workers, participate,
    /// wait for everyone.
    fn window(
        &self,
        shards: &[Mutex<Shard>],
        fleet: &Fleet,
        cfg: &ServeConfig,
        errors: &Mutex<Vec<(usize, Error)>>,
        spawned: usize,
        until: f64,
        inclusive: bool,
    ) {
        self.next.store(0, Ordering::Relaxed);
        {
            let mut st = self.lock_state();
            st.until = until;
            st.inclusive = inclusive;
            st.remaining = spawned;
            st.epoch += 1;
        }
        self.go.notify_all();
        self.claim_and_advance(shards, fleet, cfg, errors, until, inclusive);
        let mut st = self.lock_state();
        while st.remaining > 0 {
            st = self.done.wait(st).unwrap_or_else(|p| p.into_inner());
        }
    }

    fn shutdown(&self) {
        self.lock_state().shutdown = true;
        self.go.notify_all();
    }
}

// ---------------------------------------------------------------------------
// The bounded arrival lookahead
// ---------------------------------------------------------------------------

/// Slots the coordinator buffers ahead of the timeline. Any value ≥ 1 is
/// correct (the walk only ever *needs* the next arrival); a small batch
/// amortizes the per-pull bookkeeping without holding the trace.
const LOOKAHEAD_CAP: usize = 64;

/// Bounded buffer between an arrival iterator and the timeline walk: the
/// coordinator peeks the next origin time, pops arrivals as it schedules
/// them (assigning sequential request ids), and — once the source is
/// exhausted — reads the final arrival time that anchors the control-tick
/// schedule. Validates on the fly what the slice path validates up front:
/// every time must be finite, non-negative and non-decreasing.
struct Lookahead<I> {
    src: I,
    buf: VecDeque<f64>,
    /// Requests popped so far == the id of the next arrival to pop.
    issued: usize,
    /// Max origin time pulled from the source (end-of-trace anchor).
    last_ms: f64,
    exhausted: bool,
}

impl<I: Iterator<Item = f64>> Lookahead<I> {
    fn new(src: I) -> Lookahead<I> {
        Lookahead { src, buf: VecDeque::with_capacity(LOOKAHEAD_CAP), issued: 0, last_ms: 0.0, exhausted: false }
    }

    fn refill(&mut self) -> Result<()> {
        while !self.exhausted && self.buf.len() < LOOKAHEAD_CAP {
            match self.src.next() {
                None => self.exhausted = true,
                Some(t) => {
                    // `!(t >= floor)` rather than `t < floor`: NaN must
                    // fail too, and the floor starts at 0.0 so negative
                    // times are caught (mirrors the slice validation)
                    if !(t >= self.last_ms) || t == f64::INFINITY {
                        return Err(Error::hqp(format!(
                            "serve: arrival times must be finite, non-negative and \
                             non-decreasing (got {t} after {})",
                            self.last_ms
                        )));
                    }
                    self.last_ms = t;
                    self.buf.push_back(t);
                }
            }
        }
        Ok(())
    }

    /// Origin time of the next arrival, if any (refills the buffer).
    fn peek(&mut self) -> Result<Option<f64>> {
        self.refill()?;
        Ok(self.buf.front().copied())
    }

    /// Pop the next arrival as `(request id, origin time)`.
    fn pop(&mut self) -> Option<(usize, f64)> {
        let t = self.buf.pop_front()?;
        let id = self.issued;
        self.issued += 1;
        Some((id, t))
    }

    /// The final arrival's origin time — `None` until the source is
    /// exhausted and fully popped, or when the trace was empty.
    fn end(&self) -> Option<f64> {
        if self.exhausted && self.buf.is_empty() && self.issued > 0 {
            Some(self.last_ms)
        } else {
            None
        }
    }
}

// ---------------------------------------------------------------------------
// Closed-loop retries
// ---------------------------------------------------------------------------

/// One pending backoff re-entry. `origin_ms` is when the client re-sends
/// (the attempt's SLO clock starts here; it reaches the fleet
/// `transfer_ms` later, exactly like a fresh arrival).
#[derive(Clone, Copy, Debug)]
struct RetryEntry {
    origin_ms: f64,
    id: usize,
    tenant: u32,
    attempt: u32,
}

impl PartialEq for RetryEntry {
    fn eq(&self, other: &RetryEntry) -> bool {
        self.cmp(other) == std::cmp::Ordering::Equal
    }
}
impl Eq for RetryEntry {}
impl PartialOrd for RetryEntry {
    fn partial_cmp(&self, other: &RetryEntry) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for RetryEntry {
    /// Total order `(time, id, attempt)` — the heap pop order is
    /// deterministic whatever order entries were scheduled in.
    fn cmp(&self, other: &RetryEntry) -> std::cmp::Ordering {
        self.origin_ms
            .total_cmp(&other.origin_ms)
            .then(self.id.cmp(&other.id))
            .then(self.attempt.cmp(&other.attempt))
    }
}

/// The backoff before retry `attempt` (1-based) of request `id`: an
/// exponential draw with mean `retry_base_ms · 2^(attempt-1)`, from a
/// PRNG derived from `(retry_seed, id, attempt)` alone — a pure function
/// of the triple, so the draw is identical whatever barrier the failure
/// was harvested at and whatever `--jobs` advanced the window.
fn backoff_ms(cfg: &ServeConfig, id: usize, attempt: u32) -> f64 {
    let mix = cfg
        .retry_seed
        .wrapping_add((id as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15))
        .wrapping_add((attempt as u64).wrapping_mul(0xD1B5_4A32_D192_ED03));
    let mut rng = Prng::new(mix);
    let mean = cfg.retry_base_ms * f64::powi(2.0, attempt as i32 - 1);
    -mean * (1.0 - rng.next_f64()).ln()
}

// ---------------------------------------------------------------------------
// The coordinator: global timeline + barriers
// ---------------------------------------------------------------------------

#[derive(Default)]
struct GlobalAcc {
    rejected_full: u64,
    rejected_noncompliant: u64,
    rejected_unavailable: u64,
    scale_ups: u64,
    scale_downs: u64,
    wake_ms: f64,
    wake_energy_mj: f64,
    reaction_sum_ms: f64,
    /// Global events processed (arrivals, control ticks, scale decisions).
    events: u64,
    /// Max barrier time processed (makespan contribution).
    max_time: f64,
    /// Retry re-entries scheduled (rejections and harvested expiries).
    retries: u64,
    /// Rejections with no retry budget left.
    dropped_final: u64,
    /// Final-drain leftovers: retry-eligible expiries with no barrier
    /// left to re-enter at (shard-side final expiries are counted in
    /// `ShardAcc` instead).
    expired_final: u64,
    /// Coordinator-side per-tenant census (generated, retries, finals).
    tenants: Vec<TenantTotals>,
    /// Forecast-driven pre-wakes (read back from the policy at the end).
    prewakes: u64,
    /// Forecast-driven prefetch hot-swaps queued at control ticks.
    prefetch_swaps: u64,
    /// Forecast-driven downshift re-selections queued at control ticks.
    reselect_swaps: u64,
    /// Forecast-error accumulators (read back from the forecaster).
    forecast_err_sum_pct: f64,
    forecast_err_samples: u64,
}

struct Coordinator<'a> {
    fleet: &'a Fleet,
    cfg: &'a ServeConfig,
    shards: &'a [Mutex<Shard>],
    errors: &'a Mutex<Vec<(usize, Error)>>,
    gang: Option<&'a Gang>,
    spawned: usize,
    gacc: GlobalAcc,
    /// The effective tenant table (the configured classes, or one
    /// implicit default tenant carrying the global Δ_max/SLO).
    tenants: Vec<TenantClass>,
    /// Pending backoff re-entries, ordered by (time, id, attempt).
    retry_q: BinaryHeap<Reverse<RetryEntry>>,
    // reusable router/controller snapshot buffers
    backlog: Vec<f64>,
    queued: Vec<usize>,
    unavail: Vec<bool>,
    res_snap: Vec<Vec<bool>>,
}

impl<'a> Coordinator<'a> {
    fn new(
        fleet: &'a Fleet,
        cfg: &'a ServeConfig,
        shards: &'a [Mutex<Shard>],
        errors: &'a Mutex<Vec<(usize, Error)>>,
        gang: Option<&'a Gang>,
        spawned: usize,
    ) -> Coordinator<'a> {
        let n = fleet.servers.len();
        let tenants = cfg.effective_tenants();
        Coordinator {
            fleet,
            cfg,
            shards,
            errors,
            gang,
            spawned,
            gacc: GlobalAcc {
                tenants: vec![TenantTotals::default(); tenants.len()],
                ..GlobalAcc::default()
            },
            tenants,
            retry_q: BinaryHeap::new(),
            backlog: vec![0.0; n],
            queued: vec![0; n],
            unavail: vec![false; n],
            res_snap: fleet.servers.iter().map(|srv| vec![false; srv.variants.len()]).collect(),
        }
    }

    /// Lowest-shard-index error wins, whatever the thread schedule was.
    fn check_errors(&self) -> Result<()> {
        let mut errs = self.errors.lock().unwrap_or_else(|p| p.into_inner());
        if errs.is_empty() {
            return Ok(());
        }
        errs.sort_by_key(|(i, _)| *i);
        let (_, e) = errs.remove(0);
        Err(e)
    }

    /// Advance every shard through the window — via the gang when one is
    /// attached, inline in shard order otherwise. Either way every shard
    /// runs to the window end before errors are reported.
    fn advance_window(&mut self, until: f64, inclusive: bool) -> Result<()> {
        let shards = self.shards;
        match self.gang {
            Some(g) => g.window(
                shards, self.fleet, self.cfg, self.errors, self.spawned, until, inclusive,
            ),
            None => {
                for (i, m) in shards.iter().enumerate() {
                    let mut sh = lock_shard(m);
                    if let Err(e) = sh.advance(&self.fleet.servers[i], self.cfg, until, inclusive)
                    {
                        record_error(self.errors, i, e);
                    }
                }
            }
        }
        self.check_errors()
    }

    /// Serially drain events at exactly `t`, in (shard index, local seq)
    /// order, raising every shard's monotonicity floor to the barrier
    /// (any still-queued earlier event is a hard error, same as the old
    /// global virtual-time check).
    fn drain_at(&mut self, t: f64) -> Result<()> {
        let shards = self.shards;
        for (i, m) in shards.iter().enumerate() {
            let mut sh = lock_shard(m);
            sh.last_time = sh.last_time.max(t);
            if let Err(e) = sh.advance(&self.fleet.servers[i], self.cfg, t, true) {
                record_error(self.errors, i, e);
            }
        }
        self.check_errors()
    }

    /// Rebuild the router/controller snapshot arrays: remaining
    /// busy/swap/wake time plus queued work per server, the availability
    /// mask (mid-swap, swap-pending, or — under autoscaling — not
    /// `Active`) and the residency snapshot. With autoscaling off every
    /// lifecycle is `Active`, so the snapshot is exactly the
    /// pre-autoscaling one.
    fn fill_snapshot(&mut self, now: f64) {
        let shards = self.shards;
        for (s, m) in shards.iter().enumerate() {
            let sh = lock_shard(m);
            let mut est = if sh.busy {
                (sh.busy_until - now).max(0.0)
            } else if sh.swapping {
                (sh.swap_until - now).max(0.0)
            } else {
                0.0
            };
            for (v, prof) in self.fleet.servers[s].variants.iter().enumerate() {
                est += sh.batcher.backlog(v) as f64 * prof.batch1_ms();
            }
            self.backlog[s] = est;
            self.queued[s] = sh.batcher.total();
            self.unavail[s] =
                sh.swapping || sh.pending_swap.is_some() || sh.lifecycle != Lifecycle::Active;
            self.res_snap[s].clone_from(&sh.resident);
        }
    }

    /// Schedule retry `attempt` of request `id`: the client re-sends at
    /// `fail_ms + backoff`, floored at the barrier the failure was
    /// observed at (virtual time never regresses past a barrier).
    fn schedule_retry(&mut self, id: usize, tenant: usize, attempt: u32, fail_ms: f64, floor_ms: f64) {
        let at = (fail_ms + backoff_ms(self.cfg, id, attempt)).max(floor_ms);
        self.gacc.retries += 1;
        self.gacc.tenants[tenant].retries += 1;
        self.retry_q.push(Reverse(RetryEntry {
            origin_ms: at,
            id,
            tenant: tenant as u32,
            attempt,
        }));
    }

    /// A rejected admission attempt: re-enter after backoff if retry
    /// budget remains, else the request is finally dropped.
    fn fail_admission(&mut self, id: usize, tenant: usize, attempt: u32, now: f64) {
        if (attempt as usize) < self.cfg.retries {
            self.schedule_retry(id, tenant, attempt + 1, now, now);
        } else {
            self.gacc.dropped_final += 1;
            self.gacc.tenants[tenant].dropped_final += 1;
        }
    }

    /// Drain every shard's retry outbox (shard-index order, entries in
    /// shard event order) into the retry heap, flooring re-entries at the
    /// current barrier. Called at the end of every barrier iteration, so
    /// an expiry re-enters deterministically at the same virtual time for
    /// every `--jobs` value.
    fn harvest_retries(&mut self, floor_ms: f64) {
        for m in self.shards.iter() {
            let outbox = std::mem::take(&mut lock_shard(m).retry_outbox);
            for (fail_ms, req) in outbox {
                self.schedule_retry(req.id, req.tenant as usize, req.attempt + 1, fail_ms, floor_ms);
            }
        }
    }

    /// Terminal pass after the final drain: expiries that still had retry
    /// budget but no barrier left to re-enter at become final (the
    /// attempt census already counted them `expired`).
    fn expire_leftover_retries(&mut self) {
        for m in self.shards.iter() {
            let outbox = std::mem::take(&mut lock_shard(m).retry_outbox);
            for (_, req) in outbox {
                self.gacc.expired_final += 1;
                self.gacc.tenants[req.tenant as usize].expired_final += 1;
            }
        }
    }

    fn handle_arrival(
        &mut self,
        routers: &mut [Router],
        id: usize,
        origin: f64,
        now: f64,
        attempt: u32,
        residency_limited: bool,
    ) -> Result<()> {
        self.gacc.events += 1;
        // tenant assignment is a pure function of the request id, so the
        // whole retry chain stays in the class the fresh arrival drew
        let tenant = tenant_of(id, &self.tenants);
        if attempt == 0 {
            self.gacc.tenants[tenant].generated += 1;
        }
        // router input: remaining busy/swap time + queued work estimate,
        // plus the residency/availability snapshot
        self.fill_snapshot(now);
        let decision = {
            let view = FleetView {
                now_ms: now,
                backlog_ms: &self.backlog,
                queued: &self.queued,
                resident: &self.res_snap,
                unavailable: &self.unavail,
            };
            routers[tenant].route(&view)
        };
        match decision {
            None => {
                if routers[tenant].num_candidates() == 0 {
                    self.gacc.rejected_noncompliant += 1;
                } else {
                    self.gacc.rejected_unavailable += 1;
                }
                self.fail_admission(id, tenant, attempt, now);
            }
            Some(c) => {
                // routing to an asleep or draining server is structurally
                // impossible (they are unavailable in the view); reaching
                // one here is an internal bug
                let shards = self.shards;
                let mut sh = lock_shard(&shards[c.server]);
                if sh.lifecycle != Lifecycle::Active {
                    return Err(Error::hqp(
                        "serve: routed to a non-active server (lifecycle bug)",
                    ));
                }
                if sh.batcher.total() >= self.cfg.queue_cap {
                    self.gacc.rejected_full += 1;
                    drop(sh);
                    self.fail_admission(id, tenant, attempt, now);
                } else {
                    // SLO clock starts at generation (or retry re-entry):
                    // transfer delay eats into the budget
                    let qreq = QueuedReq {
                        id,
                        arrival_ms: origin,
                        deadline_ms: origin + self.tenants[tenant].slo_ms,
                        tenant: tenant as u32,
                        attempt,
                    };
                    match sh.batcher.enqueue(c.variant, qreq) {
                        EnqueueAction::BatchReady => {
                            if sh.can_dispatch() {
                                sh.try_dispatch(
                                    c.variant,
                                    now,
                                    &self.fleet.servers[c.server],
                                    self.cfg,
                                );
                            }
                        }
                        EnqueueAction::ArmFlush(token) => {
                            if sh.can_dispatch() {
                                sh.push(
                                    now + self.cfg.batch_timeout_ms,
                                    LocalKind::Flush { variant: c.variant, token },
                                );
                            }
                        }
                        EnqueueAction::Queued => {}
                    }
                }
            }
        }
        // hot-swap planning over the same snapshot: only meaningful under
        // capped memory (static policies never plan; the guard also keeps
        // the unlimited path's event stream bit-exact). Planning always
        // goes through tenant 0's router — one designated planner keeps
        // the one-swap-per-server contract single-owner.
        if residency_limited {
            let plan = {
                let view = FleetView {
                    now_ms: now,
                    backlog_ms: &self.backlog,
                    queued: &self.queued,
                    resident: &self.res_snap,
                    unavailable: &self.unavail,
                };
                routers[0].plan_swap(&view)
            };
            if let Some(plan) = plan {
                let sv = plan.server;
                let shards = self.shards;
                let mut sh = lock_shard(&shards[sv]);
                // one swap per server at a time is part of the
                // RoutePolicy contract — a plan for a server that is
                // already swapping is a policy bug
                if sh.swapping || sh.pending_swap.is_some() {
                    return Err(Error::hqp(
                        "serve: swap plan targets a server with a swap in flight",
                    ));
                }
                let at = if sh.busy { sh.busy_until } else { now };
                sh.pending_swap = Some(plan);
                sh.push(at, LocalKind::SwapStart);
            }
        }
        Ok(())
    }

    fn scale_up(&mut self, sv: usize, since_ms: f64, now: f64) -> Result<()> {
        let shards = self.shards;
        let mut sh = lock_shard(&shards[sv]);
        if sh.lifecycle != Lifecycle::Asleep || sh.waking {
            return Err(Error::hqp("serve: scale-up targets a server that is not asleep"));
        }
        if !sh.batcher.is_empty() {
            return Err(Error::hqp("serve: asleep server has queued work"));
        }
        sh.waking = true;
        // wake cost priced like a cold swap: the initial resident set's
        // weight bytes streamed over DRAM bandwidth + init, with
        // E = P·L charged for the window
        let srv = &self.fleet.servers[sv];
        let bytes: u64 = srv
            .variants
            .iter()
            .zip(srv.initial_residency())
            .filter(|(_, r)| *r)
            .map(|(v, _)| v.weight_bytes)
            .sum();
        let wake = srv.device.swap_in_ms(bytes, self.cfg.swap_init_ms);
        self.gacc.scale_ups += 1;
        self.gacc.wake_ms += wake;
        self.gacc.wake_energy_mj += srv.device.power_w * wake;
        self.gacc.reaction_sum_ms += now + wake - since_ms;
        self.gacc.events += 1;
        sh.push(now + wake, LocalKind::WakeDone);
        Ok(())
    }

    fn drain_start(&mut self, sv: usize, now: f64) -> Result<()> {
        let shards = self.shards;
        let mut sh = lock_shard(&shards[sv]);
        if sh.lifecycle != Lifecycle::Active {
            return Err(Error::hqp("serve: drain targets a non-active server"));
        }
        sh.lifecycle = Lifecycle::Draining;
        self.gacc.scale_downs += 1;
        self.gacc.events += 1;
        // finish the queue as fast as the device allows: batch timeouts
        // are bypassed from here on
        if sh.can_dispatch() {
            if let Some(next) = sh.batcher.oldest_allowed(&sh.resident) {
                sh.try_dispatch(next, now, &self.fleet.servers[sv], self.cfg);
            }
        }
        sh.sleep_if_drained(now);
        Ok(())
    }

    /// Best Δ_max-compliant serving capacity a server offers over a
    /// residency mask, requests/s (0 when nothing compliant is resident).
    fn server_capacity_rps(&self, s: usize, resident: &[bool]) -> f64 {
        self.fleet.servers[s]
            .variants
            .iter()
            .enumerate()
            .filter(|(v, p)| resident[*v] && p.compliant(self.cfg.delta_max))
            .map(|(_, p)| p.capacity_rps())
            .fold(0.0, f64::max)
    }

    /// Capacity already committed: active servers (current residency)
    /// plus wakes in flight (their initial residency) — so a ramp of
    /// pre-wakes converges instead of overshooting.
    fn committed_capacity_rps(&self, lifecycles: &[Lifecycle], wakings: &[bool]) -> f64 {
        let mut cap = 0.0;
        for s in 0..self.fleet.servers.len() {
            if lifecycles[s] == Lifecycle::Active {
                cap += self.server_capacity_rps(s, &self.res_snap[s]);
            } else if wakings[s] {
                cap += self.server_capacity_rps(s, &self.fleet.servers[s].initial_residency());
            }
        }
        cap
    }

    /// The next concrete wake a scale-up would execute (lowest-index
    /// sleeping server, mirroring the `Up` executor): its wake latency
    /// and the capacity it would add. `(0, 0)` when nothing can wake.
    fn next_wake(&self, lifecycles: &[Lifecycle], wakings: &[bool]) -> (f64, f64) {
        for s in 0..self.fleet.servers.len() {
            if lifecycles[s] == Lifecycle::Asleep && !wakings[s] {
                let srv = &self.fleet.servers[s];
                let bytes: u64 = srv
                    .variants
                    .iter()
                    .zip(srv.initial_residency())
                    .filter(|(_, r)| *r)
                    .map(|(v, _)| v.weight_bytes)
                    .sum();
                let wake_ms = srv.device.swap_in_ms(bytes, self.cfg.swap_init_ms);
                return (wake_ms, self.server_capacity_rps(s, &srv.initial_residency()));
            }
        }
        (0.0, 0.0)
    }

    /// Capacity a `Down` decision would drain right now: the idlest
    /// active server's (same pick as the `Down` executor).
    fn drain_candidate_capacity_rps(&self, lifecycles: &[Lifecycle]) -> f64 {
        let mut pick = None::<(f64, usize)>;
        for s in 0..self.fleet.servers.len() {
            if lifecycles[s] != Lifecycle::Active {
                continue;
            }
            let better = match pick {
                None => true,
                Some((b, ps)) => self.backlog[s] < b || (self.backlog[s] == b && s > ps),
            };
            if better {
                pick = Some((self.backlog[s], s));
            }
        }
        pick.map_or(0.0, |(_, s)| self.server_capacity_rps(s, &self.res_snap[s]))
    }

    /// Queue a forecast-planned swap on its server, under the same
    /// one-swap-per-server discipline as the reactive plan path. The plan
    /// was made on this tick's snapshot, which predates any scale
    /// decision executed this tick — a target that has since left
    /// `Active` (or picked up a swap) is skipped, not an error.
    fn queue_forecast_plan(&mut self, plan: SwapPlan, now: f64, prefetch: bool) {
        let shards = self.shards;
        let mut sh = lock_shard(&shards[plan.server]);
        if sh.lifecycle != Lifecycle::Active || sh.swapping || sh.pending_swap.is_some() {
            return;
        }
        if prefetch {
            self.gacc.prefetch_swaps += 1;
        } else {
            self.gacc.reselect_swaps += 1;
        }
        let at = if sh.busy { sh.busy_until } else { now };
        sh.pending_swap = Some(plan);
        sh.push(at, LocalKind::SwapStart);
    }

    /// Any shard-local event still queued — the drain-phase control-tick
    /// gate: ticks stay live while the tail is still playing out.
    fn pending_local_events(&self) -> bool {
        self.shards.iter().any(|m| !lock_shard(m).heap.is_empty())
    }

    #[allow(clippy::too_many_arguments)]
    fn handle_control(
        &mut self,
        scaler: Option<&mut Box<dyn AutoscalePolicy>>,
        tracker: &mut SignalTracker,
        forecaster: Option<&mut Forecaster>,
        planner: &Router,
        now: f64,
        max_active: usize,
        residency_limited: bool,
    ) -> Result<()> {
        self.gacc.events += 1;
        let Some(ctrl) = scaler else {
            return Err(Error::hqp("serve: control tick without a scale policy"));
        };
        self.fill_snapshot(now);
        // whole-fleet signals: lifecycle census, queued work on active
        // servers, and the cumulative outcome counters (u64 sums over
        // shards — order-independent)
        let n = self.fleet.servers.len();
        let mut lifecycles = Vec::with_capacity(n);
        let mut wakings = Vec::with_capacity(n);
        let mut queued_active = 0usize;
        let mut completed = 0u64;
        let mut expired = 0u64;
        let mut slo_attained = 0u64;
        {
            let shards = self.shards;
            for m in shards.iter() {
                let sh = lock_shard(m);
                lifecycles.push(sh.lifecycle);
                wakings.push(sh.waking);
                if sh.lifecycle == Lifecycle::Active {
                    queued_active += sh.batcher.total();
                }
                completed += sh.acc.completed;
                expired += sh.acc.expired;
                slo_attained += sh.acc.slo_attained;
            }
        }
        let n_active = lifecycles.iter().filter(|&&l| l == Lifecycle::Active).count();
        let n_waking = wakings.iter().filter(|&&w| w).count();
        let n_draining = lifecycles.iter().filter(|&&l| l == Lifecycle::Draining).count();
        let n_asleep = lifecycles
            .iter()
            .zip(&wakings)
            .filter(|(&l, &w)| l == Lifecycle::Asleep && !w)
            .count();
        let outcomes = completed
            + expired
            + self.gacc.rejected_full
            + self.gacc.rejected_noncompliant
            + self.gacc.rejected_unavailable;
        let sig = tracker.tick(
            now,
            outcomes,
            slo_attained,
            queued_active,
            n_active,
            n_waking,
            n_draining,
            n_asleep,
        );
        // predictive only: hand the controller a rate outlook before it
        // decides. The horizon is the lead time a prewake taken *now* can
        // buy — the next wake's latency plus one control interval (or the
        // explicit `--forecast-horizon-ms` override).
        let fobs: Option<ForecastObs> = match forecaster {
            None => None,
            Some(fc) => {
                let committed = self.committed_capacity_rps(&lifecycles, &wakings);
                let (next_wake_ms, next_wake_cap) =
                    if n_active + n_waking + n_draining < max_active {
                        self.next_wake(&lifecycles, &wakings)
                    } else {
                        (0.0, 0.0)
                    };
                let drain_cap = if n_active > self.cfg.autoscale.min_active {
                    self.drain_candidate_capacity_rps(&lifecycles)
                } else {
                    0.0
                };
                let horizon = self
                    .cfg
                    .forecast_horizon_ms
                    .unwrap_or(next_wake_ms + self.cfg.autoscale.interval_ms);
                fc.on_tick(now, horizon);
                let f = fc.forecast(now);
                Some(ForecastObs {
                    rate_now_rps: f.rate_now_rps,
                    rate_ahead_rps: f.rate_ahead(horizon),
                    horizon_ms: horizon,
                    confidence: f.confidence,
                    committed_capacity_rps: committed,
                    next_wake_capacity_rps: next_wake_cap,
                    drain_capacity_rps: drain_cap,
                })
            }
        };
        if let Some(obs) = &fobs {
            ctrl.observe_forecast(obs);
        }
        let decision = {
            let view = FleetView {
                now_ms: now,
                backlog_ms: &self.backlog,
                queued: &self.queued,
                resident: &self.res_snap,
                unavailable: &self.unavail,
            };
            ctrl.decide(&view, &sig)
        };
        match decision {
            ScaleDecision::Hold => {}
            ScaleDecision::Up { since_ms } => {
                // committed capacity = active + waking + draining (a
                // draining server still consumes its slot until it
                // sleeps); wake the lowest-index sleeping server if the
                // bound allows
                if n_active + n_waking + n_draining < max_active {
                    if let Some(sv) =
                        (0..n).find(|&s| lifecycles[s] == Lifecycle::Asleep && !wakings[s])
                    {
                        self.scale_up(sv, since_ms, now)?;
                    }
                }
            }
            ScaleDecision::Down => {
                // drain the idlest active server (lowest backlog, ties to
                // the higher index so server 0 drains last)
                if n_active > self.cfg.autoscale.min_active {
                    let mut pick = None::<(f64, usize)>;
                    for s in 0..n {
                        if lifecycles[s] != Lifecycle::Active {
                            continue;
                        }
                        let better = match pick {
                            None => true,
                            Some((b, ps)) => {
                                self.backlog[s] < b || (self.backlog[s] == b && s > ps)
                            }
                        };
                        if better {
                            pick = Some((self.backlog[s], s));
                        }
                    }
                    if let Some((_, sv)) = pick {
                        self.drain_start(sv, now)?;
                    }
                }
            }
        }
        // forecast-driven swap planning, same snapshot, same designated
        // planner router as the reactive path. Gated on a confident
        // forecast; each plan goes through the normal SwapStart/SwapDone
        // machinery and is priced by the existing swap cost model.
        if let Some(obs) = fobs {
            if obs.confidence >= PREDICT_CONFIDENCE_GATE && residency_limited {
                // prefetch: start upgrade swaps before forecast pressure
                // lands — the expected work over the horizon prices the
                // benefit side of the plan
                let expected = obs.rate_ahead_rps * obs.horizon_ms / 1e3;
                let plan = {
                    let view = FleetView {
                        now_ms: now,
                        backlog_ms: &self.backlog,
                        queued: &self.queued,
                        resident: &self.res_snap,
                        unavailable: &self.unavail,
                    };
                    planner.plan_prefetch(&view, expected)
                };
                if let Some(plan) = plan {
                    self.queue_forecast_plan(plan, now, true);
                }
                // sustained-low downshift (joules-per-slo routing only):
                // re-select a cheaper compliant variant on an idle server
                if self.cfg.policy == Policy::JoulesPerSlo
                    && obs.rate_ahead_rps < PREDICT_DOWN_FACTOR * obs.committed_capacity_rps
                {
                    let plan = {
                        let view = FleetView {
                            now_ms: now,
                            backlog_ms: &self.backlog,
                            queued: &self.queued,
                            resident: &self.res_snap,
                            unavailable: &self.unavail,
                        };
                        planner.plan_reselect(&view)
                    };
                    if let Some(plan) = plan {
                        self.queue_forecast_plan(plan, now, false);
                    }
                }
            }
        }
        Ok(())
    }

    /// Walk the global timeline (arrivals + control ticks), advancing
    /// shards between barriers and applying the canonical same-time order
    /// documented in the module docs. The trace streams in through a
    /// bounded [`Lookahead`] — the walk never holds more than
    /// [`LOOKAHEAD_CAP`] pending arrivals.
    fn run<I: Iterator<Item = f64>>(
        mut self,
        mut arrivals: Lookahead<I>,
        auto: bool,
        max_active: usize,
        residency_limited: bool,
        transfer_ms: f64,
    ) -> Result<GlobalAcc> {
        let cfg = self.cfg;
        // one router per effective tenant class, each enforcing that
        // tenant's Δ_max at admission; with no `--tenants` table this is
        // exactly one router under the global Δ_max (the pre-tenant path,
        // byte for byte)
        let mut routers: Vec<Router> = self
            .tenants
            .iter()
            .map(|t| {
                Router::new(self.fleet, t.dmax, cfg.policy, cfg.swap_init_ms).with_slo(t.slo_ms)
            })
            .collect();
        let closed_loop = cfg.closed_loop();
        let mut scaler = cfg.autoscale.policy.build(&cfg.autoscale);
        let mut tracker = SignalTracker::new();
        // the forecaster exists only under the predictive policy, lives on
        // the coordinator thread and is fed fresh arrivals in trace order
        // — deterministic and jobs-invariant by construction
        let predictive = auto && cfg.autoscale.policy == ScalePolicy::Predictive;
        let mut forecaster = if predictive { Some(Forecaster::new()) } else { None };
        // satellite of PR 10: with the gate on, control ticks keep firing
        // through the drain phase (while shard events remain) instead of
        // freezing at the last arrival
        let drain_ticks = auto && (cfg.scale_to_drain || predictive);
        // the control plane runs for the duration of the offered trace
        // (last arrival + transfer); tick times come from the same
        // accumulating addition (now + interval) the materialized engine
        // used, so the tick schedule is bit-exact. Since the trace end is
        // unknown until the source drains, a tick *candidate* is carried
        // unconditionally and its validity decided at the top of the
        // loop: while an arrival at `ta` is buffered, any candidate
        // `c <= ta` is provably within the trace (`ta <= end`); once the
        // source is exhausted, `end` is exact.
        let mut next_tick = if auto { Some(cfg.autoscale.interval_ms) } else { None };

        loop {
            let ta = arrivals.peek()?.map(|origin| origin + transfer_ms);
            // the earliest pending retry re-entry (same transfer delay as
            // a fresh arrival); retries never extend the control-tick
            // schedule, which stays anchored to the offered trace end
            let tr = self
                .retry_q
                .peek()
                .map(|Reverse(r)| r.origin_ms + transfer_ms);
            let tc = match (next_tick, ta) {
                // a buffered arrival bounds the trace end from below, so
                // the candidate is valid whenever it can fire first
                (Some(c), Some(_)) => Some(c),
                // source drained: the exact end decides (an empty trace
                // has no end and schedules no ticks, as before); with the
                // drain-phase gate on, ticks continue past the trace end
                // while any shard still has events to play out
                (Some(c), None) => match arrivals.end() {
                    Some(last) if c <= last + transfer_ms => Some(c),
                    Some(_) if drain_ticks && self.pending_local_events() => Some(c),
                    _ => None,
                },
                (None, _) => None,
            };
            let t = [ta, tr, tc]
                .into_iter()
                .flatten()
                .fold(None::<f64>, |m, x| Some(m.map_or(x, |m| m.min(x))));
            let Some(t) = t else { break };
            // 1. the inter-barrier window: everything strictly before t
            self.advance_window(t, false)?;
            // at least one global event processes at t
            self.gacc.max_time = self.gacc.max_time.max(t);
            // 2. arrivals at t — fresh ones first, in trace order...
            if ta == Some(t) {
                while let Some(origin) = arrivals.peek()? {
                    if origin + transfer_ms != t {
                        break;
                    }
                    let (id, origin) = arrivals.pop().expect("serve: peeked arrival vanished");
                    // fresh offered demand only, in trace order (retry
                    // re-entries are already-counted load, not fed)
                    if let Some(fc) = forecaster.as_mut() {
                        fc.on_arrival(t);
                    }
                    self.handle_arrival(&mut routers, id, origin, t, 0, residency_limited)?;
                }
            }
            // ...then retry re-entries at t, in (time, id, attempt) order
            // (a same-time re-retry scheduled inside this loop pops here
            // too; attempts strictly increase, so it terminates)
            loop {
                let due = matches!(
                    self.retry_q.peek(),
                    Some(Reverse(r)) if r.origin_ms + transfer_ms == t
                );
                if !due {
                    break;
                }
                let Reverse(r) = self.retry_q.pop().expect("serve: peeked retry vanished");
                self.handle_arrival(
                    &mut routers,
                    r.id,
                    r.origin_ms,
                    t,
                    r.attempt,
                    residency_limited,
                )?;
            }
            // 3. local events at exactly t, (shard, local seq) order
            self.drain_at(t)?;
            // 4. + 5. the control tick, then its same-time consequences
            if tc == Some(t) {
                self.handle_control(
                    scaler.as_mut(),
                    &mut tracker,
                    forecaster.as_mut(),
                    &routers[0],
                    t,
                    max_active,
                    residency_limited,
                )?;
                next_tick = Some(t + cfg.autoscale.interval_ms);
                self.drain_at(t)?;
            }
            // 6. harvest this barrier's expiry feedback into the retry
            // heap (closed loop only — open loop never fills an outbox)
            if closed_loop {
                self.harvest_retries(t);
            }
        }
        // drain everything scheduled after the last global event
        self.advance_window(f64::INFINITY, true)?;
        // expiries surfaced by the final drain are terminal: there is no
        // barrier left for a re-entry to merge at
        if closed_loop {
            self.expire_leftover_retries();
        }
        // read back the predictive bookkeeping (0 / absent otherwise)
        if let Some(ctrl) = scaler.as_ref() {
            self.gacc.prewakes = ctrl.prewakes();
        }
        if let Some(fc) = &forecaster {
            let (sum, n) = fc.err_stats();
            self.gacc.forecast_err_sum_pct = sum;
            self.gacc.forecast_err_samples = n;
        }
        Ok(self.gacc)
    }
}

/// Run the sharded simulation over a materialized trace — the
/// `iter().copied()` special case of [`run_stream`], kept as the
/// slice-path entry so existing callers are untouched.
pub(crate) fn run(
    fleet: &Fleet,
    arrivals: &[f64],
    cfg: &ServeConfig,
    jobs: usize,
) -> Result<Totals> {
    run_stream(fleet, arrivals.iter().copied(), cfg, jobs)
}

/// Run the sharded simulation over a streaming arrival source. `jobs >=
/// 1` is the worker-thread budget (validated by the caller); the event
/// order and every accumulator merge are identical for all values —
/// `jobs` only sets how many OS threads advance shards inside the
/// inter-barrier windows — and identical to the slice path, byte for
/// byte. Resident memory is independent of how many arrivals the
/// iterator yields.
pub(crate) fn run_stream<I: Iterator<Item = f64>>(
    fleet: &Fleet,
    arrivals: I,
    cfg: &ServeConfig,
    jobs: usize,
) -> Result<Totals> {
    let auto = cfg.autoscale.enabled();
    let max_active = cfg.autoscale.max_active.min(fleet.servers.len());
    let residency_limited = fleet.residency_limited();
    // per-request uplink transfer delay (0 with an infinite link, keeping
    // the arrival schedule bit-exact)
    let transfer_ms = if cfg.link_mbps.is_finite() {
        fleet.input_bytes() as f64 * 8.0 / (cfg.link_mbps * 1e6) * 1e3
    } else {
        0.0
    };

    // lifecycle: with autoscaling, the first min_active servers start
    // awake and the rest asleep; without it, everyone is permanently
    // Active and no scale machinery ever runs
    let shards: Vec<Mutex<Shard>> = fleet
        .servers
        .iter()
        .enumerate()
        .map(|(s, srv)| Mutex::new(Shard::new(srv, cfg, auto && s >= cfg.autoscale.min_active)))
        .collect();
    let errors: Mutex<Vec<(usize, Error)>> = Mutex::new(Vec::new());

    // one worker per shard is the useful maximum; below two total workers
    // the gang is pure overhead and the coordinator advances shards inline
    let spawned = jobs.min(fleet.servers.len()).saturating_sub(1);
    let lookahead = Lookahead::new(arrivals);
    let gacc = if spawned == 0 {
        Coordinator::new(fleet, cfg, &shards, &errors, None, 0).run(
            lookahead,
            auto,
            max_active,
            residency_limited,
            transfer_ms,
        )?
    } else {
        let gang = Gang::new();
        std::thread::scope(|scope| {
            for _ in 0..spawned {
                scope.spawn(|| gang.worker(&shards, fleet, cfg, &errors));
            }
            let r = Coordinator::new(fleet, cfg, &shards, &errors, Some(&gang), spawned)
                .run(lookahead, auto, max_active, residency_limited, transfer_ms);
            gang.shutdown();
            r
        })?
    };

    // every queue must have drained: the timeline only ends once no
    // flush, batch-done or swap event is pending anywhere, so a leftover
    // request means something routed to a queue residency could never
    // serve
    let shards: Vec<Shard> = shards
        .into_iter()
        .map(|m| m.into_inner().unwrap_or_else(|p| p.into_inner()))
        .collect();
    if shards.iter().any(|sh| !sh.batcher.is_empty()) {
        return Err(Error::hqp(
            "serve: requests stranded in a queue at end of trace (residency routing bug)",
        ));
    }

    // deterministic merge: per-shard accumulators fold in shard-index
    // order for every jobs value (histogram bins add as u64s, the latency
    // sum as f64 in this same fixed order)
    // global makespan first: idle-power windows still open on powered
    // servers close here (a shard cannot know the fleet-wide end time)
    let makespan_ms = shards.iter().fold(gacc.max_time, |m, sh| m.max(sh.max_time));
    let mut totals = Totals {
        rejected_full: gacc.rejected_full,
        rejected_noncompliant: gacc.rejected_noncompliant,
        rejected_unavailable: gacc.rejected_unavailable,
        scale_ups: gacc.scale_ups,
        scale_downs: gacc.scale_downs,
        wake_ms: gacc.wake_ms,
        wake_energy_mj: gacc.wake_energy_mj,
        reaction_sum_ms: gacc.reaction_sum_ms,
        makespan_ms: gacc.max_time,
        events: gacc.events,
        retries: gacc.retries,
        dropped_final: gacc.dropped_final,
        expired_final: gacc.expired_final,
        tenants: gacc.tenants,
        prewakes: gacc.prewakes,
        prefetch_swaps: gacc.prefetch_swaps,
        reselect_swaps: gacc.reselect_swaps,
        forecast_err_sum_pct: gacc.forecast_err_sum_pct,
        forecast_err_samples: gacc.forecast_err_samples,
        usage: Vec::with_capacity(shards.len()),
        ..Totals::default()
    };
    for sh in shards {
        if !sh.retry_outbox.is_empty() {
            return Err(Error::hqp(
                "serve: unharvested retry feedback at end of run (barrier bug)",
            ));
        }
        totals.completed += sh.acc.completed;
        totals.expired += sh.acc.expired;
        totals.expired_during_swap += sh.acc.expired_during_swap;
        totals.expired_final += sh.acc.expired_final;
        totals.swaps += sh.acc.swaps;
        totals.swap_ms += sh.acc.swap_ms;
        totals.swap_energy_mj += sh.acc.swap_energy_mj;
        totals.slo_attained += sh.acc.slo_attained;
        // idle energy: powered time not spent executing batches or
        // swapping, at the configured idle draw (exactly 0 by default)
        let powered =
            sh.powered_ms + sh.powered_since.map_or(0.0, |t0| (makespan_ms - t0).max(0.0));
        let busy: f64 = sh.acc.usage.iter().map(|u| u.busy_ms).sum();
        totals.idle_energy_mj += cfg.idle_watts * (powered - busy - sh.acc.swap_ms).max(0.0);
        totals.latency_stats.merge(&sh.acc.latency_stats);
        totals.peak_queue_depth = totals.peak_queue_depth.max(sh.batcher.peak() as u64);
        for (t, st) in totals.tenants.iter_mut().zip(&sh.acc.tenants) {
            t.completed += st.completed;
            t.slo_attained += st.slo_attained;
            t.expired_final += st.expired_final;
            t.latency.merge(&st.latency);
        }
        totals.usage.push(sh.acc.usage);
        totals.events += sh.events;
        totals.makespan_ms = totals.makespan_ms.max(sh.max_time);
    }
    Ok(totals)
}
