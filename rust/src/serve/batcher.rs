//! Dynamic batching for one server: per-variant FIFO admission queues
//! with a max-batch-size / batching-timeout policy.
//!
//! Invariants the event loop relies on (property-tested in
//! `tests/prop_serve.rs`):
//!
//! * a request enters exactly one queue and leaves it exactly once —
//!   either inside a dispatched batch or counted as *expired* (its SLO
//!   deadline passed while it waited);
//! * `total()` always equals the sum of queue lengths (admission control
//!   caps it);
//! * flush tokens make timeout events idempotent: any dispatch from a
//!   queue invalidates that queue's pending timeout, so a stale `Flush`
//!   event can never double-dispatch.
//!
//! ## Eviction semantics (stateful residency)
//!
//! When the serving layer evicts a variant mid-swap, its queue is
//! [`Batcher::drain`]ed: requests whose deadline already passed are
//! counted expired by the caller, survivors are [`Batcher::requeue`]d
//! onto another variant's queue as a sorted-by-arrival merge — so FIFO
//! selection ([`Batcher::oldest_allowed`]) and expiry stay deterministic
//! and every request still leaves its queue exactly once. Requeueing
//! happens only while the server is mid-swap (no flush re-arm needed:
//! dispatch resumes at swap completion).

use std::collections::VecDeque;

/// One queued request.
#[derive(Clone, Copy, Debug)]
pub struct QueuedReq {
    /// Index of the request in the arrival trace (a stable identity
    /// across drains, requeues and retries).
    pub id: usize,
    /// When this *attempt* was generated (or re-entered after backoff),
    /// virtual ms (FIFO/merge key; the latency and SLO clocks both start
    /// here).
    pub arrival_ms: f64,
    /// `arrival_ms + slo_ms` (the tenant's SLO): queued past this is
    /// expiry, completed past this is an SLO miss.
    pub deadline_ms: f64,
    /// Tenant-class index (0 when no `--tenants` table is configured).
    pub tenant: u32,
    /// 0 for the fresh arrival, k for the k-th backoff re-entry.
    pub attempt: u32,
}

/// What the caller must do after an enqueue.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EnqueueAction {
    /// Queue reached `max_batch` — dispatch now if the device is idle.
    BatchReady,
    /// First request in an empty queue — arm a flush timer with this
    /// token (fires `timeout_ms` after the enqueue).
    ArmFlush(u64),
    /// Queue was already non-empty and below `max_batch`: nothing to do.
    Queued,
}

/// A dispatched batch plus the requests that expired while queued.
#[derive(Clone, Debug, Default)]
pub struct TakenBatch {
    /// The requests actually dispatched (≤ `max_batch`, deadlines live).
    pub reqs: Vec<QueuedReq>,
    /// Requests popped with it whose deadline had already lapsed — the
    /// caller counts these expired; they are never served.
    pub expired: Vec<QueuedReq>,
}

/// Weighted-fair dequeue state: per-tenant admission weights and how
/// many requests each tenant has had admitted into batches so far. The
/// virtual finish time of a tenant's next request is
/// `(admitted + 1) / weight`; each dequeue picks the queued request
/// whose tenant's finish time is smallest (ties to queue order), so over
/// an overload each tenant's admission share converges to its weight
/// share instead of pure arrival order.
#[derive(Clone, Debug)]
struct Wfq {
    weights: Vec<f64>,
    admitted: Vec<u64>,
}

/// Per-variant admission queues + batching policy for one server.
#[derive(Clone, Debug)]
pub struct Batcher {
    /// Largest batch a single dispatch may form (≥ 1).
    pub max_batch: usize,
    /// How long an idle device waits for a partial batch to fill, ms.
    pub timeout_ms: f64,
    queues: Vec<VecDeque<QueuedReq>>,
    flush_tokens: Vec<u64>,
    total: usize,
    peak: usize,
    /// `Some` switches [`Batcher::take_batch`] from FIFO to weighted-fair
    /// dequeue; `None` (the default) is byte-identical to the pre-tenant
    /// batcher.
    wfq: Option<Wfq>,
}

impl Batcher {
    pub fn new(num_variants: usize, max_batch: usize, timeout_ms: f64) -> Batcher {
        Batcher {
            max_batch: max_batch.max(1),
            timeout_ms: timeout_ms.max(0.0),
            queues: vec![VecDeque::new(); num_variants],
            flush_tokens: vec![0; num_variants],
            total: 0,
            peak: 0,
            wfq: None,
        }
    }

    /// Switch dequeue order to weighted-fair over tenant classes with
    /// these admission weights (indexed by `QueuedReq::tenant`).
    pub fn set_weighted_fair(&mut self, weights: Vec<f64>) {
        let n = weights.len();
        self.wfq = Some(Wfq { weights, admitted: vec![0; n] });
    }

    /// Requests currently queued across all variants.
    pub fn total(&self) -> usize {
        self.total
    }

    /// High-water mark of [`Batcher::total`] over the server's lifetime —
    /// the backpressure telemetry behind `Summary.peak_queue_depth`.
    pub fn peak(&self) -> usize {
        self.peak
    }

    /// Queue length of one variant.
    pub fn len(&self, variant: usize) -> usize {
        self.queues[variant].len()
    }

    pub fn is_empty(&self) -> bool {
        self.total == 0
    }

    /// Enqueue a routed request.
    pub fn enqueue(&mut self, variant: usize, req: QueuedReq) -> EnqueueAction {
        let was_empty = self.queues[variant].is_empty();
        self.queues[variant].push_back(req);
        self.total += 1;
        self.peak = self.peak.max(self.total);
        if self.queues[variant].len() >= self.max_batch {
            EnqueueAction::BatchReady
        } else if was_empty {
            self.flush_tokens[variant] += 1;
            EnqueueAction::ArmFlush(self.flush_tokens[variant])
        } else {
            EnqueueAction::Queued
        }
    }

    /// Is a flush event with this token still live for the variant?
    pub fn flush_live(&self, variant: usize, token: u64) -> bool {
        self.flush_tokens[variant] == token && !self.queues[variant].is_empty()
    }

    /// Pop up to `max_batch` requests from one variant's queue, dropping
    /// (and reporting) the ones whose deadline passed before service
    /// could start. Invalidates any pending flush for the variant.
    pub fn take_batch(&mut self, variant: usize, now_ms: f64) -> TakenBatch {
        self.flush_tokens[variant] += 1;
        let mut out = TakenBatch::default();
        if self.wfq.is_some() {
            while out.reqs.len() < self.max_batch {
                let Some(idx) = self.wfq_pick(variant) else { break };
                let req = self.queues[variant]
                    .remove(idx)
                    .expect("batcher: wfq pick out of range");
                self.total -= 1;
                if req.deadline_ms < now_ms {
                    // an expired pick is censused, not admitted: it does
                    // not consume batch space or advance the tenant clock
                    out.expired.push(req);
                } else {
                    let w = self.wfq.as_mut().expect("batcher: wfq vanished");
                    w.admitted[req.tenant as usize] += 1;
                    out.reqs.push(req);
                }
            }
            return out;
        }
        while out.reqs.len() < self.max_batch {
            let Some(req) = self.queues[variant].pop_front() else { break };
            self.total -= 1;
            if req.deadline_ms < now_ms {
                out.expired.push(req);
            } else {
                out.reqs.push(req);
            }
        }
        out
    }

    /// Queue index of the weighted-fair pick for one variant: the request
    /// whose tenant has the smallest virtual finish time, ties broken by
    /// queue (FIFO) position — a total order, so dequeue is deterministic.
    fn wfq_pick(&self, variant: usize) -> Option<usize> {
        let w = self.wfq.as_ref().expect("batcher: wfq_pick without wfq state");
        let mut best: Option<(f64, usize)> = None;
        for (i, r) in self.queues[variant].iter().enumerate() {
            let t = r.tenant as usize;
            let finish = (w.admitted[t] as f64 + 1.0) / w.weights[t];
            if match best {
                None => true,
                Some((f, _)) => finish < f,
            } {
                best = Some((finish, i));
            }
        }
        best.map(|(_, i)| i)
    }

    /// The non-empty variant queue whose head request has waited longest
    /// (FIFO across variants; ties break on the lower variant index, so
    /// selection is deterministic).
    pub fn oldest_nonempty(&self) -> Option<usize> {
        self.oldest_where(|_| true)
    }

    /// [`Batcher::oldest_nonempty`] restricted to `allowed` (resident)
    /// variants — the serving layer's structural guarantee that a
    /// non-resident variant's queue can never form a batch.
    pub fn oldest_allowed(&self, allowed: &[bool]) -> Option<usize> {
        self.oldest_where(|v| allowed[v])
    }

    fn oldest_where(&self, allowed: impl Fn(usize) -> bool) -> Option<usize> {
        let mut best: Option<(f64, usize)> = None;
        for (v, q) in self.queues.iter().enumerate() {
            if !allowed(v) {
                continue;
            }
            if let Some(head) = q.front() {
                let better = match best {
                    None => true,
                    Some((t, _)) => head.arrival_ms < t,
                };
                if better {
                    best = Some((head.arrival_ms, v));
                }
            }
        }
        best.map(|(_, v)| v)
    }

    /// Remove (and return) every queued request of one variant — the
    /// eviction path. Invalidates the variant's pending flush; the caller
    /// decides which survivors to [`Batcher::requeue`] where.
    pub fn drain(&mut self, variant: usize) -> Vec<QueuedReq> {
        self.flush_tokens[variant] += 1;
        let q = std::mem::take(&mut self.queues[variant]);
        self.total -= q.len();
        q.into()
    }

    /// Merge evicted survivors into another variant's queue, keeping it
    /// sorted by arrival time (ties by request id) so cross-variant FIFO
    /// and expiry order stay deterministic.
    pub fn requeue(&mut self, variant: usize, reqs: Vec<QueuedReq>) {
        if reqs.is_empty() {
            return;
        }
        self.total += reqs.len();
        self.peak = self.peak.max(self.total);
        let q = &mut self.queues[variant];
        let mut merged: Vec<QueuedReq> = Vec::with_capacity(q.len() + reqs.len());
        merged.extend(q.drain(..));
        merged.extend(reqs);
        merged.sort_by(|a, b| a.arrival_ms.total_cmp(&b.arrival_ms).then(a.id.cmp(&b.id)));
        *q = merged.into();
    }

    /// Drop every queued request whose deadline has passed, returning the
    /// dropped requests (variant order, FIFO within a variant) so the
    /// caller can attribute the expiry — the post-swap purge. Uses the
    /// same strict `deadline < now` rule as [`Batcher::take_batch`].
    pub fn purge_expired(&mut self, now_ms: f64) -> Vec<QueuedReq> {
        let mut dropped = Vec::new();
        for q in &mut self.queues {
            q.retain(|r| {
                if r.deadline_ms < now_ms {
                    dropped.push(*r);
                    false
                } else {
                    true
                }
            });
        }
        self.total -= dropped.len();
        dropped
    }

    /// Estimated backlog of one variant in requests (router input).
    pub fn backlog(&self, variant: usize) -> usize {
        self.queues[variant].len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: usize, arrival: f64, deadline: f64) -> QueuedReq {
        QueuedReq { id, arrival_ms: arrival, deadline_ms: deadline, tenant: 0, attempt: 0 }
    }

    fn treq(id: usize, arrival: f64, tenant: u32) -> QueuedReq {
        QueuedReq { id, arrival_ms: arrival, deadline_ms: arrival + 1e6, tenant, attempt: 0 }
    }

    #[test]
    fn enqueue_actions() {
        let mut b = Batcher::new(2, 3, 5.0);
        assert_eq!(b.enqueue(0, req(0, 0.0, 50.0)), EnqueueAction::ArmFlush(1));
        assert_eq!(b.enqueue(0, req(1, 1.0, 50.0)), EnqueueAction::Queued);
        assert_eq!(b.enqueue(0, req(2, 2.0, 50.0)), EnqueueAction::BatchReady);
        assert_eq!(b.total(), 3);
        assert_eq!(b.len(0), 3);
        assert_eq!(b.len(1), 0);
    }

    #[test]
    fn take_batch_respects_max_and_expiry() {
        let mut b = Batcher::new(1, 2, 5.0);
        b.enqueue(0, req(0, 0.0, 1.0)); // will expire
        b.enqueue(0, req(1, 0.5, 50.0));
        b.enqueue(0, req(2, 0.6, 50.0));
        let t = b.take_batch(0, 10.0);
        assert_eq!(t.expired.len(), 1);
        assert_eq!(t.expired[0].id, 0);
        assert_eq!(t.reqs.len(), 2);
        assert_eq!(b.total(), 0);
    }

    #[test]
    fn flush_tokens_invalidate_on_dispatch() {
        let mut b = Batcher::new(1, 8, 5.0);
        let EnqueueAction::ArmFlush(tok) = b.enqueue(0, req(0, 0.0, 50.0)) else {
            panic!("expected flush arm");
        };
        assert!(b.flush_live(0, tok));
        b.take_batch(0, 1.0);
        assert!(!b.flush_live(0, tok), "dispatch must kill the pending flush");
        // re-arming after the queue refills issues a fresh token
        let EnqueueAction::ArmFlush(tok2) = b.enqueue(0, req(1, 2.0, 50.0)) else {
            panic!("expected flush arm");
        };
        assert!(tok2 > tok);
        assert!(b.flush_live(0, tok2));
    }

    #[test]
    fn oldest_nonempty_is_fifo_across_variants() {
        let mut b = Batcher::new(3, 8, 5.0);
        b.enqueue(2, req(0, 1.0, 50.0));
        b.enqueue(0, req(1, 2.0, 50.0));
        assert_eq!(b.oldest_nonempty(), Some(2));
        b.take_batch(2, 3.0);
        assert_eq!(b.oldest_nonempty(), Some(0));
        b.take_batch(0, 3.0);
        assert_eq!(b.oldest_nonempty(), None);
    }

    #[test]
    fn drain_requeue_preserves_order_and_conservation() {
        let mut b = Batcher::new(2, 8, 5.0);
        b.enqueue(0, req(0, 1.0, 50.0));
        b.enqueue(1, req(1, 2.0, 50.0));
        b.enqueue(0, req(2, 3.0, 50.0));
        let drained = b.drain(0);
        assert_eq!(drained.len(), 2);
        assert_eq!(b.total(), 1);
        assert_eq!(b.len(0), 0);
        // merge into variant 1: arrival order 1.0, 2.0, 3.0 across sources
        b.requeue(1, drained);
        assert_eq!(b.total(), 3);
        assert_eq!(b.len(1), 3);
        let t = b.take_batch(1, 4.0);
        let ids: Vec<usize> = t.reqs.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![0, 1, 2], "requeue must merge sorted by arrival");
    }

    #[test]
    fn drain_invalidates_pending_flush() {
        let mut b = Batcher::new(1, 8, 5.0);
        let EnqueueAction::ArmFlush(tok) = b.enqueue(0, req(0, 0.0, 50.0)) else {
            panic!("expected flush arm");
        };
        assert!(b.flush_live(0, tok));
        b.drain(0);
        assert!(!b.flush_live(0, tok), "eviction must kill the pending flush");
    }

    #[test]
    fn purge_expired_drops_only_past_deadlines() {
        let mut b = Batcher::new(2, 8, 5.0);
        b.enqueue(0, req(0, 0.0, 3.0));
        b.enqueue(0, req(1, 1.0, 50.0));
        b.enqueue(1, req(2, 2.0, 4.0));
        let dropped = b.purge_expired(10.0);
        assert_eq!(dropped.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 2]);
        assert_eq!(b.total(), 1);
        assert_eq!(b.len(0), 1);
        assert_eq!(b.len(1), 0);
        // boundary: deadline == now survives (strict <, like take_batch)
        let mut b = Batcher::new(1, 8, 5.0);
        b.enqueue(0, req(0, 0.0, 10.0));
        assert!(b.purge_expired(10.0).is_empty());
    }

    #[test]
    fn oldest_allowed_skips_masked_variants() {
        let mut b = Batcher::new(3, 8, 5.0);
        b.enqueue(2, req(0, 1.0, 50.0));
        b.enqueue(0, req(1, 2.0, 50.0));
        assert_eq!(b.oldest_nonempty(), Some(2));
        assert_eq!(b.oldest_allowed(&[true, true, false]), Some(0));
        assert_eq!(b.oldest_allowed(&[false, true, false]), None);
    }

    #[test]
    fn conservation_under_interleaving() {
        let mut b = Batcher::new(2, 4, 1.0);
        let mut popped = 0;
        for i in 0..100 {
            b.enqueue(i % 2, req(i, i as f64, i as f64 + 20.0));
            if i % 3 == 0 {
                let t = b.take_batch(i % 2, i as f64);
                popped += t.reqs.len() + t.expired.len();
            }
        }
        while let Some(v) = b.oldest_nonempty() {
            let t = b.take_batch(v, 1e9);
            popped += t.reqs.len() + t.expired.len();
        }
        assert_eq!(popped, 100);
        assert_eq!(b.total(), 0);
    }

    #[test]
    fn wfq_dequeue_tracks_weight_shares_not_arrival_order() {
        // tenant 0 weight 3, tenant 1 weight 1; tenant 1 arrived first
        let mut b = Batcher::new(1, 1, 5.0);
        b.set_weighted_fair(vec![3.0, 1.0]);
        for i in 0..4 {
            b.enqueue(0, treq(i, i as f64, 1));
        }
        for i in 4..12 {
            b.enqueue(0, treq(i, i as f64, 0));
        }
        let mut order = Vec::new();
        while b.total() > 0 {
            let t = b.take_batch(0, 0.0);
            order.extend(t.reqs.iter().map(|r| r.tenant));
        }
        // first 8 dequeues: tenant 0 gets ~3/4 despite arriving later
        let head: Vec<u32> = order.iter().take(8).copied().collect();
        let t0 = head.iter().filter(|&&t| t == 0).count();
        assert_eq!(order.len(), 12, "every request dequeues exactly once");
        assert_eq!(t0, 6, "weight-3 tenant takes 3/4 of the first 8 slots, got {head:?}");
        // FIFO within a tenant is preserved
        let t1_ids: Vec<u32> = order.iter().copied().filter(|&t| t == 1).collect();
        assert_eq!(t1_ids.len(), 4);
    }

    #[test]
    fn wfq_expired_picks_are_censused_without_advancing_the_clock() {
        let mut b = Batcher::new(1, 4, 5.0);
        b.set_weighted_fair(vec![1.0, 1.0]);
        b.enqueue(0, QueuedReq { id: 0, arrival_ms: 0.0, deadline_ms: 1.0, tenant: 0, attempt: 0 });
        b.enqueue(0, treq(1, 0.5, 1));
        b.enqueue(0, treq(2, 0.6, 0));
        let t = b.take_batch(0, 10.0);
        assert_eq!(t.expired.len(), 1);
        assert_eq!(t.expired[0].id, 0);
        assert_eq!(t.reqs.len(), 2);
        assert_eq!(b.total(), 0);
    }

    #[test]
    fn wfq_unset_is_fifo() {
        // identical enqueue sequence, no set_weighted_fair: strict FIFO
        let mut b = Batcher::new(1, 1, 5.0);
        for i in 0..4 {
            b.enqueue(0, treq(i, i as f64, (i % 2) as u32));
        }
        let mut ids = Vec::new();
        while b.total() > 0 {
            ids.extend(b.take_batch(0, 0.0).reqs.iter().map(|r| r.id));
        }
        assert_eq!(ids, vec![0, 1, 2, 3]);
    }

    #[test]
    fn peak_is_the_high_water_mark_of_total() {
        let mut b = Batcher::new(2, 8, 5.0);
        assert_eq!(b.peak(), 0);
        b.enqueue(0, req(0, 0.0, 50.0));
        b.enqueue(1, req(1, 1.0, 50.0));
        b.enqueue(0, req(2, 2.0, 50.0));
        assert_eq!(b.peak(), 3);
        b.take_batch(0, 3.0);
        assert_eq!(b.total(), 1);
        assert_eq!(b.peak(), 3, "peak never decreases");
        b.enqueue(0, req(3, 4.0, 50.0));
        assert_eq!(b.peak(), 3, "refilling below the peak leaves it");
        // drain + requeue moves requests without inflating the peak
        let survivors = b.drain(0);
        b.requeue(1, survivors);
        assert_eq!(b.peak(), 3);
        b.enqueue(1, req(4, 5.0, 50.0));
        b.enqueue(1, req(5, 6.0, 50.0));
        assert_eq!(b.peak(), 4);
    }
}
