//! Fleet construction: deployed HQP variants as servable profiles.
//!
//! A [`VariantProfile`] is the serving-level view of one deployed engine
//! (one row of the paper's Tables I/II): its measured accuracy drop plus a
//! per-batch-size latency/energy curve priced by the batched roofline
//! ([`crate::hwsim::simulate_batch`]), plus its engine-memory footprint
//! (`weight_bytes`). A [`Server`] is one edge device holding several
//! deployable variants in a finite engine memory — the *resident* subset
//! is servable now, the rest must be hot-swapped in first; a [`Fleet`] is
//! what the simulator routes over.
//!
//! Two construction paths (DESIGN.md §Serving):
//!
//! * **Workspace-backed** ([`workspace_fleet`]): when `artifacts/` exists,
//!   engines are lowered from the real model manifest through the real
//!   optimizer ([`crate::gopt::optimize`]), with masks and measured
//!   accuracy drops taken from the coordinator's cached result rows
//!   (`artifacts/results/<model>_<method>.json`) when present.
//! * **Reference** ([`reference_fleet`]): without artifacts, engines are
//!   built from the canonical layer tables of the paper's two models at
//!   the paper's 224×224 deployment resolution, with accuracy drops
//!   anchored to the paper's reported numbers. This keeps `hqp serve`,
//!   the serve benches and the property tests runnable (and byte-for-byte
//!   deterministic) on a bare checkout.

use crate::error::{Error, Result};
use crate::gopt::{optimize, weight_elems, FusedKind, FusedOp, OptimizeOptions, OptimizedGraph};
use crate::graph::{full_masks, Graph};
use crate::hqp::{HqpConfig, Schedule};
use crate::hwsim::{simulate_batch, Device, Precision};
use crate::runtime::manifest::Manifest;

/// Canonical schedule string for a serving method name (the preset's
/// canonical form; the raw name for non-preset methods).
fn schedule_label(method: &str) -> String {
    match Schedule::preset(method, &HqpConfig::default()) {
        Some(s) => s.canonical(),
        None => method.to_string(),
    }
}

/// One deployed variant as the serving layer sees it.
#[derive(Clone, Debug, PartialEq)]
pub struct VariantProfile {
    /// Method name (baseline / q8 / p50 / hqp / mixed).
    pub name: String,
    /// Canonical compression-schedule string that produced this variant
    /// ([`crate::hqp::Schedule::canonical`] of the method's preset, e.g.
    /// `measure-baseline >> prune >> ptq` for `hqp`; the raw method name
    /// when no preset matches). Labels fleets by *what was run*, not just
    /// what it was called.
    pub schedule: String,
    /// Measured (or paper-anchored) absolute Top-1 accuracy drop.
    pub acc_drop: f64,
    /// Deployed engine weight storage ([`crate::gopt::OptimizedGraph`]'s
    /// `weight_bytes`, itself built on [`crate::gopt::weight_elems`]) —
    /// the variant's memory footprint, which residency accounting and the
    /// hot-swap cost model ([`crate::hwsim::Device::swap_in_ms`]) price.
    pub weight_bytes: u64,
    /// Whole-batch service time for batch size `b` at `batch_ms[b - 1]`.
    pub batch_ms: Vec<f64>,
    /// Whole-batch energy (mJ), same indexing.
    pub energy_mj: Vec<f64>,
}

impl VariantProfile {
    /// Price `engine` on `dev` for batch sizes `1..=max_batch`.
    pub fn from_engine(
        name: &str,
        acc_drop: f64,
        engine: &OptimizedGraph,
        dev: &Device,
        max_batch: usize,
    ) -> VariantProfile {
        let mut batch_ms = Vec::with_capacity(max_batch);
        let mut energy_mj = Vec::with_capacity(max_batch);
        for b in 1..=max_batch.max(1) {
            let r = simulate_batch(engine, dev, b);
            batch_ms.push(r.latency_ms);
            energy_mj.push(r.energy_mj);
        }
        VariantProfile {
            name: name.to_string(),
            schedule: schedule_label(name),
            acc_drop,
            weight_bytes: engine.weight_bytes,
            batch_ms,
            energy_mj,
        }
    }

    /// Batch-1 service time, ms.
    pub fn batch1_ms(&self) -> f64 {
        self.batch_ms[0]
    }

    /// Peak sustainable throughput over the supported batch sizes,
    /// requests per second.
    pub fn capacity_rps(&self) -> f64 {
        self.batch_ms
            .iter()
            .enumerate()
            .map(|(i, &ms)| (i + 1) as f64 / ms * 1e3)
            .fold(0.0, f64::max)
    }

    /// Δ_max compliance of this variant (the admission criterion).
    pub fn compliant(&self, delta_max: f64) -> bool {
        self.acc_drop <= delta_max
    }
}

/// One edge device with its deployable variants.
///
/// With `mem_capacity_bytes == None` (the default, and the pre-residency
/// behavior) every variant is permanently resident and swaps never
/// happen. With a finite capacity the device distinguishes *resident*
/// variants (engine weights in memory, servable now) from *merely
/// deployable* ones (known profiles that must be swapped in first, at
/// [`Server::swap_in_ms`] cost).
#[derive(Clone, Debug)]
pub struct Server {
    pub device: Device,
    pub variants: Vec<VariantProfile>,
    /// Engine memory capacity. `None` = unlimited (all variants resident).
    pub mem_capacity_bytes: Option<u64>,
}

impl Server {
    /// A server with unlimited engine memory (every variant resident).
    pub fn new(device: Device, variants: Vec<VariantProfile>) -> Server {
        Server { device, variants, mem_capacity_bytes: None }
    }

    /// The deterministic initial resident set: greedy in variant order,
    /// loading each variant that still fits the capacity. Unlimited
    /// capacity loads everything — the pre-residency behavior.
    pub fn initial_residency(&self) -> Vec<bool> {
        let Some(cap) = self.mem_capacity_bytes else {
            return vec![true; self.variants.len()];
        };
        let mut used = 0u64;
        self.variants
            .iter()
            .map(|v| {
                if used + v.weight_bytes <= cap {
                    used += v.weight_bytes;
                    true
                } else {
                    false
                }
            })
            .collect()
    }

    /// Total weight bytes across this server's variants (what unlimited
    /// residency would occupy).
    pub fn total_variant_bytes(&self) -> u64 {
        self.variants.iter().map(|v| v.weight_bytes).sum()
    }

    /// Hot-swap cost of loading variant `v` on this device: engine weight
    /// streaming over DRAM bandwidth plus the fixed init overhead
    /// ([`Device::swap_in_ms`]).
    pub fn swap_in_ms(&self, v: usize, init_ms: f64) -> f64 {
        self.device.swap_in_ms(self.variants[v].weight_bytes, init_ms)
    }
}

/// The fleet the simulator routes over.
///
/// A fleet is just its servers; *how many of them are awake* is decided
/// at simulation time: with autoscaling off every server is permanently
/// active, with an [`crate::serve::AutoscaleConfig`] policy enabled the
/// controller keeps between `min_active` and `max_active` servers awake
/// (the bounds live in the config — the fleet itself stays a passive
/// description). [`Fleet::replicate_to`] grows a fleet to the peak size
/// an elastic run may scale up to.
#[derive(Clone, Debug)]
pub struct Fleet {
    /// Model every variant was compressed from (display only).
    pub model: String,
    /// The devices (with their deployable variants) the router sees.
    pub servers: Vec<Server>,
}

/// Per-request input payload at the paper's 224×224 deployment
/// resolution (one uint8 image) — what the optional network/RPC link
/// model charges per request.
pub const INPUT_BYTES: u64 = 224 * 224 * 3;

impl Fleet {
    /// Single-device fleet.
    pub fn single(model: &str, device: Device, variants: Vec<VariantProfile>) -> Fleet {
        Fleet {
            model: model.to_string(),
            servers: vec![Server::new(device, variants)],
        }
    }

    /// Cap every server's engine memory at `mb` megabytes (1 MB = 1e6
    /// bytes, consistent with the SI GB/s bandwidth constants). The CLI's
    /// `--mem-mb` entry point.
    pub fn with_mem_cap_mb(mut self, mb: f64) -> Fleet {
        for s in &mut self.servers {
            s.mem_capacity_bytes = Some((mb * 1e6) as u64);
        }
        self
    }

    /// Whether any server runs with a finite engine-memory capacity.
    pub fn residency_limited(&self) -> bool {
        self.servers.iter().any(|s| s.mem_capacity_bytes.is_some())
    }

    /// Grow the fleet to `n` servers by cloning the existing ones
    /// cyclically (server `i` is a copy of original `i % len`) — the
    /// CLI's `--max-servers` entry point, sizing the peak capacity an
    /// autoscaled run may wake up to. Shrinking is refused: dropping
    /// servers a caller explicitly constructed would silently change the
    /// experiment.
    pub fn replicate_to(mut self, n: usize) -> Result<Fleet> {
        if self.servers.is_empty() {
            return Err(Error::hqp("serve: cannot replicate an empty fleet"));
        }
        if n < self.servers.len() {
            return Err(Error::hqp(format!(
                "serve: replicate_to({n}) would shrink a {}-server fleet",
                self.servers.len()
            )));
        }
        let base = self.servers.len();
        for i in base..n {
            self.servers.push(self.servers[i % base].clone());
        }
        Ok(self)
    }

    /// Request input payload, bytes ([`INPUT_BYTES`]).
    pub fn input_bytes(&self) -> u64 {
        INPUT_BYTES
    }

    /// Largest batch size every variant supports.
    pub fn max_batch(&self) -> usize {
        self.servers
            .iter()
            .flat_map(|s| s.variants.iter().map(|v| v.batch_ms.len()))
            .min()
            .unwrap_or(0)
    }

    /// Total variant count across servers.
    pub fn num_variants(&self) -> usize {
        self.servers.iter().map(|s| s.variants.len()).sum()
    }
}

// ---------------------------------------------------------------------------
// Reference engines (no-artifacts path)
// ---------------------------------------------------------------------------

/// Per-method compression stats anchored to the paper's Tables I/II:
/// `(filter sparsity θ, absolute Top-1 accuracy drop)`. `p50`
/// deliberately violates the Δ_max = 1.5 % budget — the paper's
/// single-objective strawman — so the accuracy-constrained router must
/// refuse it.
pub fn reference_stats(model: &str, method: &str) -> Result<(f64, f64)> {
    let v = match (model, method) {
        ("resnet18", "baseline") => (0.0, 0.0),
        ("resnet18", "q8") => (0.0, 0.0041),
        ("resnet18", "p50") => (0.50, 0.0208),
        ("resnet18", "hqp") => (0.45, 0.0119),
        ("resnet18", "mixed") => (0.45, 0.0135),
        ("mobilenetv3", "baseline") => (0.0, 0.0),
        ("mobilenetv3", "q8") => (0.0, 0.0052),
        ("mobilenetv3", "p50") => (0.50, 0.0231),
        ("mobilenetv3", "hqp") => (0.45, 0.0128),
        ("mobilenetv3", "mixed") => (0.45, 0.0142),
        _ => {
            return Err(Error::hqp(format!(
                "no reference stats for {model}/{method} \
                 (models: resnet18|mobilenetv3; methods: baseline|q8|p50|hqp|mixed)"
            )))
        }
    };
    Ok(v)
}

/// One layer of a reference model: `(kind, k, cin, cout, spatial side)`.
type LayerSpec = (FusedKind, usize, usize, usize, usize);

/// ResNet-18 at the paper's 224×224 deployment resolution (stem + 4
/// stages of 2 basic blocks + 1×1 downsamples + head).
fn resnet18_layers() -> Vec<LayerSpec> {
    use FusedKind::*;
    let mut l = vec![(ConvBnAct, 7, 3, 64, 112)];
    for _ in 0..4 {
        l.push((ConvBnAct, 3, 64, 64, 56));
    }
    for &(c_in, c, hw) in &[(64usize, 128usize, 28usize), (128, 256, 14), (256, 512, 7)] {
        l.push((ConvBnAct, 3, c_in, c, hw));
        l.push((ConvBnAct, 1, c_in, c, hw)); // downsample shortcut
        for _ in 0..3 {
            l.push((ConvBnAct, 3, c, c, hw));
        }
    }
    l.push((Pool, 1, 512, 512, 1));
    l.push((Gemm, 1, 512, 1000, 1));
    l
}

/// MobileNetV3 (compact block-level approximation: expand 1×1 / depthwise
/// / project 1×1 triples at representative channel widths; SE blocks
/// folded into the surrounding convs — see DESIGN.md §Serving).
fn mobilenetv3_layers() -> Vec<LayerSpec> {
    use FusedKind::*;
    let blocks: &[(usize, usize, usize, usize, usize)] = &[
        // (expand cin, expanded, k_dw, project cout, spatial side)
        (16, 64, 3, 24, 56),
        (24, 72, 3, 40, 28),
        (40, 120, 5, 80, 14),
        (80, 200, 3, 112, 14),
        (112, 336, 5, 160, 7),
    ];
    let mut l = vec![
        (ConvBnAct, 3, 3, 16, 112),
        (DwConvBnAct, 3, 16, 16, 112),
        (ConvBnAct, 1, 16, 16, 56),
    ];
    for &(cin, exp, k, cout, hw) in blocks {
        l.push((ConvBnAct, 1, cin, exp, hw));
        l.push((DwConvBnAct, k, exp, exp, hw));
        l.push((ConvBnAct, 1, exp, cout, hw));
    }
    l.push((ConvBnAct, 1, 160, 960, 7));
    l.push((Pool, 1, 960, 960, 1));
    l.push((Gemm, 1, 960, 1280, 1));
    l.push((Gemm, 1, 1280, 1000, 1));
    l
}

/// Channel width after structural pruning at sparsity θ. Graph inputs
/// (3 image channels) and the classifier width (1000 classes) are never
/// pruned; everything else keeps at least one filter.
fn pruned(c: usize, theta: f64) -> usize {
    if c == 3 || c == 1000 {
        return c;
    }
    (((c as f64) * (1.0 - theta)).round() as usize).max(1)
}

/// Activation storage bytes per element for an engine at `p` weight
/// precision (int8 engines stream int8 activations; the mixed plan keeps
/// fp16 activations around its int4 weights).
fn act_bytes(p: Precision) -> f64 {
    match p {
        Precision::Fp32 => 4.0,
        Precision::Fp16 => 2.0,
        Precision::Int8 => 1.0,
        Precision::Int4 => 2.0,
    }
}

fn layer_flops(kind: FusedKind, k: usize, cin: usize, cout: usize, hw: usize) -> u64 {
    let sp = (hw * hw) as u64;
    match kind {
        FusedKind::ConvBnAct => 2 * (k * k * cin * cout) as u64 * sp,
        FusedKind::DwConvBnAct => 2 * (k * k * cout) as u64 * sp,
        FusedKind::Gemm => 2 * (cin * cout) as u64,
        FusedKind::Se => 2 * (cin * cout / 4) as u64,
        FusedKind::Elementwise => cout as u64 * sp,
        FusedKind::Pool => cin as u64 * 49, // post-GAP reduction remnant
    }
}

/// Build a reference engine: the layer table at sparsity θ, priced at
/// weight precision chosen per op by `prec`.
fn build_engine(
    model: &str,
    layers: &[LayerSpec],
    theta: f64,
    prec: impl Fn(usize) -> Precision,
) -> OptimizedGraph {
    let mut ops = Vec::with_capacity(layers.len());
    let mut weight_bytes = 0u64;
    let mut dense_weight_bytes = 0u64;
    for (i, &(kind, k, cin, cout, hw)) in layers.iter().enumerate() {
        let p = prec(i);
        let (pc_in, pc_out) = (pruned(cin, theta), pruned(cout, theta));
        let w_elems = weight_elems(kind, k, pc_in, pc_out);
        let w = (w_elems as f64 * p.bytes()) as u64;
        let acts =
            ((hw * hw) as f64 * (pc_in + pc_out) as f64 * act_bytes(p)) as u64;
        dense_weight_bytes += weight_elems(kind, k, cin, cout) * 4;
        weight_bytes += w;
        ops.push(FusedOp {
            name: format!("{model}.l{i}"),
            kind,
            flops: layer_flops(kind, k, pc_in, pc_out, hw),
            bytes: w + acts,
            precision: p,
            h: hw,
            w: hw,
            cin: pc_in,
            cout: pc_out,
            k,
        });
    }
    OptimizedGraph {
        model: model.to_string(),
        ops,
        weight_bytes,
        dense_weight_bytes,
    }
}

/// Build the reference engine + accuracy drop for one method.
pub fn reference_engine(model: &str, method: &str) -> Result<(OptimizedGraph, f64)> {
    let (theta, acc_drop) = reference_stats(model, method)?;
    let layers = match model {
        "resnet18" => resnet18_layers(),
        "mobilenetv3" => mobilenetv3_layers(),
        _ => return Err(Error::hqp(format!("unknown reference model {model}"))),
    };
    let n = layers.len();
    let engine = match method {
        "baseline" | "p50" => build_engine(model, &layers, theta, |_| Precision::Fp32),
        "q8" | "hqp" => build_engine(model, &layers, theta, |_| Precision::Int8),
        // mixed (§VI-A): the low-S back half of the network drops to INT4
        "mixed" => build_engine(model, &layers, theta, move |i| {
            if i >= n / 2 {
                Precision::Int4
            } else {
                Precision::Int8
            }
        }),
        other => return Err(Error::hqp(format!("unknown method {other}"))),
    };
    Ok((engine, acc_drop))
}

/// Build a reference engine at an arbitrary compression point — the
/// search subsystem's pricing hook. `theta` is the filter sparsity,
/// `int8` selects the deployed numeric regime, and `int4_back_frac` is
/// the fraction of trailing layers dropped to INT4 (0 for non-mixed
/// engines; only meaningful when `int8`).
pub fn reference_engine_at(
    model: &str,
    theta: f64,
    int8: bool,
    int4_back_frac: f64,
) -> Result<OptimizedGraph> {
    let layers = match model {
        "resnet18" => resnet18_layers(),
        "mobilenetv3" => mobilenetv3_layers(),
        _ => return Err(Error::hqp(format!("unknown reference model {model}"))),
    };
    let n = layers.len();
    let int4_from = n - ((n as f64) * int4_back_frac.clamp(0.0, 1.0)).round() as usize;
    Ok(build_engine(model, &layers, theta, move |i| {
        if !int8 {
            Precision::Fp32
        } else if i >= int4_from {
            Precision::Int4
        } else {
            Precision::Int8
        }
    }))
}

/// Reference fleet: one [`Server`] per device, each loaded with the
/// requested method variants.
pub fn reference_fleet(
    model: &str,
    devices: &[Device],
    methods: &[&str],
    max_batch: usize,
) -> Result<Fleet> {
    let mut servers = Vec::with_capacity(devices.len());
    for dev in devices {
        let mut variants = Vec::with_capacity(methods.len());
        for m in methods {
            let (engine, acc_drop) = reference_engine(model, m)?;
            variants.push(VariantProfile::from_engine(m, acc_drop, &engine, dev, max_batch));
        }
        servers.push(Server::new(dev.clone(), variants));
    }
    Ok(Fleet { model: model.to_string(), servers })
}

// ---------------------------------------------------------------------------
// Workspace-backed fleet (artifacts path)
// ---------------------------------------------------------------------------

/// Build the fleet from a real workspace manifest, pulling masks and
/// measured accuracy drops from the coordinator's cached result rows when
/// available (falling back to the reference θ / acc-drop constants for
/// methods that have not been run yet). Returns `Ok(None)` when no
/// manifest exists so callers can fall back to [`reference_fleet`].
pub fn workspace_fleet(
    artifacts_root: &str,
    model: &str,
    devices: &[Device],
    methods: &[&str],
    max_batch: usize,
) -> Result<Option<Fleet>> {
    let root = std::path::Path::new(artifacts_root);
    if !root.join("manifest.json").exists() {
        return Ok(None);
    }
    let manifest = Manifest::load(root)?;
    let mm = manifest.model(model)?;
    let graph = Graph::from_manifest(mm)?;
    let results_dir = root.join("results");

    let mut servers = Vec::with_capacity(devices.len());
    for dev in devices {
        let mut variants = Vec::with_capacity(methods.len());
        for m in methods {
            let (ref_theta, ref_drop) = reference_stats(model, m)?;
            // cached coordinator row → measured acc_drop + per-group
            // masks. v2 schedule-slug keys first, legacy v1 method keys
            // as fallback (load_schedule_results); methods without a
            // schedule preset only ever had v1 keys.
            let cached = match Schedule::preset(m, &HqpConfig::default()) {
                Some(sched) => crate::coordinator::load_schedule_results(
                    &results_dir,
                    model,
                    &sched,
                )?,
                None => crate::coordinator::load_results(
                    &results_dir,
                    &format!("{model}_{m}"),
                )?,
            };
            let (group_sparsity, acc_drop) = match cached.as_ref().and_then(|r| r.first()) {
                Some(row) => (Some(row.group_sparsity.clone()), row.report.acc_drop),
                None => (None, ref_drop),
            };
            // per-group kill counts, clamped to leave one survivor per
            // group: a cached row can carry group_sparsity == 1.0 (the
            // p50 magnitude ranking has no per-group guard) and a
            // zero-channel group would feed gopt a degenerate engine
            let mut masks = full_masks(&graph);
            for (g, mask) in masks.iter_mut().enumerate() {
                let s = group_sparsity
                    .as_ref()
                    .and_then(|gs| gs.get(g).copied())
                    .unwrap_or(ref_theta);
                let kill = (mask.len() as f64 * s).round() as usize;
                for slot in mask.iter_mut().take(kill.min(mask.len().saturating_sub(1))) {
                    *slot = false;
                }
            }
            let opts = match *m {
                "baseline" | "p50" => OptimizeOptions::fp32(),
                _ => OptimizeOptions::int8(),
            };
            let engine = optimize(&graph, &masks, &opts)?;
            variants.push(VariantProfile::from_engine(m, acc_drop, &engine, dev, max_batch));
        }
        servers.push(Server::new(dev.clone(), variants));
    }
    Ok(Some(Fleet { model: model.to_string(), servers }))
}

/// The default fleet for the CLI: workspace-backed when artifacts exist,
/// reference otherwise. Returns the fleet and the source label printed by
/// `hqp serve`.
pub fn fleet_for(
    artifacts_root: &str,
    model: &str,
    devices: &[Device],
    methods: &[&str],
    max_batch: usize,
) -> Result<(Fleet, &'static str)> {
    match workspace_fleet(artifacts_root, model, devices, methods, max_batch)? {
        Some(f) => Ok((f, "workspace engines (artifacts/)")),
        None => Ok((
            reference_fleet(model, devices, methods, max_batch)?,
            "reference engines (no artifacts — paper-anchored profiles)",
        )),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hqp_is_much_faster_than_baseline_on_nx() {
        let dev = Device::xavier_nx();
        let f =
            reference_fleet("resnet18", &[dev], &["baseline", "hqp"], 8).unwrap();
        let v = &f.servers[0].variants;
        let speedup = v[0].batch1_ms() / v[1].batch1_ms();
        assert!(
            speedup > 3.0,
            "serving-level analogue of the paper's 3.12x: got {speedup:.2}x"
        );
        assert!(v[1].capacity_rps() > v[0].capacity_rps() * 3.0);
    }

    #[test]
    fn p50_violates_delta_max_and_hqp_complies() {
        for model in ["resnet18", "mobilenetv3"] {
            let (_, p50) = reference_stats(model, "p50").unwrap();
            let (_, hqp) = reference_stats(model, "hqp").unwrap();
            assert!(p50 > 0.015, "{model}: p50 must violate the budget");
            assert!(hqp <= 0.015, "{model}: hqp must comply");
        }
    }

    #[test]
    fn batch_curve_is_monotone_and_amortizing() {
        let dev = Device::xavier_nx();
        let (engine, drop) = reference_engine("mobilenetv3", "hqp").unwrap();
        let v = VariantProfile::from_engine("hqp", drop, &engine, &dev, 16);
        for b in 1..v.batch_ms.len() {
            assert!(v.batch_ms[b] > v.batch_ms[b - 1], "batch curve monotone");
            // per-sample cost must not grow with batching
            let per_b = v.batch_ms[b] / (b + 1) as f64;
            let per_1 = v.batch_ms[0];
            assert!(per_b <= per_1 + 1e-12, "batching must amortize");
        }
        assert_eq!(v.batch_ms.len(), 16);
        assert_eq!(v.energy_mj.len(), 16);
    }

    #[test]
    fn size_reduction_orders_methods() {
        let (base, _) = reference_engine("resnet18", "baseline").unwrap();
        let (hqp, _) = reference_engine("resnet18", "hqp").unwrap();
        let (q8, _) = reference_engine("resnet18", "q8").unwrap();
        assert_eq!(base.size_reduction(), 0.0);
        assert!(q8.size_reduction() > 0.7, "int8 quarters storage");
        assert!(
            hqp.size_reduction() > q8.size_reduction(),
            "pruning + int8 beats int8 alone"
        );
    }

    #[test]
    fn nano_narrows_the_q8_gap() {
        // §IV-A heterogeneity: without INT8 tensor cores the q8 engine's
        // advantage over fp32 shrinks on Nano vs NX
        let nx = Device::xavier_nx();
        let nano = Device::jetson_nano();
        let f = reference_fleet("resnet18", &[nx, nano], &["baseline", "q8"], 1).unwrap();
        let gain = |s: &Server| s.variants[0].batch1_ms() / s.variants[1].batch1_ms();
        assert!(gain(&f.servers[0]) > gain(&f.servers[1]));
    }

    #[test]
    fn unknown_model_or_method_errors() {
        assert!(reference_engine("vgg", "hqp").is_err());
        assert!(reference_engine("resnet18", "qat").is_err());
        assert!(reference_stats("resnet18", "hqp").is_ok());
    }

    #[test]
    fn weight_footprints_order_methods_and_anchor_the_cap() {
        // resnet18 dense fp32 is ~46.7 MB; hqp (θ=0.45, int8) is ~3.7 MB.
        // The 48 MB demo cap (EXPERIMENTS.md) holds baseline alone but not
        // baseline + hqp — the scenario the swap-aware policy exploits.
        let (base, _) = reference_engine("resnet18", "baseline").unwrap();
        let (hqp, _) = reference_engine("resnet18", "hqp").unwrap();
        assert!(base.weight_bytes > 46_000_000 && base.weight_bytes < 48_000_000);
        assert!(hqp.weight_bytes > 3_000_000 && hqp.weight_bytes < 4_500_000);
        let f = reference_fleet("resnet18", &[Device::xavier_nx()], &["baseline", "hqp"], 4)
            .unwrap()
            .with_mem_cap_mb(48.0);
        assert!(f.residency_limited());
        assert_eq!(f.servers[0].initial_residency(), vec![true, false]);
        assert_eq!(
            f.servers[0].variants[0].weight_bytes, base.weight_bytes,
            "profile must carry the engine footprint"
        );
    }

    #[test]
    fn initial_residency_is_greedy_in_variant_order() {
        fn var(name: &str, bytes: u64) -> VariantProfile {
            VariantProfile {
                name: name.into(),
                schedule: String::new(),
                acc_drop: 0.0,
                weight_bytes: bytes,
                batch_ms: vec![1.0],
                energy_mj: vec![1.0],
            }
        }
        let mut s = Server::new(
            Device::ideal(),
            vec![var("a", 50_000_000), var("b", 10_000_000), var("c", 30_000_000)],
        );
        assert_eq!(s.initial_residency(), vec![true, true, true], "unlimited loads all");
        s.mem_capacity_bytes = Some(60_000_000);
        assert_eq!(s.initial_residency(), vec![true, true, false]);
        s.mem_capacity_bytes = Some(5_000_000);
        assert_eq!(s.initial_residency(), vec![false, false, false]);
        assert_eq!(s.total_variant_bytes(), 90_000_000);
        // swap cost delegates to the device model
        let want = s.device.swap_in_ms(10_000_000, 3.0);
        assert_eq!(s.swap_in_ms(1, 3.0), want);
    }

    #[test]
    fn replicate_to_clones_cyclically_and_refuses_to_shrink() {
        let f = reference_fleet(
            "resnet18",
            &[Device::xavier_nx(), Device::jetson_nano()],
            &["hqp"],
            2,
        )
        .unwrap();
        let g = f.clone().replicate_to(5).unwrap();
        assert_eq!(g.servers.len(), 5);
        for (i, s) in g.servers.iter().enumerate() {
            assert_eq!(s.device.name, g.servers[i % 2].device.name, "cyclic clone order");
            assert_eq!(s.variants[0].batch_ms, g.servers[i % 2].variants[0].batch_ms);
        }
        // same size is a no-op, smaller is an error
        assert_eq!(f.clone().replicate_to(2).unwrap().servers.len(), 2);
        assert!(f.replicate_to(1).is_err());
        let empty = Fleet { model: "m".into(), servers: vec![] };
        assert!(empty.replicate_to(3).is_err());
    }

    #[test]
    fn variant_profiles_carry_schedule_labels() {
        let f = reference_fleet(
            "resnet18",
            &[Device::xavier_nx()],
            &["baseline", "q8", "p50", "hqp", "mixed"],
            1,
        )
        .unwrap();
        let v = &f.servers[0].variants;
        assert_eq!(v[0].schedule, "measure-baseline");
        assert_eq!(v[1].schedule, "measure-baseline >> ptq");
        assert_eq!(v[2].schedule, "measure-baseline >> prune-to(mag-l1,theta=50%)");
        assert_eq!(v[3].schedule, "measure-baseline >> prune >> ptq");
        assert_eq!(v[4].schedule, "measure-baseline >> prune >> ptq >> mixed");
    }

    #[test]
    fn workspace_fleet_absent_is_none() {
        let got =
            workspace_fleet("/nonexistent/artifacts", "resnet18", &[Device::ideal()], &["hqp"], 2)
                .unwrap();
        assert!(got.is_none());
    }
}
