//! SLO-aware routing: pick a (server, variant) per request.
//!
//! Every policy routes only over the *compliant* candidate set — variants
//! whose measured accuracy drop is within Δ_max. This lifts the paper's
//! pruning-level guarantee (Algorithm 1's accept condition) into a
//! serving-level admission criterion: a request can never be served by an
//! engine that violates the accuracy budget, no matter the load. When no
//! compliant variant exists the router returns `None` and the request is
//! rejected at admission.

use super::fleet::Fleet;

/// Routing policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Policy {
    /// Cycle through the compliant (server, variant) pairs.
    RoundRobin,
    /// Least-loaded server (by estimated backlog ms), fastest compliant
    /// variant on it.
    LeastLoaded,
    /// Accuracy-constrained fastest: minimize estimated completion time
    /// (server backlog + the variant's batch-1 service time) over all
    /// compliant pairs.
    AccFastest,
}

impl Policy {
    pub fn parse(name: &str) -> Option<Policy> {
        match name {
            "round-robin" | "rr" => Some(Policy::RoundRobin),
            "least-loaded" | "ll" => Some(Policy::LeastLoaded),
            "acc-fastest" | "af" => Some(Policy::AccFastest),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Policy::RoundRobin => "round-robin",
            Policy::LeastLoaded => "least-loaded",
            Policy::AccFastest => "acc-fastest",
        }
    }
}

/// A routable (server, variant) pair.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct Candidate {
    pub server: usize,
    pub variant: usize,
}

/// The router: a policy over the precomputed compliant candidate set.
#[derive(Clone, Debug)]
pub struct Router {
    policy: Policy,
    candidates: Vec<Candidate>,
    /// batch-1 ms per candidate (est. completion = backlog + this).
    batch1_ms: Vec<f64>,
    acc_drop: Vec<f64>,
    rr_cursor: usize,
}

impl Router {
    /// Build the compliant candidate set (enumeration order: server index,
    /// then variant index — the deterministic tie-break everywhere).
    pub fn new(fleet: &Fleet, delta_max: f64, policy: Policy) -> Router {
        let mut candidates = Vec::new();
        let mut batch1_ms = Vec::new();
        let mut acc_drop = Vec::new();
        for (s, server) in fleet.servers.iter().enumerate() {
            for (v, var) in server.variants.iter().enumerate() {
                if var.compliant(delta_max) {
                    candidates.push(Candidate { server: s, variant: v });
                    batch1_ms.push(var.batch1_ms());
                    acc_drop.push(var.acc_drop);
                }
            }
        }
        Router { policy, candidates, batch1_ms, acc_drop, rr_cursor: 0 }
    }

    /// Number of compliant (server, variant) pairs.
    pub fn num_candidates(&self) -> usize {
        self.candidates.len()
    }

    /// Route one request. `backlog_ms[s]` estimates server `s`'s current
    /// backlog (remaining busy time + queued work). Returns `None` when no
    /// compliant variant exists anywhere in the fleet.
    pub fn route(&mut self, backlog_ms: &[f64]) -> Option<Candidate> {
        if self.candidates.is_empty() {
            return None;
        }
        match self.policy {
            Policy::RoundRobin => {
                let c = self.candidates[self.rr_cursor % self.candidates.len()];
                self.rr_cursor = (self.rr_cursor + 1) % self.candidates.len();
                Some(c)
            }
            Policy::LeastLoaded => {
                // least-loaded server among those with a compliant variant…
                let mut best_server = None::<(f64, usize)>;
                for c in &self.candidates {
                    let load = backlog_ms[c.server];
                    let better = match best_server {
                        None => true,
                        Some((l, s)) => load < l || (load == l && c.server < s),
                    };
                    if better {
                        best_server = Some((load, c.server));
                    }
                }
                let (_, server) = best_server?;
                // …then its fastest compliant variant
                self.best_on(server, |i| self.batch1_ms[i])
            }
            Policy::AccFastest => {
                let mut best = None::<(f64, f64, usize)>; // (finish, drop, idx)
                for (i, c) in self.candidates.iter().enumerate() {
                    let finish = backlog_ms[c.server] + self.batch1_ms[i];
                    let key = (finish, self.acc_drop[i]);
                    let better = match best {
                        None => true,
                        Some((f, d, _)) => key.0 < f || (key.0 == f && key.1 < d),
                    };
                    if better {
                        best = Some((key.0, key.1, i));
                    }
                }
                best.map(|(_, _, i)| self.candidates[i])
            }
        }
    }

    /// Lowest-key candidate on one server (first index wins ties).
    fn best_on(&self, server: usize, key: impl Fn(usize) -> f64) -> Option<Candidate> {
        let mut best = None::<(f64, usize)>;
        for (i, c) in self.candidates.iter().enumerate() {
            if c.server != server {
                continue;
            }
            let k = key(i);
            let better = match best {
                None => true,
                Some((bk, _)) => k < bk,
            };
            if better {
                best = Some((k, i));
            }
        }
        best.map(|(_, i)| self.candidates[i])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::fleet::{Fleet, Server, VariantProfile};
    use crate::hwsim::Device;

    fn var(name: &str, acc_drop: f64, ms: f64) -> VariantProfile {
        VariantProfile {
            name: name.into(),
            acc_drop,
            batch_ms: vec![ms, ms * 1.6],
            energy_mj: vec![ms * 10.0, ms * 16.0],
        }
    }

    fn fleet() -> Fleet {
        Fleet {
            model: "m".into(),
            servers: vec![
                Server {
                    device: Device::xavier_nx(),
                    variants: vec![
                        var("baseline", 0.0, 8.0),
                        var("p50", 0.021, 1.0), // violates Δmax
                        var("hqp", 0.012, 0.5),
                    ],
                },
                Server {
                    device: Device::jetson_nano(),
                    variants: vec![var("baseline", 0.0, 20.0), var("hqp", 0.012, 4.0)],
                },
            ],
        }
    }

    #[test]
    fn non_compliant_variants_are_never_candidates() {
        let r = Router::new(&fleet(), 0.015, Policy::AccFastest);
        assert_eq!(r.num_candidates(), 4, "p50 must be excluded");
        let mut r = Router::new(&fleet(), 0.015, Policy::RoundRobin);
        for _ in 0..20 {
            let c = r.route(&[0.0, 0.0]).unwrap();
            assert!(!(c.server == 0 && c.variant == 1), "routed to p50");
        }
    }

    #[test]
    fn no_compliant_variant_means_reject() {
        let mut f = fleet();
        f.servers.truncate(1);
        f.servers[0].variants = vec![var("p50", 0.021, 1.0)];
        for policy in [Policy::RoundRobin, Policy::LeastLoaded, Policy::AccFastest] {
            let mut r = Router::new(&f, 0.015, policy);
            assert_eq!(r.route(&[0.0]), None);
        }
    }

    #[test]
    fn round_robin_cycles_deterministically() {
        let mut r = Router::new(&fleet(), 0.015, Policy::RoundRobin);
        let seq: Vec<Candidate> = (0..8).map(|_| r.route(&[0.0, 0.0]).unwrap()).collect();
        assert_eq!(seq[0], seq[4]);
        assert_eq!(seq[1], seq[5]);
        let distinct: std::collections::BTreeSet<Candidate> = seq[..4].iter().copied().collect();
        assert_eq!(distinct.len(), 4, "first cycle visits all 4 compliant pairs");
    }

    #[test]
    fn acc_fastest_picks_global_fastest_then_respects_backlog() {
        let mut r = Router::new(&fleet(), 0.015, Policy::AccFastest);
        let c = r.route(&[0.0, 0.0]).unwrap();
        assert_eq!((c.server, c.variant), (0, 2), "hqp on NX is fastest");
        // heavy NX backlog shifts routing to Nano's hqp
        let c = r.route(&[100.0, 0.0]).unwrap();
        assert_eq!((c.server, c.variant), (1, 1));
    }

    #[test]
    fn least_loaded_prefers_idle_server() {
        let mut r = Router::new(&fleet(), 0.015, Policy::LeastLoaded);
        let c = r.route(&[50.0, 1.0]).unwrap();
        assert_eq!(c.server, 1);
        assert_eq!(c.variant, 1, "fastest compliant on nano is hqp");
        let c = r.route(&[0.0, 1.0]).unwrap();
        assert_eq!((c.server, c.variant), (0, 2));
    }

    #[test]
    fn parse_policy_names() {
        assert_eq!(Policy::parse("acc-fastest"), Some(Policy::AccFastest));
        assert_eq!(Policy::parse("rr"), Some(Policy::RoundRobin));
        assert_eq!(Policy::parse("least-loaded"), Some(Policy::LeastLoaded));
        assert!(Policy::parse("random").is_none());
        assert_eq!(Policy::AccFastest.name(), "acc-fastest");
    }
}
