//! SLO-aware routing: pick a (server, variant) per request, and decide
//! when a device should hot-swap its resident variant set.
//!
//! Every policy routes only over the *compliant* candidate set — variants
//! whose measured accuracy drop is within Δ_max. This lifts the paper's
//! pruning-level guarantee (Algorithm 1's accept condition) into a
//! serving-level admission criterion: a request can never be served by an
//! engine that violates the accuracy budget, no matter the load. Stateful
//! residency adds a second filter: [`Router::route`] only offers policies
//! the *live* candidates — compliant pairs whose variant is resident on
//! an available (not mid-swap) server — so a non-resident engine can
//! never be scheduled either. When no live candidate exists the router
//! returns `None` and the request is rejected at admission.
//!
//! ## The `RoutePolicy` trait
//!
//! Policies are open-ended implementations of [`RoutePolicy`] over a
//! [`FleetView`] snapshot (backlogs, queue depths, residency, and
//! availability — a server is unavailable while a swap is pending or in
//! flight, and, under autoscaling, whenever it is not
//! [`crate::serve::Lifecycle::Active`]) plus the static [`RouteCtx`]
//! tables derived from the fleet at build time. The CLI-facing
//! [`Policy`] enum is just a name registry ([`Policy::NAMES`]) that
//! builds the trait object. Besides routing, a policy may propose an
//! engine hot-swap ([`RoutePolicy::plan_swap`]); the event loop executes
//! the plan, charging the HALP-style swap cost
//! ([`crate::hwsim::Device::swap_in_ms`]). Fleet *sizing* is not routed
//! here: scale decisions belong to the separate
//! [`crate::serve::AutoscalePolicy`] control plane, which reuses this
//! module's [`FleetView`] as its input snapshot.

use super::fleet::Fleet;

/// Routing policy names — the CLI registry. [`Policy::build`] yields the
/// actual [`RoutePolicy`] implementation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Policy {
    /// Cycle through the live (server, variant) pairs.
    RoundRobin,
    /// Least-loaded server (by estimated backlog ms), fastest live
    /// variant on it.
    LeastLoaded,
    /// Accuracy-constrained fastest: minimize estimated completion time
    /// (server backlog + the variant's batch-1 service time) over all
    /// live pairs.
    AccFastest,
    /// [`Policy::AccFastest`] routing plus hot-swap planning: under
    /// sustained queue pressure, swap a faster compliant variant into a
    /// capacity-limited server when the projected queue-clearing saving
    /// exceeds the swap cost.
    SwapAware,
    /// Energy-aware routing for heterogeneous fleets: minimize expected
    /// energy per SLO-met request — each live pair is scored by its
    /// batch-1 energy (the profiles' E = P·L) divided by an estimated
    /// probability the request still meets its SLO on that server.
    JoulesPerSlo,
}

impl Policy {
    /// Canonical CLI names, in enum order — the single source of truth
    /// shared by [`Policy::parse`], [`Policy::name`] and the `main.rs`
    /// "valid: …" error strings.
    pub const NAMES: [&'static str; 5] =
        ["round-robin", "least-loaded", "acc-fastest", "swap-aware", "joules-per-slo"];

    /// Every policy (sweeps and property tests).
    pub const ALL: [Policy; 5] = [
        Policy::RoundRobin,
        Policy::LeastLoaded,
        Policy::AccFastest,
        Policy::SwapAware,
        Policy::JoulesPerSlo,
    ];

    pub fn parse(name: &str) -> Option<Policy> {
        match name {
            "round-robin" | "rr" => Some(Policy::RoundRobin),
            "least-loaded" | "ll" => Some(Policy::LeastLoaded),
            "acc-fastest" | "af" => Some(Policy::AccFastest),
            "swap-aware" | "sa" => Some(Policy::SwapAware),
            "joules-per-slo" | "jps" => Some(Policy::JoulesPerSlo),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Policy::RoundRobin => Policy::NAMES[0],
            Policy::LeastLoaded => Policy::NAMES[1],
            Policy::AccFastest => Policy::NAMES[2],
            Policy::SwapAware => Policy::NAMES[3],
            Policy::JoulesPerSlo => Policy::NAMES[4],
        }
    }

    /// Build the policy implementation.
    fn build(self, num_servers: usize) -> Box<dyn RoutePolicy> {
        match self {
            Policy::RoundRobin => Box::new(RoundRobinPolicy { cursor: 0 }),
            Policy::LeastLoaded => Box::new(LeastLoadedPolicy),
            Policy::AccFastest => Box::new(AccFastestPolicy),
            Policy::SwapAware => Box::new(SwapAwarePolicy {
                pressure_since: vec![f64::NAN; num_servers],
            }),
            Policy::JoulesPerSlo => Box::new(JoulesPerSloPolicy),
        }
    }
}

/// A routable (server, variant) pair.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct Candidate {
    /// Index into [`super::fleet::Fleet::servers`].
    pub server: usize,
    /// Index into that server's [`super::fleet::Server::variants`].
    pub variant: usize,
}

/// Immutable per-decision snapshot of the fleet's runtime state, built by
/// the event loop on every arrival.
#[derive(Clone, Copy, Debug)]
pub struct FleetView<'a> {
    /// Virtual time of the decision.
    pub now_ms: f64,
    /// Estimated backlog per server, ms (busy/swap remainder + queued
    /// work at batch-1 service times).
    pub backlog_ms: &'a [f64],
    /// Queued request count per server.
    pub queued: &'a [usize],
    /// `resident[s][v]`: is variant `v` loaded in server `s`'s engine
    /// memory right now?
    pub resident: &'a [Vec<bool>],
    /// Server cannot take new work: a swap is pending or in flight, or —
    /// under autoscaling — the server is asleep, waking or draining
    /// (anything but [`crate::serve::Lifecycle::Active`]).
    pub unavailable: &'a [bool],
}

/// Static routing tables derived from `(fleet, Δ_max)` at router build
/// time. Indices into the per-candidate vectors are candidate indices;
/// `variant_bytes` / `swap_in_ms` / `compliant` are `[server][variant]`.
#[derive(Clone, Debug)]
pub struct RouteCtx {
    /// Compliant (server, variant) pairs in (server, variant) enumeration
    /// order — the deterministic tie-break everywhere.
    pub candidates: Vec<Candidate>,
    /// Batch-1 ms per candidate (est. completion = backlog + this).
    pub batch1_ms: Vec<f64>,
    /// Measured accuracy drop per candidate (the acc-fastest tie-break).
    pub acc_drop: Vec<f64>,
    /// Fleet size (all lifecycle states included).
    pub num_servers: usize,
    /// Engine-memory capacity per server (`None` = unlimited).
    pub capacity_bytes: Vec<Option<u64>>,
    /// Weight footprint of every variant, resident or not.
    pub variant_bytes: Vec<Vec<u64>>,
    /// Batch-1 service time of every variant, compliant or not (the
    /// per-candidate `batch1_ms` only covers compliant pairs).
    pub variant_batch1_ms: Vec<Vec<f64>>,
    /// Precomputed hot-swap cost (weight streaming + init overhead) of
    /// loading each variant on each server.
    pub swap_in_ms: Vec<Vec<f64>>,
    /// Δ_max compliance of every variant (eviction ordering needs it for
    /// non-candidate variants too).
    pub compliant: Vec<Vec<bool>>,
    /// Batch-1 energy (mJ, the profiles' E = P·L) per candidate — what
    /// [`Policy::JoulesPerSlo`] minimizes per SLO-met request.
    pub batch1_mj: Vec<f64>,
    /// Batch-1 energy of every variant, compliant or not (variant
    /// re-selection ranks non-candidates too).
    pub variant_batch1_mj: Vec<Vec<f64>>,
    /// SLO deadline the fleet serves under, ms (`f64::INFINITY` when the
    /// router was built without one — energy scoring then ignores the
    /// deadline). Set via [`Router::with_slo`].
    pub slo_ms: f64,
}

/// A hot-swap proposal: evict `evict` (in order) from `server`, then load
/// `load`. The event loop validates it against live residency and charges
/// the swap cost.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SwapPlan {
    /// The server performing the swap.
    pub server: usize,
    /// Variant indices to evict, in eviction order.
    pub evict: Vec<usize>,
    /// Variant index to stream in once the evictions freed the memory.
    pub load: usize,
}

/// An open-ended routing policy over the fleet snapshot.
pub trait RoutePolicy {
    fn name(&self) -> &'static str;

    /// Pick one of `live` — indices into `ctx.candidates` whose variant
    /// is resident on an available server (never empty). Returning an
    /// index outside `live` is a policy bug; [`Router::route`] re-checks
    /// residency and rejects the request rather than scheduling it.
    fn route(&mut self, ctx: &RouteCtx, view: &FleetView, live: &[usize]) -> Option<usize>;

    /// Optionally propose an engine hot-swap. Called once per arrival
    /// (after routing) when the fleet is residency-limited. Default: a
    /// static policy that never swaps.
    fn plan_swap(&mut self, _ctx: &RouteCtx, _view: &FleetView) -> Option<SwapPlan> {
        None
    }
}

/// The router: live-candidate filtering plus a boxed [`RoutePolicy`].
pub struct Router {
    ctx: RouteCtx,
    policy: Box<dyn RoutePolicy>,
    /// Scratch: live candidate indices, rebuilt per decision.
    live: Vec<usize>,
}

impl Router {
    /// Build the compliant candidate set and static tables (enumeration
    /// order: server index, then variant index).
    pub fn new(fleet: &Fleet, delta_max: f64, policy: Policy, swap_init_ms: f64) -> Router {
        let mut candidates = Vec::new();
        let mut batch1_ms = Vec::new();
        let mut acc_drop = Vec::new();
        let mut batch1_mj = Vec::new();
        let mut capacity_bytes = Vec::with_capacity(fleet.servers.len());
        let mut variant_bytes = Vec::with_capacity(fleet.servers.len());
        let mut variant_batch1_ms = Vec::with_capacity(fleet.servers.len());
        let mut variant_batch1_mj = Vec::with_capacity(fleet.servers.len());
        let mut swap_in_ms = Vec::with_capacity(fleet.servers.len());
        let mut compliant = Vec::with_capacity(fleet.servers.len());
        for (s, server) in fleet.servers.iter().enumerate() {
            for (v, var) in server.variants.iter().enumerate() {
                if var.compliant(delta_max) {
                    candidates.push(Candidate { server: s, variant: v });
                    batch1_ms.push(var.batch1_ms());
                    acc_drop.push(var.acc_drop);
                    batch1_mj.push(var.energy_mj.first().copied().unwrap_or(0.0));
                }
            }
            capacity_bytes.push(server.mem_capacity_bytes);
            variant_bytes.push(server.variants.iter().map(|v| v.weight_bytes).collect());
            variant_batch1_ms.push(server.variants.iter().map(|v| v.batch1_ms()).collect());
            variant_batch1_mj.push(
                server
                    .variants
                    .iter()
                    .map(|v| v.energy_mj.first().copied().unwrap_or(0.0))
                    .collect(),
            );
            swap_in_ms.push(
                (0..server.variants.len())
                    .map(|v| server.swap_in_ms(v, swap_init_ms))
                    .collect(),
            );
            compliant.push(server.variants.iter().map(|v| v.compliant(delta_max)).collect());
        }
        let ctx = RouteCtx {
            candidates,
            batch1_ms,
            acc_drop,
            num_servers: fleet.servers.len(),
            capacity_bytes,
            variant_bytes,
            variant_batch1_ms,
            swap_in_ms,
            compliant,
            batch1_mj,
            variant_batch1_mj,
            slo_ms: f64::INFINITY,
        };
        let policy = policy.build(ctx.num_servers);
        Router { ctx, policy, live: Vec::new() }
    }

    /// Attach the SLO deadline the fleet serves under, so energy-aware
    /// scoring ([`Policy::JoulesPerSlo`]) can estimate whether a routed
    /// request would still meet it. Without it the deadline is treated as
    /// infinite and the policy scores on energy alone.
    pub fn with_slo(mut self, slo_ms: f64) -> Router {
        self.ctx.slo_ms = slo_ms;
        self
    }

    /// Number of compliant (server, variant) pairs, resident or not.
    pub fn num_candidates(&self) -> usize {
        self.ctx.candidates.len()
    }

    /// Route one request over the live candidates. `None` means reject:
    /// either no compliant variant exists anywhere
    /// ([`Router::num_candidates`] is 0), or none is resident on an
    /// available server right now.
    pub fn route(&mut self, view: &FleetView) -> Option<Candidate> {
        self.live.clear();
        for (i, c) in self.ctx.candidates.iter().enumerate() {
            if !view.unavailable[c.server] && view.resident[c.server][c.variant] {
                self.live.push(i);
            }
        }
        if self.live.is_empty() {
            return None;
        }
        let i = self.policy.route(&self.ctx, view, &self.live)?;
        let c = self.ctx.candidates[i];
        // residency is a hard serving invariant — re-check the policy's
        // answer rather than trusting it
        if view.unavailable[c.server] || !view.resident[c.server][c.variant] {
            debug_assert!(false, "policy {} returned a non-live candidate", self.policy.name());
            return None;
        }
        Some(c)
    }

    /// Ask the policy for a hot-swap proposal.
    pub fn plan_swap(&mut self, view: &FleetView) -> Option<SwapPlan> {
        self.policy.plan_swap(&self.ctx, view)
    }

    /// Forecast-driven swap prefetch (policy-independent): start a
    /// hot-swap toward a faster compliant variant *before* the queue
    /// pressure materializes. `expected_queued` is the controller's
    /// estimate of the requests that will arrive while the swap streams
    /// in — the reactive [`SwapAwarePolicy`] benefit test
    /// `queued · (b1_res − b1_new) > swap cost` is applied to that
    /// forecast backlog instead of the observed queue, and the sustain
    /// guard is dropped (the caller's confidence gate is the damping).
    /// Servers are scanned in index order; first viable plan wins.
    pub fn plan_prefetch(&self, view: &FleetView, expected_queued: f64) -> Option<SwapPlan> {
        for s in 0..self.ctx.num_servers {
            if view.unavailable[s] {
                continue;
            }
            let Some((b1_res, b1_new, v_new)) = upgrade_target(&self.ctx, view, s) else {
                continue;
            };
            let benefit = if b1_res.is_finite() {
                expected_queued * (b1_res - b1_new)
            } else {
                f64::INFINITY // starved: any compliant engine is a win
            };
            if benefit > self.ctx.swap_in_ms[s][v_new] {
                let evict = eviction_plan(&self.ctx, view, s, v_new);
                return Some(SwapPlan { server: s, evict, load: v_new });
            }
        }
        None
    }

    /// Forecast-driven variant re-selection (policy-independent): under
    /// sustained low load, swap an idle server toward the *cheapest*
    /// compliant variant (batch-1 energy, the profiles' E = P·L) that
    /// fits its memory — trading latency headroom the forecast says is
    /// not needed for joules on every future request. Only idle servers
    /// (empty queue, no backlog) are considered; servers are scanned in
    /// index order; first improvement wins.
    pub fn plan_reselect(&self, view: &FleetView) -> Option<SwapPlan> {
        for s in 0..self.ctx.num_servers {
            if view.unavailable[s] || view.queued[s] > 0 || view.backlog_ms[s] > 0.0 {
                continue;
            }
            let Some(cap) = self.ctx.capacity_bytes[s] else {
                continue; // unlimited memory: everything loadable is resident
            };
            let num_variants = view.resident[s].len();
            // cheapest resident compliant variant (what routing can use now)
            let mut e_res = f64::INFINITY;
            for v in 0..num_variants {
                if self.ctx.compliant[s][v] && view.resident[s][v] {
                    e_res = e_res.min(self.ctx.variant_batch1_mj[s][v]);
                }
            }
            // cheapest strictly-cheaper non-resident compliant that fits
            let mut load = None::<(f64, usize)>;
            for v in 0..num_variants {
                if !self.ctx.compliant[s][v]
                    || view.resident[s][v]
                    || self.ctx.variant_bytes[s][v] > cap
                {
                    continue;
                }
                let e = self.ctx.variant_batch1_mj[s][v];
                if e >= e_res {
                    continue;
                }
                let better = match load {
                    None => true,
                    Some((le, _)) => e < le,
                };
                if better {
                    load = Some((e, v));
                }
            }
            if let Some((_, v_new)) = load {
                let evict = eviction_plan(&self.ctx, view, s, v_new);
                return Some(SwapPlan { server: s, evict, load: v_new });
            }
        }
        None
    }
}

/// The fastest strictly-faster non-resident compliant variant that could
/// fit server `s` at all: returns `(best resident compliant batch-1 ms,
/// candidate batch-1 ms, candidate variant)`. `None` when the server has
/// unlimited memory (everything already resident) or no upgrade exists.
fn upgrade_target(ctx: &RouteCtx, view: &FleetView, s: usize) -> Option<(f64, f64, usize)> {
    let cap = ctx.capacity_bytes[s]?;
    let num_variants = view.resident[s].len();
    let mut b1_res = f64::INFINITY;
    for v in 0..num_variants {
        if ctx.compliant[s][v] && view.resident[s][v] {
            b1_res = b1_res.min(ctx.variant_batch1_ms[s][v]);
        }
    }
    let mut load = None::<(f64, usize)>;
    for v in 0..num_variants {
        if !ctx.compliant[s][v] || view.resident[s][v] || ctx.variant_bytes[s][v] > cap {
            continue;
        }
        let b1 = ctx.variant_batch1_ms[s][v];
        if b1 >= b1_res {
            continue;
        }
        let better = match load {
            None => true,
            Some((lb, _)) => b1 < lb,
        };
        if better {
            load = Some((b1, v));
        }
    }
    load.map(|(b1_new, v_new)| (b1_res, b1_new, v_new))
}

/// Evict residents of server `s` until variant `v_new` fits: non-compliant
/// residents first, then compliant residents — slowest-first within each
/// rank, index as the final tie-break. Shared by the reactive swap-aware
/// planner and the forecast-driven prefetch/re-selection planners so every
/// swap path frees memory in the same deterministic order.
fn eviction_plan(ctx: &RouteCtx, view: &FleetView, s: usize, v_new: usize) -> Vec<usize> {
    let cap = match ctx.capacity_bytes[s] {
        Some(c) => c,
        None => return Vec::new(),
    };
    let num_variants = view.resident[s].len();
    let resident_bytes: u64 = (0..num_variants)
        .filter(|&v| view.resident[s][v])
        .map(|v| ctx.variant_bytes[s][v])
        .sum();
    let mut order: Vec<usize> = (0..num_variants).filter(|&v| view.resident[s][v]).collect();
    order.sort_by(|&a, &b| {
        let rank = |v: usize| usize::from(ctx.compliant[s][v]);
        rank(a)
            .cmp(&rank(b))
            .then_with(|| ctx.variant_batch1_ms[s][b].total_cmp(&ctx.variant_batch1_ms[s][a]))
            .then(a.cmp(&b))
    });
    let mut evict = Vec::new();
    let mut freed = 0u64;
    let need = (resident_bytes + ctx.variant_bytes[s][v_new]).saturating_sub(cap);
    for v in order {
        if freed >= need {
            break;
        }
        evict.push(v);
        freed += ctx.variant_bytes[s][v];
    }
    evict
}

// ---------------------------------------------------------------------------
// Policy implementations
// ---------------------------------------------------------------------------

struct RoundRobinPolicy {
    cursor: usize,
}

impl RoutePolicy for RoundRobinPolicy {
    fn name(&self) -> &'static str {
        Policy::NAMES[0]
    }

    fn route(&mut self, _ctx: &RouteCtx, _view: &FleetView, live: &[usize]) -> Option<usize> {
        let i = live[self.cursor % live.len()];
        self.cursor = (self.cursor + 1) % live.len();
        Some(i)
    }
}

struct LeastLoadedPolicy;

impl RoutePolicy for LeastLoadedPolicy {
    fn name(&self) -> &'static str {
        Policy::NAMES[1]
    }

    fn route(&mut self, ctx: &RouteCtx, view: &FleetView, live: &[usize]) -> Option<usize> {
        // least-loaded server among those with a live variant…
        let mut best_server = None::<(f64, usize)>;
        for &i in live {
            let s = ctx.candidates[i].server;
            let load = view.backlog_ms[s];
            let better = match best_server {
                None => true,
                Some((l, bs)) => load < l || (load == l && s < bs),
            };
            if better {
                best_server = Some((load, s));
            }
        }
        let (_, server) = best_server?;
        // …then its fastest live variant (first index wins ties)
        let mut best = None::<(f64, usize)>;
        for &i in live {
            if ctx.candidates[i].server != server {
                continue;
            }
            let k = ctx.batch1_ms[i];
            let better = match best {
                None => true,
                Some((bk, _)) => k < bk,
            };
            if better {
                best = Some((k, i));
            }
        }
        best.map(|(_, i)| i)
    }
}

/// Shared by [`AccFastestPolicy`] and [`SwapAwarePolicy`]: minimize
/// estimated completion time, ties broken toward the lower accuracy drop,
/// then the lower candidate index.
fn acc_fastest_route(ctx: &RouteCtx, view: &FleetView, live: &[usize]) -> Option<usize> {
    let mut best = None::<(f64, f64, usize)>; // (finish, drop, idx)
    for &i in live {
        let c = ctx.candidates[i];
        let finish = view.backlog_ms[c.server] + ctx.batch1_ms[i];
        let drop = ctx.acc_drop[i];
        let better = match best {
            None => true,
            Some((f, d, _)) => finish < f || (finish == f && drop < d),
        };
        if better {
            best = Some((finish, drop, i));
        }
    }
    best.map(|(_, _, i)| i)
}

struct AccFastestPolicy;

impl RoutePolicy for AccFastestPolicy {
    fn name(&self) -> &'static str {
        Policy::NAMES[2]
    }

    fn route(&mut self, ctx: &RouteCtx, view: &FleetView, live: &[usize]) -> Option<usize> {
        acc_fastest_route(ctx, view, live)
    }
}

/// Backlog threshold, in multiples of the best resident batch-1 service
/// time, above which a server counts as pressured.
pub const SWAP_PRESSURE_BATCHES: f64 = 4.0;

/// How long (virtual ms) pressure must persist before a swap triggers —
/// the anti-thrash guard against transient spikes.
pub const SWAP_SUSTAIN_MS: f64 = 25.0;

/// Swap-aware policy: acc-fastest routing plus a hot-swap planner.
///
/// A server is *pressured* when its estimated backlog exceeds
/// [`SWAP_PRESSURE_BATCHES`] times its best resident compliant batch-1
/// time (or when it has no resident compliant variant at all — starved).
/// A pressured server triggers a swap to the fastest fitting non-resident
/// compliant variant once the projected queue-clearing saving
/// `queued · (b1_resident − b1_new)` exceeds the swap cost and the
/// pressure has persisted for [`SWAP_SUSTAIN_MS`]; a starved server swaps
/// immediately. Eviction frees memory in deterministic order:
/// non-compliant residents first, then compliant residents —
/// slowest-first within each rank.
struct SwapAwarePolicy {
    /// Virtual time each server's pressure episode began (NaN = none).
    pressure_since: Vec<f64>,
}

impl SwapAwarePolicy {
    fn plan_for_server(&mut self, ctx: &RouteCtx, view: &FleetView, s: usize) -> Option<SwapPlan> {
        // fastest strictly-faster non-resident compliant variant that can
        // fit the capacity at all (ties go to the lower variant index)
        let Some((b1_res, b1_new, v_new)) = upgrade_target(ctx, view, s) else {
            self.pressure_since[s] = f64::NAN;
            return None;
        };

        let starved = !b1_res.is_finite();
        let pressured = starved
            || (view.queued[s] > 0 && view.backlog_ms[s] > SWAP_PRESSURE_BATCHES * b1_res);
        if !pressured {
            self.pressure_since[s] = f64::NAN;
            return None;
        }
        // benefit: clearing today's queue on the faster engine must
        // out-earn the swap cost (HALP-style hardware-aware pricing)
        let benefit = if starved {
            f64::INFINITY
        } else {
            view.queued[s] as f64 * (b1_res - b1_new)
        };
        if benefit <= ctx.swap_in_ms[s][v_new] {
            self.pressure_since[s] = f64::NAN;
            return None;
        }
        if !starved {
            if self.pressure_since[s].is_nan() {
                self.pressure_since[s] = view.now_ms;
                return None;
            }
            if view.now_ms - self.pressure_since[s] < SWAP_SUSTAIN_MS {
                return None;
            }
        }

        let evict = eviction_plan(ctx, view, s, v_new);
        self.pressure_since[s] = f64::NAN;
        Some(SwapPlan { server: s, evict, load: v_new })
    }
}

impl RoutePolicy for SwapAwarePolicy {
    fn name(&self) -> &'static str {
        Policy::NAMES[3]
    }

    fn route(&mut self, ctx: &RouteCtx, view: &FleetView, live: &[usize]) -> Option<usize> {
        acc_fastest_route(ctx, view, live)
    }

    fn plan_swap(&mut self, ctx: &RouteCtx, view: &FleetView) -> Option<SwapPlan> {
        for s in 0..ctx.num_servers {
            if view.unavailable[s] {
                continue;
            }
            if let Some(plan) = self.plan_for_server(ctx, view, s) {
                return Some(plan);
            }
        }
        None
    }
}

/// Floor on the estimated SLO-met probability in the joules-per-SLO
/// score: a pair whose projected finish already blows the deadline is
/// still scored (at `energy / this`), so the policy degrades to
/// least-bad rather than refusing to route under overload.
pub const JPS_SLO_FLOOR: f64 = 0.05;

/// Joules-per-SLO-met routing: pick the live pair minimizing
/// `batch-1 energy / P(SLO met)`, where the probability is a linear
/// headroom estimate `clamp((slo − finish) / slo, JPS_SLO_FLOOR, 1)` over
/// the projected finish time `backlog + batch-1`. With no deadline
/// attached ([`Router::with_slo`] not called) the probability is 1 and
/// the policy routes to the cheapest live pair outright. Ties break
/// toward the earlier finish, then the lower candidate index — so on a
/// fleet where the fastest pair is also the cheapest (HQP variants
/// usually are: E = P·L and L shrank 3×) this routes exactly like
/// [`Policy::AccFastest`], and the two only diverge when energy and
/// latency genuinely trade off.
struct JoulesPerSloPolicy;

impl RoutePolicy for JoulesPerSloPolicy {
    fn name(&self) -> &'static str {
        Policy::NAMES[4]
    }

    fn route(&mut self, ctx: &RouteCtx, view: &FleetView, live: &[usize]) -> Option<usize> {
        let mut best = None::<(f64, f64, usize)>; // (score, finish, idx)
        for &i in live {
            let c = ctx.candidates[i];
            let finish = view.backlog_ms[c.server] + ctx.batch1_ms[i];
            let p_slo = if ctx.slo_ms.is_finite() && ctx.slo_ms > 0.0 {
                ((ctx.slo_ms - finish) / ctx.slo_ms).clamp(JPS_SLO_FLOOR, 1.0)
            } else {
                1.0
            };
            let score = ctx.batch1_mj[i] / p_slo;
            let better = match best {
                None => true,
                Some((bs, bf, _)) => score < bs || (score == bs && finish < bf),
            };
            if better {
                best = Some((score, finish, i));
            }
        }
        best.map(|(_, _, i)| i)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hwsim::Device;
    use crate::serve::fleet::{Fleet, Server, VariantProfile};

    fn var(name: &str, acc_drop: f64, ms: f64) -> VariantProfile {
        var_sized(name, acc_drop, ms, 10_000_000)
    }

    fn var_sized(name: &str, acc_drop: f64, ms: f64, bytes: u64) -> VariantProfile {
        VariantProfile {
            name: name.into(),
            schedule: String::new(),
            acc_drop,
            weight_bytes: bytes,
            batch_ms: vec![ms, ms * 1.6],
            energy_mj: vec![ms * 10.0, ms * 16.0],
        }
    }

    /// A profile whose energy is decoupled from its latency — the only
    /// way to make energy-aware routing disagree with acc-fastest.
    fn var_energy(name: &str, acc_drop: f64, ms: f64, mj: f64, bytes: u64) -> VariantProfile {
        VariantProfile {
            name: name.into(),
            schedule: String::new(),
            acc_drop,
            weight_bytes: bytes,
            batch_ms: vec![ms, ms * 1.6],
            energy_mj: vec![mj, mj * 1.6],
        }
    }

    fn fleet() -> Fleet {
        Fleet {
            model: "m".into(),
            servers: vec![
                Server::new(
                    Device::xavier_nx(),
                    vec![
                        var("baseline", 0.0, 8.0),
                        var("p50", 0.021, 1.0), // violates Δmax
                        var("hqp", 0.012, 0.5),
                    ],
                ),
                Server::new(
                    Device::jetson_nano(),
                    vec![var("baseline", 0.0, 20.0), var("hqp", 0.012, 4.0)],
                ),
            ],
        }
    }

    /// All-resident, all-available view over zeroed state.
    struct ViewState {
        backlog: Vec<f64>,
        queued: Vec<usize>,
        resident: Vec<Vec<bool>>,
        unavail: Vec<bool>,
    }

    impl ViewState {
        fn of(f: &Fleet) -> ViewState {
            ViewState {
                backlog: vec![0.0; f.servers.len()],
                queued: vec![0; f.servers.len()],
                resident: f.servers.iter().map(|s| s.initial_residency()).collect(),
                unavail: vec![false; f.servers.len()],
            }
        }

        fn view(&self, now: f64) -> FleetView<'_> {
            FleetView {
                now_ms: now,
                backlog_ms: &self.backlog,
                queued: &self.queued,
                resident: &self.resident,
                unavailable: &self.unavail,
            }
        }
    }

    #[test]
    fn non_compliant_variants_are_never_candidates() {
        let f = fleet();
        let st = ViewState::of(&f);
        let r = Router::new(&f, 0.015, Policy::AccFastest, 5.0);
        assert_eq!(r.num_candidates(), 4, "p50 must be excluded");
        let mut r = Router::new(&f, 0.015, Policy::RoundRobin, 5.0);
        for _ in 0..20 {
            let c = r.route(&st.view(0.0)).unwrap();
            assert!(!(c.server == 0 && c.variant == 1), "routed to p50");
        }
    }

    #[test]
    fn no_compliant_variant_means_reject() {
        let mut f = fleet();
        f.servers.truncate(1);
        f.servers[0].variants = vec![var("p50", 0.021, 1.0)];
        let st = ViewState::of(&f);
        for policy in Policy::ALL {
            let mut r = Router::new(&f, 0.015, policy, 5.0);
            assert_eq!(r.route(&st.view(0.0)), None);
        }
    }

    #[test]
    fn round_robin_cycles_deterministically() {
        let f = fleet();
        let st = ViewState::of(&f);
        let mut r = Router::new(&f, 0.015, Policy::RoundRobin, 5.0);
        let seq: Vec<Candidate> = (0..8).map(|_| r.route(&st.view(0.0)).unwrap()).collect();
        assert_eq!(seq[0], seq[4]);
        assert_eq!(seq[1], seq[5]);
        let distinct: std::collections::BTreeSet<Candidate> = seq[..4].iter().copied().collect();
        assert_eq!(distinct.len(), 4, "first cycle visits all 4 compliant pairs");
    }

    #[test]
    fn acc_fastest_picks_global_fastest_then_respects_backlog() {
        let f = fleet();
        let mut st = ViewState::of(&f);
        let mut r = Router::new(&f, 0.015, Policy::AccFastest, 5.0);
        let c = r.route(&st.view(0.0)).unwrap();
        assert_eq!((c.server, c.variant), (0, 2), "hqp on NX is fastest");
        // heavy NX backlog shifts routing to Nano's hqp
        st.backlog = vec![100.0, 0.0];
        let c = r.route(&st.view(0.0)).unwrap();
        assert_eq!((c.server, c.variant), (1, 1));
    }

    #[test]
    fn least_loaded_prefers_idle_server() {
        let f = fleet();
        let mut st = ViewState::of(&f);
        let mut r = Router::new(&f, 0.015, Policy::LeastLoaded, 5.0);
        st.backlog = vec![50.0, 1.0];
        let c = r.route(&st.view(0.0)).unwrap();
        assert_eq!(c.server, 1);
        assert_eq!(c.variant, 1, "fastest compliant on nano is hqp");
        st.backlog = vec![0.0, 1.0];
        let c = r.route(&st.view(0.0)).unwrap();
        assert_eq!((c.server, c.variant), (0, 2));
    }

    #[test]
    fn non_resident_variants_are_never_routed() {
        let f = fleet();
        let mut st = ViewState::of(&f);
        // only the slow baselines resident anywhere
        st.resident = vec![vec![true, false, false], vec![true, false]];
        for policy in Policy::ALL {
            let mut r = Router::new(&f, 0.015, policy, 5.0);
            for _ in 0..10 {
                let c = r.route(&st.view(0.0)).unwrap();
                assert_eq!(c.variant, 0, "{policy:?} routed a non-resident variant");
            }
        }
        // nothing resident at all → reject, even though candidates exist
        st.resident = vec![vec![false; 3], vec![false; 2]];
        for policy in Policy::ALL {
            let mut r = Router::new(&f, 0.015, policy, 5.0);
            assert!(r.num_candidates() > 0);
            assert_eq!(r.route(&st.view(0.0)), None);
        }
    }

    #[test]
    fn unavailable_servers_are_skipped() {
        let f = fleet();
        let mut st = ViewState::of(&f);
        st.unavail = vec![true, false];
        let mut r = Router::new(&f, 0.015, Policy::AccFastest, 5.0);
        let c = r.route(&st.view(0.0)).unwrap();
        assert_eq!(c.server, 1, "mid-swap server must not take new work");
    }

    #[test]
    fn swap_aware_plans_after_sustained_pressure() {
        // one NX: slow compliant resident, fast compliant non-resident
        let f = Fleet {
            model: "m".into(),
            servers: vec![Server {
                device: Device::xavier_nx(),
                variants: vec![
                    var_sized("fp32", 0.0, 10.0, 40_000_000),
                    var_sized("hqp", 0.012, 1.0, 4_000_000),
                ],
                mem_capacity_bytes: Some(41_000_000),
            }],
        };
        assert_eq!(f.servers[0].initial_residency(), vec![true, false]);
        let mut st = ViewState::of(&f);
        let mut r = Router::new(&f, 0.015, Policy::SwapAware, 5.0);

        // no pressure → no plan
        assert_eq!(r.plan_swap(&st.view(0.0)), None);

        // pressured (backlog > 4×10 ms, queue deep enough to out-earn the
        // ~5.07 ms swap cost): first sighting only starts the episode
        st.backlog = vec![60.0];
        st.queued = vec![6];
        assert_eq!(r.plan_swap(&st.view(100.0)), None, "sustain guard");
        assert_eq!(r.plan_swap(&st.view(110.0)), None, "still within sustain");
        let plan = r.plan_swap(&st.view(100.0 + SWAP_SUSTAIN_MS)).unwrap();
        assert_eq!(plan, SwapPlan { server: 0, evict: vec![0], load: 1 });

        // pressure that clears resets the episode
        st.backlog = vec![0.0];
        st.queued = vec![0];
        assert_eq!(r.plan_swap(&st.view(200.0)), None);
        st.backlog = vec![60.0];
        st.queued = vec![6];
        assert_eq!(r.plan_swap(&st.view(201.0)), None, "episode restarted");
    }

    #[test]
    fn swap_aware_swaps_immediately_when_starved() {
        // capacity admits only the Δ-violating p50; hqp fits after evicting
        let f = Fleet {
            model: "m".into(),
            servers: vec![Server {
                device: Device::xavier_nx(),
                variants: vec![
                    var_sized("p50", 0.021, 1.0, 10_000_000),
                    var_sized("hqp", 0.012, 2.0, 9_000_000),
                ],
                mem_capacity_bytes: Some(12_000_000),
            }],
        };
        assert_eq!(f.servers[0].initial_residency(), vec![true, false]);
        let st = ViewState::of(&f);
        let mut r = Router::new(&f, 0.015, Policy::SwapAware, 5.0);
        // no resident compliant engine: swap without waiting for pressure,
        // evicting the useless non-compliant resident
        let plan = r.plan_swap(&st.view(0.0)).unwrap();
        assert_eq!(plan, SwapPlan { server: 0, evict: vec![0], load: 1 });
    }

    #[test]
    fn swap_aware_never_plans_on_unlimited_memory() {
        let f = fleet(); // no capacities
        let mut st = ViewState::of(&f);
        st.backlog = vec![1e6, 1e6];
        st.queued = vec![500, 500];
        let mut r = Router::new(&f, 0.015, Policy::SwapAware, 5.0);
        for t in 0..10 {
            assert_eq!(r.plan_swap(&st.view(t as f64 * 100.0)), None);
        }
    }

    #[test]
    fn joules_per_slo_routes_for_energy_not_latency() {
        // fast-but-hot vs slow-but-frugal — both compliant
        let f = Fleet {
            model: "m".into(),
            servers: vec![
                Server::new(Device::xavier_nx(), vec![var_energy("hot", 0.0, 2.0, 100.0, 1)]),
                Server::new(Device::jetson_nano(), vec![var_energy("cool", 0.0, 5.0, 10.0, 1)]),
            ],
        };
        let st = ViewState::of(&f);
        // acc-fastest takes the 2 ms engine; joules-per-slo (no deadline
        // attached) takes the 10 mJ engine
        let mut af = Router::new(&f, 0.015, Policy::AccFastest, 5.0);
        assert_eq!(af.route(&st.view(0.0)).unwrap().server, 0);
        let mut jps = Router::new(&f, 0.015, Policy::JoulesPerSlo, 5.0);
        assert_eq!(jps.route(&st.view(0.0)).unwrap().server, 1);
    }

    #[test]
    fn joules_per_slo_yields_to_the_deadline() {
        let f = Fleet {
            model: "m".into(),
            servers: vec![
                Server::new(Device::xavier_nx(), vec![var_energy("hot", 0.0, 2.0, 100.0, 1)]),
                Server::new(Device::jetson_nano(), vec![var_energy("cool", 0.0, 5.0, 10.0, 1)]),
            ],
        };
        let mut st = ViewState::of(&f);
        // cheap server's backlog pushes its finish past the 6 ms SLO:
        // 10 / 0.05 (floored) = 200 > 100 / ((6-2)/6) = 150 → route hot
        st.backlog = vec![0.0, 3.0];
        let mut r = Router::new(&f, 0.015, Policy::JoulesPerSlo, 5.0).with_slo(6.0);
        assert_eq!(r.route(&st.view(0.0)).unwrap().server, 0);
        // with deadline headroom restored, energy wins again
        st.backlog = vec![0.0, 0.0];
        assert_eq!(r.route(&st.view(0.0)).unwrap().server, 1);
    }

    #[test]
    fn prefetch_plans_immediately_from_forecast_backlog() {
        // same memory-bound NX as the swap-aware sustain test
        let f = Fleet {
            model: "m".into(),
            servers: vec![Server {
                device: Device::xavier_nx(),
                variants: vec![
                    var_sized("fp32", 0.0, 10.0, 40_000_000),
                    var_sized("hqp", 0.012, 1.0, 4_000_000),
                ],
                mem_capacity_bytes: Some(41_000_000),
            }],
        };
        let st = ViewState::of(&f);
        let r = Router::new(&f, 0.015, Policy::AccFastest, 5.0);
        // a forecast backlog of 6 clears the benefit bar with no sustain
        // guard and no observed queue — the swap is paid before pressure
        let plan = r.plan_prefetch(&st.view(0.0), 6.0).unwrap();
        assert_eq!(plan, SwapPlan { server: 0, evict: vec![0], load: 1 });
        // no forecast backlog → the swap cannot pay for itself
        assert_eq!(r.plan_prefetch(&st.view(0.0), 0.0), None);
    }

    #[test]
    fn reselect_swaps_an_idle_server_toward_cheaper_joules() {
        let f = Fleet {
            model: "m".into(),
            servers: vec![Server {
                device: Device::xavier_nx(),
                variants: vec![
                    var_energy("hot", 0.0, 1.0, 50.0, 40_000_000),
                    var_energy("cool", 0.012, 4.0, 5.0, 4_000_000),
                ],
                mem_capacity_bytes: Some(41_000_000),
            }],
        };
        assert_eq!(f.servers[0].initial_residency(), vec![true, false]);
        let mut st = ViewState::of(&f);
        let r = Router::new(&f, 0.015, Policy::JoulesPerSlo, 5.0);
        // idle: re-select toward the 10× cheaper compliant engine
        let plan = r.plan_reselect(&st.view(0.0)).unwrap();
        assert_eq!(plan, SwapPlan { server: 0, evict: vec![0], load: 1 });
        // busy servers are never disturbed
        st.queued = vec![3];
        st.backlog = vec![3.0];
        assert_eq!(r.plan_reselect(&st.view(0.0)), None);
    }

    #[test]
    fn reselect_never_plans_on_unlimited_memory() {
        let f = fleet(); // no capacities: every variant already resident
        let st = ViewState::of(&f);
        let r = Router::new(&f, 0.015, Policy::JoulesPerSlo, 5.0);
        assert_eq!(r.plan_reselect(&st.view(0.0)), None);
    }

    #[test]
    fn parse_policy_names() {
        assert_eq!(Policy::parse("acc-fastest"), Some(Policy::AccFastest));
        assert_eq!(Policy::parse("rr"), Some(Policy::RoundRobin));
        assert_eq!(Policy::parse("least-loaded"), Some(Policy::LeastLoaded));
        assert_eq!(Policy::parse("swap-aware"), Some(Policy::SwapAware));
        assert_eq!(Policy::parse("sa"), Some(Policy::SwapAware));
        assert_eq!(Policy::parse("joules-per-slo"), Some(Policy::JoulesPerSlo));
        assert_eq!(Policy::parse("jps"), Some(Policy::JoulesPerSlo));
        assert!(Policy::parse("random").is_none());
        // NAMES is the single source of truth: every listed name parses
        // back to a policy whose name() round-trips
        for (i, name) in Policy::NAMES.iter().enumerate() {
            let p = Policy::parse(name).expect("every listed name must parse");
            assert_eq!(p, Policy::ALL[i]);
            assert_eq!(p.name(), *name);
        }
    }
}
