//! Multi-tenant workload classes: per-tenant Δ_max / SLO budgets and
//! admission weights.
//!
//! The paper frames HQP as a serving-level guarantee — Δ_max-compliant
//! variants under strict latency budgets — but a shared edge fleet rarely
//! serves one accuracy/latency contract. A [`TenantClass`] gives each
//! workload class its own accuracy-drop budget (`dmax`), latency SLO
//! (`slo_ms`) and weighted-fair admission share (`weight`); HALP's
//! latency-budget framing motivates the per-tenant budget rather than one
//! global SLO.
//!
//! Determinism contract: tenant assignment is a pure function of the
//! request id (a low-discrepancy golden-ratio sequence cut against the
//! cumulative weights), so the same trace maps to the same tenant
//! sequence at any `--jobs`, on the eager and the streamed path alike,
//! with no extra PRNG stream to keep in sync.

use crate::error::{Error, Result};

/// One workload class sharing the fleet.
#[derive(Clone, Debug, PartialEq)]
pub struct TenantClass {
    /// Display name (unique within a table).
    pub name: String,
    /// Per-tenant accuracy-drop budget: this tenant's requests may only
    /// be served by variants with `acc_drop <= dmax`.
    pub dmax: f64,
    /// Per-tenant latency SLO, ms: each attempt's deadline is its
    /// arrival (or retry re-entry) time plus this budget.
    pub slo_ms: f64,
    /// Weighted-fair admission share (relative; any positive scale).
    pub weight: f64,
    /// Optional arrival-rate share (the spec's 5th field): what fraction
    /// of the *offered* trace this class receives, relative to the other
    /// classes' shares. All-or-none per table: when every class carries
    /// one, [`tenant_of`] cuts the assignment sequence against these
    /// shares instead of the admission weights — so a low-weight class
    /// can still ride a heavy arrival stream (and vice versa). `None`
    /// everywhere reproduces the weight-cut assignment bit for bit.
    pub rate_share: Option<f64>,
}

/// The `--tenants` grammar, quoted by every parse error (and grepped for
/// by the CI negative step).
pub const TENANT_SPEC_FORMAT: &str = "\"name:dmax:slo_ms:weight[:rate_share],...\"";

/// Parse a `--tenants` spec: comma-separated `name:dmax:slo_ms:weight`
/// entries with an optional 5th `rate_share` field, e.g.
/// `"gold:0.01:30:8,free:0.03:100:1"` or
/// `"gold:0.01:30:8:0.2,free:0.03:100:1:0.8"`. The rate share is
/// all-or-none: either every class carries one or none does. Errors name
/// the offending entry and quote the expected format.
pub fn parse_tenants(spec: &str) -> Result<Vec<TenantClass>> {
    let bad = |entry: &str, why: &str| {
        Error::Cli(format!(
            "--tenants wants {TENANT_SPEC_FORMAT}: entry \"{entry}\" {why}"
        ))
    };
    let mut out: Vec<TenantClass> = Vec::new();
    for entry in spec.split(',') {
        let entry = entry.trim();
        if entry.is_empty() {
            return Err(bad(entry, "is empty"));
        }
        let parts: Vec<&str> = entry.split(':').collect();
        if parts.len() != 4 && parts.len() != 5 {
            return Err(bad(entry, "does not have 4 or 5 `:`-separated fields"));
        }
        let name = parts[0].trim();
        if name.is_empty() {
            return Err(bad(entry, "has an empty name"));
        }
        if out.iter().any(|t| t.name == name) {
            return Err(bad(entry, "repeats a tenant name"));
        }
        let num = |field: &str, label: &str| -> Result<f64> {
            field
                .trim()
                .parse::<f64>()
                .map_err(|_| bad(entry, &format!("has a non-numeric {label}")))
        };
        let dmax = num(parts[1], "dmax")?;
        let slo_ms = num(parts[2], "slo_ms")?;
        let weight = num(parts[3], "weight")?;
        if !(dmax >= 0.0) || !dmax.is_finite() {
            return Err(bad(entry, "needs dmax >= 0"));
        }
        if !(slo_ms > 0.0) || !slo_ms.is_finite() {
            return Err(bad(entry, "needs slo_ms > 0"));
        }
        if !(weight > 0.0) || !weight.is_finite() {
            return Err(bad(entry, "needs weight > 0"));
        }
        let rate_share = if parts.len() == 5 {
            let r = num(parts[4], "rate_share")?;
            if !(r > 0.0) || !r.is_finite() {
                return Err(bad(entry, "needs rate_share > 0"));
            }
            Some(r)
        } else {
            None
        };
        out.push(TenantClass { name: name.to_string(), dmax, slo_ms, weight, rate_share });
    }
    // all-or-none: a table where only some classes pin a rate share has
    // no defined split for the rest
    if out.iter().any(|t| t.rate_share.is_some()) && out.iter().any(|t| t.rate_share.is_none()) {
        return Err(Error::Cli(format!(
            "--tenants wants {TENANT_SPEC_FORMAT}: rate_share is all-or-none \
             (either every class carries a 5th field or none does)"
        )));
    }
    Ok(out)
}

/// Deterministic request → tenant assignment: the golden-ratio
/// low-discrepancy sequence `frac((id+1)·φ⁻¹)` cut against the
/// cumulative normalized shares — the classes' `rate_share`s when the
/// table pins them (all-or-none, enforced by [`parse_tenants`]), the
/// admission weights otherwise. Seed-free and jobs-free by construction;
/// over any long id range each tenant receives its share of requests
/// (±1/n discrepancy, far tighter than i.i.d. draws). The arrival
/// *generators* are untouched either way: only the id→class cut moves,
/// so the offered timeline stays bit-identical.
pub fn tenant_of(id: usize, tenants: &[TenantClass]) -> usize {
    if tenants.len() <= 1 {
        return 0;
    }
    const INV_PHI: f64 = 0.618_033_988_749_894_9;
    let u = ((id as f64 + 1.0) * INV_PHI).fract();
    let share = |t: &TenantClass| t.rate_share.unwrap_or(t.weight);
    let total: f64 = tenants.iter().map(share).sum();
    let mut acc = 0.0;
    for (i, t) in tenants.iter().enumerate() {
        acc += share(t) / total;
        if u < acc {
            return i;
        }
    }
    tenants.len() - 1
}

/// How the batcher orders queued requests into batches.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AdmitPolicy {
    /// Strict arrival order across tenants (the pre-tenant behavior).
    Fifo,
    /// Weighted-fair queueing over tenant classes: each dequeue picks the
    /// queued request whose tenant has the smallest virtual finish time
    /// (advanced by 1/weight per admitted request), so a high-weight
    /// tenant keeps its admission share through an overload instead of
    /// being crowded out by whoever arrived first.
    WeightedFair,
}

impl AdmitPolicy {
    /// Canonical CLI names (shared by parse/name and the `main.rs`
    /// "valid: …" error string).
    pub const NAMES: [&'static str; 2] = ["fifo", "weighted-fair"];

    pub fn parse(name: &str) -> Option<AdmitPolicy> {
        match name {
            "fifo" => Some(AdmitPolicy::Fifo),
            "weighted-fair" | "wfq" => Some(AdmitPolicy::WeightedFair),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            AdmitPolicy::Fifo => AdmitPolicy::NAMES[0],
            AdmitPolicy::WeightedFair => AdmitPolicy::NAMES[1],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn two() -> Vec<TenantClass> {
        parse_tenants("gold:0.01:30:8,free:0.03:100:1").unwrap()
    }

    #[test]
    fn parse_round_trips_fields() {
        let t = two();
        assert_eq!(t.len(), 2);
        assert_eq!(t[0].name, "gold");
        assert_eq!(t[0].dmax, 0.01);
        assert_eq!(t[0].slo_ms, 30.0);
        assert_eq!(t[0].weight, 8.0);
        assert_eq!(t[1].name, "free");
    }

    #[test]
    fn parse_rejects_malformed_specs_naming_the_format() {
        for bad in [
            "",
            "gold",
            "gold:0.01:30",
            "gold:0.01:30:8:extra",
            "gold:0.01:30:8:1:9",
            ":0.01:30:8",
            "gold:x:30:8",
            "gold:0.01:0:8",
            "gold:0.01:30:0",
            "gold:0.01:30:-1",
            "gold:0.01:30:8:0",
            "gold:0.01:30:8:-0.5",
            // rate_share is all-or-none across the table
            "gold:0.01:30:8:0.5,free:0.03:100:1",
            "gold:0.01:30:8,gold:0.02:40:1",
            "gold:0.01:30:8,,free:0.03:100:1",
        ] {
            let err = parse_tenants(bad).unwrap_err().to_string();
            assert!(
                err.contains(TENANT_SPEC_FORMAT),
                "error for {bad:?} must quote the format, got: {err}"
            );
        }
    }

    #[test]
    fn parse_accepts_the_optional_rate_share_field() {
        let t = parse_tenants("gold:0.01:30:8:0.2,free:0.03:100:1:0.8").unwrap();
        assert_eq!(t[0].rate_share, Some(0.2));
        assert_eq!(t[1].rate_share, Some(0.8));
        // 4-field specs leave the share unset (weight-cut assignment)
        assert_eq!(two()[0].rate_share, None);
    }

    #[test]
    fn rate_share_overrides_the_weight_cut() {
        // weight says 8:1 toward gold, rate share says 1:4 toward free —
        // the arrival split must follow the rate share
        let t = parse_tenants("gold:0.01:30:8:0.2,free:0.03:100:1:0.8").unwrap();
        let n = 100_000;
        let gold = (0..n).filter(|&id| tenant_of(id, &t) == 0).count() as f64;
        let share = gold / n as f64;
        assert!(
            (share - 0.2).abs() < 0.01,
            "gold arrival share {share:.4} should be ~0.2 (its rate share), not 8/9"
        );
    }

    #[test]
    fn assignment_is_deterministic_and_weight_proportional() {
        let t = two();
        let n = 100_000;
        let gold = (0..n).filter(|&id| tenant_of(id, &t) == 0).count() as f64;
        // deterministic: same id, same tenant
        for id in [0usize, 1, 17, 99_999] {
            assert_eq!(tenant_of(id, &t), tenant_of(id, &t));
        }
        let share = gold / n as f64;
        assert!(
            (share - 8.0 / 9.0).abs() < 0.01,
            "gold share {share:.4} should be ~8/9"
        );
    }

    #[test]
    fn single_tenant_always_zero() {
        let t = parse_tenants("only:0.015:50:1").unwrap();
        for id in 0..100 {
            assert_eq!(tenant_of(id, &t), 0);
        }
    }

    #[test]
    fn admit_policy_names_round_trip() {
        for name in AdmitPolicy::NAMES {
            assert_eq!(AdmitPolicy::parse(name).unwrap().name(), name);
        }
        assert_eq!(AdmitPolicy::parse("wfq"), Some(AdmitPolicy::WeightedFair));
        assert!(AdmitPolicy::parse("priority").is_none());
    }
}
