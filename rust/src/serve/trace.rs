//! Synthetic request traces: open-loop arrival-time generators.
//!
//! Four processes, all seeded through [`crate::testkit::prng::Prng`] so a
//! `(process, duration, seed)` triple always reproduces the identical
//! trace (the serving simulator's determinism contract hangs off this):
//!
//! * **Poisson** — memoryless arrivals at a constant rate; the classic
//!   open-loop serving workload.
//! * **MMPP(2)** — a two-state Markov-modulated Poisson process: the rate
//!   switches between a low and a high state with exponentially
//!   distributed dwell times. This is the bursty regime that
//!   Environment-Aware Dynamic Pruning (O'Quinn et al., 2025) argues edge
//!   pipelines must survive: the mean offered load can be modest while
//!   bursts transiently exceed a variant's capacity.
//! * **Diurnal** — an inhomogeneous Poisson process whose rate follows a
//!   sinusoid around the mean (Lewis–Shedler thinning against the peak
//!   rate): the day/night load curve compressed onto the simulator's
//!   millisecond clock.
//! * **Flash crowd** — baseline Poisson arrivals punctuated by seeded
//!   spike episodes: exponentially distributed gaps between spikes, each
//!   spike a fixed-length window at a much higher rate. Unlike MMPP the
//!   episode length is deterministic, so a spike always overruns a
//!   batcher timeout rather than sometimes ending inside one.

use crate::testkit::prng::Prng;

/// An arrival process.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ArrivalProcess {
    /// Constant-rate Poisson arrivals.
    Poisson {
        /// Mean arrival rate, requests per second.
        rps: f64,
    },
    /// Two-state MMPP: exponential dwell in each state, Poisson arrivals
    /// at the state's rate.
    Mmpp {
        /// Arrival rate in the low (trough) state, requests per second.
        rps_low: f64,
        /// Arrival rate in the high (burst) state, requests per second.
        rps_high: f64,
        /// Mean exponential dwell time in each state, ms.
        mean_dwell_ms: f64,
    },
    /// Sinusoid-modulated Poisson process:
    /// `rate(t) = rps_mean · (1 + depth · sin(2π·t/period_ms))`,
    /// realized by Lewis–Shedler thinning against the peak rate.
    Diurnal {
        /// Long-run mean arrival rate, requests per second.
        rps_mean: f64,
        /// Modulation depth in `[0, 1]`: peak = mean·(1+depth), trough =
        /// mean·(1−depth).
        depth: f64,
        /// Period of one full day/night cycle, ms (virtual time).
        period_ms: f64,
    },
    /// Baseline Poisson arrivals plus seeded spike episodes: the gap
    /// between spikes is exponential with mean `mean_gap_ms`; each spike
    /// lasts exactly `spike_ms` at `rps_peak`.
    FlashCrowd {
        /// Baseline arrival rate between spikes, requests per second.
        rps_base: f64,
        /// Arrival rate inside a spike episode, requests per second.
        rps_peak: f64,
        /// Mean exponential gap between spike episodes, ms.
        mean_gap_ms: f64,
        /// Fixed spike episode length, ms.
        spike_ms: f64,
    },
}

impl ArrivalProcess {
    /// Canonical CLI names, the single source of truth shared by
    /// [`ArrivalProcess::parse`], [`ArrivalProcess::name`] and the
    /// `main.rs` "valid: …" error strings.
    pub const NAMES: [&'static str; 4] = ["poisson", "mmpp", "diurnal", "flash-crowd"];

    /// Parse a CLI name into a process around a base rate.
    pub fn parse(name: &str, rps: f64) -> Option<ArrivalProcess> {
        match name {
            "poisson" => Some(ArrivalProcess::Poisson { rps }),
            // bursty preset: equal mean dwell in each state, so the
            // long-run mean is (0.4 + 1.6)/2 = exactly the requested rps,
            // with a 4x peak-to-trough swing
            "mmpp" => Some(ArrivalProcess::Mmpp {
                rps_low: rps * 0.4,
                rps_high: rps * 1.6,
                mean_dwell_ms: 250.0,
            }),
            // one day/night cycle every 2 virtual seconds: a --smoke
            // trace (1 s) sees half a cycle, the default 10 s trace five
            // full cycles, and the long-run mean is exactly rps
            "diurnal" => Some(ArrivalProcess::Diurnal {
                rps_mean: rps,
                depth: 0.5,
                period_ms: 2_000.0,
            }),
            // quiet baseline at 0.8·rps, ~1.4 spikes per virtual second,
            // each a 120 ms episode at 5·rps — load the autoscaler's
            // control interval can barely react inside
            "flash-crowd" => Some(ArrivalProcess::FlashCrowd {
                rps_base: rps * 0.8,
                rps_peak: rps * 5.0,
                mean_gap_ms: 700.0,
                spike_ms: 120.0,
            }),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ArrivalProcess::Poisson { .. } => ArrivalProcess::NAMES[0],
            ArrivalProcess::Mmpp { .. } => ArrivalProcess::NAMES[1],
            ArrivalProcess::Diurnal { .. } => ArrivalProcess::NAMES[2],
            ArrivalProcess::FlashCrowd { .. } => ArrivalProcess::NAMES[3],
        }
    }
}

/// Exponential variate with mean `1/rate_per_ms` (rate in events/ms).
fn exp_ms(rng: &mut Prng, rate_per_ms: f64) -> f64 {
    // 1 - u in (0, 1]: ln never sees 0
    -(1.0 - rng.next_f64()).ln() / rate_per_ms
}

/// Generate the sorted arrival times (ms, in `[0, duration_ms)`) of one
/// trace, fully materialized. Deterministic per
/// `(process, duration_ms, seed)`.
///
/// This is the eager *reference* form: [`ArrivalGen`] is a separately
/// implemented lazy state machine that must consume the PRNG in the
/// identical order, and the property tests hold the two bitwise equal.
pub fn generate(process: &ArrivalProcess, duration_ms: f64, seed: u64) -> Vec<f64> {
    let mut rng = Prng::new(seed);
    let mut out = Vec::new();
    match *process {
        ArrivalProcess::Poisson { rps } => {
            if rps <= 0.0 {
                return out;
            }
            let rate = rps / 1e3;
            let mut t = exp_ms(&mut rng, rate);
            while t < duration_ms {
                out.push(t);
                t += exp_ms(&mut rng, rate);
            }
        }
        ArrivalProcess::Mmpp { rps_low, rps_high, mean_dwell_ms } => {
            if rps_low <= 0.0 || rps_high <= 0.0 || mean_dwell_ms <= 0.0 {
                return out;
            }
            let mut high = false;
            let mut t = 0.0f64;
            let mut switch_at = exp_ms(&mut rng, 1.0 / mean_dwell_ms);
            while t < duration_ms {
                let rate = if high { rps_high } else { rps_low } / 1e3;
                let next = t + exp_ms(&mut rng, rate);
                if next < switch_at {
                    // arrival within the current state
                    t = next;
                    if t < duration_ms {
                        out.push(t);
                    }
                } else {
                    // state switch first; memorylessness lets us redraw
                    // the arrival gap from the new state's rate
                    t = switch_at;
                    high = !high;
                    switch_at = t + exp_ms(&mut rng, 1.0 / mean_dwell_ms);
                }
            }
        }
        ArrivalProcess::Diurnal { rps_mean, depth, period_ms } => {
            if rps_mean <= 0.0 || period_ms <= 0.0 || !(0.0..=1.0).contains(&depth) {
                return out;
            }
            // Lewis–Shedler thinning: draw candidates at the constant
            // peak rate, accept each with probability rate(t)/peak
            let peak = rps_mean * (1.0 + depth) / 1e3;
            let base = rps_mean / 1e3;
            let mut t = 0.0f64;
            loop {
                t += exp_ms(&mut rng, peak);
                if !(t < duration_ms) {
                    break;
                }
                let rate = base * (1.0 + depth * (std::f64::consts::TAU * t / period_ms).sin());
                if rng.next_f64() * peak < rate {
                    out.push(t);
                }
            }
        }
        ArrivalProcess::FlashCrowd { rps_base, rps_peak, mean_gap_ms, spike_ms } => {
            if rps_base <= 0.0 || rps_peak <= 0.0 || mean_gap_ms <= 0.0 || spike_ms <= 0.0 {
                return out;
            }
            // the MMPP loop shape, except entering a spike costs no draw:
            // the episode ends at exactly t + spike_ms
            let mut spiking = false;
            let mut t = 0.0f64;
            let mut switch_at = exp_ms(&mut rng, 1.0 / mean_gap_ms);
            while t < duration_ms {
                let rate = if spiking { rps_peak } else { rps_base } / 1e3;
                let next = t + exp_ms(&mut rng, rate);
                if next < switch_at {
                    t = next;
                    if t < duration_ms {
                        out.push(t);
                    }
                } else {
                    t = switch_at;
                    spiking = !spiking;
                    switch_at =
                        t + if spiking { spike_ms } else { exp_ms(&mut rng, 1.0 / mean_gap_ms) };
                }
            }
        }
    }
    out
}

/// Lazy iterator form of [`generate`]: emits the same arrival times, in
/// the same order, off the same [`Prng`] draw sequence, without ever
/// materializing the trace — O(1) state regardless of trace length.
///
/// `ArrivalGen::new(process, duration_ms, seed).collect::<Vec<_>>()` is
/// byte-identical to `generate(process, duration_ms, seed)` (property
/// tested in `tests/prop_serve.rs`), and with `duration_ms =
/// f64::INFINITY` the stream is unbounded, so `.take(n)` yields exactly
/// the first `n` arrivals of the process — the `hqp serve --requests N`
/// long-run knob.
pub struct ArrivalGen {
    rng: Prng,
    duration_ms: f64,
    state: GenState,
}

enum GenState {
    /// Exhausted (or a degenerate zero-rate process).
    Done,
    /// Poisson: `next_t` is the already-drawn candidate arrival.
    Poisson { rate: f64, next_t: f64 },
    /// MMPP(2): clock `t`, current state, and the pending switch time.
    Mmpp { rate_low: f64, rate_high: f64, dwell_rate: f64, high: bool, t: f64, switch_at: f64 },
    /// Diurnal thinning: candidate clock `t` against the peak rate.
    Diurnal { peak: f64, base: f64, depth: f64, period_ms: f64, t: f64 },
    /// Flash crowd: clock `t`, in-spike flag, and the pending switch time.
    FlashCrowd { rate_base: f64, rate_peak: f64, gap_rate: f64, spike_ms: f64, spiking: bool, t: f64, switch_at: f64 },
}

impl ArrivalGen {
    pub fn new(process: &ArrivalProcess, duration_ms: f64, seed: u64) -> ArrivalGen {
        let mut rng = Prng::new(seed);
        let state = match *process {
            ArrivalProcess::Poisson { rps } => {
                if rps <= 0.0 {
                    GenState::Done
                } else {
                    let rate = rps / 1e3;
                    let next_t = exp_ms(&mut rng, rate);
                    GenState::Poisson { rate, next_t }
                }
            }
            ArrivalProcess::Mmpp { rps_low, rps_high, mean_dwell_ms } => {
                if rps_low <= 0.0 || rps_high <= 0.0 || mean_dwell_ms <= 0.0 {
                    GenState::Done
                } else {
                    let dwell_rate = 1.0 / mean_dwell_ms;
                    let switch_at = exp_ms(&mut rng, dwell_rate);
                    GenState::Mmpp {
                        rate_low: rps_low / 1e3,
                        rate_high: rps_high / 1e3,
                        dwell_rate,
                        high: false,
                        t: 0.0,
                        switch_at,
                    }
                }
            }
            ArrivalProcess::Diurnal { rps_mean, depth, period_ms } => {
                if rps_mean <= 0.0 || period_ms <= 0.0 || !(0.0..=1.0).contains(&depth) {
                    GenState::Done
                } else {
                    GenState::Diurnal {
                        peak: rps_mean * (1.0 + depth) / 1e3,
                        base: rps_mean / 1e3,
                        depth,
                        period_ms,
                        t: 0.0,
                    }
                }
            }
            ArrivalProcess::FlashCrowd { rps_base, rps_peak, mean_gap_ms, spike_ms } => {
                if rps_base <= 0.0 || rps_peak <= 0.0 || mean_gap_ms <= 0.0 || spike_ms <= 0.0 {
                    GenState::Done
                } else {
                    let gap_rate = 1.0 / mean_gap_ms;
                    let switch_at = exp_ms(&mut rng, gap_rate);
                    GenState::FlashCrowd {
                        rate_base: rps_base / 1e3,
                        rate_peak: rps_peak / 1e3,
                        gap_rate,
                        spike_ms,
                        spiking: false,
                        t: 0.0,
                        switch_at,
                    }
                }
            }
        };
        ArrivalGen { rng, duration_ms, state }
    }
}

impl Iterator for ArrivalGen {
    type Item = f64;

    fn next(&mut self) -> Option<f64> {
        match &mut self.state {
            GenState::Done => None,
            GenState::Poisson { rate, next_t } => {
                if *next_t < self.duration_ms {
                    let out = *next_t;
                    *next_t += exp_ms(&mut self.rng, *rate);
                    Some(out)
                } else {
                    self.state = GenState::Done;
                    None
                }
            }
            GenState::Mmpp { rate_low, rate_high, dwell_rate, high, t, switch_at } => {
                // mirror of the eager loop body: spin through state
                // switches (which emit nothing) until an arrival lands
                // inside the horizon, drawing the PRNG in the exact same
                // order as `generate`
                loop {
                    if !(*t < self.duration_ms) {
                        self.state = GenState::Done;
                        return None;
                    }
                    let rate = if *high { *rate_high } else { *rate_low };
                    let next = *t + exp_ms(&mut self.rng, rate);
                    if next < *switch_at {
                        *t = next;
                        if *t < self.duration_ms {
                            return Some(*t);
                        }
                        // past the horizon: the eager loop also stops
                        // here without drawing again
                    } else {
                        *t = *switch_at;
                        *high = !*high;
                        *switch_at = *t + exp_ms(&mut self.rng, *dwell_rate);
                    }
                }
            }
            GenState::Diurnal { peak, base, depth, period_ms, t } => {
                // mirror of the eager thinning loop: candidates that the
                // sinusoid rejects emit nothing, drawing the PRNG in the
                // exact same order as `generate`
                loop {
                    *t += exp_ms(&mut self.rng, *peak);
                    if !(*t < self.duration_ms) {
                        self.state = GenState::Done;
                        return None;
                    }
                    let rate = *base
                        * (1.0 + *depth * (std::f64::consts::TAU * *t / *period_ms).sin());
                    if self.rng.next_f64() * *peak < rate {
                        return Some(*t);
                    }
                }
            }
            GenState::FlashCrowd { rate_base, rate_peak, gap_rate, spike_ms, spiking, t, switch_at } => {
                // mirror of the eager loop body, like Mmpp above —
                // entering a spike costs no draw (fixed episode length)
                loop {
                    if !(*t < self.duration_ms) {
                        self.state = GenState::Done;
                        return None;
                    }
                    let rate = if *spiking { *rate_peak } else { *rate_base };
                    let next = *t + exp_ms(&mut self.rng, rate);
                    if next < *switch_at {
                        *t = next;
                        if *t < self.duration_ms {
                            return Some(*t);
                        }
                    } else {
                        *t = *switch_at;
                        *spiking = !*spiking;
                        *switch_at = *t
                            + if *spiking {
                                *spike_ms
                            } else {
                                exp_ms(&mut self.rng, *gap_rate)
                            };
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arrival_gen_matches_generate_bitwise() {
        for p in [
            ArrivalProcess::Poisson { rps: 120.0 },
            ArrivalProcess::parse("mmpp", 120.0).unwrap(),
            ArrivalProcess::parse("diurnal", 120.0).unwrap(),
            ArrivalProcess::parse("flash-crowd", 120.0).unwrap(),
        ] {
            for seed in [1u64, 42, 0xDEAD] {
                let eager = generate(&p, 4_000.0, seed);
                let lazy: Vec<f64> = ArrivalGen::new(&p, 4_000.0, seed).collect();
                assert!(
                    eager.len() == lazy.len()
                        && eager.iter().zip(&lazy).all(|(a, b)| a.to_bits() == b.to_bits()),
                    "{} seed {seed}: lazy trace must be byte-identical to eager",
                    p.name()
                );
            }
        }
    }

    #[test]
    fn arrival_gen_unbounded_take_is_the_eager_prefix() {
        // duration = INFINITY + take(n) is how `--requests N` streams: the
        // first n arrivals must equal any eager horizon that covers them
        for p in [
            ArrivalProcess::Poisson { rps: 80.0 },
            ArrivalProcess::parse("mmpp", 80.0).unwrap(),
            ArrivalProcess::parse("diurnal", 80.0).unwrap(),
            ArrivalProcess::parse("flash-crowd", 80.0).unwrap(),
        ] {
            let eager = generate(&p, 10_000.0, 9);
            let n = eager.len() / 2;
            let lazy: Vec<f64> = ArrivalGen::new(&p, f64::INFINITY, 9).take(n).collect();
            assert_eq!(lazy.len(), n);
            assert!(lazy.iter().zip(&eager).all(|(a, b)| a.to_bits() == b.to_bits()));
        }
    }

    #[test]
    fn arrival_gen_zero_rate_is_empty_and_fused() {
        let mut g = ArrivalGen::new(&ArrivalProcess::Poisson { rps: 0.0 }, 1000.0, 1);
        assert_eq!(g.next(), None);
        assert_eq!(g.next(), None, "stays exhausted");
        let mut g = ArrivalGen::new(&ArrivalProcess::Poisson { rps: 50.0 }, 100.0, 1);
        while g.next().is_some() {}
        assert_eq!(g.next(), None, "stays exhausted after the horizon");
    }

    #[test]
    fn poisson_rate_roughly_matches() {
        let p = ArrivalProcess::Poisson { rps: 200.0 };
        let t = generate(&p, 60_000.0, 7);
        let got = t.len() as f64 / 60.0;
        assert!(
            (got - 200.0).abs() < 12.0,
            "poisson@200rps over 60s gave {got:.1} rps"
        );
    }

    #[test]
    fn traces_are_sorted_in_range_and_deterministic() {
        for p in [
            ArrivalProcess::Poisson { rps: 50.0 },
            ArrivalProcess::parse("mmpp", 50.0).unwrap(),
            ArrivalProcess::parse("diurnal", 50.0).unwrap(),
            ArrivalProcess::parse("flash-crowd", 50.0).unwrap(),
        ] {
            let a = generate(&p, 5_000.0, 42);
            let b = generate(&p, 5_000.0, 42);
            assert_eq!(a, b, "same seed must reproduce the trace");
            assert!(a.windows(2).all(|w| w[0] <= w[1]), "sorted");
            assert!(a.iter().all(|&t| t >= 0.0 && t < 5_000.0), "in range");
            let c = generate(&p, 5_000.0, 43);
            assert_ne!(a, c, "different seed must differ");
        }
    }

    #[test]
    fn mmpp_is_burstier_than_poisson() {
        // compare per-100ms-bin arrival-count variance at matched means
        let dur = 60_000.0;
        let po = generate(&ArrivalProcess::Poisson { rps: 100.0 }, dur, 11);
        let mm = generate(
            &ArrivalProcess::Mmpp { rps_low: 40.0, rps_high: 250.0, mean_dwell_ms: 250.0 },
            dur,
            11,
        );
        let var = |ts: &[f64]| {
            let bins = (dur / 100.0) as usize;
            let mut counts = vec![0f64; bins];
            for &t in ts {
                counts[((t / 100.0) as usize).min(bins - 1)] += 1.0;
            }
            let mean = counts.iter().sum::<f64>() / bins as f64;
            let v = counts.iter().map(|c| (c - mean) * (c - mean)).sum::<f64>() / bins as f64;
            // index of dispersion: var/mean (Poisson ≈ 1)
            v / mean.max(1e-9)
        };
        assert!(
            var(&mm) > var(&po) * 2.0,
            "mmpp dispersion {} must exceed poisson {}",
            var(&mm),
            var(&po)
        );
    }

    #[test]
    fn zero_rate_yields_empty_trace() {
        for name in ArrivalProcess::NAMES {
            let p = ArrivalProcess::parse(name, 0.0).unwrap();
            assert!(generate(&p, 1000.0, 1).is_empty(), "{name} at 0 rps");
        }
    }

    #[test]
    fn diurnal_mean_rate_matches_over_full_cycles() {
        // 30 full 2 s cycles: the sinusoid integrates out, leaving rps
        let p = ArrivalProcess::parse("diurnal", 200.0).unwrap();
        let t = generate(&p, 60_000.0, 7);
        let got = t.len() as f64 / 60.0;
        assert!(
            (got - 200.0).abs() < 12.0,
            "diurnal@200rps over 60s gave {got:.1} rps"
        );
    }

    #[test]
    fn diurnal_peak_half_cycle_is_denser_than_trough_half_cycle() {
        // rate(t) = mean·(1 + 0.5·sin(2πt/2000)): the first half-cycle
        // (0..1000 ms of each period) carries more arrivals than the
        // second — the day/night asymmetry the process exists to model
        let p = ArrivalProcess::parse("diurnal", 300.0).unwrap();
        let t = generate(&p, 60_000.0, 3);
        let day = t.iter().filter(|&&x| (x % 2_000.0) < 1_000.0).count() as f64;
        let night = t.len() as f64 - day;
        assert!(
            day > night * 1.4,
            "day half-cycles ({day}) must out-draw night ({night})"
        );
    }

    #[test]
    fn flash_crowd_is_burstier_than_poisson() {
        let dur = 60_000.0;
        let po = generate(&ArrivalProcess::Poisson { rps: 100.0 }, dur, 11);
        let fc = generate(&ArrivalProcess::parse("flash-crowd", 100.0).unwrap(), dur, 11);
        let var = |ts: &[f64]| {
            let bins = (dur / 100.0) as usize;
            let mut counts = vec![0f64; bins];
            for &t in ts {
                counts[((t / 100.0) as usize).min(bins - 1)] += 1.0;
            }
            let mean = counts.iter().sum::<f64>() / bins as f64;
            let v = counts.iter().map(|c| (c - mean) * (c - mean)).sum::<f64>() / bins as f64;
            v / mean.max(1e-9)
        };
        assert!(
            var(&fc) > var(&po) * 2.0,
            "flash-crowd dispersion {} must exceed poisson {}",
            var(&fc),
            var(&po)
        );
    }

    #[test]
    fn parse_names() {
        assert_eq!(ArrivalProcess::parse("poisson", 10.0).unwrap().name(), "poisson");
        assert_eq!(ArrivalProcess::parse("mmpp", 10.0).unwrap().name(), "mmpp");
        assert_eq!(ArrivalProcess::parse("diurnal", 10.0).unwrap().name(), "diurnal");
        assert_eq!(
            ArrivalProcess::parse("flash-crowd", 10.0).unwrap().name(),
            "flash-crowd"
        );
        assert!(ArrivalProcess::parse("uniform", 10.0).is_none());
        // NAMES is the single source of truth: every listed name parses
        // and round-trips through name()
        for name in ArrivalProcess::NAMES {
            assert_eq!(ArrivalProcess::parse(name, 10.0).unwrap().name(), name);
        }
    }
}
