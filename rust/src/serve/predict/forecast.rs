//! Online arrival-rate forecasting: an MMPP(2) hidden-state filter plus a
//! trace-periodicity estimator, fused into a single [`RateForecast`].
//!
//! The serving traces this repo cares about are bursty on two timescales:
//! the MMPP(2) process flips between a low and a high Poisson rate with
//! exponential dwell (mean 250 ms in the CLI preset), and the diurnal
//! process modulates the rate sinusoidally with a fixed period. A purely
//! reactive controller pays one detection lag *per burst*; this module
//! estimates where the rate is **going** so the control plane can pay the
//! wake/swap cost *before* the burst lands.
//!
//! ## Filter model
//!
//! The MMPP(2) state is tracked with a normalized two-state Bayes filter
//! over inter-arrival gaps. Per observed gap `dt`:
//!
//! 1. **Mix** — the symmetric two-state chain relaxes the belief toward
//!    ½ at rate `2q` (`q` = [`SWITCH_HAZARD_PER_MS`], the dwell prior):
//!    `p ← ½ + (p − ½)·exp(−2q·dt)`.
//! 2. **Weigh** — the gap likelihood under each state's exponential law
//!    (`λ·e^{−λ·dt}`) reweighs the belief via the log-likelihood ratio,
//!    then the belief is renormalized and clamped away from absorbing
//!    0/1.
//!
//! The two state rates are not known a priori; they are learned online as
//! belief-gated EWMAs of the observed gaps (the gap EWMA of whichever
//! state currently owns the belief is updated), seeded from the first gap
//! at the MMPP CLI preset's 0.4×/1.6× split. Everything is a pure
//! function of the arrival-time prefix, so the filter is deterministic
//! and `--jobs`-invariant by construction (it only ever runs on the
//! coordinator thread, in trace order).
//!
//! **Fixed-point discipline:** every piece of persistent filter state is
//! re-quantized onto a fixed grid ([`quantize`]) after each update. The
//! update math runs in f64, but the *stored* state always sits on the
//! grid, so state never accumulates platform- or history-shaped noise
//! below the grid and byte-identical runs stay byte-identical.
//!
//! ## Periodicity estimator
//!
//! Arrivals are also binned into a fixed ring of [`BUCKET_MS`]-wide rate
//! buckets. Every [`PERIOD_REFRESH_BUCKETS`] completed buckets the
//! estimator scans lag-domain autocorrelation over
//! `[MIN_PERIOD_LAG, MAX_PERIOD_LAG]` buckets and locks onto the best
//! lag whose normalized autocorrelation clears
//! [`PERIOD_MIN_CORR`] — for the diurnal preset (period 2 s) that is the
//! true period to within one bucket. A locked period lets
//! [`RateForecast::rate_ahead`] read next-period rates straight out of
//! last period's history instead of extrapolating the filter.
//!
//! ## Horizon semantics
//!
//! [`RateForecast::rate_ahead`]`(h)` answers "what arrival rate do I
//! expect `h` ms from now" — the controllers call it with `h` = the cost
//! of the action they are pricing (a server's wake latency, a swap
//! stream-in time), which is exactly the lead time prediction has to buy
//! for the action to be ready when the load arrives. The filter component
//! relaxes toward the long-run mean as `h` grows (a two-state chain
//! forgets its state at rate `2q`), the seasonal component does not decay
//! in `h` (the period is stable), and the two are blended by the period
//! lock quality.

/// Width of one rate-history bucket, virtual ms. 25 ms resolves the
/// 250 ms MMPP dwell preset (10 buckets/dwell) and the 2 s diurnal
/// period (80 buckets/period) comfortably.
pub const BUCKET_MS: f64 = 25.0;

/// Ring capacity in buckets: 512 × 25 ms = 12.8 s of rate history — over
/// six diurnal preset periods.
pub const RING_BUCKETS: usize = 512;

/// Prior on the MMPP switching hazard, per ms (1/250 ms matches the CLI
/// preset's mean dwell). Only shapes mixing speed; the learned state
/// rates carry the data.
pub const SWITCH_HAZARD_PER_MS: f64 = 1.0 / 250.0;

/// Belief-gated EWMA factor for the per-state gap estimates.
pub const GAP_ALPHA: f64 = 0.08;

/// Re-estimate the period every this many completed buckets.
pub const PERIOD_REFRESH_BUCKETS: u64 = 32;

/// Smallest candidate period, in buckets (8 × 25 ms = 200 ms).
pub const MIN_PERIOD_LAG: usize = 8;

/// Largest candidate period, in buckets (256 × 25 ms = 6.4 s).
pub const MAX_PERIOD_LAG: usize = 256;

/// Normalized autocorrelation a lag must clear to count as a period lock.
pub const PERIOD_MIN_CORR: f64 = 0.35;

/// How much better a longer lag must correlate to displace a shorter one.
/// A periodic trace correlates at every *multiple* of the true period;
/// scanning lags ascending with this margin locks the fundamental, not a
/// harmonic.
pub const PERIOD_HARMONIC_MARGIN: f64 = 0.05;

/// Gap observations before confidence saturates halfway
/// (`n / (n + this)`).
pub const CONFIDENCE_HALF_LIFE_OBS: f64 = 32.0;

/// Floor (and `1 −` ceiling) for the state belief — keeps the filter out
/// of the absorbing 0/1 corners so it can always change its mind.
pub const BELIEF_CLAMP: f64 = 1e-3;

/// Quantization grid for persistent filter state (the fixed-point
/// discipline): state is stored in units of this step.
pub const STATE_GRID: f64 = 1e-9;

/// Snap a value onto the persistent-state grid ([`STATE_GRID`] units).
/// All stored filter state passes through this after every update.
pub fn quantize(x: f64) -> f64 {
    (x / STATE_GRID).round() * STATE_GRID
}

/// A point-in-time forecast handed to the predictive controllers at each
/// control tick. Borrow-cheap: `rate_ahead` reads the forecaster's
/// seasonal history through the borrow.
pub struct RateForecast<'a> {
    fc: &'a Forecaster,
    /// Tick time the forecast was taken at, virtual ms.
    pub now_ms: f64,
    /// Filtered arrival rate right now, requests/s.
    pub rate_now_rps: f64,
    /// How much to trust this forecast, in `[0, 1]` — the product of a
    /// data-volume ramp and the decisiveness of the state belief (or the
    /// period lock quality, whichever is stronger). Controllers degrade
    /// to their reactive fallback below their gate.
    pub confidence: f64,
}

impl RateForecast<'_> {
    /// Expected arrival rate `horizon_ms` from now, requests/s. See the
    /// module docs for the horizon semantics.
    pub fn rate_ahead(&self, horizon_ms: f64) -> f64 {
        self.fc.rate_ahead_at(self.now_ms, horizon_ms.max(0.0))
    }
}

/// The online forecaster. One instance lives in the serving coordinator
/// (single-threaded), fed every fresh arrival in trace order and every
/// control tick; see the module docs for the model.
pub struct Forecaster {
    // --- MMPP(2) gap filter ---
    last_arrival_ms: f64, // NaN until the first arrival
    gaps_seen: u64,
    /// P(state = high), clamped to `[BELIEF_CLAMP, 1 − BELIEF_CLAMP]`.
    p_high: f64,
    /// Learned mean gap in the low-rate state, ms (large gap = low rate).
    gap_lo_ms: f64, // NaN until seeded
    /// Learned mean gap in the high-rate state, ms.
    gap_hi_ms: f64, // NaN until seeded
    // --- bucketed rate history (periodicity + realized-rate lookups) ---
    counts: Vec<u32>,
    head: usize,
    head_start_ms: f64,
    completed_buckets: u64,
    period_buckets: Option<usize>,
    period_corr: f64,
    // --- forecast-error bookkeeping (summary's forecast_abs_err_pct) ---
    pending: std::collections::VecDeque<(f64, f64)>, // (target_ms, predicted_rps)
    err_sum_pct: f64,
    err_samples: u64,
}

impl Forecaster {
    /// A fresh forecaster: belief at ½, no rates learned, no history.
    pub fn new() -> Forecaster {
        Forecaster {
            last_arrival_ms: f64::NAN,
            gaps_seen: 0,
            p_high: 0.5,
            gap_lo_ms: f64::NAN,
            gap_hi_ms: f64::NAN,
            counts: vec![0; RING_BUCKETS],
            head: 0,
            head_start_ms: 0.0,
            completed_buckets: 0,
            period_buckets: None,
            period_corr: 0.0,
            pending: std::collections::VecDeque::new(),
            err_sum_pct: 0.0,
            err_samples: 0,
        }
    }

    /// Gap observations consumed so far.
    pub fn gaps_seen(&self) -> u64 {
        self.gaps_seen
    }

    /// Forecast-error accumulators: (sum of absolute percent errors,
    /// sample count). Feeds the summary's `forecast_abs_err_pct`.
    pub fn err_stats(&self) -> (f64, u64) {
        (self.err_sum_pct, self.err_samples)
    }

    /// The locked trace period, ms, if the autocorrelation scan found
    /// one.
    pub fn period_ms(&self) -> Option<f64> {
        self.period_buckets.map(|b| b as f64 * BUCKET_MS)
    }

    /// Feed one fresh arrival (coordinator thread, trace order only —
    /// retries re-entering the system are *offered load already counted*,
    /// not new demand, and are not fed).
    pub fn on_arrival(&mut self, now_ms: f64) {
        self.advance_buckets(now_ms);
        self.counts[self.head] = self.counts[self.head].saturating_add(1);
        let prev = self.last_arrival_ms;
        self.last_arrival_ms = now_ms;
        if prev.is_nan() {
            return;
        }
        let dt = (now_ms - prev).max(STATE_GRID);
        self.gaps_seen += 1;
        if self.gap_lo_ms.is_nan() {
            // seed the state rates around the first gap at the MMPP CLI
            // preset's 0.4×/1.6× split (gap is 1/rate: low rate = long gap)
            self.gap_lo_ms = quantize(dt / 0.4);
            self.gap_hi_ms = quantize(dt / 1.6);
            return;
        }
        // (1) mix: the symmetric chain forgets its state at rate 2q
        let relax = (-2.0 * SWITCH_HAZARD_PER_MS * dt).exp();
        let p = 0.5 + (self.p_high - 0.5) * relax;
        // (2) weigh: exponential-gap log-likelihood ratio high vs low
        let lam_hi = 1.0 / self.gap_hi_ms;
        let lam_lo = 1.0 / self.gap_lo_ms;
        let llr = (lam_hi / lam_lo).ln() - (lam_hi - lam_lo) * dt;
        let odds = (p / (1.0 - p)) * llr.clamp(-30.0, 30.0).exp();
        let posterior = odds / (1.0 + odds);
        self.p_high = quantize(posterior.clamp(BELIEF_CLAMP, 1.0 - BELIEF_CLAMP));
        // belief-gated rate learning: the owning state absorbs the gap
        if self.p_high >= 0.5 {
            self.gap_hi_ms = quantize(GAP_ALPHA * dt + (1.0 - GAP_ALPHA) * self.gap_hi_ms);
        } else {
            self.gap_lo_ms = quantize(GAP_ALPHA * dt + (1.0 - GAP_ALPHA) * self.gap_lo_ms);
        }
        // keep the states ordered (high rate = short gap); a crossover
        // means the labels swapped, so swap them back
        if self.gap_hi_ms > self.gap_lo_ms {
            std::mem::swap(&mut self.gap_hi_ms, &mut self.gap_lo_ms);
            self.p_high = quantize(1.0 - self.p_high);
        }
    }

    /// Control-tick hook: advances the rate history to `now_ms`, scores
    /// any forecast whose target time has passed against the realized
    /// rate, and records a fresh prediction `horizon_ms` ahead for later
    /// scoring.
    pub fn on_tick(&mut self, now_ms: f64, horizon_ms: f64) {
        self.advance_buckets(now_ms);
        // score matured predictions (need the target's bucket + one
        // completed neighbor for the smoothed realized-rate read)
        while let Some(&(target, pred)) = self.pending.front() {
            if self.head_start_ms < target + 2.0 * BUCKET_MS {
                break;
            }
            self.pending.pop_front();
            if let Some(real) = self.rate_at(target) {
                let err = (pred - real).abs() / real.max(1.0) * 100.0;
                self.err_sum_pct += err;
                self.err_samples += 1;
            }
        }
        let pred = self.forecast(now_ms).rate_ahead(horizon_ms);
        self.pending.push_back((now_ms + horizon_ms, pred));
        if self.pending.len() > 4096 {
            self.pending.pop_front(); // bound state on pathological horizons
        }
    }

    /// Take a forecast snapshot at `now_ms`.
    pub fn forecast(&self, now_ms: f64) -> RateForecast<'_> {
        let c_data = self.gaps_seen as f64 / (self.gaps_seen as f64 + CONFIDENCE_HALF_LIFE_OBS);
        let c_state = 2.0 * (self.p_high - 0.5).abs();
        let c_period = if self.period_buckets.is_some() { self.period_corr } else { 0.0 };
        let confidence = (c_data * c_state.max(c_period)).clamp(0.0, 1.0);
        RateForecast {
            fc: self,
            now_ms,
            rate_now_rps: self.filter_rate_rps(self.p_high),
            confidence,
        }
    }

    /// Belief-weighted filter rate, requests/s.
    fn filter_rate_rps(&self, p_high: f64) -> f64 {
        if self.gap_lo_ms.is_nan() {
            return 0.0;
        }
        let r_hi = 1e3 / self.gap_hi_ms;
        let r_lo = 1e3 / self.gap_lo_ms;
        p_high * r_hi + (1.0 - p_high) * r_lo
    }

    /// The fused look-ahead rate (see [`RateForecast::rate_ahead`]).
    fn rate_ahead_at(&self, now_ms: f64, horizon_ms: f64) -> f64 {
        // filter component: belief relaxes toward ½ over the horizon
        let relax = (-2.0 * SWITCH_HAZARD_PER_MS * horizon_ms).exp();
        let p_h = 0.5 + (self.p_high - 0.5) * relax;
        let filter = self.filter_rate_rps(p_h);
        // seasonal component: the rate one period before the target time
        let seasonal = self.period_buckets.and_then(|lag| {
            self.rate_at(now_ms + horizon_ms - lag as f64 * BUCKET_MS)
        });
        match seasonal {
            Some(s) => {
                let w = self.period_corr.clamp(0.0, 0.9);
                (1.0 - w) * filter + w * s
            }
            None => filter,
        }
    }

    /// Smoothed realized rate (requests/s) around historical time `t_ms`:
    /// the mean over the 3 completed buckets centered on `t_ms`'s bucket.
    /// `None` when `t_ms` has fallen off the ring (or is not yet
    /// completed history).
    fn rate_at(&self, t_ms: f64) -> Option<f64> {
        if t_ms >= self.head_start_ms || t_ms < 0.0 {
            return None;
        }
        let back = ((self.head_start_ms - t_ms) / BUCKET_MS).floor() as u64 + 1;
        let depth = self.completed_buckets.min(RING_BUCKETS as u64 - 1);
        if back > depth {
            return None;
        }
        let mut sum = 0u64;
        let mut n = 0u64;
        for b in [back + 1, back, back.saturating_sub(1)] {
            if b >= 1 && b <= depth {
                let idx = (self.head + RING_BUCKETS - b as usize) % RING_BUCKETS;
                sum += u64::from(self.counts[idx]);
                n += 1;
            }
        }
        if n == 0 {
            return None;
        }
        Some(sum as f64 / (n as f64 * BUCKET_MS) * 1e3)
    }

    /// Roll the bucket ring forward so `now_ms` lands in the head bucket,
    /// refreshing the period estimate on schedule.
    fn advance_buckets(&mut self, now_ms: f64) {
        let mut refreshed = false;
        while now_ms >= self.head_start_ms + BUCKET_MS {
            self.head = (self.head + 1) % RING_BUCKETS;
            self.counts[self.head] = 0;
            self.head_start_ms += BUCKET_MS;
            self.completed_buckets += 1;
            if self.completed_buckets % PERIOD_REFRESH_BUCKETS == 0 {
                refreshed = true;
            }
        }
        if refreshed {
            self.refresh_period();
        }
    }

    /// Lag-domain autocorrelation scan over the completed history; locks
    /// the best lag clearing [`PERIOD_MIN_CORR`] (requiring two full
    /// periods of history so one period of evidence backs every lag).
    fn refresh_period(&mut self) {
        let depth = self.completed_buckets.min(RING_BUCKETS as u64 - 1) as usize;
        if depth < 2 * MIN_PERIOD_LAG {
            return;
        }
        // chronological completed-bucket window, oldest first
        let mut xs = Vec::with_capacity(depth);
        for b in (1..=depth).rev() {
            let idx = (self.head + RING_BUCKETS - b) % RING_BUCKETS;
            xs.push(f64::from(self.counts[idx]));
        }
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var: f64 = xs.iter().map(|x| (x - mean) * (x - mean)).sum();
        if var <= 0.0 {
            self.period_buckets = None;
            self.period_corr = 0.0;
            return;
        }
        let max_lag = MAX_PERIOD_LAG.min(depth / 2);
        let mut best: Option<(usize, f64)> = None;
        for lag in MIN_PERIOD_LAG..=max_lag {
            let mut num = 0.0;
            for i in lag..xs.len() {
                num += (xs[i] - mean) * (xs[i - lag] - mean);
            }
            // normalize by the overlap so long lags are not penalized
            // for having fewer product terms
            let corr = num / var * (xs.len() as f64 / (xs.len() - lag) as f64);
            if corr > best.map_or(PERIOD_MIN_CORR, |(_, c)| c + PERIOD_HARMONIC_MARGIN) {
                best = Some((lag, corr));
            }
        }
        match best {
            Some((lag, corr)) => {
                self.period_buckets = Some(lag);
                self.period_corr = quantize(corr.clamp(0.0, 1.0));
            }
            None => {
                self.period_buckets = None;
                self.period_corr = 0.0;
            }
        }
    }
}

impl Default for Forecaster {
    fn default() -> Self {
        Forecaster::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Deterministic synthetic arrival stream: constant-gap arrivals at
    /// `rps` over `[start_ms, end_ms)`.
    fn feed_constant(fc: &mut Forecaster, rps: f64, start_ms: f64, end_ms: f64) {
        let gap = 1e3 / rps;
        let mut t = start_ms;
        while t < end_ms {
            fc.on_arrival(t);
            t += gap;
        }
    }

    #[test]
    fn filter_tracks_a_rate_switch() {
        let mut fc = Forecaster::new();
        feed_constant(&mut fc, 100.0, 0.0, 1_000.0);
        let low = fc.forecast(1_000.0).rate_now_rps;
        // rate jumps 4×: belief must swing high and the estimate follow
        feed_constant(&mut fc, 400.0, 1_000.0, 2_000.0);
        let high = fc.forecast(2_000.0).rate_now_rps;
        assert!(
            high > low * 1.5,
            "filter must chase a 4× rate jump: low {low:.1} rps high {high:.1} rps"
        );
        assert!(fc.forecast(2_000.0).confidence > 0.2, "plenty of data: confidence must ramp");
    }

    #[test]
    fn rate_ahead_relaxes_toward_the_mean() {
        let mut fc = Forecaster::new();
        feed_constant(&mut fc, 100.0, 0.0, 500.0);
        feed_constant(&mut fc, 400.0, 500.0, 1_500.0);
        let f = fc.forecast(1_500.0);
        let near = f.rate_ahead(10.0);
        let far = f.rate_ahead(10_000.0);
        // in the high state: a long horizon forgets the state, so the
        // far forecast sits closer to the two-state midpoint
        assert!(far < near, "far horizon {far:.1} must relax below near {near:.1}");
    }

    #[test]
    fn periodicity_locks_onto_a_square_wave() {
        let mut fc = Forecaster::new();
        // 1 s period: 500 ms at 300 rps, 500 ms near-silent — several
        // full periods so the autocorrelation has evidence
        for cycle in 0..10 {
            let base = cycle as f64 * 1_000.0;
            feed_constant(&mut fc, 300.0, base, base + 500.0);
            feed_constant(&mut fc, 8.0, base + 500.0, base + 1_000.0);
        }
        fc.on_tick(10_000.0, 50.0);
        let period = fc.period_ms().expect("a 1 s square wave must produce a period lock");
        assert!(
            (period - 1_000.0).abs() <= 2.0 * BUCKET_MS,
            "locked period {period} ms must be within two buckets of the true 1000 ms"
        );
    }

    #[test]
    fn forecaster_is_a_pure_function_of_the_arrival_prefix() {
        let arrivals: Vec<f64> = (0..400).map(|i| i as f64 * 3.7).collect();
        let run = || {
            let mut fc = Forecaster::new();
            for (i, &t) in arrivals.iter().enumerate() {
                fc.on_arrival(t);
                if i % 10 == 0 {
                    fc.on_tick(t, 40.0);
                }
            }
            let f = fc.forecast(1_500.0);
            (f.rate_now_rps, f.rate_ahead(40.0), f.confidence, fc.err_stats())
        };
        assert_eq!(run(), run(), "identical arrival prefixes must yield identical forecasts");
    }

    #[test]
    fn error_tracking_scores_matured_predictions() {
        let mut fc = Forecaster::new();
        feed_constant(&mut fc, 200.0, 0.0, 500.0);
        for k in 0..40 {
            let t = 500.0 + k as f64 * 25.0;
            feed_constant(&mut fc, 200.0, t, t + 25.0);
            fc.on_tick(t, 50.0);
        }
        let (sum, n) = fc.err_stats();
        assert!(n > 0, "matured predictions must have been scored");
        // constant-rate stream: a working forecaster is not wildly off
        assert!(sum / n as f64 < 60.0, "mean abs err {:.1}% too large", sum / n as f64);
    }

    #[test]
    fn quantize_is_idempotent_and_on_grid() {
        for x in [0.0, 0.5, 1.0 / 3.0, 123.456_789, -7.1e-7] {
            let q = quantize(x);
            assert_eq!(quantize(q), q);
            assert!((q - x).abs() <= STATE_GRID);
        }
    }
}
