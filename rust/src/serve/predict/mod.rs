//! Predictive, energy-aware control plane: online arrival forecasting
//! ([`forecast`]) and the forecast-driven autoscale controller
//! ([`PredictivePolicy`]).
//!
//! The reactive controllers (queue-depth, attainment, the swap-aware
//! planner) all share a structural latency: they cannot act until the
//! damage — queued requests, missed SLOs, starved servers — is already
//! observable. This subsystem moves the control plane ahead of the
//! trace: a [`Forecaster`] watches the arrival stream on the coordinator
//! thread and the controllers spend the forecast on actions whose cost
//! is exactly a *lead time* — waking a server (its wake latency), hot-
//! swapping an engine (its stream-in time). When the forecast is right,
//! capacity is ready the moment the burst lands and `mean_reaction_ms`
//! collapses to the wake latency alone; when confidence is low, every
//! consumer degrades to its reactive baseline, so prediction is strictly
//! additive.
//!
//! The division of labor mirrors the reactive stack:
//!
//! * [`Forecaster`] (in [`forecast`]) — pure estimation, fed fresh
//!   arrivals and control ticks by the event loop.
//! * [`PredictivePolicy`] — an [`AutoscalePolicy`] that pre-wakes on
//!   forecast pressure and sleeps early on forecast troughs, wrapping a
//!   reactive [`QueueDepthPolicy`] as both safety net and low-confidence
//!   fallback.
//! * [`super::Router::plan_prefetch`] / [`super::Router::plan_reselect`]
//!   — policy-independent swap planners the event loop invokes at
//!   control ticks from the same forecast (prefetch a faster engine
//!   ahead of a burst; re-select a cheaper compliant engine when load
//!   will stay low).
//!
//! Everything is deterministic and `--jobs`-invariant: the forecaster
//! only consumes coordinator-side streams (arrival order, tick times),
//! and the controllers are pure state machines over its output.

pub mod forecast;

pub use forecast::{Forecaster, RateForecast};

use super::autoscale::{AutoscalePolicy, QueueDepthPolicy, ScaleDecision, ScaleSignals};
use super::router::FleetView;

/// Forecast confidence below which [`PredictivePolicy`] defers entirely
/// to its reactive fallback.
pub const PREDICT_CONFIDENCE_GATE: f64 = 0.35;

/// Pre-wake when the forecast rate at the wake horizon exceeds this
/// fraction of the committed (active + waking) capacity — the headroom
/// margin that fires the wake *before* saturation.
pub const PREDICT_UP_FACTOR: f64 = 0.9;

/// Sleep early when the forecast rate falls below this fraction of what
/// the fleet would still serve after draining one server. The wide gap
/// to [`PREDICT_UP_FACTOR`] is the anti-flap dead band.
pub const PREDICT_DOWN_FACTOR: f64 = 0.6;

/// Consecutive forecast-trough ticks before an early sleep fires —
/// matches the reactive controllers' consecutive-tick hysteresis.
pub const PREDICT_DOWN_TICKS: u32 = 2;

/// One control tick's forecast, already priced against the fleet by the
/// event loop (the policy sees rates and capacities, not servers): the
/// look-ahead rate is evaluated at the horizon of the *next concrete
/// wake* — the wake latency of the lowest-index asleep server plus one
/// control interval — so "will demand outrun capacity" and "can the wake
/// finish in time" are the same comparison.
#[derive(Clone, Copy, Debug)]
pub struct ForecastObs {
    /// Filtered arrival rate right now, requests/s.
    pub rate_now_rps: f64,
    /// Forecast arrival rate at the pre-wake horizon, requests/s.
    pub rate_ahead_rps: f64,
    /// The horizon `rate_ahead_rps` was evaluated at, ms.
    pub horizon_ms: f64,
    /// Forecast confidence in `[0, 1]` ([`RateForecast::confidence`]).
    pub confidence: f64,
    /// Serving capacity already committed: best-compliant-variant
    /// capacity summed over active servers *and wakes in flight* (so a
    /// ramp of pre-wakes converges instead of overshooting).
    pub committed_capacity_rps: f64,
    /// Capacity the next concrete wake would add; 0 when nothing can be
    /// woken (no asleep server, or the `max_active` bound is reached).
    pub next_wake_capacity_rps: f64,
    /// Capacity that would be lost by draining the idlest active server;
    /// 0 when draining is impossible (already at `min_active`).
    pub drain_capacity_rps: f64,
}

/// Forecast-driven autoscale controller: pre-wake ahead of forecast
/// pressure, sleep early on forecast troughs, degrade to reactive
/// queue-depth control when the forecast cannot be trusted.
///
/// Decision order per tick (see [`PredictivePolicy::decide`]):
/// 1. The wrapped reactive fallback always runs, keeping its hysteresis
///    state warm across confidence transitions.
/// 2. No forecast delivered, or confidence below
///    [`PREDICT_CONFIDENCE_GATE`] → the fallback's decision stands.
/// 3. A reactive scale-up is honored even when confident — observed
///    queue pressure means the forecast already missed; prediction must
///    never be slower than reaction.
/// 4. Otherwise capacity follows the forecast: wake when demand at the
///    wake horizon clears [`PREDICT_UP_FACTOR`] of committed capacity
///    (reaction clock anchored at *this* tick — the wake itself is the
///    only remaining latency), drain after [`PREDICT_DOWN_TICKS`]
///    consecutive trough ticks.
pub struct PredictivePolicy {
    fallback: QueueDepthPolicy,
    obs: Option<ForecastObs>,
    low_ticks: u32,
    prewakes: u64,
}

impl PredictivePolicy {
    /// Wrap the reactive fallback the policy degrades to.
    pub fn new(fallback: QueueDepthPolicy) -> PredictivePolicy {
        PredictivePolicy { fallback, obs: None, low_ticks: 0, prewakes: 0 }
    }
}

impl AutoscalePolicy for PredictivePolicy {
    fn name(&self) -> &'static str {
        super::autoscale::ScalePolicy::NAMES[3]
    }

    fn observe_forecast(&mut self, obs: &ForecastObs) {
        self.obs = Some(*obs);
    }

    fn decide(&mut self, view: &FleetView, sig: &ScaleSignals) -> ScaleDecision {
        // the fallback's state machine advances every tick so its
        // episode anchors and consecutive-tick counters stay correct
        // whenever control falls back to it
        let reactive = self.fallback.decide(view, sig);
        let Some(obs) = self.obs.take() else {
            return reactive;
        };
        if obs.confidence < PREDICT_CONFIDENCE_GATE {
            self.low_ticks = 0;
            return reactive;
        }
        if matches!(reactive, ScaleDecision::Up { .. }) {
            // observed pressure the forecast missed: react immediately
            self.low_ticks = 0;
            return reactive;
        }
        if obs.next_wake_capacity_rps > 0.0
            && obs.rate_ahead_rps > PREDICT_UP_FACTOR * obs.committed_capacity_rps
        {
            // pre-wake: the reaction clock starts now, so the eventual
            // wake reports only its own latency — no detection lag
            self.low_ticks = 0;
            self.prewakes += 1;
            return ScaleDecision::Up { since_ms: sig.now_ms };
        }
        if obs.drain_capacity_rps > 0.0
            && obs.rate_ahead_rps
                < PREDICT_DOWN_FACTOR * (obs.committed_capacity_rps - obs.drain_capacity_rps)
        {
            self.low_ticks += 1;
            if self.low_ticks >= PREDICT_DOWN_TICKS {
                self.low_ticks = 0;
                return ScaleDecision::Down;
            }
            return ScaleDecision::Hold;
        }
        // confident and in the dead band: capacity follows the forecast,
        // so reactive drains are suppressed (an empty queue now is not
        // evidence the next burst is far away — the forecast decides)
        self.low_ticks = 0;
        ScaleDecision::Hold
    }

    fn prewakes(&self) -> u64 {
        self.prewakes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::autoscale::SCALE_CONSECUTIVE;

    struct ViewState {
        backlog: Vec<f64>,
        queued: Vec<usize>,
        resident: Vec<Vec<bool>>,
        unavail: Vec<bool>,
    }

    impl ViewState {
        fn new(n: usize) -> ViewState {
            ViewState {
                backlog: vec![0.0; n],
                queued: vec![0; n],
                resident: vec![vec![true]; n],
                unavail: vec![false; n],
            }
        }

        fn view(&self, now: f64) -> FleetView<'_> {
            FleetView {
                now_ms: now,
                backlog_ms: &self.backlog,
                queued: &self.queued,
                resident: &self.resident,
                unavailable: &self.unavail,
            }
        }
    }

    fn sig(now: f64, queue_ewma: f64) -> ScaleSignals {
        ScaleSignals {
            now_ms: now,
            active: 2,
            waking: 0,
            draining: 0,
            asleep: 2,
            queue_per_active: queue_ewma,
            queue_ewma,
            window_attainment: 1.0,
            attainment_ewma: 1.0,
        }
    }

    fn obs(rate_ahead: f64, confidence: f64) -> ForecastObs {
        ForecastObs {
            rate_now_rps: rate_ahead,
            rate_ahead_rps: rate_ahead,
            horizon_ms: 10.0,
            confidence,
            committed_capacity_rps: 1_000.0,
            next_wake_capacity_rps: 500.0,
            drain_capacity_rps: 500.0,
        }
    }

    fn policy() -> PredictivePolicy {
        PredictivePolicy::new(QueueDepthPolicy::new(8.0, 1.0, SCALE_CONSECUTIVE))
    }

    #[test]
    fn prewakes_when_forecast_outruns_capacity() {
        let st = ViewState::new(4);
        let mut p = policy();
        // 950 rps forecast > 0.9 × 1000 rps committed → wake now, with
        // the reaction clock anchored at this very tick
        p.observe_forecast(&obs(950.0, 0.9));
        assert_eq!(p.decide(&st.view(100.0), &sig(100.0, 0.0)), ScaleDecision::Up {
            since_ms: 100.0
        });
        assert_eq!(p.prewakes(), 1);
        // comfortable headroom → hold, and reactive drains are suppressed
        p.observe_forecast(&obs(800.0, 0.9));
        assert_eq!(p.decide(&st.view(150.0), &sig(150.0, 0.0)), ScaleDecision::Hold);
        assert_eq!(p.prewakes(), 1);
    }

    #[test]
    fn low_confidence_degrades_to_reactive_queue_depth() {
        let st = ViewState::new(4);
        let mut p = policy();
        // a confident forecast would prewake here — but confidence is low,
        // so the queue-depth fallback governs: two pressured ticks → Up
        // anchored at the episode start, exactly the reactive contract
        p.observe_forecast(&obs(2_000.0, 0.1));
        assert_eq!(p.decide(&st.view(100.0), &sig(100.0, 12.0)), ScaleDecision::Hold);
        p.observe_forecast(&obs(2_000.0, 0.1));
        assert_eq!(
            p.decide(&st.view(150.0), &sig(150.0, 12.0)),
            ScaleDecision::Up { since_ms: 100.0 }
        );
        assert_eq!(p.prewakes(), 0, "fallback wakes are not pre-wakes");
    }

    #[test]
    fn observed_pressure_overrides_the_forecast() {
        let st = ViewState::new(4);
        let mut p = policy();
        // forecast says all-clear, but the queue is already deep: the
        // reactive safety net fires (the forecast was simply wrong)
        p.observe_forecast(&obs(100.0, 0.95));
        assert_eq!(p.decide(&st.view(100.0), &sig(100.0, 12.0)), ScaleDecision::Hold);
        p.observe_forecast(&obs(100.0, 0.95));
        assert_eq!(
            p.decide(&st.view(150.0), &sig(150.0, 12.0)),
            ScaleDecision::Up { since_ms: 100.0 }
        );
    }

    #[test]
    fn early_sleep_needs_consecutive_trough_ticks() {
        let st = ViewState::new(4);
        let mut p = policy();
        // trough: 200 rps < 0.6 × (1000 − 500) = 300 rps
        p.observe_forecast(&obs(200.0, 0.9));
        assert_eq!(p.decide(&st.view(100.0), &sig(100.0, 0.0)), ScaleDecision::Hold);
        p.observe_forecast(&obs(200.0, 0.9));
        assert_eq!(p.decide(&st.view(150.0), &sig(150.0, 0.0)), ScaleDecision::Down);
        // a burst forecast between trough ticks resets the run
        p.observe_forecast(&obs(200.0, 0.9));
        assert_eq!(p.decide(&st.view(200.0), &sig(200.0, 0.0)), ScaleDecision::Hold);
        p.observe_forecast(&obs(800.0, 0.9));
        assert_eq!(p.decide(&st.view(250.0), &sig(250.0, 0.0)), ScaleDecision::Hold);
        p.observe_forecast(&obs(200.0, 0.9));
        assert_eq!(p.decide(&st.view(300.0), &sig(300.0, 0.0)), ScaleDecision::Hold);
        p.observe_forecast(&obs(200.0, 0.9));
        assert_eq!(p.decide(&st.view(350.0), &sig(350.0, 0.0)), ScaleDecision::Down);
    }

    #[test]
    fn no_forecast_at_all_is_pure_fallback() {
        let st = ViewState::new(4);
        let mut p = policy();
        assert_eq!(p.decide(&st.view(0.0), &sig(0.0, 4.0)), ScaleDecision::Hold);
        assert_eq!(p.decide(&st.view(50.0), &sig(50.0, 0.5)), ScaleDecision::Hold);
        assert_eq!(p.decide(&st.view(100.0), &sig(100.0, 0.2)), ScaleDecision::Down);
    }
}
