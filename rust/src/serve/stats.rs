//! Constant-memory latency telemetry: a deterministic, mergeable,
//! fixed-edge log-binned histogram plus exact streaming mean/max/count.
//!
//! The pre-streaming simulator kept every completion latency in a
//! `Vec<f64>` and sorted it once at the end — O(n) resident memory and an
//! O(n log n) finish, which caps `hqp serve` far below the 10⁶–10⁷
//! request traces ROADMAP item 3 asks for. [`LatencyStats`] replaces it
//! with state whose size depends only on the *range* of observed
//! latencies, never on how many there were:
//!
//! * **Fixed log-binned edges.** Each power of two of latency (an
//!   *octave*) is split into [`LatencyStats::BINS_PER_OCTAVE`] equal
//!   sub-bins, keyed directly off the IEEE-754 bit pattern (exponent +
//!   top mantissa bits) — pure integer arithmetic, no `ln()`, so the
//!   value→bin map is exact and platform-deterministic. Edges are fixed
//!   up front (never rescaled), so two histograms built from different
//!   shards — or different runs — always share the same bins.
//! * **Mergeable u64 counts.** Merging shard histograms is integer
//!   addition bin-by-bin; counts commute, and the accompanying f64 sum is
//!   folded in shard-index order like every other f64 total, so the
//!   jobs-invariance byte-identity contract (DESIGN.md §Parallelism)
//!   holds exactly: `--jobs N` changes thread count, never bytes.
//! * **Bounded quantile error.** A quantile query returns the midpoint of
//!   the bin holding the nearest-rank sample. The bin width is
//!   `2^-BINS_PER_OCTAVE_BITS` of the bin's lower edge, so the midpoint
//!   is within [`LatencyStats::QUANTILE_REL_ERROR`] (= 2⁻⁸ ≈ 0.39 %,
//!   documented bound ≤ 1 %) of the exact sample, relative. Mean, max and
//!   count stay *exact* (streamed alongside).
//!
//! The rank definition is unchanged from the pre-histogram simulator —
//! nearest rank, `((n-1)·p).round()` — pinned here by unit tests on
//! hand-built latency sets (see [`exact_quantile`], kept as the reference
//! implementation), together with an exact-vs-histogram error-bound test.

use std::collections::BTreeMap;

/// Nearest-rank quantile over an already-sorted slice — the exact
/// percentile definition the simulator has always used
/// (`latencies[((n-1)·p).round()]`), kept as the reference the histogram
/// is tested against. Returns 0.0 for an empty slice.
pub fn exact_quantile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    sorted[((sorted.len() - 1) as f64 * p).round() as usize]
}

/// Streaming latency telemetry for one run (or one shard of one run):
/// a sparse fixed-edge log-binned histogram with exact mean/max/count.
/// Memory is O(occupied bins) — bounded by the latency *range* (octaves ×
/// [`LatencyStats::BINS_PER_OCTAVE`]), independent of the request count.
#[derive(Clone, Debug, PartialEq)]
pub struct LatencyStats {
    /// Sparse bin counts, keyed by [`bin_of`]. `BTreeMap` iterates in
    /// ascending bin (= ascending latency) order, which is what the
    /// cumulative quantile scan needs.
    bins: BTreeMap<u32, u64>,
    count: u64,
    sum_ms: f64,
    max_ms: f64,
}

impl LatencyStats {
    /// Top mantissa bits used for sub-bins: each octave splits into
    /// 2⁷ = 128 fixed bins.
    pub const SUBBUCKET_BITS: u32 = 7;

    /// Bins per power of two of latency — the recorded bin config
    /// ([`super::Summary::latency_hist`] carries it into every summary).
    pub const BINS_PER_OCTAVE: u32 = 1 << Self::SUBBUCKET_BITS;

    /// Upper bound on the relative error of any histogram-derived
    /// quantile: half a bin width over the bin's lower edge,
    /// `2^-(SUBBUCKET_BITS+1)` = 1/256 ≈ 0.39 % — comfortably inside the
    /// documented ≤ 1 % contract (DESIGN.md §Serving, Memory & streaming).
    pub const QUANTILE_REL_ERROR: f64 = 1.0 / 256.0;

    pub fn new() -> LatencyStats {
        LatencyStats { bins: BTreeMap::new(), count: 0, sum_ms: 0.0, max_ms: 0.0 }
    }

    /// Record one latency sample (ms). Non-positive values land in the
    /// underflow bin 0 (latency 0 is impossible for a served request, but
    /// the histogram must not lose counts whatever it is fed).
    pub fn record(&mut self, ms: f64) {
        *self.bins.entry(bin_of(ms)).or_insert(0) += 1;
        self.count += 1;
        self.sum_ms += ms;
        if ms > self.max_ms {
            self.max_ms = ms;
        }
    }

    /// Fold another histogram into this one: u64 bin counts add
    /// bin-by-bin, the f64 sum adds in call order — callers merge shards
    /// in shard-index order, the same deterministic fold every other
    /// accumulator uses (so summaries stay byte-identical at any
    /// `--jobs`).
    pub fn merge(&mut self, other: &LatencyStats) {
        for (&bin, &n) in &other.bins {
            *self.bins.entry(bin).or_insert(0) += n;
        }
        self.count += other.count;
        self.sum_ms += other.sum_ms;
        if other.max_ms > self.max_ms {
            self.max_ms = other.max_ms;
        }
    }

    /// Samples recorded (exact).
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Exact streaming mean, ms (0.0 when empty).
    pub fn mean_ms(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_ms / self.count as f64
        }
    }

    /// Exact maximum recorded sample, ms (0.0 when empty).
    pub fn max_ms(&self) -> f64 {
        self.max_ms
    }

    /// Occupied (non-zero) bins — the resident telemetry footprint the
    /// stress bench asserts is independent of request count.
    pub fn occupied_bins(&self) -> usize {
        self.bins.len()
    }

    /// Nearest-rank quantile from the histogram: the midpoint of the bin
    /// holding sample rank `((count-1)·p).round()` — within
    /// [`LatencyStats::QUANTILE_REL_ERROR`] of [`exact_quantile`] on the
    /// same multiset, relative. Returns 0.0 when empty.
    pub fn quantile(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let rank = ((self.count - 1) as f64 * p).round() as u64;
        let mut seen = 0u64;
        for (&bin, &n) in &self.bins {
            seen += n;
            if seen > rank {
                return bin_mid(bin);
            }
        }
        // unreachable: rank < count and the bins sum to count
        bin_mid(self.bins.keys().next_back().copied().unwrap_or(0))
    }
}

impl Default for LatencyStats {
    fn default() -> Self {
        LatencyStats::new()
    }
}

/// Map a latency to its fixed bin: the value's IEEE-754 exponent plus its
/// top [`LatencyStats::SUBBUCKET_BITS`] mantissa bits, which is monotone
/// in the value. Bin 0 is the underflow bin (non-positive input and the
/// bottom of the subnormal range).
fn bin_of(ms: f64) -> u32 {
    if ms <= 0.0 {
        return 0;
    }
    (ms.to_bits() >> (52 - LatencyStats::SUBBUCKET_BITS)) as u32
}

/// The midpoint of a bin — the representative a quantile query returns.
/// Reconstructed exactly from the bin index (the bin's edges are the two
/// adjacent `(exponent, top-mantissa)` bit patterns).
fn bin_mid(bin: u32) -> f64 {
    let shift = 52 - LatencyStats::SUBBUCKET_BITS;
    let lo = f64::from_bits((bin as u64) << shift);
    let hi = f64::from_bits(((bin as u64) + 1) << shift);
    lo / 2.0 + hi / 2.0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::prng::Prng;

    // ---- the pinned exact-percentile semantics -------------------------
    // The simulator's percentile definition is nearest rank with
    // ((n-1)·p).round() — these hand-built sets pin it exactly (the
    // behavior `build_summary` had when it sorted a Vec<f64>).

    #[test]
    fn exact_quantile_is_nearest_rank() {
        assert_eq!(exact_quantile(&[], 0.5), 0.0);
        assert_eq!(exact_quantile(&[10.0], 0.0), 10.0);
        assert_eq!(exact_quantile(&[10.0], 0.5), 10.0);
        assert_eq!(exact_quantile(&[10.0], 1.0), 10.0);
        // n=2: rank = (1·0.5).round() = 1 (f64::round is half-away-from-zero)
        assert_eq!(exact_quantile(&[1.0, 2.0], 0.5), 2.0);
        // n=4 (the mod.rs full-batch scenario's multiset): p50 rank =
        // (3·0.5).round() = 2 → the third-smallest
        assert_eq!(exact_quantile(&[16.0, 17.0, 30.0, 31.0], 0.50), 30.0);
        assert_eq!(exact_quantile(&[16.0, 17.0, 30.0, 31.0], 0.95), 31.0);
        assert_eq!(exact_quantile(&[16.0, 17.0, 30.0, 31.0], 0.99), 31.0);
        // n=5: p50 rank = 2 → the true median
        assert_eq!(exact_quantile(&[1.0, 2.0, 3.0, 4.0, 5.0], 0.50), 3.0);
        // n=11: p95 rank = (10·0.95).round() = 10 → the max
        let v: Vec<f64> = (1..=11).map(|i| i as f64).collect();
        assert_eq!(exact_quantile(&v, 0.95), 11.0);
        assert_eq!(exact_quantile(&v, 0.90), 9.0);
    }

    #[test]
    fn histogram_tracks_exact_count_mean_max() {
        let mut h = LatencyStats::new();
        for ms in [17.0, 16.0, 31.0, 30.0] {
            h.record(ms);
        }
        assert_eq!(h.count(), 4);
        assert_eq!(h.max_ms(), 31.0);
        assert!((h.mean_ms() - 23.5).abs() < 1e-12, "mean stays exact");
        let empty = LatencyStats::new();
        assert_eq!(empty.count(), 0);
        assert_eq!(empty.mean_ms(), 0.0);
        assert_eq!(empty.max_ms(), 0.0);
        assert_eq!(empty.quantile(0.5), 0.0);
    }

    #[test]
    fn quantile_matches_exact_within_the_documented_bound() {
        // exact-vs-histogram error bound, property-style: random latency
        // multisets over several orders of magnitude, every percentile the
        // summary reports — the histogram must sit within
        // QUANTILE_REL_ERROR of the exact nearest-rank value
        let mut rng = Prng::new(0xB1245);
        for case_no in 0..200 {
            let n = rng.below(400) + 1;
            let mut vals: Vec<f64> =
                (0..n).map(|_| 0.05 + rng.next_f64() * 5_000.0).collect();
            let mut h = LatencyStats::new();
            for &v in &vals {
                h.record(v);
            }
            vals.sort_by(f64::total_cmp);
            for p in [0.0, 0.25, 0.50, 0.90, 0.95, 0.99, 1.0] {
                let exact = exact_quantile(&vals, p);
                let got = h.quantile(p);
                assert!(
                    (got - exact).abs() <= exact * LatencyStats::QUANTILE_REL_ERROR,
                    "case {case_no} p{p}: hist {got} vs exact {exact} \
                     (rel err {:.5} > {:.5})",
                    ((got - exact) / exact).abs(),
                    LatencyStats::QUANTILE_REL_ERROR,
                );
            }
            assert_eq!(h.count(), vals.len() as u64);
            assert_eq!(h.max_ms(), *vals.last().unwrap());
        }
    }

    #[test]
    fn quantiles_are_monotone_in_p() {
        let mut rng = Prng::new(0x0514D);
        let mut h = LatencyStats::new();
        for _ in 0..1000 {
            h.record(0.1 + rng.next_f64() * 300.0);
        }
        let ps = [0.0, 0.1, 0.5, 0.9, 0.95, 0.99, 1.0];
        for w in ps.windows(2) {
            assert!(h.quantile(w[0]) <= h.quantile(w[1]), "p{} > p{}", w[0], w[1]);
        }
    }

    #[test]
    fn merge_is_bin_exact_and_shard_order_deterministic() {
        // split one sample stream into "shards", merge in shard order:
        // bins/count/max must equal the unsharded histogram exactly, and
        // the merge must be reproducible (same shards, same bytes)
        let mut rng = Prng::new(0x3E26E);
        let vals: Vec<f64> = (0..512).map(|_| 0.2 + rng.next_f64() * 900.0).collect();
        let mut whole = LatencyStats::new();
        for &v in &vals {
            whole.record(v);
        }
        let mut shards: Vec<LatencyStats> = (0..4).map(|_| LatencyStats::new()).collect();
        for (i, &v) in vals.iter().enumerate() {
            shards[i % 4].record(v);
        }
        let fold = |shards: &[LatencyStats]| {
            let mut m = LatencyStats::new();
            for sh in shards {
                m.merge(sh);
            }
            m
        };
        let merged = fold(&shards);
        assert_eq!(merged, fold(&shards), "same shard order must give the same bytes");
        assert_eq!(merged.bins, whole.bins, "u64 bin counts add exactly");
        assert_eq!(merged.count(), whole.count());
        assert_eq!(merged.max_ms(), whole.max_ms());
        // the f64 sum is order-dependent in the last ulp (why merges fold
        // in shard-index order); the value itself is the same mean
        assert!((merged.mean_ms() - whole.mean_ms()).abs() < 1e-9);
        for p in [0.5, 0.95, 0.99] {
            assert_eq!(merged.quantile(p), whole.quantile(p), "same bins, same quantile");
        }
    }

    #[test]
    fn footprint_is_bounded_by_range_not_count() {
        // 100x more samples from the same distribution may refine the
        // tail, but the occupied-bin footprint is capped by the value
        // range: octaves(range) × BINS_PER_OCTAVE, never O(n)
        let range_octaves = (1.0f64..1024.0).end.log2() - (1.0f64..1024.0).start.log2();
        let cap = (range_octaves as usize + 2) * LatencyStats::BINS_PER_OCTAVE as usize;
        for n in [1_000usize, 100_000] {
            let mut rng = Prng::new(0xF007);
            let mut h = LatencyStats::new();
            for _ in 0..n {
                h.record(1.0 + rng.next_f64() * 1023.0);
            }
            assert!(
                h.occupied_bins() <= cap,
                "{n} samples occupy {} bins, cap {cap}",
                h.occupied_bins()
            );
        }
    }

    #[test]
    fn bin_edges_are_fixed_and_monotone() {
        // the value→bin map is monotone, and bin midpoints reconstruct to
        // within the bin (sanity on the bit-pattern arithmetic)
        let mut rng = Prng::new(0xED6E5);
        let mut prev = (0.0f64, 0u32);
        let mut vals: Vec<f64> = (0..2000).map(|_| rng.next_f64() * 1e4).collect();
        vals.sort_by(f64::total_cmp);
        for v in vals {
            let b = bin_of(v);
            assert!(b >= prev.1, "bin_of must be monotone: {v} < {} but bin went back", prev.0);
            prev = (v, b);
            if v > 0.0 {
                let mid = bin_mid(b);
                assert!(
                    (mid - v).abs() <= v * LatencyStats::QUANTILE_REL_ERROR,
                    "midpoint {mid} not within bound of {v}"
                );
            }
        }
        assert_eq!(bin_of(0.0), 0);
        assert_eq!(bin_of(-3.0), 0, "non-positive input lands in the underflow bin");
    }
}
